"""Bench-regression gate: diff a pivot-work smoke run against the committed
baseline and fail CI when the work-elimination engine or a pricing rule
regresses.

    python scripts/bench_gate.py /tmp/pivot_work_smoke.json \
        [--baseline BENCH_pivot_work.json] [--rel-drop 0.2]

Matching: smoke workloads are compared against the baseline's
``quick_workloads`` section (the committed bench re-runs the --quick
configuration exactly so (m, n, B) match; ``workloads`` is the fallback for
older baselines).  On every matching workload the gate fails when:

* solver statuses diverge anywhere (backends, scheduler, pricing rules) —
  these are exact invariants, no tolerance;
* ``reduction_scheduled`` drops more than ``--rel-drop`` (default 20%)
  relative to the baseline;
* any pricing rule's ``pivot_cut_vs_dantzig`` drops more than ``--rel-drop``
  relative, with a small absolute slack (``--cut-slack``) so rules whose
  baseline cut is already ~0 (dantzig itself, devex on tiny LPs) don't gate
  on noise;
* any revised-backend row's ``element_reduction_vs_tableau`` drops more than
  ``--rel-drop`` relative (only checked when the smoke measured backend
  rows, i.e. was not run with --backend tableau);
* a ``pdhg`` row (the tolerance-based first-order engine) regresses:
  status agreement with the exact tableau engine drops below
  baseline - 0.02, the relative objective error vs the tableau exceeds
  ``--pdhg-obj-ceiling`` (default 1e-3 — PDHG objectives are ~tol
  accurate, not exact), mean iteration count grows more than
  ``--rel-drop`` relative (the iteration-count regression bound: restarts
  or step sizes silently degrading shows up here first), or the
  compaction-scheduled pdhg solve stops agreeing with the monolithic one;
* a ``sparse_workloads`` row (shared-pattern sparse PDHG vs the dense
  engine on the staircase fixtures, core/sparse.py) regresses:
  sparse-vs-dense status agreement drops below baseline - 0.02, the
  relative objective gap vs the dense engine exceeds 2e-3 (same algorithm,
  different float-sum association), the per-iteration element-traffic
  ratio (dense/sparse, ~1/density — the tentpole's "stop paying for
  structural zeros" number) drops more than ``--rel-drop`` relative, or
  the sparse iteration count grows more than ``--rel-drop`` relative to
  the dense engine's on the same workload;
* a ``warm_workloads`` row (the warm-start engine re-solving a perturbed
  fixture trajectory, benchmarks/pivot_work.py measure_warm) regresses:
  any engine's ``work_ratio`` (warm/cold mean re-solve iterations)
  exceeds the hard 0.5 bound — a warm re-solve must cost at most half a
  cold one — or grows more than ``--rel-drop`` relative to the baseline
  (with a small absolute slack for ratios near zero), cold-vs-warm status
  agreement drops below baseline - 0.02, or the warm objective drifts
  more than 2e-3 relative from the cold one on commonly-optimal LPs;
  baselines predating the warm engine simply have no such rows, so old
  JSONs pass untouched;
* a ``bnb_workloads`` row (the branch-and-bound driver on the MIP
  fixtures, benchmarks/pivot_work.py measure_bnb) regresses: the driver
  stops proving optimality, the proven objective changes at all (the
  fixtures have integral optima — any drift is a wrong answer), warm
  frontiers stop beating cold ones (``work_ratio`` >= 1.0 hard, since
  warm and cold solve the same tree), or the ratio grows more than
  ``--rel-drop`` relative to the baseline;
* a ``pallas_workloads`` row (the tile kernels A/B'd against their JAX
  engines under the Pallas interpreter, benchmarks/pivot_work.py
  measure_pallas) regresses: a simplex kernel (tableau/revised) loses
  pivot-exactness (status agreement below 1.0 or iteration counts
  diverging from the engine — hard bounds, these kernels execute the
  engine's pivot sequence), the PDHG kernel's status agreement drops
  below baseline - 0.02, any kernel's scheduled (compaction) run stops
  agreeing with the engine, the executed element traffic of the scheduled
  run grows more than ``--rel-drop`` relative (the element-traffic
  ceiling: segment sizing or bucket shrinking silently degrading shows up
  here), or a bucket shrink the baseline recorded disappears; smoke runs
  predating the kernels simply lack the rows, and rows missing from an
  older *baseline* pass untouched;
* the pdhg row's ``malitsky_pock`` sub-row regresses: the adaptive rule's
  mean iteration count grows more than ``--rel-drop`` relative to
  baseline, its status agreement with the fixed-step rule drops below
  baseline - 0.02, or its iteration *cut* vs fixed goes negative (the
  linesearch must never cost more than the fixed step on the adversarial
  dense class);
* a workload's ``telemetry`` row (the on-device counter plane sourcing the
  pivot accounting, src/repro/obs/) regresses: the counters stop matching
  ``LPResult.iterations`` or the lockstep accounting (hard invariants —
  the match flags are recorded by the bench itself), the row vanishes from
  a smoke run whose baseline recorded one, or ``useful_pivots`` grows more
  than ``--rel-drop`` relative; baselines predating the telemetry plane
  simply lack the row and pass untouched;
* a ``general_workloads`` row (fixture-backed real instances through the
  MPS/canonicalization pipeline) regresses: per-backend status agreement
  with the float64 oracle drops below baseline - 0.02, relative objective
  error exceeds 2e-3, or the presolve-scaling f32 effect recorded in the
  baseline (``scaling.changes_f32``) disappears — status regressions on
  real instances fail CI here, not in a paper rerun.

Pivot counts and reductions are deterministic for a given seed/B/software
stack, so on one machine the gate only fires on real behavior changes; the
relative margin absorbs cross-platform float differences.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_pivot_work.json")


def _key(w: dict):
    return (w["m"], w["n"], w["B"])


def gate(current: dict, baseline: dict, *, rel_drop: float = 0.2,
         cut_slack: float = 0.02, pdhg_obj_ceiling: float = 1e-3) -> list:
    """Returns a list of human-readable failure strings (empty == pass)."""
    failures = []
    base_rows = {_key(w): w
                 for w in (baseline.get("quick_workloads")
                           or baseline.get("workloads", []))}
    mode = current.get("backends", "all")
    check_backends = mode in ("all", "revised")
    check_pdhg = mode in ("all", "pdhg")
    matched = 0
    for w in current.get("workloads", []):
        b = base_rows.get(_key(w))
        if b is None:
            continue
        matched += 1
        tag = f"{w['m']}x{w['n']} B={w['B']}"

        if not w.get("statuses_identical", True):
            failures.append(f"{tag}: solver statuses diverged")
        floor = b["reduction_scheduled"] * (1.0 - rel_drop)
        if w["reduction_scheduled"] < floor:
            failures.append(
                f"{tag}: reduction_scheduled {w['reduction_scheduled']:.3f} "
                f"< {floor:.3f} (baseline {b['reduction_scheduled']:.3f} "
                f"- {rel_drop:.0%})")

        # ---- telemetry row (counter-plane self-consistency) ---------------
        ct = w.get("telemetry")
        if ct is not None:
            # hard invariants regardless of baseline: the on-device counters
            # must agree exactly with LPResult.iterations and the lockstep
            # accounting — a false flag means the counter plane miscounts
            if not ct.get("iterations_match_result", True):
                failures.append(
                    f"{tag}: telemetry counters diverged from "
                    "LPResult.iterations (the on-device plane miscounts)")
            if not ct.get("iterations_match_lockstep", True):
                failures.append(
                    f"{tag}: telemetry counters diverged from the lockstep "
                    "pivot accounting")
        bt = b.get("telemetry")
        if bt is not None:
            # baselines predating the telemetry plane lack the row and pass
            # untouched; once recorded, a vanished row or growing pivot
            # count gates here
            if ct is None:
                failures.append(
                    f"{tag}: telemetry row missing from the smoke run "
                    "(baseline recorded counter-plane data)")
            else:
                piv_ceiling = bt["useful_pivots"] * (1.0 + rel_drop)
                if ct["useful_pivots"] > piv_ceiling:
                    failures.append(
                        f"{tag}: telemetry useful_pivots "
                        f"{ct['useful_pivots']} > {piv_ceiling:.0f} "
                        f"(baseline {bt['useful_pivots']} + {rel_drop:.0%} "
                        "— the pivot paths got longer)")

        for rule, br in b.get("rules", {}).items():
            cr = w.get("rules", {}).get(rule)
            if cr is None:
                failures.append(f"{tag}: pricing rule {rule!r} missing")
                continue
            if not cr.get("statuses_match_dantzig", True):
                failures.append(f"{tag}: rule {rule!r} status divergence")
            cut_floor = (br["pivot_cut_vs_dantzig"] * (1.0 - rel_drop)
                         - cut_slack)
            if cr["pivot_cut_vs_dantzig"] < cut_floor:
                failures.append(
                    f"{tag}: rule {rule!r} pivot_cut_vs_dantzig "
                    f"{cr['pivot_cut_vs_dantzig']:.3f} < {cut_floor:.3f} "
                    f"(baseline {br['pivot_cut_vs_dantzig']:.3f} "
                    f"- {rel_drop:.0%})")

        # ---- first-order engine row (tolerance-based invariants) ----------
        bp = b.get("pdhg") or {}
        if check_pdhg and bp:
            cp = w.get("pdhg") or {}
            if not cp:
                failures.append(f"{tag}: pdhg row missing from the smoke run")
            else:
                floor = bp["status_match_tableau_frac"] - 0.02
                if cp["status_match_tableau_frac"] < floor:
                    failures.append(
                        f"{tag}: pdhg status agreement with tableau "
                        f"{cp['status_match_tableau_frac']:.3f} < {floor:.3f}"
                        f" (baseline {bp['status_match_tableau_frac']:.3f})")
                if cp["rel_obj_err_vs_tableau"] > pdhg_obj_ceiling:
                    failures.append(
                        f"{tag}: pdhg rel_obj_err_vs_tableau "
                        f"{cp['rel_obj_err_vs_tableau']:.2e} > "
                        f"{pdhg_obj_ceiling:.0e}")
                it_ceiling = bp["iters_mean"] * (1.0 + rel_drop)
                if cp["iters_mean"] > it_ceiling:
                    failures.append(
                        f"{tag}: pdhg iters_mean {cp['iters_mean']:.0f} > "
                        f"{it_ceiling:.0f} (baseline {bp['iters_mean']:.0f} "
                        f"+ {rel_drop:.0%} — restart/step-size regression)")
                sched_floor = bp["scheduled_status_match_frac"] - 0.02
                if cp["scheduled_status_match_frac"] < sched_floor:
                    failures.append(
                        f"{tag}: pdhg compaction round-trip agreement "
                        f"{cp['scheduled_status_match_frac']:.3f} < "
                        f"{sched_floor:.3f}")
                bmp = bp.get("malitsky_pock") or {}
                cmp_row = cp.get("malitsky_pock") or {}
                if bmp and not cmp_row:
                    failures.append(
                        f"{tag}: pdhg malitsky_pock sub-row missing from "
                        "the smoke run")
                elif bmp:
                    mp_ceiling = bmp["iters_mean"] * (1.0 + rel_drop)
                    if cmp_row["iters_mean"] > mp_ceiling:
                        failures.append(
                            f"{tag}: malitsky_pock iters_mean "
                            f"{cmp_row['iters_mean']:.0f} > "
                            f"{mp_ceiling:.0f} (baseline "
                            f"{bmp['iters_mean']:.0f} + {rel_drop:.0%} — "
                            "the linesearch stopped paying)")
                    mp_floor = bmp["status_match_fixed_frac"] - 0.02
                    if cmp_row["status_match_fixed_frac"] < mp_floor:
                        failures.append(
                            f"{tag}: malitsky_pock status agreement with "
                            f"the fixed rule "
                            f"{cmp_row['status_match_fixed_frac']:.3f} < "
                            f"{mp_floor:.3f}")
                    if cmp_row["iters_cut_vs_fixed"] < 0.0:
                        failures.append(
                            f"{tag}: malitsky_pock iteration cut vs fixed "
                            f"{cmp_row['iters_cut_vs_fixed']:+.1%} < 0 — "
                            "the adaptive rule now costs more than the "
                            "fixed step")

        if not check_backends:
            continue
        for name, bb in (b.get("backends") or {}).items():
            if name == "tableau":
                continue
            cb = (w.get("backends") or {}).get(name)
            if cb is None:
                failures.append(f"{tag}: backend row {name!r} missing")
                continue
            if not cb.get("statuses_match_tableau", True):
                failures.append(
                    f"{tag}: backend {name!r} statuses diverged from tableau")
            red_floor = (bb["element_reduction_vs_tableau"]
                         * (1.0 - rel_drop))
            if cb["element_reduction_vs_tableau"] < red_floor:
                failures.append(
                    f"{tag}: backend {name!r} element_reduction_vs_tableau "
                    f"{cb['element_reduction_vs_tableau']:.2f} < "
                    f"{red_floor:.2f} (baseline "
                    f"{bb['element_reduction_vs_tableau']:.2f} "
                    f"- {rel_drop:.0%})")
    if matched == 0:
        failures.append(
            "no workload in the smoke run matches the baseline on (m, n, B) "
            "— regenerate BENCH_pivot_work.json (its quick_workloads section "
            "is the gate's comparison target)")

    # ---- general-form (fixture-backed) rows -------------------------------
    # a per-engine smoke leg (--backend tableau|revised|pdhg) measures only
    # its own engine's general rows; the gate compares what it measured
    measured = {"tableau", "revised", "pdhg"} if mode == "all" else {mode}
    cur_gen = {(w["fixture"], w["B"]): w
               for w in current.get("general_workloads", [])}
    for bg in baseline.get("general_workloads", []):
        key = (bg["fixture"], bg["B"])
        tag = f"general {bg['fixture']} B={bg['B']}"
        cg = cur_gen.get(key)
        if cg is None:
            failures.append(f"{tag}: row missing from the smoke run")
            continue
        for backend, bb in bg.get("backends", {}).items():
            if backend not in measured:
                continue
            cb = cg.get("backends", {}).get(backend)
            if cb is None:
                failures.append(f"{tag}: backend {backend!r} missing")
                continue
            floor = bb["status_match_oracle_frac"] - 0.02
            if cb["status_match_oracle_frac"] < floor:
                failures.append(
                    f"{tag}: {backend} status agreement with the f64 oracle "
                    f"{cb['status_match_oracle_frac']:.3f} < {floor:.3f} "
                    f"(baseline {bb['status_match_oracle_frac']:.3f})")
            if cb["rel_obj_err"] > 2e-3:
                failures.append(
                    f"{tag}: {backend} rel_obj_err {cb['rel_obj_err']:.2e} "
                    "> 2e-3 after recovery")
        if bg.get("scaling", {}).get("changes_f32") \
                and not cg.get("scaling", {}).get("changes_f32"):
            failures.append(
                f"{tag}: presolve-scaling f32 effect disappeared (baseline "
                "recorded a scaled-vs-unscaled difference; the smoke run "
                "shows none — the equilibration pass likely stopped running)")

    # ---- warm-start rows (re-solve work-elimination invariants) -----------
    cur_warm = {(w["fixture"], w["B"], w["K"]): w
                for w in current.get("warm_workloads", [])}
    for bw in baseline.get("warm_workloads", []):
        key = (bw["fixture"], bw["B"], bw["K"])
        tag = f"warm {bw['fixture']} B={bw['B']} K={bw['K']}"
        cw = cur_warm.get(key)
        if cw is None:
            failures.append(f"{tag}: row missing from the smoke run")
            continue
        for backend, bb in bw.get("backends", {}).items():
            if backend not in measured:
                continue
            cb = cw.get("backends", {}).get(backend)
            if cb is None:
                failures.append(f"{tag}: backend {backend!r} missing")
                continue
            if cb["work_ratio"] > 0.5:
                failures.append(
                    f"{tag}: {backend} work_ratio {cb['work_ratio']:.3f} > "
                    "0.50 (hard bound: a warm re-solve must cost at most "
                    "half a cold one)")
            ceiling = bb["work_ratio"] * (1.0 + rel_drop) + cut_slack
            if cb["work_ratio"] > ceiling:
                failures.append(
                    f"{tag}: {backend} work_ratio {cb['work_ratio']:.3f} > "
                    f"{ceiling:.3f} (baseline {bb['work_ratio']:.3f} "
                    f"+ {rel_drop:.0%} — warm starts stopped eliminating "
                    "re-solve work)")
            floor = bb["status_match_frac"] - 0.02
            if cb["status_match_frac"] < floor:
                failures.append(
                    f"{tag}: {backend} cold-vs-warm status agreement "
                    f"{cb['status_match_frac']:.3f} < {floor:.3f} "
                    f"(baseline {bb['status_match_frac']:.3f})")
            if cb["rel_obj_err"] > 2e-3:
                failures.append(
                    f"{tag}: {backend} warm rel_obj_err "
                    f"{cb['rel_obj_err']:.2e} > 2e-3 — warm starts changed "
                    "the answer, not just the path")

    # ---- branch-and-bound rows (MIP driver invariants) --------------------
    cur_bnb = {(w["fixture"], w["frontier"]): w
               for w in current.get("bnb_workloads", [])}
    for bn in baseline.get("bnb_workloads", []):
        key = (bn["fixture"], bn["frontier"])
        tag = f"bnb {bn['fixture']} frontier={bn['frontier']}"
        cn = cur_bnb.get(key)
        if cn is None:
            failures.append(f"{tag}: row missing from the smoke run")
            continue
        for backend, bb in bn.get("backends", {}).items():
            if backend not in measured:
                continue
            cb = cn.get("backends", {}).get(backend)
            if cb is None:
                failures.append(f"{tag}: backend {backend!r} missing")
                continue
            if not cb["proven"]:
                failures.append(
                    f"{tag}: {backend} no longer proves optimality")
            if abs(cb["objective"] - bb["objective"]) \
                    > 1e-6 * max(1.0, abs(bb["objective"])):
                failures.append(
                    f"{tag}: {backend} proven objective "
                    f"{cb['objective']:.6g} != baseline "
                    f"{bb['objective']:.6g} (integral optimum — any drift "
                    "is a wrong answer)")
            if not cb["objective_match"]:
                failures.append(
                    f"{tag}: {backend} warm and cold runs disagree on the "
                    "incumbent objective")
            if cb["work_ratio"] >= 1.0:
                failures.append(
                    f"{tag}: {backend} work_ratio {cb['work_ratio']:.3f} >= "
                    "1.0 (hard bound: warm frontiers must beat cold on the "
                    "same tree)")
            ceiling = bb["work_ratio"] * (1.0 + rel_drop) + cut_slack
            if cb["work_ratio"] > ceiling:
                failures.append(
                    f"{tag}: {backend} work_ratio {cb['work_ratio']:.3f} > "
                    f"{ceiling:.3f} (baseline {bb['work_ratio']:.3f} "
                    f"+ {rel_drop:.0%} — parent-basis reuse stopped paying)")

    # ---- Pallas tile-kernel rows (kernel-vs-engine invariants) ------------
    cur_pal = {(w["m"], w["n"], w["B"]): w
               for w in current.get("pallas_workloads", [])}
    for bpw in baseline.get("pallas_workloads", []):
        key = (bpw["m"], bpw["n"], bpw["B"])
        tag = f"pallas {bpw['m']}x{bpw['n']} B={bpw['B']}"
        cpw = cur_pal.get(key)
        if cpw is None:
            failures.append(f"{tag}: row missing from the smoke run")
            continue
        for name, bk in bpw.get("kernels", {}).items():
            if name not in measured:
                continue
            ck = cpw.get("kernels", {}).get(name)
            if ck is None:
                failures.append(f"{tag}: kernel row {name!r} missing")
                continue
            if name in ("tableau", "revised"):
                # pivot-exact kernels: hard bounds, no baseline tolerance
                if ck["status_match_engine_frac"] < 1.0:
                    failures.append(
                        f"{tag}: {name} kernel status agreement "
                        f"{ck['status_match_engine_frac']:.3f} < 1.0 (the "
                        "kernel executes the engine's pivot sequence — any "
                        "divergence is a wrong answer)")
                if not ck["iters_match_engine"]:
                    failures.append(
                        f"{tag}: {name} kernel iteration counts diverged "
                        "from the engine (pivot-exactness lost)")
            else:
                floor = bk["status_match_engine_frac"] - 0.02
                if ck["status_match_engine_frac"] < floor:
                    failures.append(
                        f"{tag}: {name} kernel status agreement "
                        f"{ck['status_match_engine_frac']:.3f} < {floor:.3f}"
                        f" (baseline {bk['status_match_engine_frac']:.3f})")
            floor = bk["scheduled_status_match_frac"] - 0.02
            if ck["scheduled_status_match_frac"] < floor:
                failures.append(
                    f"{tag}: {name} kernel compaction-scheduled agreement "
                    f"{ck['scheduled_status_match_frac']:.3f} < {floor:.3f}")
            ceiling = bk["elements_scheduled"] * (1.0 + rel_drop)
            if ck["elements_scheduled"] > ceiling:
                failures.append(
                    f"{tag}: {name} kernel scheduled element traffic "
                    f"{ck['elements_scheduled']:.3e} > {ceiling:.3e} "
                    f"(baseline {bk['elements_scheduled']:.3e} "
                    f"+ {rel_drop:.0%} — segment sizing or bucket "
                    "shrinking regressed)")
            if bk.get("bucket_shrunk") and not ck.get("bucket_shrunk"):
                failures.append(
                    f"{tag}: {name} kernel no longer shrinks a bucket "
                    "under compaction (the baseline recorded at least one "
                    "gather into a smaller bucket)")

    # ---- shared-pattern sparse rows (dense-vs-sparse PDHG invariants) -----
    if check_pdhg:
        cur_sp = {(w["fixture"], w["B"]): w
                  for w in current.get("sparse_workloads", [])}
        for bs in baseline.get("sparse_workloads", []):
            key = (bs["fixture"], bs["B"])
            tag = f"sparse {bs['fixture']} B={bs['B']}"
            cs = cur_sp.get(key)
            if cs is None:
                failures.append(f"{tag}: row missing from the smoke run")
                continue
            floor = bs["status_match_dense_frac"] - 0.02
            if cs["status_match_dense_frac"] < floor:
                failures.append(
                    f"{tag}: sparse-vs-dense status agreement "
                    f"{cs['status_match_dense_frac']:.3f} < {floor:.3f} "
                    f"(baseline {bs['status_match_dense_frac']:.3f})")
            if cs["rel_obj_err_vs_dense"] > 2e-3:
                failures.append(
                    f"{tag}: sparse rel_obj_err_vs_dense "
                    f"{cs['rel_obj_err_vs_dense']:.2e} > 2e-3")
            ratio_floor = bs["element_traffic_ratio"] * (1.0 - rel_drop)
            if cs["element_traffic_ratio"] < ratio_floor:
                failures.append(
                    f"{tag}: element_traffic_ratio "
                    f"{cs['element_traffic_ratio']:.2f} < {ratio_floor:.2f} "
                    f"(baseline {bs['element_traffic_ratio']:.2f} "
                    f"- {rel_drop:.0%} — sparse traffic stopped scaling "
                    "with nnz)")
            it_ceiling = max(cs["iters_mean_dense"], 1.0) * (1.0 + rel_drop)
            if cs["iters_mean_sparse"] > it_ceiling:
                failures.append(
                    f"{tag}: sparse iters_mean {cs['iters_mean_sparse']:.0f}"
                    f" > {it_ceiling:.0f} (dense engine on the same "
                    "workload — the sparse matvecs changed the trajectory)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="smoke-run JSON (benchmarks.pivot_work "
                                    "--quick output)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed bench JSON (default: repo "
                         "BENCH_pivot_work.json)")
    ap.add_argument("--rel-drop", type=float, default=0.2,
                    help="max tolerated relative drop per metric")
    ap.add_argument("--cut-slack", type=float, default=0.02,
                    help="absolute slack on pivot_cut_vs_dantzig floors")
    ap.add_argument("--pdhg-obj-ceiling", type=float, default=1e-3,
                    help="max tolerated pdhg objective error vs tableau")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = gate(current, baseline, rel_drop=args.rel_drop,
                    cut_slack=args.cut_slack,
                    pdhg_obj_ceiling=args.pdhg_obj_ceiling)
    if failures:
        print("bench gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"bench gate OK ({os.path.basename(args.current)} vs "
          f"{os.path.basename(args.baseline)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
