#!/usr/bin/env bash
# CI entry point: tier-1 tests + executed-work benchmark smoke + bench gate.
#
#   scripts/check.sh                       # tier-1 pytest + tableau smoke + gate
#   scripts/check.sh --fast                # pytest + mps-roundtrip smoke
#   scripts/check.sh --backend revised     # suite + smoke for the revised engine
#   scripts/check.sh --backend pdhg        # suite + smoke for the first-order engine
#   scripts/check.sh --backend all         # suite + smoke once per backend
#
# The smoke also carries the general-form rows (vendored MPS fixtures through
# canonicalize -> solve -> recover vs the float64 oracle), the shared-pattern
# sparse rows on the pdhg/all legs (sparse-vs-dense PDHG agreement on the
# staircase fixtures + the nnz-scaled traffic ratio), the warm-start rows
# (perturbed fixture trajectories re-solved from the previous step's
# terminal state: each engine must at least halve re-solve work with
# unchanged statuses/objectives), and the fast path an mps-roundtrip check
# (parse fixtures, write, re-parse, assert equal).  Every leg also runs the
# telemetry smoke: the observability plane on a perturbed fixture batch —
# off by default (stats None, answers unchanged when enabled), on-device
# counters summing exactly to LPResult.iterations (and matching the f64
# oracle's lanes on the exact engines), and a compacted+traced solve
# exporting a valid Perfetto span tree.  The full legs start
# with a pallas smoke block: the revised tile kernel and the PDHG segment
# kernel (interpret=True) against their JAX engines — pivot-exactness for
# the simplex kernel, tolerance agreement plus a completed bucket shrink
# for PDHG under the compaction scheduler.
#
# Per backend the smoke run writes /tmp/pivot_work_smoke_<backend>.json
# (never the committed BENCH_pivot_work.json), asserts the absolute
# invariants (identical statuses across solvers/rules/backends, the
# work-elimination engine still eliminating work), and then
# scripts/bench_gate.py diffs it against the committed baseline so a >20%
# relative regression of reduction_scheduled / any rule's pivot cut /
# the revised backend's element reduction fails CI here rather than in a
# future bench run.
set -euo pipefail
cd "$(dirname "$0")/.."

BACKENDS="tableau"
FAST=0
while [[ $# -gt 0 ]]; do
  case "$1" in
    --fast) FAST=1 ;;
    --backend) shift; BACKENDS="${1:?--backend needs a value}" ;;
    --backend=*) BACKENDS="${1#*=}" ;;
    *) echo "usage: $0 [--fast] [--backend tableau|revised|all]" >&2; exit 2 ;;
  esac
  shift
done
case "$BACKENDS" in
  all) BACKENDS="tableau revised pdhg" ;;
  tableau|revised|pdhg) ;;
  *) echo "unknown backend '$BACKENDS' (tableau|revised|pdhg|all)" >&2; exit 2 ;;
esac

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mps_roundtrip_smoke() {
  echo "== mps-roundtrip smoke =="
  python - <<'EOF'
# parse every vendored fixture (LP and MIP), write it back, re-parse, assert
# bit-equality (seconds of work — the fixtures are tiny, nothing is solved)
import tempfile, os
import numpy as np
from repro.io.mps import (FIXTURE_NAMES, MIP_FIXTURE_NAMES, fixture_path,
                          read_mps, write_mps)

for name in FIXTURE_NAMES + MIP_FIXTURE_NAMES:
    g = read_mps(fixture_path(name))
    with tempfile.NamedTemporaryFile(suffix=".mps", delete=False) as f:
        path = f.name
    write_mps(g, path)
    g2 = read_mps(path)
    os.unlink(path)
    for field in ("A", "rhs", "c", "c0", "lb", "ub", "sense"):
        a, b = getattr(g, field), getattr(g2, field)
        assert np.array_equal(a, b), f"{name}: {field} changed in round-trip"
    assert g.maximize == g2.maximize
    if g.ranges is not None:
        assert np.array_equal(np.nan_to_num(g.ranges, nan=-1),
                              np.nan_to_num(g2.ranges, nan=-1)), name
    if g.integer is None:
        assert g2.integer is None, f"{name}: integer mask appeared"
    else:
        assert np.array_equal(g.integer, g2.integer), \
            f"{name}: integer mask changed in round-trip"
    mark = " (integer)" if g.integer is not None else ""
    print(f"  {name}: {g.m}x{g.n} round-trips bit-identically{mark}")
print("mps-roundtrip smoke OK")
EOF
}

bnb_smoke() {
  echo "== branch-and-bound smoke =="
  python - <<'EOF'
# solve the tiny knapsack frontier end-to-end on both exact simplex engines:
# proven optimality, the brute-force-verified objective, and warm frontiers
# beating cold ones (seconds of work — a 5-node tree on a 1x8 instance)
from repro.core import OPTIMAL, branch_and_bound
from repro.io.mps import fixture_path, read_mps

g = read_mps(fixture_path("knapsack"))
for backend in ("tableau", "revised"):
    warm = branch_and_bound(g, backend=backend, frontier=8)
    assert warm.status == OPTIMAL and warm.proven, \
        f"{backend}: {warm.summary()}"
    assert abs(warm.objective - 280.0) < 1e-6, \
        f"{backend}: objective {warm.objective} != 280 (brute-force optimum)"
    cold = branch_and_bound(g, backend=backend, frontier=8,
                            warm_start=False)
    assert warm.lp_iterations < cold.lp_iterations, \
        f"{backend}: warm {warm.lp_iterations} !< cold {cold.lp_iterations}"
    print(f"  {backend}: optimum 280 proven in {warm.nodes} nodes, "
          f"warm {warm.lp_iterations} vs cold {cold.lp_iterations} pivots")
print("branch-and-bound smoke OK")
EOF
}

telemetry_smoke() {
  local backend="${1:-tableau}"
  echo "== telemetry smoke (backend=$backend) =="
  TELEMETRY_BACKEND="$backend" python - <<'EOF'
# the observability plane on a perturbed fixture batch (seconds of work):
# disabled by default (stats None, answers identical to the telemetry run),
# counters summing exactly to LPResult.iterations, phase lanes matching the
# float64 oracle on the exact engines, and a compacted+traced solve whose
# span tree exports as valid Perfetto trace-event JSON
import json, os, tempfile
import numpy as np
from repro.core import solve_batched, solve_batched_compacted
from repro.core.reference import solve_batched_reference_detailed
from repro.io.mps import fixture_path, perturbed_batch, read_mps
from repro.obs import SpanTracer

backend = os.environ["TELEMETRY_BACKEND"]
g = read_mps(fixture_path("afiro"))
gb = perturbed_batch(g, 8, np.random.default_rng(3))

off = solve_batched(gb, backend=backend)
assert off.stats is None, "telemetry off must leave LPResult.stats unset"
on = solve_batched(gb, backend=backend, telemetry=True)
rep = on.stats
assert rep is not None, "telemetry=True produced no SolveReport"
assert np.array_equal(np.asarray(off.status), np.asarray(on.status)) \
    and np.allclose(np.asarray(off.objective), np.asarray(on.objective),
                    equal_nan=True), \
    "turning telemetry on changed the answers"
assert np.array_equal(rep.iterations, np.asarray(on.iterations)), \
    "telemetry iteration lanes do not sum to LPResult.iterations"
assert int(rep.iterations.sum()) > 0, "counters never fired"
if backend in ("tableau", "revised"):
    oracle, p1 = solve_batched_reference_detailed(gb)
    assert np.array_equal(rep.iterations, np.asarray(oracle.iterations)), \
        f"{backend}: telemetry iterations diverged from the f64 oracle"
    assert np.array_equal(rep.lane("phase1_iters"), np.asarray(p1)), \
        f"{backend}: phase1_iters lane diverged from the f64 oracle"
    tag = "lanes == f64 oracle"
else:
    kkt = rep.lane("kkt_gap")
    assert np.all(np.isfinite(kkt)), "pdhg kkt_gap lane not finite"
    tag = "kkt lanes finite"

tracer = SpanTracer()
solve_batched_compacted(gb, backend=backend, telemetry=True, tracer=tracer)
assert tracer.roots, "compacted solve recorded no spans"
with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
    path = f.name
tracer.to_perfetto(path)
events = json.load(open(path))["traceEvents"]
os.unlink(path)
assert any(e.get("name", "").startswith("segment") for e in events), \
    "Perfetto export lost the segment spans"
print(f"  {backend}: {int(rep.iterations.sum())} iterations counted, "
      f"{tag}, {len(events)} trace events")
print("telemetry smoke OK")
EOF
}

pallas_smoke() {
  echo "== pallas kernel smoke =="
  python - <<'EOF'
# both new tile kernels against their JAX engines on a tiny mixed batch
# (interpret=True — the Pallas interpreter, ~a minute): the revised kernel
# must be pivot-exact, the PDHG segment kernel must agree to tolerance and
# complete at least one bucket shrink through the compaction scheduler
import numpy as np
from repro.core import (OPTIMAL, random_lp_batch, solve_batched_pdhg,
                        solve_batched_revised)
from repro.kernels import solve_batched_pallas

rng = np.random.default_rng(7)
batch = random_lp_batch(rng, B=16, m=5, n=5)

ref = solve_batched_revised(batch)
pal = solve_batched_pallas(batch, backend="revised", tile_b=8)
assert np.array_equal(ref.status, pal.status), "revised kernel: statuses"
assert np.array_equal(ref.iterations, pal.iterations), \
    "revised kernel: pivot counts diverged from core/revised.py"
ok = np.asarray(ref.status) == OPTIMAL
np.testing.assert_allclose(pal.objective[ok], ref.objective[ok],
                           rtol=1e-4, atol=1e-4)
print(f"  revised tile: {int(ok.sum())}/{batch.batch} OPTIMAL, "
      "statuses+pivots identical to the engine")

pref = solve_batched_pdhg(batch)
stats = []
ppal = solve_batched_pallas(batch, backend="pdhg", tile_b=8,
                            compaction=True, segment_k=4, stats_out=stats)
match = (np.asarray(ppal.status) == np.asarray(pref.status)).mean()
assert match >= 0.95, f"pdhg segment kernel: status agreement {match:.2f}"
buckets = [s.bucket for s in stats]
assert min(buckets) < max(buckets), \
    "pdhg segment kernel: no bucket shrink through the scheduler"
print(f"  pdhg segment tile: status match {match:.2f}, "
      f"bucket ladder {sorted(set(buckets), reverse=True)}")
print("pallas kernel smoke OK")
EOF
}

if [[ "$FAST" == 1 ]]; then
  echo "== tier-1 pytest (fast) =="
  python -m pytest -x -q
  mps_roundtrip_smoke
  bnb_smoke
  telemetry_smoke tableau
  echo "ALL CHECKS PASSED"
  exit 0
fi

pallas_smoke

for backend in $BACKENDS; do
  echo "== tier-1 pytest (backend=$backend) =="
  python -m pytest -x -q

  telemetry_smoke "$backend"

  smoke="/tmp/pivot_work_smoke_${backend}.json"
  echo "== pivot-work + pricing smoke (backend=$backend) =="
  python -m benchmarks.pivot_work --quick --backend "$backend" --out "$smoke"
  SMOKE_JSON="$smoke" python - <<'EOF'
import json, os
d = json.load(open(os.environ["SMOKE_JSON"]))
for w in d["workloads"]:
    assert w["statuses_identical"], f"status divergence at {w['m']}x{w['n']}"
    assert w["reduction_scheduled"] >= 1.0, \
        f"work-elimination regressed at {w['m']}x{w['n']}: {w['reduction_scheduled']:.2f}x"
    # pricing smoke: every rule must agree with Dantzig on statuses
    # (rules change the pivot path, never the certificate)
    for rule, rr in w["rules"].items():
        assert rr["statuses_match_dantzig"], \
            f"pricing rule {rule} diverged on statuses at {w['m']}x{w['n']}"
    assert w["rules"]["steepest_edge"]["pivot_cut_vs_dantzig"] > 0.0, \
        f"steepest_edge did not cut pivots at {w['m']}x{w['n']}"
    # telemetry smoke: the counter plane now sources the pivot accounting —
    # its lanes must match both LPResult.iterations and the lockstep count
    tel = w["telemetry"]
    assert tel["iterations_match_result"], \
        f"telemetry iterations != LPResult.iterations at {w['m']}x{w['n']}"
    assert tel["iterations_match_lockstep"], \
        f"telemetry iterations != lockstep accounting at {w['m']}x{w['n']}"
    # backend smoke: the revised engine must agree with the tableau engine
    # on every status, monolithic and through the compaction scheduler
    for name, bb in w.get("backends", {}).items():
        assert bb["statuses_match_tableau"], \
            f"backend {name} diverged on statuses at {w['m']}x{w['n']}"
        assert bb.get("scheduled_statuses_match", True), \
            f"backend {name} diverged under compaction at {w['m']}x{w['n']}"
    # pdhg smoke: the first-order engine is tolerance-based — statuses must
    # agree with the exact tableau on nearly every LP, objectives to ~tol,
    # and the compaction scheduler must not change its answers
    pp = w.get("pdhg") or {}
    if pp:
        assert pp["status_match_tableau_frac"] >= 0.9, \
            f"pdhg status agreement {pp['status_match_tableau_frac']:.2f}" \
            f" < 0.9 at {w['m']}x{w['n']}"
        assert pp["rel_obj_err_vs_tableau"] < 1e-3, \
            f"pdhg rel_obj_err {pp['rel_obj_err_vs_tableau']:.2e} at " \
            f"{w['m']}x{w['n']}"
        assert pp["scheduled_status_match_frac"] >= 0.95, \
            f"pdhg compaction round-trip " \
            f"{pp['scheduled_status_match_frac']:.2f} at {w['m']}x{w['n']}"
        # adaptive step sizes: the Malitsky-Pock linesearch must never
        # cost more iterations than the fixed step, with statuses agreeing
        mp = pp["malitsky_pock"]
        assert mp["iters_cut_vs_fixed"] >= 0.0, \
            f"malitsky_pock costs more than fixed at {w['m']}x{w['n']}: " \
            f"cut {mp['iters_cut_vs_fixed']:+.1%}"
        assert mp["status_match_fixed_frac"] >= 0.9, \
            f"malitsky_pock status agreement " \
            f"{mp['status_match_fixed_frac']:.2f} at {w['m']}x{w['n']}"
# pallas smoke: the tile kernels vs their engines — the simplex kernels
# must be pivot-exact (identical statuses AND iteration counts), the
# tolerance-based pdhg kernel agrees on nearly every status, and every
# kernel's compaction-scheduled run keeps agreeing with the engine
for pw in d.get("pallas_workloads", []):
    ptag = f"{pw['m']}x{pw['n']} B={pw['B']}"
    for name, kk in pw["kernels"].items():
        if name in ("tableau", "revised"):
            assert kk["status_match_engine_frac"] == 1.0 \
                and kk["iters_match_engine"], \
                f"pallas {ptag}: {name} kernel lost pivot-exactness"
        else:
            assert kk["status_match_engine_frac"] >= 0.9, \
                f"pallas {ptag}: {name} kernel status agreement " \
                f"{kk['status_match_engine_frac']:.2f} < 0.9"
        assert kk["scheduled_status_match_frac"] >= 0.9, \
            f"pallas {ptag}: {name} scheduled-kernel agreement " \
            f"{kk['scheduled_status_match_frac']:.2f} < 0.9"
# sparse smoke (pdhg/all legs): the shared-pattern sparse engine must
# agree with the dense engine on the staircase fixtures — same algorithm,
# the matvecs just pay nnz instead of m*n — and the recorded traffic
# ratio must show it actually did (dense/sparse elements ~ 1/density)
for sw in d.get("sparse_workloads", []):
    assert sw["status_match_dense_frac"] >= 0.95, \
        f"sparse {sw['fixture']}: sparse-vs-dense status agreement " \
        f"{sw['status_match_dense_frac']:.2f} < 0.95"
    assert sw["rel_obj_err_vs_dense"] < 2e-3, \
        f"sparse {sw['fixture']}: rel_obj_err_vs_dense " \
        f"{sw['rel_obj_err_vs_dense']:.2e}"
    assert sw["element_traffic_ratio"] > 2.0, \
        f"sparse {sw['fixture']}: element traffic ratio " \
        f"{sw['element_traffic_ratio']:.2f} — not scaling with nnz"
# warm smoke: the warm-start engine must at least halve the re-solve
# iteration count on the perturbed trajectories (hard bound — the same
# one bench_gate.py holds), with cold-vs-warm statuses agreeing and
# objectives unchanged (warm starts change the path, never the answer)
for ww in d.get("warm_workloads", []):
    for name, wb in ww["backends"].items():
        assert wb["work_ratio"] <= 0.5, \
            f"warm {ww['fixture']}: {name} work_ratio " \
            f"{wb['work_ratio']:.2f} > 0.5 — warm re-solves not halving work"
        assert wb["status_match_frac"] >= 0.95, \
            f"warm {ww['fixture']}: {name} cold-vs-warm status agreement " \
            f"{wb['status_match_frac']:.2f} < 0.95"
        assert wb["rel_obj_err"] < 2e-3, \
            f"warm {ww['fixture']}: {name} rel_obj_err {wb['rel_obj_err']:.2e}"
# bnb smoke: the branch-and-bound driver must prove optimality on the
# MIP fixtures at the brute-force-verified objective, and warm-started
# frontiers must strictly beat cold ones on the identical tree (the same
# bounds bench_gate.py holds against the committed baseline)
for nw in d.get("bnb_workloads", []):
    for name, nb in nw["backends"].items():
        assert nb["proven"], \
            f"bnb {nw['fixture']}: {name} did not prove optimality"
        assert nb["objective_match"], \
            f"bnb {nw['fixture']}: {name} objective {nb['objective']} " \
            f"missed the brute-force optimum"
        assert nb["work_ratio"] < 1.0, \
            f"bnb {nw['fixture']}: {name} work_ratio " \
            f"{nb['work_ratio']:.2f} >= 1.0 — warm frontiers not paying"
# general-form smoke: real fixtures through the MPS/canonicalization
# pipeline must track the float64 oracle after recovery
for gw in d.get("general_workloads", []):
    for name, bb in gw["backends"].items():
        assert bb["status_match_oracle_frac"] >= 0.95, \
            f"general {gw['fixture']}: {name} status agreement " \
            f"{bb['status_match_oracle_frac']:.2f} < 0.95"
        assert bb["rel_obj_err"] < 2e-3, \
            f"general {gw['fixture']}: {name} rel_obj_err " \
            f"{bb['rel_obj_err']:.2e}"
print("pivot-work smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: x{w['reduction_scheduled']:.2f}"
                for w in d["workloads"]))
print("telemetry smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: {w['telemetry']['useful_pivots']} pivots "
                "counted on-device"
                for w in d["workloads"]))
print("pricing smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: se cut "
                f"{w['rules']['steepest_edge']['pivot_cut_vs_dantzig']:.1%}"
                for w in d["workloads"]))
if d["workloads"][0].get("backends"):
    print("backend smoke OK:",
          ", ".join(f"{w['m']}x{w['n']}: revised x"
                    f"{w['backends']['revised_dantzig']['element_reduction_vs_tableau']:.1f}"
                    for w in d["workloads"]))
if d["workloads"][0].get("pdhg"):
    print("pdhg smoke OK:",
          ", ".join(f"{w['m']}x{w['n']}: match "
                    f"{w['pdhg']['status_match_tableau_frac']:.2f} "
                    f"({w['pdhg']['iters_mean']:.0f} iters)"
                    for w in d["workloads"]))
if d.get("general_workloads"):
    print("general-form smoke OK:",
          ", ".join(f"{gw['fixture']} ({gw['m_canonical']}x"
                    f"{gw['n_canonical']} canonical)"
                    for gw in d["general_workloads"]))
if d.get("sparse_workloads"):
    print("sparse smoke OK:",
          ", ".join(f"{sw['fixture']} (nnz={sw['nnz']}, traffic "
                    f"x{sw['element_traffic_ratio']:.1f})"
                    for sw in d["sparse_workloads"]))
if d.get("warm_workloads"):
    print("warm smoke OK:",
          ", ".join(f"{ww['fixture']}/{name} ratio "
                    f"{wb['work_ratio']:.2f}"
                    for ww in d["warm_workloads"]
                    for name, wb in ww["backends"].items()))
if d.get("pallas_workloads"):
    print("pallas smoke OK:",
          ", ".join(f"{pw['m']}x{pw['n']}/{name} match "
                    f"{kk['status_match_engine_frac']:.2f}"
                    f"{' shrunk' if kk['bucket_shrunk'] else ''}"
                    for pw in d["pallas_workloads"]
                    for name, kk in pw["kernels"].items()))
if d.get("bnb_workloads"):
    print("bnb smoke OK:",
          ", ".join(f"{nw['fixture']}/{name} ratio "
                    f"{nb['work_ratio']:.2f}"
                    for nw in d["bnb_workloads"]
                    for name, nb in nw["backends"].items()))
EOF

  echo "== bench-regression gate (backend=$backend) =="
  python scripts/bench_gate.py "$smoke"
done

echo "ALL CHECKS PASSED"
