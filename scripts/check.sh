#!/usr/bin/env bash
# CI entry point: tier-1 tests + a short executed-work benchmark smoke.
#
#   scripts/check.sh          # full tier-1 pytest + quick pivot-work smoke
#   scripts/check.sh --fast   # pytest only
#
# The smoke run writes /tmp/pivot_work_smoke.json (never the committed
# BENCH_pivot_work.json) and fails if solver statuses diverge or the
# work-elimination engine regresses below a loose floor.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== pivot-work + pricing smoke (benchmarks/pivot_work.py --quick) =="
  python -m benchmarks.pivot_work --quick --out /tmp/pivot_work_smoke.json
  python - <<'EOF'
import json
d = json.load(open("/tmp/pivot_work_smoke.json"))
for w in d["workloads"]:
    assert w["statuses_identical"], f"status divergence at {w['m']}x{w['n']}"
    assert w["reduction_scheduled"] >= 1.0, \
        f"work-elimination regressed at {w['m']}x{w['n']}: {w['reduction_scheduled']:.2f}x"
    # pricing smoke: every rule must agree with Dantzig on statuses
    # (rules change the pivot path, never the certificate)
    for rule, rr in w["rules"].items():
        assert rr["statuses_match_dantzig"], \
            f"pricing rule {rule} diverged on statuses at {w['m']}x{w['n']}"
    assert w["rules"]["steepest_edge"]["pivot_cut_vs_dantzig"] > 0.0, \
        f"steepest_edge did not cut pivots at {w['m']}x{w['n']}"
print("pivot-work smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: x{w['reduction_scheduled']:.2f}"
                for w in d["workloads"]))
print("pricing smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: se cut "
                f"{w['rules']['steepest_edge']['pivot_cut_vs_dantzig']:.1%}"
                for w in d["workloads"]))
EOF
fi

echo "ALL CHECKS PASSED"
