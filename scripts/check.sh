#!/usr/bin/env bash
# CI entry point: tier-1 tests + executed-work benchmark smoke + bench gate.
#
#   scripts/check.sh                       # tier-1 pytest + tableau smoke + gate
#   scripts/check.sh --fast                # pytest only
#   scripts/check.sh --backend revised     # suite + smoke for the revised engine
#   scripts/check.sh --backend all         # suite + smoke once per backend
#
# Per backend the smoke run writes /tmp/pivot_work_smoke_<backend>.json
# (never the committed BENCH_pivot_work.json), asserts the absolute
# invariants (identical statuses across solvers/rules/backends, the
# work-elimination engine still eliminating work), and then
# scripts/bench_gate.py diffs it against the committed baseline so a >20%
# relative regression of reduction_scheduled / any rule's pivot cut /
# the revised backend's element reduction fails CI here rather than in a
# future bench run.
set -euo pipefail
cd "$(dirname "$0")/.."

BACKENDS="tableau"
FAST=0
while [[ $# -gt 0 ]]; do
  case "$1" in
    --fast) FAST=1 ;;
    --backend) shift; BACKENDS="${1:?--backend needs a value}" ;;
    --backend=*) BACKENDS="${1#*=}" ;;
    *) echo "usage: $0 [--fast] [--backend tableau|revised|all]" >&2; exit 2 ;;
  esac
  shift
done
case "$BACKENDS" in
  all) BACKENDS="tableau revised" ;;
  tableau|revised) ;;
  *) echo "unknown backend '$BACKENDS' (tableau|revised|all)" >&2; exit 2 ;;
esac

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "$FAST" == 1 ]]; then
  echo "== tier-1 pytest (fast) =="
  python -m pytest -x -q
  echo "ALL CHECKS PASSED"
  exit 0
fi

for backend in $BACKENDS; do
  echo "== tier-1 pytest (backend=$backend) =="
  python -m pytest -x -q

  smoke="/tmp/pivot_work_smoke_${backend}.json"
  echo "== pivot-work + pricing smoke (backend=$backend) =="
  python -m benchmarks.pivot_work --quick --backend "$backend" --out "$smoke"
  SMOKE_JSON="$smoke" python - <<'EOF'
import json, os
d = json.load(open(os.environ["SMOKE_JSON"]))
for w in d["workloads"]:
    assert w["statuses_identical"], f"status divergence at {w['m']}x{w['n']}"
    assert w["reduction_scheduled"] >= 1.0, \
        f"work-elimination regressed at {w['m']}x{w['n']}: {w['reduction_scheduled']:.2f}x"
    # pricing smoke: every rule must agree with Dantzig on statuses
    # (rules change the pivot path, never the certificate)
    for rule, rr in w["rules"].items():
        assert rr["statuses_match_dantzig"], \
            f"pricing rule {rule} diverged on statuses at {w['m']}x{w['n']}"
    assert w["rules"]["steepest_edge"]["pivot_cut_vs_dantzig"] > 0.0, \
        f"steepest_edge did not cut pivots at {w['m']}x{w['n']}"
    # backend smoke: the revised engine must agree with the tableau engine
    # on every status, monolithic and through the compaction scheduler
    for name, bb in w.get("backends", {}).items():
        assert bb["statuses_match_tableau"], \
            f"backend {name} diverged on statuses at {w['m']}x{w['n']}"
        assert bb.get("scheduled_statuses_match", True), \
            f"backend {name} diverged under compaction at {w['m']}x{w['n']}"
print("pivot-work smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: x{w['reduction_scheduled']:.2f}"
                for w in d["workloads"]))
print("pricing smoke OK:",
      ", ".join(f"{w['m']}x{w['n']}: se cut "
                f"{w['rules']['steepest_edge']['pivot_cut_vs_dantzig']:.1%}"
                for w in d["workloads"]))
if d["workloads"][0].get("backends"):
    print("backend smoke OK:",
          ", ".join(f"{w['m']}x{w['n']}: revised x"
                    f"{w['backends']['revised_dantzig']['element_reduction_vs_tableau']:.1f}"
                    for w in d["workloads"]))
EOF

  echo "== bench-regression gate (backend=$backend) =="
  python scripts/bench_gate.py "$smoke"
done

echo "ALL CHECKS PASSED"
