"""Granite-20B code [arXiv:2405.04324; hf] — MQA (kv=1). Assignment: 52L
d_model=6144 48H (kv=1) d_ff=24576 vocab=49152. The assignment tags it
llama-arch; the published 20.1B total is only consistent with the
gpt-bigcode-style 2-matrix GELU MLP (a 3-matrix SwiGLU gives 28B), so the
MLP is GELU while norm/rope follow the llama recipe (noted in DESIGN.md)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
        d_ff=24576, vocab=49152,
        mlp_kind="gelu",
        train_microbatches=2,
        remat="block", seq_shard=True, optimizer="adamw",
    )
