"""Hymba-1.5B [arXiv:2411.13676; hf] — parallel attention+mamba heads.
Assignment: 32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Simplifications (DESIGN.md): sliding-window attention in every layer (the
real model keeps 3 global-attention layers); head outputs mean-fused (the
real model learns per-path scalings)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
        d_ff=5504, vocab=32001,
        sliding_window=1024,
        d_inner=3200, ssm_state=16, conv_dim=4, dt_rank=100,
        q_chunk=256, kv_chunk=512,
        train_microbatches=2,
        remat="block", seq_shard=True, optimizer="adamw",
    )
