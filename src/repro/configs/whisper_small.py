"""Whisper-small [arXiv:2212.04356; unverified] — enc-dec, conv frontend
STUBBED (input_specs feeds precomputed frame embeddings). Assignment: 12L
d_model=768 12H (kv=12) d_ff=3072 vocab=51865."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, n_encoder_layers=12,
        d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        n_heads_padded=16, n_kv_heads_padded=16,  # TP-16 masked padding
        d_ff=3072, vocab=51865,
        mlp_kind="gelu", norm_kind="layernorm", use_rope=False,
        tie_embeddings=True,
        q_chunk=512, kv_chunk=512,
        remat="block", optimizer="adamw",
    )
