"""The paper's own workload as a dry-run config: batches of small/medium LPs
solved by the batched simplex across the production mesh (pure batch
parallelism — the paper's Sec. 5.1 load-balancing story at pod scale)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LPWorkload:
    name: str
    batch: int
    m: int
    n: int
    feasible_start: bool = True


WORKLOADS = (
    LPWorkload("lp_5d_100k", batch=100_000, m=5, n=5),
    LPWorkload("lp_28d_100k", batch=100_000, m=28, n=28),
    LPWorkload("lp_100d_50k", batch=50_000, m=100, n=100),
    LPWorkload("lp_300d_2k", batch=2048, m=300, n=300),
    LPWorkload("lp_netlib_adlittle", batch=100_000, m=71, n=97),
)
