"""The paper's own workload as a dry-run config: batches of small/medium LPs
solved by the batched simplex across the production mesh (pure batch
parallelism — the paper's Sec. 5.1 load-balancing story at pod scale).

Two workload classes:

* synthetic — random standard-form LPs at the paper's Table-2 sizes;
* fixture-backed — a vendored general-form MPS instance
  (``tests/fixtures/``, see ``repro.io.mps``) expanded into a batch of
  perturbed copies exactly the way the paper builds its Netlib batches
  (Sec. 6).  ``m``/``n`` record the *original* shape; the device solvers
  run at the canonical shape (``analysis.lp_perf.canonical_work``), which
  is how these workloads must be costed.

``build_batch`` materializes either kind.
"""
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class LPWorkload:
    name: str
    batch: int
    m: int
    n: int
    feasible_start: bool = True
    fixture: Optional[str] = None     # repro.io.mps fixture name


WORKLOADS = (
    LPWorkload("lp_5d_100k", batch=100_000, m=5, n=5),
    LPWorkload("lp_28d_100k", batch=100_000, m=28, n=28),
    LPWorkload("lp_100d_50k", batch=50_000, m=100, n=100),
    LPWorkload("lp_300d_2k", batch=2048, m=300, n=300),
    # real general-form instances, batch-expanded (canonical 35x32 / 79x49)
    LPWorkload("lp_afiro_100k", batch=100_000, m=27, n=32, fixture="afiro"),
    LPWorkload("lp_sc50b_like_50k", batch=50_000, m=50, n=48,
               fixture="sc50b_like"),
)


def build_batch(w: LPWorkload, batch: Optional[int] = None,
                rng: Optional[np.random.Generator] = None):
    """Materialize a workload: an ``LPBatch`` for synthetic entries, a
    ``GeneralLPBatch`` (perturbed copies of the vendored instance) for
    fixture-backed ones — both solvable by every ``solve_*`` entry point."""
    from repro.core.reference import random_lp_batch

    B = batch or w.batch
    rng = rng or np.random.default_rng(2018)
    if w.fixture is None:
        return random_lp_batch(rng, B=B, m=w.m, n=w.n,
                               feasible_start=w.feasible_start)
    from repro.io.mps import fixture_path, perturbed_batch, read_mps
    return perturbed_batch(read_mps(fixture_path(w.fixture)), B, rng)
