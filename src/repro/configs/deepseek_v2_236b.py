"""DeepSeek-V2 236B [arXiv:2405.04434; hf] — MoE 160e top-6 (+2 shared), MLA
kv_lora=512. Assignment: 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
Simplification noted in DESIGN.md: all layers MoE (the real model's first
layer is dense)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=0, vocab=102400,
        attn_kind="mla", q_lora=1536, kv_lora=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, d_head=192,
        mlp_kind="moe", n_experts=160, top_k=6, n_shared_experts=2,
        d_ff_expert=1536,
        rope_theta=10000.0,
        train_microbatches=4,
        remat="block", fsdp=True, seq_shard=True, optimizer="adamw",
    )
