"""Qwen3-32B [hf:Qwen/Qwen3-8B; hf] — dense GQA with qk-norm. Assignment:
64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=25600, vocab=151936,
        qk_norm=True, rope_theta=1000000.0,
        train_microbatches=4,
        remat="block", seq_shard=True, optimizer="adamw",
    )
