"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified] — MoE 16e top-1 + 1 shared expert; early-fusion multimodal is out
of scope (text backbone only, noted in DESIGN.md). Assignment: 48L
d_model=5120 40H (kv=8) d_ff=8192 vocab=202048."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        n_heads_padded=48,  # TP-16 padding: 8 output-masked dead heads
        d_head=128, d_ff=0, vocab=202048,
        mlp_kind="moe", n_experts=16, top_k=1, n_shared_experts=1,
        d_ff_expert=8192,
        rope_theta=500000.0,
        q_chunk=2048, kv_chunk=2048,
        train_microbatches=2,
        remat="block", fsdp=True, seq_shard=True, optimizer="adamw",
    )
