"""Falcon-Mamba 7B [arXiv:2410.05355; unverified] — pure Mamba-1, attn-free.
Assignment: 64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab=65024,
        attn_kind="none", mlp_kind="none",
        d_inner=8192, ssm_state=16, conv_dim=4, dt_rank=256,
        train_microbatches=2,
        remat="block", seq_shard=True, optimizer="adamw",
    )
