"""Nemotron-4 340B [arXiv:2402.16819; unverified] — dense GQA with
squared-ReLU MLP. Assignment: 96L d_model=18432 96H (kv=8) d_ff=73728
vocab=256000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
        d_ff=73728, vocab=256000,
        mlp_kind="relu2",
        train_microbatches=8,
        remat="block", fsdp=True, seq_shard=True, optimizer="adafactor",
    )
