"""Llama-3 405B [arXiv:2407.21783; unverified] — dense GQA, 128k vocab.
Assignment: 126L d_model=16384 128H (kv=8) d_ff=53248 vocab=128256."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
        d_ff=53248, vocab=128256,
        rope_theta=500000.0,
        train_microbatches=8,
        remat="block", fsdp=True, seq_shard=True, optimizer="adafactor",
    )
