"""Architecture registry: one module per assigned arch (exact published
configs) + the paper's own LP-batch workload config."""
from importlib import import_module

ARCH_IDS = (
    "deepseek_v2_236b",
    "llama4_scout_17b_a16e",
    "falcon_mamba_7b",
    "whisper_small",
    "qwen3_32b",
    "granite_20b",
    "nemotron_4_340b",
    "llama3_405b",
    "hymba_1_5b",
    "phi_3_vision_4_2b",
)

# canonical dashed ids from the assignment table
CANONICAL = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "qwen3-32b": "qwen3_32b",
    "granite-20b": "granite_20b",
    "nemotron-4-340b": "nemotron_4_340b",
    "llama3-405b": "llama3_405b",
    "hymba-1.5b": "hymba_1_5b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}


def get_config(arch: str):
    key = CANONICAL.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = import_module(f"repro.configs.{key}")
    return mod.config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
