"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf] —
phi3-mini backbone + CLIP frontend STUBBED (input_specs feeds precomputed
patch embeddings, n_patches=256). Assignment: 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
        d_ff=8192, vocab=32064,
        n_patches=256,
        remat="block", seq_shard=True, optimizer="adamw",
    )
