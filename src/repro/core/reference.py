"""Float64 NumPy two-phase simplex — the correctness oracle & CPU baseline.

Plays the role GLPK/CPLEX play in the paper's evaluation: a trusted
*sequential* CPU solver that batched device solvers are compared against,
both for correctness (tests) and for speedup curves (benchmarks). It
implements the exact same two-phase/sentinel algorithm as the JAX and Pallas
backends — including the pluggable pricing engine (``pricing=`` selects
dantzig / steepest_edge / devex, see core/pricing.py) — so that iteration
counts and pivot sequences match bit-for-bit modulo dtype *per rule*: the
oracle is the per-rule pivot-sequence ground truth.
"""
from __future__ import annotations

import numpy as np

from .forms import ensure_canonical, finish_result, prepare_warm
from .lp import (
    BIG,
    INFEASIBLE,
    ITERATION_LIMIT,
    OPTIMAL,
    UNBOUNDED,
    LPBatch,
    LPResult,
    WarmStart,
    build_tableau,
    default_max_iters,
    extract_solution,
)
from .pricing import (
    canonicalize_rule,
    init_weights_np,
    select_entering_np,
    update_weights_np,
)


def _inject_warm_np(A, b, c, ub, wb, wfl, *, m: int, n: int,
                    feas_tol: float = 1e-8):
    """Single-LP float64 mirror of ``simplex.inject_tableau_warm``: rebuild
    the two-phase tableau from a parent basis with the same per-LP
    skip/repair/cold trichotomy (see that docstring for the math).  Returns
    ``(T, basis, start_phase, flip)`` or ``None`` for the cold fallback."""
    if wb.min() < 0 or wb.max() >= n + 2 * m:
        return None
    wb2 = np.where(wb >= n + m, wb - m, wb).astype(np.int64)
    ubv = np.full(n, np.inf) if ub is None else np.asarray(ub, np.float64)
    wfl = wfl & np.isfinite(ubv)
    ubz = np.where(wfl, ubv, 0.0)
    Af = np.where(wfl[None, :], -A, A)
    bf = b - A @ ubz
    cf = np.where(wfl, -c, c)
    obj_off = float(c @ ubz)
    Acols = np.concatenate([Af, np.eye(m)], axis=1)
    Bmat = Acols[:, wb2]
    try:
        body = np.linalg.solve(
            Bmat, np.concatenate([Acols, bf[:, None]], axis=1))
    except np.linalg.LinAlgError:
        return None
    if not np.isfinite(body).all():
        return None
    xB = body[:, -1]
    eps = feas_tol * max(1.0, float(np.abs(bf).max(initial=0.0)))
    viol = xB < -eps
    rows = np.where(viol, -1.0, 1.0)[:, None] * body
    cext = np.concatenate([cf, np.zeros(m)])
    cB = np.where(viol, 0.0, cext[wb2])
    red = cext - cB @ rows[:, :n + m]
    idx = np.arange(m)
    T = np.zeros((m + 2, n + 2 * m + 1))
    T[:m, :n + m] = rows[:, :n + m]
    T[idx, n + m + idx] = np.where(viol, 1.0, 0.0)
    T[:m, -1] = rows[:, -1]
    T[m, :n + m] = red
    T[m, -1] = -(cB @ rows[:, -1] + obj_off)
    p1 = (rows * viol[:, None]).sum(axis=0)
    T[m + 1, :n + m] = p1[:n + m]
    T[m + 1, -1] = p1[-1]
    basis = np.where(viol, n + m + idx, wb2)
    return T, basis, (1 if viol.any() else 2), wfl


def _solve_single(T, basis, n, m, tol, max_iters, rule="dantzig", ub=None,
                  flip=None, start_phase=1):
    """Solve one LP in-place on its (m+2, cols) float64 tableau.

    Returns (status, iters, p1_iters): ``p1_iters`` counts the iterations
    consumed before phase 2 began (phase-1 pivots plus the transition check)
    — the input to the phase-compaction executed-work models in
    analysis/lp_perf.py and benchmarks/pivot_work.py.

    ``ub`` ((n,) or None) enables the bounded-variable method ``0 <= x <=
    ub``: columns are stored *complemented* (x' = ub - x) whenever their
    ``flip`` flag is set, so every nonbasic variable sits at 0 and the
    classic sentinel min-ratio applies unchanged.  The ratio test gains two
    cases: a basic variable may hit its own upper bound (its row is
    complemented before the pivot, making the pivot element positive), and
    the entering variable may hit its bound first — a *bound flip* that
    costs one column negation + rhs update instead of a pivot (counted as
    an iteration; pricing weights are untouched — column negation is
    norm-invariant for the d^2/w scores).  With all-+inf ``ub`` every new
    branch is dead and the classic method runs bitwise-unchanged."""
    cols = T.shape[1]
    allowed = np.zeros(cols, dtype=bool)
    allowed[: n + m] = True  # artificials and rhs never enter
    feas_thr = 1e-8 * max(1.0, T[m + 1, -1])  # relative, matches JAX backend
    weights = init_weights_np(rule, T, m)
    bounded = ub is not None and np.isfinite(ub).any()
    if flip is None:
        flip = np.zeros(n, dtype=bool)
    phase = start_phase
    iters = 0
    p1_iters = 0
    status = None
    while iters < max_iters:
        obj_row = T[m + 1] if phase == 1 else T[m]
        reduced = np.where(allowed, obj_row, -BIG)
        e = select_entering_np(reduced, weights, rule=rule, tol=tol,
                               iters=iters, ncand=n + m)
        if np.max(reduced) <= tol:
            if phase == 1:
                w = T[m + 1, -1]
                if w > feas_thr:
                    status = INFEASIBLE
                    break
                phase = 2
                iters += 1
                p1_iters = iters
                continue
            status = OPTIMAL
            break
        col = T[:m, e]
        rhs = T[:m, -1]
        with np.errstate(divide="ignore", invalid="ignore"):
            ratios = np.where(col > tol, rhs / np.where(col > tol, col, 1.0), BIG)
        if bounded:
            # a *decreasing* basic variable never binds, but an increasing
            # one (col < 0) may hit its own finite upper bound at
            # (ub_B - rhs) / (-col) — complement-and-pivot when it wins
            ubB = np.where(basis < n, ub[np.minimum(basis, n - 1)], np.inf)
            hit_ub = (col < -tol) & np.isfinite(ubB)
            with np.errstate(divide="ignore", invalid="ignore"):
                ub_ratio = (ubB - rhs) / np.where(hit_ub, -col, 1.0)
            ratios = np.where(hit_ub, ub_ratio, ratios)
        if phase == 2:
            # Basic artificials are pinned at zero in phase 2: a pivot whose
            # entering column would *grow* one (negative coefficient in its
            # row) instead kicks it out at ratio 0 — the pivot element is
            # negative, which is legal at a zero rhs.  Without this, the
            # degenerate artificials that equality-pair canonicalization
            # (core/forms.py) routinely leaves basic-at-zero can silently
            # re-relax their row during phase 2.
            ratios = np.where((basis >= n + m) & (col < -tol), 0.0, ratios)
        l = int(np.argmin(ratios))
        t_e = ub[e] if bounded and e < n else np.inf
        if t_e < ratios[l]:
            # bound flip: the entering variable hits its own upper bound
            # before any basic variable binds — complement it in place
            T[:, -1] -= t_e * T[:, e]
            T[:, e] = -T[:, e]
            flip[e] = ~flip[e]
            iters += 1
            continue
        if ratios[l] >= BIG / 2:
            status = UNBOUNDED if phase == 2 else ITERATION_LIMIT
            break
        if bounded and T[l, e] < 0 and basis[l] < n:
            # leaving basic hits its *upper* bound: complement its (unit)
            # column — negate row l, rhs_l -> ub_l - rhs_l — which makes
            # the pivot element positive and the pivot classic
            jl = int(basis[l])
            T[l] = -T[l]
            T[l, -1] += ub[jl]
            T[l, jl] = 1.0
            flip[jl] = ~flip[jl]
        pe = T[l, e]
        pivrow = T[l] / pe
        factor = T[:, e].copy()
        T -= factor[:, None] * pivrow[None, :]
        T[l] = pivrow
        weights = update_weights_np(rule, weights, T, pivrow, pe, e, basis[l],
                                    m=m, n=n)
        basis[l] = e
        iters += 1
    if status is None:
        status = ITERATION_LIMIT
    if phase == 1:
        p1_iters = iters
    return status, iters, p1_iters


def solve_batched_reference_detailed(batch: LPBatch, tol: float = 1e-9,
                                     max_iters: int | None = None,
                                     pricing: str = "dantzig",
                                     presolve: bool = True,
                                     scale: bool | None = None,
                                     warm: WarmStart | None = None):
    """Like solve_batched_reference, but also returns per-LP phase-1
    iteration counts ``(LPResult, p1_iters)`` — the input for the
    phase-compaction executed-work models (analysis/lp_perf.py,
    benchmarks/pivot_work.py).

    Accepts a ``GeneralLPBatch`` like every solver entry point: the oracle
    then solves the canonical form and reports in original coordinates
    (``presolve``/``scale`` control the canonicalization).  ``warm``
    accepts a WarmStart (any basis-carrying engine's, or a previous oracle
    solve's) and seeds each LP via `_inject_warm_np` — the f64 ground truth
    for the batched engines' warm paths."""
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    B, m, n = batch.batch, batch.m, batch.n
    rule = canonicalize_rule(pricing)
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    warm = prepare_warm(warm, rec, batch)
    T, basis, _ = build_tableau(batch.A, batch.b, batch.c)
    ub = None if batch.ub is None else np.asarray(batch.ub, np.float64)
    flip = np.zeros((B, n), dtype=bool)
    start_phase = np.ones(B, dtype=np.int32)
    if warm is not None and warm.basis is not None:
        wb = np.asarray(warm.basis, np.int64)
        wfl = (np.zeros((B, n), bool) if warm.at_upper is None
               else np.asarray(warm.at_upper, bool))
        A64 = np.asarray(batch.A, np.float64)
        b64 = np.asarray(batch.b, np.float64)
        c64 = np.asarray(batch.c, np.float64)
        for k in range(B):
            inj = _inject_warm_np(A64[k], b64[k], c64[k],
                                  None if ub is None else ub[k],
                                  wb[k], wfl[k], m=m, n=n)
            if inj is not None:
                T[k], basis[k], start_phase[k], flip[k] = inj
    status = np.zeros(B, dtype=np.int8)
    iters = np.zeros(B, dtype=np.int32)
    p1_iters = np.zeros(B, dtype=np.int32)
    for k in range(B):
        status[k], iters[k], p1_iters[k] = _solve_single(
            T[k], basis[k], n, m, tol, max_iters, rule=rule,
            ub=None if ub is None else ub[k], flip=flip[k],
            start_phase=int(start_phase[k]))
    x, obj = extract_solution(T, basis, n, ub=ub, flip=flip)
    # dual certificate off the final tableau (see simplex.extract_duals):
    # slack-column reduced costs are -y, structural entries are z = c - y.A
    # (flipped columns are complemented, so their stored entry is -z)
    y = -T[:, m, n:n + m]
    z = np.where(flip, -T[:, m, :n], T[:, m, :n])
    # non-optimal LPs report NaN objective/duals to make misuse loud
    bad = status != OPTIMAL
    obj = np.where(bad, np.nan, obj)
    y = np.where(bad[:, None], np.nan, y)
    z = np.where(bad[:, None], np.nan, z)
    res = LPResult(x=x, objective=obj, status=status, iterations=iters,
                   y=y, z=z,
                   warm=WarmStart(m=m, n=n, basis=basis.astype(np.int32),
                                  at_upper=flip.copy(), pricing=rule))
    return finish_result(rec, res), p1_iters


def solve_batched_reference(batch: LPBatch, tol: float = 1e-9,
                            max_iters: int | None = None,
                            pricing: str = "dantzig",
                            presolve: bool = True,
                            scale: bool | None = None,
                            warm: WarmStart | None = None) -> LPResult:
    """Sequentially solve every LP in the batch (float64). O(B) loop — this is
    the 'CPU sequential' side of every speedup table.  Accepts general-form
    batches (GeneralLPBatch) like every solver entry point, and a ``warm``
    carrier like every batched engine."""
    res, _ = solve_batched_reference_detailed(batch, tol=tol,
                                              max_iters=max_iters,
                                              pricing=pricing,
                                              presolve=presolve, scale=scale,
                                              warm=warm)
    return res


def solve_dual_reference(batch: LPBatch, tol: float = 1e-9) -> LPResult:
    """Solve the dual of each LP:  min b.y  s.t.  A^T y >= c, y >= 0.

    Rewritten as the standard-form max problem  max (-b).y  s.t. (-A^T) y <= -c.
    Used by the strong-duality property tests: for feasible+bounded primal,
    primal optimum == dual optimum (dual objective here is -reported).
    """
    A = np.asarray(batch.A, dtype=np.float64)
    dual = LPBatch.from_arrays(
        -np.swapaxes(A, 1, 2), -np.asarray(batch.c, np.float64),
        -np.asarray(batch.b, np.float64),
    )
    res = solve_batched_reference(dual, tol=tol)
    return LPResult(x=res.x, objective=-res.objective, status=res.status,
                    iterations=res.iterations)


def random_lp_batch(rng: np.random.Generator, B: int, m: int, n: int,
                    feasible_start: bool = True) -> LPBatch:
    """Random dense LPs following the paper's Sec. 6 recipe: A in [1,1000],
    b in [1,1000], c in [1,500]. With positive A and b the origin is feasible
    and the optimum is finite (every variable is bounded by some row).

    feasible_start=False mirrors the paper's Table-4 class: ~m/4 rows are
    flipped into ">=" rows (negative b), so the initial basic solution is
    infeasible and the two-phase method runs — but the LP itself is kept
    feasible by construction around a known interior point x0, and bounded
    because the remaining rows have all-positive coefficients.
    """
    A = rng.uniform(1.0, 1000.0, size=(B, m, n))
    c = rng.uniform(1.0, 500.0, size=(B, n))
    if feasible_start:
        b = rng.uniform(1.0, 1000.0, size=(B, m))
    else:
        x0 = rng.uniform(0.05, 0.5, size=(B, n))          # known feasible point
        ax0 = np.einsum("bmn,bn->bm", A, x0)
        b = ax0 * rng.uniform(1.05, 2.0, size=(B, m))      # x0 strictly feasible
        k = max(1, m // 4)
        rows = rng.permuted(np.tile(np.arange(m), (B, 1)), axis=1)[:, :k]
        theta = rng.uniform(0.3, 0.9, size=(B, k))
        for bi in range(B):
            for j, r in enumerate(rows[bi]):
                A[bi, r] = -A[bi, r]
                b[bi, r] = -theta[bi, j] * ax0[bi, r]      # -A_r x <= -theta*(A_r x0)
    return LPBatch.from_arrays(A, b, c)


def random_sparse_lp_batch(rng: np.random.Generator, B: int, m: int, n: int,
                           density: float = 0.1) -> LPBatch:
    """Sparse feasible LPs at given density — stand-ins for the Netlib set
    (the paper's Table 5/6 problems are highly sparse). Every column keeps at
    least one nonzero so the LP stays bounded."""
    A = rng.uniform(1.0, 1000.0, size=(B, m, n))
    mask = rng.uniform(size=(B, m, n)) < density
    # guarantee a bounding nonzero per column
    rows = rng.integers(0, m, size=(B, n))
    mask[np.arange(B)[:, None], rows, np.arange(n)[None, :]] = True
    A = A * mask
    b = rng.uniform(1.0, 1000.0, size=(B, m))
    c = rng.uniform(1.0, 500.0, size=(B, n)) * (rng.uniform(size=(B, n)) < 0.5)
    return LPBatch.from_arrays(A, b, c)
