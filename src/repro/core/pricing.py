"""Pluggable pricing engine: pivot-column selection rules for the batched simplex.

The paper's Step 1 (Sec. 4.1/5.2) hardcodes **Dantzig's rule** — enter the
column with the most positive reduced cost.  It is the cheapest rule per
pivot (one argmax the tableau already pays for) but the worst in pivot
*count*, and pivot count is exactly what the two-level work-elimination
engine (phase compaction + active-set compaction, PR 1) multiplies against:
every pivot a better rule avoids is a full rank-1 tableau update saved
across the surviving batch.

Three rules, one contract:

* ``dantzig``        — e = argmax_j d_j.  Stateless; the weights array is
                       carried but never read, so the compiled program (and
                       the pivot sequence) is identical to the pre-pricing
                       solver.
* ``steepest_edge``  — e = argmax_j d_j^2 / gamma_j with **exact** reference
                       weights gamma_j = 1 + ||B^-1 A_j||^2.  In a dense
                       tableau the current column T[:m, j] *is* B^-1 A_j, so
                       the exact gamma is a column-norm reduction over the
                       freshly updated tableau — the same O(m*C) cost as the
                       classic Goldfarb recurrence but with zero drift, which
                       is why the recompute (fused into the pivot update) is
                       the reference formulation here.
* ``devex``          — e = argmax_j d_j^2 / w_j with Forrest/Goldfarb
                       approximate reference weights: w_j starts at 1 and
                       after a pivot on (l, e) becomes
                       max(w_j, alpha_j^2 * w_e) with alpha the scaled pivot
                       row; the leaving variable r gets max(w_e/alpha_e^2, 1)
                       and the framework resets to 1 when weights overflow.
                       O(C) per pivot instead of O(m*C).

All rules share the optimality test (max_j d_j <= tol) and Steps 2-3
unchanged, so INFEASIBLE/UNBOUNDED/OPTIMAL certificates are rule-independent
— only the path (and its length) through the basis graph differs.  Weights
live in the solver state as a (B, C) array whose batch axis 0 makes the
active-set compaction gathers, shard_map specs and Pallas tile BlockSpecs
uniform across rules; phase compaction slices weights with the same column
selection as the tableau (dropping columns never changes surviving columns'
norms, so exact steepest-edge weights survive the drop exactly).

This module holds the rule math in two dialects — batched JAX (used by
core/simplex.py) and scalar NumPy (used by the float64 oracle in
core/reference.py); kernels/simplex_tile.py re-expresses the same formulas
in broadcast/one-hot form for Pallas.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .lp import BIG

PRICING_RULES = ("dantzig", "steepest_edge", "devex")

# Devex framework reset: when any reference weight exceeds this, the whole
# framework restarts at 1 (standard practice; keeps f32 scores well-scaled).
DEVEX_RESET = 1e7


def canonicalize_rule(pricing: str) -> str:
    """Validate and normalize a pricing-rule name."""
    rule = str(pricing).lower()
    if rule not in PRICING_RULES:
        raise ValueError(
            f"unknown pricing rule {pricing!r}; expected one of {PRICING_RULES}")
    return rule


# ---------------------------------------------------------------------------
# Batched JAX dialect (core/simplex.py)
# ---------------------------------------------------------------------------

def init_weights(rule: str, T: jnp.ndarray, m: int) -> jnp.ndarray:
    """Initial (B, C) pricing weights for a batch of tableaux.

    steepest_edge: exact gamma_j = 1 + ||T[:m, j]||^2 (the initial basis is
    the slack/artificial identity, so this is 1 + ||A_j||^2 for structurals).
    dantzig/devex: ones (dantzig never reads them; devex starts its reference
    framework at 1)."""
    B, _, C = T.shape
    if rule == "steepest_edge":
        return 1.0 + jnp.sum(T[:, :m, :] * T[:, :m, :], axis=1)
    return jnp.ones((B, C), T.dtype)


def select_entering(masked_cost: jnp.ndarray, w: jnp.ndarray, *, rule: str,
                    tol: float):
    """Step 1 under a pricing rule.

    ``masked_cost`` is the objective row with disallowed columns already at
    -BIG.  Returns ``(e, max_cost)``: the entering column per LP and the max
    reduced cost (the rule-independent optimality test — a rule only changes
    *which* improving column enters, never *whether* one exists)."""
    max_cost = jnp.max(masked_cost, axis=1)
    if rule == "dantzig":
        e = jnp.argmax(masked_cost, axis=1)
    else:
        improving = masked_cost > tol
        d = jnp.where(improving, masked_cost, 0.0)
        score = jnp.where(improving, d * d / w, -BIG)
        e = jnp.argmax(score, axis=1)
    return e, max_cost


def update_weights(rule: str, w, T_new, pivrow, pe_safe, e, r, do_pivot,
                   *, m: int, n: int):
    """Post-pivot weight recurrence, fused into the rank-1 update.

    ``T_new``  — tableau *after* the pivot; ``pivrow`` — the scaled pivot row
    (T_new's row l); ``pe_safe`` — pivot element (1 where ~do_pivot);
    ``e``/``r`` — entering column / leaving variable's column per LP.
    Non-pivoting LPs keep their weights bitwise.

    Devex invariant (shared by every dialect): weights of non-priceable
    columns — artificials, rhs, padding, i.e. index >= n+m — are pinned to 1.
    Selection never reads them, but without the pin they would still feed the
    DEVEX_RESET overflow max (and a leaving *artificial*'s slot aliases the
    rhs after phase compaction), making reset timing depend on which layout
    a backend happens to use.  Pinned, the full, phase-compacted, lane-padded
    and float64 dialects all carry identical effective state."""
    if rule == "dantzig":
        return w
    if rule == "steepest_edge":
        w_new = 1.0 + jnp.sum(T_new[:, :m, :] * T_new[:, :m, :], axis=1)
        return jnp.where(do_pivot[:, None], w_new, w)
    # devex
    C = w.shape[1]
    cols = jnp.arange(C)
    w_e = jnp.take_along_axis(w, e[:, None], axis=1)[:, 0]
    w_new = jnp.maximum(w, pivrow * pivrow * w_e[:, None])
    w_leave = jnp.maximum(w_e / (pe_safe * pe_safe), 1.0)
    w_new = jnp.where(cols[None, :] == r[:, None], w_leave[:, None], w_new)
    w_new = jnp.where(cols[None, :] == e[:, None], 1.0, w_new)
    w_new = jnp.where((cols < n + m)[None, :], w_new, 1.0)
    overflow = jnp.max(w_new, axis=1) > DEVEX_RESET
    w_new = jnp.where(overflow[:, None], 1.0, w_new)
    return jnp.where(do_pivot[:, None], w_new, w)


def compact_weights(w: jnp.ndarray, *, m: int, n: int) -> jnp.ndarray:
    """Phase compaction for weights: same column drop as
    ``simplex.compact_tableau`` — keep structurals+slacks and the rhs slot:
    (B, n+2m+1) -> (B, n+m+1).  Surviving columns' norms are untouched by
    the drop, so exact steepest-edge weights stay exact."""
    return jnp.concatenate([w[:, :n + m], w[:, -1:]], axis=1)


# ---------------------------------------------------------------------------
# Scalar NumPy dialect (core/reference.py float64 oracle)
# ---------------------------------------------------------------------------

def init_weights_np(rule: str, T: np.ndarray, m: int) -> np.ndarray:
    """(C,) initial weights for one float64 tableau (see init_weights)."""
    if rule == "steepest_edge":
        return 1.0 + (T[:m] * T[:m]).sum(axis=0)
    return np.ones(T.shape[1])


def select_entering_np(reduced: np.ndarray, w: np.ndarray, *, rule: str,
                       tol: float) -> int:
    """Scalar Step 1 (reduced costs with disallowed columns at -BIG)."""
    if rule == "dantzig":
        return int(np.argmax(reduced))
    improving = reduced > tol
    d = np.where(improving, reduced, 0.0)
    score = np.where(improving, d * d / w, -BIG)
    return int(np.argmax(score))


def update_weights_np(rule: str, w: np.ndarray, T_new: np.ndarray,
                      pivrow: np.ndarray, pe: float, e: int, r: int,
                      *, m: int, n: int) -> np.ndarray:
    """Scalar post-pivot recurrence (see update_weights, including the devex
    non-priceable-column pin)."""
    if rule == "dantzig":
        return w
    if rule == "steepest_edge":
        return 1.0 + (T_new[:m] * T_new[:m]).sum(axis=0)
    w_e = w[e]
    w = np.maximum(w, pivrow * pivrow * w_e)
    w[r] = max(w_e / (pe * pe), 1.0)
    w[e] = 1.0
    w[n + m:] = 1.0
    if w.max() > DEVEX_RESET:
        w[:] = 1.0
    return w
