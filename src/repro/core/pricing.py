"""Pluggable pricing engine: pivot-column selection rules for the batched simplex.

The paper's Step 1 (Sec. 4.1/5.2) hardcodes **Dantzig's rule** — enter the
column with the most positive reduced cost.  It is the cheapest rule per
pivot (one argmax the tableau already pays for) but the worst in pivot
*count*, and pivot count is exactly what the two-level work-elimination
engine (phase compaction + active-set compaction, PR 1) multiplies against:
every pivot a better rule avoids is a full rank-1 tableau update saved
across the surviving batch.

Four rules, one contract:

* ``dantzig``        — e = argmax_j d_j.  Stateless; the weights array is
                       carried but never read, so the compiled program (and
                       the pivot sequence) is identical to the pre-pricing
                       solver.
* ``steepest_edge``  — e = argmax_j d_j^2 / gamma_j with **exact** reference
                       weights gamma_j = 1 + ||B^-1 A_j||^2.  In a dense
                       tableau the current column T[:m, j] *is* B^-1 A_j, so
                       the exact gamma is a column-norm reduction over the
                       freshly updated tableau — the same O(m*C) cost as the
                       classic Goldfarb recurrence but with zero drift, which
                       is why the recompute (fused into the pivot update) is
                       the reference formulation here.
* ``devex``          — e = argmax_j d_j^2 / w_j with Forrest/Goldfarb
                       approximate reference weights: w_j starts at 1 and
                       after a pivot on (l, e) becomes
                       max(w_j, alpha_j^2 * w_e) with alpha the scaled pivot
                       row; the leaving variable r gets max(w_e/alpha_e^2, 1)
                       and the framework resets to 1 when weights overflow.
                       O(C) per pivot instead of O(m*C).
* ``partial``        — Dantzig restricted to a rotating candidate *block* of
                       columns, falling back to full Dantzig pricing the
                       moment an LP's block prices out (no improving column
                       in the block).  The block clock is the LP's own
                       iteration count (``iters % n_blocks``), which every
                       dialect already carries, so the block schedule — and
                       therefore the pivot sequence — is identical across
                       the tableau solver, the revised-simplex backend
                       (core/revised.py, where blocks actually cut the
                       pricing matvec from O(m*(n+m)) to O(m*block)) and the
                       float64 oracle.  On tableau backends the full cost
                       row is materialized anyway, so partial changes the
                       entering choice but not the per-pivot cost; it exists
                       there for cross-backend pivot-sequence parity.

All rules share the optimality test (max_j d_j <= tol) and Steps 2-3
unchanged, so INFEASIBLE/UNBOUNDED/OPTIMAL certificates are rule-independent
— only the path (and its length) through the basis graph differs.  Weights
live in the solver state as a (B, C) array whose batch axis 0 makes the
active-set compaction gathers, shard_map specs and Pallas tile BlockSpecs
uniform across rules; phase compaction slices weights with the same column
selection as the tableau (dropping columns never changes surviving columns'
norms, so exact steepest-edge weights survive the drop exactly).

This module holds the rule math in two dialects — batched JAX (used by
core/simplex.py) and scalar NumPy (used by the float64 oracle in
core/reference.py); kernels/simplex_tile.py re-expresses the same formulas
in broadcast/one-hot form for Pallas.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .lp import BIG

# Weighted rules (carry per-LP weight state through every backend).  The
# ``partial`` mode rides on top of Dantzig scoring and needs no weights, only
# the per-LP iteration clock — it is listed separately so weight-centric code
# (Pallas tile kernels, weight-gather plumbing) keeps iterating the original
# triple.
PRICING_RULES = ("dantzig", "steepest_edge", "devex")
ALL_PRICING = PRICING_RULES + ("partial",)

# Devex framework reset: when any reference weight exceeds this, the whole
# framework restarts at 1 (standard practice; keeps f32 scores well-scaled).
DEVEX_RESET = 1e7

# Partial pricing: candidate columns are scanned in blocks of this many
# columns (clamped to the candidate count).  64 keeps the revised backend's
# per-pivot pricing matvec lane-aligned and a small fraction of n+m for the
# paper's Table-5/6 regime while leaving enough candidates per block that the
# full-pricing fallback stays rare.
PARTIAL_BLOCK = 64


def canonicalize_rule(pricing: str) -> str:
    """Validate and normalize a pricing-rule name."""
    rule = str(pricing).lower()
    if rule not in ALL_PRICING:
        raise ValueError(
            f"unknown pricing rule {pricing!r}; expected one of {ALL_PRICING}")
    return rule


def partial_geometry(ncand: int, block: int | None = None):
    """(n_blocks, block_size) for partial pricing over ``ncand`` candidate
    columns.  Shared by every dialect so the block schedule is identical."""
    blk = min(int(block or PARTIAL_BLOCK), ncand)
    return -(-ncand // blk), blk


def partial_priced_candidates(ncand: int, block: int | None = None,
                              partial: bool = True) -> int:
    """Candidate columns priced per pivot under the given mode — one block
    pass plus the amortized full-pricing fallback (~once per block cycle);
    a single block degenerates to full pricing.  The shared quantity behind
    both halves of the revised work model (`core.revised.revised_elements`
    and `analysis.lp_perf.revised_pivot_flops`)."""
    if not partial:
        return ncand
    n_blocks, blk = partial_geometry(ncand, block)
    if n_blocks <= 1:
        return ncand
    return blk + ncand // n_blocks


# ---------------------------------------------------------------------------
# Batched JAX dialect (core/simplex.py)
# ---------------------------------------------------------------------------

def init_weights(rule: str, T: jnp.ndarray, m: int) -> jnp.ndarray:
    """Initial (B, C) pricing weights for a batch of tableaux.

    steepest_edge: exact gamma_j = 1 + ||T[:m, j]||^2 (the initial basis is
    the slack/artificial identity, so this is 1 + ||A_j||^2 for structurals).
    dantzig/devex: ones (dantzig never reads them; devex starts its reference
    framework at 1)."""
    B, _, C = T.shape
    if rule == "steepest_edge":
        return 1.0 + jnp.sum(T[:, :m, :] * T[:, :m, :], axis=1)
    return jnp.ones((B, C), T.dtype)


def select_entering(masked_cost: jnp.ndarray, w: jnp.ndarray, *, rule: str,
                    tol: float, iters: jnp.ndarray | None = None,
                    ncand: int | None = None):
    """Step 1 under a pricing rule.

    ``masked_cost`` is the objective row with disallowed columns already at
    -BIG.  Returns ``(e, max_cost)``: the entering column per LP and the max
    reduced cost (the rule-independent optimality test — a rule only changes
    *which* improving column enters, never *whether* one exists).

    ``partial`` additionally needs ``iters`` (the per-LP iteration clock that
    rotates the candidate block) and ``ncand`` (count of priceable columns,
    n+m in every tableau layout); the tableau dialect has the full cost row
    in hand, so the block restriction is a mask, not a work saving — see
    core/revised.py for the dialect where blocks cut the pricing matvec."""
    max_cost = jnp.max(masked_cost, axis=1)
    if rule == "dantzig":
        e = jnp.argmax(masked_cost, axis=1)
    elif rule == "partial":
        n_blocks, blk_sz = partial_geometry(ncand)
        blk = (iters % n_blocks).astype(jnp.int32)
        cols = jnp.arange(masked_cost.shape[1], dtype=jnp.int32)
        in_blk = (cols // blk_sz)[None, :] == blk[:, None]
        blk_cost = jnp.where(in_blk, masked_cost, -BIG)
        blk_max = jnp.max(blk_cost, axis=1)
        e = jnp.where(blk_max > tol,
                      jnp.argmax(blk_cost, axis=1),
                      jnp.argmax(masked_cost, axis=1))
    else:
        improving = masked_cost > tol
        d = jnp.where(improving, masked_cost, 0.0)
        score = jnp.where(improving, d * d / w, -BIG)
        e = jnp.argmax(score, axis=1)
    return e, max_cost


def update_weights(rule: str, w, T_new, pivrow, pe_safe, e, r, do_pivot,
                   *, m: int, n: int):
    """Post-pivot weight recurrence, fused into the rank-1 update.

    ``T_new``  — tableau *after* the pivot; ``pivrow`` — the scaled pivot row
    (T_new's row l); ``pe_safe`` — pivot element (1 where ~do_pivot);
    ``e``/``r`` — entering column / leaving variable's column per LP.
    Non-pivoting LPs keep their weights bitwise.

    Devex invariant (shared by every dialect): weights of non-priceable
    columns — artificials, rhs, padding, i.e. index >= n+m — are pinned to 1.
    Selection never reads them, but without the pin they would still feed the
    DEVEX_RESET overflow max (and a leaving *artificial*'s slot aliases the
    rhs after phase compaction), making reset timing depend on which layout
    a backend happens to use.  Pinned, the full, phase-compacted, lane-padded
    and float64 dialects all carry identical effective state."""
    if rule in ("dantzig", "partial"):
        return w
    if rule == "steepest_edge":
        w_new = 1.0 + jnp.sum(T_new[:, :m, :] * T_new[:, :m, :], axis=1)
        return jnp.where(do_pivot[:, None], w_new, w)
    # devex
    C = w.shape[1]
    cols = jnp.arange(C)
    w_e = jnp.take_along_axis(w, e[:, None], axis=1)[:, 0]
    w_new = jnp.maximum(w, pivrow * pivrow * w_e[:, None])
    w_leave = jnp.maximum(w_e / (pe_safe * pe_safe), 1.0)
    w_new = jnp.where(cols[None, :] == r[:, None], w_leave[:, None], w_new)
    w_new = jnp.where(cols[None, :] == e[:, None], 1.0, w_new)
    w_new = jnp.where((cols < n + m)[None, :], w_new, 1.0)
    overflow = jnp.max(w_new, axis=1) > DEVEX_RESET
    w_new = jnp.where(overflow[:, None], 1.0, w_new)
    return jnp.where(do_pivot[:, None], w_new, w)


def compact_weights(w: jnp.ndarray, *, m: int, n: int) -> jnp.ndarray:
    """Phase compaction for weights: same column drop as
    ``simplex.compact_tableau`` — keep structurals+slacks and the rhs slot:
    (B, n+2m+1) -> (B, n+m+1).  Surviving columns' norms are untouched by
    the drop, so exact steepest-edge weights stay exact."""
    return jnp.concatenate([w[:, :n + m], w[:, -1:]], axis=1)


# ---------------------------------------------------------------------------
# Scalar NumPy dialect (core/reference.py float64 oracle)
# ---------------------------------------------------------------------------

def init_weights_np(rule: str, T: np.ndarray, m: int) -> np.ndarray:
    """(C,) initial weights for one float64 tableau (see init_weights)."""
    if rule == "steepest_edge":
        return 1.0 + (T[:m] * T[:m]).sum(axis=0)
    return np.ones(T.shape[1])


def select_entering_np(reduced: np.ndarray, w: np.ndarray, *, rule: str,
                       tol: float, iters: int = 0,
                       ncand: int | None = None) -> int:
    """Scalar Step 1 (reduced costs with disallowed columns at -BIG).

    ``partial`` scans the candidate block selected by the LP's iteration
    clock (``iters``) and falls back to full Dantzig when it prices out —
    the same schedule as the JAX dialects, so oracle pivot sequences remain
    the per-rule ground truth."""
    if rule == "dantzig":
        return int(np.argmax(reduced))
    if rule == "partial":
        n_blocks, blk_sz = partial_geometry(ncand)
        blk = iters % n_blocks
        blk_red = reduced[blk * blk_sz:(blk + 1) * blk_sz]
        if blk_red.size and np.max(blk_red) > tol:
            return blk * blk_sz + int(np.argmax(blk_red))
        return int(np.argmax(reduced))
    improving = reduced > tol
    d = np.where(improving, reduced, 0.0)
    score = np.where(improving, d * d / w, -BIG)
    return int(np.argmax(score))


def update_weights_np(rule: str, w: np.ndarray, T_new: np.ndarray,
                      pivrow: np.ndarray, pe: float, e: int, r: int,
                      *, m: int, n: int) -> np.ndarray:
    """Scalar post-pivot recurrence (see update_weights, including the devex
    non-priceable-column pin)."""
    if rule in ("dantzig", "partial"):
        return w
    if rule == "steepest_edge":
        return 1.0 + (T_new[:m] * T_new[:m]).sum(axis=0)
    w_e = w[e]
    w = np.maximum(w, pivrow * pivrow * w_e)
    w[r] = max(w_e / (pe * pe), 1.0)
    w[e] = 1.0
    w[n + m:] = 1.0
    if w.max() > DEVEX_RESET:
        w[:] = 1.0
    return w
