"""LP problem containers: the canonical standard form every solver consumes.

The paper (Gurung & Ray 2018) solves LPs in *standard form*:

    maximize    c . x
    subject to  A x <= b,   x >= 0

with ``m`` constraints over ``n`` variables ("LP dimension" in the paper is
``n``). A batch holds ``B`` independent LPs of identical (m, n) — the paper's
solver makes the same same-size assumption (Sec. 5).  ``LPBatch`` below *is*
that canonical form, and it is all the device backends ever see.

Real problems rarely arrive in standard form.  The general-form entry path
(core/forms.py + io/mps.py) is the front door:

    general = repro.io.read_mps("afiro.mps")          # GeneralLPBatch: any
    batch   = repro.io.perturbed_batch(general, B)    # senses/bounds/min-max
    res     = solve_batched(batch, backend="revised") # original coordinates

Every ``solve_*`` entry point accepts a ``GeneralLPBatch`` directly: it is
canonicalized on ingestion (presolve + geometric-mean scaling on by
default; ``=``/``>=``/ranged rows become a ``<=`` pair, free variables
split, minimization flips sign — equalities therefore *grow m*), the
canonical ``LPBatch`` is solved on device, and the result is mapped back
to original coordinates by the ``Recovery`` record, so compaction,
pricing, shard_map and the Pallas kernels compose with general problems
unchanged.  Finite variable upper bounds are *native*: ``LPBatch.ub``
carries a per-column bound vector (``0 <= x <= ub``, +inf = unbounded)
and every engine runs the bounded-variable ratio test against it — a
finite bound costs zero extra rows instead of one dense row each (the
``bound_rows=True`` escape hatch in ``canonicalize`` restores the old
row encoding for A/B comparisons).

The simplex tableau layout follows Sec. 4.1/5.5 of the paper:

    rows    0..m-1 : constraint rows
    row     m      : phase-2 objective row (reduced costs; value = -T[m, -1])
    row     m+1    : phase-1 objective row (for the two-phase method)
    columns 0..n-1          : structural variables
    columns n..n+m-1        : slack variables
    columns n+m..n+2m-1     : artificial variables (zero columns when b_i >= 0)
    column  n+2m            : right-hand side

Keeping the artificial block allocated for *every* row (not only rows with
b_i < 0) is what gives every LP in the batch an identical static shape — the
JAX/TPU analogue of the paper's same-size batching requirement.

Choosing a backend
------------------

Every ``solve_*`` entry point takes ``backend=`` (validated against
``BACKEND_REGISTRY`` below); the three engines trade exactness against
per-iteration parallel depth:

* ``"tableau"`` (default, core/simplex.py) — the paper's dense simplex.
  **Exact** statuses/vertex solutions in O(m+n) pivots; each pivot is a
  rank-1 update over the whole (m+2)x(n+2m+1) tableau.  Wins on
  small/medium dense square-ish LPs (the paper's Tables 2-4 regime).
* ``"revised"`` (core/revised.py) — exact simplex on basis factors:
  O(m^2) + pricing per pivot against immutable data.  Wins when the
  canonical shape is wide (n >> m) or sparse — the paper's Netlib regime
  (see analysis.lp_perf.revised_crossover).
* ``"pdhg"`` (core/pdhg.py) — restarted primal-dual hybrid gradient
  (PDLP-style first-order method).  **Tolerance-based**: OPTIMAL means the
  KKT residuals (primal/dual feasibility + duality gap) dropped below
  ``tol``; solutions are interior-accurate rather than vertex-exact, and
  every iteration is one batched matvec pair — no pivoting, no sequential
  ratio test.  Wins when LPs are large enough that per-pivot sequential
  depth dominates (analysis.lp_perf.pdhg_crossover locates the frontier),
  and it natively emits the primal-dual certificate every backend now
  reports (``LPResult.y``/``z``).

Two orthogonal capabilities cut across the engines:

* **Bounds** — all three engines take ``LPBatch.ub`` natively: the simplex
  engines run the bounded-variable ratio test (an entering column may hit
  its own upper bound and *flip* — an O(1) bookkeeping move instead of a
  pivot), PDHG clips its primal prox step into ``[0, ub]``.  Prefer native
  bounds (the ``canonicalize`` default) whenever upper bounds exist: a
  finite bound as a row costs a dense (n+2m)-wide tableau row *and* a
  pivot to activate, as a native bound it costs nothing per iteration.
  Row encoding (``bound_rows=True``) only remains useful as an A/B
  reference and for bounds on free (split) columns.
* **Sparsity** — backends with ``supports_sparse`` (currently ``pdhg``)
  also accept a ``SparseLPBatch`` (core/sparse.py): one sparsity pattern
  shared across the batch with per-LP values, the shape
  ``io.mps.perturbed_batch`` produces.  Sparse PDHG replaces the dense
  (B, m, n) einsum pair with gather/scatter matvecs, so the per-iteration
  cost scales with ``nnz`` instead of ``m*n`` — it wins whenever density
  is below ~50% and dominates at Netlib-like 1-2% density
  (``analysis.lp_perf.sparse_matvec_flops`` quantifies the ratio).  The
  pivot-exact simplex engines stay dense: their tableaux fill in after a
  handful of pivots regardless of input sparsity.

``backend_spec(name).exact`` distinguishes the two certificate semantics;
tolerance-based backends must be compared against oracles at ``tol``, not
bitwise.

Warm starting repeated solves
-----------------------------

Sequences of near-identical batches (the reachability loop of Sec. 7, MPC,
branch-and-bound re-solves) should not pay cold-start cost every time.  Every
monolithic batched solver captures its terminal state in a backend-uniform
``WarmStart`` carrier and the next solve re-injects it:

    res1 = solve_batched(batch1)                    # cold
    res2 = solve_batched(batch2, warm=res1.warm_start())   # warm

For the simplex engines the carrier holds the final basis, the
nonbasic-at-upper flips and the pricing weights; injection rebuilds the
tableau (or refactorizes the basis) from the parent basis, checks primal
feasibility *per LP*, and each LP independently (a) skips phase 1 when the
parent basis is still feasible, (b) runs a repair phase 1 seeded from the
parent basis (only the violated rows get artificials) when it is not, or
(c) falls back to the cold construction when the basis is unusable
(singular/out of range).  For PDHG the carrier holds the final iterates,
the primal weight ``omega`` and the step-size state; injection adopts them
only when their KKT residual beats the cold zero start (the reset guard),
so a bad warm start can never do worse than cold.  Statuses and final
objectives are unchanged either way — warm starting only moves the start
point, never the optimality test.

Warm starts survive general-form canonicalization (``Recovery`` maps the
carrier between original and canonical coordinates, forms.prepare_warm) and
ride through the chunked driver's sorting/slicing like every other per-LP
leaf.  A carrier whose shape does not match the target batch is dropped
with a warning (cold solve), never an error.

Once phase 1 certifies feasibility, the artificial block and the phase-1
objective row are dead weight; the device solvers drop them with a one-shot
*phase compaction* (core/simplex.py) and finish phase 2 on the
(m+1) x (n+m+1) tableau — see ``LPBatch.compacted_tableau_shape``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# Status codes shared by every solver backend (NumPy oracle, JAX, Pallas).
OPTIMAL = 0
UNBOUNDED = 1
INFEASIBLE = 2
ITERATION_LIMIT = 3

STATUS_NAMES = {
    OPTIMAL: "optimal",
    UNBOUNDED: "unbounded",
    INFEASIBLE: "infeasible",
    ITERATION_LIMIT: "iteration_limit",
}

# The paper's branch-elimination sentinel (Sec. 5.2): invalid min-ratio
# entries are replaced by a large positive value instead of being masked
# with a conditional.
BIG = 1e30


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capabilities + lazy entry points of one solver engine.

    The registry below is the single source of truth for ``backend=``
    dispatch: every ``solve_*`` entry point validates names against it and
    routes through ``resolve_backend`` instead of special-casing strings,
    and warning paths (e.g. the Pallas fallback) consult the capability
    flags instead of hardcoding engine names.
    """

    name: str
    exact: bool                # pivot-exact simplex certificates (statuses
                               # from exact ratio tests) vs tolerance-based
                               # convergence (PDHG: OPTIMAL means KKT
                               # residuals <= tol, objectives are approximate)
    supports_pallas: bool      # has a dedicated Pallas tile kernel
    supports_compaction: bool  # composes with the active-set scheduler
    solve: str                 # "module:attr" entry points, imported lazily
    solve_compacted: str       # (the engine modules import this module, so
    solve_local: str           # the registry cannot import them eagerly)
    supports_sparse: bool = False  # accepts SparseLPBatch (shared-pattern
    solve_sparse: str = ""         # sparse matvecs) via solve_sparse
    supports_safe_bound: bool = False  # emits dual certificates (LPResult.y/z)
                                       # a consumer can turn into *valid*
                                       # relaxation bounds independent of the
                                       # engine's own tolerance (the B&B
                                       # driver's safe-bound pass requires
                                       # this from non-exact backends)


BACKEND_REGISTRY = {
    # dense tableaux, rank-1 pivot updates (core/simplex.py)
    "tableau": BackendSpec(
        name="tableau", exact=True, supports_pallas=True,
        supports_compaction=True,
        solve="repro.core.simplex:solve_batched_jax",
        solve_compacted="repro.core.compaction:solve_batched_compacted",
        solve_local="repro.core.simplex:solve_two_phase",
        supports_safe_bound=True),
    # immutable data, basis-factor updates (core/revised.py)
    "revised": BackendSpec(
        name="revised", exact=True, supports_pallas=True,
        supports_compaction=True,
        solve="repro.core.revised:solve_batched_revised",
        solve_compacted="repro.core.revised:solve_batched_revised_compacted",
        solve_local="repro.core.revised:solve_revised",
        supports_safe_bound=True),
    # restarted primal-dual hybrid gradient, matrix-free first-order
    # iterations with tolerance-based KKT convergence (core/pdhg.py);
    # the only engine whose per-iteration work is a pure matvec pair,
    # hence the only one where shared-pattern sparsity pays (core/sparse.py)
    "pdhg": BackendSpec(
        name="pdhg", exact=False, supports_pallas=True,
        supports_compaction=True,
        solve="repro.core.pdhg:solve_batched_pdhg",
        solve_compacted="repro.core.pdhg:solve_batched_pdhg_compacted",
        solve_local="repro.core.pdhg:solve_pdhg",
        supports_sparse=True,
        solve_sparse="repro.core.sparse:solve_batched_pdhg_sparse",
        supports_safe_bound=True),
}

# Back-compat tuple (older call sites iterate it for error messages).
BACKENDS = tuple(BACKEND_REGISTRY)


def canonicalize_backend(backend: str) -> str:
    """Validate a solver-engine name (shared by every ``backend=`` kwarg)."""
    if backend not in BACKEND_REGISTRY:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    return backend


def backend_spec(backend: str) -> BackendSpec:
    """The registry record for a (validated) engine name."""
    return BACKEND_REGISTRY[canonicalize_backend(backend)]


def resolve_backend(backend: str, *, compacted: bool = False,
                    local: bool = False, sparse: bool = False):
    """Late-bound engine entry point: the monolithic batched solver, the
    compaction-scheduled variant, the traceable pjit/shard_map body, or
    (``sparse=True``) the shared-pattern sparse solver for backends whose
    spec advertises ``supports_sparse``.  Importing lazily keeps the
    registry cycle-free (engine modules import this module)."""
    import importlib

    spec = backend_spec(backend)
    if sparse:
        if not spec.supports_sparse:
            raise ValueError(
                f"backend {backend!r} has no sparse entry point; "
                "sparse-capable backends: "
                f"{[s.name for s in BACKEND_REGISTRY.values() if s.supports_sparse]}")
        ref = spec.solve_sparse
    else:
        ref = (spec.solve_local if local
               else spec.solve_compacted if compacted else spec.solve)
    module, attr = ref.split(":")
    return getattr(importlib.import_module(module), attr)


@dataclasses.dataclass(frozen=True)
class LPBatch:
    """A batch of B independent LPs of identical shape (m constraints, n vars).

    Arrays may be NumPy or JAX; shapes are (B, m, n), (B, m), (B, n).

    ``ub`` (optional, (B, n)) are native variable upper bounds: the problem
    becomes ``max c.x s.t. Ax <= b, 0 <= x <= ub`` with +inf marking
    unbounded columns.  ``ub=None`` means all +inf (the paper's original
    standard form); every engine treats the two identically.
    """

    A: np.ndarray
    b: np.ndarray
    c: np.ndarray
    ub: np.ndarray | None = None

    @property
    def batch(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def n(self) -> int:
        return self.A.shape[2]

    def upper_bounds(self) -> np.ndarray:
        """The (B, n) bound vector with ``None`` materialized as all +inf —
        what the engines consume (their bounded ratio tests degenerate to
        the classic unbounded test on +inf entries)."""
        if self.ub is None:
            return np.full((self.batch, self.n), np.inf, np.float64)
        return np.asarray(self.ub)

    @staticmethod
    def from_arrays(A, b, c, ub=None) -> "LPBatch":
        A = np.asarray(A)
        b = np.asarray(b)
        c = np.asarray(c)
        if A.ndim == 2:  # single LP convenience
            A, b, c = A[None], b[None], c[None]
            if ub is not None and np.asarray(ub).ndim == 1:
                ub = np.asarray(ub)[None]
        B, m, n = A.shape
        if b.shape != (B, m) or c.shape != (B, n):
            raise ValueError(
                f"inconsistent LP batch shapes: A={A.shape} b={b.shape} c={c.shape}"
            )
        if ub is not None:
            ub = np.asarray(ub, np.float64)
            if ub.shape != (B, n):
                raise ValueError(
                    f"inconsistent ub shape: expected {(B, n)}, got {ub.shape}")
            if (ub < 0).any():
                raise ValueError("ub must be >= 0 (the canonical lower bound)")
            if not np.isfinite(ub).any():
                ub = None  # all +inf is the unbounded case
        return LPBatch(A=A, b=b, c=c, ub=ub)

    def tableau_shape(self) -> Tuple[int, int]:
        """(rows, cols) of the per-LP simplex tableau (incl. both obj rows)."""
        return (self.m + 2, self.n + 2 * self.m + 1)

    def compacted_tableau_shape(self) -> Tuple[int, int]:
        """(rows, cols) of the phase-compacted phase-2 tableau (artificial
        columns and the phase-1 objective row removed)."""
        return (self.m + 1, self.n + self.m + 1)

    def bytes_per_lp(self, dtype_size: int = 4) -> int:
        """Device bytes needed per LP — Eq. (5) of the paper, adapted.

        Tableau + basis + the two reduction scratch vectors (Data/Indices in
        the paper's Fig. 4/5 become the ratio/cost vectors here).
        """
        rows, cols = self.tableau_shape()
        tableau = rows * cols * dtype_size
        basis = self.m * 4
        scratch = 2 * cols * dtype_size  # the paper's two auxiliary arrays
        return tableau + basis + scratch


@dataclasses.dataclass(frozen=True)
class WarmStart:
    """Backend-uniform warm-start carrier: the terminal solver state of one
    batched solve, re-injectable into the next via ``solve_*(..., warm=ws)``.

    ``m``/``n`` are the *canonical* dimensions of the batch the carrier was
    captured from (a basis has no original-coordinate meaning, so for
    general-form solves the carrier stays in canonical space; only the
    equilibration scaling is peeled off its iterate leaves by ``Recovery``).
    A carrier is only usable on a batch whose canonical shape matches
    (B, m, n); mismatches are dropped with a warning at injection
    (forms.prepare_warm), degrading to a cold solve.

    Simplex leaves (tableau/revised engines):
      basis    (B, m) int32 — parent basis (column basic in each row)
      at_upper (B, n) bool  — structural columns nonbasic at their upper
                              bound (tableau ``flip`` / revised ``onub``)
      weights  (B, C)       — pricing weights at termination (``pricing``
                              tags the rule; reused only when rule and
                              shape still match, else re-initialized)
    PDHG leaves:
      x (B, n), y (B, m)    — final iterates (original coordinates)
      omega (B,)            — primal weight at termination
      eta   (B,)            — step size at termination (recorded for
                              completeness; injection re-estimates the step
                              from the new matrix, which is always safe)

    Unused leaves are None — a simplex result carries no PDHG state and
    vice versa, and each engine ignores the other's leaves at injection.
    """

    m: int
    n: int
    basis: np.ndarray | None = None
    at_upper: np.ndarray | None = None
    weights: np.ndarray | None = None
    pricing: str | None = None
    x: np.ndarray | None = None
    y: np.ndarray | None = None
    omega: np.ndarray | None = None
    eta: np.ndarray | None = None

    _ARRAY_FIELDS = ("basis", "at_upper", "weights", "x", "y", "omega", "eta")

    @property
    def batch(self) -> int:
        for f in self._ARRAY_FIELDS:
            v = getattr(self, f)
            if v is not None:
                return np.asarray(v).shape[0]
        return 0

    def _map(self, fn) -> "WarmStart":
        kw = {f: (None if getattr(self, f) is None
                  else fn(np.asarray(getattr(self, f))))
              for f in self._ARRAY_FIELDS}
        return WarmStart(m=self.m, n=self.n, pricing=self.pricing, **kw)

    def take(self, idx) -> "WarmStart":
        """Gather per-LP state along the batch axis (sorting/permutation)."""
        return self._map(lambda a: a[np.asarray(idx)])

    def slice(self, start: int, stop: int) -> "WarmStart":
        """The [start:stop) sub-carrier (chunked driver)."""
        return self._map(lambda a: a[start:stop])

    @staticmethod
    def concat(parts) -> "WarmStart | None":
        """Concatenate per-chunk carriers back into one (chunked driver).
        Any missing part (a chunk whose solver captured no state) drops the
        whole carrier — a partial warm start cannot be re-injected."""
        parts = list(parts)
        if not parts or any(p is None for p in parts):
            return None
        first = parts[0]
        kw = {}
        for f in WarmStart._ARRAY_FIELDS:
            vals = [getattr(p, f) for p in parts]
            if any(v is None for v in vals):
                kw[f] = None
            else:
                kw[f] = np.concatenate([np.asarray(v) for v in vals])
        return WarmStart(m=first.m, n=first.n, pricing=first.pricing, **kw)


@dataclasses.dataclass(frozen=True)
class LPResult:
    """Solver output for a batch: per-LP solution, objective, status, iters,
    and (when the backend provides them) the dual certificate.

    ``y``/``z`` are the backend-independent dual certificate, populated at
    OPTIMAL and NaN elsewhere (None when a path cannot produce duals, e.g.
    the Pallas tableau segment path pre-extraction):

    * ``y`` (B, m) — row duals.  Canonical batches report the duals of
      ``max c.x s.t. Ax <= b, x >= 0`` (y >= 0, strong duality b.y = c.x);
      general batches report original-coordinate row duals under the
      convention ``z = c - A^T y`` with the *original* objective vector, so
      signs follow the problem sense (see forms.Recovery.recover_duals).
    * ``z`` (B, n) — reduced costs ``c - A^T y``; complementary slackness
      pairs them with active bounds (forms.general_kkt is the checker).

    ``warm`` is the terminal solver state (basis/flips/weights for the
    simplex engines, iterates/omega/eta for PDHG) when the solve path
    captures it — the monolithic batched solvers and the chunked driver do;
    compaction-scheduled, distributed and Pallas paths report None.  Feed it
    to the next solve of a perturbed batch via
    ``solve_batched(batch2, warm=res.warm_start())``.

    ``stats`` is a ``repro.obs.SolveReport`` (per-LP telemetry counters +
    host span tree + wall-clock) when the solve ran with ``telemetry=True``;
    None otherwise.  ``stats.iterations`` always equals ``iterations``.
    """

    x: np.ndarray          # (B, n)
    objective: np.ndarray  # (B,)
    status: np.ndarray     # (B,) int8  — see status codes above
    iterations: np.ndarray  # (B,) int32
    y: np.ndarray | None = None   # (B, m) row duals (see above)
    z: np.ndarray | None = None   # (B, n) reduced costs
    warm: "WarmStart | None" = None  # terminal state for warm restarts
    stats: "object | None" = None  # obs.SolveReport when telemetry was on

    def warm_start(self) -> WarmStart:
        """The warm-start carrier for a follow-up solve of a same-shape
        (typically perturbed) batch.  Raises when this result came from a
        path that does not capture terminal state (compaction scheduler,
        distributed solvers, Pallas kernels) — solve cold there, or route
        the sequence through a monolithic/chunked entry point."""
        if self.warm is None:
            raise ValueError(
                "this LPResult carries no warm-start state (the producing "
                "path does not capture it — e.g. compaction-scheduled, "
                "distributed or Pallas solves); re-solve through a "
                "monolithic entry point to obtain one")
        return self.warm

    def summary(self) -> str:
        status = np.asarray(self.status)
        parts = [
            f"{STATUS_NAMES[code]}={int((status == code).sum())}"
            for code in sorted(STATUS_NAMES)
            if (status == code).any()
        ]
        return ", ".join(parts)


def build_tableau(A: np.ndarray, b: np.ndarray, c: np.ndarray):
    """Build the batched two-phase tableau (float64 NumPy; init path).

    Returns (T, basis, needs_phase1):
      T:      (B, m+2, n+2m+1)
      basis:  (B, m) int32   — basis[i] = column index basic in row i
      needs_phase1: (B,) bool
    Rows with b_i < 0 are negated (making rhs >= 0) and given an artificial
    variable; other rows start with their slack basic — exactly the paper's
    Sec. 4 construction, except artificial columns exist (as zeros) for all
    rows so the batch keeps one static shape.
    """
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    B, m, n = A.shape
    cols = n + 2 * m + 1
    T = np.zeros((B, m + 2, cols), dtype=np.float64)

    neg = b < 0  # (B, m)
    sign = np.where(neg, -1.0, 1.0)
    T[:, :m, :n] = A * sign[:, :, None]
    # slack block: identity scaled by the row sign
    idx = np.arange(m)
    T[:, idx, n + idx] = sign
    # artificial block: +1 only where the row was negated
    T[:, idx, n + m + idx] = np.where(neg, 1.0, 0.0)
    T[:, :m, -1] = b * sign

    # phase-2 objective row: reduced costs start at c
    T[:, m, :n] = c
    # phase-1 objective row: sum of rows that carry an artificial
    T[:, m + 1, :] = (T[:, :m, :] * neg[:, :, None]).sum(axis=1)
    # basic columns must have zero reduced cost: zero out the artificial
    # columns of the phase-1 row (they are basic where they exist)
    T[:, m + 1, n + m:n + 2 * m] = 0.0

    basis = np.where(neg, n + m + idx[None, :], n + idx[None, :]).astype(np.int32)
    return T, basis, neg.any(axis=1)


def extract_solution(T: np.ndarray, basis: np.ndarray, n: int,
                     ub: np.ndarray | None = None,
                     flip: np.ndarray | None = None):
    """Read (x, objective) off a final tableau batch.

    Batched scatter: structural basis entries (basis < n) write their row's
    rhs into x, everything else lands in a dump slot that is sliced away —
    one vectorized write instead of the old O(m) host loop over rows (a
    legal basis never repeats a column, so the writes cannot collide).

    With the bounded-variable method, columns whose ``flip`` flag is set
    are stored *complemented* (x' = ub - x): a flipped basic column reads
    ``ub - rhs``, a flipped nonbasic column sits at its upper bound.  The
    objective row's rhs already tracks the true objective through every
    flip (the complement substitution updates it), so ``-T[m, -1]`` is
    unchanged."""
    B, rows, cols = T.shape
    m = rows - 2
    rhs = T[:, :m, -1]
    sel = basis[:, :m] < n
    target = np.where(sel, basis[:, :m], n)          # n = dump slot
    xpad = np.zeros((B, n + 1), dtype=T.dtype)
    xpad[np.arange(B)[:, None], target] = np.where(sel, rhs, 0.0)
    x = xpad[:, :n]
    if flip is not None and flip.any():
        # flipped basic: ub - rhs; flipped nonbasic: ub - 0 = ub
        x = np.where(flip[:, :n], np.asarray(ub, dtype=T.dtype) - x, x)
    objective = -T[:, m, -1]
    return x, objective


def default_max_iters(m: int, n: int) -> int:
    """Iteration cap. Dantzig's rule typically terminates in O(m+n) pivots on
    the paper's problem classes; the cap only exists to bound the lockstep
    while-loop."""
    return 10 * (m + n) + 50
