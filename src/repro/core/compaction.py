"""Active-set compaction scheduler — Level 2 of the work-elimination engine.

The paper's CUDA solver load-balances through per-block early exit (Sec. 5):
each LP's block returns the moment *its* simplex terminates, and the block
scheduler backfills the SM.  Lockstep static-shape solvers (JAX while-loop,
Pallas tile kernel) lose that: a converged LP keeps occupying its batch slot,
executing masked no-op pivots until the slowest LP in its termination group
finishes.  `analysis/lp_perf.py` measures that waste as 1 - mean/max over the
pivot distribution — up to ~2x on mixed feasible/infeasible batches.

This module is a static-shape-friendly reconstruction of per-block exit:

1. run the solve in **segments** of at most ``segment_k`` pivots (each
   segment is one XLA computation with its own early-stopping while-loop);
2. after each segment, count surviving ``_RUNNING`` LPs on the host
   (one tiny D2H transfer of the status vector);
3. when the active fraction drops below ``compact_threshold``, **gather** the
   survivors into the next power-of-two bucket size and resume.

Bucket sizes walk a fixed ladder (B, B/2, B/4, ..., times ``pad_multiple``
for sharded/tiled backends), so recompiles are amortized: every batch of the
same starting size reuses the same ladder of compiled segment programs.
Because gathering LPs never changes any LP's own tableau, the pivot sequence
— and therefore status/objective/iterations — is bit-identical to the
unsegmented solver.

The scheduler is backend-agnostic: a backend supplies segment runners and
state plumbing.  `JaxBackend` (here) runs the pure-JAX phase-compacted
solver; `core.distributed._ShardMapBackend` runs segments under shard_map
(per-shard termination *inside* segments); `kernels.ops.PallasBackend` runs
the Pallas tile kernels.  Both levels compose: segments before column
compaction run on the full tableau (stage "p1"), segments after it on the
phase-compacted tableau (stage "p2") — see core/simplex.py for Level 1.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.report import report_from_counters
from ..obs.telemetry import init_telemetry, tel_to_numpy, zeros_numpy
from .forms import ensure_canonical, finish_result, prepare_warm
from .lp import (ITERATION_LIMIT, OPTIMAL, LPBatch, LPResult, WarmStart,
                 canonicalize_backend, default_max_iters, resolve_backend)
from .pricing import canonicalize_rule, compact_weights, init_weights
from .simplex import (
    _RUNNING,
    SimplexState,
    build_tableau_jax,
    compact_tableau,
    extract_duals,
    extract_solution_compacted,
    extract_solution_jax,
    inject_tableau_warm,
    phase2_step,
    simplex_step,
    tableau_elements,
)


class CompactionState(NamedTuple):
    """Resumable solver state; every leaf has the batch on axis 0 so generic
    gathers (`backend.take`) work across backends."""
    T: jax.Array       # tableaux (full in stage p1, compacted in stage p2)
    basis: jax.Array
    phase: jax.Array
    status: jax.Array
    iters: jax.Array
    w: jax.Array       # (B, C) pricing weights (core/pricing.py); gathered
                       # across segment boundaries like every other leaf
    flip: jax.Array    # (B, n) bool complement flags (bounded variables)
    ub: jax.Array      # (B, n) upper bounds (+inf = unbounded)
    thr: jax.Array     # per-LP phase-1 feasibility threshold
    tel: Any = None    # obs.TelemetryState lanes or None (empty subtree:
                       #  the telemetry-off trace is unchanged); rides the
                       #  bucket gathers like every other leaf


def auto_segment_k(m: int, n: int) -> int:
    """Segment length heuristic when the caller passes ``segment_k=None``:
    ~1/64 of the `default_max_iters` cap (floor 4), so a typical solve gets
    a handful of compaction checkpoints regardless of problem size instead
    of the one-size static 8.  Dantzig pivots O(m+n) times on the paper's
    classes, so this lands segments at roughly every 15% of the expected
    solve; steeper rules just hit the checkpoints sooner."""
    return max(4, default_max_iters(m, n) // 64)


def auto_compact_threshold(segment_k: int) -> float:
    """Compact-threshold heuristic when the caller passes
    ``compact_threshold=None``, tuned from the observed ``SegmentStat``
    survivor curves in BENCH_pivot_work.json (``scheduled.survivor_curve``).

    A gather costs ~2 state touches (read + scatter-write), i.e. roughly 2
    lockstep steps of the *new* bucket, while compacting at active fraction
    f saves (1 - f) * segment_k step-slots over the next segment alone — so
    a shrink pays off once segment_k >= 2 f / (1 - f), giving the eagerness
    curve f* = segment_k / (segment_k + 2).  The measured survivor curves
    collapse by 30-50% per segment (e.g. 2181 -> 1729 -> 150 of 4096 at
    5x5), so for the auto-derived segment_k (>= 4) every power-of-two shrink
    pays: the derived threshold sits above the pow2 ladder's own f <= 1/2
    shrink gate and never blocks one.  Only very short segments
    (segment_k <= 2, where gather overhead rivals the segment itself) get a
    stricter bar than the historical static 0.5."""
    if segment_k < 1:
        raise ValueError(f"segment_k must be >= 1, got {segment_k}")
    return min(0.95, segment_k / (segment_k + 2.0))


def resolve_compact_threshold(compact_threshold: Optional[float],
                              segment_k: int) -> float:
    """``None`` -> derived (`auto_compact_threshold`); floats pass through
    (0.5 was the historical static default)."""
    if compact_threshold is None:
        return auto_compact_threshold(segment_k)
    return float(compact_threshold)


@dataclasses.dataclass(frozen=True)
class CompactionConfig:
    segment_k: int = 8            # max pivots per segment
    compact_threshold: float = 0.5  # gather when active fraction < this
    pad_multiple: int = 1         # bucket sizes are multiples of this

    def __post_init__(self):
        if self.segment_k < 1:
            raise ValueError(f"segment_k must be >= 1, got {self.segment_k}")
        if self.pad_multiple < 1:
            raise ValueError(
                f"pad_multiple must be >= 1, got {self.pad_multiple}")


@dataclasses.dataclass
class SegmentStat:
    """Executed-work record for one segment (benchmarks/pivot_work.py)."""
    stage: str      # "p1" (full tableau) or "p2" (compacted)
    bucket: int     # batch slots occupied during the segment
    steps: int      # lockstep steps actually executed (<= segment_k)
    elements: int   # steps * bucket * tableau_elements(stage)
    survivors: int = -1  # RUNNING LPs observed after the segment (the
                         # survivor curve the auto-tune heuristic targets)


def total_elements(stats: List[SegmentStat]) -> int:
    return sum(s.elements for s in stats)


def total_steps(stats: List[SegmentStat]) -> int:
    return sum(s.steps for s in stats)


def next_bucket(active: int, pad_multiple: int = 1) -> int:
    """Next-power-of-two bucket >= active, rounded up to pad_multiple."""
    b = 1 << max(0, active - 1).bit_length()
    return -(-b // pad_multiple) * pad_multiple


def init_orig(backend, state, B: int):
    """Build the original-slot map for a freshly init'd backend state.

    Returns ``(state, orig)`` where ``orig[i]`` is the caller's batch index
    occupying slot ``i``.  A backend may return a batch-padded state from
    ``init`` (Pallas tile multiples); padding slots get ``orig == -1`` and
    are deactivated so the scheduler never counts them as active.
    """
    orig = np.arange(B, dtype=np.int64)
    B_state = int(np.asarray(backend.status_host(state)).shape[0])
    if B_state > B:
        orig = np.concatenate(
            [orig, np.full(B_state - B, -1)]).astype(np.int64)
        state = backend.deactivate(state, orig >= 0)
    return state, orig


# ---------------------------------------------------------------------------
# Traceable segment runners (shared by JaxBackend and the shard_map backend)
# ---------------------------------------------------------------------------

def segment_phase1(state: CompactionState, steps, *, m: int, n: int,
                   tol: float, rule: str = "dantzig"):
    """Run up to `steps` combined (phase-1/phase-2) pivots on the full
    tableau; stops early once no LP is still in phase 1."""
    def cond(carry):
        s, it = carry
        pending = (s.status == _RUNNING) & (s.phase == 1)
        return jnp.any(pending) & (it < steps)

    def body(carry):
        s, it = carry
        ns = simplex_step(
            SimplexState(s.T, s.basis, s.phase, s.status, s.iters, s.w,
                         s.flip, s.ub, it, s.tel),
            n=n, m=m, tol=tol, feas_thr=s.thr, rule=rule)
        return CompactionState(ns.T, ns.basis, ns.phase, ns.status, ns.iters,
                               ns.w, ns.flip, ns.ub, s.thr, ns.tel), it + 1

    state, it = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state, it


def segment_phase2(state: CompactionState, steps, *, m: int, n: int,
                   tol: float, rule: str = "dantzig"):
    """Run up to `steps` phase-2 pivots on the compacted tableau; stops early
    once every LP is terminal."""
    def cond(carry):
        s, it = carry
        return jnp.any(s.status == _RUNNING) & (it < steps)

    def body(carry):
        s, it = carry
        ns = phase2_step(
            SimplexState(s.T, s.basis, s.phase, s.status, s.iters, s.w,
                         s.flip, s.ub, it, s.tel),
            n=n, m=m, tol=tol, rule=rule)
        return CompactionState(ns.T, ns.basis, ns.phase, ns.status, ns.iters,
                               ns.w, ns.flip, ns.ub, s.thr, ns.tel), it + 1

    state, it = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state, it


def segment_combined(state: CompactionState, steps, *, m: int, n: int,
                     tol: float, rule: str = "dantzig"):
    """Run up to `steps` combined two-phase pivots on the *full* tableau;
    stops early once every LP is terminal.

    Unlike the `segment_phase1` -> column-compaction -> `segment_phase2`
    ladder, this runner never changes the tableau layout — which is what
    the frontier scheduler needs: a lane must accept a cold *or* warm
    newcomer at any segment boundary, and a newcomer starts in phase 1,
    which the phase-compacted tableau cannot represent."""
    def cond(carry):
        s, it = carry
        return jnp.any(s.status == _RUNNING) & (it < steps)

    def body(carry):
        s, it = carry
        ns = simplex_step(
            SimplexState(s.T, s.basis, s.phase, s.status, s.iters, s.w,
                         s.flip, s.ub, it, s.tel),
            n=n, m=m, tol=tol, feas_thr=s.thr, rule=rule)
        return CompactionState(ns.T, ns.basis, ns.phase, ns.status, ns.iters,
                               ns.w, ns.flip, ns.ub, s.thr, ns.tel), it + 1

    state, it = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state, it


_segment_phase1_jit = jax.jit(segment_phase1,
                              static_argnames=("m", "n", "tol", "rule"))
_segment_phase2_jit = jax.jit(segment_phase2,
                              static_argnames=("m", "n", "tol", "rule"))
_segment_combined_jit = jax.jit(segment_combined,
                                static_argnames=("m", "n", "tol", "rule"))


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _compact_columns_jit(T, *, m, n):
    return compact_tableau(T, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _compact_weights_jit(w, *, m, n):
    return compact_weights(w, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("n", "compacted"))
def _extract_jit(T, basis, status, iters, flip, ub, *, n, compacted):
    if compacted:
        x, obj = extract_solution_compacted(T, basis, n, flip=flip, ub=ub)
        m = T.shape[1] - 1
    else:
        x, obj = extract_solution_jax(T, basis, n, flip=flip, ub=ub)
        m = T.shape[1] - 2
    y, z = extract_duals(T, m=m, n=n, flip=flip)
    status = jnp.where(status == _RUNNING, ITERATION_LIMIT, status)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    opt = (status == OPTIMAL)[:, None]
    return (x, obj, status.astype(jnp.int8), iters,
            jnp.where(opt, y, jnp.nan), jnp.where(opt, z, jnp.nan))


@jax.jit
def _take_jit(state, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], state)


@jax.jit
def _scatter_jit(state, new_state, idx):
    """Write the j-lane ``new_state`` into lanes ``idx`` of ``state`` (the
    frontier scheduler's admission move — the inverse of a retirement
    gather)."""
    return jax.tree_util.tree_map(lambda a, b: a.at[idx].set(b),
                                  state, new_state)


class JaxBackend:
    """Segment runners for the pure-JAX phase-compacted solver."""

    pad_multiple = 1

    def __init__(self, m: int, n: int, tol: float, feas_tol: float, dtype,
                 pricing: str = "dantzig"):
        self.m, self.n = m, n
        self.tol, self.feas_tol = float(tol), float(feas_tol)
        self.dtype = dtype
        self.rule = canonicalize_rule(pricing)

    def init(self, A, b, c, ub=None, warm: WarmStart | None = None,
             telemetry: bool = False) -> CompactionState:
        T, basis, phase = build_tableau_jax(A, b, c)
        B = T.shape[0]
        if ub is None:
            ub = jnp.full((B, self.n), jnp.inf, dtype=T.dtype)
        else:
            ub = jnp.asarray(ub, dtype=T.dtype)
        flip = jnp.zeros((B, self.n), dtype=bool)
        ok = None
        if warm is not None and warm.basis is not None:
            wfl = (flip if warm.at_upper is None
                   else jnp.asarray(np.asarray(warm.at_upper), bool))
            T_w, basis_w, phase_w, flip_w, ok = inject_tableau_warm(
                A, b, c, ub, jnp.asarray(np.asarray(warm.basis), jnp.int32),
                wfl, m=self.m, n=self.n, feas_tol=self.feas_tol)
            T = jnp.where(ok[:, None, None], T_w, T)
            basis = jnp.where(ok[:, None], basis_w, basis)
            phase = jnp.where(ok, phase_w, phase)
            flip = jnp.where(ok[:, None], flip_w, flip)
        thr = self.feas_tol * jnp.maximum(1.0, T[:, self.m + 1, -1])
        # dantzig never reads weights: carry a (B, 1) stub so segments and
        # bucket gathers don't move a dead (B, C) array
        w = (jnp.ones((B, 1), T.dtype) if self.rule in ("dantzig", "partial")
             else init_weights(self.rule, T, self.m))
        if (ok is not None and self.rule == "devex"
                and warm.pricing == self.rule and warm.weights is not None
                and np.asarray(warm.weights).shape[1] >= self.n + self.m):
            ww = jnp.asarray(np.asarray(warm.weights), w.dtype)
            nm = self.n + self.m
            w = w.at[:, :nm].set(
                jnp.where(ok[:, None], ww[:, :nm], w[:, :nm]))
        return CompactionState(
            T=T, basis=basis, phase=phase,
            status=jnp.full((B,), _RUNNING, jnp.int32),
            iters=jnp.zeros((B,), jnp.int32), w=w,
            flip=flip, ub=ub, thr=thr,
            tel=init_telemetry(B) if telemetry else None)

    def run_phase1(self, state, steps):
        state, it = _segment_phase1_jit(state, jnp.int32(steps), m=self.m,
                                        n=self.n, tol=self.tol,
                                        rule=self.rule)
        return state, int(it)

    def run_phase2(self, state, steps):
        state, it = _segment_phase2_jit(state, jnp.int32(steps), m=self.m,
                                        n=self.n, tol=self.tol,
                                        rule=self.rule)
        return state, int(it)

    def run_combined(self, state, steps):
        state, it = _segment_combined_jit(state, jnp.int32(steps), m=self.m,
                                          n=self.n, tol=self.tol,
                                          rule=self.rule)
        return state, int(it)

    def scatter(self, state, new_state, idx) -> CompactionState:
        return _scatter_jit(state, new_state, jnp.asarray(idx))

    def compact_columns(self, state: CompactionState) -> CompactionState:
        w = (state.w if self.rule in ("dantzig", "partial")
             else _compact_weights_jit(state.w, m=self.m, n=self.n))
        return state._replace(
            T=_compact_columns_jit(state.T, m=self.m, n=self.n), w=w)

    def limit_phase1(self, state: CompactionState) -> CompactionState:
        """Budget exhausted while still in phase 1 -> iteration limit."""
        status = jnp.where(
            (state.status.reshape(-1) == _RUNNING)
            & (state.phase.reshape(-1) == 1),
            ITERATION_LIMIT, state.status.reshape(-1))
        return state._replace(status=status.reshape(state.status.shape))

    def deactivate(self, state: CompactionState, valid) -> CompactionState:
        """Mark padding slots terminal so they never count as active."""
        valid = jnp.asarray(np.asarray(valid).reshape(-1))
        status = jnp.where(valid, state.status.reshape(-1), ITERATION_LIMIT)
        return state._replace(status=status.reshape(state.status.shape).astype(
            state.status.dtype))

    def take(self, state: CompactionState, idx) -> CompactionState:
        return _take_jit(state, jnp.asarray(idx))

    def status_host(self, state) -> np.ndarray:
        return np.asarray(state.status).reshape(-1)

    def phase_host(self, state) -> np.ndarray:
        return np.asarray(state.phase).reshape(-1)

    def extract(self, state: CompactionState, stage: str):
        return tuple(np.asarray(o) for o in _extract_jit(
            state.T, state.basis, state.status.reshape(-1),
            state.iters.reshape(-1), state.flip, state.ub,
            n=self.n, compacted=(stage == "p2")))

    def elements_per_step(self, stage: str) -> int:
        return tableau_elements(self.m, self.n, compacted=(stage == "p2"))


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

def _maybe_span(tracer, name, **args):
    """``tracer.span`` when a tracer is attached, a no-op context otherwise
    (run_schedule and the frontier scheduler trace opportunistically)."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **args)


def run_schedule(backend, state: CompactionState, orig: np.ndarray, B: int,
                 n: int, *, max_iters: int, config: CompactionConfig,
                 stats_out: Optional[List[SegmentStat]] = None,
                 tracer=None) -> LPResult:
    """Drive a backend through segmented stage-1 (full tableau) and stage-2
    (phase-compacted) solves with active-set compaction in between.

    ``orig`` maps each current batch slot to its index in the caller's batch
    (-1 for padding slots, which must already be terminal).  Results land in
    dense (B, ...) output arrays; retired LPs are flushed right before every
    compaction, survivors at the end.

    When the backend state carries telemetry lanes (``state.tel`` not None)
    the per-LP counters are flushed to host buffers alongside the results —
    each LP's lanes are read at its retirement gather, so counters survive
    the bucket shrinks — and the returned ``LPResult.stats`` holds the
    assembled `obs.SolveReport`.  ``tracer`` (an `obs.SpanTracer`) records
    segment / bucket-gather spans and flush events with bucket sizes and
    survivor counts.
    """
    t_start = time.perf_counter()
    np_dtype = np.dtype(jnp.zeros((), backend.dtype).dtype)
    out_x = np.zeros((B, n), np_dtype)
    out_obj = np.full((B,), np.nan, np_dtype)
    out_status = np.full((B,), ITERATION_LIMIT, np.int8)
    out_iters = np.zeros((B,), np.int32)
    # dual-certificate buffers sized lazily off the first flush (m is not a
    # scheduler parameter; every backend now extracts a 6-tuple)
    duals = {}
    tel_host = (zeros_numpy(B)
                if getattr(state, "tel", None) is not None else None)

    def flush(state, orig, stage):
        x, obj, status, iters, y, z = backend.extract(state, stage)
        sel = orig >= 0
        oi = orig[sel]
        out_x[oi] = x[sel]
        out_obj[oi] = obj[sel]
        out_status[oi] = status[sel]
        out_iters[oi] = iters[sel]
        if not duals:
            duals["y"] = np.full((B, y.shape[1]), np.nan, np_dtype)
            duals["z"] = np.full((B, z.shape[1]), np.nan, np_dtype)
        duals["y"][oi] = y[sel]
        duals["z"][oi] = z[sel]
        tel = getattr(state, "tel", None)
        if tel_host is not None and tel is not None:
            for name, vals in tel_to_numpy(tel).items():
                tel_host[name][oi] = vals[sel]
        if tracer is not None:
            tracer.event("flush", stage=stage, lps=int(sel.sum()))

    def maybe_compact(state, orig, stage):
        """Returns (state, orig, status_host) — the single D2H status fetch
        per segment lives here; callers reuse the returned host copy."""
        status = backend.status_host(state)
        running = status == _RUNNING
        n_run = int(running.sum())
        cur = len(orig)
        if n_run == 0:
            return state, orig, status
        bucket = next_bucket(n_run, config.pad_multiple)
        if bucket >= cur or n_run >= config.compact_threshold * cur:
            return state, orig, status
        # retire everyone's current results, then gather the survivors
        with _maybe_span(tracer, "bucket_gather", stage=stage,
                         src_bucket=cur, dst_bucket=bucket,
                         survivors=n_run):
            flush(state, orig, stage)
            idx = np.nonzero(running)[0]
            pad = bucket - len(idx)
            fill = idx[np.arange(pad) % len(idx)]
            take_idx = np.concatenate([idx, fill])
            state = backend.take(state, take_idx)
            valid = np.arange(bucket) < len(idx)
            state = backend.deactivate(state, valid)
            orig = np.where(valid,
                            np.concatenate([orig[idx], orig[fill]]), -1)
        # post-gather host status is known without another transfer:
        # survivors are RUNNING, fill slots were just deactivated
        status = np.where(valid, _RUNNING, ITERATION_LIMIT)
        return state, orig, status

    def run_stage(state, orig, stage, runner, pending, budget):
        status = backend.status_host(state)
        seg = 0
        while budget > 0:
            if not pending(state, status):
                break
            steps = min(config.segment_k, budget)
            bucket = len(orig)
            with _maybe_span(tracer, f"segment[{stage}]", k=seg,
                             bucket=bucket, max_steps=steps) as sp:
                state, done = runner(state, steps)
                budget -= max(1, done)
                # a triggered bucket gather nests under its segment span
                state, orig, status = maybe_compact(state, orig, stage)
                survivors = int((status == _RUNNING).sum())
                if sp is not None:
                    # lane occupancy after the (possibly compacted) segment
                    sp.args["steps"] = int(done)
                    sp.args["survivors"] = survivors
                    sp.args["occupancy"] = survivors / max(1, len(orig))
            if stats_out is not None:
                # survivor count is compaction-invariant (gathers only drop
                # terminal LPs), so the post-compact host status serves both
                stats_out.append(SegmentStat(
                    stage=stage, bucket=bucket, steps=done,
                    elements=done * bucket * backend.elements_per_step(stage),
                    survivors=survivors))
            seg += 1
        return state, orig, budget

    def pending_p1(state, status):
        phase = backend.phase_host(state)
        return bool(np.any((status == _RUNNING) & (phase == 1)))

    def pending_p2(state, status):
        return bool(np.any(status == _RUNNING))

    # ---- stage 1: full tableau until every LP has left phase 1 -------------
    # (one max_iters budget shared across both stages, mirroring
    # simplex.solve_two_phase's shared step counter)
    state, orig, budget = run_stage(state, orig, "p1", backend.run_phase1,
                                    pending_p1, max_iters)
    state = backend.limit_phase1(state)

    # ---- one-shot column/row compaction + stage 2 ---------------------------
    state = backend.compact_columns(state)
    state, orig, _ = run_stage(state, orig, "p2", backend.run_phase2,
                               pending_p2, budget)

    flush(state, orig, "p2")
    stats = None
    if tel_host is not None:
        stats = report_from_counters(
            tel_host, wall_s=time.perf_counter() - t_start,
            backend=type(backend).__name__,
            spans=tuple(tracer.roots) if tracer is not None else ())
    return LPResult(x=out_x, objective=out_obj, status=out_status,
                    iterations=out_iters, y=duals["y"], z=duals["z"],
                    stats=stats)


def solve_batched_compacted(batch: LPBatch, *, dtype=jnp.float32,
                            tol: Optional[float] = None,
                            feas_tol: Optional[float] = None,
                            max_iters: Optional[int] = None,
                            segment_k: Optional[int] = None,
                            compact_threshold: Optional[float] = None,
                            pricing: str = "dantzig",
                            backend: str = "tableau",
                            stats_out: Optional[List[SegmentStat]] = None,
                            presolve: bool = True,
                            scale: Optional[bool] = None,
                            warm: WarmStart | None = None,
                            telemetry: bool = False,
                            tracer=None) -> LPResult:
    """Solve a batch with the two-level work-elimination engine (phase
    compaction + active-set compaction scheduler) on the pure-JAX backend.
    Accepts a GeneralLPBatch like every solver entry point (canonicalize on
    ingestion, recover on the way out).

    Bit-identical statuses/iterations to ``solve_batched_jax`` with the same
    ``pricing`` rule — only the executed device work changes.
    ``segment_k=None`` derives the segment length from `auto_segment_k`
    (scales with the `default_max_iters` cap); ``compact_threshold=None``
    derives the gather eagerness from `auto_compact_threshold` (tuned from
    the observed survivor curves).  ``stats_out`` (a list) collects
    per-segment SegmentStat records — executed work plus the observed
    survivor curve — for benchmarks/pivot_work.py.

    ``backend`` selects the solver engine under the scheduler: "tableau"
    (this module's JaxBackend), "revised" or "pdhg" route to the engine's
    own compacted entry point via the core/lp.py registry.

    ``warm`` seeds the initial state (warm-derived leaves then ride the
    bucket gathers automatically); compacted results report ``warm=None``
    (no terminal-state capture across the retirement buckets)."""
    if canonicalize_backend(backend) != "tableau":
        return resolve_backend(backend, compacted=True)(
            batch, dtype=dtype, tol=tol, feas_tol=feas_tol,
            max_iters=max_iters, segment_k=segment_k,
            compact_threshold=compact_threshold, pricing=pricing,
            stats_out=stats_out, presolve=presolve, scale=scale, warm=warm,
            telemetry=telemetry, tracer=tracer)
    with _maybe_span(tracer, "canonicalize"):
        batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if segment_k is None:
        segment_k = auto_segment_k(m, n)
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if feas_tol is None:
        feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
    backend = JaxBackend(m, n, tol, feas_tol, dtype, pricing=pricing)
    with _maybe_span(tracer, "dispatch", backend="tableau", B=batch.batch,
                     m=m, n=n):
        state = backend.init(jnp.asarray(batch.A, dtype),
                             jnp.asarray(batch.b, dtype),
                             jnp.asarray(batch.c, dtype),
                             ub=jnp.asarray(batch.upper_bounds(), dtype),
                             warm=prepare_warm(warm, rec, batch),
                             telemetry=telemetry)
    B = batch.batch
    orig = np.arange(B, dtype=np.int64)
    cfg = CompactionConfig(
        segment_k=int(segment_k),
        compact_threshold=resolve_compact_threshold(compact_threshold,
                                                    int(segment_k)),
        pad_multiple=backend.pad_multiple)
    res = run_schedule(backend, state, orig, B, n, max_iters=int(max_iters),
                       config=cfg, stats_out=stats_out, tracer=tracer)
    with _maybe_span(tracer, "recover"):
        return finish_result(rec, res)


# ---------------------------------------------------------------------------
# Frontier refill: continuous batching over a work producer
# ---------------------------------------------------------------------------

class FrontierScheduler:
    """Continuous-batching counterpart of `run_schedule`: where the bucket
    ladder only ever *shrinks* a fixed batch, this scheduler keeps a fixed
    pool of ``lanes`` batch slots and **admits new LPs into lanes freed by
    retired ones** — the same gather machinery, run in reverse.

    Built for producers that generate work *from results*: the
    branch-and-bound driver (core/branch_bound.py) retires fathomed nodes
    and pushes their freshly-branched children, which the scheduler admits
    mid-solve — the device batch never drains below the available work, so
    a 2-node frontier does not serialize a 64-lane dispatch.

    Segments run the *combined* two-phase pivot on the full tableau
    (`segment_combined`) and never column-compact: a lane must accept a
    cold or warm newcomer at any segment boundary, and a newcomer starts
    in phase 1, which the phase-compacted layout cannot represent.  The
    per-lane pivot sequence is still bit-identical to the monolithic
    lockstep solver — admission scatters never touch other lanes'
    tableaux.

    Protocol (all arrays canonical-standard-form, batch axis 0):

    * ``source(k)`` — up to ``k`` new LPs, or ``None`` when no work is
      currently available: a tuple ``(A, b, c, ub, warm, tags)`` with
      ``j <= k`` members; ``warm`` is a j-member ``WarmStart`` or None;
      ``tags`` are nonnegative ints identifying each LP.
    * ``sink(tag, row)`` — called once per retired LP with a dict holding
      ``x``/``objective``/``status``/``iterations``/``y``/``z`` (the
      monolithic extraction contract) plus ``warm``, a 1-member
      ``WarmStart`` carrying the lane's terminal basis/flip state — the
      carrier children warm-start from.  ``sink`` may push work that a
      subsequent ``source`` call returns.

    ``run`` drives segments until every lane is free and ``source`` is
    exhausted; per-LP pivots are capped at ``max_iters`` (over-budget
    lanes retire as ITERATION_LIMIT), so it always terminates.
    """

    def __init__(self, m: int, n: int, *, lanes: int = 32,
                 dtype=jnp.float32, tol: Optional[float] = None,
                 feas_tol: Optional[float] = None,
                 max_iters: Optional[int] = None,
                 segment_k: Optional[int] = None,
                 pricing: str = "dantzig",
                 stats_out: Optional[List[SegmentStat]] = None,
                 tracer=None):
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.m, self.n = int(m), int(n)
        self.lanes = next_bucket(int(lanes))
        self.dtype = dtype
        if tol is None:
            tol = 1e-6 if dtype == jnp.float32 else 1e-9
        if feas_tol is None:
            feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
        self.max_iters = int(max_iters if max_iters is not None
                             else default_max_iters(self.m, self.n))
        self.segment_k = int(segment_k if segment_k is not None
                             else auto_segment_k(self.m, self.n))
        self.stats_out = stats_out
        self.tracer = tracer
        self.backend = JaxBackend(self.m, self.n, tol, feas_tol, dtype,
                                  pricing=pricing)

    def _admit(self, state, tags, source):
        be = self.backend
        free = np.flatnonzero(tags < 0)
        if not len(free):
            return state, tags
        req = source(len(free))
        if req is None:
            return state, tags
        A, b, c, ub, warm, new_tags = req
        A = jnp.asarray(np.asarray(A), self.dtype)
        j = A.shape[0]
        if j > len(free) or j != len(new_tags):
            raise ValueError(f"source returned {j} LPs / {len(new_tags)} "
                             f"tags for {len(free)} free lanes")
        new_state = be.init(
            A, jnp.asarray(np.asarray(b), self.dtype),
            jnp.asarray(np.asarray(c), self.dtype),
            ub=None if ub is None else jnp.asarray(np.asarray(ub), self.dtype),
            warm=warm)
        if state is None:
            # bootstrap: replicate to fill all lanes, deactivate the padding
            if j < self.lanes:
                new_state = be.take(new_state, np.arange(self.lanes) % j)
                new_state = be.deactivate(new_state, np.arange(self.lanes) < j)
            state = new_state
            tags[:j] = new_tags
        else:
            idx = free[:j]
            state = be.scatter(state, new_state, idx)
            tags[idx] = new_tags
        if self.tracer is not None:
            self.tracer.event("admit", lps=int(j),
                              tags=[int(t) for t in new_tags],
                              occupied=int((tags >= 0).sum()),
                              lanes=self.lanes)
        return state, tags

    def run(self, source, sink) -> int:
        """Drain ``source`` through the lane pool; returns LPs retired."""
        be = self.backend
        tags = np.full(self.lanes, -1, np.int64)
        state = None
        retired = 0
        while True:
            state, tags = self._admit(state, tags, source)
            active = tags >= 0
            if not active.any():
                return retired
            with _maybe_span(self.tracer, "segment[frontier]",
                             lanes=self.lanes,
                             occupied=int(active.sum())) as sp:
                state, done = be.run_combined(state, self.segment_k)
                if sp is not None:
                    sp.args["steps"] = int(done)
            status = be.status_host(state)
            # per-LP budget: over-budget lanes retire as ITERATION_LIMIT
            over = (active & (status == _RUNNING)
                    & (np.asarray(state.iters).reshape(-1) >= self.max_iters))
            if over.any():
                state = be.deactivate(state, ~over)
                status = np.where(over, ITERATION_LIMIT, status)
            if self.stats_out is not None:
                self.stats_out.append(SegmentStat(
                    stage="frontier", bucket=self.lanes, steps=done,
                    elements=done * self.lanes * be.elements_per_step("p1"),
                    survivors=int((active & (status == _RUNNING)).sum())))
            done_mask = active & (status != _RUNNING)
            if done_mask.any():
                x, obj, st, it, y, z = be.extract(state, "p1")
                basis = np.asarray(state.basis)
                flip = np.asarray(state.flip)
                for i in np.flatnonzero(done_mask):
                    if self.tracer is not None:
                        self.tracer.event("retire", tag=int(tags[i]),
                                          lane=int(i), status=int(st[i]),
                                          iterations=int(it[i]))
                    sink(int(tags[i]), {
                        "x": x[i], "objective": obj[i],
                        "status": int(st[i]), "iterations": int(it[i]),
                        "y": y[i], "z": z[i],
                        "warm": WarmStart(m=self.m, n=self.n,
                                          basis=basis[i:i + 1],
                                          at_upper=flip[i:i + 1])})
                    retired += 1
                tags[done_mask] = -1
