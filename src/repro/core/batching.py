"""Batching routine — Algorithm 1 of the paper, adapted to HBM + async dispatch.

The paper sizes batches against GPU global memory (``N = floor(S / Y)``,
Eq. 5) and overlaps H2D/D2H copies with kernel execution via CUDA streams
(Sec. 5.4). Here:

* the memory budget is HBM bytes per device x device count,
* chunk *k+1* is `jax.device_put` (H2D DMA) while chunk *k*'s solve is still
  in flight — JAX's async dispatch gives the CUDA-streams pipeline for free:
  we enqueue transfer->solve per chunk and only block when gathering results
  (the paper's "all H2D, all kernels, all D2H per stream" schedule),
* results are fetched with one blocking gather at the end (D2H-res).
"""
from __future__ import annotations

import inspect
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.report import SolveReport
from .compaction import solve_batched_compacted
from .forms import ensure_canonical, finish_result, prepare_warm
from .lp import (LPBatch, LPResult, WarmStart, canonicalize_backend,
                 resolve_backend)
from .simplex import solve_batched_jax

# Conservative default budget for planning on real devices; on CPU hosts this
# is only used for chunk-size arithmetic, mirroring Eq. (5).
DEFAULT_DEVICE_BYTES = 16 * 2 ** 30  # one v5e chip's HBM
# Fraction of the budget the tableaux may claim (leave room for XLA scratch).
BUDGET_FRACTION = 0.6


def max_chunk_size(batch: LPBatch, device_bytes: int = DEFAULT_DEVICE_BYTES,
                   n_devices: int = 1, dtype_size: int = 4) -> int:
    """Paper Eq. (5): N = floor(S / Y), with S = usable device bytes."""
    usable = int(device_bytes * BUDGET_FRACTION) * n_devices
    per_lp = batch.bytes_per_lp(dtype_size)
    return max(1, usable // per_lp)


def difficulty_proxy(batch: LPBatch) -> np.ndarray:
    """Cheap per-LP difficulty estimate for sorted batching: LPs needing
    phase 1 (any b_i < 0) pivot roughly 2x as long as feasible-start ones, so
    grouping them keeps each lockstep chunk's max-iteration bound tight.

    Primary key: the count of infeasible rows (each one seeds an artificial
    that phase 1 must drive out).  Tie-break (a strictly sub-unit fraction,
    so it never reorders across counts): relative infeasibility mass — LPs
    starting deeper in the infeasible region tend to take more phase-1
    pivots.  With ``compaction=True`` this ordering is what makes buckets
    drain in waves: each chunk's survivor curve collapses together, so the
    power-of-two ladder shrinks early and often."""
    b = np.asarray(batch.b)
    neg = b < 0
    count = neg.sum(axis=1).astype(np.float64)
    mass = np.where(neg, -b, 0.0).sum(axis=1)
    frac = mass / (1.0 + mass.max()) if mass.max() > 0 else 0.0
    return count + frac


def solve_batched(batch: LPBatch, *, solver: Optional[Callable] = None,
                  chunk_size: Optional[int] = None,
                  device_bytes: int = DEFAULT_DEVICE_BYTES,
                  n_devices: int = 1, sort_by_difficulty: bool = False,
                  compaction: bool = False, pricing: str = "dantzig",
                  backend: str = "tableau",
                  presolve: bool = True, scale: Optional[bool] = None,
                  warm: Optional[WarmStart] = None,
                  pad_to_bucket: bool = False,
                  **solver_kwargs) -> LPResult:
    """Chunked batched solve (Algorithm 1). ``solver`` defaults to the pure
    JAX lockstep solver; kernels.ops.solve_batched_pallas and
    core.distributed solvers are drop-in.

    ``sort_by_difficulty`` (beyond-paper optimization): lockstep SIMD chunks
    pay max-pivots-over-chunk; reordering LPs by ``difficulty_proxy`` so
    similar-difficulty problems share a chunk cuts total executed pivots
    (measured in analysis/lp_perf.py), then results are unpermuted.

    ``compaction=True`` routes each chunk through the active-set compaction
    scheduler (core/compaction.py): dead LPs are retired into power-of-two
    buckets mid-solve instead of burning masked pivots.  With ``solver=None``
    the solver becomes ``solve_batched_compacted``; a custom ``solver`` must
    accept a ``compaction`` kwarg itself (e.g. solve_batched_pallas) or a
    ValueError is raised.  Composes with sorting: sorted chunks converge in
    tighter waves, which is exactly what the bucket ladder exploits — the
    difficulty pre-pass makes buckets drain in waves instead of dribbling.
    Pass ``segment_k=``/``compact_threshold=`` through ``solver_kwargs`` to
    tune.

    ``pricing`` selects the entering-column rule (core/pricing.py) and is
    forwarded to the solver; a custom ``solver`` must accept it when a
    non-default rule is requested.

    ``backend`` selects the solver engine — "tableau" (dense rank-1 tableau
    updates) or "revised" (core/revised.py basis-factor updates); with
    ``solver=None`` it picks the matching compacted/monolithic solver, and a
    custom ``solver`` must accept a ``backend`` kwarg when "revised" is
    requested (solve_batched_pallas does).

    A ``GeneralLPBatch`` (core/forms.py) is canonicalized *once* up front —
    chunking, sorting and memory planning all operate on the canonical
    shape (Eq. 5 budgets the canonical tableau) — and the concatenated
    result is recovered into original coordinates at the end;
    ``presolve``/``scale`` control the canonicalization.

    ``telemetry=True`` (forwarded through ``solver_kwargs`` — every built-in
    engine accepts it) turns on the per-LP counter plane (``repro.obs``);
    each chunk's ``LPResult.stats`` SolveReport is concatenated, chunk
    results are unpermuted/unpadded alongside the other per-LP leaves, and
    the merged report lands on the returned ``LPResult.stats``.

    ``warm`` (core/lp.py WarmStart, usually ``parent.warm_start()``) seeds
    every engine from a parent solve; its per-LP leaves are permuted and
    chunk-sliced alongside ``A``/``b``/``c``, and chunk results' terminal
    states are re-concatenated/unpermuted so the returned ``LPResult.warm``
    chains into the next re-solve.

    ``pad_to_bucket=True`` pads the batch up to the next power of two by
    replicating members (results for the replicas are discarded, warm
    leaves ride along).  Callers that dispatch many variable-sized batches
    of one canonical shape — the branch-and-bound frontier loop — then
    compile one XLA program per pow2 bucket instead of one per batch size,
    at the cost of solving up to 2x LPs per dispatch (replicas terminate
    in lockstep with their originals, so wall-clock cost is near zero)."""
    canonicalize_backend(backend)
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    warm = prepare_warm(warm, rec, batch)
    if solver is None:
        if backend != "tableau":
            # registry dispatch (core/lp.py BACKEND_REGISTRY): each engine
            # owns its monolithic and compaction-scheduled entry points
            solver = resolve_backend(backend, compacted=compaction)
        else:
            solver = (solve_batched_compacted if compaction
                      else solve_batched_jax)
        solver_kwargs["pricing"] = pricing
    elif compaction or pricing != "dantzig" or backend != "tableau" \
            or warm is not None:
        # only introspect when a kwarg actually needs forwarding, so
        # non-introspectable callables keep working on the default path
        params = inspect.signature(solver).parameters
        has_varkw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                        for p in params.values())
        if compaction:
            if "compaction" not in params and not has_varkw:
                raise ValueError(
                    f"compaction=True but solver {getattr(solver, '__name__', solver)!r} "
                    "does not accept a 'compaction' kwarg; use solver=None "
                    "(solve_batched_compacted) or a compaction-aware solver such "
                    "as kernels.ops.solve_batched_pallas")
            solver_kwargs["compaction"] = True
        if pricing != "dantzig":
            if "pricing" in params or has_varkw:
                solver_kwargs.setdefault("pricing", pricing)
            else:
                raise ValueError(
                    f"pricing={pricing!r} requested but solver "
                    f"{getattr(solver, '__name__', solver)!r} does not accept "
                    "a 'pricing' kwarg; use solver=None or a pricing-aware "
                    "solver")
        if backend != "tableau":
            if "backend" in params or has_varkw:
                solver_kwargs.setdefault("backend", backend)
            else:
                raise ValueError(
                    f"backend={backend!r} requested but solver "
                    f"{getattr(solver, '__name__', solver)!r} does not accept "
                    "a 'backend' kwarg; use solver=None or a backend-aware "
                    "solver such as kernels.ops.solve_batched_pallas")
        if warm is not None and "warm" not in params and not has_varkw:
            raise ValueError(
                f"warm= requested but solver "
                f"{getattr(solver, '__name__', solver)!r} does not accept "
                "a 'warm' kwarg; use solver=None or a warm-start-aware "
                "solver")
    B = batch.batch
    perm = None
    if sort_by_difficulty and B > 1:
        perm = np.argsort(difficulty_proxy(batch), kind="stable")
        batch = LPBatch(A=np.asarray(batch.A)[perm],
                        b=np.asarray(batch.b)[perm],
                        c=np.asarray(batch.c)[perm],
                        ub=None if batch.ub is None
                        else np.asarray(batch.ub)[perm])
        if warm is not None:
            warm = warm.take(perm)
    unpad_B = None
    if pad_to_bucket and B > 1:
        Bp = 1 << (B - 1).bit_length()
        if Bp != B:
            idx = np.arange(Bp) % B
            batch = LPBatch(A=np.asarray(batch.A)[idx],
                            b=np.asarray(batch.b)[idx],
                            c=np.asarray(batch.c)[idx],
                            ub=None if batch.ub is None
                            else np.asarray(batch.ub)[idx])
            if warm is not None:
                warm = warm.take(idx)
            unpad_B, B = B, Bp

    def call(sub, sub_warm):
        # warm is passed per-call (never via solver_kwargs) because each
        # chunk gets its own slice of the carrier
        if sub_warm is not None:
            return solver(sub, warm=sub_warm, **solver_kwargs)
        return solver(sub, **solver_kwargs)

    if chunk_size is None:
        chunk_size = max_chunk_size(batch, device_bytes, n_devices)
    if chunk_size >= B:
        res = call(batch, warm)
        return finish_result(rec, _unpermute(_unpad(res, unpad_B), perm))

    n_chunks = math.ceil(B / chunk_size)
    pending = []
    for i in range(n_chunks):
        s, e = i * chunk_size, min((i + 1) * chunk_size, B)
        sub = LPBatch(A=batch.A[s:e], b=batch.b[s:e], c=batch.c[s:e],
                      ub=None if batch.ub is None else batch.ub[s:e])
        # async dispatch: this returns before the device finishes; the next
        # chunk's H2D overlaps this chunk's compute (CUDA-streams analogue)
        pending.append(call(sub, None if warm is None else warm.slice(s, e)))

    def cat(field):
        vals = [getattr(r, field) for r in pending]
        if any(v is None for v in vals):  # a chunk without a certificate
            return None
        return np.concatenate([np.asarray(v) for v in vals])

    res = LPResult(x=cat("x"), objective=cat("objective"),
                   status=cat("status"), iterations=cat("iterations"),
                   y=cat("y"), z=cat("z"),
                   warm=WarmStart.concat([r.warm for r in pending]),
                   stats=SolveReport.concat([r.stats for r in pending]))
    return finish_result(rec, _unpermute(_unpad(res, unpad_B), perm))


def _unpad(res: LPResult, B) -> LPResult:
    """Drop the pad_to_bucket replica rows (no-op when B is None)."""
    if B is None:
        return res
    take = lambda a: None if a is None else np.asarray(a)[:B]  # noqa: E731
    return LPResult(x=take(res.x), objective=take(res.objective),
                    status=take(res.status), iterations=take(res.iterations),
                    y=take(res.y), z=take(res.z),
                    warm=None if res.warm is None else res.warm.slice(0, B),
                    stats=None if res.stats is None else res.stats.slice(0, B))


def _unpermute(res: LPResult, perm) -> LPResult:
    if perm is None:
        return res
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    take = lambda a: None if a is None else np.asarray(a)[inv]  # noqa: E731
    return LPResult(x=take(res.x),
                    objective=take(res.objective),
                    status=take(res.status),
                    iterations=take(res.iterations),
                    y=take(res.y), z=take(res.z),
                    warm=None if res.warm is None else res.warm.take(inv),
                    stats=None if res.stats is None else res.stats.take(inv))
