"""Multi-chip batched LP solving: lockstep (pjit) vs per-shard termination.

The paper gets load balancing from CUDA's block scheduler: each LP's block
exits as soon as *its* simplex terminates. A lockstep SPMD while-loop loses
that: every chip pivots until the globally slowest LP finishes (the loop
condition is an implicit cross-chip all-reduce). Two modes:

* ``solve_pjit``      — paper-faithful lockstep: one global `while_loop` over
                        the full sharded batch. Simple, but pays
                        max-iterations-over-batch on every chip + one scalar
                        all-reduce per pivot.
* ``solve_shard_map`` — per-shard termination: `shard_map` gives every chip
                        its own `while_loop` over its local LPs, so a chip
                        whose LPs converged early goes idle instead of
                        spinning (the TPU analogue of per-block exit). No
                        cross-chip communication at all — LPs are
                        embarrassingly parallel, which is the paper's point.

Both shard the batch axis over every mesh axis (LP solving has no model
dimension to shard).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from .lp import LPBatch, LPResult, OPTIMAL, ITERATION_LIMIT, default_max_iters
from .simplex import (
    SimplexState, _RUNNING, build_tableau_jax, simplex_step,
    extract_solution_jax,
)


def _pad_batch(batch: LPBatch, multiple: int):
    """Pad the batch to a multiple of the shard count with trivial LPs
    (max 0 s.t. x <= 1): they solve in one phase-2 check."""
    B = batch.batch
    pad = (-B) % multiple
    if pad == 0:
        return batch, B
    A = np.concatenate([batch.A, np.tile(np.eye(batch.m, batch.n)[None], (pad, 1, 1))])
    b = np.concatenate([batch.b, np.ones((pad, batch.m))])
    c = np.concatenate([batch.c, np.zeros((pad, batch.n))])
    return LPBatch(A=A, b=b, c=c), B


def _solve_local(A, b, c, *, m, n, max_iters, tol, feas_tol):
    """The same solve body as simplex._solve_core, callable under shard_map
    (local shapes) or pjit (global shapes)."""
    T, basis, phase = build_tableau_jax(A, b, c)
    B = T.shape[0]
    feas_thr = feas_tol * jnp.maximum(1.0, T[:, m + 1, -1])
    state = SimplexState(
        T=T, basis=basis, phase=phase,
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32),
        it=jnp.array(0, jnp.int32),
    )

    def cond(s):
        return jnp.any(s.status == _RUNNING) & (s.it < max_iters)

    def body(s):
        return simplex_step(s, n=n, m=m, tol=tol, feas_thr=feas_thr)

    state = jax.lax.while_loop(cond, body, state)
    status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT, state.status)
    x, obj = extract_solution_jax(state.T, state.basis, n)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    return x, obj, status.astype(jnp.int8), state.iters


def _prep(batch: LPBatch, mesh: Mesh, dtype):
    axes = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    padded, orig = _pad_batch(batch, n_dev)
    A = jnp.asarray(padded.A, dtype)
    b = jnp.asarray(padded.b, dtype)
    c = jnp.asarray(padded.c, dtype)
    return A, b, c, axes, orig, padded


def solve_pjit(batch: LPBatch, mesh: Mesh, *, dtype=jnp.float32,
               tol: float = 1e-6, feas_tol: float = 1e-5,
               max_iters: Optional[int] = None, lower_only: bool = False):
    """Lockstep global solve: batch sharded over all mesh axes, single global
    while_loop (the paper-faithful distributed baseline)."""
    m, n = batch.m, batch.n
    max_iters = max_iters or default_max_iters(m, n)
    A, b, c, axes, orig, _ = _prep(batch, mesh, dtype)
    spec = P(axes)  # batch dim sharded over every axis
    shard = NamedSharding(mesh, spec)
    fn = jax.jit(
        functools.partial(_solve_local, m=m, n=n, max_iters=max_iters,
                          tol=tol, feas_tol=feas_tol),
        in_shardings=(shard, shard, shard),
        out_shardings=(shard, shard, shard, shard),
    )
    if lower_only:
        return fn.lower(jax.ShapeDtypeStruct(A.shape, A.dtype),
                        jax.ShapeDtypeStruct(b.shape, b.dtype),
                        jax.ShapeDtypeStruct(c.shape, c.dtype))
    x, obj, status, iters = fn(A, b, c)
    return LPResult(x=np.asarray(x)[:orig], objective=np.asarray(obj)[:orig],
                    status=np.asarray(status)[:orig],
                    iterations=np.asarray(iters)[:orig])


def solve_shard_map(batch: LPBatch, mesh: Mesh, *, dtype=jnp.float32,
                    tol: float = 1e-6, feas_tol: float = 1e-5,
                    max_iters: Optional[int] = None, lower_only: bool = False):
    """Per-shard termination: each chip solves its local LPs to completion
    independently (no cross-chip sync per pivot)."""
    m, n = batch.m, batch.n
    max_iters = max_iters or default_max_iters(m, n)
    A, b, c, axes, orig, _ = _prep(batch, mesh, dtype)
    spec = P(axes)

    local = functools.partial(_solve_local, m=m, n=n, max_iters=max_iters,
                              tol=tol, feas_tol=feas_tol)
    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(spec, spec, spec, spec),
        check_vma=False,
    ))
    if lower_only:
        return fn.lower(jax.ShapeDtypeStruct(A.shape, A.dtype),
                        jax.ShapeDtypeStruct(b.shape, b.dtype),
                        jax.ShapeDtypeStruct(c.shape, c.dtype))
    x, obj, status, iters = fn(A, b, c)
    return LPResult(x=np.asarray(x)[:orig], objective=np.asarray(obj)[:orig],
                    status=np.asarray(status)[:orig],
                    iterations=np.asarray(iters)[:orig])
