"""Multi-chip batched LP solving: lockstep (pjit) vs per-shard termination.

The paper gets load balancing from CUDA's block scheduler: each LP's block
exits as soon as *its* simplex terminates. A lockstep SPMD while-loop loses
that: every chip pivots until the globally slowest LP finishes (the loop
condition is an implicit cross-chip all-reduce). Two modes:

* ``solve_pjit``      — paper-faithful lockstep: one global `while_loop` over
                        the full sharded batch. Simple, but pays
                        max-iterations-over-batch on every chip + one scalar
                        all-reduce per pivot.
* ``solve_shard_map`` — per-shard termination: `shard_map` gives every chip
                        its own `while_loop` over its local LPs, so a chip
                        whose LPs converged early goes idle instead of
                        spinning (the TPU analogue of per-block exit). No
                        cross-chip communication at all — LPs are
                        embarrassingly parallel, which is the paper's point.

Both now run the phase-compacted two-loop solve (core/simplex.py) under the
hood, and ``solve_shard_map(..., segment_k=K)`` additionally composes with
the active-set compaction scheduler (core/compaction.py): each chip runs its
local while-loop for up to K pivots, the host counts global survivors, and
when the active fraction drops below ``compact_threshold`` the surviving LPs
are gathered into the next power-of-two bucket (padded to the device count)
and the solve resumes — per-shard exit *within* a segment, per-block exit
*across* segments.

Both shard the batch axis over every mesh axis (LP solving has no model
dimension to shard).
"""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..obs.report import report_from_counters
from ..obs.telemetry import tel_to_numpy
from .compaction import _maybe_span
from .forms import ensure_canonical, finish_result
from .lp import (LPBatch, LPResult, OPTIMAL, ITERATION_LIMIT,
                 canonicalize_backend, default_max_iters)
from .simplex import solve_two_phase
from .compaction import (
    CompactionConfig, CompactionState, JaxBackend, resolve_compact_threshold,
    run_schedule, segment_phase1, segment_phase2,
)
from .revised import (
    RevisedBackend, RevisedState, auto_refactor_period, solve_revised,
    segment_revised_phase1, segment_revised_phase2,
)
from .pdhg import (
    PdhgBackend, PdhgState, default_pdhg_max_iters, segment_pdhg, solve_pdhg,
)


def _pad_batch(batch: LPBatch, multiple: int):
    """Pad the batch to a multiple of the shard count with trivial LPs
    (max 0 s.t. x <= 1): they solve in one phase-2 check."""
    B = batch.batch
    pad = (-B) % multiple
    if pad == 0:
        return batch, B
    A = np.concatenate([batch.A, np.tile(np.eye(batch.m, batch.n)[None], (pad, 1, 1))])
    b = np.concatenate([batch.b, np.ones((pad, batch.m))])
    c = np.concatenate([batch.c, np.zeros((pad, batch.n))])
    ub = None
    if batch.ub is not None:
        ub = np.concatenate([batch.ub, np.full((pad, batch.n), np.inf)])
    return LPBatch(A=A, b=b, c=c, ub=ub), B


def _solve_local(A, b, c, ub, *, m, n, max_iters, tol, feas_tol,
                 pricing="dantzig", backend="tableau",
                 refactor_period=None, telemetry=False):
    """The shared solve body — tableau (phase-compacted two-phase), revised
    (basis-factor updates) or pdhg (restarted first-order iterations) —
    callable under shard_map (local shapes) or pjit (global shapes).  All
    three return the same (x, obj, status, iters, y, z) 6-tuple, so the
    sharding specs are backend-independent.  ``telemetry=True`` appends the
    per-LP `obs.TelemetryState` counter lanes as a seventh member (every
    lane is batched on axis 0, so one extra batch-sharded spec covers the
    whole subtree)."""
    if backend == "revised":
        return solve_revised(
            A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
            feas_tol=feas_tol,
            refactor_period=int(refactor_period or auto_refactor_period(m, n)),
            pricing=pricing, telemetry=telemetry)
    if backend == "pdhg":
        from .pdhg import _check_pdhg_pricing
        _check_pdhg_pricing(pricing)   # same contract as every pdhg entry
        return solve_pdhg(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                          feas_tol=feas_tol, telemetry=telemetry)
    return solve_two_phase(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                           feas_tol=feas_tol, pricing=pricing,
                           telemetry=telemetry)


def _backend_defaults(backend: str, max_iters, tol, m: int, n: int, dtype):
    """Per-engine loop-cap/tolerance defaults at the distributed entry
    points (``tol=None`` resolves per engine): the first-order engine
    needs a far larger iteration cap (cheap iterations) and interprets
    ``tol`` as a relative KKT tolerance with its own dtype-dependent
    default (1e-5 f32 / 1e-8 f64, matching solve_batched_pdhg); the
    simplex engines keep the historical 1e-6 reduced-cost tolerance."""
    if backend == "pdhg":
        if tol is None:
            tol = 1e-5 if dtype == jnp.float32 else 1e-8
        return max_iters or default_pdhg_max_iters(m, n), tol
    if tol is None:
        tol = 1e-6
    return max_iters or default_max_iters(m, n), tol




def _prep(batch: LPBatch, mesh: Mesh, dtype):
    axes = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    padded, orig = _pad_batch(batch, n_dev)
    A = jnp.asarray(padded.A, dtype)
    b = jnp.asarray(padded.b, dtype)
    c = jnp.asarray(padded.c, dtype)
    ub = jnp.asarray(padded.upper_bounds(), dtype)
    return A, b, c, ub, axes, orig, padded


def solve_pjit(batch: LPBatch, mesh: Mesh, *, dtype=jnp.float32,
               tol: Optional[float] = None, feas_tol: float = 1e-5,
               max_iters: Optional[int] = None, lower_only: bool = False,
               pricing: str = "dantzig", backend: str = "tableau",
               refactor_period: Optional[int] = None,
               presolve: bool = True, scale: Optional[bool] = None,
               telemetry: bool = False):
    """Lockstep global solve: batch sharded over all mesh axes, single global
    while_loop (the paper-faithful distributed baseline).  ``pricing``
    selects the entering-column rule (core/pricing.py); the per-LP weights
    are loop state sharded like the tableaux, so no rule adds cross-chip
    traffic.  ``backend="revised"`` runs the basis-factor engine
    (core/revised.py) — its eta file and LU factors are loop state sharded
    with the batch, so it too stays communication-free.  GeneralLPBatch
    inputs are canonicalized on the host before sharding (the canonical
    shape is what gets partitioned) and recovered after the gather."""
    canonicalize_backend(backend)
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    max_iters, tol = _backend_defaults(backend, max_iters, tol, m, n, dtype)
    A, b, c, ub, axes, orig, _ = _prep(batch, mesh, dtype)
    spec = P(axes)  # batch dim sharded over every axis
    shard = NamedSharding(mesh, spec)
    fn = jax.jit(
        functools.partial(_solve_local, m=m, n=n, max_iters=max_iters,
                          tol=tol, feas_tol=feas_tol, pricing=pricing,
                          backend=backend, refactor_period=refactor_period,
                          telemetry=telemetry),
        in_shardings=(shard, shard, shard, shard),
        # the telemetry subtree's lanes are all batch-on-axis-0, so one
        # extra batch-sharded entry (a pytree prefix) covers every lane
        out_shardings=(shard,) * (7 if telemetry else 6),
    )
    if lower_only:
        return fn.lower(jax.ShapeDtypeStruct(A.shape, A.dtype),
                        jax.ShapeDtypeStruct(b.shape, b.dtype),
                        jax.ShapeDtypeStruct(c.shape, c.dtype),
                        jax.ShapeDtypeStruct(ub.shape, ub.dtype))
    t0 = time.perf_counter()
    out = fn(A, b, c, ub)
    x, obj, status, iters, y, z = out[:6]
    stats = None
    if telemetry:
        jax.block_until_ready(out[6])
        counters = {k: v[:orig] for k, v in tel_to_numpy(out[6]).items()}
        stats = report_from_counters(counters,
                                     wall_s=time.perf_counter() - t0,
                                     backend=backend)
    res = LPResult(x=np.asarray(x)[:orig], objective=np.asarray(obj)[:orig],
                   status=np.asarray(status)[:orig],
                   iterations=np.asarray(iters)[:orig],
                   y=np.asarray(y)[:orig], z=np.asarray(z)[:orig],
                   stats=stats)
    return finish_result(rec, res)


class _ShardMapBackend(JaxBackend):
    """Compaction-scheduler backend whose segment runners execute under
    shard_map: per-shard while-loops (each chip stops at its own segment
    convergence), host-level survivor gathering between segments."""

    def __init__(self, mesh: Mesh, m, n, tol, feas_tol, dtype,
                 pricing: str = "dantzig"):
        super().__init__(m, n, tol, feas_tol, dtype, pricing=pricing)
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.pad_multiple = int(np.prod(mesh.devices.shape))
        spec = P(axes)
        state_specs = CompactionState(
            **{f: spec for f in CompactionState._fields})
        rule = self.rule

        def p1(state, steps):
            state, it = segment_phase1(state, steps, m=m, n=n, tol=tol,
                                       rule=rule)
            return state, it.reshape(1)

        def p2(state, steps):
            state, it = segment_phase2(state, steps, m=m, n=n, tol=tol,
                                       rule=rule)
            return state, it.reshape(1)

        def wrap(fn):
            return jax.jit(shard_map(
                fn, mesh=mesh,
                in_specs=(state_specs, P()),
                out_specs=(state_specs, spec),
                check_rep=False,
            ))

        self._p1 = wrap(p1)
        self._p2 = wrap(p2)

    def run_phase1(self, state, steps):
        state, it = self._p1(state, jnp.int32(steps))
        return state, int(np.max(np.asarray(it)))

    def run_phase2(self, state, steps):
        state, it = self._p2(state, jnp.int32(steps))
        return state, int(np.max(np.asarray(it)))


class _RevisedShardMapBackend(RevisedBackend):
    """Revised-simplex segment runners under shard_map: per-shard
    while-loops (each chip's eta file and LU factors stay chip-local since
    every RevisedState leaf is batched on axis 0), host-level survivor
    gathering — and refactor-on-compact — between segments."""

    def __init__(self, mesh: Mesh, m, n, tol, feas_tol, dtype,
                 pricing: str = "dantzig",
                 refactor_period: Optional[int] = None):
        super().__init__(m, n, tol, feas_tol, dtype, pricing=pricing,
                         refactor_period=refactor_period)
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.pad_multiple = int(np.prod(mesh.devices.shape))
        spec = P(axes)
        state_specs = RevisedState(
            **{f: spec for f in RevisedState._fields})
        rule, K = self.rule, self.refactor_period

        def p1(state, steps):
            state, it = segment_revised_phase1(
                state, steps, m=m, n=n, tol=tol, refactor_period=K,
                rule=rule)
            return state, it.reshape(1)

        def p2(state, steps):
            state, it = segment_revised_phase2(
                state, steps, m=m, n=n, tol=tol, refactor_period=K,
                rule=rule)
            return state, it.reshape(1)

        def wrap(fn):
            return jax.jit(shard_map(
                fn, mesh=mesh,
                in_specs=(state_specs, P()),
                out_specs=(state_specs, spec),
                check_rep=False,
            ))

        self._p1 = wrap(p1)
        self._p2 = wrap(p2)

    def run_phase1(self, state, steps):
        state, it = self._p1(state, jnp.int32(steps))
        return state, int(np.max(np.asarray(it)))

    def run_phase2(self, state, steps):
        state, it = self._p2(state, jnp.int32(steps))
        return state, int(np.max(np.asarray(it)))


class _PdhgShardMapBackend(PdhgBackend):
    """First-order segment runners under shard_map: each chip advances its
    local LPs through check rounds independently (every PdhgState leaf —
    problem data, iterates, averages, restart state — is batched on axis 0,
    so the specs are uniform), host-level survivor gathering between
    segments.  There is no phase 1, so only the stage-2 runner is wrapped."""

    def __init__(self, mesh: Mesh, m, n, tol, dtype, check_every=None):
        kw = {} if check_every is None else {"check_every": check_every}
        super().__init__(m, n, tol, dtype, **kw)
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.pad_multiple = int(np.prod(mesh.devices.shape))
        spec = P(axes)
        state_specs = PdhgState(**{f: spec for f in PdhgState._fields})
        ce = self.check_every

        def p2(state, steps):
            state, it = segment_pdhg(state, steps, tol=self.tol,
                                     check_every=ce)
            return state, it.reshape(1)

        self._p2 = jax.jit(shard_map(
            p2, mesh=mesh,
            in_specs=(state_specs, P()),
            out_specs=(state_specs, spec),
            check_rep=False,
        ))

    def run_phase2(self, state, steps):
        state, it = self._p2(state, jnp.int32(steps))
        return state, int(np.max(np.asarray(it)))


def solve_shard_map(batch: LPBatch, mesh: Mesh, *, dtype=jnp.float32,
                    tol: Optional[float] = None, feas_tol: float = 1e-5,
                    max_iters: Optional[int] = None, lower_only: bool = False,
                    segment_k: Optional[int] = None,
                    compact_threshold: Optional[float] = None,
                    pricing: str = "dantzig", stats_out=None,
                    backend: str = "tableau",
                    refactor_period: Optional[int] = None,
                    presolve: bool = True, scale: Optional[bool] = None,
                    telemetry: bool = False, tracer=None):
    """Per-shard termination: each chip solves its local LPs to completion
    independently (no cross-chip sync per pivot).

    ``segment_k=None`` (default) keeps the original one-shot semantics.
    ``segment_k=K`` runs the solve in K-pivot segments through the active-set
    compaction scheduler (see module docstring); results are identical, work
    shrinks with the survivor count (``compact_threshold=None`` derives the
    gather eagerness from `auto_compact_threshold`).  ``pricing`` selects the
    entering-column rule (core/pricing.py) in both modes, and
    ``backend="revised"`` the basis-factor engine (core/revised.py).
    GeneralLPBatch inputs canonicalize on the host before sharding and
    recover after the gather, in both the one-shot and segmented modes."""
    canonicalize_backend(backend)
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    max_iters, tol = _backend_defaults(backend, max_iters, tol, m, n, dtype)

    if segment_k is not None and lower_only:
        raise ValueError(
            "segment_k and lower_only cannot be combined: the segmented "
            "scheduler is a host-driven loop with no single lowerable "
            "computation")
    if stats_out is not None and segment_k is None:
        raise ValueError(
            "stats_out requires segment_k: the one-shot solve has no "
            "segment accounting to record")

    if segment_k is not None:
        budget = max_iters
        if backend == "revised":
            runner = _RevisedShardMapBackend(
                mesh, m, n, tol, feas_tol, dtype, pricing=pricing,
                refactor_period=refactor_period)
        elif backend == "pdhg":
            from .pdhg import _check_pdhg_pricing
            _check_pdhg_pricing(pricing)
            runner = _PdhgShardMapBackend(mesh, m, n, tol, dtype)
            # the scheduler's step unit for pdhg is one check round
            budget = -(-max_iters // runner.check_every)
        else:
            runner = _ShardMapBackend(mesh, m, n, tol, feas_tol, dtype,
                                      pricing=pricing)
        padded, orig_B = _pad_batch(batch, runner.pad_multiple)
        state = runner.init(jnp.asarray(padded.A, dtype),
                            jnp.asarray(padded.b, dtype),
                            jnp.asarray(padded.c, dtype),
                            ub=jnp.asarray(padded.upper_bounds(), dtype),
                            telemetry=telemetry)
        B_pad = padded.batch
        orig = np.concatenate(
            [np.arange(orig_B), np.full(B_pad - orig_B, -1)]).astype(np.int64)
        # padding LPs are not real work: retire them before the first segment
        state = runner.deactivate(state, orig >= 0)
        cfg = CompactionConfig(
            segment_k=segment_k,
            compact_threshold=resolve_compact_threshold(compact_threshold,
                                                        segment_k),
            pad_multiple=runner.pad_multiple)
        return finish_result(rec, run_schedule(runner, state, orig, orig_B, n,
                                               max_iters=budget, config=cfg,
                                               stats_out=stats_out,
                                               tracer=tracer))

    A, b, c, ub, axes, orig, _ = _prep(batch, mesh, dtype)
    spec = P(axes)

    local = functools.partial(_solve_local, m=m, n=n, max_iters=max_iters,
                              tol=tol, feas_tol=feas_tol, pricing=pricing,
                              backend=backend, refactor_period=refactor_period,
                              telemetry=telemetry)
    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        # one extra batch-sharded prefix entry covers every telemetry lane
        out_specs=(spec,) * (7 if telemetry else 6),
        check_rep=False,
    ))
    if lower_only:
        return fn.lower(jax.ShapeDtypeStruct(A.shape, A.dtype),
                        jax.ShapeDtypeStruct(b.shape, b.dtype),
                        jax.ShapeDtypeStruct(c.shape, c.dtype),
                        jax.ShapeDtypeStruct(ub.shape, ub.dtype))
    t0 = time.perf_counter()
    with _maybe_span(tracer, "dispatch", backend=backend, B=batch.batch,
                     m=m, n=n):
        out = fn(A, b, c, ub)
        x, obj, status, iters, y, z = out[:6]
        stats = None
        if telemetry:
            jax.block_until_ready(out[6])
            counters = {k: v[:orig]
                        for k, v in tel_to_numpy(out[6]).items()}
            stats = report_from_counters(counters,
                                         wall_s=time.perf_counter() - t0,
                                         backend=backend)
    res = LPResult(x=np.asarray(x)[:orig], objective=np.asarray(obj)[:orig],
                   status=np.asarray(status)[:orig],
                   iterations=np.asarray(iters)[:orig],
                   y=np.asarray(y)[:orig], z=np.asarray(z)[:orig],
                   stats=stats)
    return finish_result(rec, res)
