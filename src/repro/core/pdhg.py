"""Batched restarted PDHG — a first-order LP engine beside the two simplexes.

The paper's simplex-per-LP design wins on small/medium batched LPs, but its
scaling story (Sec. 6) stalls where per-pivot *sequential depth* dominates:
every pivot is a reduction -> ratio test -> rank-1 update chain that cannot
be parallelized across iterations.  GPU LP work has since moved to
first-order methods — PDLP / cuPDLP-style **restarted primal-dual hybrid
gradient** — whose iteration is nothing but matvecs: embarrassingly batched,
no pivoting, no basis state, tolerance-based convergence.  This module is
that solver family for the repo's canonical batches:

    maximize c.x   s.t.   A x <= b,  0 <= x <= u   (core/lp.py standard form;
                                                    u may be +inf columnwise)

with dual  min b.y + u.w  s.t.  A^T y + w >= c,  y, w >= 0.  One PDHG
iteration is

    x+ = clip(x + tau * (c - A^T y), 0, u)         # primal gradient + prox
    y+ = max(0, y + sigma * (A (2 x+ - x) - b))    # dual ascent on extrapolant

i.e. exactly one (B, m, n) einsum pair per iteration over the whole batch —
native bounds cost one clip, never an extra row.  The matvecs themselves are
injectable (``Matvecs``): core/sparse.py swaps in shared-pattern scatter-add
matvecs so structurally sparse batches pay O(nnz) instead of O(m*n) per
iteration with the identical round/restart/certificate logic.

The four PDLP ingredients, batched:

* **Diagonal preconditioning** — a few Ruiz (inf-norm) equilibration sweeps
  per LP; residuals and certificates are reported in *unscaled* space via
  elementwise unscaling (no second copy of A needed).
* **Step sizes from ||A||_2** — batched power iteration on A^T A estimates
  the per-LP spectral norm; tau * sigma = (0.9 / ||A||)^2 guarantees
  convergence, and the primal weight omega = sqrt(||c|| / ||b||) balances
  the primal/dual step split (tau = eta/omega, sigma = eta*omega).
* **KKT-residual restarts** — the iterate average since the last restart is
  evaluated alongside the current iterate every ``check_every`` iterations;
  when the better of the two ("candidate") decays the KKT residual enough
  (RESTART_SUFFICIENT) the solve restarts from the candidate.  Restarting
  to averages is what upgrades PDHG's O(1/k) ergodic rate to the linear
  rate observed on LPs (sharpness), and it is per-LP: each batch member
  restarts on its own schedule.
* **Per-LP convergence + certificates** — OPTIMAL when max(primal
  infeasibility, dual infeasibility, duality gap) <= tol in relative terms.
  Divergence is classified by testing the normalized iterate as an
  approximate Farkas ray: y >= 0 with A^T y >= -eps and b.y < 0 certifies
  INFEASIBLE, x >= 0 with A x <= eps and c.x > 0 certifies UNBOUNDED —
  both checked in unscaled space, both the *exact* Farkas conditions up to
  tolerance.  ``max_iters`` exhaustion reports ITERATION_LIMIT.

Unlike the simplex engines this convergence is **tolerance-based**
(``backend_spec("pdhg").exact is False``): statuses agree with the exact
oracles at the configured tolerance, objectives to ~tol relative, and the
returned point is interior-accurate rather than a vertex.  What PDHG gives
back is the **primal-dual certificate for free**: ``LPResult.y`` (row
duals) and ``LPResult.z`` (reduced costs c - A^T y) are the iterates
themselves, the same certificate the simplex backends now derive from the
final basis — backend-uniform, and mapped to original coordinates by
``forms.Recovery.recover_duals`` for general batches.

Composition mirrors the other engines: ``solve_pdhg`` is the traceable body
(pjit/shard_map), ``solve_batched_pdhg`` the jitted entry,
``solve_batched_pdhg_compacted`` runs check-rounds as scheduler segments so
converged LPs retire into power-of-two buckets (PDHG's per-LP iteration
counts spread far wider than simplex pivot counts — mean/max ratios of
5-20x are routine — so active-set compaction pays off *harder* here), and
kernels/pdhg_tile.py holds the whole-solve Pallas tile kernel (fused
matvec + prox + restart check in VMEM).
"""
from __future__ import annotations

import functools
import time
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.report import report_from_counters
from ..obs.telemetry import init_telemetry, tel_pdhg_update, tel_to_numpy
from .forms import ensure_canonical, finish_result, prepare_warm
from .lp import (
    INFEASIBLE,
    ITERATION_LIMIT,
    OPTIMAL,
    UNBOUNDED,
    LPBatch,
    LPResult,
    WarmStart,
)

_RUNNING = -1

# Restart policy (PDLP-style, on the KKT residual of the restart candidate
# relative to the residual at the last restart): restart on *sufficient*
# decay, on *necessary* decay once the candidate has started regressing
# (oscillation), and artificially once the running average is much older
# than the last restart interval (stale-average guard).
RESTART_SUFFICIENT = 0.2
RESTART_NECESSARY = 0.9
# Adaptive primal weight (PDLP): at each restart, omega moves halfway (in
# log space) toward the observed dual/primal displacement ratio — the
# decisive ingredient on ill-conditioned dense instances (the paper's
# Sec.-6 random class goes from ~80% to 100% oracle status parity).
OMEGA_SMOOTHING = 0.5
OMEGA_MIN, OMEGA_MAX = 1e-4, 1e4
# Ruiz equilibration sweeps / power-iteration steps at setup.
RUIZ_ITERS = 10
POWER_ITERS = 40
# Safety factor on the spectral-norm bound: tau*sigma*||A||^2 = 0.9^2 < 1.
STEP_SAFETY = 0.9
# Convergence is checked (and restarts considered) every this many
# iterations; iteration counts are therefore quantized to it.
CHECK_EVERY = 16
# Farkas-ray classification: relative certificate tolerance, and the minimum
# normalized iterate magnitude before a ray is even considered (bounded
# convergent iterates stay small; diverging rays cross it immediately).
CERT_TOL = 1e-4
RAY_MIN_NORM = 1.0
# Malitsky-Pock linesearch (step_rule="malitsky_pock"): per-iteration dual
# backtracking that lets tau grow past the conservative spectral-norm bound
# on instances where the local curvature allows it — the fix for the
# adversarial dense stragglers that cap out under the fixed step.  A dual
# trial step at tau_try is accepted when
#   sqrt(beta) * tau_try * ||A^T y_try - A^T y|| <= MP_DELTA * ||y_try - y||
# (beta = omega^2, so sigma = beta * tau preserves the primal weight);
# rejection shrinks tau_try by MP_MU, and after MP_TRIALS rejections the
# iteration falls back to the known-safe fixed step (sqrt(beta) * tau0 =
# eta <= STEP_SAFETY / ||A||) and resets the growth clock.
MP_DELTA = 0.99
MP_MU = 0.7
MP_TRIALS = 6


def default_pdhg_max_iters(m: int, n: int) -> int:
    """Iteration cap for the first-order engine.  PDHG needs thousands of
    (cheap) iterations where simplex needs tens of (expensive) pivots; the
    cap only bounds the lockstep loop on pathological members (sized so the
    paper's ill-conditioned Sec.-6 random class converges with margin)."""
    return 200 * (m + n) + 30000


def pdhg_elements(m: int, n: int) -> int:
    """State elements touched per PDHG iteration (the executed-work unit of
    benchmarks/pivot_work.py): the two matvecs read the (m, n) data twice
    and write the four length-m/n vectors."""
    return 2 * m * n + 2 * (m + n)


class PdhgState(NamedTuple):
    """Resumable solver state; every leaf keeps the batch on axis 0 so the
    compaction scheduler's generic gathers apply unchanged.  The problem
    data rides in the state (like RevisedState's ``Abar``) because segment
    boundaries must be able to gather it alongside the iterates."""
    A: jax.Array        # (B, m, n) Ruiz-scaled data — or, under a sparse
                        #  matvec pair (core/sparse.py), the (B, nnz) scaled
                        #  value array of the shared pattern
    b: jax.Array        # (B, m) scaled rhs
    c: jax.Array        # (B, n) scaled objective
    rsc: jax.Array      # (B, m) row scales (residual unscaling)
    csc: jax.Array      # (B, n) col scales
    ub: jax.Array       # (B, n) scaled upper bounds (+inf = unbounded); the
                        #  prox step clips to [0, ub], so x <= ub holds
                        #  exactly at every iterate
    eta: jax.Array      # (B, 1) base step: tau*sig = eta^2 <= 1/||A||^2
    omega: jax.Array    # (B, 1) primal weight: tau = eta/omega, sig = eta*omega
    binf: jax.Array     # (B,) unscaled ||b||_inf (relative residual floor)
    cinf: jax.Array     # (B,) unscaled ||c||_inf
    x: jax.Array        # (B, n) primal iterate (scaled space)
    y: jax.Array        # (B, m) dual iterate (scaled space)
    xs: jax.Array       # (B, n) running primal sum since last restart
    ys: jax.Array       # (B, m) running dual sum
    xr: jax.Array       # (B, n) last-restart anchor (primal-weight update)
    yr: jax.Array       # (B, m) last-restart anchor
    cnt: jax.Array      # (B,) iterations in the running average
    last_res: jax.Array  # (B,) KKT residual at the last restart
    prev_res: jax.Array  # (B,) candidate residual at the previous check
    phase: jax.Array    # (B,) int32 — constant 2 (no phase 1; lets the
                        #  compaction scheduler's stage-1 pass no-op)
    status: jax.Array   # (B,) int32 — _RUNNING until terminal
    iters: jax.Array    # (B,) int32
    tel: Any = None     # obs.TelemetryState lanes or None (empty subtree:
                        #  the telemetry-off trace is unchanged)


# ---------------------------------------------------------------------------
# Matvec abstraction: the whole engine touches A only through Ax / A^T y
# ---------------------------------------------------------------------------

class Matvecs(NamedTuple):
    """The two matvecs PDHG is made of, as injectable closures.  ``data`` is
    whatever PdhgState.A holds — the dense (B, m, n) array here, a (B, nnz)
    shared-pattern value array in core/sparse.py — so one iteration/check/
    certificate implementation serves both storage formats."""
    ax: object    # (data, x: (B, n)) -> (B, m)
    aty: object   # (data, y: (B, m)) -> (B, n)


DENSE_MV = Matvecs(
    ax=lambda A, x: jnp.einsum("bmn,bn->bm", A, x),
    aty=lambda A, y: jnp.einsum("bmn,bm->bn", A, y))


# ---------------------------------------------------------------------------
# Setup: equilibration + step sizes
# ---------------------------------------------------------------------------

def ruiz_equilibrate(A: jax.Array, iters: int = RUIZ_ITERS):
    """Batched Ruiz (inf-norm) equilibration: returns (r, s) with
    r[:, :, None] * A * s[:, None, :] having rows/cols of ~unit inf-norm.
    All-zero rows/columns keep scale 1."""
    B, m, n = A.shape
    r = jnp.ones((B, m), A.dtype)
    s = jnp.ones((B, n), A.dtype)

    def body(_, rs):
        r, s = rs
        W = jnp.abs(A) * r[:, :, None] * s[:, None, :]
        rn = W.max(axis=2)
        r = r / jnp.sqrt(jnp.where(rn > 0, rn, 1.0))
        W = jnp.abs(A) * r[:, :, None] * s[:, None, :]
        cn = W.max(axis=1)
        s = s / jnp.sqrt(jnp.where(cn > 0, cn, 1.0))
        return r, s

    return jax.lax.fori_loop(0, iters, body, (r, s))


def power_sigma_max(A: jax.Array, iters: int = POWER_ITERS) -> jax.Array:
    """Batched power iteration on A^T A: per-LP spectral-norm estimate
    ||A||_2 (floored away from zero for all-zero members)."""
    B, m, n = A.shape
    v = jnp.full((B, n), 1.0 / np.sqrt(n), A.dtype)

    def body(_, v):
        w = jnp.einsum("bmn,bm->bn", A, jnp.einsum("bmn,bn->bm", A, v))
        nw = jnp.linalg.norm(w, axis=1, keepdims=True)
        return w / jnp.where(nw > 0, nw, 1.0)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.maximum(jnp.linalg.norm(jnp.einsum("bmn,bn->bm", A, v),
                                       axis=1), 1e-12)


def init_pdhg_state(A, b, c, ub=None) -> PdhgState:
    """Equilibrate, estimate step sizes, and seed the zero iterate.  ``ub``
    (unscaled, +inf = unbounded) is carried into scaled space as ub / csc
    since x_unscaled = x_scaled * csc."""
    B, m, n = A.shape
    dtype = A.dtype
    binf = jnp.abs(b).max(axis=1)
    cinf = jnp.abs(c).max(axis=1)
    r, s = ruiz_equilibrate(A)
    As = A * r[:, :, None] * s[:, None, :]
    bs = b * r
    cs = c * s
    if ub is None:
        ubs = jnp.full((B, n), jnp.inf, dtype)
    else:
        ubs = (jnp.asarray(ub, dtype) / s).astype(dtype)
    eta = STEP_SAFETY / power_sigma_max(As)
    nc = jnp.linalg.norm(cs, axis=1)
    nb = jnp.linalg.norm(bs, axis=1)
    omega = jnp.sqrt(jnp.where((nc > 0) & (nb > 0),
                               nc / jnp.maximum(nb, 1e-12), 1.0))
    omega = jnp.clip(omega, OMEGA_MIN, OMEGA_MAX)
    return PdhgState(
        A=As, b=bs, c=cs, rsc=r, csc=s, ub=ubs,
        eta=eta[:, None].astype(dtype),
        omega=omega[:, None].astype(dtype),
        binf=binf, cinf=cinf,
        x=jnp.zeros((B, n), dtype), y=jnp.zeros((B, m), dtype),
        xs=jnp.zeros((B, n), dtype), ys=jnp.zeros((B, m), dtype),
        xr=jnp.zeros((B, n), dtype), yr=jnp.zeros((B, m), dtype),
        cnt=jnp.zeros((B,), dtype),
        last_res=jnp.full((B,), jnp.inf, dtype),
        prev_res=jnp.full((B,), jnp.inf, dtype),
        phase=jnp.full((B,), 2, jnp.int32),
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32))


def inject_pdhg_warm(state: PdhgState, wx, wy, womega=None,
                     mv: Matvecs = DENSE_MV) -> PdhgState:
    """Seed the iterate from a parent solve's terminal point (warm start).

    ``wx``/``wy`` arrive in *unscaled canonical* coordinates (the WarmStart
    carrier convention) and are mapped into this state's Ruiz-scaled space,
    projected onto the feasible boxes (x into [0, ub], y into >= 0).  The
    **reset guard** makes a bad warm start harmless: each LP adopts the
    warm point only where its KKT residual is no worse than the zero
    iterate's — otherwise that LP silently starts cold.  ``womega`` carries
    the parent's adapted primal weight (clipped to the usual range); ``eta``
    is always re-estimated fresh from the new data (step sizes depend on
    ||A|| of *this* batch, not the parent's).  Restart bookkeeping
    (averages, anchors, residual history) starts clean from the adopted
    point, so the downstream round logic is oblivious to warm starts."""
    dtype = state.x.dtype
    xw = jnp.clip(jnp.asarray(wx, dtype) / state.csc, 0.0, state.ub)
    yw = jnp.maximum(jnp.asarray(wy, dtype) / state.rsc, 0.0)
    xw = jnp.where(jnp.isfinite(xw), xw, 0.0)
    yw = jnp.where(jnp.isfinite(yw), yw, 0.0)
    res_w = kkt_residuals(state, xw, yw, mv)
    res_0 = kkt_residuals(state, state.x, state.y, mv)
    adopt = jnp.isfinite(res_w) & (res_w <= res_0)
    x = jnp.where(adopt[:, None], xw, state.x)
    y = jnp.where(adopt[:, None], yw, state.y)
    omega = state.omega
    if womega is not None:
        ow = jnp.asarray(womega, dtype).reshape(-1, 1)
        ow = jnp.where(jnp.isfinite(ow),
                       jnp.clip(ow, OMEGA_MIN, OMEGA_MAX), state.omega)
        omega = jnp.where(adopt[:, None], ow, state.omega)
    return state._replace(x=x, y=y, xr=x, yr=y, omega=omega)


# ---------------------------------------------------------------------------
# Residuals + certificates
# ---------------------------------------------------------------------------

def kkt_residual_parts(s: PdhgState, x, y, mv: Matvecs = DENSE_MV):
    """Relative KKT residual components of a (scaled-space) point, reported
    for the *unscaled* problem: (primal infeasibility, dual infeasibility,
    duality gap).  Unscaling is elementwise — A itself is only touched
    through the two scaled matvecs.

    Bounded columns (finite ub) shift from the dual-infeasibility term to
    the dual objective: the dual of max c.x s.t. Ax <= b, 0 <= x <= u is
    min b.y + u.w s.t. A^T y + w >= c with w >= 0, so any positive reduced
    cost on a bounded column is absorbed by w_j = (c - A^T y)_j+ (at the
    price u_j * w_j in the gap) instead of counting as infeasibility."""
    ax = mv.ax(s.A, x)
    aty = mv.aty(s.A, y)
    rp = (jnp.maximum(ax - s.b, 0.0) / s.rsc).max(axis=1) / (1.0 + s.binf)
    zc = jnp.maximum(s.c - aty, 0.0)
    fin = jnp.isfinite(s.ub)
    rd = (jnp.where(fin, 0.0, zc) / s.csc).max(axis=1) / (1.0 + s.cinf)
    pobj = jnp.einsum("bn,bn->b", s.c, x)
    # scaled dots equal unscaled dots; u0_j * w0_j = ub_scaled_j * zc_j
    dobj = jnp.einsum("bm,bm->b", s.b, y) \
        + (jnp.where(fin, s.ub, 0.0) * zc).sum(axis=1)
    gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
    return rp, rd, gap


def kkt_residuals(s: PdhgState, x, y, mv: Matvecs = DENSE_MV):
    """max over the `kkt_residual_parts` triple — the convergence test."""
    rp, rd, gap = kkt_residual_parts(s, x, y, mv)
    return jnp.maximum(jnp.maximum(rp, rd), gap)


def _ray_certificates(s: PdhgState, active, mv: Matvecs = DENSE_MV):
    """Approximate Farkas-ray classification of diverging iterates.

    Works on the unscaled rays (y_u = r * y / ||.||, x_u = s * x / ||.||,
    both elementwise rescales of scaled matvecs):
      INFEASIBLE <- y_u >= 0, A^T y_u >= -eps (unbounded cols),
                    b.y_u + sum_fin u_j (A^T y_u)_j^- < -eps
      UNBOUNDED  <- x_u >= 0 supported on unbounded cols, A x_u <= eps,
                    c.x_u > eps
    Finite upper bounds relax the dual ray (the slack w_j = (A^T y_u)_j^-
    is admissible on bounded columns at cost u_j w_j) and restrict the
    primal ray: a recession direction of {Ax <= b, 0 <= x <= u} cannot
    move a bounded coordinate, so the candidate ray is the iterate
    *projected onto the unbounded columns* (bounded components sit at
    finite values <= u and are not part of any divergence).
    Bounded (convergent) iterates stay below RAY_MIN_NORM in normalized
    magnitude and are never classified."""
    fin = jnp.isfinite(s.ub)
    ubm = jnp.where(fin, s.ub, 0.0)
    # dual ray -> primal infeasibility
    yinf = jnp.abs(s.y * s.rsc).max(axis=1)
    yh = s.y / jnp.maximum(yinf, 1e-12)[:, None]
    aty_s = mv.aty(s.A, yh)
    aty_u = aty_s / s.csc                                # A0^T (r yh)
    by_u = jnp.einsum("bm,bm->b", s.b, yh)               # b0 . (r yh)
    # u0_j * max(0, -(A0^T yh)_j) = ub_scaled_j * max(0, -aty_scaled_j)
    uw = (ubm * jnp.maximum(-aty_s, 0.0)).sum(axis=1)
    ray_scale = 1.0 + s.binf + s.cinf
    infeas = active & (yinf > RAY_MIN_NORM) \
        & (jnp.where(fin, jnp.inf, aty_u).min(axis=1)
           >= -CERT_TOL * ray_scale) \
        & (by_u + uw <= -CERT_TOL * ray_scale)
    # primal ray -> unboundedness (supported on unbounded columns only; an
    # all-bounded LP has xinf == 0 and is never classified here)
    xray = jnp.where(fin, 0.0, s.x)
    xinf = jnp.abs(xray * s.csc).max(axis=1)
    xh = xray / jnp.maximum(xinf, 1e-12)[:, None]
    ax_u = mv.ax(s.A, xh) / s.rsc
    cx_u = jnp.einsum("bn,bn->b", s.c, xh)
    unbounded = active & (xinf > RAY_MIN_NORM) \
        & (ax_u.max(axis=1) <= CERT_TOL * ray_scale) \
        & (cx_u >= CERT_TOL * ray_scale)
    return infeas, unbounded


# ---------------------------------------------------------------------------
# The solver: fused iteration rounds + check/restart
# ---------------------------------------------------------------------------

def pdhg_round(s: PdhgState, *, tol: float,
               check_every: int = CHECK_EVERY,
               mv: Matvecs = DENSE_MV) -> PdhgState:
    """``check_every`` fused PDHG iterations followed by one convergence /
    restart / certificate check — the scheduler-visible unit of work (one
    "round").  Terminal LPs perform masked no-ops, exactly like the
    simplex engines' lockstep steps."""
    active0 = s.status == _RUNNING
    act = active0[:, None]
    tau = s.eta / s.omega
    sig = s.eta * s.omega

    def body(_, carry):
        x, y, xs, ys, cnt = carry
        aty = mv.aty(s.A, y)
        # the prox of [0, ub] indicator: clip (ub = +inf reduces to max)
        xn = jnp.clip(x + tau * (s.c - aty), 0.0, s.ub)
        ax2 = mv.ax(s.A, 2.0 * xn - x)
        yn = jnp.maximum(y + sig * (ax2 - s.b), 0.0)
        x = jnp.where(act, xn, x)
        y = jnp.where(act, yn, y)
        return (x, y, xs + jnp.where(act, x, 0.0),
                ys + jnp.where(act, y, 0.0), cnt + active0)

    x, y, xs, ys, cnt = jax.lax.fori_loop(
        0, check_every, body, (s.x, s.y, s.xs, s.ys, s.cnt))
    s = s._replace(x=x, y=y, xs=xs, ys=ys, cnt=cnt,
                   iters=s.iters + check_every * active0)
    if s.tel is not None:
        s = s._replace(tel=tel_pdhg_update(
            s.tel, inc_iters=check_every * active0))
    return _pdhg_check(s, tol=tol, mv=mv)


def _pdhg_check(s: PdhgState, *, tol: float,
                mv: Matvecs = DENSE_MV) -> PdhgState:
    """The round's convergence / restart / certificate check, shared by
    every step rule (the fixed-step and Malitsky-Pock rounds differ only
    in how they produce the iterates that land here)."""
    active0 = s.status == _RUNNING

    # ---- check: candidate = better of current iterate and running average --
    cc = jnp.maximum(s.cnt, 1.0)[:, None]
    xa, ya = s.xs / cc, s.ys / cc
    res_cur = kkt_residuals(s, s.x, s.y, mv)
    res_avg = kkt_residuals(s, xa, ya, mv)
    use_avg = res_avg < res_cur
    res = jnp.where(use_avg, res_avg, res_cur)
    xc = jnp.where(use_avg[:, None], xa, s.x)
    yc = jnp.where(use_avg[:, None], ya, s.y)

    converged = active0 & (res <= tol)
    # PDLP-style restarts: sufficient decay, or necessary decay once the
    # candidate has started regressing (the average has peaked)
    restart = (res <= RESTART_SUFFICIENT * s.last_res) \
        | ((res <= RESTART_NECESSARY * s.last_res) & (res > s.prev_res))
    restart = active0 & ~converged & restart
    adopt = (converged | restart)[:, None]
    x = jnp.where(adopt, xc, s.x)
    y = jnp.where(adopt, yc, s.y)
    xs = jnp.where(restart[:, None], 0.0, s.xs)
    ys = jnp.where(restart[:, None], 0.0, s.ys)
    cnt = jnp.where(restart, 0.0, s.cnt)
    last_res = jnp.where(restart, res, s.last_res)
    prev_res = jnp.where(restart, jnp.inf, res)

    # adaptive primal weight: at a restart, move omega (log-space, smoothed)
    # toward the dual/primal displacement ratio since the previous restart
    dx = jnp.linalg.norm(xc - s.xr, axis=1)
    dy = jnp.linalg.norm(yc - s.yr, axis=1)
    can_adapt = restart & (dx > 1e-10) & (dy > 1e-10)
    om = s.omega[:, 0]
    om_new = jnp.exp(OMEGA_SMOOTHING
                     * jnp.log(jnp.maximum(dy, 1e-12)
                               / jnp.maximum(dx, 1e-12))
                     + (1.0 - OMEGA_SMOOTHING) * jnp.log(om))
    omega = jnp.where(can_adapt, jnp.clip(om_new, OMEGA_MIN, OMEGA_MAX),
                      om)[:, None]
    xr = jnp.where(restart[:, None], xc, s.xr)
    yr = jnp.where(restart[:, None], yc, s.yr)

    infeas, unbounded = _ray_certificates(s, active0 & ~converged, mv)
    status = jnp.where(converged, OPTIMAL, s.status)
    status = jnp.where(infeas, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    tel = s.tel
    if tel is not None:
        # component triple at the adopted candidate (extra matvecs only on
        # the telemetry trace); terminal LPs recompute frozen values
        rp_t, rd_t, gap_t = kkt_residual_parts(s, xc, yc, mv)
        tel = tel_pdhg_update(tel, restart=restart, kkt=(rp_t, rd_t, gap_t),
                              omega=omega)
    return s._replace(x=x, y=y, xs=xs, ys=ys, xr=xr, yr=yr, cnt=cnt,
                      last_res=last_res, prev_res=prev_res, omega=omega,
                      status=status, tel=tel)


def pdhg_round_mp(s: PdhgState, tau, tprev, *, tol: float,
                  check_every: int = CHECK_EVERY,
                  mv: Matvecs = DENSE_MV):
    """Malitsky-Pock round: ``check_every`` iterations with per-iteration
    dual linesearch (see the MP_* constants), then the same check as
    `pdhg_round`.  ``tau``/``tprev`` are (B, 1) per-LP primal steps carried
    across rounds (the linesearch extrapolates with theta = tau/tprev);
    returns ``(state, tau, tprev)``.  The primal weight keeps adapting at
    restarts exactly as under the fixed rule — the linesearch scales the
    step magnitude, omega keeps steering the primal/dual split."""
    active0 = s.status == _RUNNING
    act = active0[:, None]
    beta = s.omega ** 2
    sqb = s.omega                    # sqrt(beta), omega > 0 by construction
    tau0 = s.eta / s.omega
    sig0 = s.eta * s.omega

    def body(_, carry):
        x, y, xs, ys, cnt, tau, tprev = carry
        aty = mv.aty(s.A, y)
        xn = jnp.clip(x + tau * (s.c - aty), 0.0, s.ub)

        def trial(_, tc):
            tau_t, y_acc, t_acc, done = tc
            theta = tau_t / jnp.maximum(tau, 1e-30)
            xbar = xn + theta * (xn - x)
            y_try = jnp.maximum(
                y + beta * tau_t * (mv.ax(s.A, xbar) - s.b), 0.0)
            lhs = sqb * tau_t * jnp.linalg.norm(
                mv.aty(s.A, y_try) - aty, axis=1)[:, None]
            rhs = MP_DELTA * jnp.linalg.norm(y_try - y, axis=1)[:, None]
            # a zero dual move (rhs == 0 == lhs) is a fixed point: accept
            ok = ~done & (lhs <= rhs + 1e-30)
            y_acc = jnp.where(ok, y_try, y_acc)
            t_acc = jnp.where(ok, tau_t, t_acc)
            done = done | ok
            return (jnp.where(done, tau_t, tau_t * MP_MU), y_acc, t_acc,
                    done)

        theta0 = tau / jnp.maximum(tprev, 1e-30)
        init = (tau * jnp.sqrt(1.0 + theta0), jnp.zeros_like(y),
                jnp.zeros_like(tau), jnp.zeros_like(tau, bool))
        _, y_acc, t_acc, done = jax.lax.fori_loop(0, MP_TRIALS, trial, init)
        # fallback: the known-safe fixed step, and reset the growth clock
        y_fb = jnp.maximum(
            y + sig0 * (mv.ax(s.A, 2.0 * xn - x) - s.b), 0.0)
        yn = jnp.where(done, y_acc, y_fb)
        tau_n = jnp.where(done, t_acc, tau0)
        tprev_n = jnp.where(done, tau, tau0)
        x = jnp.where(act, xn, x)
        y = jnp.where(act, yn, y)
        tau = jnp.where(act, tau_n, tau)
        tprev = jnp.where(act, tprev_n, tprev)
        return (x, y, xs + jnp.where(act, x, 0.0),
                ys + jnp.where(act, y, 0.0), cnt + active0, tau, tprev)

    x, y, xs, ys, cnt, tau, tprev = jax.lax.fori_loop(
        0, check_every, body, (s.x, s.y, s.xs, s.ys, s.cnt, tau, tprev))
    s = s._replace(x=x, y=y, xs=xs, ys=ys, cnt=cnt,
                   iters=s.iters + check_every * active0)
    if s.tel is not None:
        s = s._replace(tel=tel_pdhg_update(
            s.tel, inc_iters=check_every * active0))
    return _pdhg_check(s, tol=tol, mv=mv), tau, tprev


def extract_pdhg(s: PdhgState, mv: Matvecs = DENSE_MV):
    """(x, obj, status, iters, y, z) in *unscaled* canonical coordinates.
    ``z = c - A^T y`` is the reduced-cost certificate; objective and duals
    are NaN off-OPTIMAL, matching the solver convention."""
    x = s.x * s.csc
    y = s.y * s.rsc
    obj = jnp.einsum("bn,bn->b", s.c, s.x)      # == c0 . x_unscaled
    z = s.c / s.csc - mv.aty(s.A, s.y) / s.csc
    status = jnp.where(s.status == _RUNNING, ITERATION_LIMIT, s.status)
    opt = (status == OPTIMAL)
    obj = jnp.where(opt, obj, jnp.nan)
    y = jnp.where(opt[:, None], y, jnp.nan)
    z = jnp.where(opt[:, None], z, jnp.nan)
    return x, obj, status.astype(jnp.int8), s.iters, y, z


def solve_pdhg(A, b, c, ub=None, *, m: int, n: int, max_iters: int,
               tol: float, feas_tol: float = 0.0,
               check_every: int = CHECK_EVERY,
               warm_x=None, warm_y=None, warm_omega=None,
               full_state: bool = False, step_rule: str = "fixed",
               telemetry: bool = False):
    """Traceable whole-solve body (shared by jit, pjit and shard_map):
    setup + one while_loop over check rounds.  ``feas_tol`` is accepted for
    entry-point uniformity but unused (PDHG has no phase 1 — feasibility is
    part of the KKT residual).  ``warm_x``/``warm_y``/``warm_omega`` seed
    the iterate via `inject_pdhg_warm` (per-LP reset guard included);
    ``full_state=True`` appends the terminal iterate leaves
    (x, y unscaled *pre NaN-mask*, omega, eta) for WarmStart capture.
    ``step_rule`` selects the iteration: "fixed" (default — the spectral
    step estimate) or "malitsky_pock" (per-iteration dual linesearch,
    see `pdhg_round_mp`)."""
    del feas_tol
    if step_rule not in ("fixed", "malitsky_pock"):
        raise ValueError(
            f"unknown step_rule {step_rule!r}: expected 'fixed' or "
            "'malitsky_pock'")
    state = init_pdhg_state(A, b, c, ub)
    if telemetry:
        state = state._replace(tel=init_telemetry(state.x.shape[0]))
    if warm_x is not None and warm_y is not None:
        state = inject_pdhg_warm(state, warm_x, warm_y, warm_omega)
    rounds = -(-int(max_iters) // int(check_every))

    if step_rule == "malitsky_pock":
        tau0 = state.eta / state.omega

        def cond_mp(carry):
            s, _, _, it = carry
            return jnp.any(s.status == _RUNNING) & (it < rounds)

        def body_mp(carry):
            s, tau, tprev, it = carry
            s, tau, tprev = pdhg_round_mp(s, tau, tprev, tol=tol,
                                          check_every=check_every)
            return s, tau, tprev, it + 1

        state, _, _, _ = jax.lax.while_loop(
            cond_mp, body_mp, (state, tau0, tau0, jnp.int32(0)))
    else:
        def cond(carry):
            s, it = carry
            return jnp.any(s.status == _RUNNING) & (it < rounds)

        def body(carry):
            s, it = carry
            return pdhg_round(s, tol=tol, check_every=check_every), it + 1

        state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    out = extract_pdhg(state)
    if full_state:
        out = out + (state.x * state.csc, state.y * state.rsc,
                     state.omega[:, 0], state.eta[:, 0])
    if telemetry:
        out = out + (state.tel,)
    return out


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "check_every", "telemetry"))
def _solve_pdhg_core(A, b, c, ub, *, m, n, max_iters, tol, check_every,
                     telemetry=False):
    return solve_pdhg(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                      check_every=check_every, telemetry=telemetry)


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "check_every", "step_rule",
                                             "telemetry"))
def _solve_pdhg_core_state(A, b, c, ub, warm_x, warm_y, warm_omega, *, m, n,
                           max_iters, tol, check_every,
                           step_rule="fixed", telemetry=False):
    """`_solve_pdhg_core` + warm injection + terminal-iterate capture (the
    batched entry point's core; warm args may be None for a cold run)."""
    return solve_pdhg(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                      check_every=check_every, warm_x=warm_x, warm_y=warm_y,
                      warm_omega=warm_omega, full_state=True,
                      step_rule=step_rule, telemetry=telemetry)


def _check_pdhg_pricing(pricing: str) -> None:
    if pricing != "dantzig":
        raise ValueError(
            f"pricing rule {pricing!r} is a simplex concept; the pdhg "
            "backend has no pivot selection (every iteration touches every "
            "column).  Use the default pricing with backend='pdhg'.")


def solve_batched_pdhg(batch: LPBatch, *, dtype=jnp.float32,
                       tol: float | None = None,
                       feas_tol: float | None = None,
                       max_iters: int | None = None,
                       check_every: int = CHECK_EVERY,
                       pricing: str = "dantzig",
                       presolve: bool = True,
                       scale: bool | None = None,
                       warm: WarmStart | None = None,
                       step_rule: str = "fixed",
                       telemetry: bool = False) -> LPResult:
    """Solve a batch with the restarted-PDHG first-order engine.

    Same LPBatch -> LPResult contract and GeneralLPBatch acceptance as
    every solver entry point.  Differences from the simplex engines:

    * ``tol`` is the *relative KKT tolerance* (primal/dual infeasibility
      and duality gap); OPTIMAL is tolerance-based, objectives are accurate
      to ~tol relative.  Default 1e-5 (f32) / 1e-8 (f64).
    * ``iterations`` counts PDHG iterations (quantized to ``check_every``)
      — typically 10^2-10^4, not comparable to pivot counts (see
      analysis.lp_perf.pdhg_crossover for the honest flops comparison).
    * ``LPResult.y``/``z`` are the native primal-dual certificate.
    * ``warm`` accepts a `WarmStart` carrying x/y iterates (any engine's —
      the simplex backends' vertex solutions work too); adoption is
      per-LP behind the `inject_pdhg_warm` reset guard, so a stale warm
      start can never do worse than cold.
    * ``step_rule="malitsky_pock"`` enables the per-iteration dual
      linesearch (`pdhg_round_mp`) — the default stays the fixed
      spectral-estimate step.
    """
    _check_pdhg_pricing(pricing)
    del feas_tol
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_pdhg_max_iters(m, n)
    if tol is None:
        tol = 1e-5 if dtype == jnp.float32 else 1e-8
    warm = prepare_warm(warm, rec, batch)
    wx = wy = womega = None
    if warm is not None and warm.x is not None and warm.y is not None:
        wx = jnp.asarray(np.nan_to_num(np.asarray(warm.x, np.float64),
                                       posinf=0.0, neginf=0.0), dtype)
        wy = jnp.asarray(np.nan_to_num(np.asarray(warm.y, np.float64),
                                       posinf=0.0, neginf=0.0), dtype)
        if warm.omega is not None:
            womega = jnp.asarray(np.asarray(warm.omega), dtype)
    t0 = time.perf_counter()
    out = _solve_pdhg_core_state(
        jnp.asarray(batch.A, dtype), jnp.asarray(batch.b, dtype),
        jnp.asarray(batch.c, dtype),
        jnp.asarray(batch.upper_bounds(), dtype),
        wx, wy, womega,
        m=m, n=n, max_iters=int(max_iters),
        tol=float(tol), check_every=int(check_every),
        step_rule=str(step_rule), telemetry=bool(telemetry))
    x, obj, status, iters, y, z, wx_t, wy_t, om_t, eta_t = out[:10]
    stats = None
    if telemetry:
        jax.block_until_ready(out[10])
        stats = report_from_counters(tel_to_numpy(out[10]),
                                     wall_s=time.perf_counter() - t0,
                                     backend="pdhg")
    res = LPResult(x=np.asarray(x), objective=np.asarray(obj),
                   status=np.asarray(status), iterations=np.asarray(iters),
                   y=np.asarray(y), z=np.asarray(z),
                   warm=WarmStart(m=m, n=n, x=np.asarray(wx_t),
                                  y=np.asarray(wy_t), omega=np.asarray(om_t),
                                  eta=np.asarray(eta_t)),
                   stats=stats)
    return finish_result(rec, res)


# ---------------------------------------------------------------------------
# Active-set compaction integration
# ---------------------------------------------------------------------------

def segment_pdhg(state: PdhgState, steps, *, tol: float,
                 check_every: int = CHECK_EVERY):
    """Run up to ``steps`` check rounds; stops early once every LP is
    terminal (stage-2 contract of core.compaction.run_schedule)."""
    def cond(carry):
        s, it = carry
        return jnp.any(s.status == _RUNNING) & (it < steps)

    def body(carry):
        s, it = carry
        return pdhg_round(s, tol=tol, check_every=check_every), it + 1

    return jax.lax.while_loop(cond, body, (state, jnp.int32(0)))


_segment_pdhg_jit = jax.jit(segment_pdhg,
                            static_argnames=("tol", "check_every"))


@jax.jit
def _extract_pdhg_jit(state: PdhgState):
    return extract_pdhg(state)


class PdhgBackend:
    """Compaction-scheduler backend for the first-order engine.

    The scheduler's unit of work ("step") is one check round of
    ``check_every`` PDHG iterations; there is no phase 1 (``phase`` is
    constant 2, so stage-1 no-ops) and no column compaction.  PDHG's
    iteration-count spread is far wider than simplex pivots' — easy LPs
    converge in a few hundred iterations while conditioning stragglers run
    thousands — which is exactly the distribution the power-of-two bucket
    ladder was built to exploit."""

    pad_multiple = 1

    def __init__(self, m: int, n: int, tol: float, dtype,
                 check_every: int = CHECK_EVERY):
        self.m, self.n = m, n
        self.tol = float(tol)
        self.dtype = dtype
        self.check_every = int(check_every)

    def init(self, A, b, c, ub=None, warm: WarmStart | None = None,
             telemetry: bool = False) -> PdhgState:
        state = init_pdhg_state(A, b, c, ub)
        if telemetry:
            state = state._replace(tel=init_telemetry(state.x.shape[0]))
        if warm is not None and warm.x is not None and warm.y is not None:
            dtype = state.x.dtype
            wx = jnp.asarray(np.nan_to_num(np.asarray(warm.x, np.float64),
                                           posinf=0.0, neginf=0.0), dtype)
            wy = jnp.asarray(np.nan_to_num(np.asarray(warm.y, np.float64),
                                           posinf=0.0, neginf=0.0), dtype)
            womega = (None if warm.omega is None
                      else jnp.asarray(np.asarray(warm.omega), dtype))
            state = inject_pdhg_warm(state, wx, wy, womega)
        return state

    def run_phase1(self, state, steps):
        return state, 0          # no phase 1: stage 1 is a no-op

    def run_phase2(self, state, steps):
        state, it = _segment_pdhg_jit(state, jnp.int32(steps), tol=self.tol,
                                      check_every=self.check_every)
        return state, int(it)

    def compact_columns(self, state: PdhgState) -> PdhgState:
        return state             # nothing to drop: data is already minimal

    def limit_phase1(self, state: PdhgState) -> PdhgState:
        return state             # no LP is ever in phase 1

    def deactivate(self, state: PdhgState, valid) -> PdhgState:
        valid = jnp.asarray(np.asarray(valid).reshape(-1))
        status = jnp.where(valid, state.status, ITERATION_LIMIT)
        return state._replace(status=status.astype(state.status.dtype))

    def take(self, state: PdhgState, idx) -> PdhgState:
        idx = jnp.asarray(idx)
        return jax.tree_util.tree_map(lambda a: a[idx], state)

    def status_host(self, state) -> np.ndarray:
        return np.asarray(state.status).reshape(-1)

    def phase_host(self, state) -> np.ndarray:
        return np.asarray(state.phase).reshape(-1)

    def extract(self, state: PdhgState, stage: str):
        out = _extract_pdhg_jit(state)
        return tuple(np.asarray(o) for o in out)

    def elements_per_step(self, stage: str) -> int:
        return self.check_every * pdhg_elements(self.m, self.n)


def solve_batched_pdhg_compacted(
        batch: LPBatch, *, dtype=jnp.float32, tol: Optional[float] = None,
        feas_tol: Optional[float] = None, max_iters: Optional[int] = None,
        segment_k: Optional[int] = None,
        compact_threshold: Optional[float] = None,
        check_every: int = CHECK_EVERY, pricing: str = "dantzig",
        stats_out: Optional[List] = None,
        presolve: bool = True, scale: Optional[bool] = None,
        warm: WarmStart | None = None, runner=None,
        telemetry: bool = False, tracer=None) -> LPResult:
    """Restarted PDHG under the active-set compaction scheduler: K-round
    segments, power-of-two bucket gathers of still-running LPs (problem
    data, iterates, averages and restart state gathered alongside).  Same
    contract as ``solve_batched_compacted``.

    Reproducibility: gathers never change an LP's own iterates, but the
    segment runner is a *different compilation* of the same rounds than
    the monolithic while_loop — XLA fuses the f32 matvecs differently, so
    the restart trajectories (and the tol-satisfying points they stop at)
    drift to ~tol: statuses agree, objectives to ~1e-3 relative (cf. the
    revised backend's batch-decomposition note).

    ``runner`` swaps the segment executor: a factory called as
    ``runner(m, n, tol, dtype, check_every=...)`` returning a
    PdhgBackend-compatible object (kernels.ops.PdhgPallasBackend runs the
    segments as Pallas tile kernels). A runner may return a batch-padded
    state from ``init`` (tile multiples); the padding slots are marked
    terminal here so the scheduler never counts them as active."""
    from .compaction import (CompactionConfig, init_orig,
                             resolve_compact_threshold, run_schedule)

    _check_pdhg_pricing(pricing)
    del feas_tol
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_pdhg_max_iters(m, n)
    if tol is None:
        tol = 1e-5 if dtype == jnp.float32 else 1e-8
    rounds = -(-int(max_iters) // int(check_every))
    if segment_k is None:
        # a handful of compaction checkpoints across the expected solve,
        # mirroring auto_segment_k's ~1/64-of-cap heuristic in round units
        segment_k = max(4, rounds // 64)
    backend = (PdhgBackend(m, n, tol, dtype, check_every=check_every)
               if runner is None
               else runner(m, n, tol, dtype, check_every=check_every))
    state = backend.init(jnp.asarray(batch.A, dtype),
                         jnp.asarray(batch.b, dtype),
                         jnp.asarray(batch.c, dtype),
                         ub=jnp.asarray(batch.upper_bounds(), dtype),
                         warm=prepare_warm(warm, rec, batch),
                         telemetry=telemetry)
    B = batch.batch
    state, orig = init_orig(backend, state, B)
    cfg = CompactionConfig(
        segment_k=int(segment_k),
        compact_threshold=resolve_compact_threshold(compact_threshold,
                                                    int(segment_k)),
        pad_multiple=backend.pad_multiple)
    return finish_result(rec, run_schedule(backend, state, orig, B, n,
                                           max_iters=rounds, config=cfg,
                                           stats_out=stats_out,
                                           tracer=tracer))
