"""Special-case LP over a hyper-rectangle (paper Sec. 5.6).

When the feasible region is a box  B = [a_1,b_1] x ... x [a_n,b_n]  the LP
``max l.x  s.t. x in B`` has the closed form

    sum_i l_i * (a_i if l_i < 0 else b_i)

i.e. a branch-free select + dot product. The paper dedicates one GPU thread
per LP for this; on TPU the whole batch is a single fused select+multiply+
reduce across the lane axis (see kernels/hyperbox_kernel.py for the Pallas
version). Used by the reachability example (paper Sec. 7 / Table 7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def solve_hyperbox_ref(lo: np.ndarray, hi: np.ndarray, directions: np.ndarray):
    """NumPy oracle. lo/hi: (B, n) box bounds; directions: (B, n) or (K, n)
    broadcast against the batch. Returns (B,) or (B, K) support values."""
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    d = np.asarray(directions, np.float64)
    if d.ndim == 2 and d.shape[0] != lo.shape[0]:
        # (K, n) directions applied to every box -> (B, K)
        pick = np.where(d[None, :, :] < 0, lo[:, None, :], hi[:, None, :])
        return (d[None, :, :] * pick).sum(-1)
    pick = np.where(d < 0, lo, hi)
    return (d * pick).sum(-1)


@jax.jit
def solve_hyperbox(lo: jax.Array, hi: jax.Array, directions: jax.Array) -> jax.Array:
    """Batched box-LP: supports (B,n)x(B,n) -> (B,) and (B,n)x(K,n) -> (B,K)."""
    if directions.ndim == 2 and directions.shape[0] != lo.shape[0]:
        pick = jnp.where(directions[None] < 0, lo[:, None, :], hi[:, None, :])
        return (directions[None] * pick).sum(-1)
    pick = jnp.where(directions < 0, lo, hi)
    return (directions * pick).sum(-1)


def hyperbox_as_general_lp(lo: np.ndarray, hi: np.ndarray, directions: np.ndarray):
    """Encode box LPs as general-form LPs (for cross-validation against the
    simplex path).  max d.x  s.t. x <= hi, -x <= -lo.  To respect x >= 0 of
    the standard form we substitute y = x - lo (y >= 0 when lo is the lower
    bound):  max d.y + d.lo  s.t.  y <= hi - lo.
    Returns (LPBatch, offset) where true objective = lp objective + offset.
    """
    from .lp import LPBatch

    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    d = np.asarray(directions, np.float64)
    B, n = lo.shape
    A = np.tile(np.eye(n)[None], (B, 1, 1))
    b = hi - lo
    offset = (d * lo).sum(-1)
    return LPBatch.from_arrays(A, b, d), offset
