"""Batched two-phase simplex in pure JAX — the paper's solver, TPU-native.

Mapping from the paper's CUDA design (Sec. 5) to this implementation:

* one CUDA block per LP            ->  one batch slot per LP; the whole batch
                                       advances through `lax.while_loop`
* parallel reduction for Step 1/2  ->  `argmax` / `argmin` over the tableau
                                       axes (VPU cross-lane reductions)
* MAX-sentinel for invalid ratios  ->  identical `where(col>eps, b/col, BIG)`
* column-major coalesced layout    ->  dense (B, rows, cols) tiles; every
                                       pivot is a rank-1 update (outer
                                       product) which the TPU executes as
                                       aligned vector ops; the reduction
                                       vectors live on the minor (lane) axis
* per-block early exit             ->  active-mask: converged LPs perform
                                       masked no-ops (see core/distributed.py
                                       for per-shard termination and
                                       core/compaction.py for the active-set
                                       scheduler, which together restore true
                                       early exit)
* Dantzig entering rule (Step 1)   ->  pluggable pricing engine
                                       (core/pricing.py): ``pricing=`` selects
                                       dantzig (paper default, bit-identical),
                                       steepest_edge (exact gamma weights) or
                                       devex (approximate weights); per-LP
                                       weights ride in `SimplexState.w` and
                                       their recurrence is fused into the
                                       rank-1 pivot update

Two-level work elimination (this module is Level 1)
---------------------------------------------------

The paper's per-block exit means a CUDA block never executes a single dead
pivot.  A lockstep static-shape solver loses that twice over:

1. **Dead columns.**  The two-phase tableau carries `m` artificial columns
   and the phase-1 objective row through *every* phase-2 pivot, even though
   artificials can never re-enter the basis and the phase-1 row is never read
   again.  For m ~ n that is ~2x wasted FLOPs and bytes per pivot.
2. **Dead LPs.**  Converged LPs keep burning full pivot updates as masked
   no-ops until the slowest LP in the batch finishes
   (`analysis/lp_perf.py` measures this lockstep efficiency as mean/max).

Level 1 (here) fixes (1) structurally: the solve is **two chained
`while_loop`s**.  Loop 1 runs the combined step on the full
`(B, m+2, n+2m+1)` tableau until no LP is still in phase 1.  A one-shot
`compact_tableau` then drops the `m` artificial columns and the phase-1
objective row, and loop 2 finishes phase 2 on the `(B, m+1, n+m+1)`
tableau.  Dropping columns that can never enter and a row that is never
priced changes no pivot decision, so the pivot sequence — and therefore
statuses, iteration counts, x and objective — is identical to the
single-loop solver whenever the ``max_iters`` safety cap does not bind.
The two loops share one ``max_iters`` budget; when the cap *does* bind,
which LPs report ITERATION_LIMIT can differ from the single-loop schedule
(the cap is a runaway guard, not a semantic).  ``phase_compaction=False``
keeps the paper-faithful single loop for A/B benchmarks.

Level 2 — recovering per-block exit for dead LPs — is
`core/compaction.py`: the solve runs in segments of K pivots and survivors
are gathered into power-of-two buckets, so terminated LPs stop occupying
device lanes.

All LPs in the batch share one static tableau shape per loop (see
core/lp.py), so each loop is a single XLA computation: no host round-trips,
no dynamic shapes, shardable over any mesh axis with pjit/shard_map.
"""
from __future__ import annotations

import functools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.report import report_from_counters
from ..obs.telemetry import init_telemetry, tel_simplex_update, tel_to_numpy
from .forms import ensure_canonical, finish_result, prepare_warm
from .lp import (
    BIG,
    INFEASIBLE,
    ITERATION_LIMIT,
    OPTIMAL,
    UNBOUNDED,
    LPBatch,
    LPResult,
    WarmStart,
    canonicalize_backend,
    default_max_iters,
    resolve_backend,
)
from .pricing import (
    canonicalize_rule,
    compact_weights,
    init_weights,
    select_entering,
    update_weights,
)

_RUNNING = -1


class SimplexState(NamedTuple):
    T: jax.Array        # (B, rows, C) tableaux (full or phase-compacted)
    basis: jax.Array    # (B, m) int32
    phase: jax.Array    # (B,) int32 — 1 or 2
    status: jax.Array   # (B,) int32 — _RUNNING until terminal
    iters: jax.Array    # (B,) int32
    w: jax.Array        # (B, C) pricing weights (see core/pricing.py;
                        #  carried-but-unread under the dantzig rule)
    flip: jax.Array     # (B, n) bool — structural column stored complemented
                        #  (x' = ub - x); all-False when ub is all +inf
    ub: jax.Array       # (B, n) upper bounds (+inf = unbounded); structural
                        #  columns only, so column compaction never slices it
    it: jax.Array       # () int32 loop-local iteration counter
    tel: Any = None     # obs.TelemetryState counter lanes, or None (the
                        #  default) — None is an empty pytree subtree, so
                        #  the telemetry-off trace is identical to a state
                        #  without the field


class _StepConsts(NamedTuple):
    col_ok: np.ndarray    # (C,) bool — columns allowed to enter
    rows_iota: np.ndarray  # (rows,) int32 — for the pivot-row replacement
    row_m: np.ndarray     # (m,) int32 — for the basis update
    col_n: np.ndarray     # (n,) int32 — for the flip-flag scatter


@functools.lru_cache(maxsize=None)
def _step_consts(rows: int, m: int, n: int, C: int) -> _StepConsts:
    """Loop-invariant masks/iotas, built once per tableau geometry as NumPy
    constants so they are embedded in the jaxpr rather than recomputed by
    every pivot (hoisted out of `simplex_step`)."""
    return _StepConsts(
        col_ok=np.arange(C) < n + m,  # artificials + rhs never enter
        rows_iota=np.arange(rows, dtype=np.int32),
        row_m=np.arange(m, dtype=np.int32),
        col_n=np.arange(n, dtype=np.int32),
    )


def tableau_elements(m: int, n: int, compacted: bool = False) -> int:
    """Logical tableau elements touched by one pivot's rank-1 update —
    the unit of the executed-work model in analysis/lp_perf.py and
    benchmarks/pivot_work.py."""
    if compacted:
        return (m + 1) * (n + m + 1)
    return (m + 2) * (n + 2 * m + 1)


def build_tableau_jax(A: jax.Array, b: jax.Array, c: jax.Array):
    """JAX version of core.lp.build_tableau (same layout, any float dtype)."""
    B, m, n = A.shape
    dtype = A.dtype
    cols = n + 2 * m + 1
    neg = b < 0
    sign = jnp.where(neg, -1.0, 1.0).astype(dtype)

    T = jnp.zeros((B, m + 2, cols), dtype=dtype)
    T = T.at[:, :m, :n].set(A * sign[:, :, None])
    idx = jnp.arange(m)
    T = T.at[:, idx, n + idx].set(sign)
    T = T.at[:, idx, n + m + idx].set(jnp.where(neg, 1.0, 0.0).astype(dtype))
    T = T.at[:, :m, -1].set(b * sign)
    T = T.at[:, m, :n].set(c)
    p1 = (T[:, :m, :] * neg[:, :, None].astype(dtype)).sum(axis=1)
    p1 = p1.at[:, n + m:n + 2 * m].set(0.0)
    T = T.at[:, m + 1, :].set(p1)

    basis = jnp.where(neg, n + m + idx[None, :], n + idx[None, :]).astype(jnp.int32)
    phase = jnp.where(neg.any(axis=1), 1, 2).astype(jnp.int32)
    return T, basis, phase


def _gauss_solve(Bmat, rhs):
    """Batched ``B^-1 @ rhs`` via Gauss-Jordan with partial pivoting, built
    from the same per-LP elementwise/rank-1 ops as the pivot loop itself.

    ``jnp.linalg.solve`` in f32 returns *batch-size-dependent* results on
    some backends (different compilations reduce in different orders), which
    would make a chunked warm solve drift from an unchunked one; this
    routine's arithmetic is per-LP and batch-shape-invariant, keeping warm
    injection — like the cold pivot sequence — identical across chunkings.
    A singular matrix divides by ~0 and yields non-finite rows, which is
    exactly the callers' cold-fallback signal (mirroring linalg.solve's
    non-raising contract on singular batches)."""
    B, m, _ = Bmat.shape
    aug = jnp.concatenate([Bmat, rhs], axis=2)
    rows_iota = jnp.arange(m)

    def body(k, aug):
        cand = jnp.where(rows_iota[None, :] >= k,
                         jnp.abs(aug[:, :, k]), -jnp.inf)
        p = jnp.argmax(cand, axis=1)
        swap = jnp.where(rows_iota[None, :] == k, p[:, None],
                         jnp.where(rows_iota[None, :] == p[:, None], k,
                                   rows_iota[None, :]))
        aug = jnp.take_along_axis(aug, swap[:, :, None], axis=1)
        pivrow = aug[:, k, :] / aug[:, k, k][:, None]
        aug = aug - aug[:, :, k][:, :, None] * pivrow[:, None, :]
        return aug.at[:, k, :].set(pivrow)

    aug = jax.lax.fori_loop(0, m, body, aug)
    return aug[:, :, m:]


def inject_tableau_warm(A, b, c, ub, wb, wfl, *, m: int, n: int,
                        feas_tol: float):
    """Rebuild the two-phase tableau batch from a parent basis (warm start).

    ``wb`` (B, m) int32 is the parent basis, ``wfl`` (B, n) bool the parent
    nonbasic-at-upper flips.  Per LP, independently:

    * **skip** — the parent basis is still primal-feasible on the perturbed
      data: the tableau starts in phase 2 with no artificials;
    * **repair** — some basic values went negative: only those rows get an
      artificial (the new artificial's physical column is ``-B e_i``, so
      row-negating the computed tableau rows makes it basic at ``+|x_B_i|``)
      and a phase-1 objective summing exactly the violated rows drives them
      out through the ordinary pivot machinery — a repair phase 1 seeded
      from the parent basis instead of the all-artificial cold start;
    * **cold** — the basis is unusable (out-of-range indices, a singular
      basis matrix after the artificial->slack remap, non-finite solve):
      the ``ok`` flag is False and the caller swaps in the cold tableau.

    Parent artificials (degenerate, value 0, possible after equality-pair
    canonicalization) are remapped to the same row's slack: the swap flips
    at most a column sign, so the basis stays nonsingular, and a duplicate
    slack shows up as a singular solve -> cold fallback.  Flips on columns
    whose new ``ub`` went infinite are cleared (the complement no longer
    exists).  Returns ``(T, basis, phase, flip, ok)``.
    """
    B = A.shape[0]
    dtype = A.dtype
    idx = jnp.arange(m)
    in_range = ((wb >= 0) & (wb < n + 2 * m)).all(axis=1)
    wb2 = jnp.clip(jnp.where(wb >= n + m, wb - m, wb), 0, n + m - 1)
    wb2 = wb2.astype(jnp.int32)
    wfl = wfl & jnp.isfinite(ub)
    ubz = jnp.where(wfl, ub, 0.0).astype(dtype)
    # complement flipped structurals: x_j = ub_j - x'_j
    Af = jnp.where(wfl[:, None, :], -A, A)
    bf = b - jnp.einsum("bmn,bn->bm", A, ubz)
    cf = jnp.where(wfl, -c, c)
    obj_off = jnp.sum(c * ubz, axis=1)

    eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), (B, m, m))
    Acols = jnp.concatenate([Af, eye], axis=2)                 # (B, m, n+m)
    Bmat = jnp.take_along_axis(Acols, wb2[:, None, :], axis=2)
    body = _gauss_solve(Bmat, jnp.concatenate(
        [Acols, bf[:, :, None]], axis=2))                      # B^-1 [A | b]
    xB = body[:, :, -1]
    eps = feas_tol * jnp.maximum(1.0, jnp.max(jnp.abs(bf), axis=1))
    viol = xB < -eps[:, None]
    D = jnp.where(viol, -1.0, 1.0).astype(dtype)
    rows = D[:, :, None] * body          # violated rows negated: rhs >= 0
    cext = jnp.concatenate([cf, jnp.zeros((B, m), dtype)], axis=1)
    cB = jnp.where(viol, 0.0, jnp.take_along_axis(cext, wb2, axis=1))
    red = cext - jnp.einsum("bi,bij->bj", cB, rows[:, :, :n + m])

    T = jnp.zeros((B, m + 2, n + 2 * m + 1), dtype)
    T = T.at[:, :m, :n + m].set(rows[:, :, :n + m])
    T = T.at[:, idx, n + m + idx].set(jnp.where(viol, 1.0, 0.0).astype(dtype))
    T = T.at[:, :m, -1].set(rows[:, :, -1])
    T = T.at[:, m, :n + m].set(red)
    # row-m rhs: -(objective of the warm basic solution), offset included,
    # so -T[m, -1] stays the true unflipped objective through every pivot
    T = T.at[:, m, -1].set(-(jnp.sum(cB * rows[:, :, -1], axis=1) + obj_off))
    p1 = (rows * viol[:, :, None].astype(dtype)).sum(axis=1)   # (B, n+m+1)
    T = T.at[:, m + 1, :n + m].set(p1[:, :n + m])
    T = T.at[:, m + 1, -1].set(p1[:, -1])

    basis = jnp.where(viol, n + m + idx[None, :], wb2).astype(jnp.int32)
    phase = jnp.where(viol.any(axis=1), 1, 2).astype(jnp.int32)
    ok = in_range & jnp.isfinite(T).all(axis=(1, 2))
    return T, basis, phase, wfl & ok[:, None], ok


def _pivot_update(T, w, basis, factor, pivrow_raw, pe, e, l, do_pivot,
                  rows_iota, *, m, n, rule):
    """Rank-1 pivot update shared by both steps: subtract the entering-column
    outer product everywhere, then *replace* the pivot row with the scaled row
    (matching the NumPy oracle exactly, instead of the subtract-then-add-back
    formulation which re-rounds the pivot row).

    The pricing-weight recurrence (core/pricing.py) is fused here: it reads
    the freshly updated tableau / scaled pivot row while they are live, so
    steepest-edge's exact gamma recompute and devex's O(C) update add no
    extra pass over state.  Under ``rule == "dantzig"`` the weights pass
    through untouched and the whole computation DCEs away."""
    pe_safe = jnp.where(do_pivot, pe, 1.0)
    pivrow = pivrow_raw / pe_safe[:, None]
    T_new = T - factor[:, :, None] * pivrow[:, None, :]
    is_l = rows_iota[None, :, None] == l[:, None, None]
    T_new = jnp.where(is_l, pivrow[:, None, :], T_new)
    T_out = jnp.where(do_pivot[:, None, None], T_new, T)
    # leaving variable's column (basis *before* its own update) for devex
    r = jnp.take_along_axis(basis, l[:, None], axis=1)[:, 0]
    w = update_weights(rule, w, T_out, pivrow, pe_safe, e, r, do_pivot,
                       m=m, n=n)
    return T_out, w


def _bounded_ratios(ratios, col, rhs, basis, ub, *, n, tol):
    """Case (b) of the bounded-variable ratio test: a basic variable the
    entering column drives *up* (col < 0) may hit its own finite upper
    bound at ``(ub_B - rhs) / (-col)`` — slacks/artificials (basis >= n)
    have ub = +inf, so with all-+inf bounds this is the identity."""
    ubB = jnp.where(basis < n,
                    jnp.take_along_axis(ub, jnp.minimum(basis, n - 1), axis=1),
                    jnp.inf)
    hit = (col < -tol) & jnp.isfinite(ubB)
    return jnp.where(hit, (ubB - rhs) / jnp.where(hit, -col, 1.0), ratios)


def _bound_moves(T, flip, ub, basis, factor, pivrow_raw, pe, e, l,
                 wants_pivot, no_row, min_ratio, consts, *, n):
    """Resolve the two bounded-variable moves of one lockstep step.

    * **Entering-bound flip** (``ub_e < min_ratio``): the entering variable
      hits its own upper bound before any basic variable binds.  Complement
      it in place — ``rhs -= ub_e * col`` on every row (objective rows
      included, which keeps ``-T[m, -1]`` the true objective) and negate
      the column — no pivot, no weight update (column negation is
      norm-invariant for the d^2/w pricing scores).
    * **Leaving-at-upper complement**: the min ratio came from a basic
      variable hitting *its* bound (negative pivot element on a structural
      basic).  Its tableau column is a unit vector, so complementing it
      reduces to rewriting the pivot row: negate it, ``rhs_l -> ub_l -
      rhs_l``, restore the +1 basic entry — the pivot element turns
      positive and the rank-1 update proceeds classically.

    Returns ``(T, flip, pivrow_raw, pe, do_flip, do_pivot)``; with all-+inf
    ``ub`` both masks are all-False and every write is a masked identity.
    """
    B = T.shape[0]
    dtype = T.dtype
    ub_e = jnp.where(e < n,
                     jnp.take_along_axis(ub, jnp.minimum(e, n - 1)[:, None],
                                         axis=1)[:, 0],
                     jnp.inf).astype(dtype)
    do_flip = wants_pivot & (ub_e < min_ratio)
    do_pivot = wants_pivot & ~no_row & ~do_flip

    bidx = jnp.arange(B)
    is_e = consts.col_n[None, :] == e[:, None]           # (B, n)
    ub_e_term = jnp.where(do_flip, ub_e, 0.0).astype(dtype)
    T = T.at[:, :, -1].add(-ub_e_term[:, None] * factor)
    sign_e = jnp.where(do_flip, -1.0, 1.0).astype(dtype)
    T = T.at[bidx[:, None], consts.rows_iota[None, :], e[:, None]].multiply(
        sign_e[:, None])
    flip = flip ^ (do_flip[:, None] & is_e)

    jl = jnp.take_along_axis(basis, l[:, None], axis=1)[:, 0]
    need_comp = do_pivot & (pe < 0) & (jl < n)
    ub_jl = jnp.take_along_axis(ub, jnp.minimum(jl, n - 1)[:, None],
                                axis=1)[:, 0].astype(dtype)
    is_jl_full = (jnp.arange(T.shape[2], dtype=jnp.int32)[None, :]
                  == jl[:, None])                        # (B, C)
    comp_row = -pivrow_raw
    comp_row = comp_row.at[:, -1].add(jnp.where(need_comp, ub_jl, 0.0))
    comp_row = jnp.where(is_jl_full, 1.0, comp_row)
    pivrow_raw = jnp.where(need_comp[:, None], comp_row, pivrow_raw)
    pe = jnp.where(need_comp, -pe, pe)
    flip = flip ^ (need_comp[:, None] & is_jl_full[:, :n])
    return T, flip, pivrow_raw, pe, do_flip, do_pivot


def simplex_step(state: SimplexState, *, n: int, m: int, tol: float,
                 feas_thr, rule: str = "dantzig") -> SimplexState:
    """One lockstep pivot across the whole batch (masked for inactive LPs),
    on the **full** (B, m+2, n+2m+1) tableau.

    Implements Steps 1-3 of the paper's Sec. 4.1 with the Sec. 5.2 sentinel
    trick, as dense batched tensor ops.  Per-LP column/row extraction uses
    `take_along_axis` gathers (one element per batch row) instead of one-hot
    einsums; loop-invariant masks come pre-built from `_step_consts`.
    Step 1 delegates to the pricing engine (``rule``, static): dantzig keeps
    the paper's argmax bit-for-bit; steepest_edge/devex score candidates by
    d_j^2 / weight using the weights carried in ``state.w``.
    """
    T, basis, phase, status, iters, w, flip, ub, it = state[:9]
    tel = state.tel
    in_p1 = phase == 1  # pre-update phase, for telemetry attribution
    B, rows, C = T.shape
    consts = _step_consts(rows, m, n, C)
    active = status == _RUNNING

    # ---- Step 1: entering variable (pivot column) --------------------------
    cost = jnp.where((phase == 1)[:, None], T[:, m + 1, :], T[:, m, :])
    masked_cost = jnp.where(consts.col_ok[None, :], cost, -BIG)
    e, max_cost = select_entering(masked_cost, w, rule=rule, tol=tol,
                                  iters=iters, ncand=n + m)
    is_opt = max_cost <= tol

    # phase bookkeeping at optimality of the current objective row
    p1_obj = T[:, m + 1, -1]
    p1_done = active & (phase == 1) & is_opt
    infeasible = p1_done & (p1_obj > feas_thr)
    to_phase2 = p1_done & ~infeasible
    p2_done = active & (phase == 2) & is_opt

    # ---- Step 2: leaving variable (pivot row), sentinel min-ratio ----------
    factor = jnp.take_along_axis(T, e[:, None, None], axis=2)[:, :, 0]  # (B, rows)
    col = factor[:, :m]
    rhs = T[:, :m, -1]
    valid = col > tol
    ratios = jnp.where(valid, rhs / jnp.where(valid, col, 1.0), BIG)
    ratios = _bounded_ratios(ratios, col, rhs, basis, ub, n=n, tol=tol)
    # Phase 2 pins basic artificials at zero: an entering column that would
    # grow one (negative coefficient in its row) kicks it out at ratio 0
    # instead (negative pivot element, legal at zero rhs).  Degenerate
    # artificials left basic by phase 1 — routine under the equality pairs
    # core/forms.py emits — would otherwise silently re-relax their row.
    # An artificial phase 1 accepted at a small positive value (<= feas_thr)
    # makes this pivot set the entering variable to -rhs/|pivot| — a
    # bounded x>=0 violation of the same order as the feasibility debt
    # already accepted, vs. the unbounded row relaxation pinning prevents.
    pin = (phase == 2)[:, None] & (basis >= n + m) & (col < -tol)
    ratios = jnp.where(pin, 0.0, ratios)
    l = jnp.argmin(ratios, axis=1)
    min_ratio = jnp.min(ratios, axis=1)
    no_row = min_ratio >= BIG / 2

    wants_pivot = active & ~is_opt

    # ---- Step 3: bound moves + rank-1 pivot update (+ fused weights) -------
    pivrow_raw = jnp.take_along_axis(T, l[:, None, None], axis=1)[:, 0, :]
    pe = jnp.take_along_axis(col, l[:, None], axis=1)[:, 0]
    T, flip, pivrow_raw, pe, do_flip, do_pivot = _bound_moves(
        T, flip, ub, basis, factor, pivrow_raw, pe, e, l,
        wants_pivot, no_row, min_ratio, consts, n=n)
    unbounded = wants_pivot & no_row & ~do_flip & (phase == 2)
    stuck = wants_pivot & no_row & ~do_flip & (phase == 1)  # numerically impossible path
    T, w = _pivot_update(T, w, basis, factor, pivrow_raw, pe, e, l, do_pivot,
                         consts.rows_iota, m=m, n=n, rule=rule)
    basis = jnp.where(do_pivot[:, None] & (consts.row_m[None, :] == l[:, None]),
                      e[:, None].astype(jnp.int32), basis)

    status = jnp.where(infeasible, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(stuck, ITERATION_LIMIT, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    phase = jnp.where(to_phase2, 2, phase)
    inc = active & ~p2_done & ~infeasible
    iters = iters + inc.astype(jnp.int32)
    if tel is not None:
        tel = tel_simplex_update(tel, inc=inc, in_phase1=in_p1,
                                 do_pivot=do_pivot, do_flip=do_flip,
                                 degenerate=min_ratio <= 0.0)
    return SimplexState(T, basis, phase, status, iters, w, flip, ub, it + 1,
                        tel)


def phase2_step(state: SimplexState, *, n: int, m: int, tol: float,
                rule: str = "dantzig") -> SimplexState:
    """One lockstep phase-2 pivot on the **compacted** (B, m+1, n+m+1)
    tableau (artificial columns and the phase-1 objective row removed).

    Artificials can never enter (they were masked out of Step 1 already) and
    the phase-1 row is never priced in phase 2, so this performs exactly the
    pivots `simplex_step` would — at (m+1)(n+m+1)/((m+2)(n+2m+1)) of the
    per-pivot FLOPs/bytes.  ``rule`` selects the pricing engine exactly as in
    `simplex_step`; ``state.w`` is the phase-compacted weight vector."""
    T, basis, phase, status, iters, w, flip, ub, it = state[:9]
    tel = state.tel
    B, rows, C = T.shape          # rows == m + 1, C == n + m + 1
    consts = _step_consts(rows, m, n, C)
    active = (status == _RUNNING) & (phase == 2)

    cost = T[:, m, :]
    masked_cost = jnp.where(consts.col_ok[None, :], cost, -BIG)
    e, max_cost = select_entering(masked_cost, w, rule=rule, tol=tol,
                                  iters=iters, ncand=n + m)
    is_opt = max_cost <= tol
    p2_done = active & is_opt

    factor = jnp.take_along_axis(T, e[:, None, None], axis=2)[:, :, 0]
    col = factor[:, :m]
    rhs = T[:, :m, -1]
    valid = col > tol
    ratios = jnp.where(valid, rhs / jnp.where(valid, col, 1.0), BIG)
    ratios = _bounded_ratios(ratios, col, rhs, basis, ub, n=n, tol=tol)
    # basic artificials stay pinned at zero (see simplex_step); the basis
    # still indexes full-tableau columns, so >= n+m identifies them here too
    pin = (basis >= n + m) & (col < -tol)
    ratios = jnp.where(pin, 0.0, ratios)
    l = jnp.argmin(ratios, axis=1)
    min_ratio = jnp.min(ratios, axis=1)
    no_row = min_ratio >= BIG / 2

    wants_pivot = active & ~is_opt

    pivrow_raw = jnp.take_along_axis(T, l[:, None, None], axis=1)[:, 0, :]
    pe = jnp.take_along_axis(col, l[:, None], axis=1)[:, 0]
    T, flip, pivrow_raw, pe, do_flip, do_pivot = _bound_moves(
        T, flip, ub, basis, factor, pivrow_raw, pe, e, l,
        wants_pivot, no_row, min_ratio, consts, n=n)
    unbounded = wants_pivot & no_row & ~do_flip
    T, w = _pivot_update(T, w, basis, factor, pivrow_raw, pe, e, l, do_pivot,
                         consts.rows_iota, m=m, n=n, rule=rule)
    basis = jnp.where(do_pivot[:, None] & (consts.row_m[None, :] == l[:, None]),
                      e[:, None].astype(jnp.int32), basis)

    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    inc = active & ~p2_done
    iters = iters + inc.astype(jnp.int32)
    if tel is not None:
        # active implies phase == 2 here, so everything lands in the
        # phase-2 lanes regardless of the stale phase entries
        tel = tel_simplex_update(tel, inc=inc, in_phase1=phase == 1,
                                 do_pivot=do_pivot, do_flip=do_flip,
                                 degenerate=min_ratio <= 0.0)
    return SimplexState(T, basis, phase, status, iters, w, flip, ub, it + 1,
                        tel)


def compact_tableau(T: jax.Array, *, m: int, n: int) -> jax.Array:
    """One-shot phase compaction: drop the m artificial columns and the
    phase-1 objective row: (B, m+2, n+2m+1) -> (B, m+1, n+m+1).

    Basis entries that still point at a (degenerate, value-0) artificial stay
    as-is: they are >= n, so solution extraction ignores them, and removing
    the column just pins that artificial to zero — which is exactly the
    feasibility phase 1 certified."""
    return jnp.concatenate([T[:, :m + 1, :n + m], T[:, :m + 1, -1:]], axis=2)


def scatter_solution(rhs: jax.Array, basis: jax.Array, n: int) -> jax.Array:
    """x[b, basis[b, i]] = rhs[b, i] for structural basis entries (basis < n),
    as a batched scatter-add (replaces the old one-hot einsum: no (B, m, n)
    intermediate)."""
    B = rhs.shape[0]
    contrib = jnp.where(basis < n, rhs, 0.0)
    safe = jnp.clip(basis, 0, n - 1)
    x = jnp.zeros((B, n), rhs.dtype)
    return x.at[jnp.arange(B)[:, None], safe].add(contrib)


def _unflip_solution(x, flip, ub):
    """Map complemented coordinates back: x = ub - x' on flipped columns
    (covers both flipped basics — ub - rhs — and flipped nonbasics at 0,
    which sit at their upper bound)."""
    if flip is None:
        return x
    return jnp.where(flip, ub.astype(x.dtype) - x, x)


def extract_solution_jax(T: jax.Array, basis: jax.Array, n: int,
                         flip=None, ub=None):
    """Read (x, objective) off **full** (rows = m+2) tableaux."""
    m = T.shape[1] - 2
    x = scatter_solution(T[:, :m, -1], basis[:, :m], n)
    x = _unflip_solution(x, flip, ub)
    objective = -T[:, m, -1]
    return x, objective


def extract_solution_compacted(T: jax.Array, basis: jax.Array, n: int,
                               flip=None, ub=None):
    """Read (x, objective) off **phase-compacted** (rows = m+1) tableaux."""
    m = T.shape[1] - 1
    x = scatter_solution(T[:, :m, -1], basis[:, :m], n)
    x = _unflip_solution(x, flip, ub)
    objective = -T[:, m, -1]
    return x, objective


def extract_duals(T: jax.Array, *, m: int, n: int, flip=None):
    """Dual certificate off a final tableau (full or phase-compacted — both
    keep structural columns 0..n-1 and slack columns n..n+m-1 in row m).

    The phase-2 objective row holds the reduced costs ``c - y.A``; the
    slack column j = n+i has original cost 0 and (sign-adjusted) column
    ``sign_i e_i``, so its entry is ``-y_i`` irrespective of the row's
    phase-1 sign flip: ``y = c_B B^-1`` falls out of the tableau for free.
    Flipped structural columns are stored complemented, so their entry is
    ``-z_j``; ``flip`` undoes the sign.  Returns (y, z) with y (B, m) the
    canonical row duals (>= 0 at optimality) and z (B, n) the structural
    reduced costs (<= 0 at lower bound, >= 0 at upper bound)."""
    y = -T[:, m, n:n + m]
    z = T[:, m, :n]
    if flip is not None:
        z = jnp.where(flip, -z, z)
    return y, z


def _mask_duals(y, z, status):
    """Duals are a certificate of optimality only: NaN elsewhere."""
    opt = (status == OPTIMAL)[:, None]
    return jnp.where(opt, y, jnp.nan), jnp.where(opt, z, jnp.nan)


def solve_two_phase(A, b, c, ub=None, *, m: int, n: int, max_iters: int,
                    tol: float, feas_tol: float, phase_compaction: bool = True,
                    pricing: str = "dantzig",
                    warm_basis=None, warm_at_upper=None, warm_weights=None,
                    full_state: bool = False, telemetry: bool = False):
    """Traceable two-phase solve body, shared by jit (`_solve_core`), pjit and
    shard_map (core/distributed.py).

    phase_compaction=True (default): loop 1 on the full tableau until no LP
    is still in phase 1, then `compact_tableau`, then loop 2 on the small
    tableau.  The two loops share one `max_iters` budget (loop 2 resumes the
    step counter where loop 1 stopped).
    phase_compaction=False: the paper-faithful single lockstep loop (the seed
    behavior), kept as the A/B baseline for benchmarks/pivot_work.py.
    ``pricing`` selects the entering-column rule (core/pricing.py); weights
    are initialized here and phase-compacted alongside the tableau.

    ``warm_basis``/``warm_at_upper`` ((B, m) int32 / (B, n) bool) seed the
    solve from a parent basis via `inject_tableau_warm`; each LP falls back
    to the cold tableau independently when its parent basis is unusable.
    ``warm_weights`` (any width >= n+m) overlays carried devex weights.
    ``full_state=True`` appends ``(basis, flip, w)`` to the return tuple so
    batched entry points can capture a ``WarmStart``.
    ``telemetry=True`` (static) seeds an ``obs.TelemetryState`` into the
    loop carry and appends it to the return tuple; with the default False
    the carry holds ``tel=None`` — an empty pytree subtree — so the traced
    program is unchanged.
    """
    rule = canonicalize_rule(pricing)
    B = A.shape[0]
    dtype = A.dtype
    if ub is None:
        ub = jnp.full((B, n), jnp.inf, dtype=dtype)
    else:
        ub = jnp.asarray(ub, dtype=dtype)
    T, basis, phase = build_tableau_jax(A, b, c)
    flip = jnp.zeros((B, n), dtype=bool)
    if warm_basis is not None:
        wfl = (jnp.zeros((B, n), bool) if warm_at_upper is None
               else jnp.asarray(warm_at_upper, bool))
        T_w, basis_w, phase_w, flip_w, ok = inject_tableau_warm(
            A, b, c, ub, jnp.asarray(warm_basis, jnp.int32), wfl,
            m=m, n=n, feas_tol=feas_tol)
        T = jnp.where(ok[:, None, None], T_w, T)
        basis = jnp.where(ok[:, None], basis_w, basis)
        phase = jnp.where(ok, phase_w, phase)
        flip = jnp.where(ok[:, None], flip_w, flip)
    # Phase-1 feasibility threshold is *relative* to the initial infeasibility
    # mass (f32 tableaux accumulate O(scale * eps) error through pivots).
    feas_thr = feas_tol * jnp.maximum(1.0, T[:, m + 1, -1])
    w = init_weights(rule, T, m)
    if warm_basis is not None and warm_weights is not None:
        ww = jnp.asarray(warm_weights, w.dtype)
        w = w.at[:, :n + m].set(
            jnp.where(ok[:, None], ww[:, :n + m], w[:, :n + m]))
    state = SimplexState(
        T=T, basis=basis, phase=phase,
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32),
        w=w,
        flip=flip,
        ub=ub,
        it=jnp.array(0, jnp.int32),
        tel=init_telemetry(B) if telemetry else None,
    )

    def body1(s: SimplexState):
        return simplex_step(s, n=n, m=m, tol=tol, feas_thr=feas_thr,
                            rule=rule)

    if not phase_compaction:
        def cond(s: SimplexState):
            return jnp.any(s.status == _RUNNING) & (s.it < max_iters)

        state = jax.lax.while_loop(cond, body1, state)
        status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT, state.status)
        x, obj = extract_solution_jax(state.T, state.basis, n,
                                      flip=state.flip, ub=state.ub)
        y, z = extract_duals(state.T, m=m, n=n, flip=state.flip)
    else:
        # ---- loop 1: full tableau, until every LP has left phase 1 ---------
        def cond1(s: SimplexState):
            pending = (s.status == _RUNNING) & (s.phase == 1)
            return jnp.any(pending) & (s.it < max_iters)

        state = jax.lax.while_loop(cond1, body1, state)
        status = jnp.where((state.status == _RUNNING) & (state.phase == 1),
                           ITERATION_LIMIT, state.status)

        # ---- one-shot compaction + loop 2 on the small tableau -------------
        # (loop 2 inherits the step counter: one shared max_iters budget)
        state = SimplexState(
            T=compact_tableau(state.T, m=m, n=n), basis=state.basis,
            phase=state.phase, status=status, iters=state.iters,
            w=compact_weights(state.w, m=m, n=n),
            flip=state.flip, ub=state.ub,
            it=state.it, tel=state.tel)

        def cond2(s: SimplexState):
            return jnp.any(s.status == _RUNNING) & (s.it < max_iters)

        def body2(s: SimplexState):
            return phase2_step(s, n=n, m=m, tol=tol, rule=rule)

        state = jax.lax.while_loop(cond2, body2, state)
        status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT, state.status)
        x, obj = extract_solution_compacted(state.T, state.basis, n,
                                            flip=state.flip, ub=state.ub)
        y, z = extract_duals(state.T, m=m, n=n, flip=state.flip)

    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    y, z = _mask_duals(y, z, status)
    out = (x, obj, status.astype(jnp.int8), state.iters, y, z)
    if full_state:
        out = out + (state.basis, state.flip, state.w)
    if telemetry:
        out = out + (state.tel,)
    return out


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "feas_tol", "phase_compaction",
                                             "pricing", "telemetry"))
def _solve_core(A, b, c, ub, *, m: int, n: int, max_iters: int, tol: float,
                feas_tol: float, phase_compaction: bool = True,
                pricing: str = "dantzig", telemetry: bool = False):
    return solve_two_phase(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                           feas_tol=feas_tol, phase_compaction=phase_compaction,
                           pricing=pricing, telemetry=telemetry)


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "feas_tol", "phase_compaction",
                                             "pricing", "telemetry"))
def _solve_core_state(A, b, c, ub, warm_basis, warm_at_upper, warm_weights,
                      *, m: int, n: int, max_iters: int, tol: float,
                      feas_tol: float, phase_compaction: bool = True,
                      pricing: str = "dantzig", telemetry: bool = False):
    """`_solve_core` + warm injection + terminal-state capture (the batched
    entry point's core; warm args may be None for a cold capture-only run)."""
    return solve_two_phase(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                           feas_tol=feas_tol, phase_compaction=phase_compaction,
                           pricing=pricing, warm_basis=warm_basis,
                           warm_at_upper=warm_at_upper,
                           warm_weights=warm_weights, full_state=True,
                           telemetry=telemetry)


def solve_batched_jax(batch: LPBatch, *, dtype=jnp.float32, tol: float | None = None,
                      feas_tol: float | None = None, max_iters: int | None = None,
                      phase_compaction: bool = True,
                      pricing: str = "dantzig",
                      backend: str = "tableau",
                      refactor_period: int | None = None,
                      presolve: bool = True,
                      scale: bool | None = None,
                      warm: WarmStart | None = None,
                      telemetry: bool = False) -> LPResult:
    """Solve a batch of LPs with the lockstep pure-JAX simplex.

    Phase-compacted by default (identical pivot sequence, ~35-50% fewer
    tableau elements per phase-2 pivot); ``phase_compaction=False`` restores
    the paper-faithful single-loop solver.  For per-shard termination across
    a mesh use core.distributed.solve_shard_map; for active-set compaction
    (retiring finished LPs mid-solve) use core.compaction.
    ``pricing`` selects the entering-column rule — "dantzig" (paper default),
    "steepest_edge", "devex" or "partial" (core/pricing.py); better rules
    trade a cheaper pivot *count* against a slightly costlier pivot.
    ``backend`` selects the solver engine: "tableau" (this module — dense
    tableaux, rank-1 pivot updates) or "revised" (core/revised.py — immutable
    constraint data, basis-factor updates, O(m^2)+pricing per pivot;
    ``refactor_period`` bounds its eta file, ``phase_compaction`` does not
    apply).  Statuses agree across backends; pivot paths may differ in f32.

    A ``GeneralLPBatch`` (core/forms.py) is accepted directly: it is
    canonicalized on ingestion (``presolve``/``scale`` control the presolve
    pass and geometric-mean equilibration) and the result is recovered into
    original coordinates.

    ``warm`` re-injects a previous solve's ``LPResult.warm_start()`` carrier
    (validated/re-scaled by forms.prepare_warm; per-LP skip/repair/cold, see
    `inject_tableau_warm`); the returned result always carries a fresh
    ``warm`` capture for the next solve in the sequence.
    """
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    if canonicalize_backend(backend) != "tableau":
        # registry dispatch (core/lp.py BACKEND_REGISTRY): the engine
        # modules own their extra kwargs; only the revised engine takes a
        # refactor_period
        solver = resolve_backend(backend)
        kwargs = dict(dtype=dtype, tol=tol, feas_tol=feas_tol,
                      max_iters=max_iters, pricing=pricing, warm=warm,
                      telemetry=telemetry)
        if backend == "revised":
            kwargs["refactor_period"] = refactor_period
        return finish_result(rec, solver(batch, **kwargs))
    warm = prepare_warm(warm, rec, batch)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if feas_tol is None:
        feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
    A = jnp.asarray(batch.A, dtype=dtype)
    b = jnp.asarray(batch.b, dtype=dtype)
    c = jnp.asarray(batch.c, dtype=dtype)
    ub = jnp.asarray(batch.upper_bounds(), dtype=dtype)
    rule = canonicalize_rule(pricing)
    wb = wfl = ww = None
    if warm is not None and warm.basis is not None:
        wb = jnp.asarray(warm.basis, jnp.int32)
        if warm.at_upper is not None:
            wfl = jnp.asarray(warm.at_upper, bool)
        # carried weights are only meaningful for devex (its reference
        # framework is cross-solve state); steepest edge re-initializes
        # exactly from the warm tableau, dantzig/partial never read them
        if (rule == "devex" and warm.pricing == rule
                and warm.weights is not None
                and np.asarray(warm.weights).shape[1] >= n + m):
            ww = jnp.asarray(warm.weights, dtype)
    t0 = time.perf_counter()
    out = _solve_core_state(
        A, b, c, ub, wb, wfl, ww,
        m=m, n=n, max_iters=int(max_iters), tol=float(tol),
        feas_tol=float(feas_tol), phase_compaction=bool(phase_compaction),
        pricing=rule, telemetry=bool(telemetry))
    x, obj, status, iters, y, z, basis, flip, w = out[:9]
    stats = None
    if telemetry:
        jax.block_until_ready(out[9])
        stats = report_from_counters(tel_to_numpy(out[9]),
                                     wall_s=time.perf_counter() - t0,
                                     backend="tableau")
    capture = WarmStart(m=m, n=n, basis=np.asarray(basis),
                        at_upper=np.asarray(flip), weights=np.asarray(w),
                        pricing=rule)
    res = LPResult(x=np.asarray(x), objective=np.asarray(obj),
                   status=np.asarray(status), iterations=np.asarray(iters),
                   y=np.asarray(y), z=np.asarray(z), warm=capture,
                   stats=stats)
    return finish_result(rec, res)


def flops_per_pivot(m: int, n: int, compacted: bool = False) -> int:
    """Approximate FLOPs of one pivot across one tableau (for Table-5-style
    Gflop/s accounting): rank-1 update dominates: 2*rows*C plus the two
    reductions and the row scale."""
    if compacted:
        rows, C = m + 1, n + m + 1
    else:
        rows, C = m + 2, n + 2 * m + 1
    rank1 = 2 * rows * C
    reductions = 2 * C + 3 * m
    scale = C
    return rank1 + reductions + scale
