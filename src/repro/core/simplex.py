"""Batched two-phase simplex in pure JAX — the paper's solver, TPU-native.

Mapping from the paper's CUDA design (Sec. 5) to this implementation:

* one CUDA block per LP            ->  one batch slot per LP; the whole batch
                                       advances through `lax.while_loop`
* parallel reduction for Step 1/2  ->  `argmax` / `argmin` over the tableau
                                       axes (VPU cross-lane reductions)
* MAX-sentinel for invalid ratios  ->  identical `where(col>eps, b/col, BIG)`
* column-major coalesced layout    ->  dense (B, rows, cols) tiles; every
                                       pivot is a rank-1 update (outer
                                       product) which the TPU executes as
                                       aligned vector ops; the reduction
                                       vectors live on the minor (lane) axis
* per-block early exit             ->  active-mask: converged LPs perform
                                       masked no-ops (see core/distributed.py
                                       for per-shard termination which
                                       restores true early exit)

All LPs in the batch share one static tableau shape (see core/lp.py), so the
entire solve is a single XLA computation: no host round-trips, no dynamic
shapes, shardable over any mesh axis with pjit/shard_map.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .lp import (
    BIG,
    INFEASIBLE,
    ITERATION_LIMIT,
    OPTIMAL,
    UNBOUNDED,
    LPBatch,
    LPResult,
    default_max_iters,
)

_RUNNING = -1


class SimplexState(NamedTuple):
    T: jax.Array        # (B, m+2, C) tableaux
    basis: jax.Array    # (B, m) int32
    phase: jax.Array    # (B,) int32 — 1 or 2
    status: jax.Array   # (B,) int32 — _RUNNING until terminal
    iters: jax.Array    # (B,) int32
    it: jax.Array       # () int32 global iteration counter


def build_tableau_jax(A: jax.Array, b: jax.Array, c: jax.Array):
    """JAX version of core.lp.build_tableau (same layout, any float dtype)."""
    B, m, n = A.shape
    dtype = A.dtype
    cols = n + 2 * m + 1
    neg = b < 0
    sign = jnp.where(neg, -1.0, 1.0).astype(dtype)

    T = jnp.zeros((B, m + 2, cols), dtype=dtype)
    T = T.at[:, :m, :n].set(A * sign[:, :, None])
    idx = jnp.arange(m)
    T = T.at[:, idx, n + idx].set(sign)
    T = T.at[:, idx, n + m + idx].set(jnp.where(neg, 1.0, 0.0).astype(dtype))
    T = T.at[:, :m, -1].set(b * sign)
    T = T.at[:, m, :n].set(c)
    p1 = (T[:, :m, :] * neg[:, :, None].astype(dtype)).sum(axis=1)
    p1 = p1.at[:, n + m:n + 2 * m].set(0.0)
    T = T.at[:, m + 1, :].set(p1)

    basis = jnp.where(neg, n + m + idx[None, :], n + idx[None, :]).astype(jnp.int32)
    phase = jnp.where(neg.any(axis=1), 1, 2).astype(jnp.int32)
    return T, basis, phase


def simplex_step(state: SimplexState, *, n: int, m: int, tol: float,
                 feas_thr) -> SimplexState:
    """One lockstep pivot across the whole batch (masked for inactive LPs).

    Implements Steps 1-3 of the paper's Sec. 4.1 with the Sec. 5.2 sentinel
    trick, as dense batched tensor ops (one-hot einsum extraction instead of
    per-LP dynamic indexing keeps everything gather-free and MXU/VPU dense).
    """
    T, basis, phase, status, iters, it = state
    B, rows, C = T.shape
    dtype = T.dtype
    active = status == _RUNNING

    # ---- Step 1: entering variable (pivot column) --------------------------
    cost = jnp.where((phase == 1)[:, None], T[:, m + 1, :], T[:, m, :])
    col_ok = (jnp.arange(C) < n + m)  # artificials + rhs never enter
    masked_cost = jnp.where(col_ok[None, :], cost, -BIG)
    e = jnp.argmax(masked_cost, axis=1)
    max_cost = jnp.max(masked_cost, axis=1)
    is_opt = max_cost <= tol

    # phase bookkeeping at optimality of the current objective row
    w = T[:, m + 1, -1]
    p1_done = active & (phase == 1) & is_opt
    infeasible = p1_done & (w > feas_thr)
    to_phase2 = p1_done & ~infeasible
    p2_done = active & (phase == 2) & is_opt

    # ---- Step 2: leaving variable (pivot row), sentinel min-ratio ----------
    onehot_e = jax.nn.one_hot(e, C, dtype=dtype)
    col = jnp.einsum("brc,bc->br", T[:, :m, :], onehot_e)
    rhs = T[:, :m, -1]
    valid = col > tol
    ratios = jnp.where(valid, rhs / jnp.where(valid, col, 1.0), BIG)
    l = jnp.argmin(ratios, axis=1)
    min_ratio = jnp.min(ratios, axis=1)
    no_row = min_ratio >= BIG / 2

    wants_pivot = active & ~is_opt
    unbounded = wants_pivot & no_row & (phase == 2)
    stuck = wants_pivot & no_row & (phase == 1)  # numerically impossible path
    do_pivot = wants_pivot & ~no_row

    # ---- Step 3: rank-1 pivot update ---------------------------------------
    onehot_l = jax.nn.one_hot(l, m, dtype=dtype)          # constraint rows
    onehot_l_full = jax.nn.one_hot(l, rows, dtype=dtype)  # incl. objective rows
    pe = jnp.einsum("br,br->b", col, onehot_l)
    pe_safe = jnp.where(do_pivot, pe, 1.0)
    pivrow = jnp.einsum("br,brc->bc", onehot_l, T[:, :m, :]) / pe_safe[:, None]
    factor = jnp.einsum("brc,bc->br", T, onehot_e)        # entering col, all rows
    T_new = T - factor[:, :, None] * pivrow[:, None, :]
    T_new = T_new + onehot_l_full[:, :, None] * pivrow[:, None, :]

    sel = do_pivot[:, None, None]
    T = jnp.where(sel, T_new, T)
    basis = jnp.where(do_pivot[:, None] & (onehot_l > 0.5), e[:, None].astype(jnp.int32), basis)

    status = jnp.where(infeasible, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(stuck, ITERATION_LIMIT, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    phase = jnp.where(to_phase2, 2, phase)
    iters = iters + (active & ~p2_done & ~infeasible).astype(jnp.int32)
    return SimplexState(T, basis, phase, status, iters, it + 1)


def extract_solution_jax(T: jax.Array, basis: jax.Array, n: int):
    m = T.shape[1] - 2
    rhs = T[:, :m, -1]
    onehot = jax.nn.one_hot(basis, n, dtype=T.dtype)  # (B, m, n); 0-row if basis>=n
    x = jnp.einsum("bm,bmn->bn", rhs, onehot)
    objective = -T[:, m, -1]
    return x, objective


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol", "feas_tol"))
def _solve_core(A, b, c, *, m: int, n: int, max_iters: int, tol: float, feas_tol: float):
    T, basis, phase = build_tableau_jax(A, b, c)
    B = T.shape[0]
    # Phase-1 feasibility threshold is *relative* to the initial infeasibility
    # mass (f32 tableaux accumulate O(scale * eps) error through pivots).
    feas_thr = feas_tol * jnp.maximum(1.0, T[:, m + 1, -1])
    state = SimplexState(
        T=T, basis=basis, phase=phase,
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32),
        it=jnp.array(0, jnp.int32),
    )

    def cond(s: SimplexState):
        return jnp.any(s.status == _RUNNING) & (s.it < max_iters)

    def body(s: SimplexState):
        return simplex_step(s, n=n, m=m, tol=tol, feas_thr=feas_thr)

    state = jax.lax.while_loop(cond, body, state)
    status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT, state.status)
    x, obj = extract_solution_jax(state.T, state.basis, n)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    return x, obj, status.astype(jnp.int8), state.iters


def solve_batched_jax(batch: LPBatch, *, dtype=jnp.float32, tol: float | None = None,
                      feas_tol: float | None = None, max_iters: int | None = None) -> LPResult:
    """Solve a batch of LPs with the lockstep pure-JAX simplex.

    This is the paper-faithful batched solver (every LP advances one pivot
    per device step; converged LPs are masked). For per-shard termination
    across a mesh use core.distributed.solve_sharded.
    """
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if feas_tol is None:
        feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
    A = jnp.asarray(batch.A, dtype=dtype)
    b = jnp.asarray(batch.b, dtype=dtype)
    c = jnp.asarray(batch.c, dtype=dtype)
    x, obj, status, iters = _solve_core(
        A, b, c, m=m, n=n, max_iters=int(max_iters), tol=float(tol),
        feas_tol=float(feas_tol))
    return LPResult(x=np.asarray(x), objective=np.asarray(obj),
                    status=np.asarray(status), iterations=np.asarray(iters))


def flops_per_pivot(m: int, n: int) -> int:
    """Approximate FLOPs of one pivot across one tableau (for Table-5-style
    Gflop/s accounting): rank-1 update dominates: 2*(m+2)*C plus the two
    reductions and the row scale."""
    C = n + 2 * m + 1
    rank1 = 2 * (m + 2) * C
    reductions = 2 * C + 3 * m
    scale = C
    return rank1 + reductions + scale
