"""Batched branch-and-bound: MIP trees as frontiers of warm-started LPs.

The paper's thesis is that small LPs only pay off on an accelerator when
solved as large same-shape batches; "Batched First-Order Methods for
Parallel LP Solving in MIP" (Blin et al., PAPERS.md) supplies the killer
workload: a branch-and-bound tree emits thousands of *near-identical* node
relaxations — every node is the root LP with a handful of variable bounds
tightened.  This driver turns that observation into the repo's MIP layer:

* **the frontier is one batch** — open nodes differ from the root only in
  ``lb``/``ub``, so a frontier of B nodes canonicalizes through
  ``forms.rebind_bounds`` (the cheap bound-edit path: the root's canonical
  ``A``/``c``/scales broadcast, only rhs/shift/native-ub recompute) and is
  solved in **one device dispatch** through ``solve_batched``.  PR 6's
  native-bound ratio test is what makes a branch a pure bound edit: a
  tightened ``ub`` lands in the canonical ``LPBatch.ub`` vector, never in
  a new row, so every node in the tree shares one static canonical shape;
* **children start warm** — each node stores its parent's per-LP
  ``WarmStart`` slice (canonical coordinates, raw engine scaling) and the
  next frontier dispatch re-injects the stacked carriers.  A child differs
  from its parent by one bound, so the parent basis is usually
  dual-feasible-after-repair and re-solves in a handful of pivots — the
  measured warm/cold iteration ratio is the ``bnb`` row of
  BENCH_pivot_work.json;
* **fathoming is certificate-driven** — per-LP INFEASIBLE prunes,
  integral OPTIMAL solutions update the incumbent (objective recomputed
  exactly in float64 from the rounded point), and bound pruning compares
  the node's relaxation bound against the incumbent.  For the exact
  simplex engines the relaxation objective *is* the bound (minus a float32
  safety slack); for PDHG — whose OPTIMAL means "KKT residuals below tol",
  an *approximate* objective — the PR 5 dual certificate ``LPResult.y`` is
  passed through ``safe_dual_bound``, which is valid for **any** dual
  vector, so tolerance noise can never prune the true optimum.  Backends
  advertise this via ``BackendSpec.supports_safe_bound``; non-exact
  backends without it are rejected.

Two dispatch modes:

* ``mode="dispatch"`` (default, all backends): solve whole frontiers per
  round through ``solve_batched(..., pad_to_bucket=True)`` — one compiled
  XLA program per pow2 frontier bucket;
* ``mode="stream"`` (tableau only): drive the ``FrontierScheduler``
  (core/compaction.py) — fathomed nodes retire mid-batch and
  freshly-branched children are admitted into the freed lanes, so the
  device batch never drains between rounds.

The driver itself is host-side NumPy: selection (best-first or diving),
branching (most-fractional), and bookkeeping are O(frontier) scalar work
per round — the device only ever sees batched LP relaxations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .batching import solve_batched
from .compaction import FrontierScheduler, _maybe_span
from .forms import (GeneralLPBatch, Recovery, canonicalize, general_violation,
                    rebind_bounds)
from .lp import (INFEASIBLE, ITERATION_LIMIT, OPTIMAL, UNBOUNDED, LPBatch,
                 LPResult, WarmStart, backend_spec)

SEARCHES = ("best", "depth")
MODES = ("dispatch", "stream")


def safe_dual_bound(g: GeneralLPBatch, y: np.ndarray) -> np.ndarray:
    """A bound on each LP's optimal value that is valid for **any** row-dual
    vector ``y`` (B, m) — the safe-bound pass behind
    ``BackendSpec.supports_safe_bound``.

    From the exact identity ``c.x = y.(Ax) + z.x`` with ``z = c - A^T y``,
    bounding each term over the feasible box gives, for minimization::

        min c.x + c0  >=  c0 + sum_i min(y_i lo_i, y_i hi_i)
                             + sum_j min(z_j lb_j, z_j ub_j)

    (maximization: the mirrored upper bound with max picks).  This holds
    for *every* y, so duals from a tolerance-based solver (PDHG) — or
    float32-noisy duals from an exact one — still yield bounds safe to
    prune with.  Entries of ``y`` whose optimizing side is an infinite row
    bound are projected to 0 first (still valid: any y is); a reduced cost
    pushing against an infinite variable bound honestly yields ``-inf``
    (``+inf`` for max) — no information.  NaN duals are treated as 0.

    Returns (B,) bounds in the problem's own sense: a lower bound on the
    minimum, or an upper bound on the maximum.
    """
    y = np.nan_to_num(np.asarray(y, np.float64),
                      nan=0.0, posinf=0.0, neginf=0.0)
    lo, hi = g.row_bounds()
    lb = np.asarray(g.lb, np.float64)
    ub = np.asarray(g.ub, np.float64)
    if not g.maximize:
        bad = ((y > 0) & ~np.isfinite(lo)) | ((y < 0) & ~np.isfinite(hi))
        yp = np.where(bad, 0.0, y)
        rt = (np.where(yp > 0, yp, 0.0) * np.where(yp > 0, lo, 0.0)
              + np.where(yp < 0, yp, 0.0) * np.where(yp < 0, hi, 0.0))
        z = np.asarray(g.c, np.float64) - np.einsum("bmn,bm->bn", g.A, yp)
        ct = (np.where(z > 0, z, 0.0) * np.where(z > 0, lb, 0.0)
              + np.where(z < 0, z, 0.0) * np.where(z < 0, ub, 0.0))
    else:
        bad = ((y > 0) & ~np.isfinite(hi)) | ((y < 0) & ~np.isfinite(lo))
        yp = np.where(bad, 0.0, y)
        rt = (np.where(yp > 0, yp, 0.0) * np.where(yp > 0, hi, 0.0)
              + np.where(yp < 0, yp, 0.0) * np.where(yp < 0, lo, 0.0))
        z = np.asarray(g.c, np.float64) - np.einsum("bmn,bm->bn", g.A, yp)
        ct = (np.where(z > 0, z, 0.0) * np.where(z > 0, ub, 0.0)
              + np.where(z < 0, z, 0.0) * np.where(z < 0, lb, 0.0))
    return np.asarray(g.c0, np.float64) + rt.sum(axis=1) + ct.sum(axis=1)


def _cold_carrier(m: int, n: int) -> WarmStart:
    """A 1-member carrier encoding the cold start (slack basis, zero
    iterates): lets root/reset nodes share a frontier dispatch with
    genuinely warm siblings — ``WarmStart.concat`` needs uniform leaves,
    and injecting the cold construction *as* a warm start is a no-op."""
    return WarmStart(m=m, n=n,
                     basis=np.arange(n, n + m, dtype=np.int32)[None],
                     at_upper=np.zeros((1, n), bool),
                     x=np.zeros((1, n)), y=np.zeros((1, m)),
                     omega=np.ones(1), eta=np.ones(1))


@dataclasses.dataclass
class _Node:
    """One open node: bound edits vs the root + inherited bookkeeping."""
    lb: np.ndarray            # (n,) original-coordinate bounds
    ub: np.ndarray
    bound: float              # inherited relaxation bound (min-form)
    depth: int
    warm: Optional[WarmStart]  # parent's terminal state, canonical coords


@dataclasses.dataclass(frozen=True)
class BnBResult:
    """Outcome of one branch-and-bound run (original problem sense).

    ``status`` reuses the LP codes: OPTIMAL — incumbent proven optimal to
    ``gap_tol``; INFEASIBLE — no integer-feasible point exists (proven);
    UNBOUNDED — the root relaxation is unbounded; ITERATION_LIMIT — the
    node budget ran out or some node was unresolvable, ``objective``/
    ``bound`` bracket the true optimum.  ``proven`` is the single flag
    tests should assert.
    """
    x: Optional[np.ndarray]   # (n,) incumbent (integer cols exactly integral)
    objective: float          # incumbent value (NaN when none found)
    bound: float              # proven bound on the optimum (problem sense)
    status: int
    proven: bool
    nodes: int                # LP relaxations solved
    dispatches: int           # device dispatches (rounds / admit groups)
    lp_iterations: int        # total LP iterations across all node solves
    max_depth: int
    gap: float                # |objective - bound| / max(1, |objective|)

    def summary(self) -> str:
        names = {OPTIMAL: "optimal", UNBOUNDED: "unbounded",
                 INFEASIBLE: "infeasible", ITERATION_LIMIT: "node_limit"}
        return (f"{names[self.status]}: objective={self.objective:.6g} "
                f"bound={self.bound:.6g} nodes={self.nodes} "
                f"lp_iters={self.lp_iterations} depth<={self.max_depth}")


def _normalize_integer(g: GeneralLPBatch, integer) -> np.ndarray:
    if integer is None:
        integer = g.integer
    if integer is None:
        raise ValueError(
            "no integer columns: pass integer= or set GeneralLPBatch.integer "
            "(read_mps records INTORG/INTEND markers and BV/UI/LI bounds)")
    integer = np.asarray(integer)
    if integer.dtype != bool:
        mask = np.zeros(g.n, bool)
        mask[integer.reshape(-1).astype(int)] = True
        integer = mask
    integer = integer.reshape(g.n)
    if not integer.any():
        raise ValueError("integer mask is empty")
    fin = (np.isfinite(g.lb[:, integer]).all()
           and np.isfinite(g.ub[:, integer]).all())
    if not fin:
        raise ValueError(
            "integer columns need finite lb and ub at the root: branching "
            "edits bounds, and the canonical batch's bound-finiteness "
            "pattern must stay invariant across the tree "
            "(forms.rebind_bounds)")
    return integer


def branch_and_bound(g: GeneralLPBatch, *, integer=None,
                     backend: str = "tableau", mode: str = "dispatch",
                     search: str = "best", frontier: int = 16,
                     lanes: Optional[int] = None,
                     warm_start: bool = True,
                     max_nodes: int = 10_000,
                     gap_tol: float = 1e-6, int_tol: float = 1e-5,
                     bound_slack: float = 1e-5, feas_accept: float = 1e-5,
                     pricing: str = "dantzig", tracer=None,
                     **solver_kwargs) -> BnBResult:
    """Solve the mixed-integer program ``g`` (integer columns per
    ``integer``/``g.integer``) by batched LP-based branch-and-bound.

    ``g`` must be a single instance (batch of 1) with finite bounds on
    every integer column.  ``backend`` is any BACKEND_REGISTRY engine; a
    non-exact backend must advertise ``supports_safe_bound`` (its node
    bounds then go through the ``safe_dual_bound`` certificate pass
    instead of trusting tolerance-based objectives).  ``search`` picks the
    node order — ``"best"`` (best-bound-first: strongest bound growth) or
    ``"depth"`` (diving: incumbents early, frontier stays warm-start
    coherent).  ``frontier`` caps nodes per device dispatch
    (``mode="dispatch"``); ``lanes`` sizes the refill pool
    (``mode="stream"``, tableau only, default ``next pow2 >= frontier``).
    ``warm_start=False`` solves every node cold (the A/B the ``bnb``
    benchmark row measures).  Remaining kwargs (``dtype``, ``tol``,
    ``max_iters``, ...) forward to the LP engine via ``solve_batched``.

    Fathoming tolerances: a node is pruned when its relaxation bound
    cannot beat the incumbent by more than ``gap_tol`` (relative), so the
    returned incumbent is optimal to ``gap_tol`` when ``proven``;
    ``bound_slack`` is the float32 safety margin subtracted from exact
    engines' relaxation objectives before they are used as bounds;
    ``int_tol`` decides integrality of a relaxation solution and
    ``feas_accept`` re-checks the rounded candidate's original-space
    feasibility before it may become the incumbent.

    ``tracer`` (an `obs.SpanTracer`) records node lifecycle events — one
    ``node`` event per fathom/branch decision with the outcome and depth —
    plus dispatch spans; in ``mode="stream"`` it is also handed to the
    `FrontierScheduler` for admit/retire lane events.
    """
    spec = backend_spec(backend)
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    if search not in SEARCHES:
        raise ValueError(
            f"unknown search {search!r}; expected one of {SEARCHES}")
    if mode == "stream" and backend != "tableau":
        raise ValueError(
            "mode='stream' drives the tableau FrontierScheduler; use "
            "mode='dispatch' for the revised/pdhg engines")
    if not spec.exact and not spec.supports_safe_bound:
        raise ValueError(
            f"backend {backend!r} is tolerance-based and does not support "
            "the safe-bound certificate pass (BackendSpec."
            "supports_safe_bound); its objectives cannot prune safely")
    if g.batch != 1:
        raise ValueError(f"branch_and_bound takes one instance, got a batch "
                         f"of {g.batch}")
    int_mask = _normalize_integer(g, integer)
    int_cols = np.flatnonzero(int_mask)
    if frontier < 1:
        raise ValueError(f"frontier must be >= 1, got {frontier}")

    # Integer columns' bounds are forced into canonical *rows*: a branch
    # then edits only ``b``, which the engines' warm repair phase 1 can fix
    # under the parent basis; a tightened native ``ub`` under a stale basis
    # would go undetected (the injected vertex can sit above the new bound).
    lp0, rec0 = canonicalize(g, bound_rows=int_mask)
    mval = (lambda v: -v) if g.maximize else (lambda v: v)

    # ---- mutable search state (shared by both modes via _process) ---------
    open_nodes: List[_Node] = [
        _Node(lb=np.asarray(g.lb[0], np.float64).copy(),
              ub=np.asarray(g.ub[0], np.float64).copy(),
              bound=-np.inf, depth=0, warm=None)]
    state = {"incumbent": np.inf, "x": None, "proven": True,
             "unbounded": False, "nodes": 0, "dispatches": 0,
             "lp_iters": 0, "max_depth": 0}

    def note(outcome: str, nd: "_Node", **kw):
        if tracer is not None:
            tracer.event("node", outcome=outcome, depth=nd.depth,
                         bound=float(nd.bound), **kw)

    def prune_eps():
        inc = state["incumbent"]
        return gap_tol * max(1.0, abs(inc)) if np.isfinite(inc) else 0.0

    def select(k: int) -> List[_Node]:
        if search == "best":
            open_nodes.sort(key=lambda nd: nd.bound)
            take = open_nodes[:k]
            del open_nodes[:k]
        else:                               # diving: deepest-first
            take = open_nodes[-k:]
            del open_nodes[-k:]
        return take

    def _branch(nd: _Node, j: int, split: float, bound: float,
                warm: Optional[WarmStart]):
        dn_ub = nd.ub.copy()
        dn_ub[j] = split
        up_lb = nd.lb.copy()
        up_lb[j] = split + 1.0
        for lb2, ub2 in ((nd.lb.copy(), dn_ub), (up_lb, nd.ub.copy())):
            open_nodes.append(_Node(lb=lb2, ub=ub2, bound=bound,
                                    depth=nd.depth + 1, warm=warm))
        state["max_depth"] = max(state["max_depth"], nd.depth + 1)

    def _process(nd: _Node, status: int, obj: float, x: np.ndarray,
                 node_g_row, y_row, warm: Optional[WarmStart]):
        """Fathom/branch one solved node (x/obj/y in original coords)."""
        if status == INFEASIBLE:
            note("infeasible", nd)
            return
        if status == UNBOUNDED:
            note("unbounded", nd)
            if nd.depth == 0:
                state["unbounded"] = True
            else:          # a child more constrained than a bounded root:
                state["proven"] = False   # numerically suspect — don't claim
            return
        if status == ITERATION_LIMIT:
            # x is whatever the limit left behind — branch on a domain
            # split instead (always valid), cold-start the children
            unfixed = int_cols[nd.lb[int_cols] < nd.ub[int_cols]]
            if not len(unfixed):
                note("limit_stuck", nd)
                state["proven"] = False
                return
            j = int(unfixed[0])
            note("limit_split", nd, column=j)
            _branch(nd, j, np.floor((nd.lb[j] + nd.ub[j]) / 2.0),
                    nd.bound, None)
            return
        # OPTIMAL relaxation
        if spec.exact:
            nb = mval(obj) - bound_slack * (1.0 + abs(obj))
        else:
            sb = float(safe_dual_bound(node_g_row, y_row[None])[0])
            nb = mval(sb) if np.isfinite(sb) else nd.bound
        nb = max(nb, nd.bound)
        if nb >= state["incumbent"] - prune_eps():
            note("fathomed", nd, node_bound=float(nb))
            return                          # fathom by bound
        xi = x[int_cols]
        frac = np.abs(xi - np.round(xi))
        if float(frac.max()) <= int_tol:
            cand = np.asarray(x, np.float64).copy()
            cand[int_cols] = np.round(xi)
            viol = float(general_violation(g, cand[None])[0])
            if viol <= feas_accept:
                v = mval(float(g.objective_value(cand[None])[0]))
                if v < state["incumbent"]:
                    state["incumbent"], state["x"] = v, cand
                    note("incumbent", nd, objective=mval(v))
                else:
                    note("integral", nd)
            else:                           # rounding broke feasibility —
                note("round_infeasible", nd)
                state["proven"] = False     # pathological; don't fabricate
            return
        j = int(int_cols[int(np.argmax(frac))])
        split = float(np.clip(np.floor(x[j]), nd.lb[j], nd.ub[j] - 1.0))
        note("branched", nd, column=j, split=split, node_bound=float(nb))
        _branch(nd, j, split, nb, warm if warm_start else None)

    # ---- frontier loop ----------------------------------------------------
    if mode == "dispatch":
        while open_nodes and not state["unbounded"] \
                and state["nodes"] < max_nodes:
            take = select(min(frontier, len(open_nodes),
                              max_nodes - state["nodes"]))
            LB = np.stack([nd.lb for nd in take])
            UB = np.stack([nd.ub for nd in take])
            lp_f, rec_f = rebind_bounds(lp0, rec0, LB, UB)
            ws = None
            if warm_start:
                ws = WarmStart.concat(
                    [nd.warm if nd.warm is not None
                     else _cold_carrier(lp0.m, lp0.n) for nd in take])
            with _maybe_span(tracer, "bnb_dispatch", nodes=len(take),
                             open_nodes=len(open_nodes)):
                res_can = solve_batched(lp_f, backend=backend,
                                        pricing=pricing, warm=ws,
                                        pad_to_bucket=True, **solver_kwargs)
            res = rec_f.recover(res_can)
            state["nodes"] += len(take)
            state["dispatches"] += 1
            state["lp_iters"] += int(np.asarray(res.iterations).sum())
            gf = rec_f.general
            for i, nd in enumerate(take):
                row_g = dataclasses.replace(
                    gf, A=gf.A[i:i + 1], rhs=gf.rhs[i:i + 1],
                    lb=gf.lb[i:i + 1], ub=gf.ub[i:i + 1],
                    c=gf.c[i:i + 1], c0=gf.c0[i:i + 1]) \
                    if not spec.exact else None
                w = (res_can.warm.slice(i, i + 1)
                     if res_can.warm is not None else None)
                _process(nd, int(res.status[i]), float(res.objective[i])
                         if res.objective is not None else np.nan,
                         np.asarray(res.x[i], np.float64), row_g,
                         None if res.y is None else np.asarray(res.y[i]), w)
    else:                                   # mode == "stream"
        sched = FrontierScheduler(
            lp0.m, lp0.n, lanes=(frontier if lanes is None else lanes),
            pricing=pricing, tracer=tracer,
            **{k: v for k, v in solver_kwargs.items()
               if k in ("dtype", "tol", "feas_tol", "max_iters",
                        "segment_k", "stats_out")})
        pending = {}
        seq = [0]

        def source(k):
            if not open_nodes or state["unbounded"] \
                    or state["nodes"] >= max_nodes:
                return None
            take = select(min(k, len(open_nodes),
                              max_nodes - state["nodes"]))
            LB = np.stack([nd.lb for nd in take])
            UB = np.stack([nd.ub for nd in take])
            lp_f, rec_f = rebind_bounds(lp0, rec0, LB, UB)
            tags = []
            for i, nd in enumerate(take):
                pending[seq[0]] = (nd, rec_f, i)
                tags.append(seq[0])
                seq[0] += 1
            ws = None
            if warm_start:
                ws = WarmStart.concat(
                    [nd.warm if nd.warm is not None
                     else _cold_carrier(lp0.m, lp0.n) for nd in take])
            state["nodes"] += len(take)
            state["dispatches"] += 1
            return (np.asarray(lp_f.A), np.asarray(lp_f.b),
                    np.asarray(lp_f.c), lp_f.upper_bounds(), ws, tags)

        def sink(tag, row):
            nd, rec_f, i = pending.pop(tag)
            rec1 = _slice_recovery(rec_f, i)
            res1 = LPResult(
                x=row["x"][None], objective=np.array([row["objective"]]),
                status=np.array([row["status"]], np.int8),
                iterations=np.array([row["iterations"]], np.int32),
                y=row["y"][None], z=row["z"][None])
            res = rec1.recover(res1)
            state["lp_iters"] += int(row["iterations"])
            _process(nd, int(res.status[0]),
                     float(res.objective[0]),
                     np.asarray(res.x[0], np.float64), None,
                     None if res.y is None else np.asarray(res.y[0]),
                     row["warm"])

        sched.run(source, sink)

    # ---- verdict ----------------------------------------------------------
    inc = state["incumbent"]
    have_inc = np.isfinite(inc)
    exhausted = not open_nodes and not state["unbounded"]
    if state["unbounded"]:
        status, proven = UNBOUNDED, True
        bound_min = -np.inf
    elif exhausted and state["proven"]:
        status = OPTIMAL if have_inc else INFEASIBLE
        proven = True
        bound_min = inc
    else:
        status, proven = ITERATION_LIMIT, False
        bound_min = min([nd.bound for nd in open_nodes] + [inc]) \
            if (open_nodes or have_inc) else -np.inf
    objective = mval(inc) if have_inc else np.nan
    bound = mval(bound_min) if np.isfinite(bound_min) else \
        (np.inf if g.maximize else -np.inf)
    gap = (abs(objective - bound) / max(1.0, abs(objective))
           if have_inc and np.isfinite(bound) else np.inf)
    if proven:
        gap = 0.0
    return BnBResult(x=state["x"], objective=objective, bound=bound,
                     status=status, proven=proven, nodes=state["nodes"],
                     dispatches=state["dispatches"],
                     lp_iterations=state["lp_iters"],
                     max_depth=state["max_depth"], gap=gap)


def _slice_recovery(rec: Recovery, i: int) -> Recovery:
    """The single-row view of a frontier Recovery (stream-mode retirement
    recovers nodes one at a time as they leave the lane pool)."""
    gf = rec.general
    g1 = dataclasses.replace(gf, A=gf.A[i:i + 1], rhs=gf.rhs[i:i + 1],
                             lb=gf.lb[i:i + 1], ub=gf.ub[i:i + 1],
                             c=gf.c[i:i + 1], c0=gf.c0[i:i + 1])
    sl = (lambda a: None if a is None
          else (a if a.shape[0] == 1 else a[i:i + 1]))
    return dataclasses.replace(
        rec, general=g1, baseline=rec.baseline[i:i + 1],
        shift=rec.shift[i:i + 1],
        status_override=rec.status_override[i:i + 1],
        col_scale=sl(rec.col_scale), row_scale=sl(rec.row_scale))
