"""The paper's primary contribution: batched LP solving as a device-native,
shardable primitive (Gurung & Ray 2018, adapted CUDA->TPU/JAX).

Public API:
    LPBatch, LPResult, status codes      — problem/result containers
    GeneralLPBatch, canonicalize         — general-form LPs (senses/ranges/
                                           bounds/min-max, core/forms.py):
                                           every solve_* accepts one
                                           directly; io/mps.py parses MPS
                                           files into them
    solve_batched_jax                    — lockstep pure-JAX batched simplex
                                           (phase-compacted two-loop solve)
    solve_batched_revised                — revised simplex: basis-factor
                                           updates + partial pricing
                                           (``backend="revised"`` on every
                                           solve_* is the same engine)
    solve_batched_compacted              — active-set compaction scheduler
    solve_batched                        — HBM-aware chunked driver (Alg. 1)
    SparseLPBatch, solve_batched_pdhg_sparse
                                         — shared-pattern sparse batches:
                                           one COO pattern across the batch,
                                           (B, nnz) values; PDHG matvecs
                                           scale with nnz, not m*n
    solve_hyperbox                       — box-LP closed form (Sec. 5.6)
    solve_pjit / solve_shard_map         — multi-chip batch-parallel solvers
    expert_capacity_lp                   — MoE integration (LP router)
    PRICING_RULES / ALL_PRICING          — pluggable pivot pricing
                                           (``pricing=`` on every solve_*):
                                           dantzig | steepest_edge | devex
                                           | partial
    WarmStart                            — cross-solve state carrier
                                           (``res.warm_start()`` ->
                                           ``solve_*(..., warm=ws)``): basis
                                           + flips + pricing weights for the
                                           simplexes, iterates + primal
                                           weight for PDHG
    branch_and_bound                     — batched MIP branch-and-bound:
                                           frontiers of bound-edited nodes
                                           solved as one warm-started batch
                                           per dispatch (core/branch_bound.py)
"""
from .lp import (  # noqa: F401
    BACKEND_REGISTRY, BACKENDS, BIG, INFEASIBLE, ITERATION_LIMIT, OPTIMAL,
    UNBOUNDED, LPBatch, LPResult, STATUS_NAMES, WarmStart, backend_spec,
    build_tableau, canonicalize_backend, default_max_iters, resolve_backend,
)
from .forms import (  # noqa: F401
    GeneralLPBatch, Recovery, canonical_shape, canonicalize, general_kkt,
    general_violation, prepare_warm, random_general_lp_batch, rebind_bounds,
)
from .pricing import ALL_PRICING, PRICING_RULES, canonicalize_rule  # noqa: F401
from .simplex import (  # noqa: F401
    solve_batched_jax, flops_per_pivot, tableau_elements,
)
from .batching import solve_batched, max_chunk_size  # noqa: F401
from .compaction import (  # noqa: F401
    CompactionConfig, SegmentStat, auto_compact_threshold, auto_segment_k,
    solve_batched_compacted,
)
from .revised import (  # noqa: F401
    auto_refactor_period, revised_elements, solve_batched_revised,
    solve_batched_revised_compacted,
)
from .pdhg import (  # noqa: F401
    default_pdhg_max_iters, pdhg_elements, solve_batched_pdhg,
    solve_batched_pdhg_compacted,
)
from .sparse import (  # noqa: F401
    SparseLPBatch, solve_batched_pdhg_sparse, sparse_matvecs,
    sparse_pdhg_elements,
)
from .hyperbox import solve_hyperbox, solve_hyperbox_ref, hyperbox_as_general_lp  # noqa: F401
from .reference import (  # noqa: F401
    random_lp_batch, random_sparse_lp_batch, solve_batched_reference,
    solve_batched_reference_detailed, solve_dual_reference,
)
from .distributed import solve_pjit, solve_shard_map  # noqa: F401
from .lp_router import expert_capacity_lp  # noqa: F401
from .branch_bound import (  # noqa: F401
    BnBResult, branch_and_bound, safe_dual_bound,
)
