"""Batched revised simplex — basis-factor updates instead of tableau updates.

The paper's solver (core/simplex.py) carries the *entire* dense tableau
through every pivot: one rank-1 update writes O(m*(n+2m)) elements, which is
what PR 1's work-elimination engine and PR 2's pricing rules multiply
against.  The classic fix — the **revised simplex method** — keeps the
constraint data immutable and maintains only a factorization of the m x m
basis matrix:

* ``Abar`` (B, m, n+2m) — the sign-adjusted constraint columns (structurals,
  slacks, artificials; exactly the tableau's column layout, so basis indices,
  statuses and solution extraction are interchangeable with the tableau
  backend).  **Never written after construction.**
* ``lu/perm`` — a batched LU factorization (``jax.lax.linalg.lu``) of the
  basis matrix at the last refactorization point.
* ``etaR/etaV`` — a product-form **eta file**: one rank-1 update factor per
  pivot since the last refactorization.  After pivot (l, e) with FTRAN column
  u = B^-1 a_e, the new basis inverse is E B^-1 with E the identity except
  column l = eta, eta_l = 1/u_l, eta_i = -u_i/u_l.
* every ``refactor_period`` pivots (and at every active-set compaction
  gather) the basis matrix is re-gathered from ``Abar`` and re-factorized,
  emptying the eta file — the standard stability/cost tradeoff.

Per pivot the solver runs:

1. **BTRAN**: y = B^-T c_B — reverse-order transposed eta applications, then
   a transposed LU solve.  O(m^2 + k*m).
2. **pricing**: reduced costs d_j = c_j - y . a_j over candidate columns.
   ``pricing="dantzig"`` prices all n+m candidates (O(m*(n+m)));
   ``pricing="partial"`` prices one rotating block of ``PARTIAL_BLOCK``
   columns (O(m*block)) and falls back to a full pass only for LPs whose
   block prices out (which is also where optimality is detected) — the
   contract extension in core/pricing.py, same block schedule as the tableau
   dialect and the float64 oracle.
3. **ratio test**: u = B^-1 a_e by FTRAN (LU solve + forward eta
   applications), then the paper's sentinel min-ratio over u.  O(m^2 + k*m).
4. **update**: x_B and one appended eta column — O(m) writes.  The tableau
   backend writes O(m*(n+2m)) elements here; this asymmetry is the whole
   point (see ``revised_elements``).

Phase handling mirrors the tableau backend exactly: the same two-phase
construction (phase-1 cost = -1 on artificials), the same per-LP phase
switch, feasibility threshold, status codes and iteration accounting — so on
well-conditioned batches the two backends execute the same pivot sequence
and report identical statuses (cross-checked in benchmarks/pivot_work.py and
tests/test_revised.py; float32 reduced costs are *recomputed* here rather
than carried incrementally, so long degenerate ties can order differently
without changing certificates).

Composition: ``RevisedBackend`` plugs into the active-set compaction
scheduler (core/compaction.py) — every state leaf keeps the batch on axis 0
so bucket gathers work unchanged, and ``take`` refactorizes after each
gather (**refactor-on-compact**) so segments always resume from a clean LU.

Reproducibility contract: unlike the tableau engine (whose per-LP rank-1
path is independent of batchmates, hence bitwise-invariant to batch
decomposition), the eta-file slot clock and the refactor trigger are shared
across the (local) batch — splitting a batch across shard_map shards or
compaction buckets shifts *when* each LP's basis is refactorized and hence
f32 rounding.  Identical batch composition (jit vs pjit) is bitwise;
different decompositions guarantee identical certificates and
objectives/solutions to f32 tolerance (~1e-6), verified in
tests/test_revised.py.
``backend="revised"`` on solve_batched / solve_pjit / solve_shard_map
routes here; ``solve_batched_pallas(backend="revised")`` runs the revised
tile kernel (kernels/revised_tile.py), which reuses this module's state
builder, warm injection and pivot semantics and validates against it.
"""
from __future__ import annotations

import functools
import time
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..obs.report import report_from_counters
from ..obs.telemetry import (init_telemetry, tel_revised_update,
                             tel_simplex_update, tel_to_numpy)
from .forms import ensure_canonical, finish_result, prepare_warm
from .compaction import (
    CompactionConfig,
    JaxBackend,
    SegmentStat,
    auto_segment_k,
    resolve_compact_threshold,
    run_schedule,
)
from .lp import (
    BIG,
    INFEASIBLE,
    ITERATION_LIMIT,
    OPTIMAL,
    UNBOUNDED,
    LPBatch,
    LPResult,
    WarmStart,
    default_max_iters,
)
from .pricing import (canonicalize_rule, partial_geometry,
                      partial_priced_candidates)
from .simplex import _RUNNING, scatter_solution

# Pricing rules the revised backend supports.  steepest_edge needs
# ||B^-1 a_j||^2 per candidate (O(m^2) per column without the tableau) and
# devex needs the full updated pivot row — both are tableau-dialect rules;
# the revised backend's lever is *partial* pricing instead.
REVISED_RULES = ("dantzig", "partial")


def canonicalize_revised_rule(pricing: str) -> str:
    rule = canonicalize_rule(pricing)
    if rule not in REVISED_RULES:
        raise ValueError(
            f"pricing rule {rule!r} is tableau-only; the revised backend "
            f"supports {REVISED_RULES} (steepest-edge/devex weights need "
            "the dense tableau the revised method exists to avoid)")
    return rule


def auto_refactor_period(m: int, n: int) -> int:
    """Eta-file length when the caller passes ``refactor_period=None``.

    Balancing the amortized refactorization cost (~(2/3)m^3/K flops per
    pivot) against the eta-application cost (~3*K*m per pivot, growing with
    the file) gives K* ~ m/2; clamp to keep tiny problems from refactoring
    every pivot and huge ones from dragging hundred-deep eta files."""
    return max(4, min(64, m // 2))


def revised_elements(m: int, n: int, *, refactor_period: int | None = None,
                     partial: bool = False, block: int | None = None) -> int:
    """Tableau-element-equivalent work of one revised pivot, in the repo's
    executed-work unit (state elements *written* per pivot — the unit
    ``simplex.tableau_elements`` charges the tableau's rank-1 update).

    The immutable (m, n+2m) block is never written; a pivot writes the BTRAN
    and FTRAN solution vectors, the updated basic solution and one eta column
    (4m), plus the priced reduced costs, plus the amortized refactorization
    (LU factors + the gathered basis matrix, 2m^2 every K pivots).  The
    O(m*(n+m)) -> O(m^2)/K + pricing drop is the revised method's claim;
    ``analysis.lp_perf.revised_pivot_flops`` gives the companion flops model
    (where triangular-solve *reads* are charged too, and the crossover is in
    the n/m aspect ratio rather than uniform)."""
    K = refactor_period or auto_refactor_period(m, n)
    priced = partial_priced_candidates(n + m, block, partial=partial)
    return int(4 * m + priced + (2 * m * m) // K)


class RevisedState(NamedTuple):
    """Resumable revised-simplex state; every leaf keeps the batch on axis 0
    so the compaction scheduler's generic gathers apply unchanged."""
    Abar: jax.Array      # (B, m, n+2m) immutable sign-adjusted columns
    cvec: jax.Array      # (B, n+m) phase-2 costs over candidate columns
    xB: jax.Array        # (B, m) basic-variable values
    basis: jax.Array     # (B, m) int32 — column basic in each row
    phase: jax.Array     # (B,) int32
    status: jax.Array    # (B,) int32 — _RUNNING until terminal
    iters: jax.Array     # (B,) int32
    lu: jax.Array        # (B, m, m) LU factors of the refactorization basis
    perm: jax.Array      # (B, m) int32 — row permutation (A[perm] = L U)
    perm_inv: jax.Array  # (B, m) int32 — its inverse, for transposed solves
    etaR: jax.Array      # (B, K) int32 — eta pivot rows
    etaV: jax.Array      # (B, K, m) — eta columns
    cnt: jax.Array       # (B,) int32 — live etas (uniform; array-shaped so
                         #  compaction gathers treat it like every leaf)
    onub: jax.Array      # (B, n) bool — nonbasic structural parked at its
                         #  *upper* bound (reduced-cost sign is flagged, the
                         #  immutable columns are never complemented)
    ub: jax.Array        # (B, n) upper bounds (+inf = unbounded)
    thr: jax.Array       # (B,) phase-1 feasibility threshold
    tel: Any = None      # obs.TelemetryState lanes or None (empty subtree:
                         #  the telemetry-off trace is unchanged)


def build_revised_state(A: jax.Array, b: jax.Array, c: jax.Array, ub=None, *,
                        feas_tol: float, refactor_period: int) -> RevisedState:
    """Initial state: tableau column layout (structurals | slacks |
    artificials), sign-adjusted rows, identity starting basis => LU of I."""
    B, m, n = A.shape
    dtype = A.dtype
    neg = b < 0
    sign = jnp.where(neg, -1.0, 1.0).astype(dtype)
    idx = jnp.arange(m)

    slack = jnp.zeros((B, m, m), dtype).at[:, idx, idx].set(sign)
    art = jnp.zeros((B, m, m), dtype).at[:, idx, idx].set(
        jnp.where(neg, 1.0, 0.0).astype(dtype))
    Abar = jnp.concatenate([A * sign[:, :, None], slack, art], axis=2)
    bbar = b * sign
    cvec = jnp.concatenate([c, jnp.zeros((B, m), dtype)], axis=1)

    basis = jnp.where(neg, n + m + idx[None, :],
                      n + idx[None, :]).astype(jnp.int32)
    phase = jnp.where(neg.any(axis=1), 1, 2).astype(jnp.int32)
    # same relative phase-1 threshold as the tableau backend: the initial
    # phase-1 objective is the total infeasibility mass sum_neg bbar_i
    thr = feas_tol * jnp.maximum(1.0, jnp.where(neg, bbar, 0.0).sum(axis=1))

    eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), (B, m, m))
    iota = jnp.broadcast_to(idx.astype(jnp.int32), (B, m))
    K = int(refactor_period)
    if ub is None:
        ub = jnp.full((B, n), jnp.inf, dtype=dtype)
    else:
        ub = jnp.asarray(ub, dtype=dtype)
    return RevisedState(
        Abar=Abar, cvec=cvec, xB=bbar, basis=basis, phase=phase,
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32),
        lu=eye, perm=iota, perm_inv=iota,
        etaR=jnp.zeros((B, K), jnp.int32),
        etaV=jnp.zeros((B, K, m), dtype),
        cnt=jnp.zeros((B,), jnp.int32),
        onub=jnp.zeros((B, n), dtype=bool), ub=ub, thr=thr)


# ---------------------------------------------------------------------------
# FTRAN / BTRAN
# ---------------------------------------------------------------------------

def _lu_solve(lu, perm, v):
    """x = B0^-1 v via P B0 = L U: x = U^-1 L^-1 v[perm]."""
    t = jnp.take_along_axis(v, perm, axis=1)[..., None]
    t = lax.linalg.triangular_solve(lu, t, left_side=True, lower=True,
                                    unit_diagonal=True)
    t = lax.linalg.triangular_solve(lu, t, left_side=True, lower=False)
    return t[..., 0]


def _lu_solve_t(lu, perm_inv, v):
    """y = B0^-T v via B0^T = U^T L^T P: solve the two transposed triangles,
    then undo the row permutation."""
    t = v[..., None]
    t = lax.linalg.triangular_solve(lu, t, left_side=True, lower=False,
                                    transpose_a=True)
    t = lax.linalg.triangular_solve(lu, t, left_side=True, lower=True,
                                    transpose_a=True, unit_diagonal=True)
    return jnp.take_along_axis(t[..., 0], perm_inv, axis=1)


def _apply_etas_fwd(v, etaR, etaV, cnt0, iota_m):
    """FTRAN tail: v <- E_k ... E_1 v, oldest eta first.
    (E v)_i = v_i + eta_i * v_r for i != r, (E v)_r = eta_r * v_r."""
    def body(k, v):
        r = lax.dynamic_index_in_dim(etaR, k, axis=1, keepdims=False)
        eta = lax.dynamic_index_in_dim(etaV, k, axis=1, keepdims=False)
        vr = jnp.take_along_axis(v, r[:, None], axis=1)
        upd = eta * vr
        return jnp.where(iota_m[None, :] == r[:, None], upd, v + upd)

    return lax.fori_loop(0, cnt0, body, v)


def _apply_etas_rev(v, etaR, etaV, cnt0, iota_m):
    """BTRAN head: v <- E_1^T ... E_k^T v, newest eta first.
    (E^T v)_j = v_j for j != r, (E^T v)_r = eta . v."""
    def body(i, v):
        k = cnt0 - 1 - i
        r = lax.dynamic_index_in_dim(etaR, k, axis=1, keepdims=False)
        eta = lax.dynamic_index_in_dim(etaV, k, axis=1, keepdims=False)
        dot = jnp.sum(eta * v, axis=1, keepdims=True)
        return jnp.where(iota_m[None, :] == r[:, None], dot, v)

    return lax.fori_loop(0, cnt0, body, v)


def _refactorize(Abar, basis):
    """Gather the current basis matrix from the immutable columns and LU it,
    emptying the eta file (cnt is reset by the caller)."""
    B0 = jnp.take_along_axis(Abar, basis[:, None, :].astype(jnp.int32), axis=2)
    lu, _, perm = lax.linalg.lu(B0)
    perm = perm.astype(jnp.int32)
    perm_inv = jnp.argsort(perm, axis=1).astype(jnp.int32)
    return lu, perm, perm_inv


def inject_revised_warm(state: RevisedState, wb, wonub, *, m: int, n: int,
                        feas_tol: float) -> RevisedState:
    """Seed a freshly built ``RevisedState`` from a parent basis (warm start).

    The revised analogue of ``simplex.inject_tableau_warm``, per LP:

    * **skip** — refactorize the parent basis against the *new* data, solve
      for the basic values; all nonnegative means phase 2 starts directly
      from the parent vertex (at-upper nonbasics contribute through the
      effective rhs);
    * **repair** — rows whose basic value went negative get a fresh
      artificial whose *physical column* is ``-(B e_i)``: the new basis
      matrix is the old one with those columns negated (still nonsingular),
      its basic solution is ``|x_B|`` elementwise, and the ordinary phase-1
      costs (-1 on columns >= n+m, which pricing never scans) drive the
      artificials back out — a repair phase 1 seeded from the parent basis;
    * **cold** — out-of-range indices or a singular parent basis (duplicate
      columns after the artificial->slack remap surface as a non-finite
      solve): the LP keeps the cold state.

    The slack diagonal — the row-sign record ``extract_duals_revised``
    reads — lives in columns n..n+m-1 and is never overwritten."""
    Abar, ub = state.Abar, state.ub
    B = Abar.shape[0]
    dtype = Abar.dtype
    ncand = n + m
    idx = jnp.arange(m)
    in_range = ((wb >= 0) & (wb < n + 2 * m)).all(axis=1)
    wb2 = jnp.clip(jnp.where(wb >= ncand, wb - m, wb), 0, ncand - 1)
    wb2 = wb2.astype(jnp.int32)
    onub_w = wonub & jnp.isfinite(ub)
    bbar = state.xB                      # cold state: xB == sign-adjusted b
    rhs_eff = bbar - jnp.einsum(
        "bmn,bn->bm", Abar[:, :, :n],
        jnp.where(onub_w, ub, 0.0).astype(dtype))
    lu, perm, perm_inv = _refactorize(Abar, wb2)
    xB = _lu_solve(lu, perm, rhs_eff)
    ok = in_range & jnp.isfinite(xB).all(axis=1)
    eps = feas_tol * jnp.maximum(1.0, jnp.max(jnp.abs(bbar), axis=1))
    viol = xB < -eps[:, None]

    Bcols = jnp.take_along_axis(Abar, wb2[:, None, :], axis=2)   # (B, m, m)
    art_w = jnp.where(viol[:, None, :], -Bcols, Abar[:, :, ncand:])
    Abar_w = jnp.concatenate([Abar[:, :, :ncand], art_w], axis=2)
    basis_w = jnp.where(viol, ncand + idx[None, :], wb2).astype(jnp.int32)
    lu2, perm2, pinv2 = _refactorize(Abar_w, basis_w)
    xB_w = jnp.where(viol, -xB, xB)
    phase_w = jnp.where(viol.any(axis=1), 1, 2).astype(jnp.int32)
    thr_w = feas_tol * jnp.maximum(
        1.0, jnp.where(viol, -xB, 0.0).sum(axis=1))

    ok2 = ok[:, None]
    ok3 = ok[:, None, None]
    return state._replace(
        Abar=jnp.where(ok3, Abar_w, Abar),
        xB=jnp.where(ok2, xB_w, state.xB),
        basis=jnp.where(ok2, basis_w, state.basis),
        phase=jnp.where(ok, phase_w, state.phase),
        lu=jnp.where(ok3, lu2, state.lu),
        perm=jnp.where(ok2, perm2, state.perm),
        perm_inv=jnp.where(ok2, pinv2, state.perm_inv),
        onub=jnp.where(ok2, onub_w, state.onub),
        thr=jnp.where(ok, thr_w, state.thr))


# ---------------------------------------------------------------------------
# One lockstep revised pivot
# ---------------------------------------------------------------------------

def revised_step(state: RevisedState, *, m: int, n: int, tol: float,
                 refactor_period: int, rule: str = "dantzig") -> RevisedState:
    """One lockstep revised-simplex pivot across the batch (masked for
    inactive LPs): refactor-if-due, BTRAN, pricing, FTRAN, min-ratio,
    eta-append — the Step 1-3 structure of simplex_step re-expressed on the
    basis factorization instead of the tableau."""
    (Abar, cvec, xB, basis, phase, status, iters, lu, perm, perm_inv,
     etaR, etaV, cnt, onub, ub, thr) = state[:16]
    tel = state.tel
    in_p1 = phase == 1  # pre-update phase, for telemetry attribution
    B = xB.shape[0]
    K = int(refactor_period)
    iota_m = jnp.arange(m, dtype=jnp.int32)
    ncand = n + m
    active = status == _RUNNING
    refac_due = cnt[0] >= K  # scalar; captured pre-reset for telemetry
    # nonbasic-at-upper flags over all candidates (slacks never flip: ub=inf)
    onub_pad = jnp.concatenate([onub, jnp.zeros((B, m), bool)], axis=1)

    # ---- periodic refactorization (eta file full) --------------------------
    def do_refac(_):
        l, p, pi = _refactorize(Abar, basis)
        return l, p, pi, jnp.zeros_like(cnt)

    lu, perm, perm_inv, cnt = lax.cond(
        cnt[0] >= K, do_refac, lambda _: (lu, perm, perm_inv, cnt),
        operand=None)
    cnt0 = cnt[0]

    # ---- Step 1: BTRAN + pricing ------------------------------------------
    # phase-2 costs: c on structurals (slacks 0); phase-1 costs: -1 on
    # artificials, 0 on candidates => candidate reduced costs -y.a_j
    basis_c = jnp.where(basis < ncand,
                        jnp.take_along_axis(
                            cvec, jnp.minimum(basis, ncand - 1), axis=1),
                        0.0)
    cB = jnp.where((phase == 1)[:, None],
                   -(basis >= ncand).astype(xB.dtype), basis_c)
    y = _apply_etas_rev(cB, etaR, etaV, cnt0, iota_m)
    y = _lu_solve_t(lu, perm_inv, y)

    in_p2 = (phase == 2)[:, None]

    # Basic columns are masked out of pricing: their reduced cost is exactly
    # zero in exact arithmetic (so the mask never changes a pivot), but here
    # it is *recomputed* as c_j - y.a_j and the f32 residual can exceed tol —
    # the tableau dialect zeroes the entering column exactly during the
    # rank-1 update and needs no mask; without it a basic column can
    # "re-enter" as a no-op pivot forever.
    bidx = jnp.arange(B)
    basis_safe = jnp.minimum(basis, ncand - 1)
    basis_mask_val = jnp.where(basis < ncand, -BIG, BIG)  # BIG => no-op min

    def price_full(_):
        # improvement score: d_j entering from the lower bound, -d_j from the
        # upper bound (an at-upper variable improves by *decreasing*, which
        # pays off when its reduced cost is positive)
        d = jnp.where(in_p2, cvec, 0.0) - jnp.einsum(
            "bm,bmn->bn", y, Abar[:, :, :ncand])
        d = jnp.where(onub_pad, -d, d)
        return d.at[bidx[:, None], basis_safe].min(basis_mask_val)

    if rule == "partial":
        n_blocks, blk_sz = partial_geometry(ncand)
        blk = (iters % n_blocks).astype(jnp.int32)
        cols = blk[:, None] * blk_sz + jnp.arange(blk_sz, dtype=jnp.int32)
        valid = cols < ncand
        cols_safe = jnp.minimum(cols, ncand - 1)
        Ablk = jnp.take_along_axis(Abar, cols_safe[:, None, :], axis=2)
        cblk = jnp.where(in_p2, jnp.take_along_axis(cvec, cols_safe, axis=1),
                         0.0)
        in_basis = (cols_safe[:, :, None] == basis[:, None, :]).any(axis=2)
        onub_blk = jnp.take_along_axis(onub_pad, cols_safe, axis=1)
        d_raw = cblk - jnp.einsum("bm,bmc->bc", y, Ablk)
        d_blk = jnp.where(valid & ~in_basis,
                          jnp.where(onub_blk, -d_raw, d_raw), -BIG)
        blk_max = jnp.max(d_blk, axis=1)
        e_blk = jnp.take_along_axis(
            cols_safe, jnp.argmax(d_blk, axis=1)[:, None], axis=1)[:, 0]
        blk_improving = blk_max > tol
        priced_out = active & ~blk_improving
        # the full fallback also carries the optimality test, so it runs
        # (for the whole batch) only when some active LP's block priced out
        need_full = jnp.any(priced_out)
        d_full = lax.cond(need_full, price_full,
                          lambda _: jnp.full((B, ncand), -BIG, xB.dtype),
                          operand=None)
        full_max = jnp.max(d_full, axis=1)
        e = jnp.where(blk_improving, e_blk,
                      jnp.argmax(d_full, axis=1).astype(jnp.int32))
        max_cost = jnp.where(blk_improving, blk_max, full_max)
    else:
        priced_out = None
        d_full = price_full(None)
        e = jnp.argmax(d_full, axis=1).astype(jnp.int32)
        max_cost = jnp.max(d_full, axis=1)

    is_opt = max_cost <= tol

    # phase bookkeeping at optimality of the current objective (pre-pivot)
    p1_obj = jnp.where(basis >= ncand, xB, 0.0).sum(axis=1)
    p1_done = active & (phase == 1) & is_opt
    infeasible = p1_done & (p1_obj > thr)
    to_phase2 = p1_done & ~infeasible
    p2_done = active & (phase == 2) & is_opt

    # ---- Step 2: FTRAN + sentinel min-ratio --------------------------------
    # the entering variable moves *down* from its upper bound when flagged:
    # the basic response to a unit move along the edge is -dir * u
    a_e = jnp.take_along_axis(Abar, e[:, None, None], axis=2)[:, :, 0]
    u = _lu_solve(lu, perm, a_e)
    u = _apply_etas_fwd(u, etaR, etaV, cnt0, iota_m)
    onub_e = jnp.take_along_axis(onub_pad, e[:, None], axis=1)[:, 0]
    dir_e = jnp.where(onub_e, -1.0, 1.0).astype(xB.dtype)
    ucol = dir_e[:, None] * u
    valid_row = ucol > tol
    ratios = jnp.where(valid_row, xB / jnp.where(valid_row, ucol, 1.0), BIG)
    # a basic variable the move drives *up* (ucol < 0) may hit its own
    # finite upper bound (slacks/artificials: ub = +inf, never binds)
    ubB = jnp.where(basis < n,
                    jnp.take_along_axis(ub, jnp.minimum(basis, n - 1),
                                        axis=1),
                    jnp.inf).astype(xB.dtype)
    hit_ub = (ucol < -tol) & jnp.isfinite(ubB)
    ratios = jnp.where(hit_ub,
                       (ubB - xB) / jnp.where(hit_ub, -ucol, 1.0), ratios)
    # phase 2 pins basic artificials at zero (same rule as the tableau
    # dialect's simplex_step): an entering column that would grow one leaves
    # it at ratio 0 on a negative pivot element instead
    pin = (phase == 2)[:, None] & (basis >= ncand) & (ucol < -tol)
    ratios = jnp.where(pin, 0.0, ratios)
    l = jnp.argmin(ratios, axis=1).astype(jnp.int32)
    min_ratio = jnp.min(ratios, axis=1)
    no_row = min_ratio >= BIG / 2

    wants_pivot = active & ~is_opt
    # entering variable's own bound: travel of ub_e parks it at the opposite
    # bound with no basis change (a bound flip; strict < is the tie-break
    # shared with the oracle and the tableau dialect)
    t_e = jnp.where(e < n,
                    jnp.take_along_axis(ub, jnp.minimum(e, n - 1)[:, None],
                                        axis=1)[:, 0],
                    jnp.inf).astype(xB.dtype)
    do_flip = wants_pivot & (t_e < min_ratio)
    unbounded = wants_pivot & no_row & ~do_flip & (phase == 2)
    stuck = wants_pivot & no_row & ~do_flip & (phase == 1)
    do_pivot = wants_pivot & ~no_row & ~do_flip

    # ---- Step 3: O(m) update — x_B, bound flags and one eta column ---------
    ul = jnp.take_along_axis(u, l[:, None], axis=1)[:, 0]
    ul_safe = jnp.where(do_pivot, ul, 1.0)
    move = do_flip | do_pivot
    theta = jnp.where(do_flip, t_e, jnp.where(do_pivot, min_ratio, 0.0))
    is_l = iota_m[None, :] == l[:, None]
    # entering variable's post-pivot value: theta above its departing bound
    enter_val = jnp.where(onub_e, t_e - min_ratio, min_ratio)
    xB_new = jnp.where(is_l & do_pivot[:, None], enter_val[:, None],
                       xB - theta[:, None] * ucol)
    xB = jnp.where(move[:, None], xB_new, xB)

    # bound-flag bookkeeping: a flip toggles the entering flag; a pivot
    # clears it (the variable is basic now) and marks the leaving variable
    # at-upper when the min ratio came from its upper-bound row
    col_n = jnp.arange(n, dtype=jnp.int32)
    is_e_n = col_n[None, :] == e[:, None]
    onub = onub ^ (do_flip[:, None] & is_e_n)
    onub = onub & ~(do_pivot[:, None] & is_e_n)
    jl = jnp.take_along_axis(basis, l[:, None], axis=1)[:, 0]
    hit_l = jnp.take_along_axis(hit_ub, l[:, None], axis=1)[:, 0]
    leave_up = do_pivot & hit_l & (jl < n)
    onub = onub | (leave_up[:, None]
                   & (col_n[None, :] == jl[:, None]))

    r_eta = jnp.where(do_pivot, l, 0)
    eta = jnp.where(do_pivot[:, None], -u / ul_safe[:, None], 0.0)
    eta = jnp.where(iota_m[None, :] == r_eta[:, None],
                    jnp.where(do_pivot, 1.0 / ul_safe, 1.0)[:, None], eta)
    zero = jnp.int32(0)
    etaR = lax.dynamic_update_slice(etaR, r_eta[:, None], (zero, cnt0))
    etaV = lax.dynamic_update_slice(etaV, eta[:, None, :], (zero, cnt0, zero))
    # non-pivoting LPs got an identity eta; skip the slot when nobody pivots
    cnt = cnt + jnp.any(do_pivot).astype(jnp.int32)

    basis = jnp.where(do_pivot[:, None] & is_l, e[:, None], basis)

    status = jnp.where(infeasible, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(stuck, ITERATION_LIMIT, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    phase = jnp.where(to_phase2, 2, phase)
    inc = active & ~p2_done & ~infeasible
    iters = iters + inc.astype(jnp.int32)
    if tel is not None:
        tel = tel_simplex_update(tel, inc=inc, in_phase1=in_p1,
                                 do_pivot=do_pivot, do_flip=do_flip,
                                 degenerate=min_ratio <= 0.0)
        tel = tel_revised_update(tel, refactor=refac_due & active,
                                 eta_len=cnt, block_rotation=priced_out)
    return RevisedState(Abar, cvec, xB, basis, phase, status, iters,
                        lu, perm, perm_inv, etaR, etaV, cnt, onub, ub, thr,
                        tel)


def extract_solution_revised(state: RevisedState, n: int):
    """(x, objective) off the basic solution — no tableau to read.  Nonbasic
    structurals parked at their upper bound contribute ``ub_j`` to both."""
    x = scatter_solution(state.xB, state.basis, n)
    ncand = state.cvec.shape[1]
    cb = jnp.take_along_axis(state.cvec,
                             jnp.minimum(state.basis, ncand - 1), axis=1)
    obj = jnp.where(state.basis < n, cb * state.xB, 0.0).sum(axis=1)
    at_ub = jnp.where(state.onub, state.ub.astype(x.dtype), 0.0)
    x = x + at_ub
    obj = obj + (state.cvec[:, :n] * at_ub).sum(axis=1)
    return x, obj


def extract_duals_revised(state: RevisedState, n: int):
    """Dual certificate ``y = c_B B^-1`` off the final basis factors: one
    extra BTRAN (phase-2 costs), then the candidate pricing matvec for the
    structural reduced costs — the revised-simplex analogue of reading the
    tableau's objective row (simplex.extract_duals).

    The BTRAN solves against the *sign-adjusted* rows; the slack diagonal
    of ``Abar`` carries exactly that sign, so ``y = sign * y_scaled``
    reports the canonical-row duals (same convention as the tableau
    backend).  Returns (y (B, m), z (B, n))."""
    m = state.xB.shape[1]
    ncand = state.cvec.shape[1]
    iota_m = jnp.arange(m, dtype=jnp.int32)
    cB = jnp.where(state.basis < ncand,
                   jnp.take_along_axis(
                       state.cvec, jnp.minimum(state.basis, ncand - 1),
                       axis=1),
                   0.0)
    y_s = _apply_etas_rev(cB, state.etaR, state.etaV, state.cnt[0], iota_m)
    y_s = _lu_solve_t(state.lu, state.perm_inv, y_s)
    idx = jnp.arange(m)
    sign = state.Abar[:, idx, n + idx]          # slack diagonal = row sign
    y = sign * y_s
    z = state.cvec[:, :n] - jnp.einsum("bm,bmn->bn", y_s,
                                       state.Abar[:, :, :n])
    return y, z


def solve_revised(A, b, c, ub=None, *, m: int, n: int, max_iters: int,
                  tol: float, feas_tol: float, refactor_period: int,
                  pricing: str = "dantzig",
                  warm_basis=None, warm_at_upper=None,
                  full_state: bool = False, telemetry: bool = False):
    """Traceable whole-solve body (shared by jit, pjit and shard_map): one
    while_loop, per-LP phase switch inside the step (the revised method has
    no dead tableau columns, so there is nothing to phase-compact).

    ``warm_basis``/``warm_at_upper`` seed the solve from a parent basis via
    `inject_revised_warm` (per-LP skip/repair/cold); ``full_state=True``
    appends ``(basis, onub)`` to the return tuple for WarmStart capture."""
    rule = canonicalize_revised_rule(pricing)
    state = build_revised_state(A, b, c, ub, feas_tol=feas_tol,
                                refactor_period=refactor_period)
    if telemetry:
        state = state._replace(tel=init_telemetry(A.shape[0]))
    if warm_basis is not None:
        wonub = (jnp.zeros((A.shape[0], n), bool) if warm_at_upper is None
                 else jnp.asarray(warm_at_upper, bool))
        state = inject_revised_warm(state, jnp.asarray(warm_basis, jnp.int32),
                                    wonub, m=m, n=n, feas_tol=feas_tol)

    def cond(carry):
        s, it = carry
        return jnp.any(s.status == _RUNNING) & (it < max_iters)

    def body(carry):
        s, it = carry
        return revised_step(s, m=m, n=n, tol=tol,
                            refactor_period=refactor_period,
                            rule=rule), it + 1

    state, _ = lax.while_loop(cond, body, (state, jnp.int32(0)))
    status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT, state.status)
    x, obj = extract_solution_revised(state, n)
    y, z = extract_duals_revised(state, n)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    opt = (status == OPTIMAL)[:, None]
    y = jnp.where(opt, y, jnp.nan)
    z = jnp.where(opt, z, jnp.nan)
    out = (x, obj, status.astype(jnp.int8), state.iters, y, z)
    if full_state:
        out = out + (state.basis, state.onub)
    if telemetry:
        out = out + (state.tel,)
    return out


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "feas_tol", "refactor_period",
                                             "pricing", "telemetry"))
def _solve_revised_core(A, b, c, ub, *, m, n, max_iters, tol, feas_tol,
                        refactor_period, pricing, telemetry=False):
    return solve_revised(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                         feas_tol=feas_tol, refactor_period=refactor_period,
                         pricing=pricing, telemetry=telemetry)


@functools.partial(jax.jit, static_argnames=("m", "n", "max_iters", "tol",
                                             "feas_tol", "refactor_period",
                                             "pricing", "telemetry"))
def _solve_revised_core_state(A, b, c, ub, warm_basis, warm_at_upper, *, m, n,
                              max_iters, tol, feas_tol, refactor_period,
                              pricing, telemetry=False):
    """`_solve_revised_core` + warm injection + terminal-state capture (the
    batched entry point's core; warm args may be None for a cold run)."""
    return solve_revised(A, b, c, ub, m=m, n=n, max_iters=max_iters, tol=tol,
                         feas_tol=feas_tol, refactor_period=refactor_period,
                         pricing=pricing, warm_basis=warm_basis,
                         warm_at_upper=warm_at_upper, full_state=True,
                         telemetry=telemetry)


def solve_batched_revised(batch: LPBatch, *, dtype=jnp.float32,
                          tol: float | None = None,
                          feas_tol: float | None = None,
                          max_iters: int | None = None,
                          refactor_period: int | None = None,
                          pricing: str = "dantzig",
                          presolve: bool = True,
                          scale: bool | None = None,
                          warm: WarmStart | None = None,
                          telemetry: bool = False) -> LPResult:
    """Solve a batch of LPs with the lockstep revised simplex.

    Same LPBatch -> LPResult contract, status codes and defaults as
    ``solve_batched_jax`` — including GeneralLPBatch acceptance
    (canonicalize on ingestion, recover on the way out); ``pricing``
    accepts "dantzig" (full pricing) or "partial" (rotating column blocks,
    core/pricing.py).  ``refactor_period`` bounds the eta file (None
    derives ~m/2 via `auto_refactor_period`).  ``warm`` accepts a
    `WarmStart` from a previous solve (any basis-carrying engine): its
    basis/at_upper leaves seed the eta-file via `inject_revised_warm`;
    the result's own ``warm`` field carries the terminal basis onward."""
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if refactor_period is None:
        refactor_period = auto_refactor_period(m, n)
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if feas_tol is None:
        feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
    warm = prepare_warm(warm, rec, batch)
    wb = wonub = None
    if warm is not None and warm.basis is not None:
        wb = jnp.asarray(np.asarray(warm.basis), jnp.int32)
        if warm.at_upper is not None:
            wonub = jnp.asarray(np.asarray(warm.at_upper), bool)
    rule = canonicalize_revised_rule(pricing)
    t0 = time.perf_counter()
    out = _solve_revised_core_state(
        jnp.asarray(batch.A, dtype), jnp.asarray(batch.b, dtype),
        jnp.asarray(batch.c, dtype),
        jnp.asarray(batch.upper_bounds(), dtype),
        wb, wonub,
        m=m, n=n, max_iters=int(max_iters),
        tol=float(tol), feas_tol=float(feas_tol),
        refactor_period=int(refactor_period),
        pricing=rule, telemetry=bool(telemetry))
    x, obj, status, iters, y, z, basis, onub = out[:8]
    stats = None
    if telemetry:
        jax.block_until_ready(out[8])
        stats = report_from_counters(tel_to_numpy(out[8]),
                                     wall_s=time.perf_counter() - t0,
                                     backend="revised")
    res = LPResult(x=np.asarray(x), objective=np.asarray(obj),
                   status=np.asarray(status), iterations=np.asarray(iters),
                   y=np.asarray(y), z=np.asarray(z),
                   warm=WarmStart(m=m, n=n, basis=np.asarray(basis),
                                  at_upper=np.asarray(onub), pricing=rule),
                   stats=stats)
    return finish_result(rec, res)


# ---------------------------------------------------------------------------
# Active-set compaction integration
# ---------------------------------------------------------------------------

def segment_revised_phase1(state: RevisedState, steps, *, m: int, n: int,
                           tol: float, refactor_period: int,
                           rule: str = "dantzig"):
    """Run up to `steps` revised pivots; stops early once no LP is still in
    phase 1 (stage-1 contract of core.compaction.run_schedule)."""
    def cond(carry):
        s, it = carry
        pending = (s.status == _RUNNING) & (s.phase == 1)
        return jnp.any(pending) & (it < steps)

    def body(carry):
        s, it = carry
        return revised_step(s, m=m, n=n, tol=tol,
                            refactor_period=refactor_period,
                            rule=rule), it + 1

    return lax.while_loop(cond, body, (state, jnp.int32(0)))


def segment_revised_phase2(state: RevisedState, steps, *, m: int, n: int,
                           tol: float, refactor_period: int,
                           rule: str = "dantzig"):
    """Run up to `steps` revised pivots; stops early once every LP is
    terminal (stage-2 contract)."""
    def cond(carry):
        s, it = carry
        return jnp.any(s.status == _RUNNING) & (it < steps)

    def body(carry):
        s, it = carry
        return revised_step(s, m=m, n=n, tol=tol,
                            refactor_period=refactor_period,
                            rule=rule), it + 1

    return lax.while_loop(cond, body, (state, jnp.int32(0)))


_segment_rev_p1_jit = jax.jit(
    segment_revised_phase1,
    static_argnames=("m", "n", "tol", "refactor_period", "rule"))
_segment_rev_p2_jit = jax.jit(
    segment_revised_phase2,
    static_argnames=("m", "n", "tol", "refactor_period", "rule"))


@jax.jit
def _refactor_state_jit(state: RevisedState) -> RevisedState:
    lu, perm, perm_inv = _refactorize(state.Abar, state.basis)
    tel = state.tel
    if tel is not None:
        # refactor-on-compact counts as a refactorization for every
        # gathered (still-running) LP
        tel = tel_revised_update(
            tel, refactor=state.status == _RUNNING,
            eta_len=jnp.zeros_like(state.cnt))
    return state._replace(lu=lu, perm=perm, perm_inv=perm_inv,
                          cnt=jnp.zeros_like(state.cnt), tel=tel)


@functools.partial(jax.jit, static_argnames=("n",))
def _extract_revised_jit(state: RevisedState, *, n: int):
    x, obj = extract_solution_revised(state, n)
    y, z = extract_duals_revised(state, n)
    status = jnp.where(state.status == _RUNNING, ITERATION_LIMIT,
                       state.status)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    opt = (status == OPTIMAL)[:, None]
    return (x, obj, status.astype(jnp.int8), state.iters,
            jnp.where(opt, y, jnp.nan), jnp.where(opt, z, jnp.nan))


class RevisedBackend(JaxBackend):
    """Compaction-scheduler backend for the revised simplex.

    Reuses JaxBackend's generic plumbing (status/phase host fetches, padding
    deactivation, bucket gathers via the tree-mapped take) — RevisedState
    keeps every leaf batched on axis 0, including the eta file and LU
    factors, exactly so those gathers stay generic.  ``take`` additionally
    refactorizes after every gather (refactor-on-compact): the gathered LU
    is still valid per LP, but restarting segments from a clean factor keeps
    the eta file short and bounds f32 drift across bucket shrinks."""

    def __init__(self, m, n, tol, feas_tol, dtype, pricing="dantzig",
                 refactor_period: int | None = None):
        super().__init__(m, n, tol, feas_tol, dtype, pricing="dantzig")
        self.rule = canonicalize_revised_rule(pricing)
        self.refactor_period = int(refactor_period
                                   or auto_refactor_period(m, n))

    def init(self, A, b, c, ub=None, warm: WarmStart | None = None,
             telemetry: bool = False) -> RevisedState:
        state = build_revised_state(A, b, c, ub, feas_tol=self.feas_tol,
                                    refactor_period=self.refactor_period)
        if telemetry:
            state = state._replace(tel=init_telemetry(A.shape[0]))
        if warm is not None and warm.basis is not None:
            wonub = (jnp.zeros((A.shape[0], self.n), bool)
                     if warm.at_upper is None
                     else jnp.asarray(np.asarray(warm.at_upper), bool))
            state = inject_revised_warm(
                state, jnp.asarray(np.asarray(warm.basis), jnp.int32),
                wonub, m=self.m, n=self.n, feas_tol=self.feas_tol)
        return state

    def run_phase1(self, state, steps):
        state, it = _segment_rev_p1_jit(
            state, jnp.int32(steps), m=self.m, n=self.n, tol=self.tol,
            refactor_period=self.refactor_period, rule=self.rule)
        return state, int(it)

    def run_phase2(self, state, steps):
        state, it = _segment_rev_p2_jit(
            state, jnp.int32(steps), m=self.m, n=self.n, tol=self.tol,
            refactor_period=self.refactor_period, rule=self.rule)
        return state, int(it)

    def compact_columns(self, state: RevisedState) -> RevisedState:
        # nothing to drop: the revised method never materialized the
        # artificial columns' tableau, only their immutable data columns
        return state

    def take(self, state: RevisedState, idx) -> RevisedState:
        gathered = super().take(state, idx)
        return _refactor_state_jit(gathered)

    def extract(self, state: RevisedState, stage: str):
        return tuple(np.asarray(o)
                     for o in _extract_revised_jit(state, n=self.n))

    def elements_per_step(self, stage: str) -> int:
        return revised_elements(self.m, self.n,
                                refactor_period=self.refactor_period,
                                partial=(self.rule == "partial"))


def solve_batched_revised_compacted(
        batch: LPBatch, *, dtype=jnp.float32, tol: Optional[float] = None,
        feas_tol: Optional[float] = None, max_iters: Optional[int] = None,
        segment_k: Optional[int] = None,
        compact_threshold: Optional[float] = None,
        refactor_period: Optional[int] = None, pricing: str = "dantzig",
        stats_out: Optional[List[SegmentStat]] = None,
        presolve: bool = True, scale: Optional[bool] = None,
        warm: WarmStart | None = None,
        telemetry: bool = False, tracer=None) -> LPResult:
    """Revised simplex under the active-set compaction scheduler: K-pivot
    segments, power-of-two bucket gathers of survivors (eta file, LU factors
    and basis arrays gathered alongside), refactorization after every gather.
    Same contract as ``solve_batched_compacted`` (GeneralLPBatch accepted).
    ``warm`` seeds the initial state (the warm-derived leaves then ride the
    bucket gathers automatically); the compacted result reports
    ``warm=None``."""
    batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if segment_k is None:
        segment_k = auto_segment_k(m, n)
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if feas_tol is None:
        feas_tol = 1e-5 if dtype == jnp.float32 else 1e-7
    backend = RevisedBackend(m, n, tol, feas_tol, dtype, pricing=pricing,
                             refactor_period=refactor_period)
    state = backend.init(jnp.asarray(batch.A, dtype),
                         jnp.asarray(batch.b, dtype),
                         jnp.asarray(batch.c, dtype),
                         ub=jnp.asarray(batch.upper_bounds(), dtype),
                         warm=prepare_warm(warm, rec, batch),
                         telemetry=telemetry)
    B = batch.batch
    orig = np.arange(B, dtype=np.int64)
    cfg = CompactionConfig(
        segment_k=int(segment_k),
        compact_threshold=resolve_compact_threshold(
            compact_threshold, int(segment_k)),
        pad_multiple=backend.pad_multiple)
    return finish_result(rec, run_schedule(backend, state, orig, B, n,
                                           max_iters=int(max_iters),
                                           config=cfg, stats_out=stats_out,
                                           tracer=tracer))
