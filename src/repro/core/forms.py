"""General-form LP batches and the canonicalization pipeline.

Real LP suites (Netlib here; MIPLIB-derived batches elsewhere) are
*general-form*: min/max objectives, ``<=`` / ``=`` / ``>=`` and ranged rows,
and variable lower/upper/free bounds — while every solver in this repo
consumes the paper's *standard form* (``max c.x  s.t.  A x <= b, x >= 0``,
core/lp.py).  This module is the bridge:

    GeneralLPBatch  --canonicalize()-->  (LPBatch, Recovery)

``canonicalize`` is an invertible, host-side (float64 NumPy) transform:

1. **presolve** (on by default): fixed-variable elimination (``lb == ub``),
   empty-column elimination (cost-optimal bound substitution), empty-row
   removal (with per-LP infeasibility detection folded into ``Recovery``);
2. **bound handling**: finite lower bounds are shifted out
   (``y = x - lb``), free variables (``lb = -inf``) are split into
   ``y+ - y-`` column pairs, finite upper bounds become the canonical
   batch's *native* ``LPBatch.ub`` vector (every engine runs the
   bounded-variable ratio test against it) — except bounds on split free
   columns, which still need a row (a bound on ``y+ - y-`` is not a
   column bound), and everything when ``bound_rows=True`` (the legacy
   one-dense-row-per-bound encoding, kept as an A/B reference);
3. **row senses**: ``>=`` rows are negated, ``=`` and ranged rows become a
   ``<=`` pair — equalities *grow m*, which is why the
   revised-vs-tableau work models (analysis/lp_perf.py) must be evaluated
   on canonical shapes;
4. **scaling** (on by default): geometric-mean row/column equilibration of
   the canonical data, with scales snapped to powers of two so the
   transform is mantissa-exact; unscaling is folded into ``Recovery``.
   Scaling never changes exact-arithmetic statuses but does change float32
   pivot paths — it is the f32 accuracy lever for badly-scaled instances
   (the paper's Sec. 6 concern).

``Recovery.recover`` maps an ``LPResult`` on the canonical batch back to
original coordinates: un-scale, un-split, un-shift, re-insert presolved
variables, re-apply the objective sense and constant, and override statuses
for LPs presolve proved infeasible.  The reported objective is *recomputed*
as ``c.x + c0`` in original coordinates, so result self-consistency is
exact by construction; ``general_violation`` provides the matching
original-space primal certificate check.

Every ``solve_*`` entry point accepts a ``GeneralLPBatch`` directly (it
canonicalizes on ingestion and recovers on the way out), so the tableau and
revised engines, compaction, pricing, shard_map and Pallas all compose
unchanged — they only ever see the canonical ``LPBatch``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np

from .lp import INFEASIBLE, OPTIMAL, LPBatch, LPResult, WarmStart

# Row senses (MPS letters).
LE, GE, EQ = "L", "G", "E"
SENSES = (LE, GE, EQ)


def _bcast(arr, shape, name, dtype=np.float64):
    """Broadcast per-structure (m,)/(n,) data against the batch axis."""
    out = np.asarray(arr, dtype=dtype)
    if out.ndim == len(shape) - 1:
        out = np.broadcast_to(out[None], shape)
    if out.shape != shape:
        raise ValueError(f"{name}: expected shape {shape}, got {out.shape}")
    return np.ascontiguousarray(out)


@dataclasses.dataclass(frozen=True)
class GeneralLPBatch:
    """A batch of B general-form LPs sharing one structure.

        optimize  c . x + c0      (min by default — the MPS convention)
        s.t.      lo_i <= A_i . x <= hi_i    (senses/ranges per row)
                  lb <= x <= ub              (+-inf allowed)

    Numeric data (``A``, ``rhs``, ``lb``, ``ub``, ``c``, ``c0``) is per-LP;
    structure (``sense``, ``ranges``, names, objective direction) is shared
    across the batch so the canonical form has one static shape — the same
    same-size contract the paper's batches obey (perturbed copies of one
    instance, Sec. 6).
    """

    A: np.ndarray          # (B, m, n) float64
    sense: np.ndarray      # (m,) '<U1' in {L, G, E}
    rhs: np.ndarray        # (B, m)
    lb: np.ndarray         # (B, n), -inf for free-below
    ub: np.ndarray         # (B, n), +inf for unbounded-above
    c: np.ndarray          # (B, n)
    c0: np.ndarray         # (B,) objective constant
    maximize: bool = False
    ranges: Optional[np.ndarray] = None  # (m,), NaN = no range
    name: str = "general"
    row_names: Optional[Tuple[str, ...]] = None
    col_names: Optional[Tuple[str, ...]] = None
    integer: Optional[np.ndarray] = None  # (n,) bool — columns required
                                          # integral (structure, shared
                                          # across the batch).  Every LP
                                          # solver ignores it (solves the
                                          # continuous relaxation); the
                                          # branch-and-bound driver
                                          # (core/branch_bound.py) enforces
                                          # it by branching on lb/ub.

    @property
    def batch(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def n(self) -> int:
        return self.A.shape[2]

    @staticmethod
    def from_arrays(A, sense, rhs, *, lb=None, ub=None, c=None, c0=0.0,
                    maximize=False, ranges=None, name="general",
                    row_names=None, col_names=None,
                    integer=None) -> "GeneralLPBatch":
        A = np.asarray(A, dtype=np.float64)
        if A.ndim == 2:
            A = A[None]
        B, m, n = A.shape
        sense = np.asarray(sense, dtype="<U1").reshape(m)
        bad = ~np.isin(sense, SENSES)
        if bad.any():
            raise ValueError(f"unknown row senses {set(sense[bad])}; "
                             f"expected one of {SENSES}")
        rhs = _bcast(rhs, (B, m), "rhs")
        lb = _bcast(np.zeros(n) if lb is None else lb, (B, n), "lb")
        ub = _bcast(np.full(n, np.inf) if ub is None else ub, (B, n), "ub")
        c = _bcast(np.zeros(n) if c is None else c, (B, n), "c")
        c0 = np.broadcast_to(np.asarray(c0, np.float64), (B,)).copy()
        if ranges is not None:
            ranges = np.asarray(ranges, np.float64).reshape(m)
        if (lb > ub).any():
            raise ValueError("lb > ub on some variable")
        if integer is not None:
            integer = np.asarray(integer)
            if integer.dtype != bool:      # index list -> (n,) mask
                mask = np.zeros(n, bool)
                mask[integer.reshape(-1).astype(int)] = True
                integer = mask
            integer = integer.reshape(n)
            if not integer.any():
                integer = None
        return GeneralLPBatch(A=A, sense=sense, rhs=rhs, lb=lb, ub=ub, c=c,
                              c0=c0, maximize=bool(maximize), ranges=ranges,
                              name=name,
                              row_names=tuple(row_names) if row_names else None,
                              col_names=tuple(col_names) if col_names else None,
                              integer=integer)

    def row_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row activity interval (lo, hi), each (B, m), from sense +
        rhs + RANGES (MPS semantics: an ``E`` row's range sign picks the
        side the interval grows toward)."""
        B, m = self.rhs.shape
        lo = np.full((B, m), -np.inf)
        hi = np.full((B, m), np.inf)
        is_l = self.sense == LE
        is_g = self.sense == GE
        is_e = self.sense == EQ
        hi[:, is_l] = self.rhs[:, is_l]
        lo[:, is_g] = self.rhs[:, is_g]
        lo[:, is_e] = self.rhs[:, is_e]
        hi[:, is_e] = self.rhs[:, is_e]
        if self.ranges is not None:
            has = ~np.isnan(self.ranges)
            r = self.ranges
            sel = has & is_l
            lo[:, sel] = self.rhs[:, sel] - np.abs(r[sel])[None]
            sel = has & is_g
            hi[:, sel] = self.rhs[:, sel] + np.abs(r[sel])[None]
            sel = has & is_e & (r >= 0)
            hi[:, sel] = self.rhs[:, sel] + r[sel][None]
            sel = has & is_e & (r < 0)
            lo[:, sel] = self.rhs[:, sel] + r[sel][None]
        return lo, hi

    def objective_value(self, x: np.ndarray) -> np.ndarray:
        """c . x + c0 in original coordinates (the recovered objective)."""
        return np.einsum("bn,bn->b", self.c,
                         np.asarray(x, np.float64)) + self.c0

    def with_bounds(self, lb=None, ub=None) -> "GeneralLPBatch":
        """Validated copy-edit: the same batch with new variable bounds.

        The bound-edit entry point for branching (core/branch_bound.py) and
        MPC-style receding-horizon updates — everything except ``lb``/``ub``
        is shared with ``self`` (no data copies).  Accepts (n,), (1, n) or
        (B', n) arrays; when ``self`` holds a single LP, a (B', n) bound
        stack *broadcasts the batch*: the result is B' copies of the
        instance differing only in bounds (one frontier of branch-and-bound
        nodes, say).  Omitted sides keep their current values.  Raises on
        shape mismatches and on ``lb > ub``."""
        B, n = self.batch, self.n

        def norm(v, cur, what):
            if v is None:
                return cur
            v = np.asarray(v, np.float64)
            if v.ndim == 1:
                v = v[None]
            if v.ndim != 2 or v.shape[1] != n:
                raise ValueError(f"{what}: expected (n,)=({n},) or (B, {n}),"
                                 f" got {v.shape}")
            return v

        lb2 = norm(lb, self.lb, "lb")
        ub2 = norm(ub, self.ub, "ub")
        Bt = max(B, lb2.shape[0], ub2.shape[0])
        for what, v in (("lb", lb2), ("ub", ub2)):
            if v.shape[0] not in (1, Bt):
                raise ValueError(
                    f"{what} batch {v.shape[0]} incompatible with batch {Bt}")
        if Bt != B and B != 1:
            raise ValueError(
                f"cannot broadcast a batch of {B} to {Bt} bound rows "
                "(only single-instance batches broadcast)")
        ex = lambda a, shape: np.broadcast_to(a, shape)  # noqa: E731
        lb2 = ex(lb2, (Bt, n))
        ub2 = ex(ub2, (Bt, n))
        if (lb2 > ub2).any():
            raise ValueError("lb > ub on some variable")
        if Bt == B:
            return dataclasses.replace(self, lb=lb2, ub=ub2)
        return dataclasses.replace(
            self, A=ex(self.A, (Bt, self.m, n)), rhs=ex(self.rhs, (Bt, self.m)),
            c=ex(self.c, (Bt, n)), c0=ex(self.c0, (Bt,)), lb=lb2, ub=ub2)


def general_violation(g: GeneralLPBatch, x: np.ndarray) -> np.ndarray:
    """Max primal violation per LP of ``x`` in *original* coordinates
    (row activity intervals and variable bounds) — the original-space
    feasibility certificate used by tests and benchmarks."""
    x = np.asarray(x, np.float64)
    lo, hi = g.row_bounds()
    act = np.einsum("bmn,bn->bm", g.A, x)
    vrow = np.maximum(np.where(np.isfinite(lo), lo - act, 0.0),
                      np.where(np.isfinite(hi), act - hi, 0.0))
    vcol = np.maximum(np.where(np.isfinite(g.lb), g.lb - x, 0.0),
                      np.where(np.isfinite(g.ub), x - g.ub, 0.0))
    return np.maximum(vrow.max(axis=1, initial=0.0),
                      vcol.max(axis=1, initial=0.0))


def general_kkt(g: GeneralLPBatch, x: np.ndarray, y: np.ndarray,
                z: Optional[np.ndarray] = None) -> dict:
    """Full KKT check of a primal-dual pair in *original* coordinates — the
    certificate every backend's parity tests share (the dual-side extension
    of ``general_violation``).

    ``(y, z)`` follow the ``Recovery.recover_duals`` convention
    (``z = c - A^T y`` with the original objective; signs flip with the
    sense).  Returns per-LP (B,) arrays:

    * ``primal``          — ``general_violation`` (row + bound violations);
    * ``stationarity``    — ||z - (c - A^T y)||_inf (0 when z is derived);
    * ``dual_sign``       — multiplier-sign violations: a row dual pushing
                            against a bound the row does not have, a reduced
                            cost with the wrong sign for the variable's
                            bound structure (free variables need z = 0);
    * ``complementarity`` — positive multiplier x slack products: row duals
                            against their row slack, reduced costs against
                            their bound gaps;
    * ``max``             — the elementwise max of all four.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    zc = np.asarray(g.c, np.float64) - np.einsum("bmn,bm->bn", g.A, y)
    if z is None:
        z = zc
        stat = np.zeros(g.batch)
    else:
        z = np.asarray(z, np.float64)
        stat = np.abs(z - zc).max(axis=1, initial=0.0)
    csign = 1.0 if g.maximize else -1.0
    yh, zh = csign * y, csign * z            # max-form multipliers
    lo, hi = g.row_bounds()
    act = np.einsum("bmn,bn->bm", g.A, x)
    hi_f, lo_f = np.isfinite(hi), np.isfinite(lo)
    lb_f, ub_f = np.isfinite(g.lb), np.isfinite(g.ub)
    yp, ym = np.maximum(yh, 0.0), np.maximum(-yh, 0.0)
    zp, zm = np.maximum(zh, 0.0), np.maximum(-zh, 0.0)
    # max form: y+ needs a finite hi to push against, y- a finite lo;
    # z+ needs a finite ub (bound dual), z- a finite lb; free cols: z = 0.
    dual_sign = np.maximum(
        np.maximum(np.where(~hi_f, yp, 0.0), np.where(~lo_f, ym, 0.0))
        .max(axis=1, initial=0.0),
        np.maximum(np.where(~ub_f, zp, 0.0), np.where(~lb_f, zm, 0.0))
        .max(axis=1, initial=0.0))
    compl = np.maximum(
        np.maximum(yp * np.where(hi_f, np.maximum(hi - act, 0.0), 0.0),
                   ym * np.where(lo_f, np.maximum(act - lo, 0.0), 0.0))
        .max(axis=1, initial=0.0),
        np.maximum(zp * np.where(ub_f, np.maximum(g.ub - x, 0.0), 0.0),
                   zm * np.where(lb_f, np.maximum(x - g.lb, 0.0), 0.0))
        .max(axis=1, initial=0.0))
    primal = general_violation(g, x)
    return {
        "primal": primal, "stationarity": stat, "dual_sign": dual_sign,
        "complementarity": compl,
        "max": np.maximum(np.maximum(primal, stat),
                          np.maximum(dual_sign, compl)),
    }


def _pow2(s: np.ndarray) -> np.ndarray:
    """Snap positive scales to the nearest power of two (mantissa-exact
    scaling: equilibration then changes exponents only)."""
    return np.exp2(np.round(np.log2(s)))


def _equilibrate(A: np.ndarray, iters: int = 2):
    """Geometric-mean row/column equilibration of a (B, m, n) batch.
    Returns (row_scale (B, m), col_scale (B, n)), powers of two, such that
    ``row_scale[:, :, None] * A * col_scale[:, None, :]`` has row/column
    magnitude ranges centered near 1.  All-zero rows/columns get scale 1."""
    B, m, n = A.shape
    r = np.ones((B, m))
    s = np.ones((B, n))
    W = np.abs(A)
    for _ in range(iters):
        cur = W * r[:, :, None] * s[:, None, :]
        nz = cur > 0
        big = np.where(nz, cur, -np.inf).max(axis=2)
        small = np.where(nz, cur, np.inf).min(axis=2)
        ok = np.isfinite(big) & (big > 0)
        r = r * np.where(ok, 1.0 / np.sqrt(np.where(ok, big * small, 1.0)), 1.0)
        cur = W * r[:, :, None] * s[:, None, :]
        nz = cur > 0
        big = np.where(nz, cur, -np.inf).max(axis=1)
        small = np.where(nz, cur, np.inf).min(axis=1)
        ok = np.isfinite(big) & (big > 0)
        s = s * np.where(ok, 1.0 / np.sqrt(np.where(ok, big * small, 1.0)), 1.0)
    return _pow2(r), _pow2(s)


@dataclasses.dataclass(frozen=True)
class Recovery:
    """Invertible record of everything ``canonicalize`` did, sufficient to
    report an ``LPResult`` in original coordinates — primal solution *and*
    dual certificate (row duals + reduced costs)."""

    general: GeneralLPBatch
    kept: np.ndarray           # (nk,) original column indices that survived
    baseline: np.ndarray       # (B, n) presolved-variable values (0 elsewhere)
    shift: np.ndarray          # (B, nk) lower-bound shift (0 for free cols)
    free: np.ndarray           # (nk,) bool — column was split (has neg part)
    status_override: np.ndarray  # (B,) int16, -1 = none (presolve verdicts)
    col_scale: Optional[np.ndarray]  # (B, n_canonical) or None
    row_scale: Optional[np.ndarray]  # (B, m_canonical) or None
    m_canonical: int
    n_canonical: int
    # dual bookkeeping: which original rows survived presolve, and which
    # canonical row blocks they produced (canonical rows are ordered
    # [hi_rows | lo_rows | row-encoded ub rows] by construction; native
    # ``LPBatch.ub`` bounds emit no rows, their multipliers surface as
    # reduced costs, which ``recover_duals`` recomputes anyway)
    rows: np.ndarray = None      # (mk,) original row indices that survived
    hi_rows: np.ndarray = None   # indices into ``rows``: A x <= hi rows
    lo_rows: np.ndarray = None   # indices into ``rows``: -A x <= -lo rows
    # frozen structural decisions, recorded so ``rebind_bounds`` can re-run
    # the *numeric* part of canonicalize for new per-LP lb/ub without
    # re-deriving (and possibly flipping) the structure: presolve's verdict
    # per eliminated column, which kept columns carry row-encoded vs native
    # upper bounds (indices into the canonical column space)
    fixed_cols: np.ndarray = None    # original col idx: presolved lb == ub
    dropped_cols: np.ndarray = None  # original col idx: empty cols
                                     # substituted at a cost-optimal bound
    ub_cols: np.ndarray = None       # kept-col idx with an ub *row*
    native_cols: np.ndarray = None   # kept-col idx with a native LPBatch.ub

    def recover_x(self, x_can: np.ndarray) -> np.ndarray:
        """Canonical solution (B, n_canonical) -> original x (B, n)."""
        x_can = np.asarray(x_can, np.float64)
        if self.col_scale is not None:
            x_can = x_can * self.col_scale
        nk = len(self.kept)
        y = x_can[:, :nk].copy()
        if self.free.any():
            y[:, self.free] -= x_can[:, nk:]
        y += self.shift
        x = self.baseline.copy()
        x[:, self.kept] = y
        return x

    def recover_duals(self, y_can: np.ndarray):
        """Canonical row duals (B, m_canonical) -> original-coordinate
        ``(y, z)``.

        Canonical rows were emitted as [A x <= hi | -A x <= -lo | ub rows]
        over the presolve-surviving rows, so the original row dual is the
        unscaled hi-multiplier minus the lo-multiplier (E/ranged rows carry
        both); ub-row multipliers are *bound* duals and are deliberately
        folded into the reduced costs instead.  Convention: the returned
        pair satisfies ``z = c - A^T y`` with the **original** objective
        vector — for minimization this is the standard (HiGHS/scipy) sign
        convention (y <= 0 on active <=-rows, z >= 0 at active lower
        bounds); maximization flips every sign.  Presolve-dropped rows get
        dual 0; presolve-dropped columns still get a meaningful reduced
        cost because ``z`` is recomputed from the full original data."""
        g = self.general
        B, m = g.batch, g.m
        y_can = np.asarray(y_can, np.float64)
        if self.row_scale is not None:
            y_can = y_can * self.row_scale
        nh, nl = len(self.hi_rows), len(self.lo_rows)
        y_kept = np.zeros((B, len(self.rows)))
        y_kept[:, self.hi_rows] += y_can[:, :nh]
        y_kept[:, self.lo_rows] -= y_can[:, nh:nh + nl]
        y_max = np.zeros((B, m))
        y_max[:, self.rows] = y_kept          # canonical-max-form duals
        csign = 1.0 if g.maximize else -1.0
        y = csign * y_max
        z = np.asarray(g.c, np.float64) - np.einsum("bmn,bm->bn", g.A, y)
        return y, z

    def recover(self, res: LPResult) -> LPResult:
        """Map a canonical LPResult back to the original problem: original
        coordinates, original objective sense/constant, presolve status
        overrides applied.  The objective is recomputed as ``c.x + c0`` in
        original coordinates (NaN for non-optimal statuses, matching the
        solver convention); the dual certificate, when the backend produced
        one, is mapped through ``recover_duals`` under the same NaN mask."""
        x = self.recover_x(np.asarray(res.x))
        status = np.asarray(res.status).copy()
        ov = self.status_override >= 0
        status[ov] = self.status_override[ov].astype(status.dtype)
        obj = self.general.objective_value(x)
        opt = status == OPTIMAL
        obj = np.where(opt, obj, np.nan)
        y = z = None
        if res.y is not None:
            y, z = self.recover_duals(np.where(np.isnan(res.y), 0.0, res.y))
            y = np.where(opt[:, None], y, np.nan)
            z = np.where(opt[:, None], z, np.nan)
        # warm-start state stays in *canonical* coordinates (a basis has no
        # original-space meaning) but the equilibration scaling is peeled
        # off the iterate leaves: a perturbed follow-up batch re-scales with
        # its own factors at injection (prepare_warm)
        warm = res.warm
        if warm is not None:
            wx, wy = warm.x, warm.y
            if wx is not None and self.col_scale is not None:
                wx = np.asarray(wx) * self.col_scale
            if wy is not None and self.row_scale is not None:
                wy = np.asarray(wy) * self.row_scale
            warm = dataclasses.replace(warm, x=wx, y=wy)
        return LPResult(x=x, objective=obj, status=status,
                        iterations=np.asarray(res.iterations), y=y, z=z,
                        warm=warm, stats=res.stats)


def canonicalize(g: GeneralLPBatch, *, presolve: bool = True,
                 scale: Optional[bool] = None,
                 feas_tol: float = 1e-9,
                 bound_rows=False) -> Tuple[LPBatch, Recovery]:
    """General form -> the paper's standard form (see module docstring).

    ``scale=None`` follows ``presolve`` (equilibration is part of the
    default presolve pass); pass ``scale=False`` to canonicalize without
    touching the numbers — useful for A/B-ing f32 behavior.

    ``bound_rows=True`` restores the legacy encoding of finite upper
    bounds as one dense ``x_j <= ub_j`` row each; the default routes them
    into the canonical batch's native ``LPBatch.ub`` vector (zero extra
    rows).  Bounds on split free columns always stay rows — a bound on
    ``y+ - y-`` is not a column bound.  A (n,) bool mask forces *those*
    columns' bounds into rows and leaves the rest native: the
    branch-and-bound driver uses this for integer columns, because a
    bound edit that lands in ``b`` (a row's rhs) is repairable by the
    engines' warm-start phase-1 machinery, while a native ``ub`` edit
    under a stale basis is not (a basic variable above a freshly
    tightened native bound goes undetected).
    """
    if scale is None:
        scale = presolve
    B, m, n = g.batch, g.m, g.n
    lo, hi = g.row_bounds()
    A = np.asarray(g.A, np.float64)
    csign = 1.0 if g.maximize else -1.0
    cmax = csign * np.asarray(g.c, np.float64)   # standard form maximizes
    lb = np.asarray(g.lb, np.float64)
    ub = np.asarray(g.ub, np.float64)

    baseline = np.zeros((B, n))
    keep_col = np.ones(n, bool)
    keep_row = np.ones(m, bool)
    status_override = np.full(B, -1, np.int16)
    fixed = np.zeros(n, bool)
    droppable = np.zeros(n, bool)

    if presolve:
        # --- fixed variables: lb == ub for every batch member ------------
        fixed = (lb == ub).all(axis=0) & np.isfinite(lb).all(axis=0)
        # --- empty columns: structurally zero across the batch -----------
        empty = (A == 0.0).all(axis=(0, 1)) & ~fixed
        # value each member wants: the cost-optimal bound; keep the column
        # when any member's *optimizing* bound is infinite — dropping it
        # would hide unboundedness (the kept zero column's positive-cost
        # side then has no ratio row, so the solver certifies UNBOUNDED)
        want_ub = cmax > 0
        want_lb = cmax < 0
        val = np.where(want_ub, ub,
                       np.where(want_lb, lb,
                                np.where(np.isfinite(lb), lb, ub)))
        droppable = empty & np.isfinite(val).all(axis=0)
        sub = fixed | droppable
        if sub.any():
            baseline[:, fixed] = lb[:, fixed]
            baseline[:, droppable] = val[:, droppable]
            contrib = np.einsum("bmk,bk->bm", A[:, :, sub], baseline[:, sub])
            lo = lo - contrib
            hi = hi - contrib
            keep_col &= ~sub
        # --- empty rows (after column elimination) ------------------------
        empty_row = (A[:, :, keep_col] == 0.0).all(axis=(0, 2))
        if empty_row.any():
            bad = ((np.where(np.isfinite(lo), lo, -np.inf) > feas_tol)
                   | (np.where(np.isfinite(hi), hi, np.inf) < -feas_tol))
            status_override[bad[:, empty_row].any(axis=1)] = INFEASIBLE
            keep_row &= ~empty_row

    kept = np.flatnonzero(keep_col)
    rows = np.flatnonzero(keep_row)
    A = A[:, rows][:, :, kept]
    lo, hi = lo[:, rows], hi[:, rows]
    lbk, ubk, ck = lb[:, kept], ub[:, kept], cmax[:, kept]

    # --- bounds: shift finite lower bounds, split free columns -----------
    lb_fin = np.isfinite(lbk)
    mixed = lb_fin.any(axis=0) & ~lb_fin.all(axis=0)
    if mixed.any():
        raise ValueError(
            "lower-bound finiteness must be batch-uniform per column "
            f"(columns {np.flatnonzero(mixed)} mix finite and -inf): the "
            "canonical batch needs one static shape")
    free = ~lb_fin[0] if B else ~lb_fin.any(axis=0)
    shift = np.where(lb_fin, lbk, 0.0)
    contrib = np.einsum("bmk,bk->bm", A, shift)
    lo, hi = lo - contrib, hi - contrib
    ub_shifted = ubk - shift            # finite iff ub finite
    ub_fin = np.isfinite(ub_shifted)
    if (ub_fin.any(axis=0) & ~ub_fin.all(axis=0)).any():
        raise ValueError(
            "upper-bound finiteness must be batch-uniform per column: the "
            "canonical batch needs one static shape")
    bounded_cols = np.flatnonzero(ub_fin.all(axis=0)) if B else np.array([], int)
    # native bounds by default; row encoding for free (split) columns and,
    # under bound_rows=True (or per-column via a mask), for the selection
    if bound_rows is True:
        ub_cols = bounded_cols
    elif bound_rows is False:
        ub_cols = bounded_cols[free[bounded_cols]]
    else:
        forced = np.asarray(bound_rows, bool).reshape(n)[kept]
        ub_cols = bounded_cols[free[bounded_cols] | forced[bounded_cols]]
    native_cols = np.setdiff1d(bounded_cols, ub_cols)

    nk = len(kept)
    nf = int(free.sum())
    n_can = nk + nf
    hi_fin = np.isfinite(hi)
    lo_fin = np.isfinite(lo)
    # A row bound that is infinite for some members but finite for others
    # has no faithful static-shape encoding (substituting a large finite
    # bound would mis-report genuinely unbounded members as OPTIMAL), so
    # reject it — same contract as the variable-bound uniformity checks.
    mixed_rows = ((hi_fin.any(axis=0) & ~hi_fin.all(axis=0))
                  | (lo_fin.any(axis=0) & ~lo_fin.all(axis=0)))
    if mixed_rows.any():
        raise ValueError(
            "row-bound finiteness must be batch-uniform per row (rows "
            f"{np.flatnonzero(mixed_rows)} mix finite and infinite rhs): "
            "the canonical batch needs one static shape")
    hi_rows = np.flatnonzero(hi_fin.all(axis=0))
    lo_rows = np.flatnonzero(lo_fin.all(axis=0))
    m_can = len(hi_rows) + len(lo_rows) + len(ub_cols)

    A_can = np.zeros((B, m_can, n_can))
    b_can = np.zeros((B, m_can))
    pos = A if nf == 0 else np.concatenate([A, -A[:, :, free]], axis=2)
    r0 = len(hi_rows)
    A_can[:, :r0] = pos[:, hi_rows]
    b_can[:, :r0] = hi[:, hi_rows]
    r1 = r0 + len(lo_rows)
    A_can[:, r0:r1] = -pos[:, lo_rows]
    b_can[:, r0:r1] = -lo[:, lo_rows]
    # upper-bound rows: y_j <= ub' (free columns: y+ - y- <= ub')
    free_slot = np.cumsum(free) - 1      # index into the neg block
    for k, j in enumerate(ub_cols):
        i = r1 + k
        A_can[:, i, j] = 1.0
        if free[j]:
            A_can[:, i, nk + free_slot[j]] = -1.0
        b_can[:, i] = ub_shifted[:, j]
    c_can = ck if nf == 0 else np.concatenate([ck, -ck[:, free]], axis=1)
    # native upper bounds: a (B, n_can) vector instead of rows (split
    # negative parts are unbounded above)
    ub_can = np.full((B, n_can), np.inf)
    if len(native_cols):
        ub_can[:, native_cols] = ub_shifted[:, native_cols]

    # Degenerate shells: presolve can empty the canonical problem entirely
    # (every row redundant and/or every column substituted).  The solvers
    # need at least one row and one column, so pad with an inert 0.y <= 1
    # row / zero-cost zero column — neither changes the solution set, and
    # unboundedness along a padded-away direction is still caught (an empty
    # entering column has no ratio row).
    if n_can == 0:
        n_can = 1
        A_can = np.zeros((B, m_can, 1))
        c_can = np.zeros((B, 1))
        ub_can = np.full((B, 1), np.inf)
    if m_can == 0:
        m_can = 1
        A_can = np.zeros((B, 1, n_can))
        b_can = np.ones((B, 1))

    row_scale = col_scale = None
    if scale and m_can and n_can:
        row_scale, col_scale = _equilibrate(A_can)
        A_can = A_can * row_scale[:, :, None] * col_scale[:, None, :]
        b_can = b_can * row_scale
        c_can = c_can * col_scale
        # the solver variable is x_s = x / col_scale, so bounds scale too
        ub_can = ub_can / col_scale

    lp = LPBatch.from_arrays(A_can, b_can, c_can, ub=ub_can)
    rec = Recovery(general=g, kept=kept, baseline=baseline, shift=shift,
                   free=free, status_override=status_override,
                   col_scale=col_scale, row_scale=row_scale,
                   m_canonical=m_can, n_canonical=n_can,
                   rows=rows, hi_rows=hi_rows, lo_rows=lo_rows,
                   fixed_cols=np.flatnonzero(fixed),
                   dropped_cols=np.flatnonzero(droppable),
                   ub_cols=ub_cols, native_cols=native_cols)
    return lp, rec


def rebind_bounds(lp0: LPBatch, rec: Recovery, lb, ub, *,
                  feas_tol: float = 1e-9) -> Tuple[LPBatch, Recovery]:
    """Cheap per-LP bound-edit canonicalization: re-run only the *numeric*
    part of ``canonicalize`` for new variable bounds, reusing the parent's
    frozen structure (presolve masks, free splits, ub encoding, row blocks,
    pow2 scales).

    This is the branch-and-bound fast path: a frontier of B nodes differs
    from the root only in ``lb``/``ub``, so the canonical ``A``/``c`` are
    the root's (broadcast across the frontier — zero copies) and only the
    rhs, the lower-bound shift and the native bound vector are recomputed.
    Crucially the canonical *shape and column meaning are guaranteed
    stable* across every rebind of the same root, which is what lets a
    parent node's ``WarmStart`` carrier inject into its children — a full
    re-``canonicalize`` could flip a presolve mask mid-tree and silently
    drop every warm start.

    ``lp0``/``rec`` come from ``canonicalize(root)`` (root batch of 1, or
    of B matching the bound stacks); ``lb``/``ub`` are (B, n) bound stacks
    in original coordinates.  Raises ``ValueError`` when the new bounds
    are structurally incompatible with the frozen decisions (finiteness
    pattern changed, a presolved-fixed column un-fixed, a degenerate
    padded shell) — callers that can't guarantee stability should fall
    back to ``canonicalize``.
    """
    g0 = rec.general
    if rec.fixed_cols is None or rec.ub_cols is None:
        raise ValueError("rebind_bounds needs a Recovery produced by this "
                         "version's canonicalize (frozen masks missing)")
    m, n = g0.m, g0.n
    lb = np.asarray(lb, np.float64)
    ub = np.asarray(ub, np.float64)
    if lb.ndim == 1:
        lb = lb[None]
    if ub.ndim == 1:
        ub = ub[None]
    B = lb.shape[0]
    if lb.shape != (B, n) or ub.shape != (B, n):
        raise ValueError(f"bound stacks must be (B, {n}); got lb {lb.shape},"
                         f" ub {ub.shape}")
    if g0.batch not in (1, B):
        raise ValueError(f"root batch {g0.batch} incompatible with {B} "
                         "bound rows")
    if (lb > ub).any():
        raise ValueError("lb > ub on some variable")
    kept, rows = rec.kept, rec.rows
    nk, nf = len(kept), int(rec.free.sum())
    r0, r1 = len(rec.hi_rows), len(rec.hi_rows) + len(rec.lo_rows)
    if rec.n_canonical != nk + nf or rec.m_canonical != r1 + len(rec.ub_cols):
        raise ValueError("root canonicalized to a padded degenerate shell; "
                         "rebind_bounds cannot preserve it — re-canonicalize")

    # --- presolve contributions with the frozen verdicts -------------------
    lo, hi = g0.row_bounds()
    lo = np.ascontiguousarray(np.broadcast_to(lo, (B, m)))
    hi = np.ascontiguousarray(np.broadcast_to(hi, (B, m)))
    A0 = np.asarray(g0.A, np.float64)
    csign = 1.0 if g0.maximize else -1.0
    cmax = np.broadcast_to(csign * np.asarray(g0.c, np.float64), (B, n))
    baseline = np.zeros((B, n))
    fx = rec.fixed_cols
    if len(fx):
        if (lb[:, fx] != ub[:, fx]).any():
            raise ValueError(
                "a presolved-fixed column is no longer fixed (lb != ub); "
                "the frozen structure cannot represent it — re-canonicalize")
        baseline[:, fx] = lb[:, fx]
    dr = rec.dropped_cols
    if len(dr):
        val = np.where(cmax[:, dr] > 0, ub[:, dr],
                       np.where(cmax[:, dr] < 0, lb[:, dr],
                                np.where(np.isfinite(lb[:, dr]), lb[:, dr],
                                         ub[:, dr])))
        if not np.isfinite(val).all():
            raise ValueError(
                "an eliminated empty column's cost-optimal bound became "
                "infinite under the new bounds — re-canonicalize")
        baseline[:, dr] = val
    sub = np.concatenate([fx, dr])
    if len(sub):
        Ab = np.broadcast_to(A0, (B, m, n))
        contrib = np.einsum("bmk,bk->bm", Ab[:, :, sub], baseline[:, sub])
        lo -= contrib
        hi -= contrib
    status_override = np.full(B, -1, np.int16)
    dropped_rows = np.setdiff1d(np.arange(m), rows)
    if len(dropped_rows):
        bad = ((np.where(np.isfinite(lo), lo, -np.inf) > feas_tol)
               | (np.where(np.isfinite(hi), hi, np.inf) < -feas_tol))
        status_override[bad[:, dropped_rows].any(axis=1)] = INFEASIBLE

    # --- shift + bound vectors over the kept columns -----------------------
    lo, hi = lo[:, rows], hi[:, rows]
    lbk, ubk = lb[:, kept], ub[:, kept]
    lb_fin = np.isfinite(lbk)
    if (lb_fin != ~rec.free[None, :]).any():
        raise ValueError(
            "lower-bound finiteness changed vs the root (a free column "
            "gained a finite lb or vice versa); the frozen free-split "
            "structure cannot represent it — re-canonicalize")
    shift = np.where(lb_fin, lbk, 0.0)
    Ak = np.broadcast_to(A0[:, rows][:, :, kept], (B, len(rows), nk))
    contrib = np.einsum("bmk,bk->bm", Ak, shift)
    lo, hi = lo - contrib, hi - contrib
    ub_shifted = ubk - shift
    bounded = np.zeros(nk, bool)
    bounded[rec.ub_cols] = True
    bounded[rec.native_cols] = True
    if (np.isfinite(ub_shifted) != bounded[None, :]).any():
        raise ValueError(
            "upper-bound finiteness changed vs the root; the frozen bound "
            "encoding cannot represent it — re-canonicalize")

    b_can = np.empty((B, rec.m_canonical))
    b_can[:, :r0] = hi[:, rec.hi_rows]
    b_can[:, r0:r1] = -lo[:, rec.lo_rows]
    for k, j in enumerate(rec.ub_cols):
        b_can[:, r1 + k] = ub_shifted[:, j]
    ub_can = np.full((B, rec.n_canonical), np.inf)
    if len(rec.native_cols):
        ub_can[:, rec.native_cols] = ub_shifted[:, rec.native_cols]
    if rec.row_scale is not None:
        b_can = b_can * rec.row_scale
        ub_can = ub_can / rec.col_scale

    # per-LP equilibration scales make the canonical A/c per-LP only when
    # the root itself was a batch; a B=1 root broadcasts for free
    A_t, c_t = np.asarray(lp0.A), np.asarray(lp0.c)
    if A_t.shape[0] != B:
        A_t = np.broadcast_to(A_t[:1], (B,) + A_t.shape[1:])
        c_t = np.broadcast_to(c_t[:1], (B,) + c_t.shape[1:])
    lp = LPBatch.from_arrays(A_t, b_can, c_t, ub=ub_can)
    rec_new = dataclasses.replace(
        rec, general=g0.with_bounds(lb=lb, ub=ub), baseline=baseline,
        shift=shift, status_override=status_override)
    return lp, rec_new


def canonical_shape(g: GeneralLPBatch, *, presolve: bool = True,
                    bound_rows: bool = False) -> Tuple[int, int]:
    """(m, n) of the canonical standard-form batch ``canonicalize`` would
    produce — the shape the work models must be evaluated at (equalities
    grow m; free variables grow n; finite upper bounds grow m only under
    ``bound_rows=True`` or on free columns).

    Computed *analytically* from the bound/row finiteness masks — the
    presolve keep/drop masks and the shift-invariance of finiteness pin
    the shape down without materializing (or equilibrating) the canonical
    arrays, so per-workload callers (work models, launch/dryrun_lp.py)
    stop paying the full O(B*m*n) ``canonicalize``."""
    B, m, n = g.batch, g.m, g.n
    lo, hi = g.row_bounds()
    A = np.asarray(g.A, np.float64)
    csign = 1.0 if g.maximize else -1.0
    cmax = csign * np.asarray(g.c, np.float64)
    lb = np.asarray(g.lb, np.float64)
    ub = np.asarray(g.ub, np.float64)

    keep_col = np.ones(n, bool)
    keep_row = np.ones(m, bool)
    if presolve:
        # same keep/drop masks as canonicalize's presolve pass
        fixed = (lb == ub).all(axis=0) & np.isfinite(lb).all(axis=0)
        empty = (A == 0.0).all(axis=(0, 1)) & ~fixed
        val = np.where(cmax > 0, ub,
                       np.where(cmax < 0, lb,
                                np.where(np.isfinite(lb), lb, ub)))
        droppable = empty & np.isfinite(val).all(axis=0)
        keep_col &= ~(fixed | droppable)
        keep_row &= ~(A[:, :, keep_col] == 0.0).all(axis=(0, 2))

    kept = np.flatnonzero(keep_col)
    rows = np.flatnonzero(keep_row)
    # the lower-bound shift subtracts a finite contribution everywhere, so
    # row-bound and upper-bound *finiteness* are shift-invariant
    free = ~np.isfinite(lb[:, kept]).all(axis=0)
    nk = len(kept)
    n_can = nk + int(free.sum())
    ub_fin = np.isfinite(ub[:, kept]).all(axis=0)
    n_ub_rows = int(ub_fin.sum()) if bound_rows else int((ub_fin & free).sum())
    m_can = (int(np.isfinite(hi[:, rows]).all(axis=0).sum())
             + int(np.isfinite(lo[:, rows]).all(axis=0).sum())
             + n_ub_rows)
    return max(m_can, 1), max(n_can, 1)


def ensure_canonical(batch, *, presolve: bool = True,
                     scale: Optional[bool] = None,
                     bound_rows: bool = False):
    """Entry-point shim: pass ``LPBatch`` through untouched; canonicalize a
    ``GeneralLPBatch``.  Returns (LPBatch, Recovery-or-None)."""
    if isinstance(batch, GeneralLPBatch):
        return canonicalize(batch, presolve=presolve, scale=scale,
                            bound_rows=bound_rows)
    return batch, None


def finish_result(rec, res: LPResult) -> LPResult:
    """Entry-point shim: apply ``Recovery`` when the input was general."""
    return res if rec is None else rec.recover(res)


def prepare_warm(warm: Optional[WarmStart], rec: Optional[Recovery],
                 batch: LPBatch) -> Optional[WarmStart]:
    """Validate a ``WarmStart`` against the canonical batch about to be
    solved and map its iterate leaves into the engine's scaled coordinates.

    The single validation gate every entry point routes through: a carrier
    whose batch/shape does not match (the follow-up batch changed size, or
    the perturbation changed the canonical shape) is *dropped with a
    warning* — warm starting is an optimization, never a correctness
    requirement, so shape drift degrades to a cold solve instead of
    erroring.  ``rec=None`` (the input was already canonical) skips the
    re-scaling and only validates."""
    if warm is None:
        return None
    B, m, n = batch.batch, batch.m, batch.n

    def drop(why):
        warnings.warn(f"warm start dropped ({why}); solving cold")
        return None

    if warm.m != m or warm.n != n:
        return drop(f"carrier is {warm.m}x{warm.n}, batch canonicalizes "
                    f"to {m}x{n}")
    if warm.batch != B:
        return drop(f"carrier batch {warm.batch} != batch size {B}")
    for field, rows, cols in (("basis", m, None), ("at_upper", n, None),
                              ("x", n, None), ("y", m, None),
                              ("omega", None, None), ("eta", None, None)):
        v = getattr(warm, field)
        if v is None:
            continue
        want = (B,) if rows is None else (B, rows)
        if np.asarray(v).shape != want:
            return drop(f"leaf {field!r} has shape {np.asarray(v).shape}, "
                        f"expected {want}")
    if rec is None:
        return warm
    wx, wy = warm.x, warm.y
    if wx is not None and rec.col_scale is not None:
        wx = np.asarray(wx) / rec.col_scale
    if wy is not None and rec.row_scale is not None:
        wy = np.asarray(wy) / rec.row_scale
    return dataclasses.replace(warm, x=wx, y=wy)


def random_general_lp_batch(rng: np.random.Generator, B: int, m: int, n: int,
                            *, eq_frac: float = 0.2, ge_frac: float = 0.3,
                            free_frac: float = 0.0, ranged_frac: float = 0.0,
                            bounded: bool = True,
                            maximize: Optional[bool] = None
                            ) -> GeneralLPBatch:
    """Random general-form batches built around a known interior point, for
    the canonicalize->solve->recover property tests.

    Row senses are drawn per structure (shared across the batch); row
    bounds are placed around ``A @ x0`` so every member is feasible, and
    with ``bounded=True`` every variable gets a finite upper bound so the
    canonical LP is bounded.  ``free_frac`` turns a fraction of columns
    free-below (exercising the split path; such batches may be unbounded —
    callers compare statuses rather than assume OPTIMAL).
    """
    if maximize is None:
        maximize = bool(rng.integers(2))
    A = rng.uniform(-3.0, 3.0, size=(B, m, n))
    A *= rng.uniform(size=(B, m, n)) < 0.6
    x0 = rng.uniform(0.5, 2.0, size=(B, n))
    act = np.einsum("bmn,bn->bm", A, x0)
    sense = np.where(
        rng.uniform(size=m) < eq_frac, EQ,
        np.where(rng.uniform(size=m) < ge_frac / max(1e-9, 1 - eq_frac),
                 GE, LE)).astype("<U1")
    margin = rng.uniform(0.1, 2.0, size=(B, m))
    rhs = np.where(sense[None, :] == EQ, act,
                   np.where(sense[None, :] == GE, act - margin, act + margin))
    ranges = None
    if ranged_frac > 0:
        # range >= the batch-max margin keeps x0 inside the two-sided row
        ranges = np.where(rng.uniform(size=m) < ranged_frac,
                          margin.max(axis=0) + rng.uniform(0.1, 2.0, size=m),
                          np.nan)
        ranges[sense == EQ] = np.nan   # keep E rows exact (simpler oracle)
    lb = np.where(rng.uniform(size=n) < 0.5,
                  rng.uniform(-1.0, 0.4, size=(B, n)), 0.0)
    lb = np.minimum(lb, x0 - 0.05)
    if free_frac > 0:
        lb[:, rng.uniform(size=n) < free_frac] = -np.inf
    if bounded:
        ub = x0 + rng.uniform(0.5, 3.0, size=(B, n))
    else:
        ub = np.where(rng.uniform(size=n) < 0.5,
                      x0 + rng.uniform(0.5, 3.0, size=(B, n)), np.inf)
    c = rng.uniform(-2.0, 2.0, size=(B, n))
    c0 = rng.uniform(-5.0, 5.0, size=B)
    return GeneralLPBatch.from_arrays(
        A, sense, rhs, lb=lb, ub=ub, c=c, c0=c0, maximize=maximize,
        ranges=ranges, name=f"random_general_{m}x{n}")
