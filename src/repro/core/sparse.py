"""Shared-pattern sparse batches + the sparse first-order solver.

The paper's batching premise — B LPs of *identical shape* solved in lockstep
— extends one level deeper for the workloads that actually motivate batching
(``io.mps.perturbed_batch``: one Netlib-style instance, B perturbations):
every batch member shares a single **sparsity pattern** and differs only in
its nonzero *values*.  Netlib-like LPs are 1-2% dense, so the dense
``(B, m, n)`` einsum pair that powers core/pdhg.py spends ~98% of its reads
on structural zeros.

``SparseLPBatch`` stores that workload natively: one ``(nnz,)`` coordinate
pattern (rows, cols) shared across the batch and a ``(B, nnz)`` value array.
The two PDHG matvecs become a gather + segment-scatter pair

    (A x)_i   = sum over k with rows[k] == i of  vals[:, k] * x[:, cols[k]]
    (A^T y)_j = sum over k with cols[k] == j of  vals[:, k] * y[:, rows[k]]

so per-iteration element traffic is ``2*nnz + 2*(m+n)`` instead of
``2*m*n + 2*(m+n)`` (see ``analysis.lp_perf.sparse_matvec_flops``).  The
pattern is a *compile-time constant* (NumPy indices baked into the jitted
computation), which is exactly what the shared-pattern restriction buys:
one compilation serves the whole batch, gathers vectorize over B.

Everything downstream of the matvecs — Ruiz equilibration, power-iteration
step sizes, the fused round/restart/certificate logic, extraction — is the
*same code* as the dense engine: core/pdhg.py touches A only through an
injectable ``Matvecs`` pair, and this module supplies the sparse pair.
Statuses/objectives therefore agree with dense PDHG to working precision
(the sums merely associate differently).

Only the first-order engine has a sparse entry point
(``backend_spec("pdhg").supports_sparse``): the simplex engines' tableaux
and basis factors fill in after a handful of pivots regardless of input
sparsity, so they stay dense by design.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .lp import LPBatch, LPResult
from .pdhg import (
    CHECK_EVERY,
    OMEGA_MAX,
    OMEGA_MIN,
    POWER_ITERS,
    RUIZ_ITERS,
    STEP_SAFETY,
    Matvecs,
    PdhgState,
    _check_pdhg_pricing,
    _RUNNING,
    default_pdhg_max_iters,
    extract_pdhg,
    pdhg_round,
)


def sparse_pdhg_elements(nnz: int, m: int, n: int) -> int:
    """State elements touched per sparse PDHG iteration: the two matvecs
    read the (B, nnz) values twice and write the four length-m/n vectors —
    the sparse counterpart of ``pdhg.pdhg_elements``."""
    return 2 * nnz + 2 * (m + n)


@dataclasses.dataclass(frozen=True)
class SparseLPBatch:
    """B LPs ``max c.x s.t. Ax <= b, 0 <= x <= ub`` sharing one sparsity
    pattern: COO coordinates ``(rows, cols)`` of length nnz (host NumPy —
    they become compile-time gather indices) and per-LP values ``(B, nnz)``.

    The batch is in **canonical form** by construction (inequality rows,
    nonnegative variables, optional native upper bounds); build one from an
    already-canonical dense ``LPBatch`` via ``from_dense``."""

    rows: np.ndarray            # (nnz,) int32 row coordinate of each entry
    cols: np.ndarray            # (nnz,) int32 col coordinate
    vals: np.ndarray            # (B, nnz) per-LP values
    b: np.ndarray               # (B, m)
    c: np.ndarray               # (B, n)
    m: int
    n: int
    ub: Optional[np.ndarray] = None   # (B, n) or None (all +inf)

    @property
    def batch(self) -> int:
        return self.vals.shape[0]

    @property
    def nnz(self) -> int:
        return self.rows.shape[0]

    @property
    def density(self) -> float:
        return self.nnz / float(self.m * self.n)

    def upper_bounds(self) -> np.ndarray:
        if self.ub is None:
            return np.full((self.batch, self.n), np.inf)
        return np.asarray(self.ub)

    @staticmethod
    def from_dense(batch: LPBatch, tol: float = 0.0) -> "SparseLPBatch":
        """Extract the shared pattern as the union of per-LP nonzeros
        (entries with |A| > tol in *any* member).  Members where a pattern
        entry happens to be zero simply carry a zero value — the pattern is
        shared, the values are not."""
        A = np.asarray(batch.A)
        mask = (np.abs(A) > tol).any(axis=0)
        rows, cols = np.nonzero(mask)
        return SparseLPBatch(
            rows=rows.astype(np.int32), cols=cols.astype(np.int32),
            vals=np.ascontiguousarray(A[:, rows, cols]),
            b=np.asarray(batch.b), c=np.asarray(batch.c),
            m=batch.m, n=batch.n, ub=batch.ub)

    def to_dense(self) -> LPBatch:
        """Materialize the dense ``(B, m, n)`` batch (A/B reference)."""
        A = np.zeros((self.batch, self.m, self.n), self.vals.dtype)
        A[:, self.rows, self.cols] = self.vals
        return LPBatch.from_arrays(A, self.b, self.c, ub=self.ub)


def sparse_matvecs(rows, cols, m: int, n: int) -> Matvecs:
    """The shared-pattern matvec pair as a ``pdhg.Matvecs`` closure over
    the (host-constant) pattern.  ``data`` is the (B, nnz) value array."""
    rows = jnp.asarray(np.asarray(rows, np.int32))
    cols = jnp.asarray(np.asarray(cols, np.int32))

    def ax(vals, x):
        B = vals.shape[0]
        prod = vals * x[:, cols]
        return jnp.zeros((B, m), vals.dtype).at[:, rows].add(prod)

    def aty(vals, y):
        B = vals.shape[0]
        prod = vals * y[:, rows]
        return jnp.zeros((B, n), vals.dtype).at[:, cols].add(prod)

    return Matvecs(ax=ax, aty=aty)


def _ruiz_equilibrate_sparse(vals, rows, cols, m: int, n: int,
                             iters: int = RUIZ_ITERS):
    """Sparse twin of ``pdhg.ruiz_equilibrate``: row/col inf-norms via
    segment scatter-max over the pattern.  Empty rows/columns keep scale 1
    (their scattered max stays 0, exactly the dense all-zero case)."""
    B = vals.shape[0]
    av = jnp.abs(vals)
    r = jnp.ones((B, m), vals.dtype)
    s = jnp.ones((B, n), vals.dtype)

    def body(_, rs):
        r, s = rs
        W = av * r[:, rows] * s[:, cols]
        rn = jnp.zeros((B, m), vals.dtype).at[:, rows].max(W)
        r = r / jnp.sqrt(jnp.where(rn > 0, rn, 1.0))
        W = av * r[:, rows] * s[:, cols]
        cn = jnp.zeros((B, n), vals.dtype).at[:, cols].max(W)
        s = s / jnp.sqrt(jnp.where(cn > 0, cn, 1.0))
        return r, s

    return jax.lax.fori_loop(0, iters, body, (r, s))


def _power_sigma_max_mv(vals, mv: Matvecs, n: int,
                        iters: int = POWER_ITERS) -> jax.Array:
    """``pdhg.power_sigma_max`` through the injectable matvecs."""
    B = vals.shape[0]
    v = jnp.full((B, n), 1.0 / np.sqrt(n), vals.dtype)

    def body(_, v):
        w = mv.aty(vals, mv.ax(vals, v))
        nw = jnp.linalg.norm(w, axis=1, keepdims=True)
        return w / jnp.where(nw > 0, nw, 1.0)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.maximum(jnp.linalg.norm(mv.ax(vals, v), axis=1), 1e-12)


def init_pdhg_state_sparse(vals, b, c, ub, rows, cols, m: int, n: int,
                           mv: Matvecs) -> PdhgState:
    """Sparse twin of ``pdhg.init_pdhg_state``: identical state layout with
    ``PdhgState.A`` holding the (B, nnz) *scaled value array* — every
    downstream consumer touches it only through ``mv``."""
    B = vals.shape[0]
    dtype = vals.dtype
    binf = jnp.abs(b).max(axis=1)
    cinf = jnp.abs(c).max(axis=1)
    r, s = _ruiz_equilibrate_sparse(vals, rows, cols, m, n)
    vs = vals * r[:, rows] * s[:, cols]
    bs = b * r
    cs = c * s
    if ub is None:
        ubs = jnp.full((B, n), jnp.inf, dtype)
    else:
        ubs = (jnp.asarray(ub, dtype) / s).astype(dtype)
    eta = STEP_SAFETY / _power_sigma_max_mv(vs, mv, n)
    nc = jnp.linalg.norm(cs, axis=1)
    nb = jnp.linalg.norm(bs, axis=1)
    omega = jnp.sqrt(jnp.where((nc > 0) & (nb > 0),
                               nc / jnp.maximum(nb, 1e-12), 1.0))
    omega = jnp.clip(omega, OMEGA_MIN, OMEGA_MAX)
    return PdhgState(
        A=vs, b=bs, c=cs, rsc=r, csc=s, ub=ubs,
        eta=eta[:, None].astype(dtype),
        omega=omega[:, None].astype(dtype),
        binf=binf, cinf=cinf,
        x=jnp.zeros((B, n), dtype), y=jnp.zeros((B, m), dtype),
        xs=jnp.zeros((B, n), dtype), ys=jnp.zeros((B, m), dtype),
        xr=jnp.zeros((B, n), dtype), yr=jnp.zeros((B, m), dtype),
        cnt=jnp.zeros((B,), dtype),
        last_res=jnp.full((B,), jnp.inf, dtype),
        prev_res=jnp.full((B,), jnp.inf, dtype),
        phase=jnp.full((B,), 2, jnp.int32),
        status=jnp.full((B,), _RUNNING, jnp.int32),
        iters=jnp.zeros((B,), jnp.int32))


# One jitted whole-solve per pattern: the coordinates are baked into the
# computation as constants, so the cache key is the pattern (plus shape).
# Re-solving perturbed batches of the same instance — the intended workload
# — hits both this cache and jit's own.
_CORE_CACHE: dict = {}


def _sparse_core(rows: np.ndarray, cols: np.ndarray, m: int, n: int):
    key = (rows.tobytes(), cols.tobytes(), m, n)
    core = _CORE_CACHE.get(key)
    if core is not None:
        return core
    mv = sparse_matvecs(rows, cols, m, n)
    r_idx = np.asarray(rows, np.int32)
    c_idx = np.asarray(cols, np.int32)

    @functools.partial(jax.jit,
                       static_argnames=("max_iters", "tol", "check_every"))
    def core(vals, b, c, ub, *, max_iters, tol, check_every):
        state = init_pdhg_state_sparse(vals, b, c, ub, r_idx, c_idx,
                                       m, n, mv)
        rounds = -(-int(max_iters) // int(check_every))

        def cond(carry):
            s, it = carry
            return jnp.any(s.status == _RUNNING) & (it < rounds)

        def body(carry):
            s, it = carry
            return (pdhg_round(s, tol=tol, check_every=check_every, mv=mv),
                    it + 1)

        state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
        return extract_pdhg(state, mv)

    _CORE_CACHE[key] = core
    return core


def solve_batched_pdhg_sparse(batch: SparseLPBatch, *, dtype=jnp.float32,
                              tol: Optional[float] = None,
                              feas_tol: Optional[float] = None,
                              max_iters: Optional[int] = None,
                              check_every: int = CHECK_EVERY,
                              pricing: str = "dantzig") -> LPResult:
    """Restarted PDHG over a shared-pattern sparse batch — the
    ``resolve_backend("pdhg", sparse=True)`` entry point.

    Same tolerance semantics and LPResult contract as
    ``pdhg.solve_batched_pdhg`` (statuses at ``tol``, native primal-dual
    certificate in ``y``/``z``); per-iteration element traffic is
    ``sparse_pdhg_elements(nnz, m, n)`` instead of the dense
    ``pdhg_elements(m, n)``.  Accepts ``SparseLPBatch`` only — for dense
    batches use the dense entry point, or ``SparseLPBatch.from_dense``
    when the pattern is genuinely shared and sparse."""
    if not isinstance(batch, SparseLPBatch):
        raise TypeError(
            "solve_batched_pdhg_sparse takes a SparseLPBatch; wrap a "
            "canonical dense batch with SparseLPBatch.from_dense(batch) "
            "or call the dense solver")
    _check_pdhg_pricing(pricing)
    del feas_tol
    m, n = batch.m, batch.n
    if max_iters is None:
        max_iters = default_pdhg_max_iters(m, n)
    if tol is None:
        tol = 1e-5 if dtype == jnp.float32 else 1e-8
    core = _sparse_core(np.asarray(batch.rows, np.int32),
                        np.asarray(batch.cols, np.int32), m, n)
    x, obj, status, iters, y, z = core(
        jnp.asarray(batch.vals, dtype), jnp.asarray(batch.b, dtype),
        jnp.asarray(batch.c, dtype),
        jnp.asarray(batch.upper_bounds(), dtype),
        max_iters=int(max_iters), tol=float(tol),
        check_every=int(check_every))
    return LPResult(x=np.asarray(x), objective=np.asarray(obj),
                    status=np.asarray(status), iterations=np.asarray(iters),
                    y=np.asarray(y), z=np.asarray(z))
