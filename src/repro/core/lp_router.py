"""MoE expert-capacity allocation as batched LPs — the paper's technique as a
first-class feature of the training framework.

Standard token-choice MoE fixes a uniform per-expert capacity
``C = S*k/E * capacity_factor`` and drops overflow tokens. Under skewed
routing this wastes slots on cold experts while hot experts drop tokens.
We instead solve, per token-group g, the small LP

    maximize   sum_e  u_ge * x_ge          (u = router demand mass per expert)
    subject to sum_e  x_ge       <= S*k    (total dispatch slots in the group)
               x_ge              <= c_max  (per-expert ceiling, memory bound)
               x_ge - d_ge       <= 0      (never allocate beyond demand)
               x  >= 0

whose solution is the per-expert slot allocation. One LP per group, E
variables, E+... constraints — exactly the paper's workload shape (batches of
thousands of dim-16..160 LPs), solved on-device by the batched simplex with
zero host round-trips. Gradients do not flow through the allocation
(stop-gradient), matching how capacity truncation is already treated.

STUB in one respect: this module solves each group's LP inline with a
fixed engine and a hand-rolled `_solve_core` call.  The ROADMAP item
"Streaming solve service: continuous batching over shape classes" names
the intended endpoint — routing these allocations (and any other
heterogeneous LP traffic) through a shared scheduler that buckets by
shape class, picks the backend from the BACKEND_REGISTRY capability
table + `analysis/lp_perf.py` crossover models, and refills device lanes
via `core/compaction.py` `FrontierScheduler` instead of dispatching
fixed batches.  Until that service exists, this stays a direct call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .lp import LPBatch
from .simplex import solve_batched_jax, _solve_core
from .lp import OPTIMAL


def expert_capacity_lp(demand: jax.Array, total_slots: float, c_max: float):
    """demand: (G, E) nonnegative routing mass per group/expert.
    Returns (G, E) slot allocations solving the LP above, computed on-device.

    The LP is solved in f32 by the batched simplex; the result is rounded
    down to integers and stop-gradiented by the caller.
    """
    G, E = demand.shape
    d = jax.lax.stop_gradient(demand.astype(jnp.float32))
    # One real constraint: sum_e x <= total_slots.  The per-expert ceilings
    # (x_e <= c_max, x_e <= d_e) fold into native variable upper bounds
    # ub_e = min(c_max, d_e) — the bounded ratio test handles them at zero
    # row cost, shrinking the tableau from (1+2E) x E to 1 x E.
    m = 1
    A = jnp.ones((G, 1, E), jnp.float32)
    b = jnp.full((G, 1), float(total_slots), jnp.float32)
    ub = jnp.minimum(jnp.full((G, E), float(c_max), jnp.float32), d)
    c = d + 1e-3  # maximize demand-weighted allocation; epsilon breaks ties
    x, obj, status, iters, _, _ = _solve_core(
        A, b, c, ub, m=m, n=E, max_iters=8 * (m + E) + 50, tol=1e-6,
        feas_tol=1e-5)
    # Fall back to uniform capacity for (numerically) unsolved groups.
    uniform = jnp.minimum(float(total_slots) / E, float(c_max))
    x = jnp.where((status == OPTIMAL)[:, None], x, uniform)
    return jax.lax.stop_gradient(x)
