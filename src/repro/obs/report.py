"""Per-solve aggregation: ``SolveReport`` attached as ``LPResult.stats``.

A ``SolveReport`` bundles the per-LP counter lanes collected by the
on-device telemetry plane (``obs.telemetry``) with the host-side span tree
(``obs.trace``) and the end-to-end wall-clock of the solve.  It supports
the same ``take`` / ``slice`` / ``concat`` algebra as ``WarmStart`` so the
chunked driver can split, solve, and reassemble reports alongside results,
and offers batch-level views (percentiles, histograms, a printable
summary) for bench scripts and the serving example.

NumPy-only — no JAX imports — so reports are cheap to hold on the host.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from .telemetry import ALL_LANES, F32_LANES, INT_LANES
from .trace import Span, spans_to_perfetto

__all__ = ["SolveReport", "Span", "report_from_counters",
           "INT_LANES", "F32_LANES", "ALL_LANES"]


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """Telemetry for one batched solve.

    ``counters`` maps lane name -> per-LP ``(B,)`` array (see
    ``obs.telemetry`` for lane semantics).  ``spans`` is the host span tree
    (empty for monolithic solves without a tracer).  ``wall_s`` is the
    end-to-end host wall-clock of the solve that produced it."""

    counters: dict
    spans: tuple = ()
    wall_s: float = 0.0
    backend: str = ""

    # -- shape algebra (mirrors WarmStart) ----------------------------------

    @property
    def batch_size(self) -> int:
        for v in self.counters.values():
            return int(np.asarray(v).shape[0])
        return 0

    def _map(self, fn) -> "SolveReport":
        return dataclasses.replace(
            self, counters={k: fn(np.asarray(v))
                            for k, v in self.counters.items()})

    def take(self, idx) -> "SolveReport":
        idx = np.asarray(idx)
        return self._map(lambda a: a[idx])

    def slice(self, start: int, stop: int) -> "SolveReport":
        return self._map(lambda a: a[start:stop])

    @staticmethod
    def concat(parts: Sequence["SolveReport | None"]) -> "SolveReport | None":
        """Concatenate chunk reports along the batch axis.  Any ``None``
        part drops the whole report (same contract as ``WarmStart``)."""
        parts = list(parts)
        if not parts or any(p is None for p in parts):
            return None
        counters = {k: np.concatenate([np.asarray(p.counters[k])
                                       for p in parts])
                    for k in parts[0].counters}
        spans = tuple(s for p in parts for s in p.spans)
        return SolveReport(counters=counters, spans=spans,
                           wall_s=sum(p.wall_s for p in parts),
                           backend=parts[0].backend)

    # -- per-lane views -----------------------------------------------------

    def lane(self, name: str) -> np.ndarray:
        return np.asarray(self.counters[name])

    @property
    def iterations(self) -> np.ndarray:
        """Per-LP iteration counts (phase-1 + phase-2 lanes); equals
        ``LPResult.iterations`` exactly on every engine."""
        return self.lane("phase1_iters") + self.lane("phase2_iters")

    @property
    def pivots(self) -> np.ndarray:
        return self.lane("phase1_pivots") + self.lane("phase2_pivots")

    def total(self, name: str):
        return self.lane(name).sum().item()

    def percentiles(self, name: str, qs=(50, 90, 99)) -> dict:
        vals = self.lane(name).astype(np.float64)
        return {f"p{q:g}": float(np.percentile(vals, q)) for q in qs}

    def histogram(self, name: str, bins: int = 16):
        """(counts, edges) histogram of one lane across the batch."""
        counts, edges = np.histogram(self.lane(name).astype(np.float64),
                                     bins=bins)
        return counts, edges

    # -- aggregates ---------------------------------------------------------

    def summary(self) -> dict:
        """JSON-friendly batch aggregate: per-lane totals, mean, p50/p99 and
        max for every lane that is not identically zero, plus wall-clock and
        derived throughput."""
        B = self.batch_size
        lanes = {}
        for name in self.counters:
            vals = self.lane(name).astype(np.float64)
            if not np.any(vals):
                continue
            lanes[name] = {
                "total": float(vals.sum()), "mean": float(vals.mean()),
                "p50": float(np.percentile(vals, 50)),
                "p99": float(np.percentile(vals, 99)),
                "max": float(vals.max()),
            }
        out = {"batch_size": B, "backend": self.backend,
               "wall_s": self.wall_s, "lanes": lanes,
               "iterations_total": int(self.iterations.sum())}
        if self.wall_s > 0 and B:
            out["solves_per_sec"] = B / self.wall_s
        return out

    def render(self) -> str:
        """Human-readable multi-line summary table."""
        s = self.summary()
        lines = [f"SolveReport backend={s['backend'] or '?'} "
                 f"B={s['batch_size']} wall={s['wall_s']:.4f}s "
                 f"iters_total={s['iterations_total']}"]
        if "solves_per_sec" in s:
            lines[0] += f" solves/s={s['solves_per_sec']:.1f}"
        w = max((len(k) for k in s["lanes"]), default=0)
        for name, st in s["lanes"].items():
            lines.append(
                f"  {name:<{w}}  total={st['total']:>12g}  "
                f"mean={st['mean']:>10.2f}  p50={st['p50']:>8g}  "
                f"p99={st['p99']:>10g}  max={st['max']:>10g}")
        return "\n".join(lines)

    # -- exporters ----------------------------------------------------------

    def to_perfetto(self, path: str | None = None) -> dict:
        """Chrome/Perfetto trace-event JSON of the span tree."""
        return spans_to_perfetto(list(self.spans), path=path)

    def to_json(self, path: str | None = None) -> str:
        doc = {"summary": self.summary(),
               "spans": [s.to_dict() for s in self.spans]}
        text = json.dumps(doc, sort_keys=True)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text


def report_from_counters(counters: dict, *, spans=(), wall_s: float = 0.0,
                         backend: str = "") -> SolveReport:
    """Build a report from host counter arrays (engine extraction path)."""
    return SolveReport(counters={k: np.asarray(v) for k, v in
                                 counters.items()},
                       spans=tuple(spans), wall_s=float(wall_s),
                       backend=backend)
