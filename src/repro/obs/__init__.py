"""Solver observability plane: on-device counters, span tracing, reports.

Three layers (see ``docs/architecture.md`` § Observability):

* ``obs.telemetry`` — ``TelemetryState``, the per-LP counter pytree that
  rides through engine states, compaction gathers, the chunked driver and
  the Pallas segment kernels when ``telemetry=True``.
* ``obs.trace`` — ``SpanTracer``, nested host-side wall-clock spans with a
  JSONL event stream and a Chrome/Perfetto trace-event exporter.
* ``obs.report`` — ``SolveReport``, the per-solve aggregate attached as
  ``LPResult.stats``.

``obs.work`` holds the shared tableau-element work accounting used by both
``analysis/lp_perf.py`` and ``benchmarks/pivot_work.py``.
"""
from .report import SolveReport, report_from_counters
from .telemetry import (ALL_LANES, F32_LANES, INT_LANES, TelemetryState,
                        init_telemetry, tel_to_numpy)
from .trace import Span, SpanTracer, spans_to_perfetto
from .work import element_updates_lockstep, lockstep_steps

__all__ = [
    "SolveReport", "report_from_counters",
    "TelemetryState", "init_telemetry", "tel_to_numpy",
    "ALL_LANES", "INT_LANES", "F32_LANES",
    "Span", "SpanTracer", "spans_to_perfetto",
    "element_updates_lockstep", "lockstep_steps",
]
