"""Shared tableau-element work accounting.

The single source of truth for "how many tableau elements did a lockstep
batched solve touch" — previously duplicated between
``analysis/lp_perf.py`` (the analytical model) and
``benchmarks/pivot_work.py`` (the bench's bespoke copy).  Both now call
here, so BENCH rows and user-facing telemetry can never drift apart.

``repro.core`` is imported lazily inside the functions to keep the obs
package importable before (and independent of) the engine modules.
"""
from __future__ import annotations

import numpy as np


def lockstep_steps(iters) -> int:
    """Device steps a non-compacted lockstep solve executes for a batch
    with these per-LP iteration counts: every LP rides until the slowest
    finishes, plus the final all-converged check step."""
    iters = np.asarray(iters)
    return int(iters.max()) + 1 if iters.size else 0


def element_updates_lockstep(iters, m: int, n: int, *,
                             compacted: bool = False) -> float:
    """Tableau-element updates of a lockstep (non-scheduled) batched solve:
    ``(max(iters) + 1) * B * tableau_elements(m, n)``.

    ``iters`` may be per-LP iteration counts from ``LPResult.iterations``
    or the telemetry plane's ``phase1_iters + phase2_iters`` (identical by
    construction)."""
    from repro.core.simplex import tableau_elements

    iters = np.asarray(iters)
    return float(lockstep_steps(iters) * iters.size
                 * tableau_elements(m, n, compacted=compacted))
