"""Host-side span tracer: nested wall-clock spans + structured event stream.

The tracer records the host-visible shape of a solve — canonicalize →
dispatch → segment k → bucket gather → recover — as a tree of ``Span``
objects with wall-clock bounds and arbitrary key/value args (lane
occupancy, bucket size, survivor counts).  Instantaneous events (an LP
retiring, a B&B node fathoming, a frontier admit) land in the same stream.

Two exporters:

* ``to_jsonl()`` — one JSON object per line, in completion order; the
  structured event stream that unifies ``SegmentStat`` logs and
  ``FrontierScheduler`` lifecycle events.
* ``to_perfetto()`` — Chrome/Perfetto trace-event JSON (``ph: "X"``
  complete events for spans, ``ph: "i"`` instants), loadable at
  https://ui.perfetto.dev or chrome://tracing.

Pure host/NumPy-free module: only ``time``/``json``/``dataclasses``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Callable


@dataclasses.dataclass
class Span:
    """One timed region.  ``t0``/``t1`` are seconds on the tracer clock."""

    name: str
    t0: float
    t1: float = 0.0
    depth: int = 0
    args: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    @property
    def dur_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def to_dict(self) -> dict:
        return {
            "type": "span", "name": self.name, "t0": self.t0, "t1": self.t1,
            "dur_s": self.dur_s, "depth": self.depth, "args": dict(self.args),
            "children": [c.to_dict() for c in self.children],
            "events": [dict(e) for e in self.events],
        }

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


class SpanTracer:
    """Records a tree of nested spans plus instantaneous events."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._origin = clock()
        self._stack: list[Span] = []
        self.roots: list[Span] = []
        self.root_events: list[dict] = []  # events recorded with no open span
        self._log: list[dict] = []  # completion-order structured stream

    def _now(self) -> float:
        return self._clock() - self._origin

    @contextmanager
    def span(self, name: str, **args: Any):
        s = Span(name=name, t0=self._now(), depth=len(self._stack),
                 args=dict(args))
        (self._stack[-1].children if self._stack else self.roots).append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.t1 = self._now()
            self._stack.pop()
            d = s.to_dict()
            d.pop("children")  # the stream is flat; nesting is via depth
            d.pop("events")
            self._log.append(d)

    def event(self, name: str, **args: Any) -> None:
        """Record an instantaneous event under the current span (or at the
        root when no span is open)."""
        e = {"type": "event", "name": name, "ts": self._now(),
             "depth": len(self._stack), "args": dict(args)}
        target = self._stack[-1].events if self._stack else self.root_events
        target.append({"name": name, "ts": e["ts"], "args": e["args"]})
        self._log.append(e)

    # -- exporters ----------------------------------------------------------

    def to_jsonl(self, path: str | None = None) -> str:
        """Structured event stream: one JSON object per line, in completion
        order (events when recorded, spans when closed)."""
        text = "\n".join(json.dumps(rec, sort_keys=True) for rec in self._log)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text + ("\n" if text else ""))
        return text

    def to_perfetto(self, path: str | None = None, *, pid: int = 1,
                    tid: int = 1) -> dict:
        return spans_to_perfetto(self.roots, path=path, pid=pid, tid=tid,
                                 extra_events=self.root_events)


def spans_to_perfetto(roots, path: str | None = None, *, pid: int = 1,
                      tid: int = 1, extra_events=()) -> dict:
    """Chrome trace-event JSON from a span tree (``ph:"X"`` complete events
    with microsecond timestamps; instants as ``ph:"i"``)."""
    trace_events = []
    for e in extra_events:
        trace_events.append({
            "name": e["name"], "ph": "i", "cat": "solve", "s": "t",
            "ts": round(e["ts"] * 1e6, 3), "pid": pid, "tid": tid,
            "args": _jsonable(e["args"]),
        })
    for root in roots:
        for s in root.walk():
            trace_events.append({
                "name": s.name, "ph": "X", "cat": "solve",
                "ts": round(s.t0 * 1e6, 3), "dur": round(s.dur_s * 1e6, 3),
                "pid": pid, "tid": tid, "args": _jsonable(s.args),
            })
            for e in s.events:
                trace_events.append({
                    "name": e["name"], "ph": "i", "cat": "solve", "s": "t",
                    "ts": round(e["ts"] * 1e6, 3), "pid": pid, "tid": tid,
                    "args": _jsonable(e["args"]),
                })
    doc = {"traceEvents": trace_events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return doc


def _jsonable(args: dict) -> dict:
    out = {}
    for k, v in args.items():
        if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
            v = v.item()
        elif hasattr(v, "tolist"):
            v = v.tolist()
        out[k] = v
    return out
