"""On-device telemetry counter plane: per-LP solver counters as a pytree.

``TelemetryState`` is a NamedTuple of per-LP ``(B,)`` lanes that rides as a
trailing ``tel`` field on every engine state (``SimplexState`` /
``RevisedState`` / ``PdhgState``, the compaction scheduler's
``CompactionState`` and the padded tile-kernel carriers).  The trick that
makes it zero-cost when disabled: JAX treats ``None`` as an *empty pytree
subtree*, so a state whose ``tel`` leaf is ``None`` has exactly the same
flattened structure — and therefore exactly the same traced jaxpr — as a
state without the field at all.  Engines only touch the counters behind a
Python-level ``if state.tel is not None:`` branch, so ``telemetry=False``
(the default) traces today's program bit-for-bit, while ``telemetry=True``
retraces with the counter lanes woven into the while-loop carries, the
compaction gathers (``tree_map`` over the state handles them for free), the
chunked driver's permutes and the shard_map specs.

Lane semantics (every lane is per-LP, shape ``(B,)``):

int32 lanes
    ``phase1_iters`` / ``phase2_iters`` — the engine's ``iterations``
      counter split by the *pre-update* phase of each counted step.  By
      construction ``phase1_iters + phase2_iters == LPResult.iterations``
      exactly: the increment mask is the same one the engines apply to
      ``iters`` (it includes phase-transition and terminal-check steps, so
      pivots + flips alone would *not* reproduce it).
    ``phase1_pivots`` / ``phase2_pivots`` — executed basis-changing pivots
      per phase (excludes bound flips and transition steps).
    ``bound_flips`` — bounded-ratio-test flips (an entering column hit its
      own upper bound; O(1) bookkeeping instead of a pivot).
    ``degenerate_pivots`` — pivots whose min-ratio was exactly zero (the
      step changed the basis but not the iterate).
    ``refactorizations`` — revised engine: LU refactorizations (eta file
      reset); counted host-side at segment boundaries on the Pallas path.
    ``eta_len`` — revised engine: eta-file length at termination.
    ``block_rotations`` — revised engine partial pricing: steps where the
      LP's rotating block priced out and the full fallback pass (which
      also carries the optimality test) was consulted.
    ``restarts`` — PDHG: adopted restarts (average or current iterate).

float32 lanes
    ``kkt_primal`` / ``kkt_dual`` / ``kkt_gap`` — PDHG: the last KKT
      residual triple measured at a check round (the components whose max
      is the convergence test).
    ``omega`` — PDHG: primal weight at termination.

Lanes an engine does not own stay zero — a ``SolveReport`` keyed off these
counters is backend-uniform by construction.

This module deliberately imports nothing from ``repro.core`` (the engine
modules import *it*), keeping the dependency edge one-way.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

# Lane registries: the packing order used when telemetry rides through a
# Pallas kernel as dense (tile_b, LANES) rows (see ``tel_to_rows``).
INT_LANES = (
    "phase1_iters", "phase2_iters", "phase1_pivots", "phase2_pivots",
    "bound_flips", "degenerate_pivots", "refactorizations", "eta_len",
    "block_rotations", "restarts",
)
F32_LANES = ("kkt_primal", "kkt_dual", "kkt_gap", "omega")
ALL_LANES = INT_LANES + F32_LANES

# name -> column index inside the packed kernel rows
INT_LANE = {name: i for i, name in enumerate(INT_LANES)}
F32_LANE = {name: i for i, name in enumerate(F32_LANES)}
# packed-row widths (padded to a power of two so the tile layouts stay
# simple; the extra columns are dead)
INT_ROW_WIDTH = 16
F32_ROW_WIDTH = 8


class TelemetryState(NamedTuple):
    """Per-LP counter lanes; every leaf is shape (B,).  See module doc."""

    phase1_iters: Any
    phase2_iters: Any
    phase1_pivots: Any
    phase2_pivots: Any
    bound_flips: Any
    degenerate_pivots: Any
    refactorizations: Any
    eta_len: Any
    block_rotations: Any
    restarts: Any
    kkt_primal: Any
    kkt_dual: Any
    kkt_gap: Any
    omega: Any


def init_telemetry(B: int) -> TelemetryState:
    """All-zero counter lanes for a batch of ``B`` LPs."""
    zi = jnp.zeros((B,), jnp.int32)
    zf = jnp.zeros((B,), jnp.float32)
    return TelemetryState(*([zi] * len(INT_LANES) + [zf] * len(F32_LANES)))


def _count(mask):
    """bool mask of shape (B,) or (B, 1) -> int32 increment of shape (B,)."""
    m = mask.astype(jnp.int32)
    return m[:, 0] if m.ndim == 2 else m


def _flat(v):
    """(B,) or (B, 1) float -> (B,) float32."""
    v = v.astype(jnp.float32)
    return v[:, 0] if v.ndim == 2 else v


def tel_simplex_update(tel: TelemetryState, *, inc, in_phase1, do_pivot,
                       do_flip, degenerate) -> TelemetryState:
    """One simplex step (tableau or revised): ``inc`` is the exact mask the
    engine adds to ``iters`` this step, ``in_phase1`` the pre-update phase,
    ``do_pivot``/``do_flip``/``degenerate`` the step-kind masks.  All masks
    may be (B,) or (B, 1) bool."""
    inc, p1 = _count(inc).astype(bool), _count(in_phase1).astype(bool)
    piv = _count(do_pivot).astype(bool)
    return tel._replace(
        phase1_iters=tel.phase1_iters + _count(inc & p1),
        phase2_iters=tel.phase2_iters + _count(inc & ~p1),
        phase1_pivots=tel.phase1_pivots + _count(piv & p1),
        phase2_pivots=tel.phase2_pivots + _count(piv & ~p1),
        bound_flips=tel.bound_flips + _count(do_flip),
        degenerate_pivots=tel.degenerate_pivots + _count(piv & _count(
            degenerate).astype(bool)))


def tel_revised_update(tel: TelemetryState, *, refactor=None, eta_len=None,
                       block_rotation=None) -> TelemetryState:
    """Revised-engine extras: ``refactor`` (bool mask or scalar bool) bumps
    the refactorization count, ``eta_len`` overwrites the eta-file length
    lane (absolute, not incremental), ``block_rotation`` bumps the partial
    pricing rotation count."""
    kw = {}
    if refactor is not None:
        r = refactor if hasattr(refactor, "astype") else jnp.asarray(refactor)
        r = r.astype(jnp.int32)
        if r.ndim == 0:
            r = jnp.broadcast_to(r, tel.refactorizations.shape)
        kw["refactorizations"] = tel.refactorizations + _count(r)
    if eta_len is not None:
        e = eta_len.astype(jnp.int32)
        if e.ndim == 0:
            e = jnp.broadcast_to(e, tel.eta_len.shape)
        kw["eta_len"] = _count(e)
    if block_rotation is not None:
        kw["block_rotations"] = tel.block_rotations + _count(block_rotation)
    return tel._replace(**kw) if kw else tel


def tel_pdhg_update(tel: TelemetryState, *, inc_iters=None, restart=None,
                    kkt=None, omega=None) -> TelemetryState:
    """One PDHG check round: ``inc_iters`` adds to ``phase2_iters`` (the
    engine has no phase 1), ``restart`` counts adopted restarts, ``kkt`` is
    the (rp, rd, gap) residual triple of this round (overwrites — "last
    measured"), ``omega`` the current primal weight (overwrites)."""
    kw = {}
    if inc_iters is not None:
        kw["phase2_iters"] = tel.phase2_iters + _count(inc_iters)
    if restart is not None:
        kw["restarts"] = tel.restarts + _count(restart)
    if kkt is not None:
        rp, rd, gap = kkt
        kw["kkt_primal"] = _flat(rp)
        kw["kkt_dual"] = _flat(rd)
        kw["kkt_gap"] = _flat(gap)
    if omega is not None:
        kw["omega"] = _flat(omega)
    return tel._replace(**kw)


# ---------------------------------------------------------------------------
# Packed-row conversion for the Pallas segment kernels: a TelemetryState
# becomes one (B, INT_ROW_WIDTH) int32 row plus one (B, F32_ROW_WIDTH)
# float32 row, updated in-kernel via the INT_LANE/F32_LANE column indices.
# ---------------------------------------------------------------------------

def tel_to_rows(tel: TelemetryState):
    """Pack counter lanes into the dense (B, W) rows the tile kernels carry
    through VMEM.  Returns (int_rows, f32_rows)."""
    B = tel.phase1_iters.shape[0]
    ints = jnp.zeros((B, INT_ROW_WIDTH), jnp.int32)
    for name in INT_LANES:
        ints = ints.at[:, INT_LANE[name]].set(
            getattr(tel, name).astype(jnp.int32))
    f32s = jnp.zeros((B, F32_ROW_WIDTH), jnp.float32)
    for name in F32_LANES:
        f32s = f32s.at[:, F32_LANE[name]].set(
            getattr(tel, name).astype(jnp.float32))
    return ints, f32s


def rows_to_tel(int_rows, f32_rows) -> TelemetryState:
    """Inverse of ``tel_to_rows``."""
    kw = {name: int_rows[:, INT_LANE[name]] for name in INT_LANES}
    kw.update({name: f32_rows[:, F32_LANE[name]] for name in F32_LANES})
    return TelemetryState(**kw)


def lane_add(row, lane: int, mask):
    """In-kernel helper: add a (tile_b, 1) bool/int mask into column
    ``lane`` of a packed (tile_b, W) counter row (branch-free one-hot)."""
    width = row.shape[1]
    onehot = (jnp.arange(width)[None, :] == lane).astype(row.dtype)
    return row + mask.astype(row.dtype) * onehot


def lane_set(row, lane: int, value):
    """In-kernel helper: overwrite column ``lane`` of a packed counter row
    with a (tile_b, 1) value (branch-free select)."""
    width = row.shape[1]
    onehot = jnp.arange(width)[None, :] == lane
    return jnp.where(onehot, value.astype(row.dtype), row)


def tel_to_numpy(tel: TelemetryState) -> dict:
    """Counter lanes as a {lane: np.ndarray} dict (device -> host)."""
    return {name: np.asarray(getattr(tel, name)) for name in ALL_LANES}


def zeros_numpy(B: int) -> dict:
    """Host-side all-zero counters dict (the flush target for scheduled
    solves, filled per original LP index as LPs retire)."""
    out = {name: np.zeros(B, np.int32) for name in INT_LANES}
    out.update({name: np.zeros(B, np.float32) for name in F32_LANES})
    return out
