"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

  PYTHONPATH=src python -m repro.analysis.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict


def load(dirpath: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(recs, mesh="16x16"):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | "
        "MODEL_FLOPS | useful ratio | fits HBM (GiB/chip) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("tag"):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — |")
            continue
        if "roofline" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r.get('error', '?')} | | | | | | |")
            continue
        rl = r["roofline"]
        ma = r.get("memory_analysis", {})
        per_dev = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)) / 2**30
        fits = "yes" if per_dev <= 16 else f"NO ({per_dev:.0f})"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['bottleneck']} | {rl['model_flops']:.2e} | "
            f"{rl['useful_ratio']:.2f} | {fits} ({per_dev:.1f}) |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | compile_s | flops/dev | mem GiB/dev "
        "(traffic) | coll GiB/dev | args+temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("tag"):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip "
                         f"| — | — | — | — | — |")
            continue
        if "roofline" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**FAIL** {r.get('error','')} | | | | | |")
            continue
        hc = r["hlo_cost"]
        coll = r["collectives"]["_total"]["bytes"]
        ma = r.get("memory_analysis", {})
        per_dev = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.0f} | {hc['flops']:.2e} | "
            f"{fmt_bytes(hc['mem_bytes'])} | {fmt_bytes(coll)} | "
            f"{per_dev:.1f} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """Worst roofline fraction / most collective-bound / paper-representative."""
    cands = []
    for r in recs:
        if r.get("mesh") != "16x16" or "roofline" not in r or r.get("tag"):
            continue
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / dom if dom else 0
        cands.append((frac, rl["collective_s"] / max(rl["compute_s"], 1e-9),
                      r["arch"], r["shape"], rl["bottleneck"]))
    cands.sort()
    return cands


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run (all cells x both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))
    print("\n## Hillclimb candidates (sorted by compute fraction)\n")
    for frac, collr, arch, shape, b in pick_hillclimb(recs)[:12]:
        print(f"- {arch} {shape}: compute-fraction={frac:.2f} "
              f"coll/compute={collr:.1f} bottleneck={b}")


if __name__ == "__main__":
    main()
