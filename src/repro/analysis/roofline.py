"""Three-term roofline from the compiled dry-run artifacts.

Terms (per §Roofline of the experiment plan), computed from *per-device*
numerators (cost_analysis of the SPMD-partitioned executable is per-device),
so the denominators use a single chip's peaks:

    compute_s    = HLO_FLOPs_per_device   / 197e12   (bf16 peak, v5e)
    memory_s     = HLO_bytes_per_device   / 819e9    (HBM bandwidth)
    collective_s = coll_bytes_per_device  / 50e9     (ICI per-link)

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (+attention
term for decode): the ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes remat
recompute and padding/dispatch waste.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig, ShapeCell

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bottleneck: str

    def as_dict(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_total,
            "useful_ratio": self.useful_ratio,
            "bottleneck": self.bottleneck,
        }


def active_params(cfg: ModelConfig, total_params: float) -> float:
    """Active (per-token) parameter count: total minus unrouted experts."""
    if cfg.mlp_kind != "moe":
        return total_params
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * 3 \
        * cfg.d_model * cfg.d_ff_expert
    return total_params - inactive


def model_flops(cfg: ModelConfig, cell: ShapeCell, total_params: float) -> float:
    n_act = active_params(cfg, total_params)
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        base = 6.0 * n_act * tokens
    elif cell.kind == "prefill":
        base = 2.0 * n_act * tokens
    else:  # decode: one token per sequence + cache-attention reads
        base = 2.0 * n_act * cell.global_batch
        if cfg.n_heads and cfg.attn_kind != "none":
            S_eff = min(cell.seq_len, cfg.sliding_window or cell.seq_len)
            base += (4.0 * cell.global_batch * cfg.n_layers * S_eff
                     * cfg.n_heads * (cfg.d_head or 0))
    # causal attention FLOPs for train/prefill (not in 6ND)
    if cell.kind in ("train", "prefill") and cfg.n_heads and cfg.attn_kind != "none":
        S_eff = min(cell.seq_len, cfg.sliding_window or cell.seq_len)
        mult = 3.0 if cell.kind == "train" else 1.0  # fwd+bwd
        base += mult * 2.0 * 2.0 * tokens * S_eff / 2 * cfg.n_heads \
            * (cfg.d_head or 0) / 1.0
    return base


def compute_roofline(cfg: ModelConfig, cell: ShapeCell, *,
                     per_device_flops: float, per_device_bytes: float,
                     per_device_coll_bytes: float, chips: int,
                     total_params: float) -> Roofline:
    compute_s = per_device_flops / PEAK_FLOPS
    memory_s = per_device_bytes / HBM_BW
    collective_s = per_device_coll_bytes / ICI_BW
    mf = model_flops(cfg, cell, total_params)
    hlo_total = per_device_flops * chips
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        bottleneck=bottleneck,
    )
