"""Trip-count-aware cost model over partitioned HLO text.

XLA's `cost_analysis()` on the CPU backend counts a while-loop body ONCE,
not x trip-count — useless for scan-over-layers models (a 126-layer llama3
shows 66x fewer FLOPs than 6ND). This module re-derives the three roofline
numerators directly from the HLO text, multiplying every computation's cost
by the product of `known_trip_count` values along its call chain:

  * flops       — 2*M*N*K per dot (batch dims included), recursed into
                  fusion bodies too (CPU output-fusions can contain dots).
  * mem_bytes   — sum of operand+output bytes per materializing instruction
                  (fusion = one instruction: its internals are register/
                  cache-resident, which is exactly the HBM-traffic model we
                  want for the memory roofline term).
  * collectives — bytes per kind (all-reduce / all-gather / reduce-scatter /
                  all-to-all / collective-permute), async pairs counted once.

Whiles without a static trip count (e.g. the LP solver's convergence loop)
multiply by `default_trip`, which callers set to the expected pivot count.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\(?[\w\[\]\{\},\s/*]*?\)?\s*([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CompStats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(
        default_factory=lambda: defaultdict(lambda: {"count": 0, "bytes": 0.0}))
    # call sites: (callee_name, multiplier)
    calls: List[Tuple[str, float]] = field(default_factory=list)


# TPU-fusion-optimistic HBM-traffic model: only ops that fundamentally move
# memory count; stray elementwise instructions (which TPU fuses into matmul
# epilogues / neighboring loops, but CPU HLO leaves unfused) do not.
_MEM_OPS = {
    "dot", "convolution", "fusion", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "reduce-window",
    "sort", "pad", "concatenate", "slice", "transpose", "reverse", "rng",
    "cholesky", "triangular-solve",
} | {k for k in COLLECTIVES} | {k + "-start" for k in COLLECTIVES}


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.symbols: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, CompStats] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HDR_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur = m.group(2).lstrip("%")
                    if m.group(1):
                        self.entry = cur
                    self.comps[cur] = []
                    self.symbols[cur] = {}
                    # parameters from the header
                    for pm in re.finditer(r"([\w\.\-]+)\s*:\s*([^,\)]+)",
                                          m.group(3)):
                        self.symbols[cur][pm.group(1)] = pm.group(2)
                continue
            if line.strip() == "}":
                cur = None
                continue
            self.comps[cur].append(line)
            dm = _DEF_RE.match(line)
            if dm:
                name = dm.group(1).lstrip("%")
                rhs = dm.group(2)
                shp = rhs.split(" ", 1)[0]
                self.symbols[cur][name] = shp

    # ------------------------------------------------------------------
    def _operand_shapes(self, comp: str, args_text: str) -> List[str]:
        shapes = []
        for m in re.finditer(r"%([\w\.\-]+)", args_text):
            s = self.symbols[comp].get(m.group(1))
            if s:
                shapes.append(s)
        return shapes

    def _dot_flops(self, comp: str, line: str) -> float:
        dm = _DEF_RE.match(line)
        if not dm:
            return 0.0
        rhs = dm.group(2)
        out_dims_all = _shape_dims(rhs.split(" dot(")[0])
        if not out_dims_all:
            return 0.0
        out_n = 1
        for d in out_dims_all[0][1]:
            out_n *= d
        args = rhs.split(" dot(", 1)[1]
        operand_text = args.split("), ")[0]
        opshapes = self._operand_shapes(comp, operand_text)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
        if not opshapes or cm is None:
            return 2.0 * out_n  # degenerate
        lhs_dims = _shape_dims(opshapes[0])
        if not lhs_dims:
            return 2.0 * out_n
        k = 1
        for idx in cm.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims[0][1]):
                    k *= lhs_dims[0][1][i]
        return 2.0 * out_n * k

    def _fusion_traffic(self, fusion_comp: Optional[str], out_b: int,
                        arg_bytes: List[int]) -> float:
        """Fusion i/o traffic with slice-awareness.

        Two scan patterns need in-place accounting or whole carried buffers
        get charged on every iteration:
          * dynamic-update-slice of a pass-through buffer (scan accumulator):
            traffic = 2 x updated slice, not the buffer;
          * dynamic-slice of a large parameter (scan reading one chunk of a
            carried tensor, e.g. a KV block per attention step): traffic =
            2 x slice, not the parent buffer.
        """
        plain = out_b + sum(arg_bytes)
        if fusion_comp is None or fusion_comp not in self.comps:
            return plain
        update_bytes = []
        target_sizes = []
        slice_bytes = []
        sliced_sizes = []
        for line in self.comps[fusion_comp]:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            if "dynamic-update-slice(" in rhs:
                args = re.findall(r"%([\w\.\-]+)",
                                  rhs.split("dynamic-update-slice(", 1)[1])
                if len(args) >= 2:
                    target_sizes.append(
                        _shape_bytes(self.symbols[fusion_comp].get(args[0], "")))
                    update_bytes.append(
                        _shape_bytes(self.symbols[fusion_comp].get(args[1], "")))
            elif re.search(r"\bdynamic-slice\(", rhs):
                out_sb = _shape_bytes(rhs.split(" dynamic-slice(")[0])
                args = re.findall(r"%([\w\.\-]+)",
                                  rhs.split("dynamic-slice(", 1)[1])
                if args:
                    src = _shape_bytes(self.symbols[fusion_comp].get(args[0], ""))
                    if src > 4 * max(out_sb, 1):  # genuinely chunked read
                        sliced_sizes.append(src)
                        slice_bytes.append(out_sb)
        if not update_bytes and not slice_bytes:
            return plain
        # pass-through buffers and the update/slice tensors themselves are
        # already covered by the 2x terms — don't double count them as args
        consumed = set(target_sizes) | set(sliced_sizes) | set(update_bytes)
        traffic = 2.0 * sum(update_bytes) + 2.0 * sum(slice_bytes)
        traffic += sum(b for b in arg_bytes if b not in consumed)
        remaining_out = out_b - sum(target_sizes) - sum(slice_bytes)
        if remaining_out > 0:
            traffic += remaining_out
        return traffic

    def _comp_stats(self, comp: str, in_fusion: bool = False) -> CompStats:
        if comp in self._memo:
            return self._memo[comp]
        st = CompStats()
        for line in self.comps.get(comp, []):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
            if not opm:
                continue
            op = opm.group(1)

            if op == "dot":
                st.flops += self._dot_flops(comp, line)
            # collectives (count -start, skip -done)
            for kind in COLLECTIVES:
                if op == kind or op == kind + "-start":
                    out_b = _shape_bytes(rhs[: opm.start()])
                    arg_text = rhs[opm.end():].split(", replica_groups")[0] \
                        .split(", channel_id")[0]
                    arg_names = re.findall(r"%([\w\.\-]+)", arg_text)
                    op_b = sum(_shape_bytes(self.symbols[comp].get(a, ""))
                               for a in arg_names)
                    st.coll[kind]["count"] += 1
                    st.coll[kind]["bytes"] += max(out_b, op_b)
                    break

            # memory traffic
            if op in _MEM_OPS and not in_fusion:
                out_b = _shape_bytes(rhs[: opm.start()])
                arg_text = rhs[opm.end():]
                arg_text = re.split(r"\),\s*[a-z_]+=", arg_text)[0]
                arg_names = re.findall(r"%([\w\.\-]+)", arg_text)
                arg_bytes = [_shape_bytes(self.symbols[comp].get(a, ""))
                             for a in arg_names]
                if op == "fusion":
                    fm = re.search(r"calls=%?([\w\.\-]+)", rhs)
                    st.mem_bytes += self._fusion_traffic(
                        fm.group(1) if fm else None, out_b, arg_bytes)
                elif op == "dynamic-update-slice" and len(arg_bytes) >= 2:
                    # in-place: read+write only the updated slice
                    st.mem_bytes += 2 * arg_bytes[1]
                elif op == "gather" and arg_bytes:
                    st.mem_bytes += 2 * out_b + (arg_bytes[1] if
                                                 len(arg_bytes) > 1 else 0)
                elif op == "scatter" and len(arg_bytes) >= 3:
                    st.mem_bytes += 3 * arg_bytes[2]
                elif op == "dynamic-slice" and arg_bytes:
                    st.mem_bytes += 2 * out_b
                else:
                    st.mem_bytes += out_b + sum(arg_bytes)

            # call sites
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                tm = _TRIP_RE.search(rhs)
                trip = float(tm.group(1)) if tm else None
                if bm:
                    st.calls.append((bm.group(1), trip))
                cm2 = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if cm2:
                    st.calls.append((cm2.group(1), trip))
            elif op == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", rhs)
                if fm:
                    st.calls.append((fm.group(1), -1.0))  # -1 => fusion body
            elif op == "call":
                fm = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
                if fm:
                    st.calls.append((fm.group(1), 1.0))
            elif op == "conditional":
                for br in re.finditer(
                        r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w\.\-,% ]+)\}?",
                        rhs):
                    for name in re.findall(r"%?([\w\.\-]+)", br.group(1)):
                        st.calls.append((name, 1.0))
        self._memo[comp] = st
        return st

    def total(self, default_trip: float = 1.0):
        """Roll up from the entry computation."""
        seen_stack = set()

        def roll(comp: str, in_fusion: bool) -> Tuple[float, float, dict]:
            st = self._comp_stats(comp, in_fusion=in_fusion)
            flops = st.flops
            mem = 0.0 if in_fusion else st.mem_bytes
            coll = {k: dict(v) for k, v in st.coll.items()}
            for callee, trip in st.calls:
                if callee not in self.comps or callee in seen_stack:
                    continue
                seen_stack.add(callee)
                child_fusion = in_fusion or (trip == -1.0)
                mult = 1.0 if trip == -1.0 else (
                    trip if trip is not None else default_trip)
                f2, m2, c2 = roll(callee, child_fusion)
                seen_stack.discard(callee)
                flops += mult * f2
                mem += mult * m2
                for k, v in c2.items():
                    e = coll.setdefault(k, {"count": 0, "bytes": 0.0})
                    e["count"] += mult * v["count"]
                    e["bytes"] += mult * v["bytes"]
            return flops, mem, coll

        flops, mem, coll = roll(self.entry, False)
        total = {"count": sum(v["count"] for v in coll.values()),
                 "bytes": sum(v["bytes"] for v in coll.values())}
        coll["_total"] = total
        return {"flops": flops, "mem_bytes": mem, "collectives": coll}


def module_cost(hlo_text: str, default_trip: float = 1.0) -> dict:
    return HloModule(hlo_text).total(default_trip=default_trip)
