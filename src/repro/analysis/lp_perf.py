"""§Perf analysis for the paper-representative workload: batched LP solving.

Quantifies the three-level termination story (DESIGN.md §2) with measured
pivot-count distributions, and the VMEM-residency argument for the Pallas
kernel, producing the numbers cited in EXPERIMENTS.md §Perf:

1. lockstep waste        — a global while-loop executes max(pivots) for every
                           LP; waste = 1 - mean/max.
2. per-shard termination — shard_map's per-chip loops each stop at their own
                           max; expected executed pivots = mean over shards
                           of shard-max.
3. per-tile early exit   — the Pallas kernel's grid tiles stop independently.
4. sorted batching       — difficulty-sorted chunks tighten each chunk's max
                           (beyond-paper optimization in core/batching.py).
5. HBM-traffic model     — pure-XLA lockstep re-reads the tableau from HBM
                           every pivot (while-loop carry); the VMEM-resident
                           kernel touches HBM once per solve: traffic ratio
                           ~= pivots executed.

  PYTHONPATH=src python -m repro.analysis.lp_perf
"""
from __future__ import annotations

import numpy as np

from repro.core import LPBatch, random_lp_batch, solve_batched_reference
from repro.core.simplex import flops_per_pivot


def executed_pivots(iters: np.ndarray, group: int) -> float:
    """Total device pivots when termination granularity = `group` LPs."""
    n = len(iters)
    pad = (-n) % group
    arr = np.concatenate([iters, np.zeros(pad, iters.dtype)])
    return float(arr.reshape(-1, group).max(axis=1).sum() * group)


def analyze(m: int, n: int, B: int = 4096, mixed: bool = True,
            chips: int = 256, tile_b: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    half = B // 2
    if mixed:
        b1 = random_lp_batch(rng, half, m, n, feasible_start=True)
        b2 = random_lp_batch(rng, B - half, m, n, feasible_start=False)
        batch = LPBatch(A=np.concatenate([b1.A, b2.A]),
                        b=np.concatenate([b1.b, b2.b]),
                        c=np.concatenate([b1.c, b2.c]))
        order = rng.permutation(B)
        batch = LPBatch(A=batch.A[order], b=batch.b[order], c=batch.c[order])
    else:
        batch = random_lp_batch(rng, B, m, n)
    ref = solve_batched_reference(batch)
    iters = ref.iterations.astype(np.int64)

    useful = float(iters.sum())
    lockstep = executed_pivots(iters, B)
    per_shard = executed_pivots(iters, max(1, B // chips))
    per_tile = executed_pivots(iters, tile_b)
    # sorted batching: difficulty-sorted then per-shard groups
    srt = np.sort(iters)
    per_shard_sorted = executed_pivots(srt, max(1, B // chips))
    per_tile_sorted = executed_pivots(srt, tile_b)

    fpp = flops_per_pivot(m, n)
    rows = m + 2
    cols = n + 2 * m + 1
    tableau_bytes = rows * cols * 4
    # HBM traffic per LP: lockstep XLA re-reads+writes the tableau per
    # executed pivot; the Pallas tile kernel reads it once and writes results
    xla_traffic = 2 * tableau_bytes * lockstep / B
    kernel_traffic = tableau_bytes + (n + 16) * 4

    return {
        "m": m, "n": n, "B": B, "mixed": mixed,
        "pivots_mean": float(iters.mean()), "pivots_max": int(iters.max()),
        "eff_lockstep": useful / lockstep,
        "eff_per_shard": useful / per_shard,
        "eff_per_tile": useful / per_tile,
        "eff_per_shard_sorted": useful / per_shard_sorted,
        "eff_per_tile_sorted": useful / per_tile_sorted,
        "flops_per_pivot": fpp,
        "hbm_bytes_per_lp_xla": xla_traffic,
        "hbm_bytes_per_lp_kernel": float(kernel_traffic),
        "traffic_ratio": xla_traffic / kernel_traffic,
    }


def main():
    print("workload,eff_lockstep,eff_shard,eff_tile,eff_shard_sorted,"
          "eff_tile_sorted,traffic_ratio_xla_vs_kernel")
    for (m, n, mixed) in [(5, 5, True), (28, 28, True), (50, 50, True),
                          (100, 100, True), (28, 28, False)]:
        r = analyze(m, n, mixed=mixed)
        print(f"lp_{n}d{'_mixed' if mixed else ''},"
              f"{r['eff_lockstep']:.3f},{r['eff_per_shard']:.3f},"
              f"{r['eff_per_tile']:.3f},{r['eff_per_shard_sorted']:.3f},"
              f"{r['eff_per_tile_sorted']:.3f},{r['traffic_ratio']:.1f}")


if __name__ == "__main__":
    main()
