"""§Perf analysis for the paper-representative workload: batched LP solving.

Quantifies the three-level termination story (DESIGN.md §2) with measured
pivot-count distributions, and the VMEM-residency argument for the Pallas
kernel, producing the numbers cited in EXPERIMENTS.md §Perf:

1. lockstep waste        — a global while-loop executes max(pivots) for every
                           LP; waste = 1 - mean/max.
2. per-shard termination — shard_map's per-chip loops each stop at their own
                           max; expected executed pivots = mean over shards
                           of shard-max.
3. per-tile early exit   — the Pallas kernel's grid tiles stop independently.
4. sorted batching       — difficulty-sorted chunks tighten each chunk's max
                           (beyond-paper optimization in core/batching.py).
5. HBM-traffic model     — pure-XLA lockstep re-reads the tableau from HBM
                           every pivot (while-loop carry); the VMEM-resident
                           kernel touches HBM once per solve: traffic ratio
                           ~= pivots executed.
6. work elimination      — executed *tableau-element updates* before/after
                           the two-level engine: phase-compacted tableaux
                           (core/simplex.py) shrink the per-pivot update;
                           the active-set compaction scheduler
                           (core/compaction.py) shrinks the batch as LPs
                           retire.  `element_updates_*` below are the
                           closed-form models; benchmarks/pivot_work.py
                           cross-checks them against measured SegmentStats.
7. pricing rules         — every model above is per-rule: ``pricing=``
                           replays the workload under dantzig /
                           steepest_edge / devex pivot selection
                           (core/pricing.py), so the work models quantify
                           how fewer pivots multiply against both
                           compaction levels (`compare_pricing`).
8. revised simplex       — flops-per-pivot model for the basis-factor
                           backend (core/revised.py): BTRAN/FTRAN
                           triangular+eta solves O(m^2), pricing O(m*C),
                           amortized LU refactorization — vs the tableau's
                           O(m*(n+2m)) rank-1 update.  `revised_crossover`
                           locates the n/m frontier where the revised
                           backend wins on *flops*; on element *updates*
                           (state written per pivot, `revised_elements`)
                           it wins everywhere because the (m, n+2m) data
                           block is immutable.
9. canonical shapes      — general-form problems (core/forms.py) are solved
                           at their *canonical* shape: equalities grow m,
                           free variables grow n, presolve shrinks both.
                           `canonical_work` re-evaluates every per-pivot
                           model at the canonical (m, n) — the
                           revised-vs-tableau crossover must be judged
                           there, not at the original shape (a
                           square-looking Netlib instance with many
                           equalities canonicalizes tall, which is
                           tableau-hostile).  Finite upper bounds are
                           handled *natively* by the bounded ratio test
                           (no rows); `canonical_work` also reports the
                           counterfactual ``bound_rows=True`` shape and
                           the element/flops ratio the row encoding would
                           have cost — the tentpole's "stop paying for
                           upper-bound rows" number.
10. sparsity             — shared-pattern sparse batches (core/sparse.py)
                           replace the PDHG matvecs' 2mn flops with 2nnz:
                           `sparse_matvec_flops` / `sparse_pdhg_iteration_
                           flops` are the density-aware twins of the dense
                           models, and `sparse_pdhg_speedup` is the
                           dense/sparse flops ratio (~1/density for
                           matvec-dominated shapes) that
                           benchmarks/pivot_work.py cross-checks against
                           measured element counts.

  PYTHONPATH=src python -m repro.analysis.lp_perf
"""
from __future__ import annotations

import numpy as np

from repro.core import LPBatch, random_lp_batch, solve_batched_reference_detailed
from repro.core.compaction import next_bucket
from repro.core.pricing import PRICING_RULES, partial_priced_candidates
from repro.core.revised import auto_refactor_period, revised_elements  # noqa: F401  (re-export: the element-update side of the model)
from repro.core.simplex import flops_per_pivot, tableau_elements
from repro.obs.work import element_updates_lockstep  # noqa: F401  (re-export: the shared lockstep accounting — benchmarks/pivot_work.py uses the same helper)


def executed_pivots(iters: np.ndarray, group: int) -> float:
    """Total device pivots when termination granularity = `group` LPs."""
    n = len(iters)
    pad = (-n) % group
    arr = np.concatenate([iters, np.zeros(pad, iters.dtype)])
    return float(arr.reshape(-1, group).max(axis=1).sum() * group)


def element_updates_phase_compacted(p1_iters: np.ndarray, iters: np.ndarray,
                                    m: int, n: int) -> float:
    """Level 1 only (monolithic two-loop solve): full-tableau steps until the
    last LP leaves phase 1, compacted-tableau steps for the rest."""
    B = len(iters)
    s1 = int(p1_iters.max())
    s2 = int(np.maximum(iters - p1_iters, 0).max()) + 1
    return float(s1 * B * tableau_elements(m, n)
                 + s2 * B * tableau_elements(m, n, compacted=True))


class _ScheduleSim:
    """Host-side replay of core.compaction.run_schedule's executed-work
    accounting: same segment quantization, same power-of-two bucket ladder,
    with bucket membership carried across stages (the real scheduler never
    re-expands the bucket at the stage-1 -> stage-2 transition)."""

    def __init__(self, B: int, segment_k: int, compact_threshold: float,
                 pad_multiple: int):
        self.segment_k = segment_k
        self.compact_threshold = compact_threshold
        self.pad_multiple = pad_multiple
        self.in_bucket = np.ones(B, bool)
        self.bucket = B
        self.elems = 0.0

    def run_stage(self, length: np.ndarray, retire_at: np.ndarray,
                  per: int) -> int:
        """``length[i]``: stage-local steps until LP i stops being *pending*
        (its loop-exit condition); ``retire_at[i]``: steps until it stops
        counting as RUNNING for bucket sizing (length <= retire_at).
        Returns the stage's executed lockstep steps."""
        done = 0
        while True:
            pending = self.in_bucket & (length > done)
            if not pending.any():
                return done
            step = min(self.segment_k, int(length[pending].max()) - done)
            self.elems += step * self.bucket * per
            done += step
            running = self.in_bucket & (retire_at > done)
            n_run = int(running.sum())
            if n_run == 0:
                continue  # next pending check ends the stage
            new_bucket = next_bucket(n_run, self.pad_multiple)
            if new_bucket < self.bucket \
                    and n_run < self.compact_threshold * self.bucket:
                self.in_bucket = running
                self.bucket = new_bucket


def element_updates_scheduled(p1_iters: np.ndarray, iters: np.ndarray,
                              m: int, n: int, segment_k: int = 8,
                              compact_threshold: float = 0.5,
                              pad_multiple: int = 1) -> float:
    """Both levels: simulate the segment/bucket ladder of
    core.compaction.run_schedule over the measured per-LP pivot counts —
    no device needed."""
    p1 = p1_iters.astype(np.int64)
    total = iters.astype(np.int64)
    sim = _ScheduleSim(len(total), segment_k, compact_threshold, pad_multiple)
    # stage 1 (full tableau): an LP is pending until it leaves phase 1 and
    # RUNNING until its whole solve terminates (total pivots + final check);
    # meanwhile the combined step also advances its phase-2 pivots.
    s1 = sim.run_stage(length=p1, retire_at=total + 1,
                       per=tableau_elements(m, n))
    # stage 2 (compacted tableau): only pivots not already executed during
    # stage 1 remain, plus the terminal check; LPs finished in stage 1 are 0.
    rem = np.where(total + 1 <= s1, 0, np.maximum(total - s1, 0) + 1)
    sim.run_stage(length=rem, retire_at=rem,
                  per=tableau_elements(m, n, compacted=True))
    return sim.elems


def revised_pivot_flops(m: int, n: int, *, refactor_period: int | None = None,
                        partial: bool = False,
                        block: int | None = None) -> float:
    """Honest flops of one revised-simplex pivot (core/revised.py).

    * BTRAN + FTRAN: two LU solves (2 m^2 flops each) ......... 4 m^2
    * eta passes: 2 applications x avg K/2 etas x 3 flops/el .. 3 K m
    * pricing matvec over priced candidates ................... 2 m C_priced
      (full: C = n+m; partial: one block + the amortized full
       fallback, ~once per block cycle)
    * amortized refactorization: LU (2/3 m^3) + basis gather .. /K
    * x_B / eta update ........................................ 5 m

    Unlike ``revised_elements`` (state *written*, where revised wins at
    every size because the tableau's rank-1 write never happens), the flops
    model charges triangular-solve reads — so the tableau backend, at
    2 flops per tableau element, stays cheaper on *square* dense LPs and the
    revised method pays off as n grows past a few multiples of m (or under
    sparsity the dense model can't see): the classic textbook crossover,
    located by `revised_crossover`."""
    K = refactor_period or auto_refactor_period(m, n)
    ncand = n + m
    priced = partial_priced_candidates(ncand, block, partial=partial)
    solves = 4.0 * m * m
    etas = 3.0 * K * m
    pricing = 2.0 * m * priced
    refac = (2.0 * m ** 3 / 3.0 + m * m) / K
    return solves + etas + pricing + refac + 5.0 * m


def tableau_pivot_flops(m: int, n: int, compacted: bool = False) -> float:
    """Tableau-backend flops per pivot in the same currency: ~2 flops per
    tableau element touched by the rank-1 update (see `flops_per_pivot` for
    the Gflop/s-accounting variant; this one drops the shared reductions so
    the backend comparison isolates the update term)."""
    return 2.0 * tableau_elements(m, n, compacted=compacted)


def revised_crossover(m: int, *, partial: bool = True,
                      refactor_period: int | None = None,
                      max_ratio: int = 64) -> int | None:
    """Smallest n (scanned up to ``max_ratio * m``) where the revised
    backend's flops-per-pivot model undercuts the phase-compacted tableau's.
    Returns None if the tableau wins over the whole scanned range (dense
    square-ish problems — the tableau's best case)."""
    for n in range(1, max_ratio * m + 1):
        if revised_pivot_flops(m, n, partial=partial,
                               refactor_period=refactor_period) \
                < tableau_pivot_flops(m, n, compacted=True):
            return n
    return None


def pdhg_iteration_flops(m: int, n: int) -> float:
    """Honest flops of one PDHG iteration (core/pdhg.py): two (m, n)
    matvecs (2mn flops each) plus the O(m+n) prox/extrapolation updates.
    Each check round adds six more matvecs — KKT residuals of both the
    current and the average iterate (4) plus the two Farkas-ray tests —
    amortized in as 12mn/CHECK_EVERY."""
    from repro.core.pdhg import CHECK_EVERY

    return 4.0 * m * n + 6.0 * (m + n) + 12.0 * m * n / CHECK_EVERY


def sparse_matvec_flops(nnz: int) -> float:
    """Honest flops of one shared-pattern sparse matvec (core/sparse.py):
    one multiply + one scatter-add per stored nonzero.  The dense
    counterpart is 2mn — the ratio is exactly the density."""
    return 2.0 * nnz


def sparse_pdhg_iteration_flops(nnz: int, m: int, n: int) -> float:
    """Density-aware twin of `pdhg_iteration_flops`: two sparse matvecs per
    iteration plus the O(m + n) prox/extrapolation updates, with the six
    check-round matvecs amortized in — every 2mn replaced by 2nnz, the
    vector work unchanged (it never depended on the pattern)."""
    from repro.core.pdhg import CHECK_EVERY

    return 2.0 * sparse_matvec_flops(nnz) + 6.0 * (m + n) \
        + 6.0 * sparse_matvec_flops(nnz) / CHECK_EVERY


def sparse_pdhg_speedup(m: int, n: int, nnz: int) -> float:
    """Dense/sparse flops ratio for one PDHG iteration at this pattern:
    -> ~1/density while the matvecs dominate, degrading toward 1 as the
    O(m + n) vector work takes over on very sparse or very small shapes."""
    return pdhg_iteration_flops(m, n) / sparse_pdhg_iteration_flops(nnz, m, n)


def pdhg_crossover_pivots(m: int, n: int, pdhg_iters: float,
                          *, partial: bool = True) -> dict:
    """The headline first-order-vs-simplex comparison: how many *pivots*
    a simplex engine may spend before a PDHG solve of ``pdhg_iters``
    iterations is cheaper on honest flops — and, since Dantzig-style pivot
    counts grow ~O(m+n) while PDHG's iteration count is governed by
    conditioning rather than size, the problem scale where the first-order
    engine takes over.

    The *sequential-depth* column is the sharper story: a simplex pivot is
    a dependent reduce -> ratio -> rank-1 chain (3 serial stages on a
    parallel machine), while a PDHG iteration is 2 matvec stages; but each
    simplex pivot processes O(m x n) state that cannot be split across
    iterations, so once batch parallelism saturates the device the
    iteration *count* is the critical path.  ``depth_ratio`` reports
    (pivots x 3) / (iterations x 2): > 1 means the first-order engine has
    the shorter critical path even before flops win."""
    tab = tableau_pivot_flops(m, n, compacted=True)
    rev = revised_pivot_flops(m, n, partial=partial)
    it_flops = pdhg_iteration_flops(m, n)
    total = pdhg_iters * it_flops
    exp_pivots = float(m + n)    # Dantzig's empirical O(m+n) on this suite
    return {
        "pdhg_iteration_flops": it_flops,
        "pdhg_total_flops": total,
        "crossover_pivots_vs_tableau": total / tab,
        "crossover_pivots_vs_revised": total / rev,
        "expected_pivots": exp_pivots,
        "pdhg_wins_flops_vs_tableau": bool(total < exp_pivots * tab),
        "pdhg_wins_flops_vs_revised": bool(total < exp_pivots * rev),
        "depth_ratio": (exp_pivots * 3.0) / max(pdhg_iters * 2.0, 1.0),
    }


def pdhg_crossover_size(pdhg_iters: float, *, max_m: int = 100000) -> int | None:
    """Smallest square size m (= n) where the first-order engine undercuts
    the phase-compacted tableau on *total* honest flops: simplex pivot
    counts grow ~O(m+n) on this suite while restarted-PDHG iteration
    counts are governed by conditioning, not size — so past this m the
    per-solve flops budget flips even though a single iteration and a
    single pivot cost nearly the same.  Returns None if the tableau wins
    over the whole scanned range (i.e. ``pdhg_iters`` is too large)."""
    for m in range(2, max_m + 1, max(1, max_m // 4096)):
        if pdhg_iters * pdhg_iteration_flops(m, m) \
                < (2.0 * m) * tableau_pivot_flops(m, m, compacted=True):
            return m
    return None


def canonical_work(g, *, presolve: bool = True) -> dict:
    """Canonical-vs-original shape accounting for a general-form batch.

    Returns the original and canonical (m, n) plus every per-pivot work
    model evaluated at the canonical shape — the shape the device solvers
    actually run at.  ``revised_wins_flops`` is the headline: whether the
    basis-factor backend undercuts the phase-compacted tableau *on this
    instance's canonical geometry* (equalities/upper bounds grow m, so
    instances that look square in the original data are often
    revised-territory after canonicalization).
    """
    from repro.core.forms import canonical_shape

    mc, nc = canonical_shape(g, presolve=presolve)
    mr, nr = canonical_shape(g, presolve=presolve, bound_rows=True)
    tab_flops = tableau_pivot_flops(mc, nc, compacted=True)
    rev_flops = revised_pivot_flops(mc, nc, partial=True)
    el_native = tableau_elements(mc, nc, compacted=True)
    el_rows = tableau_elements(mr, nr, compacted=True)
    return {
        "name": g.name, "m": g.m, "n": g.n,
        "m_canonical": mc, "n_canonical": nc,
        "row_growth": mc / max(1, g.m), "col_growth": nc / max(1, g.n),
        # counterfactual: finite ubs encoded as x_j <= u_j rows instead of
        # the bounded ratio test — what every per-pivot model would pay
        "m_bound_rows": mr, "n_bound_rows": nr,
        "bound_rows_added": mr - mc,
        "bound_row_element_ratio": el_rows / el_native,
        "bound_row_flops_ratio":
            tableau_pivot_flops(mr, nr, compacted=True) / tab_flops,
        "tableau_elements_canonical": el_native,
        "revised_elements_canonical": revised_elements(mc, nc, partial=True),
        "tableau_flops_canonical": tab_flops,
        "revised_flops_canonical": rev_flops,
        "revised_wins_flops": bool(rev_flops < tab_flops),
        "revised_crossover_n": revised_crossover(mc),
    }


def _workload(m: int, n: int, B: int, mixed: bool, seed: int) -> LPBatch:
    rng = np.random.default_rng(seed)
    half = B // 2
    if mixed:
        b1 = random_lp_batch(rng, half, m, n, feasible_start=True)
        b2 = random_lp_batch(rng, B - half, m, n, feasible_start=False)
        batch = LPBatch(A=np.concatenate([b1.A, b2.A]),
                        b=np.concatenate([b1.b, b2.b]),
                        c=np.concatenate([b1.c, b2.c]))
        order = rng.permutation(B)
        batch = LPBatch(A=batch.A[order], b=batch.b[order], c=batch.c[order])
    else:
        batch = random_lp_batch(rng, B, m, n)
    return batch


def analyze(m: int, n: int, B: int = 4096, mixed: bool = True,
            chips: int = 256, tile_b: int = 8, seed: int = 0,
            pricing: str = "dantzig"):
    batch = _workload(m, n, B, mixed, seed)
    ref, p1_iters = solve_batched_reference_detailed(batch, pricing=pricing)
    iters = ref.iterations.astype(np.int64)
    p1_iters = p1_iters.astype(np.int64)

    useful = float(iters.sum())
    lockstep = executed_pivots(iters, B)
    per_shard = executed_pivots(iters, max(1, B // chips))
    per_tile = executed_pivots(iters, tile_b)
    # sorted batching: difficulty-sorted then per-shard groups
    srt = np.sort(iters)
    per_shard_sorted = executed_pivots(srt, max(1, B // chips))
    per_tile_sorted = executed_pivots(srt, tile_b)

    fpp = flops_per_pivot(m, n)
    tableau_bytes = tableau_elements(m, n) * 4
    # HBM traffic per LP: lockstep XLA re-reads+writes the tableau per
    # executed pivot; the Pallas tile kernel reads it once and writes results
    xla_traffic = 2 * tableau_bytes * lockstep / B
    kernel_traffic = tableau_bytes + (n + 16) * 4

    # two-level work-elimination model (element updates = pivots x tableau)
    el_lock = element_updates_lockstep(iters, m, n)
    el_pc = element_updates_phase_compacted(p1_iters, iters, m, n)
    el_sched = element_updates_scheduled(p1_iters, iters, m, n)

    return {
        "m": m, "n": n, "B": B, "mixed": mixed, "pricing": pricing,
        "pivots_mean": float(iters.mean()), "pivots_max": int(iters.max()),
        "eff_lockstep": useful / lockstep,
        "eff_per_shard": useful / per_shard,
        "eff_per_tile": useful / per_tile,
        "eff_per_shard_sorted": useful / per_shard_sorted,
        "eff_per_tile_sorted": useful / per_tile_sorted,
        "flops_per_pivot": fpp,
        "flops_per_pivot_compacted": flops_per_pivot(m, n, compacted=True),
        "hbm_bytes_per_lp_xla": xla_traffic,
        "hbm_bytes_per_lp_kernel": float(kernel_traffic),
        "traffic_ratio": xla_traffic / kernel_traffic,
        "elems_lockstep": el_lock,
        "elems_phase_compacted": el_pc,
        "elems_scheduled": el_sched,
        "work_reduction_phase_compacted": el_lock / el_pc,
        "work_reduction_scheduled": el_lock / el_sched,
    }


def compare_pricing(m: int, n: int, B: int = 4096, mixed: bool = True,
                    seed: int = 0) -> dict:
    """Replay one workload under every pricing rule through the float64
    oracle and report per-rule pivot counts plus the two-level work models —
    the closed-form view of how pivot savings multiply against phase
    compaction and the bucket ladder.  Rules must agree on statuses (they
    change the path, never the certificate)."""
    batch = _workload(m, n, B, mixed, seed)
    out = {"m": m, "n": n, "B": B, "mixed": mixed, "rules": {}}
    base_status = None
    for rule in PRICING_RULES:
        ref, p1 = solve_batched_reference_detailed(batch, pricing=rule)
        iters = ref.iterations.astype(np.int64)
        p1 = p1.astype(np.int64)
        if base_status is None:
            base_status = ref.status
        out["rules"][rule] = {
            "pivots_mean": float(iters.mean()),
            "pivots_max": int(iters.max()),
            "pivots_total": int(iters.sum()),
            "statuses_match": bool(np.array_equal(ref.status, base_status)),
            "elems_lockstep": element_updates_lockstep(iters, m, n),
            "elems_phase_compacted":
                element_updates_phase_compacted(p1, iters, m, n),
            "elems_scheduled": element_updates_scheduled(p1, iters, m, n),
        }
    dz = out["rules"]["dantzig"]["pivots_mean"]
    for rule in PRICING_RULES:
        out["rules"][rule]["pivot_cut_vs_dantzig"] = (
            1.0 - out["rules"][rule]["pivots_mean"] / max(dz, 1e-12))
    return out


def main():
    print("workload,eff_lockstep,eff_shard,eff_tile,eff_shard_sorted,"
          "eff_tile_sorted,traffic_ratio_xla_vs_kernel,"
          "work_red_phase_compact,work_red_scheduled")
    for (m, n, mixed) in [(5, 5, True), (28, 28, True), (50, 50, True),
                          (100, 100, True), (28, 28, False)]:
        r = analyze(m, n, mixed=mixed)
        print(f"lp_{n}d{'_mixed' if mixed else ''},"
              f"{r['eff_lockstep']:.3f},{r['eff_per_shard']:.3f},"
              f"{r['eff_per_tile']:.3f},{r['eff_per_shard_sorted']:.3f},"
              f"{r['eff_per_tile_sorted']:.3f},{r['traffic_ratio']:.1f},"
              f"{r['work_reduction_phase_compacted']:.2f},"
              f"{r['work_reduction_scheduled']:.2f}")
    print()
    print("pricing,pivots_mean,pivots_max,pivot_cut_vs_dantzig,"
          "elems_scheduled,statuses_match  # 28x28 mixed B=4096")
    cmp = compare_pricing(28, 28)
    for rule, r in cmp["rules"].items():
        print(f"{rule},{r['pivots_mean']:.2f},{r['pivots_max']},"
              f"{r['pivot_cut_vs_dantzig']:.3f},{r['elems_scheduled']:.3e},"
              f"{r['statuses_match']}")
    print()
    print("backend_model,m,n,flops_per_pivot,element_updates_per_pivot,"
          "crossover_n_at_m  # tableau (compacted) vs revised")
    for (m, n) in [(28, 28), (100, 100), (100, 400), (50, 500)]:
        print(f"tableau,{m},{n},{tableau_pivot_flops(m, n, compacted=True):.3e},"
              f"{tableau_elements(m, n, compacted=True):.3e},")
        print(f"revised_partial,{m},{n},"
              f"{revised_pivot_flops(m, n, partial=True):.3e},"
              f"{revised_elements(m, n, partial=True):.3e},"
              f"{revised_crossover(m)}")
    print()
    print("fixture,m,n,m_canonical,n_canonical,m_bound_rows,"
          "bound_row_element_ratio,tableau_flops,revised_flops,"
          "revised_wins  # general-form instances at canonical shape; "
          "bound_row_* = cost of encoding ubs as rows instead of natively")
    from repro.io.mps import FIXTURE_NAMES, fixture_path, read_mps
    for name in FIXTURE_NAMES:
        g = read_mps(fixture_path(name))
        w = canonical_work(g)
        print(f"{w['name']},{w['m']},{w['n']},{w['m_canonical']},"
              f"{w['n_canonical']},{w['m_bound_rows']},"
              f"{w['bound_row_element_ratio']:.2f},"
              f"{w['tableau_flops_canonical']:.3e},"
              f"{w['revised_flops_canonical']:.3e},{w['revised_wins_flops']}")
    print()
    print("sparse_pdhg,fixture,m,n,nnz,density,dense_iter_flops,"
          "sparse_iter_flops,speedup  # shared-pattern matvecs vs dense")
    for name in FIXTURE_NAMES:
        g = read_mps(fixture_path(name))
        nnz = int((np.asarray(g.A[0]) != 0).sum())
        print(f"sparse_pdhg,{name},{g.m},{g.n},{nnz},"
              f"{nnz / max(1, g.m * g.n):.4f},"
              f"{pdhg_iteration_flops(g.m, g.n):.3e},"
              f"{sparse_pdhg_iteration_flops(nnz, g.m, g.n):.3e},"
              f"{sparse_pdhg_speedup(g.m, g.n, nnz):.2f}")
    print()
    print("pdhg_crossover,m,n,iters,flops_per_iter,pivot_budget_vs_tableau,"
          "expected_pivots,pdhg_wins  # first-order vs simplex, honest flops"
          " (iters = typical measured restarted-PDHG counts)")
    for (m, n, iters) in [(28, 28, 3000), (100, 100, 5000),
                          (500, 500, 8000), (2000, 2000, 12000)]:
        w = pdhg_crossover_pivots(m, n, iters)
        print(f"pdhg,{m},{n},{iters},{w['pdhg_iteration_flops']:.3e},"
              f"{w['crossover_pivots_vs_tableau']:.1f},"
              f"{w['expected_pivots']:.0f},"
              f"{w['pdhg_wins_flops_vs_tableau']}")
    for iters in (3000, 10000, 30000):
        print(f"pdhg_crossover_size(iters={iters}): m = "
              f"{pdhg_crossover_size(iters)}  # square size where the "
              "O(m+n) pivot count overtakes a conditioning-bound "
              "iteration count")


if __name__ == "__main__":
    main()
