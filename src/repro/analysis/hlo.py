"""HLO-text analysis: collective-bytes extraction for the roofline.

`cost_analysis()` reports FLOPs and memory traffic but not collective
traffic, so we parse the SPMD-partitioned module text and sum the bytes of
every cross-device collective. Async pairs (`all-gather-start` /
`all-gather-done`) are counted once (on the start). Bytes per op =
max(operand bytes, output bytes) — a consistent proxy for on-wire traffic
across all-reduce (out==in), all-gather (out = in x shards) and
reduce-scatter (in = out x shards).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Returns {op_kind: {'count': int, 'bytes': int}} plus '_total'."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition(" = ")
        for kind in COLLECTIVES:
            # match `kind(`, `kind-start(`; skip `-done` (second half of async)
            m = re.search(rf"\b{kind}(-start)?\(", rhs)
            if not m:
                continue
            if re.search(rf"\b{kind}-done\(", rhs):
                continue
            out_b = _shape_bytes(rhs[: m.start()]) + _shape_bytes(lhs)
            operand_text = rhs[m.end():]
            op_b = _shape_bytes(operand_text.split(", replica_groups")[0]
                                .split(", channel_id")[0])
            stats[kind]["count"] += 1
            stats[kind]["bytes"] += max(out_b, op_b)
            break
    total = {"count": sum(v["count"] for v in stats.values()),
             "bytes": sum(v["bytes"] for v in stats.values())}
    out = dict(stats)
    out["_total"] = total
    return out


def op_histogram(hlo_text: str, top: int = 20):
    """Crude op-kind histogram of a partitioned module (perf debugging)."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"= (?:[a-z0-9_]+\[.*?\]\{?[0-9,]*\}?\s+)?([a-z][a-z0-9-]*)\(",
                      line)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
