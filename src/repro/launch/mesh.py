"""Production mesh definitions.

A v5e pod is a 16x16 chip torus: single-pod mesh (data=16, model=16).
Multi-pod adds a leading pure-DP 'pod' axis: (pod=2, data=16, model=16) —
512 chips. Defined as functions so importing this module never touches jax
device state (the dry-run re-initializes jax with 512 host devices first).
"""
from __future__ import annotations

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU host-device tests."""
    return make_mesh(shape, axes)
