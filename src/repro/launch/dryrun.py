import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first init, and the production meshes need 512 host placeholder
devices. Everything else (smoke tests, benches) sees 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 16x16 pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 2x16x16

Per cell it records: memory_analysis (fits-in-HBM evidence), cost_analysis
(FLOPs/bytes for the roofline), and the collective-bytes histogram parsed
from the partitioned HLO. Artifacts land in artifacts/dryrun/*.json.
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             cfg_override=None, tag: str = "") -> dict:
    from repro.launch.mesh import make_production_mesh
    from repro.launch.cells import build_cell
    from repro.analysis.hlo_cost import module_cost
    from repro.analysis.roofline import compute_roofline
    from repro.models import shape_by_name

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "tag": tag}
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, cfg=cfg_override)
    if "skipped" in cell:
        record["skipped"] = cell["skipped"]
        _save(record, out_dir)
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: SKIP ({cell['skipped']})")
        return record

    cfg = cell["cfg"]
    total_params = sum(int(x.size) for x in jax.tree.leaves(cell["args"][0]))
    record["total_params"] = total_params
    try:
        with mesh:
            jitted = jax.jit(cell["fn"],
                             in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"])
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        record["memory_analysis"] = _mem_dict(mem)
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["cost_analysis_raw"] = {
            k: float(v) for k, v in dict(cost).items()
            if np.isscalar(v) and k in ("flops", "bytes accessed",
                                        "transcendentals", "optimal_seconds")}
        txt = compiled.as_text()
        # trip-count-aware HLO cost model (cost_analysis counts while bodies
        # once — useless for scan-over-layers; see analysis/hlo_cost.py)
        hcost = module_cost(txt)
        record["hlo_cost"] = {"flops": hcost["flops"],
                              "mem_bytes": hcost["mem_bytes"]}
        record["collectives"] = hcost["collectives"]
        record["lower_s"] = round(t_lower, 1)
        record["compile_s"] = round(t_compile, 1)

        flops = hcost["flops"]
        bytes_acc = hcost["mem_bytes"]
        coll_b = hcost["collectives"]["_total"]["bytes"]
        rl = compute_roofline(
            cfg, shape_by_name(shape_name),
            per_device_flops=flops, per_device_bytes=bytes_acc,
            per_device_coll_bytes=coll_b, chips=chips,
            total_params=total_params)
        record["roofline"] = rl.as_dict()
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"flops/dev={flops:.3e} collB/dev={coll_b:.3e} "
              f"bottleneck={rl.bottleneck}")
    except Exception as e:  # a failed cell is a bug — record it loudly
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: FAIL {record['error']}")
    _save(record, out_dir)
    return record


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _save(record: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = record.get("tag", "")
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}" + \
        (f"__{tag}" if tag else "")
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main():
    from repro.configs import ARCH_IDS, CANONICAL
    from repro.models.config import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    args = ap.parse_args()

    archs = list(CANONICAL) if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.out))
    n_ok = sum("roofline" in r for r in results)
    n_skip = sum("skipped" in r for r in results)
    n_fail = sum("error" in r for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
