"""End-to-end training driver.

Production behaviors wired in:
  * auto-resume from the latest atomic checkpoint (--checkpoint-dir),
  * async checkpointing off the training thread (--save-every),
  * deterministic data skip-to-step on restart (pipeline state = step),
  * straggler watchdog: wall-time per step vs running median; slow steps
    (> --straggler-factor x median) are logged as incidents,
  * optional mesh: --mesh 2x2 shards over (data, model) host devices,
  * gradient-accumulation microbatching (--microbatches).

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
      --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 (data x model)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--curve-out", default=None,
                    help="CSV path for the loss curve")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (custom model size)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    args = ap.parse_args()

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={int(np.prod(shape))}")

    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.distributed.sharding import Sharder, make_mesh
    from repro.distributed.steps import make_train_step
    from repro.models import build_model
    from repro.optim import get_optimizer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {k: getattr(args, a) for k, a in
                 [("d_model", "d_model"), ("n_layers", "n_layers"),
                  ("d_ff", "d_ff"), ("vocab", "vocab")]
                 if getattr(args, a) is not None}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = None
    shd = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
        shd = Sharder(cfg, mesh)

    model = build_model(cfg, shd)
    params, specs = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M mesh={args.mesh}")

    opt = get_optimizer(cfg.optimizer if not args.reduced else "adamw",
                        lr=args.lr)
    opt_state = jax.jit(opt.init)(params)
    if shd is not None:
        params = jax.device_put(params, shd.param_shardings(specs))

    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatches=args.microbatches))
    data = DataPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                        seed=0)

    start = 0
    mgr = None
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = mgr.extra(latest).get("data_step", latest)
            print(f"[train] resumed from step {latest}")

    durations = []
    curve = []
    ctx = mesh if mesh is not None else _null_ctx()
    with ctx:
        for s in range(start, args.steps):
            t0 = time.perf_counter()
            batch = jax.tree.map(jnp.asarray, data.batch_at(s))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > args.straggler_factor * med:
                print(f"[watchdog] straggler step {s}: {dt:.2f}s "
                      f"(median {med:.2f}s)")
            if s % args.log_every == 0 or s == args.steps - 1:
                tok_s = args.batch * args.seq / dt
                print(f"[train] step={s} loss={loss:.4f} {dt:.2f}s "
                      f"({tok_s:.0f} tok/s)")
            curve.append((s, loss))
            if mgr and s > start and s % args.save_every == 0:
                mgr.save(s, {"params": params, "opt": opt_state},
                         blocking=False, extra={"data_step": s})
    if mgr:
        mgr.wait()
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 extra={"data_step": args.steps})
    if args.curve_out:
        os.makedirs(os.path.dirname(args.curve_out) or ".", exist_ok=True)
        with open(args.curve_out, "w") as f:
            f.write("step,loss\n")
            for s, l in curve:
                f.write(f"{s},{l:.5f}\n")
        print(f"[train] wrote {args.curve_out}")
    print(f"[train] final loss {curve[-1][1]:.4f} "
          f"(first {curve[0][1]:.4f})")


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
