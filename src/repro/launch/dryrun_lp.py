import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's own workload on the production mesh: batches of
LPs sharded over all 256/512 chips, in both distribution modes:

  * pjit      — lockstep global while-loop (paper-faithful: every pivot is
                synchronized across the whole batch; the loop condition is a
                cross-chip all-reduce)
  * shard_map — per-chip termination (the TPU analogue of the paper's
                per-block early exit; zero cross-chip collectives)

The simplex while-loop has no static trip count, so the HLO cost model takes
default_trip = the oracle-measured mean pivot count for the workload class.

  PYTHONPATH=src python -m repro.launch.dryrun_lp [--multi-pod]
"""
import argparse
import json

import numpy as np


def main():
    import jax
    from repro.configs.paper_lp import WORKLOADS, build_batch
    from repro.core import LPBatch, canonical_shape, solve_batched_reference
    from repro.core.distributed import solve_pjit, solve_shard_map
    from repro.launch.mesh import make_production_mesh
    from repro.analysis.hlo_cost import module_cost
    from repro.core.simplex import flops_per_pivot

    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun_lp")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    os.makedirs(args.out, exist_ok=True)
    rng = np.random.default_rng(0)

    for wl in WORKLOADS:
        # measure typical pivot counts on a small oracle sample (the oracle
        # accepts fixture-backed GeneralLPBatch samples directly)
        sample = build_batch(wl, batch=32, rng=rng)
        ref = solve_batched_reference(sample)
        mean_pivots = float(ref.iterations.mean())

        # fixture workloads are lowered at their *canonical* shape — that is
        # the tableau geometry the chips actually execute
        m_dev, n_dev = ((wl.m, wl.n) if wl.fixture is None
                        else canonical_shape(sample))
        batch = LPBatch(
            A=np.zeros((wl.batch, m_dev, n_dev), np.float32),
            b=np.zeros((wl.batch, m_dev), np.float32),
            c=np.zeros((wl.batch, n_dev), np.float32))
        rec = {"workload": wl.name, "mesh": mesh_name, "chips": chips,
               "batch": wl.batch, "m": wl.m, "n": wl.n,
               "m_device": m_dev, "n_device": n_dev,
               "mean_pivots": mean_pivots}
        for mode, solver in (("pjit", solve_pjit),
                             ("shard_map", solve_shard_map)):
            with mesh:
                lowered = solver(batch, mesh, lower_only=True)
                compiled = lowered.compile()
            txt = compiled.as_text()
            cost = module_cost(txt, default_trip=mean_pivots)
            ana = flops_per_pivot(m_dev, n_dev) * mean_pivots * wl.batch / chips
            mem = compiled.memory_analysis()
            rec[mode] = {
                "flops_per_dev": cost["flops"],
                "analytic_flops_per_dev": ana,
                "mem_bytes_per_dev": cost["mem_bytes"],
                "collective_bytes_per_dev":
                    cost["collectives"]["_total"]["bytes"],
                "collective_count": cost["collectives"]["_total"]["count"],
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "compute_s": cost["flops"] / 197e12,
                "memory_s": cost["mem_bytes"] / 819e9,
                "collective_s":
                    cost["collectives"]["_total"]["bytes"] / 50e9,
            }
            print(f"[dryrun-lp] {wl.name} {mesh_name} {mode}: "
                  f"pivots~{mean_pivots:.0f} "
                  f"flops/dev={rec[mode]['flops_per_dev']:.2e} "
                  f"collB/dev={rec[mode]['collective_bytes_per_dev']:.2e} "
                  f"coll#={rec[mode]['collective_count']:.0f}")
        with open(os.path.join(args.out,
                               f"{wl.name}__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
