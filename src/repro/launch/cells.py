"""(architecture x input-shape x mesh) cell construction for the dry-run.

For every cell this builds: the step function (train_step / prefill / decode),
ShapeDtypeStruct arguments (no allocation — the full configs only ever exist
as abstract shapes on this host), and in/out NamedShardings resolved through
the per-arch Sharder (so non-divisible dims degrade to replication instead of
failing to partition).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model, shape_by_name
from repro.models.config import ModelConfig, ShapeCell
from repro.distributed.sharding import Sharder
from repro.distributed.steps import (make_decode_step, make_prefill_step,
                                     make_train_step)
from repro.optim import get_optimizer

FULL_ATTENTION_FAMILIES = ("dense", "moe", "vlm", "encdec")


def cell_is_skipped(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    if cell.name == "long_500k" and cfg.family in FULL_ATTENTION_FAMILIES:
        return ("skipped: 524k-token dense-KV decode is quadratic-cost for "
                "pure full-attention archs (per assignment, run only for "
                "SSM/hybrid)")
    return None


def _abstract_params(model):
    captured = {}

    def initfn(k):
        p, s = model.init(k)
        captured["specs"] = s
        return p

    sds = jax.eval_shape(initfn, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sds, captured["specs"]


def _guarded_sharding(shd: Sharder, sds_tree, logical_tree):
    """logical spec -> NamedSharding, dropping any dim whose size doesn't
    divide its mesh-axis extent."""
    if shd.mesh is None:
        return None

    def one(sds, logical):
        entries = []
        for dim, ax in enumerate(tuple(logical)):
            r = shd.rules.get(ax) if ax is not None else None
            if r is not None and sds.shape[dim] % shd._axis_size(r) != 0:
                r = None
            entries.append(r)
        return NamedSharding(shd.mesh, P(*entries))

    return jax.tree.map(one, sds_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def _batch_axes(shd: Sharder, n: int):
    if shd.mesh is None:
        return None
    dp = shd.dp_axes or None
    if dp is not None and n % shd._axis_size(dp) != 0:
        dp = None
    return dp


def build_cell(arch: str, shape_name: str, mesh, *,
               cfg: Optional[ModelConfig] = None,
               microbatches: Optional[int] = None):
    """Returns a dict {kind, fn, args, in_shardings, out_shardings, cfg,
    note} or {'skipped': reason}."""
    cfg = cfg or get_config(arch)
    cell = shape_by_name(shape_name)
    skip = cell_is_skipped(cfg, cell)
    if skip:
        return {"skipped": skip, "cfg": cfg}

    shd = Sharder(cfg, mesh)
    model = build_model(cfg, shd)
    params_sds, specs = _abstract_params(model)
    param_sh = shd.param_shardings(specs)

    B, S = cell.global_batch, cell.seq_len
    bt = _batch_axes(shd, B)
    i32 = jnp.int32

    def nsh(spec):
        return NamedSharding(mesh, spec) if mesh is not None else None

    if cell.kind == "train":
        opt = get_optimizer(cfg.optimizer)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_logical = opt.state_logical(specs)
        opt_sh = _guarded_sharding_opt(shd, opt_sds, opt_logical)

        batch_sds = {"tokens": jax.ShapeDtypeStruct((B, _text_len(cfg, S)), i32),
                     "labels": jax.ShapeDtypeStruct((B, _text_len(cfg, S)), i32)}
        batch_sh = {"tokens": nsh(P(bt, None)), "labels": nsh(P(bt, None))}
        if cfg.family == "vlm":
            batch_sds["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
            batch_sh["patches"] = nsh(P(bt, None, None))
        if cfg.family == "encdec":
            batch_sds["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
            batch_sh["frames"] = nsh(P(bt, None, None))

        mb = microbatches if microbatches is not None \
            else cfg.train_microbatches
        fn = make_train_step(model, opt, microbatches=mb)
        return {
            "kind": "train", "fn": fn, "cfg": cfg, "model": model,
            "args": (params_sds, opt_sds, batch_sds),
            "in_shardings": (param_sh, opt_sh, batch_sh),
            "out_shardings": (param_sh, opt_sh, None),
        }

    if cell.kind == "prefill":
        tokens_sds = jax.ShapeDtypeStruct((B, _text_len(cfg, S)), i32)
        extra_sds, extra_sh = _extra_inputs(cfg, B, S, bt, nsh)
        cache_sh = _guarded_sharding(shd, model.cache_shape(B, S),
                                     model.cache_logical_spec())
        fn = make_prefill_step(model)
        return {
            "kind": "prefill", "fn": fn, "cfg": cfg, "model": model,
            "args": (params_sds, tokens_sds, extra_sds),
            "in_shardings": (param_sh, nsh(P(bt, None)), extra_sh),
            "out_shardings": (nsh(P(bt, None)) if mesh else None, cache_sh),
        }

    # decode: one token against a full cache of S
    cache_sds = model.cache_shape(B, S)
    cache_sh = _guarded_sharding(shd, cache_sds, model.cache_logical_spec())
    token_sds = jax.ShapeDtypeStruct((B,), i32)
    pos_sds = jax.ShapeDtypeStruct((B,), i32)
    fn = make_decode_step(model)
    return {
        "kind": "decode", "fn": fn, "cfg": cfg, "model": model,
        "args": (params_sds, cache_sds, token_sds, pos_sds),
        "in_shardings": (param_sh, cache_sh, nsh(P(bt)), nsh(P(bt))),
        "out_shardings": (nsh(P(bt, None)) if mesh else None, cache_sh),
    }


def _text_len(cfg: ModelConfig, S: int) -> int:
    return S - cfg.n_patches if cfg.family == "vlm" else S


def _extra_inputs(cfg: ModelConfig, B: int, S: int, bt, nsh):
    if cfg.family == "vlm":
        return ({"patches": jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))},
            {"patches": nsh(P(bt, None, None))})
    if cfg.family == "encdec":
        return ({"frames": jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.dtype(cfg.dtype))},
            {"frames": nsh(P(bt, None, None))})
    return None, None


def _guarded_sharding_opt(shd: Sharder, sds_tree, logical_tree):
    """Optimizer-state shardings: ZeRO-1 via opt_state_spec + divisibility
    guard."""
    if shd.mesh is None:
        return None

    def one(sds, logical):
        spec = shd.opt_state_spec(tuple(logical))
        entries = []
        for dim, r in enumerate(spec):
            if r is not None and sds.shape[dim] % shd._axis_size(r) != 0:
                r = None
            entries.append(r)
        return NamedSharding(shd.mesh, P(*entries))

    return jax.tree.map(one, sds_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
