"""Batched serving driver: continuous prefill + decode with a step-level
scheduler (static batch; decode slot reuse), reporting tokens/s.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of batched request waves")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G

    prefill = jax.jit(lambda p, t: model.prefill(p, t))
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))

    def pad_caches(caches, old, new):
        def pad(x):
            if x.ndim >= 3 and x.shape[2] == old:
                padw = [(0, 0)] * x.ndim
                padw[2] = (0, new - old)
                return jnp.pad(x, padw)
            return x
        return jax.tree.map(pad, caches)

    t_first = None
    n_tokens = 0
    t0 = time.perf_counter()
    for wave in range(args.requests):
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)
        logits, caches = prefill(params, prompts)
        caches = pad_caches(caches, P, total)
        tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
        out = [np.asarray(tok)]
        for g in range(G - 1):
            pos = jnp.full((B,), P + g, jnp.int32)
            logits, caches = decode(params, caches, tok, pos)
            tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
            out.append(np.asarray(tok))
            if t_first is None:
                t_first = time.perf_counter() - t0
        n_tokens += B * G
        print(f"[serve] wave {wave}: generated {B}x{G} tokens; "
              f"sample={np.stack(out, 1)[0][:8].tolist()}")
    dt = time.perf_counter() - t0
    print(f"[serve] {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s, ttft~{t_first:.2f}s)")


if __name__ == "__main__":
    main()
