"""Fixed-format MPS reader/writer -> ``GeneralLPBatch``.

The front door for real LP suites (Netlib et al.): parses the classic
fixed-format MPS sections — NAME, ROWS (N/L/G/E), COLUMNS, RHS (including
the objective-row entry, which sets the objective constant ``c0 = -value``
per the MPS convention), RANGES, BOUNDS (UP/LO/FX/FR/MI/PL/BV) and ENDATA —
plus the common OBJSENSE extension.  Parsing is whitespace-tolerant (names
may not contain blanks), which accepts both strictly column-aligned files
and the free-format variants most tools emit; ``*`` comment lines are
skipped.

Integrality is *recorded, not enforced*: columns inside
``'MARKER' 'INTORG'``/``'INTEND'`` pairs and columns with BV/UI/LI bounds
land in ``GeneralLPBatch.integer`` (a (n,) mask).  Every LP solver ignores
the mask (it solves the continuous relaxation); the branch-and-bound
driver (core/branch_bound.py) is the consumer that enforces it.

``write_mps`` emits a fixed-format file that re-parses bit-identically
(values at ``%.12g``), which is what the CI ``mps-roundtrip`` smoke and the
fixture round-trip tests assert.  ``perturbed_batch`` expands one parsed
instance into a B-sized batch by multiplicative perturbation of the nonzero
data — the paper's recipe for building same-shape batches out of a single
real instance (Sec. 6).
"""
from __future__ import annotations

import os
import warnings
from typing import Optional

import numpy as np

from repro.core.forms import GeneralLPBatch

_FIXTURE_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))), "tests", "fixtures")

# The vendored general-form instances (tests/fixtures/README.md has the
# provenance notes).  Benchmarks and configs address them by these names.
FIXTURE_NAMES = ("afiro", "sc50b_like", "sc205_like", "testprob")

# The vendored MIP instances (integer columns; the branch-and-bound driver's
# fixtures).  Kept separate so the pure-LP benchmark loops above stay
# unchanged; their LP relaxations parse/solve like any other fixture.
MIP_FIXTURE_NAMES = ("knapsack", "assignment", "scheduling")


def fixture_path(name: str) -> str:
    """Absolute path of a vendored fixture (with or without ``.mps``)."""
    if not name.endswith(".mps"):
        name += ".mps"
    return os.path.join(_FIXTURE_DIR, name)


def read_mps(path: str) -> GeneralLPBatch:
    """Parse an MPS file into a single-member ``GeneralLPBatch`` (B=1)."""
    name = "mps"
    maximize = False
    row_order: list = []           # (sense, row_name), objective excluded
    free_rows: set = set()         # secondary N rows (legal MPS; ignored)
    obj_row: Optional[str] = None
    entries: dict = {}             # col -> {row: val}
    col_order: list = []
    rhs: dict = {}
    obj_const = 0.0
    ranges: dict = {}
    bounds: dict = {}              # col -> [lb, ub]
    integer_cols: set = set()      # columns declared integral
    in_integer = False             # inside an INTORG..INTEND marker pair
    section = None

    with open(path) as f:
        for lineno, raw in enumerate(f, 1):
            if raw.startswith("*") or not raw.strip():
                continue
            fields = raw.split()
            if not raw[0].isspace():           # section header
                section = fields[0].upper()
                if section == "NAME" and len(fields) > 1:
                    name = fields[1]
                elif section == "OBJSENSE" and len(fields) > 1:
                    maximize = fields[1].upper().startswith("MAX")
                elif section == "ENDATA":
                    break
                continue
            if section == "OBJSENSE":
                maximize = fields[0].upper().startswith("MAX")
            elif section == "ROWS":
                sense, rname = fields[0].upper(), fields[1]
                if sense == "N":
                    if obj_row is None:        # first N row is the objective
                        obj_row = rname
                    else:                      # later N rows are free rows:
                        free_rows.add(rname)   # entries are discarded
                elif sense in ("L", "G", "E"):
                    row_order.append((sense, rname))
                else:
                    raise ValueError(
                        f"{path}:{lineno}: unknown row sense {sense!r}")
            elif section == "COLUMNS":
                if len(fields) >= 3 and fields[1].upper() == "'MARKER'":
                    mk = fields[-1].upper().strip("'")
                    if mk == "INTORG":
                        in_integer = True
                    elif mk == "INTEND":
                        in_integer = False
                    else:
                        warnings.warn(f"{path}:{lineno}: unknown marker "
                                      f"{mk!r} ignored")
                    continue
                col = fields[0]
                if col not in entries:
                    entries[col] = {}
                    col_order.append(col)
                if in_integer:
                    integer_cols.add(col)
                for rname, val in zip(fields[1::2], fields[2::2]):
                    entries[col][rname] = float(val)
            elif section == "RHS":
                # field[0] is the RHS-set label
                for rname, val in zip(fields[1::2], fields[2::2]):
                    if rname == obj_row:
                        obj_const = -float(val)   # MPS objective constant
                    else:
                        rhs[rname] = float(val)
            elif section == "RANGES":
                for rname, val in zip(fields[1::2], fields[2::2]):
                    ranges[rname] = float(val)
            elif section == "BOUNDS":
                btype = fields[0].upper()
                col = fields[2]
                b = bounds.setdefault(col, [0.0, np.inf])
                val = float(fields[3]) if len(fields) > 3 else None
                if btype == "UP":
                    b[1] = val
                    if val is not None and val < 0 and b[0] == 0.0:
                        # historic MPS semantics: a negative UP bound with
                        # no explicit LO frees the variable below
                        b[0] = -np.inf
                elif btype == "LO":
                    b[0] = val
                elif btype == "FX":
                    b[0] = b[1] = val
                elif btype == "FR":
                    b[0], b[1] = -np.inf, np.inf
                elif btype == "MI":
                    b[0] = -np.inf
                elif btype == "PL":
                    b[1] = np.inf
                elif btype == "BV":
                    b[0], b[1] = 0.0, 1.0
                    integer_cols.add(col)
                elif btype == "UI":
                    b[1] = val
                    integer_cols.add(col)
                elif btype == "LI":
                    b[0] = val
                    integer_cols.add(col)
                else:
                    raise ValueError(
                        f"{path}:{lineno}: unsupported bound type {btype!r}")
            elif section in (None, "NAME"):
                raise ValueError(f"{path}:{lineno}: data before any section")

    if obj_row is None:
        raise ValueError(f"{path}: no objective (N) row")
    m, n = len(row_order), len(col_order)
    ridx = {rname: i for i, (_, rname) in enumerate(row_order)}
    A = np.zeros((1, m, n))
    c = np.zeros((1, n))
    for j, col in enumerate(col_order):
        for rname, val in entries[col].items():
            if rname == obj_row:
                c[0, j] = val
            elif rname in ridx:
                A[0, ridx[rname], j] = val
            elif rname not in free_rows:
                raise ValueError(f"{path}: column {col!r} references "
                                 f"unknown row {rname!r}")
    for rname in list(rhs) + list(ranges):
        if rname not in ridx and rname not in free_rows:
            raise ValueError(f"{path}: RHS/RANGES references unknown row "
                             f"{rname!r}")
    sense = np.array([s for s, _ in row_order], dtype="<U1")
    b = np.array([[rhs.get(rname, 0.0) for _, rname in row_order]])
    rng_arr = None
    if any(rname in ridx for rname in ranges):
        rng_arr = np.full(m, np.nan)
        for rname, val in ranges.items():
            if rname in ridx:
                rng_arr[ridx[rname]] = val
    lb = np.zeros((1, n))
    ub = np.full((1, n), np.inf)
    cidx = {col: j for j, col in enumerate(col_order)}
    for col, (blo, bhi) in bounds.items():
        if col not in cidx:
            raise ValueError(f"{path}: BOUNDS references unknown column "
                             f"{col!r}")
        lb[0, cidx[col]], ub[0, cidx[col]] = blo, bhi
    integer = None
    if integer_cols:
        integer = np.array([col in integer_cols for col in col_order], bool)
    return GeneralLPBatch.from_arrays(
        A, sense, b, lb=lb, ub=ub, c=c, c0=obj_const, maximize=maximize,
        ranges=rng_arr, name=name,
        row_names=[rname for _, rname in row_order], col_names=col_order,
        integer=integer)


def _num(v: float) -> str:
    return f"{v:.12g}"


def _pairs(label: str, items) -> list:
    """Format (row, value) pairs two per line under a section label."""
    out = []
    items = list(items)
    for k in range(0, len(items), 2):
        pair = items[k:k + 2]
        line = f"    {label:<10}{pair[0][0]:<10}{_num(pair[0][1]):>14}"
        if len(pair) == 2:
            line += f"   {pair[1][0]:<10}{_num(pair[1][1]):>14}"
        out.append(line)
    return out


def write_mps(g: GeneralLPBatch, path: str) -> None:
    """Write a single-member ``GeneralLPBatch`` as fixed-format MPS.

    Round-trip contract: ``read_mps(write_mps(g))`` reproduces the batch
    bit-identically at %.12g (the fixture round-trip smoke in
    scripts/check.sh asserts exactly this).
    """
    if g.batch != 1:
        raise ValueError(
            f"write_mps writes one instance, got a batch of {g.batch} "
            "(slice it, or write the un-perturbed source instance)")
    m, n = g.m, g.n
    rows = list(g.row_names) if g.row_names else [f"R{i}" for i in range(m)]
    cols = list(g.col_names) if g.col_names else [f"C{j}" for j in range(n)]
    out = [f"NAME          {g.name}"]
    if g.maximize:
        out += ["OBJSENSE", "    MAX"]
    out.append("ROWS")
    out += [f" {g.sense[i]}  {rows[i]}" for i in range(m)]
    out.append(" N  COST")
    out.append("COLUMNS")
    intg = (np.zeros(n, bool) if g.integer is None
            else np.asarray(g.integer, bool))
    in_int = False
    for j in range(n):
        if intg[j] != in_int:
            mk = "INTORG" if intg[j] else "INTEND"
            out.append(f"    MARKER                 'MARKER'"
                       f"                 '{mk}'")
            in_int = bool(intg[j])
        items = [(rows[i], g.A[0, i, j]) for i in range(m)
                 if g.A[0, i, j] != 0.0]
        if g.c[0, j] != 0.0 or not items:
            # an explicit objective entry also *declares* columns that have
            # no nonzeros at all, so they survive the round-trip
            items.append(("COST", g.c[0, j]))
        out += _pairs(cols[j], items)
    if in_int:
        out.append("    MARKER                 'MARKER'"
                   "                 'INTEND'")
    out.append("RHS")
    items = [(rows[i], g.rhs[0, i]) for i in range(m) if g.rhs[0, i] != 0.0]
    if g.c0[0] != 0.0:
        items.append(("COST", -g.c0[0]))
    out += _pairs("RHS", items)
    if g.ranges is not None and np.isfinite(g.ranges).any():
        out.append("RANGES")
        out += _pairs("RNG", [(rows[i], g.ranges[i]) for i in range(m)
                              if np.isfinite(g.ranges[i])])
    blines = []
    for j in range(n):
        lo, hi = g.lb[0, j], g.ub[0, j]
        if lo == 0.0 and np.isinf(hi):
            continue
        if lo == hi:
            blines.append(f" FX BND       {cols[j]:<10}{_num(lo):>14}")
            continue
        if np.isneginf(lo) and np.isinf(hi):
            blines.append(f" FR BND       {cols[j]:<10}")
            continue
        if np.isneginf(lo):
            blines.append(f" MI BND       {cols[j]:<10}")
        elif lo != 0.0:
            blines.append(f" LO BND       {cols[j]:<10}{_num(lo):>14}")
        if not np.isinf(hi):
            blines.append(f" UP BND       {cols[j]:<10}{_num(hi):>14}")
    if blines:
        out.append("BOUNDS")
        out += blines
    out.append("ENDATA")
    with open(path, "w") as f:
        f.write("\n".join(out) + "\n")


def perturbed_batch(g: GeneralLPBatch, B: int,
                    rng: Optional[np.random.Generator] = None,
                    rel: float = 0.01,
                    perturb: tuple = ("A", "rhs", "c")) -> GeneralLPBatch:
    """Expand one instance into a B-sized batch the way the paper builds
    its Netlib batches: each member is the instance with nonzero data
    multiplicatively perturbed by ±``rel``.  Member 0 is the unperturbed
    original; structure (sparsity, senses, ranges, bounds, names) is shared
    so the whole batch canonicalizes to one static shape."""
    if rng is None:
        rng = np.random.default_rng(0)
    if g.batch != 1:
        raise ValueError("perturbed_batch expands a single instance "
                         f"(got batch={g.batch})")

    def expand(arr, on):
        tiled = np.repeat(np.asarray(arr, np.float64), B, axis=0)
        if on:
            noise = 1.0 + rel * rng.uniform(-1.0, 1.0, size=tiled.shape)
            noise[0] = 1.0
            tiled = tiled * np.where(tiled != 0.0, noise, 1.0)
        return tiled

    return GeneralLPBatch(
        A=expand(g.A, "A" in perturb),
        sense=g.sense,
        rhs=expand(g.rhs, "rhs" in perturb),
        lb=np.repeat(g.lb, B, axis=0),
        ub=np.repeat(g.ub, B, axis=0),
        c=expand(g.c, "c" in perturb),
        c0=np.repeat(g.c0, B, axis=0),
        maximize=g.maximize, ranges=g.ranges,
        name=f"{g.name}_x{B}", row_names=g.row_names, col_names=g.col_names,
        integer=g.integer)


def perturbed_sequence(g: GeneralLPBatch, B: int, K: int,
                       rng: Optional[np.random.Generator] = None,
                       rel: float = 0.01, step_rel: float = 0.005,
                       perturb: tuple = ("rhs", "c")) -> list:
    """Deterministic trajectory of ``K`` successively-perturbed batches from
    one instance — the shared workload for warm-start benchmarks and tests.

    Batch 0 is ``perturbed_batch(g, B, rel=rel)``; each subsequent batch
    applies an independent multiplicative ±``step_rel`` nudge to the
    *nonzero* entries of the perturbed fields of its predecessor (default
    rhs + c: the bound-edit/objective-nudge workload of MPC loops and
    branch-and-bound frontiers — pass ``perturb=("A", "rhs", "c")`` for
    matrix drift too).  Nudging only nonzeros keeps the sparsity pattern,
    senses, bounds and canonical shape static across the trajectory, which
    is exactly the contract a ``WarmStart`` carrier rides on.  With the
    default ``rng=None`` the trajectory is reproducible (seed 0).
    Returns a list of K ``GeneralLPBatch`` objects."""
    if rng is None:
        rng = np.random.default_rng(0)
    if K < 1:
        raise ValueError(f"K must be >= 1, got {K}")
    seq = [perturbed_batch(g, B, rng=rng, rel=rel, perturb=perturb)]

    def nudge(arr, on):
        arr = np.asarray(arr, np.float64)
        if not on:
            return arr.copy()
        noise = 1.0 + step_rel * rng.uniform(-1.0, 1.0, size=arr.shape)
        return arr * np.where(arr != 0.0, noise, 1.0)

    for _ in range(K - 1):
        p = seq[-1]
        seq.append(GeneralLPBatch(
            A=nudge(p.A, "A" in perturb),
            sense=p.sense,
            rhs=nudge(p.rhs, "rhs" in perturb),
            lb=p.lb.copy(), ub=p.ub.copy(),
            c=nudge(p.c, "c" in perturb),
            c0=p.c0.copy(),
            maximize=p.maximize, ranges=p.ranges,
            name=f"{g.name}_seq{len(seq)}", row_names=p.row_names,
            col_names=p.col_names, integer=p.integer))
    return seq
