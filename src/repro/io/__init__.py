"""Problem ingestion: file formats -> GeneralLPBatch (core/forms.py)."""
from .mps import (  # noqa: F401
    FIXTURE_NAMES, fixture_path, perturbed_batch, read_mps, write_mps,
)
