"""Fault-tolerant checkpointing: atomic, async, mesh-elastic.

* **Atomic**: writes land in `step_XXXX.tmp/` and are renamed to `step_XXXX/`
  only after fsync — a killed job can never leave a half checkpoint that
  auto-resume would pick up.
* **Async**: `save(..., blocking=False)` snapshots device arrays to host
  (np.asarray forces a D2H gather) and hands serialization to a writer
  thread; the train loop keeps stepping while bytes hit disk.
* **Elastic / resharding restore**: checkpoints store full (unsharded)
  arrays per leaf; `restore(..., shardings=...)` re-lays them out for ANY
  mesh via device_put — so a job checkpointed on (2,16,16) restarts on
  (16,16) or a differently-sized data axis (elastic re-scale after node
  loss). Tested in tests/test_checkpoint.py including mesh changes.
* **Retention**: keep the newest `keep` checkpoints; `latest_step()` powers
  auto-resume in launch/train.py.

Production note: per-leaf .npy + JSON tree manifest is deliberately simple;
swap the `_write_leaf/_read_leaf` pair for tensorstore/OCDBT for >TB models
(interface is the same — the manifest only stores leaf paths).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[dict] = None):
        """Snapshot device arrays to host immediately, then write (possibly
        async). `tree` is any pytree of arrays."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # D2H gather (full arrays)

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "treedef": str(treedef), "extra": extra or {}}
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def restore(self, step: int, like: Any, *, shardings: Any = None) -> Any:
        """Rebuild the pytree saved at `step`. `like` provides the treedef;
        `shardings` (optional matching tree or single sharding) re-lays
        leaves out on the current mesh (elastic restore)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), \
            f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves_like)}"
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None and not _is_single(shardings)
                        else [shardings] * len(leaves_like))
        for i, (ref, sh) in enumerate(zip(leaves_like, shard_leaves)):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            if hasattr(ref, "dtype"):
                arr = arr.astype(ref.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out)

    def extra(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f).get("extra", {})


def _is_single(sh) -> bool:
    return isinstance(sh, (jax.sharding.Sharding, type(None)))
