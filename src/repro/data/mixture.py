"""Data-source mixture weights as an LP — paper technique in the data layer.

Choose source weights w to maximize estimated utility sum_i u_i w_i subject
to token-budget rows (per-source availability caps, a minimum-diversity
floor per source, total = 1). Solved with the repo's batched simplex — many
such LPs solve at once when sweeping utility estimates (e.g. one per
validation slice), which is exactly the paper's many-small-LPs regime.

    max  u.w
    s.t. w_i <= cap_i            (availability)
         -w_i <= -floor_i        (diversity floor; makes start infeasible ->
                                  exercises the two-phase path)
         sum w <= 1
"""
from __future__ import annotations

import numpy as np

from repro.core import LPBatch, OPTIMAL, solve_batched_jax


def optimal_mixture(utilities: np.ndarray, caps: np.ndarray,
                    floors: np.ndarray) -> np.ndarray:
    """utilities: (B, S) batch of utility estimates; caps/floors: (S,).
    Returns (B, S) normalized mixture weights."""
    utilities = np.atleast_2d(np.asarray(utilities, np.float64))
    B, S = utilities.shape
    caps = np.broadcast_to(caps, (B, S)).astype(np.float64)
    floors = np.broadcast_to(floors, (B, S)).astype(np.float64)
    eye = np.tile(np.eye(S)[None], (B, 1, 1))
    A = np.concatenate([eye, -eye, np.ones((B, 1, S))], axis=1)
    b = np.concatenate([caps, -floors, np.ones((B, 1))], axis=1)
    res = solve_batched_jax(LPBatch.from_arrays(A, b, utilities))
    w = np.where((res.status == OPTIMAL)[:, None], res.x, 1.0 / S)
    return w / np.maximum(w.sum(-1, keepdims=True), 1e-9)
