from .pipeline import DataPipeline, SyntheticLM  # noqa: F401
from .mixture import optimal_mixture  # noqa: F401
