"""Deterministic synthetic LM data pipeline (host-sharded, prefetching).

Production posture without a real corpus: token streams are generated from a
seeded Markov-ish process (so a model *can* learn structure and the loss
curve is meaningful), sharded by host (`host_id/num_hosts`), resumable at an
exact step (state = (seed, step) — restart-safe without checkpointing the
stream), with a background prefetch thread that keeps `prefetch` batches
ready while the device computes (the data-side analogue of the paper's
H2D/compute overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import numpy as np


class SyntheticLM:
    """Order-1 Markov token source with per-source transition sharpness —
    different 'sources' have different entropies so mixture weights matter."""

    def __init__(self, vocab: int, seed: int = 0, sharpness: float = 2.0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # low-rank transition logits keep memory O(vocab * rank)
        rank = min(64, vocab)
        self._u = rng.normal(size=(vocab, rank)) * sharpness / np.sqrt(rank)
        self._v = rng.normal(size=(rank, vocab))

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        for t in range(seq):
            logits = self._u[toks[:, t]] @ self._v
            logits -= logits.max(-1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(-1, keepdims=True)
            # vectorized categorical draw
            cum = np.cumsum(p, axis=-1)
            u = rng.random((batch, 1))
            toks[:, t + 1] = (u > cum).sum(-1)
        return toks


class DataPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, *,
                 sources: int = 3, mixture: Optional[Sequence[float]] = None,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2):
        assert batch % num_hosts == 0
        self.vocab = vocab
        self.local_batch = batch // num_hosts
        self.seq = seq
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.sources = [SyntheticLM(vocab, seed=1000 + i, sharpness=1.0 + i)
                        for i in range(sources)]
        self.mixture = np.asarray(mixture if mixture is not None
                                  else np.ones(sources) / sources)
        self.mixture = self.mixture / self.mixture.sum()
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._step = 0

    # -- deterministic batch addressing (resume == skip-to-step) -------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, self.host_id, step, 0xBA7C4))
        src_ids = rng.choice(len(self.sources), size=self.local_batch,
                             p=self.mixture)
        toks = np.empty((self.local_batch, self.seq + 1), np.int32)
        for i, s in enumerate(src_ids):
            toks[i] = self.sources[s].sample(rng, 1, self.seq)[0]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # -- prefetch thread -------------------------------------------------------
    def start(self, step: int = 0):
        self._step = step
        self._stop.clear()

        def worker():
            s = step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is None:
            b = self.batch_at(self._step)
            self._step += 1
            return b
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
