"""train_step / serve_step builders (pjit-ready pure functions).

`make_train_step` supports gradient-accumulation microbatching: the batch is
split along its leading axis and scanned, accumulating f32 grads; XLA
overlaps each microbatch's backward collectives with the next microbatch's
compute. The optimizer update runs once per step on the accumulated grads.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def make_train_step(model, optimizer, microbatches: int = 1,
                    clip_norm: Optional[float] = 1.0):
    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mbatch):
                l, g = jax.value_and_grad(model.loss_fn)(params, mbatch)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                   acc, g)
                return acc, l

            grads, losses = jax.lax.scan(body, zero, mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()

        gnorm = global_norm(grads)
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model):
    def prefill_step(params, tokens, extra=None):
        kw = {}
        if extra is not None:
            if "patches" in extra:
                kw["patches"] = extra["patches"]
            if "frames" in extra:
                kw["frames"] = extra["frames"]
        return model.prefill(params, tokens, **kw)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos)
    return decode_step
