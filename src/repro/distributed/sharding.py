"""Logical-axis sharding rules -> mesh PartitionSpecs (DP/FSDP/TP/SP/EP).

Params carry *logical* axis tuples (see models/layers.py); this module
resolves them against a mesh:

    batch     -> ('pod','data')  (pod axis is pure DP when present)
    vocab/ff/heads/experts/d_inner -> 'model'   (tensor/expert parallel)
    residual  -> 'data' iff FSDP (2-D sharded params for the giant archs)
    seq_sp    -> 'model' iff sequence-parallel residual stream
    kv_heads  -> 'model' only when the arch's KV-head projection divides tp
    heads     -> 'model' only when H divides tp (else replicated attention)
    kv_seq    -> 'model' when the decode cache is sequence-sharded

Divisibility is decided per-arch at Sharder construction, so every
(arch x mesh) combination lowers without uneven-sharding surprises.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    # jax 0.4.x: no axis_types kwarg / jax.sharding.AxisType yet
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devices, axes)


class Sharder:
    """Resolves logical axis names for one (cfg, mesh) pair."""

    def __init__(self, cfg: ModelConfig, mesh: Optional[Mesh]):
        self.cfg = cfg
        self.mesh = mesh
        if mesh is None:
            self.tp = 1
            self.tp_axis = None
            self.dp_axes = ()
            self.rules = {}
            return
        names = mesh.axis_names
        self.tp = mesh.shape["model"] if "model" in names else 1
        self.tp_axis = "model" if "model" in names else None
        self.dp_axes = tuple(a for a in ("pod", "data") if a in names)

        n_heads = cfg.n_heads_padded or cfg.n_heads
        n_kv = cfg.n_kv_heads_padded or cfg.n_kv_heads
        heads_ok = n_heads > 0 and n_heads % self.tp == 0
        kv_ok = n_kv > 0 and n_kv % self.tp == 0
        ff_ok = cfg.d_ff > 0 and cfg.d_ff % self.tp == 0
        ffe_ok = cfg.d_ff_expert > 0 and cfg.d_ff_expert % self.tp == 0
        exp_ok = cfg.n_experts > 0 and cfg.n_experts % self.tp == 0
        din_ok = cfg.d_inner > 0 and cfg.d_inner % self.tp == 0
        fsdp = cfg.fsdp and "data" in names and cfg.d_model % mesh.shape["data"] == 0

        self.rules = {
            "layers": None,
            "batch": self.dp_axes or None,
            "vocab": "model",
            "residual": "data" if fsdp else None,
            "ff": "model" if ff_ok else None,
            "ff_expert": "model" if ffe_ok else None,
            "heads": "model" if heads_ok else None,
            "kv_heads": "model" if kv_ok else None,
            "experts": "model" if exp_ok else None,
            "d_inner": "model" if din_ok else None,
            "seq_sp": "model" if cfg.seq_shard else None,
            "kv_seq": None if kv_ok else "model",
            "expert_local": None,  # inside-shard_map expert dim
        }
        # vocab divisibility (padded vocab is a multiple of 128; 128 % tp == 0
        # for tp in {1,2,4,8,16,...,128})
        if cfg.vocab_padded % self.tp != 0:
            self.rules["vocab"] = None

    # -- params ------------------------------------------------------------
    def spec(self, logical: Tuple) -> P:
        if self.mesh is None:
            return P()
        return P(*(self.rules.get(ax) if ax is not None else None
                   for ax in logical))

    def param_shardings(self, spec_tree):
        """Map a logical-spec tree to NamedSharding tree."""
        if self.mesh is None:
            return None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, self.spec(s)), spec_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def opt_state_spec(self, logical: Tuple) -> P:
        """ZeRO-1: optimizer moments additionally shard 'residual' over
        'data' even when the params themselves don't (fsdp off)."""
        if self.mesh is None:
            return P()
        axes = []
        used = set(a for a in (self.rules.get(ax) for ax in logical) if a)
        for ax in logical:
            r = self.rules.get(ax) if ax is not None else None
            if r is None and ax == "residual" and "data" not in used \
                    and "data" in self.mesh.axis_names:
                axes.append("data")
                used.add("data")
            else:
                axes.append(r)
        return P(*axes)

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    # -- activations ---------------------------------------------------------
    def act(self, x, *logical):
        """with_sharding_constraint, guarded: a dim is only sharded when its
        size divides the axis size (e.g. seq=1 at decode never shards)."""
        if self.mesh is None:
            return x
        entries = []
        for dim, ax in enumerate(logical):
            r = self.rules.get(ax) if ax is not None else None
            if r is not None and x.shape[dim] % self._axis_size(r) != 0:
                r = None
            entries.append(r)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries)))

    def pspec(self, *logical) -> P:
        if self.mesh is None:
            return P()
        return P(*(self.rules.get(ax) if ax is not None else None
                   for ax in logical))


NULL = object()
