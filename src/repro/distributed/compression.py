"""Int8 gradient compression with error feedback (opt-in).

Large-scale posture: cross-pod gradient reduction rides the slow DCN links;
quantizing gradients to int8 (per-tensor symmetric scale) cuts that traffic
4x vs f32 / 2x vs bf16. Naive quantization biases updates; error feedback
(EF / EF21-style) carries the quantization residual into the next step,
restoring convergence (residual is a fixed point of the compressor).

`compress_decompress` reproduces exactly the numerics the weights see when
the all-reduce transports int8: quantize -> (sum is linear, so reduce of
quantized values == quantized values here where grads are already reduced by
autodiff) -> dequantize. The wire-level placement (quantize before the
cross-pod reduce) changes *where* rounding happens, not its magnitude class;
on this CPU rig the transport itself is XLA-internal, so we integrate at the
optimizer boundary and carry EF state in the train step — the measurable
object is the training trajectory, tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale


def compress_decompress(g: jax.Array):
    q, s = quantize_int8(g.astype(jnp.float32))
    return dequantize_int8(q, s)


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, ef_state):
    """Error-feedback compression over a gradient pytree.

    c = C(g + e);  e' = g + e - c.  Returns (compressed grads, new EF state).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        c = compress_decompress(corrected)
        return c, corrected - c

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def make_compressed_train_step(model, optimizer, *, microbatches: int = 1,
                               clip_norm: Optional[float] = 1.0):
    """train_step variant whose gradient pathway is int8+EF compressed.
    State pytree gains an 'ef' member alongside the optimizer state."""
    from .steps import global_norm

    def train_step(params, opt_state, ef_state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        gnorm = global_norm(grads)
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        grads, ef_state = ef_compress_tree(grads, ef_state)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, ef_state, metrics

    return train_step
