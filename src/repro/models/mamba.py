"""Mamba-1 selective SSM (falcon-mamba-7b; also the SSM half of hymba).

Training/prefill uses a *chunked associative scan*: an outer `lax.scan` over
sequence chunks carries (h, conv_tail) so the materialized (B, chunk, d_inner,
state) discretization tensors stay VMEM/HBM-friendly, while within a chunk
`associative_scan` exposes log-depth parallelism to the VPU. Decode is the
exact O(1) recurrence. d_inner shards over 'model' (every per-channel tensor
is embarrassingly parallel across channels); state/dt_rank stay local.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init


class MambaCache(NamedTuple):
    h: jax.Array     # (B, d_inner, state) f32 SSM state
    conv: jax.Array  # (B, conv_dim - 1, d_inner) rolling conv window


def mamba_init(key, cfg: ModelConfig):
    D, di, st, dr, cv = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.dt_rank, cfg.conv_dim)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["w_in"], s["w_in"] = dense_init(ks[0], D, 2 * di, dtype, ("residual", "d_inner"))
    p["conv_w"] = (jax.random.normal(ks[1], (cv, di), jnp.float32) * 0.2).astype(dtype)
    s["conv_w"] = (None, "d_inner")
    p["conv_b"] = jnp.zeros((di,), dtype)
    s["conv_b"] = ("d_inner",)
    p["w_x"], s["w_x"] = dense_init(ks[2], di, dr + 2 * st, dtype, ("d_inner", None))
    p["w_dt"], s["w_dt"] = dense_init(ks[3], dr, di, dtype, (None, "d_inner"))
    p["dt_bias"] = jnp.full((di,), -4.6, dtype)  # softplus^-1(0.01)
    s["dt_bias"] = ("d_inner",)
    # S4D-real init: A = -[1..state] per channel
    p["A_log"] = jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None],
                                  (di, 1)))
    s["A_log"] = ("d_inner", None)
    p["D"] = jnp.ones((di,), jnp.float32)
    s["D"] = ("d_inner",)
    p["w_out"], s["w_out"] = dense_init(ks[4], di, D, dtype, ("d_inner", "residual"))
    return p, s


def _ssm_coeffs(p, xc, cfg: ModelConfig):
    """xc: (B, T, di) post-conv activations -> discretized (dA, dBx, Cc)."""
    st, dr = cfg.ssm_state, cfg.dt_rank
    proj = xc @ p["w_x"]                                    # (B, T, dr+2st)
    dt_r, B_ssm, C_ssm = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B, T, di)
    A = -jnp.exp(p["A_log"])                                # (di, st)
    dA = jnp.exp(dt[..., None] * A)                          # (B, T, di, st)
    dBx = (dt * xc.astype(jnp.float32))[..., None] \
        * B_ssm.astype(jnp.float32)[..., None, :]            # (B, T, di, st)
    return dA, dBx, C_ssm.astype(jnp.float32)


def _chunk_scan(h0, dA, dBx):
    """Associative scan of h_t = dA_t h_{t-1} + dBx_t within a chunk, seeded
    with h0 by prepending the identity element carrying h0."""
    B, T, di, st = dA.shape
    a = jnp.concatenate([jnp.ones((B, 1, di, st), dA.dtype), dA], axis=1)
    b = jnp.concatenate([h0[:, None], dBx], axis=1)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs[:, 1:], hs[:, -1]                              # (B,T,di,st), h_T


def _causal_conv_chunk(p, x_chunk, tail, cv):
    """x_chunk: (B, T, di); tail: (B, cv-1, di) previous inputs."""
    xin = jnp.concatenate([tail, x_chunk], axis=1)           # (B, T+cv-1, di)
    out = sum(xin[:, i:i + x_chunk.shape[1]] * p["conv_w"][i]
              for i in range(cv))
    new_tail = xin[:, -(cv - 1):] if cv > 1 else tail
    return out + p["conv_b"], new_tail


def mamba_apply(p, x, cfg: ModelConfig, *, mode: str,
                cache: MambaCache | None = None, shd=None,
                chunk: int = 512) -> Tuple[jax.Array, MambaCache | None]:
    """x: (B, S, D) (S == 1 for decode)."""
    B, S, D = x.shape
    di, st, cv = cfg.d_inner, cfg.ssm_state, cfg.conv_dim
    xz = x @ p["w_in"]
    xr, z = jnp.split(xz, 2, axis=-1)                        # (B, S, di) each
    if shd is not None:
        xr = shd.act(xr, "batch", None, "d_inner")

    if mode == "decode":
        assert cache is not None
        conv_win = jnp.concatenate([cache.conv, xr], axis=1)  # (B, cv, di)
        xc = jnp.einsum("bcd,cd->bd", conv_win, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None]                         # (B, 1, di)
        dA, dBx, C_ssm = _ssm_coeffs(p, xc, cfg)
        h = cache.h * dA[:, 0] + dBx[:, 0]                    # (B, di, st)
        y = jnp.einsum("bds,bs->bd", h, C_ssm[:, 0])[:, None]
        y = y + p["D"] * xc.astype(jnp.float32)
        new_cache = MambaCache(h=h, conv=conv_win[:, 1:])
        out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        return out @ p["w_out"], new_cache

    # train / prefill: chunked scan over sequence
    T = min(chunk, S)
    assert S % T == 0, "seq must divide ssm chunk"
    nc = S // T
    xr_c = xr.reshape(B, nc, T, di).swapaxes(0, 1)           # (nc, B, T, di)
    z_c = z.reshape(B, nc, T, di).swapaxes(0, 1)
    if shd is not None:
        # pin scan xs to (chunk, batch, time, channel-sharded) — without this
        # GSPMD picks a layout whose per-iteration dynamic_slice forces an
        # involuntary full rematerialization (observed on the 16x16 mesh)
        xr_c = shd.act(xr_c, None, "batch", None, "d_inner")
        z_c = shd.act(z_c, None, "batch", None, "d_inner")

    h0 = jnp.zeros((B, di, st), jnp.float32)
    tail0 = jnp.zeros((B, cv - 1, di), x.dtype)

    def step(carry, inp):
        h, tail = carry
        xrc, zc = inp
        xc, tail = _causal_conv_chunk(p, xrc, tail, cv)
        xc = jax.nn.silu(xc)
        dA, dBx, C_ssm = _ssm_coeffs(p, xc, cfg)
        if cfg.ssm_impl == "kernel":
            from repro.kernels.ssm_scan import ssm_scan_bt_ds
            hs, h_last = ssm_scan_bt_ds(dA, dBx, h)
        else:
            hs, h_last = _chunk_scan(h, dA, dBx)
        y = jnp.einsum("btds,bts->btd", hs, C_ssm)
        y = y + p["D"] * xc.astype(jnp.float32)
        y = (y * jax.nn.silu(zc.astype(jnp.float32))).astype(x.dtype)
        return (h_last, tail), y

    (h_last, tail_last), ys = jax.lax.scan(step, (h0, tail0), (xr_c, z_c))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    new_cache = None
    if mode == "prefill":
        new_cache = MambaCache(h=h_last, conv=tail_last[:, -(cv - 1):].astype(x.dtype)
                               if cv > 1 else tail_last)
    return y @ p["w_out"], new_cache


def mamba_cache_shape(cfg: ModelConfig, batch: int):
    return MambaCache(
        h=jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, cfg.conv_dim - 1, cfg.d_inner),
                                  jnp.dtype(cfg.dtype)),
    )
