"""Mixture-of-Experts with expert parallelism (EP) via explicit all-to-all.

Design (scales to 1000+ nodes):

* Experts are sharded over the 'model' mesh axis; tokens are sharded over
  ('pod','data') and — under sequence parallelism — over 'model' too.
* The layer runs under `shard_map`: each shard routes its *local* tokens
  (top-k over the full expert set, router weights replicated), ranks them
  into per-expert capacity slots (cumsum-of-one-hot, deterministic), packs a
  (tp, E_local, C, D) send buffer, and exchanges it with one
  `jax.lax.all_to_all` over 'model'. Expert FFNs run on local experts only;
  a second all-to-all returns results; combine is local. Total comm:
  2 x all-to-all of (k x tokens x D x capacity_factor) bytes — the classic
  DeepSpeed-MoE/GShard schedule, with zero all-reduces.
* Static shapes everywhere: capacity slots are fixed; overflow tokens are
  dropped via a sentinel row (the paper's MAX-sentinel trick reappears —
  invalid slots index a zero row instead of being branched around).

LP-capacity routing (the paper's technique inside the framework): instead of
a uniform per-expert capacity cutoff, a batch of small LPs (one per shard
group) reallocates the slot budget across experts by demand — solved
on-device by repro.core's batched simplex. Static buffer shapes are kept;
only the cutoff mask changes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from .config import ModelConfig
from .layers import dense_init

def moe_init(key, cfg: ModelConfig):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], D, E, dtype, ("residual", None))
    scale = 1.0 / np.sqrt(D)
    p["w_gate"] = (jax.random.normal(ks[1], (E, D, Fe), jnp.float32) * scale).astype(dtype)
    p["w_up"] = (jax.random.normal(ks[2], (E, D, Fe), jnp.float32) * scale).astype(dtype)
    p["w_down"] = (jax.random.normal(ks[3], (E, Fe, D), jnp.float32) / np.sqrt(Fe)).astype(dtype)
    s["w_gate"] = ("experts", "residual", None)
    s["w_up"] = ("experts", "residual", None)
    s["w_down"] = ("experts", None, "residual")
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        p["ws_gate"], s["ws_gate"] = dense_init(ks[4], D, Fs, dtype, ("residual", "ff_expert"))
        p["ws_up"], s["ws_up"] = dense_init(ks[5], D, Fs, dtype, ("residual", "ff_expert"))
        p["ws_down"], s["ws_down"] = dense_init(ks[6], Fs, D, dtype, ("ff_expert", "residual"))
    return p, s


def _capacity(n_tok: int, k: int, E: int, cf: float) -> int:
    c = int(np.ceil(n_tok * k / E * cf))
    return max(4, (c + 3) // 4 * 4)


def _moe_local(x, p, cfg: ModelConfig, *, tp: int, tp_axis: Optional[str]):
    """Per-shard MoE body. x: (N, D) local tokens; p holds LOCAL expert slabs
    (El, D, Fe). Runs identically for tp=1 (no mesh) and under shard_map."""
    N, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    El = p["w_gate"].shape[0]
    Cl = _capacity(N, K, E, cfg.capacity_factor)

    # --- routing (f32) ------------------------------------------------------
    logits = (x @ p["router"]).astype(jnp.float32)          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                  # (N, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                               # (N*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot              # rank within expert
    slot = jnp.take_along_axis(ranks, flat_e[:, None], 1)[:, 0]

    # --- capacity cutoff: uniform, or LP-reallocated (paper technique) ------
    if cfg.lp_capacity:
        from repro.core.lp_router import expert_capacity_lp
        demand = probs.sum(0)[None, :] * K                  # (1, E) soft load
        caps = expert_capacity_lp(demand, total_slots=float(N * K),
                                  c_max=float(Cl))[0]       # (E,)
        cap_of = jnp.take(caps, flat_e)
        keep = slot < cap_of
    else:
        keep = slot < Cl

    # --- dispatch: pack (tp, El, Cl, D) send buffer, sentinel-drop overflow -
    sent = tp * El * Cl
    dest = jnp.where(keep, flat_e * Cl + slot, sent)
    xk = jnp.repeat(x, K, axis=0)                            # (N*K, D)
    buf = jnp.zeros((sent + 1, D), x.dtype).at[dest].add(
        xk * keep[:, None].astype(x.dtype))
    buf = buf[:sent].reshape(tp, El * Cl, D)

    if tp_axis is not None and tp > 1:
        buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
    # buf: (tp, El*Cl, D) — rows grouped by source shard for MY experts
    h_in = buf.reshape(tp, El, Cl, D).transpose(1, 0, 2, 3).reshape(El, tp * Cl, D)

    # --- expert FFN (SwiGLU) on local experts --------------------------------
    g = jnp.einsum("ecd,edf->ecf", h_in, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h_in, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])

    # --- return path ----------------------------------------------------------
    y = y.reshape(El, tp, Cl, D).transpose(1, 0, 2, 3).reshape(tp, El * Cl, D)
    if tp_axis is not None and tp > 1:
        y = jax.lax.all_to_all(y, tp_axis, split_axis=0, concat_axis=0,
                               tiled=False)
    y_flat = jnp.concatenate([y.reshape(sent, D),
                              jnp.zeros((1, D), y.dtype)], axis=0)
    z = jnp.take(y_flat, dest, axis=0)                       # (N*K, D)
    w = (top_w.reshape(-1) * keep).astype(x.dtype)
    out = (z * w[:, None]).reshape(N, K, D).sum(axis=1)
    return out


def moe_apply(p, x, cfg: ModelConfig, shd=None):
    """x: (B, S, D). Routed experts via shard_map EP; shared experts as a
    plain TP dense MLP outside."""
    B, S, D = x.shape
    E = cfg.n_experts

    routed_p = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
    if shd is not None and shd.mesh is not None and shd.tp_axis is not None \
            and E % shd.tp == 0 and shd.tp > 1:
        mesh, tp, tp_axis = shd.mesh, shd.tp, shd.tp_axis
        dp = shd.dp_axes or None
        if dp is not None and B % shd._axis_size(dp) != 0:
            dp = None
        seq_ax = shd.rules.get("seq_sp")
        if seq_ax is not None and S % shd._axis_size(seq_ax) != 0:
            seq_ax = None
        x_spec = jax.sharding.PartitionSpec(dp, seq_ax, None)
        w_spec = {
            "router": jax.sharding.PartitionSpec(None, None),
            "w_gate": jax.sharding.PartitionSpec("model", None, None),
            "w_up": jax.sharding.PartitionSpec("model", None, None),
            "w_down": jax.sharding.PartitionSpec("model", None, None),
        }

        def body(xl, pl):
            Bl, Sl, Dl = xl.shape
            out = _moe_local(xl.reshape(Bl * Sl, Dl), pl, cfg, tp=tp,
                             tp_axis=tp_axis)
            return out.reshape(Bl, Sl, Dl)

        out = shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, w_spec),
            out_specs=x_spec,
            check_rep=False,
        )(x, routed_p)
    else:
        out = _moe_local(x.reshape(B * S, D), routed_p, cfg, tp=1,
                         tp_axis=None).reshape(B, S, D)

    if cfg.n_shared_experts:
        h = jax.nn.silu(x @ p["ws_gate"]) * (x @ p["ws_up"])
        if shd is not None:
            h = shd.act(h, "batch", None, "ff_expert")
        out = out + h @ p["ws_down"]
    return out
