"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Train/prefill materialize per-head K/V from the 512-dim latent (what DeepSeek
does in training). Decode uses the *absorbed* form: the up-projections fold
into the query/output path so attention contracts directly against the
(B, S, kv_lora) latent cache — per-token decode FLOPs drop from
O(S·H·dh·kv_lora) re-materialization to O(S·(kv_lora+rope)) reads, and the
cache is ~an order of magnitude smaller than GQA's. The latent cache has no
head axis, so it sequence-shards over 'model' at decode (flash-decoding-style
partial softmax + two small all-reduces).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_norm, apply_rope, dense_init, norm_init
from .attention import blockwise_attention, NEG_INF


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora) compressed latents
    k_rope: jax.Array  # (B, S, rope_dim) shared positional key


def mla_init(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    qn, qr, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl, ql = cfg.kv_lora, cfg.q_lora
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["wq_a"], s["wq_a"] = dense_init(ks[0], D, ql, dtype, ("residual", None))
    p["q_norm"], s["q_norm"] = norm_init(ql, "rmsnorm", dtype)
    p["wq_b"], s["wq_b"] = dense_init(ks[1], ql, H * (qn + qr), dtype, (None, "heads"))
    p["wkv_a"], s["wkv_a"] = dense_init(ks[2], D, kvl + qr, dtype, ("residual", None))
    p["kv_norm"], s["kv_norm"] = norm_init(kvl, "rmsnorm", dtype)
    p["wkv_b"], s["wkv_b"] = dense_init(ks[3], kvl, H * (qn + vh), dtype, (None, "heads"))
    p["wo"], s["wo"] = dense_init(ks[4], H * vh, D, dtype, ("heads", "residual"))
    return p, s


def _project_q(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, qn, qr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm") @ p["wq_b"]
    q = q.reshape(B, S, H, qn + qr)
    q_nope, q_pe = q[..., :qn], q[..., qn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _latents(p, x, cfg: ModelConfig, positions):
    kvl, qr = cfg.kv_lora, cfg.qk_rope_dim
    kv = x @ p["wkv_a"]                                     # (B, S, kvl+qr)
    c_kv = apply_norm(p["kv_norm"], kv[..., :kvl], "rmsnorm")
    k_pe = apply_rope(kv[..., kvl:], positions, cfg.rope_theta)  # (B, S, qr)
    return c_kv, k_pe


def mla_apply(p, x, cfg: ModelConfig, *, positions, mode: str,
              cache: Optional[MLACache] = None,
              pos: Optional[jax.Array] = None, shd=None
              ) -> Tuple[jax.Array, Optional[MLACache]]:
    B, S, D = x.shape
    H = cfg.n_heads
    qn, qr, vh, kvl = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                       cfg.kv_lora)
    q_nope, q_pe = _project_q(p, x, cfg, positions)

    if mode == "decode":
        assert cache is not None and pos is not None
        c_new, kpe_new = _latents(p, x, cfg, positions)
        c_kv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache.c_kv, c_new, pos)
        k_rope = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache.k_rope, kpe_new, pos)
        new_cache = MLACache(c_kv=c_kv, k_rope=k_rope)

        # absorbed attention: fold W_UK into q, W_UV into the output path
        wkv_b = p["wkv_b"].reshape(kvl, H, qn + vh)
        w_uk = wkv_b[..., :qn]                               # (kvl, H, qn)
        w_uv = wkv_b[..., qn:]                               # (kvl, H, vh)
        q_lat = jnp.einsum("bshn,khn->bshk", q_nope, w_uk)   # (B,1,H,kvl)
        s_lat = jnp.einsum("bshk,btk->bhst", q_lat, c_kv,
                           preferred_element_type=jnp.float32)
        s_pe = jnp.einsum("bshr,btr->bhst", q_pe, k_rope,
                          preferred_element_type=jnp.float32)
        scores = (s_lat + s_pe) / jnp.sqrt(float(qn + qr))
        mask = jnp.arange(c_kv.shape[1])[None, :] <= pos[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btk->bshk", probs.astype(c_kv.dtype), c_kv)
        out = jnp.einsum("bshk,khv->bshv", o_lat, w_uv)       # (B,1,H,vh)
        out = out.reshape(B, S, H * vh) @ p["wo"]
        return out, new_cache

    # train / prefill: materialized per-head K/V
    c_kv, k_pe = _latents(p, x, cfg, positions)
    wkv_b = p["wkv_b"].reshape(kvl, H, qn + vh)
    k_nope = jnp.einsum("btk,khn->bthn", c_kv, wkv_b[..., :qn])
    v = jnp.einsum("btk,khv->bthv", c_kv, wkv_b[..., qn:])
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, qr))
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    if shd is not None:
        q = shd.act(q, "batch", "seq", "heads", None)
        k = shd.act(k, "batch", "seq", "heads", None)
    # pad v's head dim up to qk dim for the shared blockwise kernel
    out = blockwise_attention(q, k,
                              jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qn + qr - vh))),
                              causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)[..., :vh]
    out = out.reshape(B, S, H * vh) @ p["wo"]
    new_cache = MLACache(c_kv=c_kv, k_rope=k_pe) if mode == "prefill" else None
    return out, new_cache


def mla_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    dt = jnp.dtype(cfg.dtype)
    return MLACache(
        c_kv=jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora), dt),
        k_rope=jax.ShapeDtypeStruct((batch, seq, cfg.qk_rope_dim), dt),
    )
