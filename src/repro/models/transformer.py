"""Decoder-only LM assembly: scan-over-layers, remat, KV caches, chunked loss.

One class covers 9/10 assigned archs (all but whisper): dense GQA (llama3,
qwen3, granite, nemotron), MoE (deepseek-v2 via MLA, llama4-scout), SSM
(falcon-mamba), hybrid (hymba), and VLM (phi-3-vision = phi3 backbone +
precomputed patch embeddings).

Layers are stacked (vmapped init) and iterated with `lax.scan` so HLO size is
depth-independent (a 126-layer llama3-405b compiles as one scanned block).
`remat='block'` checkpoints each layer: only the (optionally
sequence-sharded) residual carry is saved across the backward pass.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (NULL_SHARDER, apply_norm, cross_entropy, embed_init,
                     embed_lookup, head_init, logits_apply, mlp_apply,
                     mlp_init, norm_init, stack_init)
from .attention import (KVCache, gqa_apply, gqa_cache_shape, gqa_init)
from .mla import MLACache, mla_apply, mla_cache_shape, mla_init
from .mamba import MambaCache, mamba_apply, mamba_cache_shape, mamba_init
from .moe import moe_apply, moe_init


class HymbaCache(NamedTuple):
    kv: KVCache
    ssm: MambaCache


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, cfg.norm_kind,
                                       jnp.dtype(cfg.param_dtype))
    if cfg.attn_kind == "mla":
        p["attn"], s["attn"] = mla_init(ks[0], cfg)
    elif cfg.family == "ssm":
        p["ssm"], s["ssm"] = mamba_init(ks[0], cfg)
    elif cfg.family == "hybrid":
        p["attn"], s["attn"] = gqa_init(ks[0], cfg)
        p["ssm"], s["ssm"] = mamba_init(ks[3], cfg)
    else:
        p["attn"], s["attn"] = gqa_init(ks[0], cfg)
    if cfg.d_ff or cfg.mlp_kind == "moe":
        p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm_kind,
                                           jnp.dtype(cfg.param_dtype))
        if cfg.mlp_kind == "moe":
            p["mlp"], s["mlp"] = moe_init(ks[1], cfg)
        else:
            p["mlp"], s["mlp"] = mlp_init(ks[1], cfg)
    return p, s


def _block_apply(p, x, cfg: ModelConfig, *, mode: str, positions,
                 cache, pos, shd):
    """Returns (x, new_cache). cache/new_cache is the per-layer slice."""
    if shd is not None:
        x = shd.act(x, "batch", "seq_sp", None)
    h = apply_norm(p["norm1"], x, cfg.norm_kind)
    new_cache = None
    if cfg.attn_kind == "mla":
        a, new_cache = mla_apply(p["attn"], h, cfg, positions=positions,
                                 mode=mode, cache=cache, pos=pos, shd=shd)
    elif cfg.family == "ssm":
        a, new_cache = mamba_apply(p["ssm"], h, cfg, mode=mode, cache=cache,
                                   shd=shd)
    elif cfg.family == "hybrid":
        kv_c = cache.kv if cache is not None else None
        ssm_c = cache.ssm if cache is not None else None
        a1, kv_new = gqa_apply(p["attn"], h, cfg, positions=positions,
                               mode=mode, cache=kv_c, pos=pos, shd=shd)
        a2, ssm_new = mamba_apply(p["ssm"], h, cfg, mode=mode, cache=ssm_c,
                                  shd=shd)
        a = 0.5 * (a1 + a2)
        if kv_new is not None or ssm_new is not None:
            new_cache = HymbaCache(kv=kv_new, ssm=ssm_new)
    else:
        a, new_cache = gqa_apply(p["attn"], h, cfg, positions=positions,
                                 mode=mode, cache=cache, pos=pos, shd=shd)
    if shd is not None:
        # constrain the sublayer output BEFORE the residual add so GSPMD
        # emits reduce-scatter (not all-reduce + slice) for the row-parallel
        # matmul partials under sequence parallelism
        a = shd.act(a, "batch", "seq_sp", None)
    x = x + a
    if "mlp" in p:
        h2 = apply_norm(p["norm2"], x, cfg.norm_kind)
        if cfg.mlp_kind == "moe":
            m = moe_apply(p["mlp"], h2, cfg, shd=shd)
        else:
            m = mlp_apply(p["mlp"], h2, cfg, shd=shd)
        if shd is not None:
            m = shd.act(m, "batch", "seq_sp", None)
        x = x + m
    if shd is not None:
        x = shd.act(x, "batch", "seq_sp", None)
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

class LM:
    """Functional decoder LM. Params are plain pytrees; all methods are
    jit/pjit-compatible pure functions of (params, inputs)."""

    def __init__(self, cfg: ModelConfig, shd=None):
        self.cfg = cfg
        self.shd = shd

    # -- init ----------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        params, specs = {}, {}
        params["embed"], specs["embed"] = embed_init(k_emb, cfg)
        params["layers"], specs["layers"] = stack_init(
            lambda k: _block_init(k, cfg), cfg.n_layers, k_layers)
        params["final_norm"], specs["final_norm"] = norm_init(
            cfg.d_model, cfg.norm_kind, jnp.dtype(cfg.param_dtype))
        params["head"], specs["head"] = head_init(k_head, cfg)
        return params, specs

    # -- embedding frontend (tokens [+ patch stubs]) ---------------------------
    def _embed_inputs(self, params, tokens, patches=None):
        x = embed_lookup(params["embed"], tokens).astype(jnp.dtype(self.cfg.dtype))
        if patches is not None:  # VLM stub: precomputed patch embeddings
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        return x

    def _run_layers(self, params, x, *, mode, positions, caches=None, pos=None):
        cfg, shd = self.cfg, self.shd

        def body(carry, layer):
            p_l, cache_l = layer
            fn = _block_apply
            if cfg.remat == "block" and mode == "train":
                fn = jax.checkpoint(
                    functools.partial(_block_apply, cfg=cfg, mode=mode,
                                      positions=positions, pos=pos, shd=shd),
                    static_argnums=())
                x_new, c_new = fn(p_l, carry, cache=cache_l)
            else:
                x_new, c_new = _block_apply(
                    p_l, carry, cfg=cfg, mode=mode, positions=positions,
                    cache=cache_l, pos=pos, shd=shd)
            return x_new, c_new

        if caches is None:
            caches = _none_like_layers(params["layers"], cfg.n_layers)
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
        return x, new_caches

    # -- training loss ----------------------------------------------------------
    def loss_fn(self, params, batch):
        """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32,
        optional 'patches': (B,P,D)}. Labels < 0 are masked."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_inputs(params, tokens, batch.get("patches"))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._run_layers(params, x, mode="train", positions=positions)
        x = apply_norm(params["final_norm"], x, cfg.norm_kind)
        labels = batch["labels"]
        if batch.get("patches") is not None:
            x = x[:, -labels.shape[1]:]  # loss only on text positions
        return self._chunked_ce(params, x, labels)

    def _chunked_ce(self, params, x, labels, chunk: int = 1024):
        """Sequence-chunked cross entropy so (S, vocab) logits never fully
        materialize (vocab stays sharded over 'model')."""
        cfg = self.cfg
        B, S, D = x.shape
        head = params["head"] if params.get("head") else params["embed"]
        chunk = min(chunk, S)
        n = (S + chunk - 1) // chunk
        tot = jnp.zeros((), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for i in range(n):
            xs = x[:, i * chunk:(i + 1) * chunk]
            ls = labels[:, i * chunk:(i + 1) * chunk]
            logits = logits_apply(head, xs, cfg)
            mask = ls >= 0
            lsafe = jnp.maximum(ls, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lsafe[..., None], axis=-1)[..., 0]
            tot = tot + jnp.sum((logz - gold) * mask)
            cnt = cnt + jnp.sum(mask)
        return tot / jnp.maximum(cnt, 1.0)

    # -- serving ----------------------------------------------------------------
    def prefill(self, params, tokens, patches=None):
        """Returns (last-position logits (B, vocab_padded), stacked caches)."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, patches)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, caches = self._run_layers(params, x, mode="prefill",
                                     positions=positions)
        x = apply_norm(params["final_norm"], x, cfg.norm_kind)
        head = params["head"] if params.get("head") else params["embed"]
        logits = logits_apply(head, x[:, -1:], cfg)[:, 0]
        return logits, caches

    def decode_step(self, params, caches, token, pos):
        """token: (B,) int32; pos: (B,) int32 write/attend position.
        Returns (logits (B, vocab_padded), updated caches)."""
        cfg = self.cfg
        x = self._embed_inputs(params, token[:, None])
        positions = pos[:, None]
        x, new_caches = self._run_layers(params, x, mode="decode",
                                         positions=positions, caches=None
                                         if caches is None else caches,
                                         pos=pos)
        x = apply_norm(params["final_norm"], x, cfg.norm_kind)
        head = params["head"] if params.get("head") else params["embed"]
        logits = logits_apply(head, x[:, :1], cfg)[:, 0]
        return logits, new_caches

    # -- cache shapes/specs --------------------------------------------------------
    def cache_shape(self, batch: int, seq: int):
        cfg = self.cfg
        L = cfg.n_layers

        def stack(tree):
            return jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct((L,) + sd.shape, sd.dtype), tree)

        if cfg.attn_kind == "mla":
            return stack(mla_cache_shape(cfg, batch, seq))
        if cfg.family == "ssm":
            return stack(mamba_cache_shape(cfg, batch))
        if cfg.family == "hybrid":
            return stack(HymbaCache(kv=gqa_cache_shape(cfg, batch, seq),
                                    ssm=mamba_cache_shape(cfg, batch)))
        return stack(gqa_cache_shape(cfg, batch, seq))

    def cache_logical_spec(self):
        cfg = self.cfg
        if cfg.attn_kind == "mla":
            return MLACache(c_kv=("layers", "batch", "kv_seq", None),
                            k_rope=("layers", "batch", "kv_seq", None))
        if cfg.family == "ssm":
            return MambaCache(h=("layers", "batch", "d_inner", None),
                              conv=("layers", "batch", None, "d_inner"))
        kv = KVCache(k=("layers", "batch", "kv_seq", "kv_heads", None),
                     v=("layers", "batch", "kv_seq", "kv_heads", None))
        if cfg.family == "hybrid":
            return HymbaCache(
                kv=kv, ssm=MambaCache(h=("layers", "batch", "d_inner", None),
                                      conv=("layers", "batch", None, "d_inner")))
        return kv


def _none_like_layers(layer_params, n_layers: int):
    """A scan-compatible 'xs' of Nones matching the layer axis."""
    return None


# scan needs xs=None handled: wrap (params, None) pairing
def _pair_for_scan(params_layers, caches):
    return (params_layers, caches)
