"""10-architecture model zoo (pure JAX, scan-over-layers, shardable)."""
from .config import ModelConfig, SHAPES, ShapeCell, shape_by_name  # noqa: F401
from .transformer import LM  # noqa: F401
from .encdec import EncDecLM  # noqa: F401


def build_model(cfg: ModelConfig, shd=None):
    if cfg.family == "encdec":
        return EncDecLM(cfg, shd)
    return LM(cfg, shd)
