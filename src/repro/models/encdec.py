"""Whisper-style encoder-decoder (whisper-small backbone).

Per the assignment spec the conv frontend is a STUB: `input_specs()` feeds
precomputed frame embeddings (B, S, D) directly to the encoder (the two
stride-1/2 convs + GELU of real Whisper are host-side preprocessing here).
Encoder: bidirectional self-attention + GELU MLP, sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU MLP, learned
positions. LayerNorm everywhere (norm_kind='layernorm'), no RoPE.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (apply_norm, embed_init, embed_lookup, head_init,
                     logits_apply, mlp_apply, mlp_init, norm_init, stack_init)
from .attention import (KVCache, blockwise_attention, cross_attn_apply,
                        cross_attn_init, cross_kv, gqa_apply, gqa_cache_shape,
                        gqa_init)
from .transformer import LM


class EncDecCache(NamedTuple):
    self_kv: KVCache          # (L, B, S_dec, KV, dh)
    cross_k: jax.Array        # (L, B, S_enc, H, dh)
    cross_v: jax.Array


def sinusoids(length: int, channels: int):
    """Whisper's sinusoidal position embedding."""
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    t = jnp.arange(length)[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _enc_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, cfg.norm_kind,
                                       jnp.dtype(cfg.param_dtype))
    p["attn"], s["attn"] = gqa_init(ks[0], cfg)
    p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm_kind,
                                       jnp.dtype(cfg.param_dtype))
    p["mlp"], s["mlp"] = mlp_init(ks[1], cfg)
    return p, s


def _dec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p, s = _enc_block_init(ks[0], cfg)
    p["norm_x"], s["norm_x"] = norm_init(cfg.d_model, cfg.norm_kind,
                                         jnp.dtype(cfg.param_dtype))
    p["xattn"], s["xattn"] = cross_attn_init(ks[1], cfg)
    return p, s


class EncDecLM:
    """Same functional API shape as transformer.LM (loss_fn / prefill /
    decode_step), with batch = {'frames', 'tokens', 'labels'}."""

    def __init__(self, cfg: ModelConfig, shd=None):
        self.cfg = cfg
        self.shd = shd

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params, specs = {}, {}
        params["embed"], specs["embed"] = embed_init(ks[0], cfg)
        params["pos_table"] = (jax.random.normal(ks[1], (32768, cfg.d_model),
                                                 jnp.float32) * 0.01
                               ).astype(jnp.dtype(cfg.param_dtype))
        specs["pos_table"] = (None, "residual")
        params["enc_layers"], specs["enc_layers"] = stack_init(
            lambda k: _enc_block_init(k, cfg), cfg.n_encoder_layers, ks[2])
        params["dec_layers"], specs["dec_layers"] = stack_init(
            lambda k: _dec_block_init(k, cfg), cfg.n_layers, ks[3])
        params["enc_norm"], specs["enc_norm"] = norm_init(
            cfg.d_model, cfg.norm_kind, jnp.dtype(cfg.param_dtype))
        params["dec_norm"], specs["dec_norm"] = norm_init(
            cfg.d_model, cfg.norm_kind, jnp.dtype(cfg.param_dtype))
        params["head"], specs["head"] = head_init(ks[4], cfg)
        return params, specs

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames, *, for_train: bool = False):
        cfg, shd = self.cfg, self.shd
        B, S, D = frames.shape
        x = frames.astype(jnp.dtype(cfg.dtype)) + \
            sinusoids(S, D).astype(jnp.dtype(cfg.dtype))[None]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(carry, p_l):
            h = apply_norm(p_l["norm1"], carry, cfg.norm_kind)
            # bidirectional: reuse gqa projections, causal off via direct call
            a = _bidir_attn(p_l["attn"], h, cfg, shd)
            x1 = carry + a
            h2 = apply_norm(p_l["norm2"], x1, cfg.norm_kind)
            return x1 + mlp_apply(p_l["mlp"], h2, cfg, shd), None

        if cfg.remat == "block" and for_train:
            inner = body
            body = lambda c, l: jax.checkpoint(inner)(c, l)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return apply_norm(params["enc_norm"], x, cfg.norm_kind)

    # -- decoder ---------------------------------------------------------------
    def _dec_embed(self, params, tokens, pos0=0):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        S = tokens.shape[1]
        pos_emb = jax.lax.dynamic_slice_in_dim(params["pos_table"], pos0, S, 0)
        return x + pos_emb[None].astype(x.dtype)

    def _dec_layers(self, params, x, enc_out, *, mode, positions,
                    caches=None, pos=None):
        cfg, shd = self.cfg, self.shd

        def body(carry, layer):
            p_l, cache_l = layer
            h = apply_norm(p_l["norm1"], carry, cfg.norm_kind)
            kv_c = cache_l.self_kv if cache_l is not None else None
            a, new_kv = gqa_apply(p_l["attn"], h, cfg, positions=positions,
                                  mode=mode, cache=kv_c, pos=pos, shd=shd)
            x1 = carry + a
            hx = apply_norm(p_l["norm_x"], x1, cfg.norm_kind)
            if mode == "decode":
                ck, cv = cache_l.cross_k, cache_l.cross_v
            else:
                ck, cv = cross_kv(p_l["xattn"], enc_out, cfg)
            x2 = x1 + cross_attn_apply(p_l["xattn"], hx, (ck, cv), cfg)
            h2 = apply_norm(p_l["norm2"], x2, cfg.norm_kind)
            out = x2 + mlp_apply(p_l["mlp"], h2, cfg, shd)
            new_cache = None
            if mode == "prefill":
                new_cache = EncDecCache(self_kv=new_kv, cross_k=ck, cross_v=cv)
            elif mode == "decode":
                new_cache = EncDecCache(self_kv=new_kv, cross_k=ck, cross_v=cv)
            return out, new_cache

        if cfg.remat == "block" and mode == "train":
            inner = body
            body = lambda c, l: jax.checkpoint(inner)(c, l)
        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
        return x, new_caches

    # -- public API ---------------------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], for_train=True)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._dec_embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._dec_layers(params, x, enc_out, mode="train",
                                positions=positions)
        x = apply_norm(params["dec_norm"], x, cfg.norm_kind)
        lm = LM(cfg, self.shd)
        return lm._chunked_ce(params, x, batch["labels"])

    def prefill(self, params, tokens, frames=None):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        x = self._dec_embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, caches = self._dec_layers(params, x, enc_out, mode="prefill",
                                     positions=positions)
        x = apply_norm(params["dec_norm"], x, cfg.norm_kind)
        head = params["head"] if params.get("head") else params["embed"]
        logits = logits_apply(head, x[:, -1:], cfg)[:, 0]
        return logits, caches

    def decode_step(self, params, caches, token, pos):
        cfg = self.cfg
        B = token.shape[0]
        x = jax.vmap(
            lambda t, i: embed_lookup(params["embed"], t[None])[0]
            + jax.lax.dynamic_slice_in_dim(params["pos_table"], i, 1, 0)[0]
        )(token, pos)[:, None].astype(jnp.dtype(cfg.dtype))
        positions = pos[:, None]
        x, new_caches = self._dec_layers(params, x, None, mode="decode",
                                         positions=positions, caches=caches,
                                         pos=pos)
        x = apply_norm(params["dec_norm"], x, cfg.norm_kind)
        head = params["head"] if params.get("head") else params["embed"]
        logits = logits_apply(head, x[:, :1], cfg)[:, 0]
        return logits, new_caches

    def cache_shape(self, batch: int, seq: int, enc_seq: Optional[int] = None):
        cfg = self.cfg
        enc_seq = enc_seq or seq
        L = cfg.n_layers
        dt = jnp.dtype(cfg.dtype)
        kv = gqa_cache_shape(cfg, batch, seq)
        dh = cfg.d_head
        H = cfg.n_heads_padded or cfg.n_heads

        def stk(sd):
            return jax.ShapeDtypeStruct((L,) + sd.shape, sd.dtype)

        return EncDecCache(
            self_kv=KVCache(k=stk(kv.k), v=stk(kv.v)),
            cross_k=jax.ShapeDtypeStruct((L, batch, enc_seq, H, dh), dt),
            cross_v=jax.ShapeDtypeStruct((L, batch, enc_seq, H, dh), dt),
        )

    def cache_logical_spec(self):
        kv = KVCache(k=("layers", "batch", "kv_seq", "kv_heads", None),
                     v=("layers", "batch", "kv_seq", "kv_heads", None))
        return EncDecCache(
            self_kv=kv,
            cross_k=("layers", "batch", "kv_seq", "heads", None),
            cross_v=("layers", "batch", "kv_seq", "heads", None),
        )


def _bidir_attn(p, x, cfg: ModelConfig, shd):
    """Non-causal self-attention (encoder): reuses gqa weights, full window."""
    B, S, D = x.shape
    dh = cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    KV = cfg.n_kv_heads_padded or cfg.n_kv_heads
    from .attention import repeat_kv
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    out = blockwise_attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                              causal=False, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)
    if H != cfg.n_heads:
        out = out * (jnp.arange(H) < cfg.n_heads)[None, None, :, None] \
            .astype(out.dtype)
    return out.reshape(B, S, H * dh) @ p["wo"]
