"""Attention: blockwise-causal GQA (flash-style, pure JAX), KV-cache decode,
sliding-window, qk-norm, and cross-attention.

Memory/FLOP design (this matters for the roofline):

* Train/prefill attention is *blockwise*: an outer Python loop over query
  chunks and an inner `lax.scan` over only the kv chunks each query chunk can
  see (triangular schedule). FLOPs are exact-causal (no masked-out waste) and
  the live score buffer is (B, H, q_chunk, kv_chunk) — never (S, S).
* GQA is computed with K/V *repeated* to the query-head count so the head
  axis shards cleanly over 'model' whenever H % tp == 0 (the repeat is a
  broadcast the compiler keeps fused; K/V themselves are tiny).
* Decode attends one token against the full cache. For archs whose kv-head
  count doesn't divide the tensor-parallel axis, the cache is *sequence*-
  sharded and the softmax/contraction reductions over the sharded axis lower
  to two small all-reduces (flash-decoding style); otherwise the cache is
  head-sharded and decode is collective-free.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_norm, apply_rope, dense_init

NEG_INF = -1e30


def _fit_chunk(S: int, c: int) -> int:
    """Largest chunk <= c that divides S (static python arithmetic)."""
    c = max(1, min(c, S))
    while S % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# core blockwise attention (shared by GQA / MLA / cross)
# ---------------------------------------------------------------------------

def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, q_chunk: int, kv_chunk: int,
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jax.Array:
    """q: (B, Sq, H, dh); k/v: (B, Sk, H, dh) (already head-repeated).
    Returns (B, Sq, H, dh). Triangular chunk schedule, online softmax."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qc = _fit_chunk(Sq, q_chunk)
    kc = _fit_chunk(Sk, kv_chunk)
    nq = Sq // qc
    nk = Sk // kc

    outs = []
    for i in range(nq):
        qi = q[:, i * qc:(i + 1) * qc]                       # (B, qc, H, dh)
        q_pos = q_offset + i * qc + jnp.arange(qc)
        if causal:
            j_hi = min(nk, (q_offset + (i + 1) * qc + kc - 1) // kc)
        else:
            j_hi = nk
        j_lo = 0
        if window is not None:
            j_lo = max(0, (q_offset + i * qc - window) // kc)
        njs = j_hi - j_lo
        ks = k[:, j_lo * kc:j_hi * kc].reshape(B, njs, kc, H, dh)
        vs = v[:, j_lo * kc:j_hi * kc].reshape(B, njs, kc, H, dh)
        ks = jnp.moveaxis(ks, 1, 0)                          # (nj, B, kc, H, dh)
        vs = jnp.moveaxis(vs, 1, 0)

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        acc0 = jnp.zeros((B, H, qc, dh), jnp.float32)

        def body_fixed(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            k_pos = (j_lo + j) * kc + jnp.arange(kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            body_fixed, (m0, l0, acc0), (ks, vs, jnp.arange(njs)))
        out_i = acc / jnp.maximum(l[..., None], 1e-20)
        outs.append(jnp.moveaxis(out_i, 1, 2).astype(q.dtype))  # (B, qc, H, dh)
    return jnp.concatenate(outs, axis=1)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None) -> jax.Array:
    """q: (B, 1, H, dh); caches: (B, S, H, dh) (head-repeated). Attends to
    cache positions <= pos (and > pos - window if sliding)."""
    B, S, H, dh = k_cache.shape
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(S)
    mask = k_pos[None, :] <= pos[:, None]                    # (B, S)
    if window is not None:
        mask = mask & (k_pos[None, :] > (pos[:, None] - window))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(-1)[..., None], 1e-20)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)           # (B, 1, H, dh)


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, dh) -> (B, S, KV*groups, dh); heads ordered kv-major so
    query head h uses kv head h // groups."""
    if groups == 1:
        return k
    B, S, KV, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, groups, dh)) \
              .reshape(B, S, KV * groups, dh)


# ---------------------------------------------------------------------------
# GQA block (standard decoder attention used by 8/10 archs)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig):
    D, dh = cfg.d_model, cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    KV = cfg.n_kv_heads_padded or cfg.n_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], D, H * dh, dtype, ("residual", "heads"))
    p["wk"], s["wk"] = dense_init(ks[1], D, KV * dh, dtype, ("residual", "kv_heads"))
    p["wv"], s["wv"] = dense_init(ks[2], D, KV * dh, dtype, ("residual", "kv_heads"))
    p["wo"], s["wo"] = dense_init(ks[3], H * dh, D, dtype, ("heads", "residual"))
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), dtype)
        p["k_scale"] = jnp.ones((dh,), dtype)
        s["q_scale"] = (None,)
        s["k_scale"] = (None,)
    return p, s


def _qk_normalize(x, scale):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


class KVCache(NamedTuple):
    k: jax.Array  # (B, S, KV, dh)
    v: jax.Array


def gqa_apply(p, x: jax.Array, cfg: ModelConfig, *, positions: jax.Array,
              mode: str, cache: Optional[KVCache] = None,
              pos: Optional[jax.Array] = None, shd=None
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """mode: 'train' | 'prefill' | 'decode'. prefill returns the filled
    cache; decode takes+returns the cache updated at `pos`."""
    B, S, D = x.shape
    dh = cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    KV = cfg.n_kv_heads_padded or cfg.n_kv_heads
    groups = H // KV

    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_scale"])
        k = _qk_normalize(k, p["k_scale"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if shd is not None:
        q = shd.act(q, "batch", "seq", "heads", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and pos is not None
        k_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache.k, k, pos)
        v_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache.v, v, pos)
        new_cache = KVCache(k_cache, v_cache)
        out = decode_attention(
            q, repeat_kv(k_cache, groups), repeat_kv(v_cache, groups), pos,
            window=cfg.sliding_window)
    else:
        out = blockwise_attention(
            q, repeat_kv(k, groups), repeat_kv(v, groups),
            causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            window=cfg.sliding_window)
        if mode == "prefill":
            new_cache = KVCache(k, v)
    if H != cfg.n_heads:  # zero the TP-padding heads (function-preserving)
        out = out * (jnp.arange(H) < cfg.n_heads)[None, None, :, None] \
            .astype(out.dtype)
    out = out.reshape(B, S, H * dh)
    return out @ p["wo"], new_cache


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    """Per-layer cache ShapeDtypeStructs (stacked over layers by the model).
    Sliding-window archs only need a window-sized cache."""
    S = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
    dt = jnp.dtype(cfg.dtype)
    KV = cfg.n_kv_heads_padded or cfg.n_kv_heads
    return KVCache(
        k=jax.ShapeDtypeStruct((batch, S, KV, cfg.d_head), dt),
        v=jax.ShapeDtypeStruct((batch, S, KV, cfg.d_head), dt),
    )


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig):
    D, dh = cfg.d_model, cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], D, H * dh, dtype, ("residual", "heads"))
    p["wk"], s["wk"] = dense_init(ks[1], D, H * dh, dtype, ("residual", "heads"))
    p["wv"], s["wv"] = dense_init(ks[2], D, H * dh, dtype, ("residual", "heads"))
    p["wo"], s["wo"] = dense_init(ks[3], H * dh, D, dtype, ("heads", "residual"))
    return p, s


def cross_attn_apply(p, x, enc_kv, cfg: ModelConfig):
    """x: (B, S, D) decoder stream; enc_kv: (k, v) each (B, Senc, H, dh)."""
    B, S, D = x.shape
    dh = cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k, v = enc_kv
    if S == 1:  # decode step: dense single-query path
        pos = jnp.full((B,), k.shape[1] - 1, jnp.int32)
        out = decode_attention(q, k, v, pos)  # full visibility via pos=Senc-1
    else:
        out = blockwise_attention(q, k, v, causal=False,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    if H != cfg.n_heads:  # zero TP-padding heads
        out = out * (jnp.arange(H) < cfg.n_heads)[None, None, :, None] \
            .astype(out.dtype)
    return out.reshape(B, S, H * dh) @ p["wo"]


def cross_kv(p, enc_out, cfg: ModelConfig):
    B, Senc, D = enc_out.shape
    dh = cfg.d_head
    H = cfg.n_heads_padded or cfg.n_heads
    k = (enc_out @ p["wk"]).reshape(B, Senc, H, dh)
    v = (enc_out @ p["wv"]).reshape(B, Senc, H, dh)
    return k, v
