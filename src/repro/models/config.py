"""Model configuration for the 10-architecture zoo.

One frozen dataclass covers every family (dense / MoE / SSM / hybrid /
enc-dec / VLM); arch constructors live in repro.configs.<id>. All sizes are
the *exact* published configs from the assignment table; `reduced()` derives
the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None     # default d_model // n_heads
    # TP head padding: lift H (and KV) to a multiple of the model axis with
    # output-masked dead heads (zero gradient, function-preserving) so
    # attention shards instead of replicating. 0 = disabled.
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    # --- attention flavor ---
    attn_kind: str = "gqa"           # gqa | mla | none
    qk_norm: bool = False            # qwen3
    use_rope: bool = True            # whisper uses absolute positions instead
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # hymba SWA
    # --- MLP flavor ---
    mlp_kind: str = "swiglu"         # swiglu | relu2 | gelu | moe
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    lp_capacity: bool = False        # paper-technique LP router (opt-in)
    # --- MLA (deepseek-v2) ---
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba1) ---
    d_inner: int = 0
    ssm_state: int = 0
    ssm_impl: str = "assoc"   # assoc (XLA associative_scan) | kernel (Pallas)
    conv_dim: int = 4
    dt_rank: int = 0
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    # --- VLM ---
    n_patches: int = 0               # stub patch-embedding count
    # --- norm / misc ---
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- numerics & memory ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "block"             # none | block (checkpoint each layer)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # --- parallelism ---
    train_microbatches: int = 1      # gradient-accumulation chunks per step
    fsdp: bool = False               # shard params/opt-state over 'data' too
    seq_shard: bool = False          # sequence-parallel residual stream
    optimizer: str = "adamw"         # adamw | adafactor

    def __post_init__(self):
        if self.d_head is None and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return (self.vocab + 127) // 128 * 128

    @property
    def d_attn(self) -> int:
        return self.n_heads * (self.d_head or 0)

    def n_params(self) -> float:
        """Analytic parameter count (embeddings included, biases ignored)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0.0
        if self.attn_kind == "gqa":
            hd = self.d_head
            per_layer += D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
                + self.n_heads * hd * D
        elif self.attn_kind == "mla":
            qh = self.qk_nope_dim + self.qk_rope_dim
            per_layer += D * self.q_lora + self.q_lora * self.n_heads * qh
            per_layer += D * (self.kv_lora + self.qk_rope_dim)
            per_layer += self.kv_lora * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * D
        if self.mlp_kind == "swiglu":
            per_layer += 3 * D * F
        elif self.mlp_kind in ("relu2", "gelu"):
            per_layer += 2 * D * F
        elif self.mlp_kind == "moe":
            fe = self.d_ff_expert
            per_layer += self.n_experts * 3 * D * fe
            per_layer += self.n_shared_experts * 3 * D * fe
            per_layer += D * self.n_experts  # router
        if self.family in ("ssm", "hybrid"):
            di, st = self.d_inner, self.ssm_state
            ssm = D * 2 * di + di * self.conv_dim + di * (self.dt_rank + 2 * st) \
                + self.dt_rank * di + di * st + di + di * D
            per_layer += ssm
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            hd = self.d_head
            enc_layer = (2 + 2) * D * self.n_heads * hd / 2 + 2 * D * F  # approx
            per_layer += D * self.n_heads * hd + self.n_heads * hd * D  # cross attn kq/vo
            emb += self.n_encoder_layers * enc_layer
        return emb + L * per_layer

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink(v, lo, div=4):
            return max(lo, v // div) if v else 0
        return dataclasses.replace(
            self,
            n_layers=2,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16 if self.n_heads else None,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=32 if self.d_ff_expert else 0,
            kv_lora=16 if self.kv_lora else 0,
            q_lora=24 if self.q_lora else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            d_inner=128 if self.d_inner else 0,
            ssm_state=8 if self.ssm_state else 0,
            dt_rank=8 if self.dt_rank else 0,
            sliding_window=32 if self.sliding_window else None,
            n_patches=8 if self.n_patches else 0,
            q_chunk=32,
            kv_chunk=32,
            train_microbatches=1,
            n_heads_padded=0,
            n_kv_heads_padded=0,
            fsdp=False,
            seq_shard=False,
            dtype="float32",
            param_dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch x input-shape) dry-run cell."""
    name: str           # train_4k | prefill_32k | decode_32k | long_500k
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
