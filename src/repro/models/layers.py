"""Shared layers + the lightweight functional param/spec system.

Params are pytrees of arrays; every init function returns ``(params, specs)``
where ``specs`` mirrors the params tree with tuples of *logical axis names*
(resolved to mesh PartitionSpecs by distributed/sharding.py). Layer stacks
are built by vmapping init over a leading 'layers' axis so the forward pass
can `lax.scan` over layers (keeps HLO size O(1) in depth — essential for
compiling 96-126-layer models).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ---------------------------------------------------------------------------
# param init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, spec) -> Tuple[jax.Array, Tuple]:
    scale = 1.0 / jnp.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return w.astype(dtype), spec


def stack_init(init_fn: Callable, n: int, key) -> Tuple[Params, Specs]:
    """vmap an init over a leading layer axis; specs gain a 'layers' dim."""
    keys = jax.random.split(key, n)
    p0, s0 = init_fn(keys[0])
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    specs = jax.tree.map(lambda s: ("layers",) + tuple(s), s0,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}, {"scale": (None,)}
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": (None,), "bias": (None,)})


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32) \
            + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (SwiGLU / squared-ReLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        p = {}
        s = {}
        p["w_gate"], s["w_gate"] = dense_init(ks[0], D, F, dtype, ("residual", "ff"))
        p["w_up"], s["w_up"] = dense_init(ks[1], D, F, dtype, ("residual", "ff"))
        p["w_down"], s["w_down"] = dense_init(ks[2], F, D, dtype, ("ff", "residual"))
        return p, s
    p = {}
    s = {}
    p["w_in"], s["w_in"] = dense_init(ks[0], D, F, dtype, ("residual", "ff"))
    p["w_down"], s["w_down"] = dense_init(ks[2], F, D, dtype, ("ff", "residual"))
    return p, s


def mlp_apply(p, x, cfg: ModelConfig, shd=None):
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp_kind == "relu2":  # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    else:  # gelu (whisper)
        h = jax.nn.gelu(x @ p["w_in"], approximate=True)
    if shd is not None:
        h = shd.act(h, "batch", "seq", "ff")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: (..., S, H, d_head) or (..., S, d); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                   # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == angles.ndim + 1:                        # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings & logits
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    V, D = cfg.vocab_padded, cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    p = {"table": (jax.random.normal(key, (V, D), jnp.float32) * 0.01).astype(dtype)}
    return p, {"table": ("vocab", "residual")}


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def logits_apply(p_head, x, cfg: ModelConfig):
    """x: (..., D) -> (..., vocab_padded) f32 with padded entries masked."""
    logits = (x @ p_head["table"].T if "table" in p_head else x @ p_head["w"])
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def head_init(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}, {}
    D, V = cfg.d_model, cfg.vocab_padded
    dtype = jnp.dtype(cfg.param_dtype)
    w, spec = dense_init(key, D, V, dtype, ("residual", "vocab"))
    return {"w": w}, {"w": spec}


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None):
    """logits (..., V) f32, labels (...) int32. Mean NLL over mask."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# sharding shim (real rules live in distributed/sharding.py)
# ---------------------------------------------------------------------------

class NullSharder:
    """No-op activation sharder for single-device tests."""

    def act(self, x, *logical):
        return x


NULL_SHARDER = NullSharder()
