"""Revised-simplex tile kernel: BTRAN/FTRAN pivots on a VMEM-resident slab.

The pure-JAX engine (core/revised.py) prices and pivots on the basis
*factorization* — an LU of the basis matrix plus a product-form eta file —
instead of the dense tableau.  This kernel moves that hot loop into Pallas:
a ``(tile_b, ...)`` slab of LPs keeps its immutable data block, basis
inverse, basic solution, basis map and bound flags in VMEM and runs bounded
revised pivots (BTRAN -> pricing -> FTRAN -> sentinel min-ratio -> eta
append) without touching HBM between pivots.

Representation choice: ``lax.linalg.lu`` / ``triangular_solve`` do not lower
inside a Pallas kernel, so periodic refactorization is staged at *segment
boundaries* — the ISSUE's sanctioned alternative to an in-kernel LU.  The
host keeps a dense basis inverse ``Binv = B0^{-1}`` (computed from the same
``jax.lax.linalg`` LU path the engine uses, see `refactor_tile`), the kernel
applies it as two broadcast matvecs (BTRAN: ``Binv^T v``, FTRAN:
``Binv v``) and layers its *kernel-internal* eta file on top.  The eta file
never crosses the kernel boundary: a segment exits when the file fills
(``cnt == refactor_period``), the host refactorizes, and the next segment
starts from an empty file — exactly the engine's refactor-if-due schedule,
relocated to the segment clock.

Pivot semantics (pricing masks, rotating partial-pricing blocks, the bounded
sentinel ratio test, bound flips, phase-2 artificial pinning, the
``cnt += any(do_pivot)`` eta clock) mirror ``core.revised.revised_step``
statement-for-statement, re-expressed with one-hot lane masks instead of
gathers.  Parity contract: statuses match the pure-JAX engine exactly on the
test fixtures and objectives agree to f32 tolerance — bit-for-bit equality
is *not* promised because the dense inverse rounds differently from the
engine's triangular solves (the engine documents the same drift across its
own refactorization schedules).

Padded geometry (``revised_dims``): rows to a multiple of 8, candidate and
data lanes to multiples of 128.  Padding slots carry an identity slack basis
so their inverse stays finite, and are deactivated (ITERATION_LIMIT) before
the first segment.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core.lp import (BIG, INFEASIBLE, ITERATION_LIMIT, OPTIMAL,
                           UNBOUNDED)
from repro.obs.telemetry import (INT_LANE, INT_ROW_WIDTH, init_telemetry,
                                 lane_add, lane_set, tel_revised_update)
from repro.core.pricing import partial_geometry
from repro.core.revised import (auto_refactor_period, build_revised_state,
                                canonicalize_revised_rule,
                                inject_revised_warm)
from repro.core.simplex import _RUNNING, scatter_solution


def _round_up(v: int, k: int) -> int:
    return -(-v // k) * k


def revised_dims(m: int, n: int):
    """Padded (rows, data lanes, candidate lanes) for an (m, n) LP:
    MC rows (multiple of 8), NC2 lanes over the full column layout
    (structurals | slacks | artificials), NCP lanes over the priced
    candidates (structurals | slacks)."""
    MC = _round_up(max(m, 1), 8)
    NC2 = _round_up(n + 2 * m, 128)
    NCP = _round_up(n + m, 128)
    return MC, NC2, NCP


def pick_revised_tile_b(m: int, n: int, vmem_budget: int = 8 * 2 ** 20,
                        refactor_period: int | None = None,
                        dtype_size: int = 4) -> int:
    """Largest batch tile whose VMEM working set fits the budget: the
    immutable data block, the dense basis inverse, the eta file, the one-hot
    pricing masks and a handful of lane/row vectors."""
    MC, NC2, NCP = revised_dims(m, n)
    K = int(refactor_period or auto_refactor_period(m, n))
    per_lp = (MC * NC2 + MC * MC + 2 * MC * NCP + (K + 2) * MC
              + 8 * NCP + 10 * MC + 16) * dtype_size
    tile = max(1, int(vmem_budget) // per_lp)
    if tile >= 8:
        tile = (tile // 8) * 8
    return int(max(1, min(tile, 512)))


class RevisedTileState(NamedTuple):
    """Padded revised-simplex state for the tile kernel; every leaf keeps the
    batch on axis 0 so the compaction scheduler's generic gathers apply
    unchanged.  ``Binv`` is the dense inverse of the *current* basis — valid
    exactly at segment boundaries, where the eta file is empty."""
    Abar: jax.Array    # (B, MC, NC2) immutable sign-adjusted columns
    cvec: jax.Array    # (B, NCP) phase-2 candidate costs (0 on pad lanes)
    ub: jax.Array      # (B, NCP) upper bounds (+inf beyond structurals)
    thr: jax.Array     # (B, 1) phase-1 feasibility threshold
    Binv: jax.Array    # (B, MC, MC) dense basis inverse (identity pad block)
    xB: jax.Array      # (B, MC) basic-variable values
    basis: jax.Array   # (B, MC) int32 column basic in each row
    onub: jax.Array    # (B, NCP) int32 nonbasic-at-upper flags
    phase: jax.Array   # (B, 1) int32
    status: jax.Array  # (B, 1) int32
    iters: jax.Array   # (B, 1) int32
    tel: Any = None    # optional obs.telemetry.TelemetryState ((B,) lanes)


# ---------------------------------------------------------------------------
# Host-side refactorization (the segment-boundary jax.lax.linalg path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "n"))
def _refactor_binv(Abar_t, basis_t, *, m: int, n: int):
    """Dense inverse of the current basis matrix, gathered from the padded
    immutable columns: LU + two triangular solves against the row-permuted
    identity (the same ``jax.lax.linalg`` path as the engine's
    refactorization).  Padding rows/columns hold the identity so the pivot
    matvecs pass padded entries through untouched."""
    Ab = Abar_t[:, :m, :]
    bs = basis_t[:, :m].astype(jnp.int32)
    B0 = jnp.take_along_axis(Ab, bs[:, None, :], axis=2)
    lu, _, perm = lax.linalg.lu(B0)
    perm = perm.astype(jnp.int32)
    eye = jnp.broadcast_to(jnp.eye(m, dtype=Abar_t.dtype),
                           (B0.shape[0], m, m))
    pe = jnp.take_along_axis(eye, perm[:, :, None], axis=1)
    t = lax.linalg.triangular_solve(lu, pe, left_side=True, lower=True,
                                    unit_diagonal=True)
    Binv_m = lax.linalg.triangular_solve(lu, t, left_side=True, lower=False)
    MC = Abar_t.shape[1]
    mi = jnp.arange(MC)
    out = jnp.zeros((B0.shape[0], MC, MC), Abar_t.dtype)
    out = out.at[:, mi, mi].set(1.0)
    return out.at[:, :m, :m].set(Binv_m)


def refactor_tile(state: RevisedTileState, *, m: int, n: int
                  ) -> RevisedTileState:
    """Segment-boundary refactorization: recompute the dense basis inverse
    so the next kernel segment starts from an empty eta file.  On the
    telemetry trace this is where refactorizations are counted — the kernel
    relocates the engine's refactor-if-due schedule to the segment clock, so
    every boundary refactor of a still-running LP bumps its lane and resets
    the eta-file length (mirroring core.revised._refactor_state_jit)."""
    tel = state.tel
    if tel is not None:
        tel = tel_revised_update(tel, refactor=state.status == _RUNNING,
                                 eta_len=jnp.zeros_like(tel.eta_len))
    return state._replace(Binv=_refactor_binv(state.Abar, state.basis,
                                              m=m, n=n), tel=tel)


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "n", "tile_b"))
def _pad_tile_state(Abar, cvec, ub, thr, xB, basis, onub, phase, status,
                    iters, *, m: int, n: int, tile_b: int):
    B = Abar.shape[0]
    dtype = Abar.dtype
    MC, NC2, NCP = revised_dims(m, n)
    B_pad = _round_up(max(B, 1), tile_b)
    idx = jnp.arange(m)
    Abar_t = jnp.zeros((B_pad, MC, NC2), dtype).at[:B, :m, :n + 2 * m].set(
        Abar)
    # padding slots get an identity slack basis: finite inverse, no work
    Abar_t = Abar_t.at[B:, idx, n + idx].set(1.0)
    cvec_t = jnp.zeros((B_pad, NCP), dtype).at[:B, :n + m].set(cvec)
    ub_t = jnp.full((B_pad, NCP), jnp.inf, dtype).at[:B, :n].set(ub)
    thr_t = jnp.zeros((B_pad, 1), dtype).at[:B, 0].set(thr)
    xB_t = jnp.zeros((B_pad, MC), dtype).at[:B, :m].set(xB)
    rowM = jnp.arange(MC, dtype=jnp.int32)
    basis_t = jnp.broadcast_to(n + rowM, (B_pad, MC)).astype(jnp.int32)
    basis_t = basis_t.at[:B, :m].set(basis.astype(jnp.int32))
    onub_t = jnp.zeros((B_pad, NCP), jnp.int32).at[:B, :n].set(
        onub.astype(jnp.int32))
    phase_t = jnp.full((B_pad, 1), 2, jnp.int32).at[:B, 0].set(phase)
    status_t = jnp.full((B_pad, 1), ITERATION_LIMIT,
                        jnp.int32).at[:B, 0].set(status)
    iters_t = jnp.zeros((B_pad, 1), jnp.int32).at[:B, 0].set(iters)
    Binv = _refactor_binv(Abar_t, basis_t, m=m, n=n)
    return RevisedTileState(Abar=Abar_t, cvec=cvec_t, ub=ub_t, thr=thr_t,
                            Binv=Binv, xB=xB_t, basis=basis_t, onub=onub_t,
                            phase=phase_t, status=status_t, iters=iters_t)


def build_revised_tile_state(A, b, c, ub=None, *, m: int, n: int,
                             tile_b: int, feas_tol: float,
                             warm_basis=None, warm_at_upper=None,
                             telemetry: bool = False) -> RevisedTileState:
    """Build (and optionally warm-inject) the engine's ``RevisedState``, then
    pad it onto the tile layout.  The engine's own builder and
    ``inject_revised_warm`` are reused verbatim so cold/skip/repair/cold-fallback
    decisions are identical to the pure-JAX path.  ``telemetry=True`` seeds
    zero counter lanes over the padded batch (padding slots stay zero — the
    scheduler's flush only reads real original indices)."""
    B = A.shape[0]
    st = build_revised_state(A, b, c, ub, feas_tol=feas_tol,
                             refactor_period=1)
    if warm_basis is not None:
        wonub = (jnp.zeros((B, n), bool) if warm_at_upper is None
                 else jnp.asarray(np.asarray(warm_at_upper), bool))
        st = inject_revised_warm(
            st, jnp.asarray(np.asarray(warm_basis), jnp.int32), wonub,
            m=m, n=n, feas_tol=feas_tol)
    state = _pad_tile_state(st.Abar, st.cvec, st.ub, st.thr, st.xB, st.basis,
                            st.onub, st.phase, st.status, st.iters,
                            m=m, n=n, tile_b=tile_b)
    if telemetry:
        state = state._replace(tel=init_telemetry(state.status.shape[0]))
    return state


# ---------------------------------------------------------------------------
# The segment kernel
# ---------------------------------------------------------------------------

def _revised_segment_kernel(steps_ref, Abar_ref, cvec_ref, ub_ref, thr_ref,
                            Binv_ref, xB_ref, basis_ref, onub_ref, phase_ref,
                            status_ref, iters_ref, *refs,
                            stage: str, m: int, n: int, tol: float,
                            K: int, rule: str, telemetry: bool = False):
    """Up to ``steps`` bounded revised pivots on one (tile_b, ...) slab.

    Mirrors ``core.revised.revised_step`` with the basis inverse applied as
    broadcast matvecs and the eta file kept kernel-internal: the loop exits
    when the stage's pending set empties, the step budget runs out, or the
    eta file fills (the host refactorizes between segments).

    With ``telemetry=True`` a packed (tile_b, INT_ROW_WIDTH) counter row
    rides the carry (extra input after ``iters``, extra output after ``it``)
    and every pivot bumps its lanes with the same masks the engine feeds
    ``tel_simplex_update`` / ``tel_revised_update``; the disabled trace is
    byte-identical to the pre-telemetry kernel."""
    if telemetry:
        ti_ref = refs[0]
        (xB_out, basis_out, onub_out, phase_out, status_out, iters_out,
         it_out, ti_out) = refs[1:]
    else:
        ti_ref = ti_out = None
        (xB_out, basis_out, onub_out, phase_out, status_out, iters_out,
         it_out) = refs
    steps = steps_ref[0, 0]
    Abar = Abar_ref[...]
    cvec = cvec_ref[...]
    ub = ub_ref[...]
    thr = thr_ref[...]
    Binv = Binv_ref[...]
    tile_b, MC, NC2 = Abar.shape
    NCP = cvec.shape[1]
    dtype = Abar.dtype
    ncand = n + m

    row = lax.broadcasted_iota(jnp.int32, (tile_b, MC), 1)
    lane = lax.broadcasted_iota(jnp.int32, (tile_b, NCP), 1)
    lane2 = lax.broadcasted_iota(jnp.int32, (tile_b, NC2), 1)
    row_ok = row < m
    col_ok = lane < ncand
    if rule == "partial":
        n_blocks, blk_sz = partial_geometry(ncand)

    def btran(v, etaR, etaV, cnt):
        # newest eta first, then the dense inverse transposed
        def body(i, v):
            k = cnt - 1 - i
            r = lax.dynamic_slice(etaR, (0, k), (tile_b, 1))
            ev = lax.dynamic_slice(etaV, (0, k, 0), (tile_b, 1, MC))[:, 0, :]
            dot = jnp.sum(ev * v, axis=1, keepdims=True)
            return jnp.where(row == r, dot, v)
        v = lax.fori_loop(0, cnt, body, v)
        return jnp.sum(Binv * v[:, :, None], axis=1)

    def ftran(a_e, etaR, etaV, cnt):
        # dense inverse first, then oldest eta first
        u = jnp.sum(Binv * a_e[:, None, :], axis=2)
        def body(k, v):
            r = lax.dynamic_slice(etaR, (0, k), (tile_b, 1))
            ev = lax.dynamic_slice(etaV, (0, k, 0), (tile_b, 1, MC))[:, 0, :]
            vr = jnp.sum(jnp.where(row == r, v, 0.0), axis=1, keepdims=True)
            upd = ev * vr
            return jnp.where(row == r, upd, v + upd)
        return lax.fori_loop(0, cnt, body, u)

    def pivot(carry):
        (it, xB, basis, onub, phase, status, iters, etaR, etaV, cnt,
         ti) = carry
        active = status == _RUNNING
        in_p1 = phase == 1
        in_p2 = phase == 2

        # ---- Step 1: BTRAN + pricing --------------------------------------
        # one-hot basic-lane map over the priced candidates (rows < m only)
        hitc = (lane[:, None, :] == basis[:, :, None]) & row_ok[:, :, None]
        basis_c = jnp.sum(jnp.where(hitc, cvec[:, None, :], 0.0), axis=2)
        art = (basis >= ncand) & row_ok
        cB = jnp.where(in_p1, -art.astype(dtype),
                       jnp.where(row_ok, basis_c, 0.0))
        y = btran(cB, etaR, etaV, cnt)
        yA = jnp.sum(Abar[:, :, :NCP] * y[:, :, None], axis=1)
        d = jnp.where(in_p2, cvec, 0.0) - yA
        d = jnp.where(onub != 0, -d, d)
        is_basic = jnp.any(hitc & (basis < ncand)[:, :, None], axis=1)
        d_full = jnp.where(col_ok & ~is_basic, d, -BIG)

        if rule == "partial":
            blk = iters % n_blocks
            lo = blk * blk_sz
            in_block = (lane >= lo) & (lane < lo + blk_sz)
            d_blk = jnp.where(in_block, d_full, -BIG)
            blk_max = jnp.max(d_blk, axis=1, keepdims=True)
            e_blk = jnp.argmax(d_blk, axis=1).astype(jnp.int32)[:, None]
            blk_improving = blk_max > tol
            e = jnp.where(blk_improving, e_blk,
                          jnp.argmax(d_full, axis=1).astype(jnp.int32)
                          [:, None])
            max_cost = jnp.where(blk_improving, blk_max,
                                 jnp.max(d_full, axis=1, keepdims=True))
        else:
            e = jnp.argmax(d_full, axis=1).astype(jnp.int32)[:, None]
            max_cost = jnp.max(d_full, axis=1, keepdims=True)

        is_opt = max_cost <= tol
        p1_obj = jnp.sum(jnp.where(art, xB, 0.0), axis=1, keepdims=True)
        p1_done = active & in_p1 & is_opt
        infeasible = p1_done & (p1_obj > thr)
        to_phase2 = p1_done & ~infeasible
        p2_done = active & in_p2 & is_opt

        # ---- Step 2: FTRAN + sentinel min-ratio ---------------------------
        a_e = jnp.sum(jnp.where((lane2 == e)[:, None, :], Abar, 0.0), axis=2)
        u = ftran(a_e, etaR, etaV, cnt)
        onub_e = jnp.sum(jnp.where(lane == e, onub, 0), axis=1,
                         keepdims=True) != 0
        dir_e = jnp.where(onub_e, -1.0, 1.0).astype(dtype)
        ucol = dir_e * u
        valid_row = ucol > tol
        ratios = jnp.where(valid_row,
                           xB / jnp.where(valid_row, ucol, 1.0), BIG)
        ubB = jnp.min(jnp.where(hitc & (basis < n)[:, :, None],
                                ub[:, None, :], jnp.inf), axis=2)
        hit_ub = (ucol < -tol) & jnp.isfinite(ubB)
        ratios = jnp.where(hit_ub,
                           (ubB - xB) / jnp.where(hit_ub, -ucol, 1.0),
                           ratios)
        pin = in_p2 & (basis >= ncand) & row_ok & (ucol < -tol)
        ratios = jnp.where(pin, 0.0, ratios)
        l = jnp.argmin(ratios, axis=1).astype(jnp.int32)[:, None]
        min_ratio = jnp.min(ratios, axis=1, keepdims=True)
        no_row = min_ratio >= BIG / 2

        wants_pivot = active & ~is_opt
        t_e = jnp.min(jnp.where((lane == e) & (lane < n), ub, jnp.inf),
                      axis=1, keepdims=True)
        do_flip = wants_pivot & (t_e < min_ratio)
        unbounded = wants_pivot & no_row & ~do_flip & in_p2
        stuck = wants_pivot & no_row & ~do_flip & in_p1
        do_pivot = wants_pivot & ~no_row & ~do_flip

        # ---- Step 3: O(m) update ------------------------------------------
        is_l = row == l
        ul = jnp.sum(jnp.where(is_l, u, 0.0), axis=1, keepdims=True)
        ul_safe = jnp.where(do_pivot, ul, 1.0)
        move = do_flip | do_pivot
        theta = jnp.where(do_flip, t_e,
                          jnp.where(do_pivot, min_ratio, 0.0))
        enter_val = jnp.where(onub_e, t_e - min_ratio, min_ratio)
        xB_new = jnp.where(is_l & do_pivot, enter_val, xB - theta * ucol)
        xB = jnp.where(move, xB_new, xB)

        is_e_n = (lane == e) & (lane < n)
        onub = jnp.where(do_flip & is_e_n, 1 - onub, onub)
        onub = jnp.where(do_pivot & is_e_n, 0, onub)
        jl = jnp.sum(jnp.where(is_l & row_ok, basis, 0), axis=1,
                     keepdims=True)
        hit_l = jnp.sum(jnp.where(is_l, hit_ub.astype(jnp.int32), 0),
                        axis=1, keepdims=True) != 0
        leave_up = do_pivot & hit_l & (jl < n)
        onub = jnp.where(leave_up & (lane == jl), 1, onub)

        r_eta = jnp.where(do_pivot, l, 0)
        eta = jnp.where(do_pivot, -u / ul_safe, 0.0)
        eta = jnp.where(row == r_eta,
                        jnp.where(do_pivot, 1.0 / ul_safe, 1.0), eta)
        etaR = lax.dynamic_update_slice(etaR, r_eta, (0, cnt))
        etaV = lax.dynamic_update_slice(etaV, eta[:, None, :], (0, cnt, 0))
        cnt = cnt + jnp.any(do_pivot).astype(jnp.int32)

        basis = jnp.where(do_pivot & is_l, e, basis)
        status = jnp.where(infeasible, INFEASIBLE, status)
        status = jnp.where(unbounded, UNBOUNDED, status)
        status = jnp.where(stuck, ITERATION_LIMIT, status)
        status = jnp.where(p2_done, OPTIMAL, status)
        inc = active & ~p2_done & ~infeasible
        if ti is not None:
            # same masks core.revised.revised_step feeds tel_simplex_update;
            # attribution is on the pre-update phase (in_p1 captured above)
            ti = lane_add(ti, INT_LANE["phase1_iters"], inc & in_p1)
            ti = lane_add(ti, INT_LANE["phase2_iters"], inc & ~in_p1)
            ti = lane_add(ti, INT_LANE["phase1_pivots"], do_pivot & in_p1)
            ti = lane_add(ti, INT_LANE["phase2_pivots"], do_pivot & ~in_p1)
            ti = lane_add(ti, INT_LANE["bound_flips"], do_flip)
            ti = lane_add(ti, INT_LANE["degenerate_pivots"],
                          do_pivot & (min_ratio <= 0.0))
            # eta-file length is absolute (overwritten; the boundary
            # refactor zeroes it host-side in refactor_tile)
            ti = lane_set(ti, INT_LANE["eta_len"],
                          jnp.broadcast_to(cnt, (tile_b, 1)))
            if rule == "partial":
                ti = lane_add(ti, INT_LANE["block_rotations"],
                              active & ~blk_improving)
        phase = jnp.where(to_phase2, 2, phase)
        iters = iters + inc.astype(jnp.int32)
        return (it + 1, xB, basis, onub, phase, status, iters,
                etaR, etaV, cnt, ti)

    def cond(carry):
        (it, xB, basis, onub, phase, status, iters, etaR, etaV, cnt,
         ti) = carry
        if stage == "p1":
            pending = (status == _RUNNING) & (phase == 1)
        else:
            pending = status == _RUNNING
        return jnp.any(pending) & (it < steps) & (cnt < K)

    ti0 = ti_ref[...] if telemetry else None
    init = (jnp.int32(0), xB_ref[...], basis_ref[...], onub_ref[...],
            phase_ref[...], status_ref[...], iters_ref[...],
            jnp.zeros((tile_b, K), jnp.int32),
            jnp.zeros((tile_b, K, MC), dtype), jnp.int32(0), ti0)
    (it, xB, basis, onub, phase, status, iters, _, _, _,
     ti) = lax.while_loop(cond, pivot, init)

    xB_out[...] = xB
    basis_out[...] = basis
    onub_out[...] = onub
    phase_out[...] = phase
    status_out[...] = status
    iters_out[...] = iters
    it_out[...] = jnp.full((tile_b, 1), it, jnp.int32)
    if telemetry:
        ti_out[...] = ti


@functools.partial(
    jax.jit,
    static_argnames=("stage", "m", "n", "tile_b", "tol", "K", "interpret",
                     "pricing"))
def revised_segment_pallas(steps, Abar, cvec, ub, thr, Binv, xB, basis, onub,
                           phase, status, iters, tel_int=None, *, stage: str,
                           m: int, n: int, tile_b: int, tol: float, K: int,
                           interpret: bool = True,
                           pricing: str = "dantzig"):
    """Run up to ``steps`` revised pivots per tile (stage-aware early exit,
    eta-file boundary at ``K`` pivots).  Returns the mutated state leaves
    plus the per-LP executed-step count; call `refactor_tile` before the
    next segment.  ``tel_int`` is an optional (B, INT_ROW_WIDTH) packed
    telemetry row, carried through the kernel and returned as an eighth
    element when given."""
    B, MC, NC2 = Abar.shape
    NCP = cvec.shape[1]
    grid = (B // tile_b,)
    dtype = Abar.dtype
    telemetry = tel_int is not None
    vec = lambda i: (i, 0)
    cube = lambda i: (i, 0, 0)
    kernel = functools.partial(_revised_segment_kernel, stage=stage, m=m,
                               n=n, tol=float(tol), K=int(K),
                               rule=pricing, telemetry=telemetry)
    out_shape = [
        jax.ShapeDtypeStruct((B, MC), dtype),         # xB
        jax.ShapeDtypeStruct((B, MC), jnp.int32),     # basis
        jax.ShapeDtypeStruct((B, NCP), jnp.int32),    # onub
        jax.ShapeDtypeStruct((B, 1), jnp.int32),      # phase
        jax.ShapeDtypeStruct((B, 1), jnp.int32),      # status
        jax.ShapeDtypeStruct((B, 1), jnp.int32),      # iters
        jax.ShapeDtypeStruct((B, 1), jnp.int32),      # executed steps
    ]
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),             # steps
        pl.BlockSpec((tile_b, MC, NC2), cube),              # Abar
        pl.BlockSpec((tile_b, NCP), vec),                   # cvec
        pl.BlockSpec((tile_b, NCP), vec),                   # ub
        pl.BlockSpec((tile_b, 1), vec),                     # thr
        pl.BlockSpec((tile_b, MC, MC), cube),               # Binv
        pl.BlockSpec((tile_b, MC), vec),                    # xB
        pl.BlockSpec((tile_b, MC), vec),                    # basis
        pl.BlockSpec((tile_b, NCP), vec),                   # onub
        pl.BlockSpec((tile_b, 1), vec),                     # phase
        pl.BlockSpec((tile_b, 1), vec),                     # status
        pl.BlockSpec((tile_b, 1), vec),                     # iters
    ]
    out_specs = [
        pl.BlockSpec((tile_b, MC), vec),
        pl.BlockSpec((tile_b, MC), vec),
        pl.BlockSpec((tile_b, NCP), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
    ]
    operands = (Abar, cvec, ub, thr, Binv, xB, basis, onub, phase,
                status, iters)
    if telemetry:
        in_specs.append(pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec))
        out_specs.append(pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec))
        out_shape.append(jax.ShapeDtypeStruct((B, INT_ROW_WIDTH), jnp.int32))
        operands = operands + (tel_int,)
    steps_arr = jnp.full((1, 1), steps, jnp.int32)
    return pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret)(steps_arr, *operands)


# ---------------------------------------------------------------------------
# Extraction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "n"))
def _extract_revised_tile_jit(state: RevisedTileState, *, m: int, n: int):
    """(x, obj, status, iters, y, z) off a segment-boundary state.  The dual
    BTRAN is a single ``Binv^T c_B`` matvec — valid because the eta file is
    empty at every boundary (the kernel never exports a non-empty file)."""
    ncand = n + m
    xBm = state.xB[:, :m]
    bm = state.basis[:, :m]
    x = scatter_solution(xBm, bm, n)
    cb = jnp.where(bm < ncand,
                   jnp.take_along_axis(state.cvec,
                                       jnp.minimum(bm, ncand - 1), axis=1),
                   0.0)
    obj = jnp.where(bm < n, cb * xBm, 0.0).sum(axis=1)
    onubn = state.onub[:, :n] != 0
    at_ub = jnp.where(onubn, state.ub[:, :n], 0.0)
    x = x + at_ub
    obj = obj + (state.cvec[:, :n] * at_ub).sum(axis=1)

    y_s = jnp.einsum("bij,bi->bj", state.Binv[:, :m, :m], cb)
    idx = jnp.arange(m)
    sign = state.Abar[:, idx, n + idx]
    y = sign * y_s
    z = state.cvec[:, :n] - jnp.einsum("bm,bmn->bn", y_s,
                                       state.Abar[:, :m, :n])
    status = jnp.where(state.status[:, 0] == _RUNNING, ITERATION_LIMIT,
                       state.status[:, 0])
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    opt = (status == OPTIMAL)[:, None]
    return (x, obj, status.astype(jnp.int8), state.iters[:, 0],
            jnp.where(opt, y, jnp.nan), jnp.where(opt, z, jnp.nan))


# ---------------------------------------------------------------------------
# Whole-solve driver
# ---------------------------------------------------------------------------

def revised_pallas(A, b, c, ub=None, *, m: int, n: int, tile_b: int,
                   max_iters: int, tol: float, feas_tol: float,
                   refactor_period: int | None = None,
                   pricing: str = "dantzig", interpret: bool = True,
                   warm_basis=None, warm_at_upper=None):
    """Whole-solve entry point: host loop of kernel segments with
    refactorization at every boundary.  Returns the standard 8-tuple
    (x, obj, status, iters, y, z, basis, onub) sliced to the caller's
    batch."""
    B = A.shape[0]
    rule = canonicalize_revised_rule(pricing)
    K = int(refactor_period or auto_refactor_period(m, n))
    state = build_revised_tile_state(A, b, c, ub, m=m, n=n, tile_b=tile_b,
                                     feas_tol=feas_tol,
                                     warm_basis=warm_basis,
                                     warm_at_upper=warm_at_upper)
    remaining = int(max_iters)
    while remaining > 0:
        if not bool((np.asarray(state.status) == _RUNNING).any()):
            break
        xB, basis, onub, phase, status, iters, it = revised_segment_pallas(
            jnp.int32(remaining), state.Abar, state.cvec, state.ub,
            state.thr, state.Binv, state.xB, state.basis, state.onub,
            state.phase, state.status, state.iters, stage="p2", m=m, n=n,
            tile_b=tile_b, tol=float(tol), K=K, interpret=interpret,
            pricing=rule)
        state = state._replace(xB=xB, basis=basis, onub=onub, phase=phase,
                               status=status, iters=iters)
        state = refactor_tile(state, m=m, n=n)
        remaining -= max(1, int(np.max(np.asarray(it))))
    x, obj, status, iters, y, z = _extract_revised_tile_jit(state, m=m, n=n)
    return (x[:B], obj[:B], status[:B], iters[:B], y[:B], z[:B],
            state.basis[:B, :m], state.onub[:B, :n] != 0)
