"""Pure-jnp oracles for the Pallas kernels.

The simplex oracle is the core lockstep JAX solver (core/simplex.py) — the
kernels must agree with it exactly (same pivot rule, same sentinel, same
tolerances), modulo tile padding. The hyperbox oracle is the closed form.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lp import LPBatch
from repro.core.simplex import solve_batched_jax
from repro.core.hyperbox import solve_hyperbox


def simplex_ref(A, b, c, ub=None, *, max_iters: int, tol: float = 1e-6):
    """Returns (x, obj, status, iters) matching kernels.simplex_tile output."""
    import numpy as np
    batch = LPBatch(A=np.asarray(A), b=np.asarray(b), c=np.asarray(c),
                    ub=None if ub is None else np.asarray(ub))
    res = solve_batched_jax(batch, max_iters=max_iters, tol=tol)
    return res.x, res.objective, res.status, res.iterations


def hyperbox_ref(lo, hi, d):
    return solve_hyperbox(jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(d))
