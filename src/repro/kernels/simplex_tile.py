"""Pallas TPU kernel: whole-solve batched simplex over VMEM-resident tiles.

CUDA design (paper Sec. 5) -> TPU realization:

* one CUDA block per LP, blocks scheduled over SMs
    -> one grid step per *tile* of ``tile_b`` LPs; the tile's tableaux live in
       VMEM for the entire solve (the paper keeps its tableau in global
       memory — VMEM residency is the TPU upgrade: zero HBM traffic between
       pivots, only the initial tableau in and the solution out).
* column-major tableau for warp-coalesced column operations
    -> the tableau tile is laid out (tile_b, rows, cols) with the *column*
       axis on the 128-lane dimension: Step-1 argmax (a "row operation") and
       the entering-column extraction (a "column operation") are both
       single-lane-axis reductions; the Step-3 rank-1 update is a fully
       aligned broadcast FMA. This is the same more-column-ops-than-row-ops
       argument as the paper's Sec. 5.3, transplanted to lanes.
* parallel reduction with MAX-sentinel (no warp divergence)
    -> ``jnp.where(col > tol, rhs/col, BIG)`` then lane-axis ``argmin`` — the
       VPU has no divergence, but the sentinel keeps the reduction dense and
       NaN-free exactly as in the paper.
* per-block early exit
    -> per-tile ``while_loop``: a tile whose LPs all terminated stops
       pivoting (grid steps execute sequentially per core, so early tiles
       hand their time to later ones); the segment kernels below additionally
       let core/compaction.py retire finished LPs *between* tiles — the
       bucket-ladder reconstruction of the paper's per-block exit.

Two-level work elimination (mirrors core/simplex.py):

* **Level 1 — phase-compacted tableaux.** The whole-solve kernel runs two
  chained while_loops: the combined two-phase step on the full
  (tile_b, R, C) tile until no LP in the tile still needs phase 1, then an
  in-register compaction that drops the m artificial columns and the phase-1
  objective row, then a pure phase-2 loop on the (tile_b, R2, C2) tile.
  On the lane-padded layout this saves whole 128-lane column blocks whenever
  round_up(n+m+1) < round_up(n+2m+1) (e.g. 100x100: 384 -> 256 lanes) and
  always saves the wasted phase-1-row FMAs.
* **Level 2 — segment kernels.** ``segment_pallas`` exposes the same loops
  as resumable K-pivot segments (state in/state out, dynamic step bound read
  from a scalar input) so the active-set compaction scheduler can shrink the
  batch between segments.

Every LP in the tile shares static shapes: full stage rows = m + 2 (two
objective rows: phase-2 and phase-1), cols = n + 2m + 1 padded to a lane
multiple, with the RHS moved to the *last padded* column so padding columns
(always zero, never allowed to enter) sit inertly in the middle; compacted
stage rows = m + 1, cols = n + m + 1 padded likewise.

Pricing (core/pricing.py) is threaded through both kernels as a static
``pricing`` argument: Step 1 scores candidates per rule, and the per-LP
weight vector — a (tile_b, C) lane-aligned row riding next to the tableau —
has its recurrence fused into `_tile_pivot`.  The whole-solve kernel
initializes weights in VMEM (nothing extra crosses HBM); the resumable
segment kernels carry them as explicit state so the active-set compaction
scheduler can gather them across bucket shrinks.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lp import BIG, INFEASIBLE, ITERATION_LIMIT, OPTIMAL, UNBOUNDED
from repro.core.pricing import DEVEX_RESET
from repro.obs.telemetry import INT_LANE, INT_ROW_WIDTH, lane_add

_RUNNING = -1


def _round_up(v: int, k: int) -> int:
    return (v + k - 1) // k * k


def compacted_dims(m: int, n: int) -> Tuple[int, int]:
    """(rows, lane-padded cols) of the phase-compacted tile."""
    return _round_up(m + 1, 8), _round_up(n + m + 1, 128)


def full_dims(m: int, n: int) -> Tuple[int, int]:
    """(rows, lane-padded cols) of the full two-phase tile."""
    return _round_up(m + 2, 8), _round_up(n + 2 * m + 1, 128)


def _tile_min_ratio(T, col_full, row_ids, pin_rows, basis, ub, lane,
                    *, m: int, tol: float):
    """Step 2: sentinel min-ratio over the constraint rows (lane-axis argmin).
    Returns (l, no_row, min_ratio).  ``pin_rows`` marks rows whose basic
    variable is an artificial pinned at zero (phase 2): when the entering
    column would grow one (negative coefficient), that row leaves at ratio 0
    instead — the same escape-prevention rule as core.simplex.simplex_step.

    Bounded case (b) rides in between (mirrors core.simplex._bounded_ratios):
    a basic variable the entering column drives *up* (col < -tol) binds at
    its own finite upper bound at ``(ub_B - rhs) / (-col)``.  ``ub`` is the
    (tile_b, C) lane row with +inf on every non-structural lane, so the
    basic bound is a min-select over the basis one-hot (min, not sum —
    inf * 0 poisons a sum) and all-+inf bounds reduce to the classic test."""
    C = T.shape[2]
    col = jnp.where(row_ids < m, col_full, 0.0)
    rhs = T[:, :, C - 1]                                        # (tile_b, R)
    valid = col > tol
    ratios = jnp.where(valid, rhs / jnp.where(valid, col, 1.0), BIG)
    b_rows = basis[:, :row_ids.shape[1]]
    hitb = lane[:, None, :] == b_rows[:, :, None]       # (tile_b, R, C)
    ubB = jnp.min(jnp.where(hitb, ub[:, None, :], jnp.inf), axis=2)
    hit = (col < -tol) & jnp.isfinite(ubB)
    ratios = jnp.where(hit, (ubB - rhs) / jnp.where(hit, -col, 1.0), ratios)
    ratios = jnp.where(pin_rows & (col < -tol), 0.0, ratios)
    min_ratio = jnp.min(ratios, axis=1, keepdims=True)
    l = jnp.argmin(ratios, axis=1)[:, None]                     # (tile_b, 1)
    no_row = min_ratio >= BIG / 2
    return l, no_row, min_ratio


def _tile_select(masked_cost, w, *, rule: str, tol: float):
    """Step 1 under a pricing rule, tile/broadcast form (lane-axis argmax of
    the rule's score; the optimality test stays the rule-independent max
    reduced cost).  Mirrors core.pricing.select_entering."""
    max_cost = jnp.max(masked_cost, axis=1, keepdims=True)
    if rule == "dantzig":
        e = jnp.argmax(masked_cost, axis=1)[:, None]
    else:
        improving = masked_cost > tol
        d = jnp.where(improving, masked_cost, 0.0)
        score = jnp.where(improving, d * d / w, -BIG)
        e = jnp.argmax(score, axis=1)[:, None]
    return e, max_cost


def _tile_flip(T, flip, ub, lane, col_full, e, t_e, wants_pivot, no_row,
               min_ratio):
    """Entering-bound flip (core.simplex._bound_moves, first move) on the
    lane-padded tile: when the entering variable hits its own finite upper
    bound before any basic variable binds (``t_e < min_ratio``), complement
    it in place — ``rhs -= t_e * col`` on every row (objective rows
    included) and negate the column — no pivot, no weight update (column
    negation is norm-invariant for the d^2/w pricing scores).  ``flip`` is
    the (tile_b, C) 0/1 complement-parity lane row."""
    C = T.shape[2]
    dtype = T.dtype
    do_flip = wants_pivot & (t_e < min_ratio)
    do_pivot = wants_pivot & ~no_row & ~do_flip
    is_rhs = (lane == C - 1).astype(dtype)                      # (tile_b, C)
    ub_e_term = jnp.where(do_flip, t_e, 0.0)
    T = T - (ub_e_term * col_full)[:, :, None] * is_rhs[:, None, :]
    flip_e = do_flip & (lane == e)
    sign = jnp.where(flip_e, -1.0, 1.0).astype(dtype)
    T = T * sign[:, None, :]
    flip = flip ^ flip_e.astype(flip.dtype)
    return T, flip, do_flip, do_pivot


def _tile_pivot(T, basis, w, flip, ub, col_full, row_ids, lane, e, l,
                do_pivot, *, m: int, n: int, rule: str):
    """Step 3: rank-1 pivot update + basis update, shared by the full and
    compacted tile steps (one copy keeps them bit-for-bit in sync with each
    other and with the pure-JAX `_pivot_update`).  The pricing-weight
    recurrence is fused here exactly as in the pure-JAX path: steepest-edge
    recomputes exact gammas off the live updated tile, devex applies its
    O(C) multiplicative update (with the non-priceable-column pin — see
    core.pricing.update_weights), dantzig passes weights through untouched.

    Leaving-at-upper complement (core.simplex._bound_moves, second move):
    a negative pivot element on a structural basic means the min ratio came
    from that variable hitting *its* upper bound.  Its tableau column is a
    unit vector, so complementing it reduces to rewriting the extracted
    pivot row — negate it, ``rhs_l -> ub_l - rhs_l``, restore the +1 basic
    entry — after which the pivot element is positive and the rank-1
    update proceeds classically."""
    dtype = T.dtype
    C = T.shape[2]
    is_l = row_ids == l                                         # (tile_b, R)
    pe = jnp.sum(col_full * is_l.astype(dtype), axis=1, keepdims=True)
    pivrow_raw = jnp.sum(T * is_l.astype(dtype)[:, :, None], axis=1)

    jl = jnp.sum(jnp.where(is_l & (row_ids < m), basis[:, :row_ids.shape[1]],
                           0), axis=1, keepdims=True)           # (tile_b, 1)
    need_comp = do_pivot & (pe < 0) & (jl < n)
    is_jl = lane == jl                                          # (tile_b, C)
    ub_jl = jnp.min(jnp.where(is_jl, ub, jnp.inf), axis=1, keepdims=True)
    comp_row = -pivrow_raw
    comp_row = comp_row + (jnp.where(need_comp, ub_jl, 0.0)
                           * (lane == C - 1).astype(dtype))
    comp_row = jnp.where(is_jl, 1.0, comp_row)
    pivrow_raw = jnp.where(need_comp, comp_row, pivrow_raw)
    pe = jnp.where(need_comp, -pe, pe)
    flip = flip ^ (need_comp & is_jl).astype(flip.dtype)

    pe_safe = jnp.where(do_pivot, pe, 1.0)
    pivrow = pivrow_raw / pe_safe
    T_new = T - col_full[:, :, None] * pivrow[:, None, :]
    # replace (not re-add) the pivot row — matches the NumPy oracle
    T_new = jnp.where(is_l[:, :, None], pivrow[:, None, :], T_new)
    T = jnp.where(do_pivot[:, :, None], T_new, T)

    if rule == "steepest_edge":
        con = jnp.where((row_ids < m)[:, :, None], T, 0.0)
        w_new = 1.0 + jnp.sum(con * con, axis=1)
        w = jnp.where(do_pivot, w_new, w)
    elif rule == "devex":
        onehot_e = (lane == e).astype(dtype)
        w_e = jnp.sum(w * onehot_e, axis=1, keepdims=True)
        # leaving variable's column: basis at the pivot row, pre-update
        # (basis keeps the full-stage row height across both stages — slice
        # it to this tile's rows before masking with the tile-height iotas)
        b_rows = basis[:, :row_ids.shape[1]]
        r = jnp.sum(jnp.where(is_l & (row_ids < m), b_rows, 0), axis=1,
                    keepdims=True)
        w_new = jnp.maximum(w, pivrow * pivrow * w_e)
        w_leave = jnp.maximum(w_e / (pe_safe * pe_safe), 1.0)
        w_new = jnp.where(lane == r, w_leave, w_new)
        w_new = jnp.where(lane == e, 1.0, w_new)
        w_new = jnp.where(lane < n + m, w_new, 1.0)
        overflow = jnp.max(w_new, axis=1, keepdims=True) > DEVEX_RESET
        w_new = jnp.where(overflow, 1.0, w_new)
        w = jnp.where(do_pivot, w_new, w)

    basis_rows = jax.lax.broadcasted_iota(jnp.int32, basis.shape, 1)
    basis = jnp.where(do_pivot & (basis_rows == l) & (basis_rows < m),
                      e.astype(jnp.int32), basis)
    return T, basis, w, flip


def _tile_step(T, basis, w, flip, ub, phase, status, iters, ti=None, *,
               m: int, n: int, tol: float, thr, rule: str = "dantzig"):
    """One combined two-phase pivot across the (tile_b, R, C) tile.
    Broadcast/reduce formulation (no einsum) so every op lowers to
    VPU-friendly elementwise + lane reductions inside Pallas.

    ``ti`` is an optional (tile_b, INT_ROW_WIDTH) packed telemetry row
    (obs.telemetry.tel_to_rows); when present the step's counter lanes are
    bumped in-kernel and the row is returned as an eighth element — the
    ``ti=None`` trace is unchanged."""
    tile_b, R, C = T.shape
    dtype = T.dtype
    active = status == _RUNNING

    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_b, C), 1)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R), 1)

    # ---- Step 1: entering column (pricing rule, lane-axis argmax) ----------
    cost = jnp.where((phase == 1), T[:, m + 1, :], T[:, m, :])
    col_ok = lane < (n + m)
    masked_cost = jnp.where(col_ok, cost, -BIG)
    e, max_cost = _tile_select(masked_cost, w, rule=rule, tol=tol)
    is_opt = max_cost <= tol

    p1_obj = T[:, m + 1, C - 1][:, None]
    p1_done = active & (phase == 1) & is_opt
    infeasible = p1_done & (p1_obj > thr)
    to_phase2 = p1_done & ~infeasible
    p2_done = active & (phase == 2) & is_opt

    # ---- Steps 2 + 3 --------------------------------------------------------
    onehot_e = (lane == e).astype(dtype)                        # (tile_b, C)
    col_full = jnp.sum(T * onehot_e[:, None, :], axis=2)        # (tile_b, R)
    pin_rows = (phase == 2) & (basis[:, :R] >= n + m) & (row_ids < m)
    l, no_row, min_ratio = _tile_min_ratio(T, col_full, row_ids, pin_rows,
                                           basis, ub, lane, m=m, tol=tol)

    wants_pivot = active & ~is_opt
    t_e = jnp.min(jnp.where(lane == e, ub, jnp.inf), axis=1, keepdims=True)
    T, flip, do_flip, do_pivot = _tile_flip(
        T, flip, ub, lane, col_full, e, t_e, wants_pivot, no_row, min_ratio)
    unbounded = wants_pivot & no_row & ~do_flip & (phase == 2)
    stuck = wants_pivot & no_row & ~do_flip & (phase == 1)

    T, basis, w, flip = _tile_pivot(T, basis, w, flip, ub, col_full, row_ids,
                                    lane, e, l, do_pivot, m=m, n=n, rule=rule)

    status = jnp.where(infeasible, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(stuck, ITERATION_LIMIT, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    inc = active & ~p2_done & ~infeasible
    if ti is not None:
        # same masks the engine feeds tel_simplex_update; attribution is on
        # the pre-update phase (captured before the to_phase2 write below)
        in_p1 = phase == 1
        ti = lane_add(ti, INT_LANE["phase1_iters"], inc & in_p1)
        ti = lane_add(ti, INT_LANE["phase2_iters"], inc & ~in_p1)
        ti = lane_add(ti, INT_LANE["phase1_pivots"], do_pivot & in_p1)
        ti = lane_add(ti, INT_LANE["phase2_pivots"], do_pivot & ~in_p1)
        ti = lane_add(ti, INT_LANE["bound_flips"], do_flip)
        ti = lane_add(ti, INT_LANE["degenerate_pivots"],
                      do_pivot & (min_ratio <= 0.0))
    phase = jnp.where(to_phase2, 2, phase)
    iters = iters + inc.astype(jnp.int32)
    if ti is not None:
        return T, basis, w, flip, phase, status, iters, ti
    return T, basis, w, flip, phase, status, iters


def _tile_step_p2(T, basis, w, flip, ub, phase, status, iters, ti=None, *,
                  m: int, n: int, tol: float, rule: str = "dantzig"):
    """One phase-2 pivot on the **compacted** (tile_b, R2, C2) tile: no
    artificial columns, no phase-1 row, no phase bookkeeping.  ``ti`` is the
    same optional packed telemetry row as `_tile_step`."""
    tile_b, R2, C2 = T.shape
    dtype = T.dtype
    active = (status == _RUNNING) & (phase == 2)

    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_b, C2), 1)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R2), 1)

    cost = T[:, m, :]
    col_ok = lane < (n + m)
    masked_cost = jnp.where(col_ok, cost, -BIG)
    e, max_cost = _tile_select(masked_cost, w, rule=rule, tol=tol)
    is_opt = max_cost <= tol
    p2_done = active & is_opt

    onehot_e = (lane == e).astype(dtype)
    col_full = jnp.sum(T * onehot_e[:, None, :], axis=2)
    # the basis keeps full-stage column indices, so >= n+m still identifies
    # basic artificials on the compacted tile (every LP here is phase 2)
    pin_rows = (basis[:, :R2] >= n + m) & (row_ids < m)
    l, no_row, min_ratio = _tile_min_ratio(T, col_full, row_ids, pin_rows,
                                           basis, ub, lane, m=m, tol=tol)

    wants_pivot = active & ~is_opt
    t_e = jnp.min(jnp.where(lane == e, ub, jnp.inf), axis=1, keepdims=True)
    T, flip, do_flip, do_pivot = _tile_flip(
        T, flip, ub, lane, col_full, e, t_e, wants_pivot, no_row, min_ratio)
    unbounded = wants_pivot & no_row & ~do_flip

    T, basis, w, flip = _tile_pivot(T, basis, w, flip, ub, col_full, row_ids,
                                    lane, e, l, do_pivot, m=m, n=n, rule=rule)

    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    inc = active & ~p2_done
    if ti is not None:
        # every LP on the compacted tile is phase 2
        ti = lane_add(ti, INT_LANE["phase2_iters"], inc)
        ti = lane_add(ti, INT_LANE["phase2_pivots"], do_pivot)
        ti = lane_add(ti, INT_LANE["bound_flips"], do_flip)
        ti = lane_add(ti, INT_LANE["degenerate_pivots"],
                      do_pivot & (min_ratio <= 0.0))
    iters = iters + inc.astype(jnp.int32)
    if ti is not None:
        return T, basis, w, flip, phase, status, iters, ti
    return T, basis, w, flip, phase, status, iters


def _compact_tile(T, *, m: int, n: int):
    """Drop artificial columns + phase-1 row on the lane-padded layout:
    (B, R, C) -> (B, R2, C2) with the RHS moved to the new last lane.
    Works on kernel tile values and on batched host arrays alike."""
    C = T.shape[2]
    R2, C2 = compacted_dims(m, n)
    T2 = jnp.zeros(T.shape[:1] + (R2, C2), T.dtype)
    T2 = T2.at[:, :m + 1, :n + m].set(T[:, :m + 1, :n + m])
    T2 = T2.at[:, :m + 1, C2 - 1].set(T[:, :m + 1, C - 1])
    return T2


def _compact_tile_weights(w, *, m: int, n: int):
    """Phase compaction of the lane-padded pricing-weight row:
    (B, C) -> (B, C2).  Dropped/pad lanes get weight 1 (never priced —
    they sit outside the ``lane < n+m`` entering mask)."""
    _, C2 = compacted_dims(m, n)
    w2 = jnp.ones(w.shape[:1] + (C2,), w.dtype)
    return w2.at[:, :n + m].set(w[:, :n + m])


def _compact_tile_lane(v, fill, *, m: int, n: int):
    """Phase compaction of a generic lane row (bound vector: fill=+inf,
    flip parity: fill=0): (B, C) -> (B, C2) keeping the n+m live lanes."""
    _, C2 = compacted_dims(m, n)
    v2 = jnp.full(v.shape[:1] + (C2,), fill, v.dtype)
    return v2.at[:, :n + m].set(v[:, :n + m])


def _init_tile_weights(T, row_ids, *, m: int, rule: str):
    """In-VMEM weight init (mirrors core.pricing.init_weights on the padded
    layout): exact gammas for steepest_edge, ones otherwise."""
    if rule == "steepest_edge":
        con = jnp.where((row_ids < m)[:, :, None], T, 0.0)
        return 1.0 + jnp.sum(con * con, axis=1)
    return jnp.ones(T.shape[:1] + (T.shape[2],), T.dtype)


def _extract_tile(T2, basis, status, flip, ub, *, m: int, n: int, n_pad: int,
                  m_pad: int):
    """In-kernel solution extraction from the compacted tile: only
    (x, obj) and the dual certificate leave VMEM — the paper's "D2H-res"
    transfer shape.  The phase-2 objective row holds the certificate for
    free (see core.simplex.extract_duals): slack entries are -y, structural
    entries are the reduced costs z; both are NaN off-OPTIMAL.

    Flipped (complemented) structural lanes store ``ub - x``: map the
    primal back with ``x = ub - x_stored`` (a nonbasic-at-upper variable
    stores 0 and reads back ub) and negate the reduced cost, whose flagged
    sign means "profitable to *decrease* off the bound"."""
    tile_b, R2, C2 = T2.shape
    rhs = T2[:, :, C2 - 1]                                     # (tile_b, R2)
    b2 = basis[:, :R2]
    xcols = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R2, n_pad), 2)
    hit = (b2[:, :, None] == xcols) & (b2[:, :, None] < n)
    x = jnp.sum(jnp.where(hit, rhs[:, :, None], 0.0), axis=1)
    flip_x = flip[:, :n_pad] != 0
    x = jnp.where(flip_x, ub[:, :n_pad] - x, x)
    obj = -T2[:, m, C2 - 1][:, None]
    opt = status == OPTIMAL
    obj = jnp.where(opt, obj, jnp.nan)
    y = jnp.concatenate(
        [-T2[:, m, n:n + m], jnp.zeros((tile_b, m_pad - m), T2.dtype)],
        axis=1)
    z = jnp.concatenate(
        [T2[:, m, :n], jnp.zeros((tile_b, n_pad - n), T2.dtype)], axis=1)
    z = jnp.where(flip_x, -z, z)
    y = jnp.where(opt, y, jnp.nan)
    z = jnp.where(opt, z, jnp.nan)
    return x, obj, y, z


def _simplex_kernel(T_ref, basis_ref, phase_ref, thr_ref, ub_ref,
                    x_ref, obj_ref, status_ref, iters_ref, y_ref, z_ref,
                    *, m: int, n: int, tol: float, max_iters: int,
                    rule: str = "dantzig"):
    """Whole-solve kernel: loop 1 (combined step, full tile) -> in-register
    phase compaction -> loop 2 (phase-2 step, compacted tile) -> extraction.
    The loops share one ``max_iters`` budget (loop 2 resumes loop 1's step
    counter), mirroring core.simplex.solve_two_phase.  Pricing weights and
    the bound-flip parity row are initialized and carried entirely in VMEM —
    selecting a smarter rule or adding variable bounds changes zero extra
    HBM traffic beyond the (tile_b, C) bound lane row itself."""
    T = T_ref[...]
    basis = basis_ref[...]
    phase = phase_ref[...]
    thr = thr_ref[...]
    ub = ub_ref[...]
    tile_b, R, C = T.shape
    status = jnp.full((tile_b, 1), _RUNNING, jnp.int32)
    iters = jnp.zeros((tile_b, 1), jnp.int32)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R), 1)
    w = _init_tile_weights(T, row_ids, m=m, rule=rule)
    flip = jnp.zeros((tile_b, C), jnp.int32)

    # ---- loop 1: full tile until no LP in the tile still needs phase 1 -----
    def cond1(state):
        T, basis, w, flip, phase, status, iters, it = state
        pending = (status == _RUNNING) & (phase == 1)
        return jnp.any(pending) & (it < max_iters)

    def body1(state):
        T, basis, w, flip, phase, status, iters, it = state
        T, basis, w, flip, phase, status, iters = _tile_step(
            T, basis, w, flip, ub, phase, status, iters, m=m, n=n, tol=tol,
            thr=thr, rule=rule)
        return T, basis, w, flip, phase, status, iters, it + 1

    T, basis, w, flip, phase, status, iters, it1 = jax.lax.while_loop(
        cond1, body1,
        (T, basis, w, flip, phase, status, iters, jnp.int32(0)))
    status = jnp.where((status == _RUNNING) & (phase == 1), ITERATION_LIMIT,
                       status)

    # ---- phase compaction + loop 2 on the small tile ------------------------
    T2 = _compact_tile(T, m=m, n=n)
    w2 = _compact_tile_weights(w, m=m, n=n)
    flip2 = _compact_tile_lane(flip, 0, m=m, n=n)
    ub2 = _compact_tile_lane(ub, jnp.inf, m=m, n=n)

    def cond2(state):
        T2, basis, w2, flip2, phase, status, iters, it = state
        return jnp.any(status == _RUNNING) & (it < max_iters)

    def body2(state):
        T2, basis, w2, flip2, phase, status, iters, it = state
        T2, basis, w2, flip2, phase, status, iters = _tile_step_p2(
            T2, basis, w2, flip2, ub2, phase, status, iters, m=m, n=n,
            tol=tol, rule=rule)
        return T2, basis, w2, flip2, phase, status, iters, it + 1

    T2, basis, w2, flip2, phase, status, iters, _ = jax.lax.while_loop(
        cond2, body2, (T2, basis, w2, flip2, phase, status, iters, it1))
    status = jnp.where(status == _RUNNING, ITERATION_LIMIT, status)

    x, obj, y, z = _extract_tile(T2, basis, status, flip2, ub2, m=m, n=n,
                                 n_pad=x_ref.shape[1], m_pad=y_ref.shape[1])
    x_ref[...] = x
    obj_ref[...] = obj
    status_ref[...] = status
    iters_ref[...] = iters
    y_ref[...] = y
    z_ref[...] = z


def _segment_kernel(steps_ref, T_ref, basis_ref, w_ref, flip_ref, ub_ref,
                    phase_ref, thr_ref, status_ref, iters_ref, *refs,
                    stage: str, m: int, n: int, tol: float,
                    rule: str = "dantzig", telemetry: bool = False):
    """Resumable K-pivot segment for the compaction scheduler: state in,
    state out (pricing weights and the bound-flip parity row included, so
    bucket gathers between segments preserve the rule's recurrence and the
    complement bookkeeping), step bound read from a scalar input (no
    recompile per K).  The bound lane row is read-only (input, no output).

    With ``telemetry=True`` one extra (tile_b, INT_ROW_WIDTH) packed counter
    row rides the carry (input after ``iters``, output after ``it``) and the
    pivot steps bump its lanes in VMEM; the default trace is byte-identical
    to the pre-telemetry kernel."""
    if telemetry:
        ti_ref = refs[0]
        (T_out, basis_out, w_out, flip_out, phase_out, status_out,
         iters_out, it_out, ti_out) = refs[1:]
    else:
        ti_ref = ti_out = None
        (T_out, basis_out, w_out, flip_out, phase_out, status_out,
         iters_out, it_out) = refs
    steps = steps_ref[0, 0]
    T = T_ref[...]
    basis = basis_ref[...]
    w = w_ref[...]
    flip = flip_ref[...]
    ub = ub_ref[...]
    phase = phase_ref[...]
    thr = thr_ref[...]
    status = status_ref[...]
    iters = iters_ref[...]
    ti0 = ti_ref[...] if telemetry else None
    tile_b = T.shape[0]

    # the telemetry row rides the carry as a pytree leaf; ``None`` is an
    # empty subtree, so the disabled loop carries exactly today's state
    if stage == "p1":
        def cond(state):
            T, basis, w, flip, phase, status, iters, ti, it = state
            pending = (status == _RUNNING) & (phase == 1)
            return jnp.any(pending) & (it < steps)

        def body(state):
            T, basis, w, flip, phase, status, iters, ti, it = state
            out = _tile_step(
                T, basis, w, flip, ub, phase, status, iters, ti, m=m, n=n,
                tol=tol, thr=thr, rule=rule)
            T, basis, w, flip, phase, status, iters = out[:7]
            ti = out[7] if telemetry else None
            return T, basis, w, flip, phase, status, iters, ti, it + 1
    else:
        def cond(state):
            T, basis, w, flip, phase, status, iters, ti, it = state
            return jnp.any(status == _RUNNING) & (it < steps)

        def body(state):
            T, basis, w, flip, phase, status, iters, ti, it = state
            out = _tile_step_p2(
                T, basis, w, flip, ub, phase, status, iters, ti, m=m, n=n,
                tol=tol, rule=rule)
            T, basis, w, flip, phase, status, iters = out[:7]
            ti = out[7] if telemetry else None
            return T, basis, w, flip, phase, status, iters, ti, it + 1

    T, basis, w, flip, phase, status, iters, ti, it = jax.lax.while_loop(
        cond, body,
        (T, basis, w, flip, phase, status, iters, ti0, jnp.int32(0)))

    T_out[...] = T
    basis_out[...] = basis
    w_out[...] = w
    flip_out[...] = flip
    phase_out[...] = phase
    status_out[...] = status
    iters_out[...] = iters
    it_out[...] = jnp.full((tile_b, 1), it, jnp.int32)
    if telemetry:
        ti_out[...] = ti


@functools.partial(
    jax.jit,
    static_argnames=("stage", "m", "n", "tile_b", "tol", "interpret",
                     "pricing"))
def segment_pallas(steps, T, basis, w, flip, ub, phase, thr, status, iters,
                   tel_int=None, *, stage: str, m: int, n: int, tile_b: int,
                   tol: float, interpret: bool = True,
                   pricing: str = "dantzig"):
    """Run one scheduler segment (<= ``steps`` pivots) over all tiles.
    Returns (T, basis, w, flip, phase, status, iters, it) with ``it`` the
    per-tile executed step count broadcast over the tile's rows.  ``ub`` is
    carried by the scheduler's state (gathered across bucket shrinks) but is
    read-only inside the kernel.

    ``tel_int`` is an optional (B, INT_ROW_WIDTH) packed telemetry row
    (obs.telemetry.tel_to_rows); when given it is carried through the kernel,
    its counter lanes bumped per pivot, and returned as a ninth element."""
    B, R_, C_ = T.shape
    grid = (B // tile_b,)
    Rb = basis.shape[1]
    Cw = w.shape[1]
    Cl = flip.shape[1]
    telemetry = tel_int is not None
    steps_arr = jnp.full((1, 1), steps, jnp.int32)
    kernel = functools.partial(_segment_kernel, stage=stage, m=m, n=n,
                               tol=float(tol), rule=pricing,
                               telemetry=telemetry)
    vec = lambda i: (i, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((tile_b, R_, C_), lambda i: (i, 0, 0)),
        pl.BlockSpec((tile_b, Rb), vec),
        pl.BlockSpec((tile_b, Cw), vec),
        pl.BlockSpec((tile_b, Cl), vec),
        pl.BlockSpec((tile_b, Cl), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
    ]
    out_specs = [
        pl.BlockSpec((tile_b, R_, C_), lambda i: (i, 0, 0)),
        pl.BlockSpec((tile_b, Rb), vec),
        pl.BlockSpec((tile_b, Cw), vec),
        pl.BlockSpec((tile_b, Cl), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
        pl.BlockSpec((tile_b, 1), vec),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, R_, C_), T.dtype),
        jax.ShapeDtypeStruct((B, Rb), jnp.int32),
        jax.ShapeDtypeStruct((B, Cw), T.dtype),
        jax.ShapeDtypeStruct((B, Cl), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
    ]
    operands = (steps_arr, T, basis, w, flip, ub, phase, thr, status, iters)
    if telemetry:
        in_specs.append(pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec))
        out_specs.append(pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec))
        out_shape.append(jax.ShapeDtypeStruct((B, INT_ROW_WIDTH), jnp.int32))
        operands = operands + (tel_int,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


def pick_tile_b(m: int, n: int, vmem_budget: int = 8 * 2 ** 20,
                dtype_size: int = 4) -> int:
    """Choose the LP-tile batch so the working set fits the VMEM budget —
    the paper's Eq. (5)/(6) block-size limit recast as a VMEM tiling rule
    (and the reason our solver has no 511-dimension hard cap). Sized for
    loop 1 (the full tableau); the compacted loop-2 tile is strictly
    smaller."""
    R, C = full_dims(m, n)
    # tableau + ~6 (tile_b, C) scratch vectors + basis/ratios
    per_lp = (R * C + 6 * C + 4 * R) * dtype_size
    tile = max(1, vmem_budget // per_lp)
    if tile >= 8:
        tile = tile // 8 * 8
    return max(1, min(tile, 512))


def build_padded_tableau(A: jax.Array, b: jax.Array, c: jax.Array,
                         tile_b: int, feas_tol: float = 1e-5, ub=None
                         ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array, jax.Array, int, int]:
    """Build (B_pad, R, C) tableaux with RHS in the last padded column,
    plus basis/phase/threshold and the (B_pad, C) upper-bound lane row
    (finite entries on structural lanes, +inf everywhere else — slack,
    artificial, RHS and padding lanes can never flip), padded so B divides
    into tiles."""
    B, m, n = A.shape
    dtype = A.dtype
    R, C = full_dims(m, n)
    B_pad = _round_up(B, tile_b)

    neg = b < 0
    sign = jnp.where(neg, -1.0, 1.0).astype(dtype)
    T = jnp.zeros((B_pad, R, C), dtype=dtype)
    T = T.at[:B, :m, :n].set(A * sign[:, :, None])
    idx = jnp.arange(m)
    T = T.at[:B, idx, n + idx].set(sign)
    T = T.at[:B, idx, n + m + idx].set(jnp.where(neg, 1.0, 0.0).astype(dtype))
    T = T.at[:B, :m, C - 1].set(b * sign)
    T = T.at[:B, m, :n].set(c)
    p1 = (T[:B, :m, :] * neg[:, :, None].astype(dtype)).sum(axis=1)
    p1 = p1.at[:, n + m:n + 2 * m].set(0.0)
    T = T.at[:B, m + 1, :].set(p1)

    basis = jnp.full((B_pad, R), C - 1, jnp.int32)  # sentinel >= n for pad rows
    basis = basis.at[:B, :m].set(
        jnp.where(neg, n + m + idx[None, :], n + idx[None, :]).astype(jnp.int32))
    phase = jnp.ones((B_pad, 1), jnp.int32) * 2
    phase = phase.at[:B, 0].set(jnp.where(neg.any(axis=1), 1, 2))
    # padding LPs: all-zero tableau -> phase-2 cost row all zeros -> they
    # terminate OPTIMAL on the first check and never pivot.
    thr = jnp.zeros((B_pad, 1), dtype)
    thr = thr.at[:B, 0].set(feas_tol * jnp.maximum(1.0, T[:B, m + 1, C - 1]))
    ub_lane = jnp.full((B_pad, C), jnp.inf, dtype)
    if ub is not None:
        ub_lane = ub_lane.at[:B, :n].set(jnp.asarray(ub, dtype))
    return T, basis, phase, thr, ub_lane, R, C


@functools.partial(
    jax.jit,
    static_argnames=("m", "n", "tile_b", "max_iters", "tol", "feas_tol",
                     "interpret", "pricing"))
def simplex_pallas(A, b, c, ub=None, *, m: int, n: int, tile_b: int,
                   max_iters: int, tol: float = 1e-6, feas_tol: float = 1e-5,
                   interpret: bool = True, pricing: str = "dantzig"):
    """Solve the batch with the phase-compacted Pallas tile kernel. Returns
    (x, obj, status, iters) for the original (unpadded) batch.  ``pricing``
    selects the entering-column rule (core/pricing.py); ``ub`` adds native
    variable upper bounds (handled by the in-VMEM bounded ratio test, never
    as extra rows)."""
    B = A.shape[0]
    T, basis, phase, thr, ub_lane, R, C = build_padded_tableau(
        A, b, c, tile_b, feas_tol=feas_tol, ub=ub)
    B_pad = T.shape[0]
    grid = (B_pad // tile_b,)
    n_pad = _round_up(n, 128)
    m_pad = _round_up(m, 8)

    kernel = functools.partial(_simplex_kernel, m=m, n=n, tol=tol,
                               max_iters=max_iters, rule=pricing)
    x, obj, status, iters, y, z = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, R, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, R), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, m_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, n_pad), A.dtype),
            jax.ShapeDtypeStruct((B_pad, 1), A.dtype),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, m_pad), A.dtype),
            jax.ShapeDtypeStruct((B_pad, n_pad), A.dtype),
        ],
        interpret=interpret,
    )(T, basis, phase, thr, ub_lane)
    return (x[:B, :n], obj[:B, 0], status[:B, 0].astype(jnp.int8),
            iters[:B, 0], y[:B, :m], z[:B, :n])
