"""Pallas TPU kernel: whole-solve batched simplex over VMEM-resident tiles.

CUDA design (paper Sec. 5) -> TPU realization:

* one CUDA block per LP, blocks scheduled over SMs
    -> one grid step per *tile* of ``tile_b`` LPs; the tile's tableaux live in
       VMEM for the entire solve (the paper keeps its tableau in global
       memory — VMEM residency is the TPU upgrade: zero HBM traffic between
       pivots, only the initial tableau in and the solution out).
* column-major tableau for warp-coalesced column operations
    -> the tableau tile is laid out (tile_b, rows, cols) with the *column*
       axis on the 128-lane dimension: Step-1 argmax (a "row operation") and
       the entering-column extraction (a "column operation") are both
       single-lane-axis reductions; the Step-3 rank-1 update is a fully
       aligned broadcast FMA. This is the same more-column-ops-than-row-ops
       argument as the paper's Sec. 5.3, transplanted to lanes.
* parallel reduction with MAX-sentinel (no warp divergence)
    -> ``jnp.where(col > tol, rhs/col, BIG)`` then lane-axis ``argmin`` — the
       VPU has no divergence, but the sentinel keeps the reduction dense and
       NaN-free exactly as in the paper.
* per-block early exit
    -> per-tile ``while_loop``: a tile whose LPs all terminated stops
       pivoting (grid steps execute sequentially per core, so early tiles
       hand their time to later ones).

Every LP in the tile shares static shapes: rows = m + 2 (two objective rows:
phase-2 and phase-1), cols = n + 2m + 1 padded to a lane multiple, with the
RHS moved to the *last padded* column so padding columns (always zero, never
allowed to enter) sit inertly in the middle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lp import BIG, INFEASIBLE, ITERATION_LIMIT, OPTIMAL, UNBOUNDED

_RUNNING = -1


def _tile_step(T, basis, phase, status, iters, *, m: int, n: int, tol: float,
               thr):
    """One pivot across the (tile_b, R, C) tile. Broadcast/reduce formulation
    (no einsum) so every op lowers to VPU-friendly elementwise + lane
    reductions inside Pallas."""
    tile_b, R, C = T.shape
    dtype = T.dtype
    active = status == _RUNNING

    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_b, C), 1)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R), 1)

    # ---- Step 1: entering column (Dantzig rule, lane-axis argmax) ----------
    cost = jnp.where((phase == 1), T[:, m + 1, :], T[:, m, :])
    col_ok = lane < (n + m)
    masked_cost = jnp.where(col_ok, cost, -BIG)
    max_cost = jnp.max(masked_cost, axis=1, keepdims=True)
    e = jnp.argmax(masked_cost, axis=1)[:, None]                # (tile_b, 1)
    is_opt = max_cost <= tol

    w = T[:, m + 1, C - 1][:, None]
    p1_done = active & (phase == 1) & is_opt
    infeasible = p1_done & (w > thr)
    to_phase2 = p1_done & ~infeasible
    p2_done = active & (phase == 2) & is_opt

    # ---- Step 2: leaving row (sentinel min-ratio, lane-axis argmin) --------
    onehot_e = (lane == e).astype(dtype)                        # (tile_b, C)
    col_full = jnp.sum(T * onehot_e[:, None, :], axis=2)        # (tile_b, R)
    col = jnp.where(row_ids < m, col_full, 0.0)
    rhs = T[:, :, C - 1]                                        # (tile_b, R)
    valid = col > tol
    ratios = jnp.where(valid, rhs / jnp.where(valid, col, 1.0), BIG)
    min_ratio = jnp.min(ratios, axis=1, keepdims=True)
    l = jnp.argmin(ratios, axis=1)[:, None]                     # (tile_b, 1)
    no_row = min_ratio >= BIG / 2

    wants_pivot = active & ~is_opt
    unbounded = wants_pivot & no_row & (phase == 2)
    stuck = wants_pivot & no_row & (phase == 1)
    do_pivot = wants_pivot & ~no_row

    # ---- Step 3: rank-1 pivot update ----------------------------------------
    onehot_l = (row_ids == l).astype(dtype)                     # (tile_b, R)
    pe = jnp.sum(col_full * onehot_l, axis=1, keepdims=True)
    pe_safe = jnp.where(do_pivot, pe, 1.0)
    pivrow = jnp.sum(T * onehot_l[:, :, None], axis=1) / pe_safe  # (tile_b, C)
    T_new = T - col_full[:, :, None] * pivrow[:, None, :]
    T_new = T_new + onehot_l[:, :, None] * pivrow[:, None, :]
    T = jnp.where(do_pivot[:, :, None], T_new, T)

    basis_rows = jax.lax.broadcasted_iota(jnp.int32, basis.shape, 1)
    basis = jnp.where(do_pivot & (basis_rows == l) & (basis_rows < m),
                      e.astype(jnp.int32), basis)

    status = jnp.where(infeasible, INFEASIBLE, status)
    status = jnp.where(unbounded, UNBOUNDED, status)
    status = jnp.where(stuck, ITERATION_LIMIT, status)
    status = jnp.where(p2_done, OPTIMAL, status)
    phase = jnp.where(to_phase2, 2, phase)
    iters = iters + (active & ~p2_done & ~infeasible).astype(jnp.int32)
    return T, basis, phase, status, iters


def _simplex_kernel(T_ref, basis_ref, phase_ref, thr_ref,
                    x_ref, obj_ref, status_ref, iters_ref,
                    *, m: int, n: int, tol: float, max_iters: int):
    T = T_ref[...]
    basis = basis_ref[...]
    phase = phase_ref[...]
    thr = thr_ref[...]
    tile_b, R, C = T.shape
    status = jnp.full((tile_b, 1), _RUNNING, jnp.int32)
    iters = jnp.zeros((tile_b, 1), jnp.int32)

    def cond(state):
        T, basis, phase, status, iters, it = state
        return jnp.any(status == _RUNNING) & (it < max_iters)

    def body(state):
        T, basis, phase, status, iters, it = state
        T, basis, phase, status, iters = _tile_step(
            T, basis, phase, status, iters, m=m, n=n, tol=tol, thr=thr)
        return T, basis, phase, status, iters, it + 1

    T, basis, phase, status, iters, _ = jax.lax.while_loop(
        cond, body, (T, basis, phase, status, iters, jnp.int32(0)))

    status = jnp.where(status == _RUNNING, ITERATION_LIMIT, status)

    # solution extraction in-kernel: only (x, obj, status, iters) leave VMEM —
    # the paper's "D2H-res" (results only, not tableaux) transfer shape.
    rhs = T[:, :, C - 1]                                       # (tile_b, R)
    n_pad = x_ref.shape[1]
    xcols = jax.lax.broadcasted_iota(jnp.int32, (tile_b, R, n_pad), 2)
    hit = (basis[:, :, None] == xcols) & (basis[:, :, None] < n)
    x_ref[...] = jnp.sum(jnp.where(hit, rhs[:, :, None], 0.0), axis=1)
    obj = -T[:, m, C - 1][:, None]
    obj_ref[...] = jnp.where(status == OPTIMAL, obj, jnp.nan)
    status_ref[...] = status
    iters_ref[...] = iters


def _round_up(v: int, k: int) -> int:
    return (v + k - 1) // k * k


def pick_tile_b(m: int, n: int, vmem_budget: int = 8 * 2 ** 20,
                dtype_size: int = 4) -> int:
    """Choose the LP-tile batch so the working set fits the VMEM budget —
    the paper's Eq. (5)/(6) block-size limit recast as a VMEM tiling rule
    (and the reason our solver has no 511-dimension hard cap)."""
    R = _round_up(m + 2, 8)
    C = _round_up(n + 2 * m + 1, 128)
    # tableau + ~6 (tile_b, C) scratch vectors + basis/ratios
    per_lp = (R * C + 6 * C + 4 * R) * dtype_size
    tile = max(1, vmem_budget // per_lp)
    if tile >= 8:
        tile = tile // 8 * 8
    return max(1, min(tile, 512))


def build_padded_tableau(A: jax.Array, b: jax.Array, c: jax.Array,
                         tile_b: int) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, int, int]:
    """Build (B_pad, R, C_pad) tableaux with RHS in the last padded column,
    plus basis/phase/threshold, padded so B divides into tiles."""
    B, m, n = A.shape
    dtype = A.dtype
    R = _round_up(m + 2, 8)
    C = _round_up(n + 2 * m + 1, 128)
    B_pad = _round_up(B, tile_b)

    neg = b < 0
    sign = jnp.where(neg, -1.0, 1.0).astype(dtype)
    T = jnp.zeros((B_pad, R, C), dtype=dtype)
    T = T.at[:B, :m, :n].set(A * sign[:, :, None])
    idx = jnp.arange(m)
    T = T.at[:B, idx, n + idx].set(sign)
    T = T.at[:B, idx, n + m + idx].set(jnp.where(neg, 1.0, 0.0).astype(dtype))
    T = T.at[:B, :m, C - 1].set(b * sign)
    T = T.at[:B, m, :n].set(c)
    p1 = (T[:B, :m, :] * neg[:, :, None].astype(dtype)).sum(axis=1)
    p1 = p1.at[:, n + m:n + 2 * m].set(0.0)
    T = T.at[:B, m + 1, :].set(p1)

    basis = jnp.full((B_pad, R), C - 1, jnp.int32)  # sentinel >= n for pad rows
    basis = basis.at[:B, :m].set(
        jnp.where(neg, n + m + idx[None, :], n + idx[None, :]).astype(jnp.int32))
    phase = jnp.ones((B_pad, 1), jnp.int32) * 2
    phase = phase.at[:B, 0].set(jnp.where(neg.any(axis=1), 1, 2))
    # padding LPs: all-zero tableau -> phase-2 cost row all zeros -> they
    # terminate OPTIMAL on the first check and never pivot.
    thr = jnp.zeros((B_pad, 1), dtype)
    thr = thr.at[:B, 0].set(1e-5 * jnp.maximum(1.0, T[:B, m + 1, C - 1]))
    return T, basis, phase, thr, R, C


@functools.partial(
    jax.jit,
    static_argnames=("m", "n", "tile_b", "max_iters", "tol", "interpret"))
def simplex_pallas(A, b, c, *, m: int, n: int, tile_b: int, max_iters: int,
                   tol: float = 1e-6, interpret: bool = True):
    """Solve the batch with the Pallas tile kernel. Returns (x, obj, status,
    iters) for the original (unpadded) batch."""
    B = A.shape[0]
    T, basis, phase, thr, R, C = build_padded_tableau(A, b, c, tile_b)
    B_pad = T.shape[0]
    grid = (B_pad // tile_b,)
    n_pad = _round_up(n, 128)

    kernel = functools.partial(_simplex_kernel, m=m, n=n, tol=tol,
                               max_iters=max_iters)
    x, obj, status, iters = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, R, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, R), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, n_pad), A.dtype),
            jax.ShapeDtypeStruct((B_pad, 1), A.dtype),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(T, basis, phase, thr)
    return (x[:B, :n], obj[:B, 0], status[:B, 0].astype(jnp.int8),
            iters[:B, 0])
