"""Pallas TPU kernel for the Mamba-1 selective-scan recurrence (hillclimb 4).

The pure-XLA path (`mamba._chunk_scan`) uses `associative_scan`, which
materializes log2(T) levels of (B, T, d_inner, state) temporaries — the
measured reason falcon-mamba's memory roofline term is ~100x its compute
term. This kernel runs the recurrence

    h_t = dA_t * h_t-1 + dBx_t,        t = 0..T-1

sequentially *inside* VMEM: per (batch-tile, channel-tile) grid cell it
reads dA/dBx once, keeps h in registers/VMEM, and writes hs once — HBM
traffic = 3 tensor passes instead of ~2*log2(T)+2. The time loop is
latency-bound on the VPU, but with (TB x DT) = (1 x 512) lanes busy per step
and the channel grid axis parallel across cores, utilization recovers while
traffic drops ~12x (measured via the dry-run cost model in EXPERIMENTS
§Perf cell D).

Backward is the standard reverse recurrence, also as a kernel:

    g_t   += dA_t+1 * g_t+1                    (suffix scan of cotangents)
    ddBx_t = g_t
    ddA_t  = g_t * h_t-1
    dh0    = dA_0 * g_0

wired through `jax.custom_vjp` so `ssm_scan` is a drop-in for the
associative-scan implementation (gradients verified against it in
tests/test_ssm_kernel.py).

Layout: state `s` rides the sublane axis and channels ride the 128-lane
axis: blocks are (TB, T, S, DT). Callers pass (B, T, d, s) arrays; the ops
wrapper transposes (documented — a fused production version would keep the
(s, d)-minor layout end-to-end).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(dA_ref, dBx_ref, h0_ref, hs_ref, hT_ref):
    TB, T, S, DT = dA_ref.shape
    h0 = h0_ref[...]                                   # (TB, S, DT)

    def body(t, h):
        h = dA_ref[:, t] * h + dBx_ref[:, t]           # (TB, S, DT)
        hs_ref[:, t] = h
        return h

    h = jax.lax.fori_loop(0, T, body, h0)
    hT_ref[...] = h


def _bwd_kernel(dA_ref, hs_ref, h0_ref, g_ref, ghT_ref,
                ddA_ref, ddBx_ref, dh0_ref):
    TB, T, S, DT = dA_ref.shape
    # suffix recurrence over cotangents; gh carries d L / d h_t (total)
    gh0 = ghT_ref[...]                                 # cotangent of h_T

    def body(i, gh):
        t = T - 1 - i
        gh = gh + g_ref[:, t]
        h_prev = jnp.where(t == 0, h0_ref[...], hs_ref[:, jnp.maximum(t - 1, 0)])
        ddA_ref[:, t] = gh * h_prev
        ddBx_ref[:, t] = gh
        return dA_ref[:, t] * gh

    gh = jax.lax.fori_loop(0, T, body, jnp.zeros_like(gh0) + gh0)
    dh0_ref[...] = gh


def _round_up(v: int, k: int) -> int:
    return (v + k - 1) // k * k


def _grid_call(kernel, arrays, out_shapes, TB: int, DT: int, interpret: bool):
    """Common pallas_call: grid over (batch tiles, channel tiles); every
    array is (B, [T,] S, D)-shaped with D minor."""
    B = arrays[0].shape[0]
    D = arrays[0].shape[-1]
    grid = (B // TB, D // DT)

    def spec_for(a):
        if a.ndim == 4:
            return pl.BlockSpec((TB, a.shape[1], a.shape[2], DT),
                                lambda b, d: (b, 0, 0, d))
        return pl.BlockSpec((TB, a.shape[1], DT), lambda b, d: (b, 0, d))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_for(a) for a in arrays],
        out_specs=[spec_for(o) for o in out_shapes],
        out_shape=out_shapes,
        interpret=interpret,
    )(*arrays)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def ssm_scan(dA, dBx, h0, TB: int = 1, DT: int = 128,
             interpret: bool = True):
    """dA, dBx: (B, T, S, D) f32; h0: (B, S, D) f32 ->
    (hs (B, T, S, D), hT (B, S, D))."""
    hs, hT = _ssm_fwd(dA, dBx, h0, TB, DT, interpret)
    return hs, hT


def _ssm_fwd(dA, dBx, h0, TB, DT, interpret):
    B, T, S, D = dA.shape
    out_shapes = [jax.ShapeDtypeStruct((B, T, S, D), dA.dtype),
                  jax.ShapeDtypeStruct((B, S, D), dA.dtype)]
    return _grid_call(_fwd_kernel, [dA, dBx, h0], out_shapes, TB, DT,
                      interpret)


def _fwd_rule(dA, dBx, h0, TB, DT, interpret):
    hs, hT = _ssm_fwd(dA, dBx, h0, TB, DT, interpret)
    return (hs, hT), (dA, hs, h0)


def _bwd_rule(TB, DT, interpret, res, cts):
    dA, hs, h0 = res
    g_hs, g_hT = cts
    B, T, S, D = dA.shape
    zero = jnp.zeros((B, S, D), dA.dtype)
    g_hs = jnp.zeros_like(dA) if isinstance(g_hs, jax.custom_derivatives.SymbolicZero) else g_hs  # pragma: no cover
    g_hT = zero if g_hT is None else g_hT
    out_shapes = [jax.ShapeDtypeStruct((B, T, S, D), dA.dtype),
                  jax.ShapeDtypeStruct((B, T, S, D), dA.dtype),
                  jax.ShapeDtypeStruct((B, S, D), dA.dtype)]
    ddA, ddBx, dh0 = _grid_call(_bwd_kernel, [dA, hs, h0, g_hs, g_hT],
                                out_shapes, TB, DT, interpret)
    return ddA, ddBx, dh0


ssm_scan.defvjp(_fwd_rule, _bwd_rule)


def ssm_scan_bt_ds(dA, dBx, h0, *, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array]:
    """Adapter for mamba's (B, T, d, s) layout -> kernel's (B, T, s, d).
    Pads channels to a lane multiple. Returns ((B, T, d, s), (B, d, s))."""
    B, T, d, s = dA.shape
    DT = 128 if d % 128 == 0 else _round_up(min(d, 128), 8)
    d_pad = _round_up(d, DT)

    def prep(x, time_major):
        x = jnp.moveaxis(x, -2, -1)  # (..., s, d)
        if d_pad != d:
            pad = [(0, 0)] * x.ndim
            pad[-1] = (0, d_pad - d)
            x = jnp.pad(x, pad)
        return x

    hs, hT = ssm_scan(prep(dA, True), prep(dBx, True), prep(h0, False),
                      1, DT, interpret)
    hs = jnp.moveaxis(hs, -1, -2)[..., :d, :]
    hT = jnp.moveaxis(hT, -1, -2)[..., :d, :]
    return hs, hT
