"""Pallas TPU kernel for the hyper-rectangle LP special case (paper Sec. 5.6).

The paper dedicates one 32-thread block (one active thread!) per box LP; on
TPU the whole tile is a single fused select+FMA+lane-reduction:

    support = sum_i  d_i * (d_i < 0 ? lo_i : hi_i)

Grid over batch tiles; (tile_b, n_pad) blocks in VMEM; padding lanes carry
d = 0 so they contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hyperbox_kernel(lo_ref, hi_ref, d_ref, out_ref):
    lo = lo_ref[...]
    hi = hi_ref[...]
    d = d_ref[...]
    pick = jnp.where(d < 0, lo, hi)
    out_ref[...] = jnp.sum(d * pick, axis=1, keepdims=True)


def _round_up(v: int, k: int) -> int:
    return (v + k - 1) // k * k


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def hyperbox_pallas(lo, hi, d, *, tile_b: int = 256, interpret: bool = True):
    """lo/hi/d: (B, n) -> (B,) support values."""
    B, n = lo.shape
    n_pad = _round_up(n, 128)
    B_pad = _round_up(B, tile_b)

    def pad(a, fill=0.0):
        return jnp.pad(a, ((0, B_pad - B), (0, n_pad - n)),
                       constant_values=fill)

    out = pl.pallas_call(
        _hyperbox_kernel,
        grid=(B_pad // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, n_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B_pad, 1), lo.dtype),
        interpret=interpret,
    )(pad(lo), pad(hi), pad(d))
    return out[:B, 0]
