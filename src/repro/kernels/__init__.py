"""Pallas TPU kernels for the paper's compute hot-spots: the batched simplex
pivot loop (simplex_tile.py, phase-compacted two-loop solve + resumable
segment kernels for the active-set compaction scheduler) and the hyperbox
special case (hyperbox_kernel.py). Validated on CPU with interpret=True
against ref.py."""
from .ops import PallasBackend, solve_batched_pallas, solve_hyperbox_pallas  # noqa: F401
from .simplex_tile import (  # noqa: F401
    compacted_dims, full_dims, pick_tile_b, segment_pallas, simplex_pallas,
)
from .hyperbox_kernel import hyperbox_pallas  # noqa: F401
