"""Pallas TPU kernels for the paper's compute hot-spots: the batched simplex
pivot loop (simplex_tile.py, phase-compacted two-loop solve + resumable
segment kernels for the active-set compaction scheduler), the batched
restarted-PDHG whole-solve loop (pdhg_tile.py — fused matvec + prox +
restart check in VMEM, ``backend="pdhg"``) and the hyperbox special case
(hyperbox_kernel.py). Validated on CPU with interpret=True against ref.py /
the pure-JAX engines."""
from .ops import PallasBackend, solve_batched_pallas, solve_hyperbox_pallas  # noqa: F401
from .simplex_tile import (  # noqa: F401
    compacted_dims, full_dims, pick_tile_b, segment_pallas, simplex_pallas,
)
from .pdhg_tile import pdhg_pallas, pick_pdhg_tile_b  # noqa: F401
from .hyperbox_kernel import hyperbox_pallas  # noqa: F401
