"""Pallas TPU kernels for the paper's compute hot-spots: the batched simplex
pivot loop (simplex_tile.py) and the hyperbox special case
(hyperbox_kernel.py). Validated on CPU with interpret=True against ref.py."""
from .ops import solve_batched_pallas, solve_hyperbox_pallas  # noqa: F401
from .simplex_tile import pick_tile_b, simplex_pallas  # noqa: F401
from .hyperbox_kernel import hyperbox_pallas  # noqa: F401
