"""Jit'd public wrappers for the Pallas kernels.

``solve_batched_pallas`` is a drop-in for core.simplex.solve_batched_jax
(same LPBatch -> LPResult contract) and is what core.batching dispatches to
when ``solver=`` is pointed here. ``interpret=True`` executes the kernel body
on CPU for validation; on a real TPU pass ``interpret=False``.

``compaction=True`` routes the solve through the active-set compaction
scheduler (core/compaction.py) with Pallas segment kernels: the batch is
solved in K-pivot segments and surviving LPs are gathered into
power-of-two buckets (multiples of ``tile_b``) as others terminate — the
paper's per-block early exit rebuilt on static shapes. Defaults preserve the
one-shot whole-solve kernel semantics.

``pricing=`` selects the entering-column rule (core/pricing.py:
dantzig | steepest_edge | devex) on both the whole-solve and segment paths.
``pricing="partial"`` degrades to dantzig here with a warning: the tile
kernel keeps the full cost row resident in VMEM, so block-restricted pricing
saves nothing — the rule exists for the revised backend's pricing matvec.

``backend=`` dispatch follows the core/lp.py registry; every registered
backend now has a real Pallas surface. ``backend="pdhg"`` (core/pdhg.py)
runs the whole-solve first-order tile kernel (kernels/pdhg_tile.py —
fused matvec + prox + restart check in VMEM); with ``compaction=True``
the scheduler's segments run the resumable PDHG *segment* kernel, so
bucket gathers happen between kernel launches instead of abandoning
Pallas. ``backend="revised"`` (core/revised.py) runs the revised-simplex
tile kernel (kernels/revised_tile.py — BTRAN/FTRAN against a
VMEM-resident basis inverse + eta file, refactorization at segment
boundaries), monolithic or under the scheduler with refactor-on-gather.
A backend whose registry entry reports ``supports_pallas=False`` falls
back to its pure-JAX path with a warning (fired once per process, not
once per call) so the entry-point contract stays uniform — no registered
backend currently takes that path.

``warm=`` accepts the backend-uniform `WarmStart` carrier: the revised
kernel injects a parent basis (phase-1 skip / repair, exactly the
engine's `inject_revised_warm`), the pdhg paths inject iterates +
primal weight; the tableau tile kernel has no injection surface and
warns once before starting cold.

Like every solve_* entry point, a ``GeneralLPBatch`` (core/forms.py) is
accepted directly: canonicalize on ingestion (``presolve=``/``scale=``),
solve the canonical form in the kernel, recover into original coordinates.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forms import ensure_canonical, finish_result, prepare_warm
from repro.core.lp import (ITERATION_LIMIT, OPTIMAL, LPBatch, LPResult,
                           WarmStart, backend_spec, default_max_iters)
from repro.core.compaction import (
    CompactionConfig, CompactionState, JaxBackend, SegmentStat, _maybe_span,
    _take_jit, auto_segment_k, init_orig, resolve_compact_threshold,
    run_schedule,
)
from repro.obs.telemetry import init_telemetry, rows_to_tel, tel_to_rows
from repro.core.pdhg import PdhgBackend
from repro.core.pricing import canonicalize_rule
from repro.core.revised import RevisedBackend, canonicalize_revised_rule
from repro.core.simplex import _RUNNING, scatter_solution
from .simplex_tile import (
    _compact_tile, _compact_tile_lane, _compact_tile_weights,
    _init_tile_weights, build_padded_tableau, pick_tile_b, segment_pallas,
    simplex_pallas,
)
from .pdhg_tile import (
    _extract_pdhg_tile_jit, build_pdhg_tile_state, pdhg_segment_pallas,
    pick_pdhg_tile_b,
)
from .revised_tile import (
    _extract_revised_tile_jit, build_revised_tile_state, pick_revised_tile_b,
    refactor_tile, revised_pallas, revised_segment_pallas,
)
from .hyperbox_kernel import hyperbox_pallas


# Fallback/degradation warnings fire once per process, not once per call:
# batched sweeps dispatch thousands of solves and a per-call warning is pure
# spam.  Keyed so distinct conditions still each get their one warning.
_WARNED: set = set()


def _warn_once(key: str, message: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(message, stacklevel=3)


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _compact_padded_jit(T, *, m, n):
    return _compact_tile(T, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _compact_padded_weights_jit(w, *, m, n):
    return _compact_tile_weights(w, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("fill", "m", "n"))
def _compact_padded_lane_jit(v, *, fill, m, n):
    return _compact_tile_lane(v, fill, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("m", "rule"))
def _init_padded_weights_jit(T, *, m, rule):
    row_ids = jax.lax.broadcasted_iota(jnp.int32, T.shape[:2], 1)
    return _init_tile_weights(T, row_ids, m=m, rule=rule)


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _extract_padded_jit(T, basis, status, iters, flip, ub, *, m, n):
    C = T.shape[2]
    rows = T.shape[1]
    rhs = T[:, :, C - 1]
    x = scatter_solution(rhs, basis[:, :rows], n)
    # complemented structural lanes store ub - x; nonbasic-at-upper reads ub
    flip_x = flip[:, :n] != 0
    x = jnp.where(flip_x, ub[:, :n] - x, x)
    obj = -T[:, m, C - 1]
    # dual certificate off the padded tableau (structural + slack columns
    # keep their unpadded positions; see core.simplex.extract_duals)
    y = -T[:, m, n:n + m]
    z = jnp.where(flip_x, -T[:, m, :n], T[:, m, :n])
    status = jnp.where(status == _RUNNING, ITERATION_LIMIT, status)
    obj = jnp.where(status == OPTIMAL, obj, jnp.nan)
    opt = (status == OPTIMAL)[:, None]
    return (x, obj, status.astype(jnp.int8), iters,
            jnp.where(opt, y, jnp.nan), jnp.where(opt, z, jnp.nan))


class PallasBackend(JaxBackend):
    """Compaction-scheduler backend running Pallas segment kernels on the
    lane-padded tile layout (RHS in the last padded column). Bucket sizes
    are multiples of ``tile_b`` so every segment is a whole grid of tiles;
    executed-work accounting stays in logical (unpadded) tableau elements so
    numbers are comparable across backends."""

    def __init__(self, m, n, tol, feas_tol, tile_b, interpret=True,
                 dtype=jnp.float32, pricing="dantzig"):
        super().__init__(m, n, tol, feas_tol, dtype, pricing=pricing)
        self.tile_b = int(tile_b)
        self.interpret = bool(interpret)
        self.pad_multiple = self.tile_b

    def init(self, A, b, c, ub=None, telemetry: bool = False
             ) -> CompactionState:
        T, basis, phase, thr, ub_lane, _, _ = build_padded_tableau(
            A, b, c, self.tile_b, feas_tol=self.feas_tol, ub=ub)
        B_pad = T.shape[0]
        # dantzig never reads weights: a (B, 1) stub keeps the segment
        # kernels from streaming a dead (B, C) lane row through HBM
        w = (jnp.ones((B_pad, 1), T.dtype) if self.rule in ("dantzig", "partial")
             else _init_padded_weights_jit(T, m=self.m, rule=self.rule))
        # flip parity and bound lane rows ride the state so bucket gathers
        # keep them aligned with their tableaux (ub is kernel-read-only)
        return CompactionState(
            T=T, basis=basis, phase=phase,
            status=jnp.full((B_pad, 1), _RUNNING, jnp.int32),
            iters=jnp.zeros((B_pad, 1), jnp.int32), w=w,
            flip=jnp.zeros((B_pad, T.shape[2]), jnp.int32), ub=ub_lane,
            thr=thr, tel=init_telemetry(B_pad) if telemetry else None)

    def _run(self, state: CompactionState, steps: int, stage: str):
        # counters cross the kernel boundary as one packed int32 row; the
        # f32 lanes are not touched by the tableau kernel and pass through
        rows = None if state.tel is None else tel_to_rows(state.tel)
        outs = segment_pallas(
            jnp.int32(steps), state.T, state.basis, state.w, state.flip,
            state.ub, state.phase, state.thr, state.status, state.iters,
            None if rows is None else rows[0],
            stage=stage, m=self.m, n=self.n, tile_b=self.tile_b,
            tol=self.tol, interpret=self.interpret, pricing=self.rule)
        T, basis, w, flip, phase, status, iters, it = outs[:8]
        tel = state.tel if rows is None else rows_to_tel(outs[8], rows[1])
        new = CompactionState(T=T, basis=basis, phase=phase, status=status,
                              iters=iters, w=w, flip=flip, ub=state.ub,
                              thr=state.thr, tel=tel)
        return new, int(np.max(np.asarray(it)))

    def run_phase1(self, state, steps):
        return self._run(state, steps, "p1")

    def run_phase2(self, state, steps):
        return self._run(state, steps, "p2")

    def compact_columns(self, state: CompactionState) -> CompactionState:
        w = (state.w if self.rule in ("dantzig", "partial")
             else _compact_padded_weights_jit(state.w, m=self.m, n=self.n))
        return state._replace(
            T=_compact_padded_jit(state.T, m=self.m, n=self.n), w=w,
            flip=_compact_padded_lane_jit(state.flip, fill=0, m=self.m,
                                          n=self.n),
            ub=_compact_padded_lane_jit(state.ub, fill=float("inf"),
                                        m=self.m, n=self.n))

    def extract(self, state: CompactionState, stage: str):
        return tuple(np.asarray(o) for o in _extract_padded_jit(
            state.T, state.basis, state.status.reshape(-1),
            state.iters.reshape(-1), state.flip, state.ub,
            m=self.m, n=self.n))


class RevisedPallasBackend(RevisedBackend):
    """Compaction-scheduler backend running the revised-simplex tile kernel
    (kernels/revised_tile.py) on the padded tile layout. Bucket sizes are
    multiples of ``tile_b`` so every segment is a whole grid of tiles; the
    host refactorizes the basis inverse at every segment boundary and after
    every bucket gather, so each kernel launch starts from an empty eta
    file. Work accounting (`elements_per_step`) is inherited from the
    pure-JAX revised backend — numbers stay comparable across executors."""

    def __init__(self, m, n, tol, feas_tol, tile_b, interpret=True,
                 dtype=jnp.float32, pricing="dantzig",
                 refactor_period=None):
        super().__init__(m, n, tol, feas_tol, dtype, pricing=pricing,
                         refactor_period=refactor_period)
        self.tile_b = int(tile_b)
        self.interpret = bool(interpret)
        self.pad_multiple = self.tile_b

    def init(self, A, b, c, ub=None, warm: WarmStart | None = None,
             telemetry: bool = False):
        wb = wu = None
        if warm is not None and warm.basis is not None:
            wb = jnp.asarray(np.asarray(warm.basis), jnp.int32)
            if warm.at_upper is not None:
                wu = jnp.asarray(np.asarray(warm.at_upper), bool)
        return build_revised_tile_state(
            A, b, c, ub, m=self.m, n=self.n, tile_b=self.tile_b,
            feas_tol=self.feas_tol, warm_basis=wb, warm_at_upper=wu,
            telemetry=telemetry)

    def _run(self, state, steps, stage):
        rows = None if state.tel is None else tel_to_rows(state.tel)
        outs = revised_segment_pallas(
            jnp.int32(steps), state.Abar, state.cvec, state.ub, state.thr,
            state.Binv, state.xB, state.basis, state.onub, state.phase,
            state.status, state.iters,
            None if rows is None else rows[0],
            stage=stage, m=self.m, n=self.n,
            tile_b=self.tile_b, tol=self.tol, K=self.refactor_period,
            interpret=self.interpret, pricing=self.rule)
        xB, basis, onub, phase, status, iters, it = outs[:7]
        tel = state.tel if rows is None else rows_to_tel(outs[7], rows[1])
        new = state._replace(xB=xB, basis=basis, onub=onub, phase=phase,
                             status=status, iters=iters, tel=tel)
        # the boundary refactor also counts refactorizations on the
        # telemetry trace (the kernel's eta file never crosses a segment)
        return (refactor_tile(new, m=self.m, n=self.n),
                int(np.max(np.asarray(it))))

    def run_phase1(self, state, steps):
        return self._run(state, steps, "p1")

    def run_phase2(self, state, steps):
        return self._run(state, steps, "p2")

    def take(self, state, idx):
        # generic leaf gather (RevisedTileState, not RevisedState, so skip
        # RevisedBackend's engine-state refactor), then refactor-on-compact
        gathered = _take_jit(state, jnp.asarray(idx))
        return refactor_tile(gathered, m=self.m, n=self.n)

    def extract(self, state, stage: str):
        return tuple(np.asarray(o) for o in _extract_revised_tile_jit(
            state, m=self.m, n=self.n)[:6])


class PdhgPallasBackend(PdhgBackend):
    """Compaction-scheduler backend running the resumable PDHG segment
    kernel (kernels/pdhg_tile.py). Same scheduling semantics as
    core.pdhg.PdhgBackend — one scheduler "step" is one check round of
    ``check_every`` iterations — with the rounds executed inside
    ``pallas_call`` on the padded tile layout, so iterates, averages and
    restart bookkeeping stay in VMEM between the scheduler's gathers."""

    def __init__(self, m, n, tol, dtype, check_every=None, *,
                 tile_b=None, interpret=True, vmem_budget=8 * 2 ** 20):
        from repro.core.pdhg import CHECK_EVERY
        super().__init__(m, n, tol, dtype,
                         check_every=(CHECK_EVERY if check_every is None
                                      else check_every))
        if tile_b is None:
            tile_b = pick_pdhg_tile_b(m, n, vmem_budget)
        self.tile_b = int(tile_b)
        self.interpret = bool(interpret)
        self.pad_multiple = self.tile_b

    def init(self, A, b, c, ub=None, warm: WarmStart | None = None,
             telemetry: bool = False):
        s0 = super().init(A, b, c, ub, warm=warm, telemetry=telemetry)
        return build_pdhg_tile_state(s0, m=self.m, n=self.n,
                                     tile_b=self.tile_b)

    def run_phase2(self, state, steps):
        state, it = pdhg_segment_pallas(
            jnp.int32(steps), state, m=self.m, n=self.n,
            tile_b=self.tile_b, tol=self.tol,
            check_every=self.check_every, interpret=self.interpret)
        return state, int(np.max(np.asarray(it)))

    def deactivate(self, state, valid):
        # tile status is (B, 1): a (B,) mask would broadcast to (B, B)
        valid = jnp.asarray(np.asarray(valid).reshape(-1, 1))
        status = jnp.where(valid, state.status, ITERATION_LIMIT)
        return state._replace(status=status.astype(state.status.dtype))

    def extract(self, state, stage: str):
        return tuple(np.asarray(o) for o in _extract_pdhg_tile_jit(
            state, m=self.m, n=self.n))


def solve_batched_pallas(batch: LPBatch, *, dtype=jnp.float32,
                         tile_b: Optional[int] = None,
                         max_iters: Optional[int] = None,
                         tol: Optional[float] = None,
                         feas_tol: float = 1e-5,
                         vmem_budget: int = 8 * 2 ** 20,
                         interpret: bool = True,
                         compaction: bool = False,
                         segment_k: Optional[int] = None,
                         compact_threshold: Optional[float] = None,
                         pricing: str = "dantzig",
                         backend: str = "tableau",
                         refactor_period: Optional[int] = None,
                         stats_out: Optional[List[SegmentStat]] = None,
                         presolve: bool = True,
                         scale: Optional[bool] = None,
                         warm: Optional[WarmStart] = None,
                         telemetry: bool = False,
                         tracer=None) -> LPResult:
    with _maybe_span(tracer, "canonicalize"):
        batch, rec = ensure_canonical(batch, presolve=presolve, scale=scale)
    m, n = batch.m, batch.n
    pricing = canonicalize_rule(pricing)
    warm = prepare_warm(warm, rec, batch)
    if telemetry and not compaction:
        # the whole-solve tile kernels have no counter plane: the resumable
        # segment kernels are where the packed rows ride (ISSUE 10)
        _warn_once(
            "pallas-whole-telemetry",
            "solve_batched_pallas(telemetry=True) requires compaction=True "
            "(counters ride the resumable segment kernels); the whole-solve "
            "kernel path returns stats=None")
        telemetry = False
    spec = backend_spec(backend)
    if not spec.supports_pallas:
        # registry-driven fallback for backends without a kernel surface
        # (none registered today) — the entry-point contract stays uniform
        _warn_once(
            f"{backend}-fallback",
            f"solve_batched_pallas(backend={backend!r}): the registry "
            f"reports no Pallas {backend} kernel; falling back to the "
            f"pure-JAX path (see core/lp.py BACKEND_REGISTRY)")
        from repro.core.lp import resolve_backend
        kwargs = dict(dtype=dtype, tol=tol, feas_tol=feas_tol,
                      max_iters=max_iters, pricing=pricing)
        if compaction:
            kwargs.update(segment_k=segment_k,
                          compact_threshold=compact_threshold,
                          stats_out=stats_out, telemetry=telemetry,
                          tracer=tracer)
        return finish_result(rec, resolve_backend(
            backend, compacted=compaction)(batch, **kwargs))
    if backend == "pdhg":
        from repro.core.pdhg import _check_pdhg_pricing
        _check_pdhg_pricing(pricing)
        if compaction:
            # the scheduler's segments run the resumable PDHG segment
            # kernel; bucket gathers happen between kernel launches
            from repro.core.pdhg import solve_batched_pdhg_compacted
            runner = functools.partial(PdhgPallasBackend, tile_b=tile_b,
                                       interpret=interpret,
                                       vmem_budget=vmem_budget)
            return finish_result(rec, solve_batched_pdhg_compacted(
                batch, dtype=dtype, tol=tol, max_iters=max_iters,
                segment_k=segment_k, compact_threshold=compact_threshold,
                stats_out=stats_out, warm=warm, runner=runner,
                telemetry=telemetry, tracer=tracer))
        from repro.core.pdhg import default_pdhg_max_iters
        from .pdhg_tile import pdhg_pallas
        if warm is not None:
            _warn_once(
                "pdhg-whole-warm",
                "solve_batched_pallas(backend='pdhg', warm=...): the "
                "whole-solve tile kernel starts cold; use compaction=True "
                "for warm iterate injection through the segment kernel")
        if tol is None:
            tol = 1e-5 if dtype == jnp.float32 else 1e-8
        if max_iters is None:
            max_iters = default_pdhg_max_iters(m, n)
        if tile_b is None:
            tile_b = pick_pdhg_tile_b(m, n, vmem_budget)
        x, obj, status, iters, y, z = pdhg_pallas(
            jnp.asarray(batch.A, dtype), jnp.asarray(batch.b, dtype),
            jnp.asarray(batch.c, dtype),
            jnp.asarray(batch.upper_bounds(), dtype),
            m=m, n=n, tile_b=int(tile_b),
            max_iters=int(max_iters), tol=float(tol), interpret=interpret)
        return finish_result(rec, LPResult(
            x=np.asarray(x), objective=np.asarray(obj),
            status=np.asarray(status), iterations=np.asarray(iters),
            y=np.asarray(y), z=np.asarray(z)))
    if backend == "revised":
        rule = canonicalize_revised_rule(pricing)
        if tol is None:
            tol = 1e-6 if dtype == jnp.float32 else 1e-9
        if max_iters is None:
            max_iters = default_max_iters(m, n)
        if tile_b is None:
            tile_b = pick_revised_tile_b(m, n, vmem_budget,
                                         refactor_period=refactor_period)
        A = jnp.asarray(batch.A, dtype)
        b = jnp.asarray(batch.b, dtype)
        c = jnp.asarray(batch.c, dtype)
        ub = jnp.asarray(batch.upper_bounds(), dtype)
        if compaction:
            if segment_k is None:
                segment_k = auto_segment_k(m, n)
            runner = RevisedPallasBackend(
                m, n, tol, feas_tol, tile_b, interpret=interpret,
                dtype=dtype, pricing=rule, refactor_period=refactor_period)
            B = batch.batch
            with _maybe_span(tracer, "dispatch", backend="revised-pallas",
                             B=B, m=m, n=n):
                state = runner.init(A, b, c, ub=ub, warm=warm,
                                    telemetry=telemetry)
                state, orig = init_orig(runner, state, B)
            cfg = CompactionConfig(
                segment_k=int(segment_k),
                compact_threshold=resolve_compact_threshold(
                    compact_threshold, int(segment_k)),
                pad_multiple=runner.pad_multiple)
            return finish_result(rec, run_schedule(
                runner, state, orig, B, n, max_iters=int(max_iters),
                config=cfg, stats_out=stats_out, tracer=tracer))
        wb = wu = None
        if warm is not None and warm.basis is not None:
            wb = jnp.asarray(np.asarray(warm.basis), jnp.int32)
            if warm.at_upper is not None:
                wu = jnp.asarray(np.asarray(warm.at_upper), bool)
        x, obj, status, iters, y, z, basis, onub = revised_pallas(
            A, b, c, ub, m=m, n=n, tile_b=int(tile_b),
            max_iters=int(max_iters), tol=float(tol),
            feas_tol=float(feas_tol), refactor_period=refactor_period,
            pricing=rule, interpret=interpret, warm_basis=wb,
            warm_at_upper=wu)
        res = LPResult(x=np.asarray(x), objective=np.asarray(obj),
                       status=np.asarray(status),
                       iterations=np.asarray(iters),
                       y=np.asarray(y), z=np.asarray(z),
                       warm=WarmStart(m=m, n=n, basis=np.asarray(basis),
                                      at_upper=np.asarray(onub),
                                      pricing=rule))
        return finish_result(rec, res)
    if warm is not None:
        _warn_once(
            "tableau-warm",
            "solve_batched_pallas(backend='tableau', warm=...): the "
            "tableau tile kernel has no warm-start injection; starting "
            "cold (backend='revised' and the pdhg segment path inject)")
    if pricing == "partial":
        _warn_once(
            "partial-pricing",
            "solve_batched_pallas(pricing='partial'): the tile kernel keeps "
            "the full cost row in VMEM, so partial pricing saves nothing "
            "here; using dantzig (identical certificates). Use "
            "backend='revised' for real block pricing.")
        pricing = "dantzig"
    if tol is None:
        tol = 1e-6 if dtype == jnp.float32 else 1e-9
    if tile_b is None:
        tile_b = pick_tile_b(m, n, vmem_budget)
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    if segment_k is None:
        segment_k = auto_segment_k(m, n)
    A = jnp.asarray(batch.A, dtype)
    b = jnp.asarray(batch.b, dtype)
    c = jnp.asarray(batch.c, dtype)
    ub = jnp.asarray(batch.upper_bounds(), dtype)

    if compaction:
        runner = PallasBackend(m, n, tol, feas_tol, tile_b,
                               interpret=interpret, dtype=dtype,
                               pricing=pricing)
        B = batch.batch
        with _maybe_span(tracer, "dispatch", backend="tableau-pallas",
                         B=B, m=m, n=n):
            state = runner.init(A, b, c, ub=ub, telemetry=telemetry)
            state, orig = init_orig(runner, state, B)
        cfg = CompactionConfig(
            segment_k=int(segment_k),
            compact_threshold=resolve_compact_threshold(
                compact_threshold, int(segment_k)),
            pad_multiple=runner.pad_multiple)
        return finish_result(rec, run_schedule(runner, state, orig, B, n,
                                               max_iters=int(max_iters),
                                               config=cfg,
                                               stats_out=stats_out,
                                               tracer=tracer))

    x, obj, status, iters, y, z = simplex_pallas(
        A, b, c, ub, m=m, n=n, tile_b=int(tile_b), max_iters=int(max_iters),
        tol=float(tol), feas_tol=float(feas_tol), interpret=interpret,
        pricing=pricing)
    res = LPResult(x=np.asarray(x), objective=np.asarray(obj),
                   status=np.asarray(status), iterations=np.asarray(iters),
                   y=np.asarray(y), z=np.asarray(z))
    return finish_result(rec, res)


def solve_hyperbox_pallas(lo, hi, d, *, tile_b: int = 256,
                          interpret: bool = True) -> np.ndarray:
    out = hyperbox_pallas(jnp.asarray(lo, jnp.float32),
                          jnp.asarray(hi, jnp.float32),
                          jnp.asarray(d, jnp.float32),
                          tile_b=tile_b, interpret=interpret)
    return np.asarray(out)
