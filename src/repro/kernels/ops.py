"""Jit'd public wrappers for the Pallas kernels.

``solve_batched_pallas`` is a drop-in for core.simplex.solve_batched_jax
(same LPBatch -> LPResult contract) and is what core.batching dispatches to
when ``solver=`` is pointed here. ``interpret=True`` executes the kernel body
on CPU for validation; on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.lp import LPBatch, LPResult, default_max_iters
from .simplex_tile import pick_tile_b, simplex_pallas
from .hyperbox_kernel import hyperbox_pallas


def solve_batched_pallas(batch: LPBatch, *, dtype=jnp.float32,
                         tile_b: Optional[int] = None,
                         max_iters: Optional[int] = None,
                         tol: float = 1e-6,
                         vmem_budget: int = 8 * 2 ** 20,
                         interpret: bool = True) -> LPResult:
    m, n = batch.m, batch.n
    if tile_b is None:
        tile_b = pick_tile_b(m, n, vmem_budget)
    if max_iters is None:
        max_iters = default_max_iters(m, n)
    A = jnp.asarray(batch.A, dtype)
    b = jnp.asarray(batch.b, dtype)
    c = jnp.asarray(batch.c, dtype)
    x, obj, status, iters = simplex_pallas(
        A, b, c, m=m, n=n, tile_b=int(tile_b), max_iters=int(max_iters),
        tol=float(tol), interpret=interpret)
    return LPResult(x=np.asarray(x), objective=np.asarray(obj),
                    status=np.asarray(status), iterations=np.asarray(iters))


def solve_hyperbox_pallas(lo, hi, d, *, tile_b: int = 256,
                          interpret: bool = True) -> np.ndarray:
    out = hyperbox_pallas(jnp.asarray(lo, jnp.float32),
                          jnp.asarray(hi, jnp.float32),
                          jnp.asarray(d, jnp.float32),
                          tile_b=tile_b, interpret=interpret)
    return np.asarray(out)
