"""Pallas TPU kernel: whole-solve batched restarted PDHG over VMEM tiles.

The simplex tile kernel (kernels/simplex_tile.py) keeps a *mutating*
tableau resident in VMEM; the PDHG tile keeps the **immutable** problem
data resident and mutates only the small iterate vectors — the same
VMEM-residency upgrade over HBM-looping XLA, applied to the first-order
engine (core/pdhg.py):

* one grid step per tile of ``tile_b`` LPs; the tile's (tile_b, M, N)
  constraint block, both iterate pairs, the running averages and the
  restart bookkeeping all live in VMEM for the entire solve — zero HBM
  traffic between iterations, solutions + certificates out at the end.
* the two matvecs per iteration are broadcast-FMA + axis reductions
  (``sum(A * x[:, None, :], axis=2)`` / ``sum(A * y[:, :, None], axis=1)``)
  — the VPU formulation the simplex tiles already use; no gathers, no
  scatters, no pivoting.
* the whole restart machinery — candidate selection between current and
  average iterate, sufficient/necessary decay tests, adaptive primal
  weight — is fused into the same loop: "fused matvec + prox + restart
  check in VMEM".
* per-tile early exit: the outer while_loop stops the moment every LP in
  the tile is terminal, so a tile of easy LPs hands its time to later
  tiles (grid steps execute sequentially per core).

Setup (Ruiz equilibration + power-iteration step sizes, core/pdhg.py) runs
as ordinary jitted JAX on the host side of the pallas_call — it is a
once-per-solve cost and keeping it outside the kernel lets the kernel
treat (A, b, c, scales, steps) as pure inputs.

Layout: A is (tile_b, M, N) with M = round8(m), N = round128(n); length-n
vectors ride as (tile_b, N) lane rows, length-m vectors as (tile_b, M)
rows (same convention as the simplex tile's ``basis``).  Upper bounds are
one more (tile_b, N) lane row (scaled, +inf on free and padded lanes):
the prox clips to [0, ub], bounded columns move their reduced cost into
the dual objective, and the Farkas rays get the bounded-column
relaxation/projection — all mirroring core/pdhg.py exactly.  Zero padding
is inert by construction: padded rows/columns have A = 0, b = 0, c = 0
and unit scales, so iterates, residuals and Farkas certificates never see
them; padded batch slots are all-zero LPs that converge on their first
check.  Validated under ``interpret=True`` like the simplex tiles.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lp import INFEASIBLE, ITERATION_LIMIT, OPTIMAL, UNBOUNDED
from repro.obs.telemetry import (F32_LANE, F32_ROW_WIDTH, INT_LANE,
                                 INT_ROW_WIDTH, lane_add, lane_set,
                                 rows_to_tel, tel_to_rows)
from repro.core.pdhg import (
    CERT_TOL,
    CHECK_EVERY,
    OMEGA_MAX,
    OMEGA_MIN,
    OMEGA_SMOOTHING,
    RAY_MIN_NORM,
    RESTART_NECESSARY,
    RESTART_SUFFICIENT,
    init_pdhg_state,
)

_RUNNING = -1


def _round_up(v: int, k: int) -> int:
    return (v + k - 1) // k * k


def pdhg_dims(m: int, n: int):
    """(M, N) of the padded tile: rows to a sublane multiple, the minor
    (lane) axis to 128."""
    return _round_up(m, 8), _round_up(n, 128)


def pick_pdhg_tile_b(m: int, n: int, vmem_budget: int = 8 * 2 ** 20,
                     dtype_size: int = 4) -> int:
    """Tile batch so the working set fits VMEM: the (M, N) data block plus
    ~8 length-N and ~8 length-M live vectors per LP."""
    M, N = pdhg_dims(m, n)
    per_lp = (M * N + 8 * N + 8 * M + 16) * dtype_size
    tile = max(1, vmem_budget // per_lp)
    if tile >= 8:
        tile = tile // 8 * 8
    return max(1, min(tile, 512))


def _mv(A, x):
    """(tile_b, M, N) @ (tile_b, N) -> (tile_b, M) as broadcast-FMA + lane
    reduction (VPU formulation; padded columns contribute zero)."""
    return jnp.sum(A * x[:, None, :], axis=2)


def _mtv(A, y):
    """(tile_b, M, N)^T @ (tile_b, M) -> (tile_b, N) via the sublane axis."""
    return jnp.sum(A * y[:, :, None], axis=1)


def _make_pdhg_round(A, b, c, r, s, eta, binf, cinf, ub, *, tol: float,
                     check_every: int, telemetry: bool = False):
    """Build the fused check-round closure both PDHG kernels run: one round
    = ``check_every`` prox iterations + the in-VMEM convergence / restart /
    certificate check, mirroring core.pdhg.pdhg_round exactly (same
    constants, same candidate rule, same adaptive primal weight).

    Carry layout (shared by the whole-solve and segment kernels):
    ``(it, x, y, xs, ys, xr, yr, cnt, last, prev, om, status, iters)``.
    With ``telemetry=True`` two packed counter rows — (tile_b,
    INT_ROW_WIDTH) int32 and (tile_b, F32_ROW_WIDTH) float32 — are appended
    to the carry and updated per round (iterations, adopted restarts, the
    KKT triple at the adopted candidate, the primal weight); the disabled
    closure is byte-identical to the pre-telemetry one."""
    dtype = A.dtype
    fin = jnp.isfinite(ub)
    ubm = jnp.where(fin, ub, 0.0)

    def kkt_parts(x, y):
        ax = _mv(A, x)
        aty = _mtv(A, y)
        rp = jnp.max(jnp.maximum(ax - b, 0.0) / r, axis=1, keepdims=True) \
            / (1.0 + binf)
        # bounded columns: positive reduced cost is absorbed by the bound
        # dual w_j = (c - A^T y)_j+ (core.pdhg.kkt_residuals)
        zc = jnp.maximum(c - aty, 0.0)
        rd = jnp.max(jnp.where(fin, 0.0, zc) / s, axis=1, keepdims=True) \
            / (1.0 + cinf)
        pobj = jnp.sum(c * x, axis=1, keepdims=True)
        dobj = jnp.sum(b * y, axis=1, keepdims=True) \
            + jnp.sum(ubm * zc, axis=1, keepdims=True)
        gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
        return rp, rd, gap

    def kkt(x, y):
        rp, rd, gap = kkt_parts(x, y)
        return jnp.maximum(jnp.maximum(rp, rd), gap)

    def body(carry):
        if telemetry:
            (it, x, y, xs, ys, xr, yr, cnt, last, prev, om, status,
             iters, ti, tf) = carry
        else:
            (it, x, y, xs, ys, xr, yr, cnt, last, prev, om, status,
             iters) = carry
            ti = tf = None
        active = status == _RUNNING          # (tile_b, 1)
        tau = eta / om
        sig = eta * om

        def step(_, st):
            x, y, xs, ys, cnt = st
            aty = _mtv(A, y)
            # prox of the [0, ub] indicator: clip (ub = +inf -> max)
            xn = jnp.clip(x + tau * (c - aty), 0.0, ub)
            ax2 = _mv(A, 2.0 * xn - x)
            yn = jnp.maximum(y + sig * (ax2 - b), 0.0)
            x = jnp.where(active, xn, x)
            y = jnp.where(active, yn, y)
            return (x, y, xs + jnp.where(active, x, 0.0),
                    ys + jnp.where(active, y, 0.0),
                    cnt + active.astype(dtype))

        x, y, xs, ys, cnt = jax.lax.fori_loop(
            0, check_every, step, (x, y, xs, ys, cnt))
        iters = iters + check_every * active.astype(jnp.int32)

        cc = jnp.maximum(cnt, 1.0)
        xa, ya = xs / cc, ys / cc
        if telemetry:
            # keep the component triples so the adopted candidate's
            # residuals can be recorded without extra matvecs; selecting
            # precomputed parts equals recomputing at (xc, yc) exactly
            rp_c, rd_c, gap_c = kkt_parts(x, y)
            rp_a, rd_a, gap_a = kkt_parts(xa, ya)
            res_cur = jnp.maximum(jnp.maximum(rp_c, rd_c), gap_c)
            res_avg = jnp.maximum(jnp.maximum(rp_a, rd_a), gap_a)
        else:
            res_cur = kkt(x, y)
            res_avg = kkt(xa, ya)
        use_avg = res_avg < res_cur
        res = jnp.where(use_avg, res_avg, res_cur)
        xc = jnp.where(use_avg, xa, x)
        yc = jnp.where(use_avg, ya, y)

        converged = active & (res <= tol)

        # Farkas-ray classification (core.pdhg._ray_certificates, inlined)
        # on the PRE-adoption iterates — exactly the vectors pdhg_round
        # tests, so kernel and pure-JAX paths classify on the same round
        test = active & ~converged
        ray_scale = 1.0 + binf + cinf
        yinf = jnp.max(jnp.abs(y * r), axis=1, keepdims=True)
        yh = y / jnp.maximum(yinf, 1e-12)
        aty_s = _mtv(A, yh)
        aty_u = aty_s / s
        by_u = jnp.sum(b * yh, axis=1, keepdims=True)
        # bounded columns relax the dual ray at cost u_j (A^T yh)_j^-
        uw = jnp.sum(ubm * jnp.maximum(-aty_s, 0.0), axis=1, keepdims=True)
        infeas = test & (yinf > RAY_MIN_NORM) \
            & (jnp.min(jnp.where(fin, jnp.inf, aty_u), axis=1,
                       keepdims=True)
               >= -CERT_TOL * ray_scale) \
            & (by_u + uw <= -CERT_TOL * ray_scale)
        # primal ray projected onto unbounded columns (bounded coordinates
        # cannot recede; an all-bounded LP has xinf == 0, never classified)
        xray = jnp.where(fin, 0.0, x)
        xinf = jnp.max(jnp.abs(xray * s), axis=1, keepdims=True)
        xh = xray / jnp.maximum(xinf, 1e-12)
        ax_u = _mv(A, xh) / r
        cx_u = jnp.sum(c * xh, axis=1, keepdims=True)
        unbounded = test & (xinf > RAY_MIN_NORM) \
            & (jnp.max(ax_u, axis=1, keepdims=True)
               <= CERT_TOL * ray_scale) \
            & (cx_u >= CERT_TOL * ray_scale)

        restart = (res <= RESTART_SUFFICIENT * last) \
            | ((res <= RESTART_NECESSARY * last) & (res > prev))
        restart = active & ~converged & restart
        adopt = converged | restart
        x = jnp.where(adopt, xc, x)
        y = jnp.where(adopt, yc, y)
        xs = jnp.where(restart, 0.0, xs)
        ys = jnp.where(restart, 0.0, ys)
        cnt = jnp.where(restart, 0.0, cnt)
        last = jnp.where(restart, res, last)
        prev = jnp.where(restart, jnp.inf, res)

        # adaptive primal weight (core/pdhg.py OMEGA_* constants)
        dx = jnp.sqrt(jnp.sum((xc - xr) ** 2, axis=1, keepdims=True))
        dy = jnp.sqrt(jnp.sum((yc - yr) ** 2, axis=1, keepdims=True))
        can = restart & (dx > 1e-10) & (dy > 1e-10)
        om_new = jnp.exp(OMEGA_SMOOTHING
                         * jnp.log(jnp.maximum(dy, 1e-12)
                                   / jnp.maximum(dx, 1e-12))
                         + (1.0 - OMEGA_SMOOTHING) * jnp.log(om))
        om = jnp.where(can, jnp.clip(om_new, OMEGA_MIN, OMEGA_MAX), om)
        xr = jnp.where(restart, xc, xr)
        yr = jnp.where(restart, yc, yr)

        status = jnp.where(converged, OPTIMAL, status)
        status = jnp.where(infeas, INFEASIBLE, status)
        status = jnp.where(unbounded, UNBOUNDED, status)
        if telemetry:
            # mirrors core.pdhg: iterations use the pre-round active mask,
            # restarts count adopted restarts, the KKT lanes hold the
            # adopted candidate's triple, omega the post-update weight
            ti = lane_add(ti, INT_LANE["phase2_iters"],
                          check_every * active.astype(jnp.int32))
            ti = lane_add(ti, INT_LANE["restarts"], restart)
            tf = lane_set(tf, F32_LANE["kkt_primal"],
                          jnp.where(use_avg, rp_a, rp_c))
            tf = lane_set(tf, F32_LANE["kkt_dual"],
                          jnp.where(use_avg, rd_a, rd_c))
            tf = lane_set(tf, F32_LANE["kkt_gap"],
                          jnp.where(use_avg, gap_a, gap_c))
            tf = lane_set(tf, F32_LANE["omega"], om)
            return (it + 1, x, y, xs, ys, xr, yr, cnt, last, prev, om,
                    status, iters, ti, tf)
        return (it + 1, x, y, xs, ys, xr, yr, cnt, last, prev, om, status,
                iters)

    return body


def _pdhg_kernel(A_ref, b_ref, c_ref, r_ref, s_ref, eta_ref, om_ref,
                 binf_ref, cinf_ref, ub_ref,
                 x_out, obj_out, status_out, iters_out, y_out, z_out,
                 *, tol: float, max_rounds: int, check_every: int):
    """Whole-solve kernel: run the shared check round from a cold start
    until every LP in the tile is terminal or the round budget is spent."""
    A = A_ref[...]
    b = b_ref[...]
    c = c_ref[...]
    r = r_ref[...]
    s = s_ref[...]
    eta = eta_ref[...]          # (tile_b, 1)
    om0 = om_ref[...]
    binf = binf_ref[...]
    cinf = cinf_ref[...]
    ub = ub_ref[...]            # (tile_b, N) scaled upper bounds, +inf free
    tile_b, M, N = A.shape
    dtype = A.dtype

    zeros_n = jnp.zeros((tile_b, N), dtype)
    zeros_m = jnp.zeros((tile_b, M), dtype)
    inf1 = jnp.full((tile_b, 1), jnp.inf, dtype)

    body = _make_pdhg_round(A, b, c, r, s, eta, binf, cinf, ub,
                            tol=tol, check_every=check_every)

    def cond(carry):
        it = carry[0]
        status = carry[11]
        return jnp.any(status == _RUNNING) & (it < max_rounds)

    init = (jnp.int32(0), zeros_n, zeros_m, zeros_n, zeros_m, zeros_n,
            zeros_m, jnp.zeros((tile_b, 1), dtype), inf1, inf1, om0,
            jnp.full((tile_b, 1), _RUNNING, jnp.int32),
            jnp.zeros((tile_b, 1), jnp.int32))
    (_, x, y, _, _, _, _, _, _, _, _, status, iters) = jax.lax.while_loop(
        cond, body, init)
    status = jnp.where(status == _RUNNING, ITERATION_LIMIT, status)

    # extraction in unscaled coordinates (+ NaN masks off-OPTIMAL)
    opt = status == OPTIMAL
    obj = jnp.sum(c * x, axis=1, keepdims=True)
    z = (c - _mtv(A, y)) / s
    x_out[...] = x * s
    obj_out[...] = jnp.where(opt, obj, jnp.nan)
    status_out[...] = status
    iters_out[...] = iters
    y_out[...] = jnp.where(opt, y * r, jnp.nan)
    z_out[...] = jnp.where(opt, z, jnp.nan)


@functools.partial(
    jax.jit,
    static_argnames=("m", "n", "tile_b", "max_iters", "tol", "check_every",
                     "interpret"))
def pdhg_pallas(A, b, c, ub=None, *, m: int, n: int, tile_b: int,
                max_iters: int, tol: float, check_every: int = CHECK_EVERY,
                interpret: bool = True):
    """Solve the batch with the whole-solve PDHG tile kernel.  Returns
    (x, obj, status, iters, y, z) for the original (unpadded) batch —
    the same 6-tuple contract as every solve body.  ``ub`` is an optional
    (B, n) array of upper bounds (+inf = free above)."""
    B = A.shape[0]
    dtype = A.dtype
    # setup outside the kernel: equilibration + step sizes (jitted JAX)
    s0 = init_pdhg_state(A, b, c, ub)
    M, N = pdhg_dims(m, n)
    B_pad = _round_up(B, tile_b)

    def pad(a, rows, fill=0.0):
        out = jnp.full((B_pad, rows), fill, dtype)
        return out.at[:B, :a.shape[1]].set(a)

    Ap = jnp.zeros((B_pad, M, N), dtype).at[:B, :m, :n].set(s0.A)
    bp = pad(s0.b, M)
    cp = pad(s0.c, N)
    rp = pad(s0.rsc, M, 1.0)
    sp = pad(s0.csc, N, 1.0)
    etap = pad(s0.eta, 1, 1.0)
    omp = pad(s0.omega, 1, 1.0)
    binfp = pad(s0.binf[:, None], 1)
    cinfp = pad(s0.cinf[:, None], 1)
    # padded lanes carry +inf (A = c = 0 there, so iterates stay 0 anyway)
    ubp = pad(s0.ub, N, jnp.inf)

    grid = (B_pad // tile_b,)
    rounds = -(-int(max_iters) // int(check_every))
    kernel = functools.partial(_pdhg_kernel, tol=float(tol),
                               max_rounds=rounds,
                               check_every=int(check_every))
    vec = lambda i: (i, 0)  # noqa: E731
    x, obj, status, iters, y, z = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, M, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, M), vec),
            pl.BlockSpec((tile_b, N), vec),
            pl.BlockSpec((tile_b, M), vec),
            pl.BlockSpec((tile_b, N), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, N), vec),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, N), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, 1), vec),
            pl.BlockSpec((tile_b, M), vec),
            pl.BlockSpec((tile_b, N), vec),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, N), dtype),
            jax.ShapeDtypeStruct((B_pad, 1), dtype),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, M), dtype),
            jax.ShapeDtypeStruct((B_pad, N), dtype),
        ],
        interpret=interpret,
    )(Ap, bp, cp, rp, sp, etap, omp, binfp, cinfp, ubp)
    return (x[:B, :n], obj[:B, 0], status[:B, 0].astype(jnp.int8),
            iters[:B, 0], y[:B, :m], z[:B, :n])


# ---------------------------------------------------------------------------
# Segment kernel: resumable rounds for the compaction scheduler
# ---------------------------------------------------------------------------

class PdhgTileState(NamedTuple):
    """Padded resumable PDHG state for the segment kernel; every leaf keeps
    the batch on axis 0 so the compaction scheduler's generic gathers apply
    unchanged — the tile-layout analogue of core.pdhg.PdhgState."""
    A: jax.Array       # (B, M, N) Ruiz-scaled data
    b: jax.Array       # (B, M)
    c: jax.Array       # (B, N)
    rsc: jax.Array     # (B, M) row scales
    csc: jax.Array     # (B, N) col scales
    eta: jax.Array     # (B, 1) base step
    binf: jax.Array    # (B, 1) unscaled ||b||_inf
    cinf: jax.Array    # (B, 1) unscaled ||c||_inf
    ub: jax.Array      # (B, N) scaled upper bounds (+inf free/padded)
    x: jax.Array       # (B, N) primal iterate
    y: jax.Array       # (B, M) dual iterate
    xs: jax.Array      # (B, N) running primal sum since last restart
    ys: jax.Array      # (B, M) running dual sum
    xr: jax.Array      # (B, N) last-restart anchor
    yr: jax.Array      # (B, M) last-restart anchor
    cnt: jax.Array     # (B, 1) iterations in the running average
    last: jax.Array    # (B, 1) KKT residual at the last restart
    prev: jax.Array    # (B, 1) candidate residual at the previous check
    omega: jax.Array   # (B, 1) primal weight
    phase: jax.Array   # (B, 1) int32 — constant 2 (scheduler stage-1 no-op)
    status: jax.Array  # (B, 1) int32
    iters: jax.Array   # (B, 1) int32
    tel: Any = None    # optional obs.telemetry.TelemetryState ((B,) lanes)


@functools.partial(jax.jit, static_argnames=("m", "n", "tile_b"))
def build_pdhg_tile_state(s0, *, m: int, n: int, tile_b: int
                          ) -> PdhgTileState:
    """Pad an engine ``PdhgState`` (cold or warm-injected) onto the tile
    layout.  Padding slots are all-zero LPs deactivated outright; padded
    lanes are inert (A = b = c = 0, unit scales, +inf bounds).  A telemetry
    pytree riding the engine state is zero-padded leaf-wise (padding slots
    never accumulate: they are deactivated before the first round)."""
    B = s0.A.shape[0]
    dtype = s0.A.dtype
    M, N = pdhg_dims(m, n)
    B_pad = _round_up(B, tile_b)

    def pad(a, rows, fill=0.0):
        out = jnp.full((B_pad, rows), fill, dtype)
        return out.at[:B, :a.shape[1]].set(a)

    def pad1(a, fill=0.0):
        return pad(a.reshape(B, 1), 1, fill)

    tel = s0.tel
    if tel is not None:
        tel = jax.tree.map(
            lambda v: jnp.zeros((B_pad,), v.dtype).at[:B].set(v), tel)
    Ap = jnp.zeros((B_pad, M, N), dtype).at[:B, :m, :n].set(s0.A)
    return PdhgTileState(
        A=Ap, b=pad(s0.b, M), c=pad(s0.c, N), rsc=pad(s0.rsc, M, 1.0),
        csc=pad(s0.csc, N, 1.0), eta=pad(s0.eta, 1, 1.0),
        binf=pad1(s0.binf), cinf=pad1(s0.cinf), ub=pad(s0.ub, N, jnp.inf),
        x=pad(s0.x, N), y=pad(s0.y, M), xs=pad(s0.xs, N), ys=pad(s0.ys, M),
        xr=pad(s0.xr, N), yr=pad(s0.yr, M), cnt=pad1(s0.cnt),
        last=pad1(s0.last_res, jnp.inf), prev=pad1(s0.prev_res, jnp.inf),
        omega=pad(s0.omega, 1, 1.0),
        phase=jnp.full((B_pad, 1), 2, jnp.int32).at[:B, 0].set(s0.phase),
        status=jnp.full((B_pad, 1), ITERATION_LIMIT,
                        jnp.int32).at[:B, 0].set(s0.status),
        iters=jnp.zeros((B_pad, 1), jnp.int32).at[:B, 0].set(s0.iters),
        tel=tel)


def _pdhg_segment_kernel(steps_ref, A_ref, b_ref, c_ref, r_ref, s_ref,
                         eta_ref, binf_ref, cinf_ref, ub_ref,
                         x_ref, y_ref, xs_ref, ys_ref, xr_ref, yr_ref,
                         cnt_ref, last_ref, prev_ref, om_ref, status_ref,
                         iters_ref, *refs,
                         tol: float, check_every: int,
                         telemetry: bool = False):
    """Resumable segment: up to ``steps`` check rounds of the *same* fused
    round closure the whole-solve kernel runs, with the full iterate /
    average / restart state streamed in and out so the compaction
    scheduler's bucket gathers happen between kernel segments.

    With ``telemetry=True`` the packed int32/float32 counter rows ride the
    carry (extra inputs after ``iters``, extra outputs after ``it``); the
    disabled trace is byte-identical to the pre-telemetry kernel."""
    if telemetry:
        ti_ref, tf_ref = refs[:2]
        (x_out, y_out, xs_out, ys_out, xr_out, yr_out, cnt_out, last_out,
         prev_out, om_out, status_out, iters_out, it_out, ti_out,
         tf_out) = refs[2:]
    else:
        ti_ref = tf_ref = ti_out = tf_out = None
        (x_out, y_out, xs_out, ys_out, xr_out, yr_out, cnt_out, last_out,
         prev_out, om_out, status_out, iters_out, it_out) = refs
    steps = steps_ref[0, 0]
    A = A_ref[...]
    round_body = _make_pdhg_round(
        A, b_ref[...], c_ref[...], r_ref[...], s_ref[...], eta_ref[...],
        binf_ref[...], cinf_ref[...], ub_ref[...],
        tol=tol, check_every=check_every, telemetry=telemetry)

    def cond(carry):
        it = carry[0]
        status = carry[11]
        return jnp.any(status == _RUNNING) & (it < steps)

    init = (jnp.int32(0), x_ref[...], y_ref[...], xs_ref[...], ys_ref[...],
            xr_ref[...], yr_ref[...], cnt_ref[...], last_ref[...],
            prev_ref[...], om_ref[...], status_ref[...], iters_ref[...])
    if telemetry:
        init = init + (ti_ref[...], tf_ref[...])
    out = jax.lax.while_loop(cond, round_body, init)
    (it, x, y, xs, ys, xr, yr, cnt, last, prev, om, status,
     iters) = out[:13]

    x_out[...] = x
    y_out[...] = y
    xs_out[...] = xs
    ys_out[...] = ys
    xr_out[...] = xr
    yr_out[...] = yr
    cnt_out[...] = cnt
    last_out[...] = last
    prev_out[...] = prev
    om_out[...] = om
    status_out[...] = status
    iters_out[...] = iters
    it_out[...] = jnp.full(it_out.shape, it, jnp.int32)
    if telemetry:
        ti_out[...] = out[13]
        tf_out[...] = out[14]


@functools.partial(
    jax.jit,
    static_argnames=("m", "n", "tile_b", "tol", "check_every", "interpret"))
def pdhg_segment_pallas(steps, state: PdhgTileState, *, m: int, n: int,
                        tile_b: int, tol: float,
                        check_every: int = CHECK_EVERY,
                        interpret: bool = True):
    """Run up to ``steps`` check rounds per tile and return
    ``(new_state, executed_rounds)`` — the PDHG analogue of the simplex
    ``segment_pallas`` protocol (early exit per tile once every LP in it is
    terminal).  A telemetry pytree on ``state.tel`` is packed onto dense
    counter rows around the kernel (obs.telemetry.tel_to_rows) and carried
    through VMEM; ``state.tel is None`` traces the pre-telemetry program."""
    B, M, N = state.A.shape
    grid = (B // tile_b,)
    dtype = state.A.dtype
    telemetry = state.tel is not None
    vec = lambda i: (i, 0)  # noqa: E731
    kernel = functools.partial(_pdhg_segment_kernel, tol=float(tol),
                               check_every=int(check_every),
                               telemetry=telemetry)
    spec_n = pl.BlockSpec((tile_b, N), vec)
    spec_m = pl.BlockSpec((tile_b, M), vec)
    spec_1 = pl.BlockSpec((tile_b, 1), vec)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),          # steps
        pl.BlockSpec((tile_b, M, N), lambda i: (i, 0, 0)),
        spec_m, spec_n, spec_m, spec_n,                  # b c rsc csc
        spec_1, spec_1, spec_1,                          # eta binf cinf
        spec_n,                                          # ub
        spec_n, spec_m, spec_n, spec_m, spec_n, spec_m,  # x y xs ys xr yr
        spec_1, spec_1, spec_1, spec_1, spec_1, spec_1,  # cnt..iters
    ]
    out_specs = [
        spec_n, spec_m, spec_n, spec_m, spec_n, spec_m,
        spec_1, spec_1, spec_1, spec_1, spec_1, spec_1,
        spec_1,                                          # executed
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, N), dtype),
        jax.ShapeDtypeStruct((B, M), dtype),
        jax.ShapeDtypeStruct((B, N), dtype),
        jax.ShapeDtypeStruct((B, M), dtype),
        jax.ShapeDtypeStruct((B, N), dtype),
        jax.ShapeDtypeStruct((B, M), dtype),
        jax.ShapeDtypeStruct((B, 1), dtype),
        jax.ShapeDtypeStruct((B, 1), dtype),
        jax.ShapeDtypeStruct((B, 1), dtype),
        jax.ShapeDtypeStruct((B, 1), dtype),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
    ]
    operands = (jnp.full((1, 1), steps, jnp.int32), state.A, state.b,
                state.c, state.rsc, state.csc, state.eta, state.binf,
                state.cinf, state.ub, state.x, state.y, state.xs, state.ys,
                state.xr, state.yr, state.cnt, state.last, state.prev,
                state.omega, state.status, state.iters)
    if telemetry:
        ti, tf = tel_to_rows(state.tel)
        in_specs += [pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec),
                     pl.BlockSpec((tile_b, F32_ROW_WIDTH), vec)]
        out_specs += [pl.BlockSpec((tile_b, INT_ROW_WIDTH), vec),
                      pl.BlockSpec((tile_b, F32_ROW_WIDTH), vec)]
        out_shape += [jax.ShapeDtypeStruct((B, INT_ROW_WIDTH), jnp.int32),
                      jax.ShapeDtypeStruct((B, F32_ROW_WIDTH), jnp.float32)]
        operands = operands + (ti, tf)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    (x, y, xs, ys, xr, yr, cnt, last, prev, om, status, iters,
     it) = outs[:13]
    tel = rows_to_tel(outs[13], outs[14]) if telemetry else None
    new = state._replace(x=x, y=y, xs=xs, ys=ys, xr=xr, yr=yr, cnt=cnt,
                         last=last, prev=prev, omega=om, status=status,
                         iters=iters, tel=tel)
    return new, it


@functools.partial(jax.jit, static_argnames=("m", "n"))
def _extract_pdhg_tile_jit(state: PdhgTileState, *, m: int, n: int):
    """(x, obj, status, iters, y, z) in unscaled coordinates off the padded
    iterates — the same epilogue as the whole-solve kernel."""
    status = jnp.where(state.status[:, 0] == _RUNNING, ITERATION_LIMIT,
                       state.status[:, 0])
    opt = (status == OPTIMAL)[:, None]
    obj = jnp.sum(state.c * state.x, axis=1)
    z = (state.c - _mtv(state.A, state.y)) / state.csc
    x = state.x * state.csc
    y = state.y * state.rsc
    return (x[:, :n], jnp.where(opt[:, 0], obj, jnp.nan),
            status.astype(jnp.int8), state.iters[:, 0],
            jnp.where(opt, y, jnp.nan)[:, :m],
            jnp.where(opt, z, jnp.nan)[:, :n])
