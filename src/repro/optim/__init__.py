"""Sharded optimizers (ZeRO-1 state sharding via Sharder.opt_state_spec)."""
from .adamw import adamw  # noqa: F401
from .adafactor import adafactor  # noqa: F401


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise KeyError(name)
