"""AdamW. Moments in f32; params stay in their storage dtype (bf16 on TPU —
production would add an f32 master copy or stochastic rounding; the tiny CPU
training runs in this repo use f32 params so the update is exact).

State layout mirrors the param tree; ZeRO-1 sharding comes from the Sharder:
moments additionally shard the 'residual' axis over 'data' even when params
are only tensor-parallel, so optimizer memory scales 1/(dp*tp)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable          # params -> state
    update: callable        # (grads, state, params, step) -> (params, state)
    state_logical: callable  # param logical specs -> state logical specs


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
          warmup: int = 100):
    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(1, warmup))
        return lr * warm

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        step = state["step"]
        lr_t = schedule(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            step_val = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_val).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step + 1}

    def state_logical(param_specs):
        return {"m": param_specs, "v": param_specs, "step": ()}

    return Optimizer(init=init, update=update, state_logical=state_logical)
