"""Adafactor (factored second moments, no first moment) — the optimizer of
choice for the 340B/405B dry-runs: state is O(rows+cols) per matrix instead
of O(rows*cols), which is what lets those models fit 16 GiB/chip HBM
alongside bf16 params (see EXPERIMENTS.md memory tables)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Optimizer


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip=1.0, warmup: int = 100):
    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(1, warmup))
        return lr * warm

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def zeros(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(zeros, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        step = state["step"]
        lr_t = schedule(step)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
                new_v = {"vr": vr, "vc": vc}
            else:
                nv = beta * v["v"] + (1 - beta) * g2
                denom = jnp.sqrt(nv)
                new_v = {"v": nv}
            u = g / jnp.maximum(denom, eps)
            norm = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, norm / clip)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v, "step": step + 1}

    def state_logical(param_specs):
        def spec_of(s):
            s = tuple(s)
            if len(s) >= 2:
                return {"vr": s[:-1], "vc": s[:-2] + s[-1:]}
            return {"v": s}
        return {"v": jax.tree.map(spec_of, param_specs,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}

    return Optimizer(init=init, update=update, state_logical=state_logical)
