"""The paper's technique inside the training framework: LP-allocated MoE
expert capacity (repro.core.lp_router) vs uniform capacity under skewed
routing.

    PYTHONPATH=src python examples/moe_lp_routing.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.lp_router import expert_capacity_lp
from repro.models import build_model

# skewed demand: two hot experts
rng = np.random.default_rng(0)
E, G = 16, 4
demand = np.maximum(rng.normal(1.0, 0.3, (G, E)), 0.05)
demand[:, 0] *= 12.0
demand[:, 1] *= 6.0
slots = 48.0
c_uniform = slots / E

caps = np.asarray(expert_capacity_lp(jnp.asarray(demand, jnp.float32),
                                     total_slots=slots, c_max=24.0))
served_lp = np.minimum(caps, demand).sum(-1)
served_uni = np.minimum(c_uniform, demand).sum(-1)
print("per-group demand served (higher is better):")
for g in range(G):
    print(f"  group {g}: uniform={served_uni[g]:7.2f}  "
          f"lp={served_lp[g]:7.2f}  (+{100*(served_lp[g]/served_uni[g]-1):.0f}%)")
print(f"hot-expert capacity: uniform={c_uniform:.1f} "
      f"-> lp={caps[0, 0]:.1f}")

# end-to-end: a reduced llama4-style MoE with the LP router enabled
cfg = dataclasses.replace(get_config("llama4-scout-17b-a16e").reduced(),
                          lp_capacity=True)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
loss = model.loss_fn(params, {"tokens": toks, "labels": toks})
print(f"\nreduced llama4-MoE with lp_capacity=True: loss={float(loss):.4f} "
      "(forward+routing LPs solved on-device)")
