"""The paper's motivating application (Sec. 3/7): state-space exploration of
a linear control system via support-function sampling — XSpeed's workload.

Computes a 2000-step flow-pipe of a 5-dim system, sampling K directions per
step: T*K = 80k box LPs solved via (a) the Sec. 5.6 closed form and (b) the
general batched simplex, reproducing the paper's observation that the
hyperbox special-case is the dominant win for this application.

    PYTHONPATH=src python examples/reachability.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (GeneralLPBatch, hyperbox_as_general_lp,
                        solve_batched_jax, solve_hyperbox, solve_hyperbox_ref)

rng = np.random.default_rng(1)
n, T, K = 5, 2000, 40

# five-dimensional linear system (Girard'05 benchmark shape): x' = Ax
A = np.array([[-1, -4, 0, 0, 0],
              [4, -1, 0, 0, 0],
              [0, 0, -3, 1, 0],
              [0, 0, -1, -3, 0],
              [0, 0, 0, 0, -2]], float)
dt = 0.005
M = np.eye(n) + dt * A  # Euler step

lo, hi = [np.full(n, 0.9)], [np.full(n, 1.1)]  # initial box around (1,..,1)
for _ in range(T - 1):
    c = (lo[-1] + hi[-1]) / 2
    r = (hi[-1] - lo[-1]) / 2
    lo.append(M @ c - np.abs(M) @ r - 1e-4)
    hi.append(M @ c + np.abs(M) @ r + 1e-4)
lo, hi = np.stack(lo), np.stack(hi)

dirs = rng.normal(size=(K, n))
dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)

lo_e = np.repeat(lo, K, axis=0)
hi_e = np.repeat(hi, K, axis=0)
d_e = np.tile(dirs, (T, 1))
print(f"{T} flow-pipe steps x {K} directions = {T*K} box LPs")

jl, jh, jd = map(jnp.asarray, (lo_e, hi_e, d_e))
sup = np.asarray(solve_hyperbox(jl, jh, jd))  # warm up + solve
t0 = time.perf_counter()
sup = np.asarray(solve_hyperbox(jl, jh, jd))
t_box = time.perf_counter() - t0

t0 = time.perf_counter()
_ = solve_hyperbox_ref(lo_e, hi_e, d_e)
t_np = time.perf_counter() - t0

lp, off = hyperbox_as_general_lp(lo_e[:4000], hi_e[:4000], d_e[:4000])
t0 = time.perf_counter()
res = solve_batched_jax(lp)
t_simplex = (time.perf_counter() - t0) * (T * K / 4000)

print(f"hyperbox solver (paper Sec. 5.6): {t_box*1e3:8.1f} ms")
print(f"numpy closed form (sequential-ish): {t_np*1e3:6.1f} ms "
      f"({t_np/t_box:.1f}x slower)")
print(f"general batched simplex (extrapolated): {t_simplex*1e3:8.1f} ms "
      f"({t_simplex/t_box:.0f}x slower)")
np.testing.assert_allclose(res.objective + off,
                           sup.reshape(T * K)[:4000], rtol=1e-4, atol=1e-6)
print("hyperbox == simplex on the same LPs (checked on 4000)")

# warm-start chaining along the flow-pipe: the next 4000 LPs are the SAME
# K directions against boxes drifted 100 Euler steps further — i.e. the
# same general-form LPs with edited variable bounds.  Build the slice once
# as a GeneralLPBatch and get the drifted slice with ``with_bounds`` (a
# validated copy-edit: A/c untouched, only lb/ub replaced — the same
# bound-edit path the branch-and-bound frontier rides).  The optimal basis
# of a box LP depends only on the direction's sign pattern relative to the
# box, which the drift never flips, so re-solving from the previous
# slice's terminal state (``warm=res2.warm_start()``) needs ~0 pivots
# where a cold solve re-pays the full pivot path.
g1 = GeneralLPBatch.from_arrays(
    A=d_e[:4000, None, :], sense=["L"],
    rhs=np.full((4000, 1), 1e6),           # vacuous row; bounds do the work
    lb=lo_e[:4000], ub=hi_e[:4000], c=d_e[:4000], maximize=True)
res2 = solve_batched_jax(g1)
np.testing.assert_allclose(res2.objective, sup.reshape(T * K)[:4000],
                           rtol=1e-4, atol=1e-6)
g2 = g1.with_bounds(lb=lo_e[4000:8000], ub=hi_e[4000:8000])
cold2 = solve_batched_jax(g2)
warm2 = solve_batched_jax(g2, warm=res2.warm_start())
print(f"flow-pipe warm chaining (next 4000 LPs via with_bounds): "
      f"cold {cold2.iterations.mean():.1f} pivots/LP -> "
      f"warm {warm2.iterations.mean():.1f}; statuses agree: "
      f"{bool(np.array_equal(cold2.status, warm2.status))}")
np.testing.assert_allclose(warm2.objective,
                           sup.reshape(T * K)[4000:8000], rtol=1e-4,
                           atol=1e-6)
print(f"state-space envelope at t=0:   {sup.reshape(T, K)[0, :4].round(3)}")
print(f"state-space envelope at t=end: {sup.reshape(T, K)[-1, :4].round(3)}")
