"""Quickstart: solve LPs on-device — from an MPS file or raw arrays.

    PYTHONPATH=src python examples/quickstart.py

Choosing a backend (``backend=`` on every solve_*; core/lp.py registry):

* ``"tableau"`` (default) — the paper's dense simplex.  Exact vertex
  solutions and statuses in O(m+n) pivots; wins on small/medium dense
  square-ish batches (the regime of the paper's Tables 2-4).
* ``"revised"`` — exact simplex on basis factors; wins when the canonical
  shape is wide (n >> m) or sparse (``revised_crossover`` locates the
  frontier — the paper's Netlib regime).
* ``"pdhg"`` — restarted primal-dual hybrid gradient (PDLP-style
  first-order method).  Tolerance-based: OPTIMAL means the KKT residuals
  dropped below ``tol``; objectives are ~tol-accurate, solutions interior
  rather than vertex.  Every iteration is one batched matvec pair — no
  pivoting — so it scales past the sizes where per-pivot sequential depth
  dominates (``pdhg_crossover_size`` puts the square-dense flops frontier
  at m ~ iters/2, i.e. thousands), and it returns the primal-dual
  certificate (``LPResult.y``/``z``) natively — the simplex backends
  derive the same certificate from the optimal basis, so ``y``/``z`` are
  backend-uniform.

Three structural features every backend exploits (sections 0c, 1b and 4
below):

* **warm starts** — ``res.warm_start()`` extracts a backend-uniform
  ``WarmStart`` carrier (basis + bound flips + pricing weights for the
  simplex engines; iterates + primal weight for PDHG) and ``warm=`` on any
  ``solve_*`` resumes each LP from its parent's terminal state, so a
  re-solve after a small perturbation costs a handful of pivots instead
  of a full cold solve; engines repair or fall back to cold per LP, so
  statuses and objectives never change.

* **native variable bounds** — pass ``ub=`` on ``LPBatch.from_arrays``
  (or just use MPS ``UP``/``FX`` bounds) and ``0 <= x <= u`` is enforced
  by the bounded ratio test, not by ``x_j <= u_j`` rows: canonical m
  stays small, and the engines flip variables between their bounds in
  O(row) work instead of pivoting against a dense bound row.
* **shared-pattern sparsity** — a batch of perturbed copies of one
  instance shares one nonzero pattern; ``SparseLPBatch.from_dense``
  stores it once (COO) with ``(B, nnz)`` values, and the PDHG backend's
  matvecs then cost 2*nnz instead of 2*m*n elements per iteration
  (``resolve_backend("pdhg", sparse=True)`` routes there).
"""
import numpy as np

from repro.analysis.lp_perf import (canonical_work, pdhg_crossover_size,
                                    revised_crossover, revised_pivot_flops,
                                    tableau_pivot_flops)
from repro.core import (LPBatch, STATUS_NAMES, random_lp_batch,
                        revised_elements, solve_batched,
                        solve_batched_reference, tableau_elements)
from repro.io.mps import fixture_path, perturbed_batch, read_mps
from repro.kernels import solve_batched_pallas

rng = np.random.default_rng(0)

# 0) the general-form entry path: MPS file -> GeneralLPBatch -> any solve_*.
# Netlib AFIRO (8 equality rows, minimization) is canonicalized on ingestion
# (equalities grow m: 27x32 -> 35x32, presolve + pow2 equilibration on by
# default) and the result is recovered into ORIGINAL coordinates — here the
# published optimum -464.7531.
afiro = read_mps(fixture_path("afiro"))
res0 = solve_batched(afiro, backend="revised")
print(f"AFIRO (MPS -> general form -> revised backend): "
      f"status={STATUS_NAMES[int(res0.status[0])]} "
      f"objective={res0.objective[0]:.4f}")
w = canonical_work(afiro)
print(f"  canonical shape {w['m_canonical']}x{w['n_canonical']} "
      f"(from {w['m']}x{w['n']}); revised wins on flops there: "
      f"{w['revised_wins_flops']}")

# 0b) the paper's batch recipe: one real instance x B perturbed copies
batch_afiro = perturbed_batch(afiro, 512, rng)
res0b = solve_batched(batch_afiro, backend="revised", pricing="partial")
print(f"AFIRO x512 perturbed batch: {res0b.summary()}")

# 0c) warm-starting repeated solves: re-solving a nudged copy of the batch
# from the parent's terminal state (``warm=res.warm_start()``) costs ~0
# pivots instead of a full cold solve — the parent's optimal basis is
# optimal or one repair step away for every LP.  The carrier is
# backend-uniform: the same ``warm_start()`` call seeds the tableau,
# revised, and pdhg engines (pdhg resumes from the parent's iterates and
# primal weight instead of a basis).
nudged = perturbed_batch(afiro, 512, rng)
cold = solve_batched(nudged, backend="revised", pricing="partial")
warm = solve_batched(nudged, backend="revised", pricing="partial",
                     warm=res0b.warm_start())
print(f"AFIRO x512 nudged re-solve: cold {cold.iterations.mean():.1f} "
      f"pivots/LP -> warm {warm.iterations.mean():.1f}; statuses agree: "
      f"{bool(np.array_equal(cold.status, warm.status))}")

# 1) a hand-written LP:  max x+2y  s.t.  x+y<=4, x<=2, y<=3, x,y>=0  -> 7 at (1,3)
batch = LPBatch.from_arrays(
    A=[[1.0, 1.0], [1.0, 0.0], [0.0, 1.0]],
    b=[4.0, 2.0, 3.0],
    c=[1.0, 2.0])
res = solve_batched(batch)
print(f"single LP: status={STATUS_NAMES[int(res.status[0])]} "
      f"objective={res.objective[0]:.3f} x={res.x[0]}")

# 1b) native upper bounds: max 3x+2y s.t. x+y<=10, 0<=x<=2, 0<=y<=3 -> 12
# at (2, 3) — both variables end at their *upper* bound, reached by bound
# flips in the ratio test; no x<=u rows are ever materialized (compare
# the three-row encoding of the same LP in section 1).
bounded = LPBatch.from_arrays(
    A=[[1.0, 1.0]], b=[10.0], c=[3.0, 2.0], ub=[2.0, 3.0])
res_ub = solve_batched(bounded)
print(f"bounded LP (native ub, one row): "
      f"status={STATUS_NAMES[int(res_ub.status[0])]} "
      f"objective={res_ub.objective[0]:.3f} x={res_ub.x[0]}")

# 2) a batch of 10k random LPs (the paper's regime): chunked device solve
big = random_lp_batch(rng, B=10_000, m=10, n=10)
res = solve_batched(big)                      # pure-JAX lockstep backend
print(f"10k LPs (jax):    {res.summary()}")

# 3) same batch through the Pallas TPU kernel (interpret=True on CPU)
res_k = solve_batched(big, solver=solve_batched_pallas, chunk_size=4096)
print(f"10k LPs (pallas): {res_k.summary()}")

# 3b) steepest-edge pricing: same certificates, ~half the pivots
res_se = solve_batched(big, pricing="steepest_edge")
print(f"10k LPs (steepest-edge): {res_se.summary()} "
      f"(mean pivots {res_se.iterations.mean():.1f} "
      f"vs dantzig {res.iterations.mean():.1f})")

# 3c) revised-simplex backend: immutable (A, b, c), basis-factor updates
# (eta file + periodic LU refactorization), partial pricing over column
# blocks — same certificates, O(m^2)+pricing per pivot instead of the
# tableau's O(m*(n+2m)) rank-1 update
res_rev = solve_batched(big, backend="revised", pricing="partial")
print(f"10k LPs (revised): {res_rev.summary()}")
m, n = big.m, big.n
print("work models per pivot at "
      f"{m}x{n}: tableau {tableau_elements(m, n, compacted=True)} element "
      f"updates / {tableau_pivot_flops(m, n, compacted=True):.0f} flops, "
      f"revised {revised_elements(m, n, partial=True)} element updates / "
      f"{revised_pivot_flops(m, n, partial=True):.0f} flops "
      f"(flops crossover at n ~ {revised_crossover(m)} for m={m}: the "
      "immutable data block is never rewritten, so element updates win "
      "everywhere while dense-square flops stay tableau-territory)")

# 3d) first-order backend: restarted PDHG — tolerance-based convergence,
# one batched matvec pair per iteration, native dual certificates.  On
# AFIRO the recovered duals satisfy the original-coordinate KKT system.
res_fo = solve_batched(batch_afiro, backend="pdhg")
print(f"AFIRO x512 (pdhg):  {res_fo.summary()} "
      f"(mean iterations {res_fo.iterations.mean():.0f} — cheap matvec "
      "iterations, not pivots)")
print(f"  row duals for the first LP (original coordinates, min "
      f"convention): y[:4] = {np.round(res_fo.y[0][:4], 4)}")
print(f"  first-order flops crossover vs tableau (square dense, ~10k "
      f"iters): m ~ {pdhg_crossover_size(10000)}")

# 4) shared-pattern sparse batches: the SC205-class staircase fixture is
# ~2.5% dense after canonicalization and every perturbed copy shares the
# same pattern — store it once (COO) with (B, nnz) values and the PDHG
# matvecs pay nnz, not m*n.  Statuses/objectives match the dense engine
# (same algorithm; only the matvec implementation changes).
from repro.core import (SparseLPBatch, canonicalize, pdhg_elements,
                        solve_batched_pdhg_sparse, sparse_pdhg_elements)
sc205 = read_mps(fixture_path("sc205_like"))
canon, _ = canonicalize(perturbed_batch(sc205, 16, rng))
sp = SparseLPBatch.from_dense(canon)
res_sp = solve_batched_pdhg_sparse(sp)
print(f"SC205-like x16 sparse pdhg: {res_sp.summary()} "
      f"(nnz={sp.nnz}, density {sp.density:.3f}; "
      f"{sparse_pdhg_elements(sp.nnz, sp.m, sp.n)} elements/iter vs "
      f"{pdhg_elements(sp.m, sp.n)} dense — "
      f"x{pdhg_elements(sp.m, sp.n) / sparse_pdhg_elements(sp.nnz, sp.m, sp.n):.1f} less traffic)")

# cross-check 100 of them against the float64 oracle
sub = LPBatch(A=big.A[:100], b=big.b[:100], c=big.c[:100])
ref = solve_batched_reference(sub)
ok = ref.status == 0
rel = np.abs(ref.objective[ok] - res.objective[:100][ok]) \
    / np.abs(ref.objective[ok])
print(f"max relative objective error vs float64 oracle: {rel.max():.2e}")
