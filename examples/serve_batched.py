"""Batched serving example (prefill + decode waves with KV-cache reuse).

    PYTHONPATH=src python examples/serve_batched.py

STUB — this drives the seed's LM serving loop, not an LP solve service.
The real target is the ROADMAP item "Streaming solve service: continuous
batching over shape classes": an async service that accepts LPs of
heterogeneous (m, n), pads them into pow2 shape-class buckets, admits new
arrivals into lanes freed by the compaction scheduler, routes each class
to the cheapest backend via BACKEND_REGISTRY, and reports p50/p99 latency
under a Poisson load generator.  The lane-refill half of that design now
exists — `core/compaction.py` `FrontierScheduler` retires finished LPs
mid-batch and admits new ones into the freed lanes (its `source`/`sink`
protocol is the intended service admission API; `core/branch_bound.py`
``mode="stream"`` is its first production consumer) — but the async
driver, shape-class bucketing, and latency reporting remain unbuilt.
"""
import subprocess
import sys

subprocess.run([
    sys.executable, "-m", "repro.launch.serve",
    "--arch", "hymba-1.5b", "--reduced",
    "--batch", "4", "--prompt-len", "32", "--gen", "16", "--requests", "2",
], check=True)
