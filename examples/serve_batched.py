"""Batched LP solve service, minimal loop: a stream of perturbed fixture
batches solved with the telemetry plane on, reported as a per-wave
p50/p99 latency + solves/sec table derived from each wave's SolveReport.

    PYTHONPATH=src python examples/serve_batched.py [--fixture afiro]
        [--waves 4] [--batch 16] [--backend tableau] [--trace out.json]

This is the first concrete step on the ROADMAP item "Streaming solve
service: continuous batching over shape classes".  What exists here: a
synchronous wave loop over one shape class — each wave is a perturbed
re-solve of the fixture (the MPC/branch-and-bound repeated-solve
workload), warm-started from the previous wave's terminal state, solved
through the compaction scheduler with telemetry on, and summarized from
``LPResult.stats`` (``repro.obs.SolveReport``).  Still unbuilt: the async
admission loop (``FrontierScheduler``'s source/sink protocol is the
intended API), heterogeneous shape-class bucketing, and a Poisson load
generator.

``--trace`` additionally writes a Chrome/Perfetto trace-event JSON of the
last wave's span tree (canonicalize -> dispatch -> segment k -> bucket
gathers) — load it at https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import OPTIMAL, solve_batched, solve_batched_compacted
from repro.io.mps import fixture_path, perturbed_sequence, read_mps
from repro.obs import SpanTracer


def serve(fixture: str = "afiro", waves: int = 4, batch: int = 16,
          backend: str = "tableau", trace: str | None = None,
          seed: int = 0) -> list:
    g = read_mps(fixture_path(fixture))
    stream = perturbed_sequence(g, batch, waves, np.random.default_rng(seed))
    print(f"serving {waves} waves of {batch} perturbed {fixture!r} LPs "
          f"({g.m}x{g.n}) on the {backend!r} engine\n")
    header = (f"{'wave':>4}  {'B':>4}  {'optimal':>7}  {'iters p50':>9}  "
              f"{'iters p99':>9}  {'lat p50':>9}  {'lat p99':>9}  "
              f"{'solves/s':>8}")
    print(header)
    print("-" * len(header))
    rows = []
    warm = None
    tracer = None
    for k, gb in enumerate(stream):
        # monolithic chunked driver: captures terminal state, so each wave
        # warm-starts from the previous one (the repeated-solve win)
        res = solve_batched(gb, backend=backend, warm=warm, telemetry=True)
        warm = res.warm
        rep = res.stats
        # per-LP latency model: the wave's wall-clock prorated by each LP's
        # share of the executed iterations (lockstep lanes finish together;
        # what differs per LP is how much work it contributed)
        iters = rep.iterations.astype(np.float64)
        if iters.sum() > 0:
            lat = rep.wall_s * iters / iters.sum()
        else:  # warm starts can re-solve the whole wave in zero pivots
            lat = np.full_like(iters, rep.wall_s / max(len(iters), 1))
        row = {
            "wave": k, "B": rep.batch_size,
            "optimal": int((np.asarray(res.status) == OPTIMAL).sum()),
            "iters_p50": float(np.percentile(iters, 50)),
            "iters_p99": float(np.percentile(iters, 99)),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "solves_per_sec": rep.summary().get("solves_per_sec", 0.0),
        }
        rows.append(row)
        print(f"{row['wave']:>4}  {row['B']:>4}  {row['optimal']:>7}  "
              f"{row['iters_p50']:>9.0f}  {row['iters_p99']:>9.0f}  "
              f"{row['latency_p50_s'] * 1e3:>7.2f}ms  "
              f"{row['latency_p99_s'] * 1e3:>7.2f}ms  "
              f"{row['solves_per_sec']:>8.1f}")
    total_lps = sum(r["B"] for r in rows)
    total_wall = sum(r["B"] / r["solves_per_sec"] for r in rows
                     if r["solves_per_sec"])
    if total_wall:
        print(f"\n{total_lps} LPs in {total_wall:.3f}s "
              f"({total_lps / total_wall:.1f} solves/s sustained)")
    if trace is not None:
        # one compacted multi-segment re-solve of the final wave with the
        # span tracer on — the documented way to get a Perfetto trace
        tracer = SpanTracer()
        solve_batched_compacted(stream[-1], backend=backend, telemetry=True,
                                tracer=tracer)
        tracer.to_perfetto(trace)
        print(f"wrote Perfetto trace of a compacted {fixture!r} solve to "
              f"{trace} (open at https://ui.perfetto.dev)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fixture", default="afiro")
    ap.add_argument("--waves", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--backend", default="tableau",
                    choices=("tableau", "revised", "pdhg"))
    ap.add_argument("--trace", default=None,
                    help="write a Perfetto trace JSON of the last wave")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(fixture=args.fixture, waves=args.waves, batch=args.batch,
          backend=args.backend, trace=args.trace, seed=args.seed)


if __name__ == "__main__":
    main()
