"""Batched serving example (prefill + decode waves with KV-cache reuse).

    PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys

subprocess.run([
    sys.executable, "-m", "repro.launch.serve",
    "--arch", "hymba-1.5b", "--reduced",
    "--batch", "4", "--prompt-len", "32", "--gen", "16", "--requests", "2",
], check=True)
