"""End-to-end training example: a ~9M-param qwen3-family model on synthetic
Markov data, with checkpoints, watchdog and loss curve. For the ~100M-param
run documented in EXPERIMENTS.md use --d-model 512 --n-layers 12
--d-ff 2048 --vocab 32000 (slower on CPU).

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

subprocess.run([
    sys.executable, "-m", "repro.launch.train",
    "--arch", "qwen3-32b", "--reduced",
    "--d-model", "256", "--n-layers", "8", "--d-ff", "1024",
    "--vocab", "2048",
    "--steps", "300", "--batch", "4", "--seq", "128",
    "--checkpoint-dir", "artifacts/train_lm_ckpt",
    "--curve-out", "artifacts/train_lm_loss.csv",
    "--log-every", "20",
], check=True)
