"""Batched branch-and-bound: solve small MIPs as warm-started LP frontiers.

    PYTHONPATH=src python examples/branch_bound.py

A branch-and-bound tree is the batched-LP workload the paper's thesis was
waiting for: every node is the root relaxation with a handful of variable
bounds tightened, so a frontier of open nodes shares one canonical shape
and solves as ONE device dispatch (core/branch_bound.py).  The driver

* keeps the frontier as a single bound-edited batch
  (``forms.rebind_bounds``: the root's canonical A/c/scales are frozen,
  only rhs/shift recompute),
* warm-starts every child from its parent's terminal basis — a child
  differs by one bound, so it typically re-solves in a couple of pivots,
* fathoms on per-LP status/bound/incumbent; with the PDHG backend the
  relaxation objective is only ~tol-accurate, so pruning goes through the
  ``safe_dual_bound`` certificate pass instead (valid for ANY duals).

This demo runs the three vendored MIP fixtures (tests/fixtures/README.md)
through the exact engines and PDHG, then A/Bs warm vs cold frontiers.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import OPTIMAL, branch_and_bound
from repro.io.mps import MIP_FIXTURE_NAMES, fixture_path, read_mps


def main():
    print("=== 1. the three MIP fixtures, every backend =================")
    for name in MIP_FIXTURE_NAMES:
        g = read_mps(fixture_path(name))
        n_int = int(g.integer.sum())
        print(f"\n{name}: m={g.m} n={g.n} ({n_int} integer columns), "
              f"{'max' if g.maximize else 'min'}")
        for backend in ("tableau", "revised", "pdhg"):
            t0 = time.perf_counter()
            res = branch_and_bound(g, backend=backend, frontier=8)
            dt = time.perf_counter() - t0
            assert res.status == OPTIMAL and res.proven, res
            print(f"  {backend:8s} objective={res.objective:10.4f}  "
                  f"nodes={res.nodes:3d}  dispatches={res.dispatches:2d}  "
                  f"lp_iters={res.lp_iterations:6d}  [{dt:.2f}s]")

    print("\n=== 2. warm vs cold frontiers (the tentpole payoff) =========")
    for name in ("knapsack", "scheduling"):
        g = read_mps(fixture_path(name))
        warm = branch_and_bound(g, backend="tableau", frontier=8)
        cold = branch_and_bound(g, backend="tableau", frontier=8,
                                warm_start=False)
        assert warm.objective == cold.objective
        ratio = warm.lp_iterations / max(1, cold.lp_iterations)
        print(f"  {name:10s} warm={warm.lp_iterations:4d} pivots  "
              f"cold={cold.lp_iterations:4d} pivots  "
              f"(x{ratio:.2f} of cold, same {warm.nodes}-node tree)")

    print("\n=== 3. streaming frontier (continuous batching) =============")
    g = read_mps(fixture_path("scheduling"))
    res = branch_and_bound(g, backend="tableau", mode="stream",
                           frontier=8, lanes=8)
    assert res.proven
    print(f"  scheduling via FrontierScheduler lanes=8: "
          f"objective={res.objective:.4f} nodes={res.nodes} "
          f"lp_iters={res.lp_iterations}")
    print("  (fathomed nodes retire mid-batch; children refill freed "
          "lanes\n   without draining the device batch)")


if __name__ == "__main__":
    main()
