"""Paper Table 6: Netlib-like problems, batched device solve vs sequential
CPU (GLPK/CPLEX stand-in = float64 NumPy simplex)."""
from repro.core import random_sparse_lp_batch, solve_batched_jax, \
    solve_batched_reference

from .common import NETLIB_LIKE, RNG, emit, timeit


def run(batches=(1, 10, 100, 1000), problems=NETLIB_LIKE, seq_cap: int = 50):
    rows = []
    for name, m, n in problems:
        for B in batches:
            lps = random_sparse_lp_batch(RNG, B=B, m=m, n=n, density=0.1)
            t_jax = timeit(lambda: solve_batched_jax(lps), iters=2)
            Bs = min(B, seq_cap)
            sub = random_sparse_lp_batch(RNG, B=Bs, m=m, n=n, density=0.1)
            t_seq = timeit(lambda: solve_batched_reference(sub), warmup=0,
                           iters=1) * (B / Bs)
            emit(f"table6/{name}_batch{B}", t_jax,
                 f"seq={t_seq:.4f}s;speedup={t_seq / t_jax:.2f}x")
            rows.append((name, B, t_seq, t_jax))
    return rows
