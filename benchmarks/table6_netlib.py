"""Paper Table 6: real Netlib-class instances, batched device solve vs
sequential CPU (GLPK/CPLEX stand-in = float64 NumPy simplex).

Runs on the vendored general-form MPS fixtures (``tests/fixtures/``; AFIRO
reproduces the published Netlib optimum exactly — see the fixtures README
for provenance), batch-expanded by multiplicative perturbation the way the
paper builds its Netlib batches (Sec. 6).  For each fixture x batch size:

* both device engines (tableau and revised) solve the batch in f32 and are
  checked against the float64 oracle *after recovery to original
  coordinates* (status parity + relative objective error + original-space
  feasibility certificate);
* the sequential-CPU side is the float64 oracle on a capped subset,
  extrapolated — the paper's Table-6 speedup methodology;
* the presolve-scaling A/B records how geometric-mean equilibration
  changes f32 iteration counts / accuracy per fixture (the paper's Sec. 6
  f32-accuracy concern; on the deliberately ill-scaled SC50B-class
  staircase the unscaled f32 solve fails outright).
"""
import dataclasses

from repro.core import solve_batched_jax, solve_batched_reference
from repro.io.mps import fixture_path, perturbed_batch, read_mps

from .common import RNG, emit, oracle_checks, timeit

FIXTURES = ("afiro", "sc50b_like")


def _head(g, k: int):
    """Leading k members of a GeneralLPBatch (shared structure, sliced
    numeric data)."""
    return dataclasses.replace(
        g, A=g.A[:k], rhs=g.rhs[:k], lb=g.lb[:k], ub=g.ub[:k], c=g.c[:k],
        c0=g.c0[:k])


def run(batches=(1, 10, 100, 1000), fixtures=FIXTURES, seq_cap: int = 50):
    rows = []
    for name in fixtures:
        g1 = read_mps(fixture_path(name))
        for B in batches:
            lps = perturbed_batch(g1, B, RNG)
            t_jax = timeit(lambda: solve_batched_jax(lps), iters=2)

            # sequential-CPU side: float64 oracle on the leading slice,
            # extrapolated (the paper's Table-6 methodology) — the same
            # slice is the post-recovery correctness reference, so the
            # per-backend check solves only the slice, not the full batch
            Bs = min(B, seq_cap)
            sub = _head(lps, Bs)
            ref = solve_batched_reference(sub)
            t_seq = timeit(lambda: solve_batched_reference(sub), warmup=0,
                           iters=1) * (B / Bs)

            checks = {
                backend: oracle_checks(
                    sub, solve_batched_jax(sub, backend=backend), ref)
                for backend in ("tableau", "revised")
            }
            emit(f"table6/{name}_batch{B}", t_jax,
                 f"seq={t_seq:.4f}s;speedup={t_seq / t_jax:.2f}x;"
                 f"tab_err={checks['tableau']['rel_obj_err']:.1e};"
                 f"rev_err={checks['revised']['rel_obj_err']:.1e}")
            rows.append((name, B, t_seq, t_jax, checks))

        # presolve-scaling A/B (single instance, f32): the Sec.-6 accuracy
        # story measured rather than asserted
        scaled = solve_batched_jax(g1, scale=True)
        raw = solve_batched_jax(g1, scale=False)
        emit(f"table6/{name}_scaling_ab", 0.0,
             f"scaled:status={int(scaled.status[0])},"
             f"iters={int(scaled.iterations[0])};"
             f"unscaled:status={int(raw.status[0])},"
             f"iters={int(raw.iterations[0])}")
    return rows
