"""Executed-pivot-work benchmark: lockstep vs phase-compacted vs
compaction-scheduled batched simplex (the two-level work-elimination engine),
now crossed with the pluggable pricing engine.

For each Table-2 size (mixed feasible/infeasible batches, half needing
phase 1) this measures, per solver:

* executed lockstep steps,
* executed tableau-element updates (steps x occupied batch slots x tableau
  elements — the work unit of analysis/lp_perf.py; phase-compacted steps
  count the (m+1)(n+m+1) tableau, full steps the (m+2)(n+2m+1) one),
* wall-clock (median over post-compile runs),

and checks that all three solvers return *identical* statuses (they execute
identical pivot sequences; only dead work differs).

On top of that, a per-rule section runs the full two-level engine under each
pricing rule (core/pricing.py: dantzig / steepest_edge / devex) and records
per-LP executed pivots, element updates, wall-clock, and that every rule
agrees with Dantzig on statuses (rules change the path, never the
certificate).

A per-backend section (``workloads[].backends``, ``--backend`` selects)
crosses the tableau engine with the revised-simplex engine
(core/revised.py, dantzig + partial pricing): executed pivots, wall-clock,
tableau-element-equivalent updates (`revised_elements` — state written per
pivot, the unit the tableau's rank-1 update is charged in) and the honest
flops model (`analysis.lp_perf.revised_pivot_flops`, where the dense-square
tableau still wins and the crossover sits at n/m ~ 2-4), plus a
statuses-match check against the tableau engine.

A ``general_workloads`` section exercises the general-form pipeline on the
vendored real-instance MPS fixtures (io/mps.py + core/forms.py): each
fixture is batch-expanded by perturbation, solved by both engines in f32,
and compared against the float64 oracle *after recovery to original
coordinates* — plus a scaled-vs-unscaled f32 A/B that records whether
presolve equilibration changes iteration counts or statuses (it flips the
ill-scaled SC50B-class staircase from failing to solving).  These rows are
identical in --quick and full runs so scripts/bench_gate.py can gate status
regressions on real instances.

A ``sparse_workloads`` section A/Bs the shared-pattern sparse PDHG engine
(core/sparse.py) against the dense one on the staircase fixtures: the same
canonical LPs, one COO pattern across the batch, statuses/objectives
required to agree (same algorithm — only the matvecs change), and the
per-iteration element traffic recorded as the dense/sparse ratio
(~1/density) that scripts/bench_gate.py holds a floor under.

A ``warm_workloads`` section measures the warm-start engine (core/lp.py
WarmStart): a ``perturbed_sequence`` trajectory per fixture is re-solved
cold and warm-chained per engine, and the per-re-solve work ratio
(warm/cold mean iterations), status agreement and objective error are
recorded — scripts/bench_gate.py holds the ratio under 0.5 (a warm
re-solve must cost at most half a cold one) on top of the usual
baseline-relative bound.

A ``bnb_workloads`` section measures the branch-and-bound driver
(core/branch_bound.py) on the MIP fixtures: the same tree solved with
warm-started frontiers vs cold, per exact engine — recorded are the proven
objective, node/dispatch counts, total LP iterations both ways and their
``work_ratio`` (warm/cold).  scripts/bench_gate.py requires proven
optimality, an unchanged objective, and warm frontiers beating cold
(ratio < 1.0 hard, plus the baseline-relative bound).

A ``pallas_workloads`` section A/Bs the Pallas tile kernels
(src/repro/kernels/, interpret=True on this CPU environment) against their
JAX engines on small mixed batches: the tableau and revised kernels must
reproduce engine statuses *and* iteration counts exactly (they execute the
same pivot sequences), the PDHG kernel to tolerance; each kernel also runs
under the compaction scheduler (segment kernels + bucket gathers) with the
executed element traffic and bucket-shrink count recorded —
scripts/bench_gate.py holds a status floor and an element-traffic ceiling
per kernel row.  Wall-clock is recorded but informational only: these are
interpreter runs, not TPU timings.

The ``pdhg`` row additionally carries a ``malitsky_pock`` sub-row: the
adaptive-step-size rule (``step_rule="malitsky_pock"``) on the same
adversarial dense workload, recording the iteration cut vs the fixed-step
rule (statuses must keep agreeing — the rule changes the trajectory, not
the certificate).

Results land in ``BENCH_pivot_work.json`` next to this file so future PRs
have a perf trajectory to beat; a ``quick_workloads`` section re-runs the
--quick configuration (B=128) so scripts/bench_gate.py can diff a CI smoke
run against the committed baseline on exactly matching workloads.

  PYTHONPATH=src python -m benchmarks.pivot_work [--quick] [--out PATH]
                                                 [--backend tableau|revised|all]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.analysis.lp_perf import (pdhg_iteration_flops, revised_pivot_flops,
                                    tableau_pivot_flops)
from repro.core import (LPBatch, OPTIMAL, pdhg_elements, random_lp_batch,
                        revised_elements, solve_batched_compacted,
                        solve_batched_jax, solve_batched_pdhg,
                        solve_batched_pdhg_compacted, solve_batched_revised,
                        solve_batched_revised_compacted)
from repro.core.compaction import auto_segment_k, total_elements, total_steps
from repro.core.lp import default_max_iters
from repro.core.pricing import PRICING_RULES
from repro.core.simplex import tableau_elements
from repro.obs.work import element_updates_lockstep, lockstep_steps

try:  # package and direct-script execution
    from .common import timeit
except ImportError:  # pragma: no cover
    from common import timeit

SIZES = ((5, 5), (10, 10), (28, 28), (50, 50), (100, 100))
QUICK_SIZES = ((5, 5), (28, 28))
GENERAL_FIXTURES = ("afiro", "sc50b_like")
SPARSE_FIXTURES = ("sc50b_like", "sc205_like")   # staircases: shared pattern
GENERAL_B = 32      # same in --quick and full runs: the gate matches on it
WARM_FIXTURES = ("afiro", "sc50b_like")  # same in both modes (gate keys on
WARM_B = 16                              # fixture/B/K); sc205 would push the
WARM_K = 4                               # smoke past its minute budget
BNB_FIXTURES = ("knapsack", "scheduling")  # assignment is root-integral
BNB_FRONTIER = 8                           # (1 node): nothing to A/B there
PALLAS_SIZES = ((5, 5), (12, 8))  # interpreter-sized: the kernels run on
PALLAS_B = 48                     # the Pallas CPU interpreter here, so the
PALLAS_TILE_B = 8                 # rows stay minutes, not hours


def mixed_batch(m: int, n: int, B: int, seed: int = 0) -> LPBatch:
    """Half feasible-start, half phase-1 LPs, shuffled — the workload where
    lockstep waste is worst (paper Table 4 mixed with Table 2)."""
    rng = np.random.default_rng(seed)
    half = B // 2
    b1 = random_lp_batch(rng, half, m, n, feasible_start=True)
    b2 = random_lp_batch(rng, B - half, m, n, feasible_start=False)
    batch = LPBatch(A=np.concatenate([b1.A, b2.A]),
                    b=np.concatenate([b1.b, b2.b]),
                    c=np.concatenate([b1.c, b2.c]))
    order = rng.permutation(B)
    return LPBatch(A=batch.A[order], b=batch.b[order], c=batch.c[order])


def measure_backends(batch: LPBatch, sched, segment_k: int, iters: int) -> dict:
    """Per-backend rows: the revised engine (dantzig + partial pricing) vs
    the tableau engine, monolithic and through the compaction scheduler.
    ``sched`` is the tableau engine's compaction-scheduled result (the
    statuses-match reference).

    On CPU the revised engine's triangular/eta solves are latency-bound
    (hundreds of tiny ops per lockstep step), so at the Table-2 tail the
    measured rows use a leading slice of the same workload (``B`` in the
    row records it): statuses are compared against the tableau result on
    that slice, and the element-reduction stays honest because the
    executed-work unit is per pivot — the tableau side is re-quantified on
    the identical slice."""
    m, n = batch.m, batch.n
    B = batch.batch
    # full batch through 28x28; 512 at 50x50; 256 at 100x100+
    B_rev = min(B, 512 if m < 100 else 256) if m >= 50 else B
    sub = LPBatch(A=np.asarray(batch.A)[:B_rev],
                  b=np.asarray(batch.b)[:B_rev],
                  c=np.asarray(batch.c)[:B_rev])
    tab_status = np.asarray(sched.status)[:B_rev]
    tab_iters = np.asarray(sched.iterations)[:B_rev].astype(np.int64)
    out = {
        "tableau": {
            "pivots_mean": float(sched.iterations.mean()),
            "elements_per_pivot": tableau_elements(m, n, compacted=True),
            "flops_per_pivot": tableau_pivot_flops(m, n, compacted=True),
            "statuses_match_tableau": True,
        }
    }
    for rule in ("dantzig", "partial"):
        partial = rule == "partial"
        res = solve_batched_revised(sub, pricing=rule)
        wall = timeit(lambda: solve_batched_revised(sub, pricing=rule),
                      warmup=0, iters=iters)
        stats = []
        res_sched = solve_batched_revised_compacted(
            sub, segment_k=segment_k, pricing=rule, stats_out=stats)
        steps = lockstep_steps(res.iterations)
        per_pivot = revised_elements(m, n, partial=partial)
        out[f"revised_{rule}"] = {
            "B": B_rev,
            "pivots_mean": float(res.iterations.astype(np.int64).mean()),
            "pivots_max": int(res.iterations.max()),
            "elements_per_pivot": per_pivot,
            "flops_per_pivot": revised_pivot_flops(m, n, partial=partial),
            "elements_lockstep": int(steps * B_rev * per_pivot),
            "elements_scheduled": int(total_elements(stats)),
            "wall_s": wall,
            "statuses_match_tableau": bool(
                np.array_equal(res.status, tab_status)),
            "scheduled_statuses_match": bool(
                np.array_equal(res_sched.status, tab_status)),
        }
        # tableau-element-equivalent reduction at matching (lockstep)
        # granularity on the identical LP slice: steps x slots x per-pivot
        out[f"revised_{rule}"]["element_reduction_vs_tableau"] = (
            element_updates_lockstep(tab_iters, m, n)
            / max(1, out[f"revised_{rule}"]["elements_lockstep"]))
    return out


def measure_general(fixture: str, B: int = GENERAL_B, *, iters: int = 1,
                    seed: int = 0, backends: str = "all") -> dict:
    """One fixture-backed general-form workload row: canonical-shape
    accounting, the selected f32 engines vs the float64 oracle after
    recovery (status parity + objective error + original-space
    feasibility), and the scaled-vs-unscaled f32 A/B on the source
    instance.  ``backends`` mirrors the CLI flag so a per-engine CI leg
    measures only its own engine."""
    from repro.analysis.lp_perf import canonical_work
    from repro.core import solve_batched_jax, solve_batched_reference
    from repro.io.mps import fixture_path, perturbed_batch, read_mps

    try:
        from .common import oracle_checks
    except ImportError:  # pragma: no cover - direct-script execution
        from common import oracle_checks

    g1 = read_mps(fixture_path(fixture))
    batch = perturbed_batch(g1, B, np.random.default_rng(seed))
    shapes = canonical_work(g1)
    ref = solve_batched_reference(batch)
    row = {
        "fixture": fixture, "B": B,
        "m": g1.m, "n": g1.n,
        "m_canonical": shapes["m_canonical"],
        "n_canonical": shapes["n_canonical"],
        "revised_wins_flops_canonical": shapes["revised_wins_flops"],
        "oracle_pivots_mean": float(ref.iterations.mean()),
        "backends": {},
    }
    engines = (("tableau", "revised", "pdhg") if backends == "all"
               else (backends,))
    for backend in engines:
        res = solve_batched_jax(batch, backend=backend)
        wall = timeit(lambda: solve_batched_jax(batch, backend=backend),
                      warmup=0, iters=iters)
        row["backends"][backend] = dict(
            oracle_checks(batch, res, ref),
            pivots_mean=float(res.iterations.astype(np.int64).mean()),
            wall_s=wall)
    # scaling A/B on the single source instance (deterministic)
    scaled = solve_batched_jax(g1, scale=True)
    raw = solve_batched_jax(g1, scale=False)
    row["scaling"] = {
        "scaled_status": int(scaled.status[0]),
        "scaled_iters": int(scaled.iterations[0]),
        "unscaled_status": int(raw.status[0]),
        "unscaled_iters": int(raw.iterations[0]),
        "changes_f32": bool(scaled.status[0] != raw.status[0]
                            or scaled.iterations[0] != raw.iterations[0]),
    }
    return row


def measure_sparse(fixture: str, B: int = GENERAL_B, *, iters: int = 1,
                   seed: int = 0) -> dict:
    """Shared-pattern sparse PDHG vs the dense engine on one staircase
    fixture batch: identical canonical LPs (one COO pattern shared across
    the batch, per-LP values), so statuses and objectives must agree up to
    float-sum association — the measurable difference is per-iteration
    element traffic, which the sparse path pays in nnz instead of m*n."""
    from repro.analysis.lp_perf import sparse_pdhg_iteration_flops
    from repro.core import (SparseLPBatch, canonicalize,
                            solve_batched_pdhg_sparse, sparse_pdhg_elements)
    from repro.io.mps import fixture_path, perturbed_batch, read_mps

    g = read_mps(fixture_path(fixture))
    gb = perturbed_batch(g, B, np.random.default_rng(seed))
    batch, _ = canonicalize(gb)
    sp = SparseLPBatch.from_dense(batch)
    m, n, nnz = sp.m, sp.n, sp.nnz
    dense = solve_batched_pdhg(batch)
    t_dense = timeit(lambda: solve_batched_pdhg(batch), warmup=0, iters=iters)
    sparse = solve_batched_pdhg_sparse(sp)
    t_sparse = timeit(lambda: solve_batched_pdhg_sparse(sp), warmup=0,
                      iters=iters)
    ok = (np.asarray(dense.status) == OPTIMAL) \
        & (np.asarray(sparse.status) == OPTIMAL)
    rel = (np.abs(sparse.objective[ok] - dense.objective[ok])
           / np.maximum(np.abs(dense.objective[ok]), 1e-12)).max() \
        if ok.any() else 0.0
    return {
        "fixture": fixture, "B": B, "m": m, "n": n, "nnz": nnz,
        "density": nnz / max(1, m * n),
        "elements_per_iter_dense": pdhg_elements(m, n),
        "elements_per_iter_sparse": sparse_pdhg_elements(nnz, m, n),
        "element_traffic_ratio":
            pdhg_elements(m, n) / sparse_pdhg_elements(nnz, m, n),
        "flops_per_iter_sparse": sparse_pdhg_iteration_flops(nnz, m, n),
        "iters_mean_dense": float(dense.iterations.astype(np.int64).mean()),
        "iters_mean_sparse": float(sparse.iterations.astype(np.int64).mean()),
        "status_match_dense_frac": float(
            (np.asarray(sparse.status) == np.asarray(dense.status)).mean()),
        "rel_obj_err_vs_dense": float(rel),
        "wall_s_dense": t_dense,
        "wall_s_sparse": t_sparse,
    }


def measure_warm(fixture: str, B: int = WARM_B, K: int = WARM_K, *,
                 seed: int = 0, backends: str = "all") -> dict:
    """Warm-start engine row: a ``perturbed_sequence`` trajectory (K nudged
    copies of one fixture batch, the repeated-solve workload from the
    reachability pipeline) solved cold at every step and warm-chained from
    the previous step's terminal state (``res.warm_start()``).  Records, per
    engine, the mean re-solve iteration counts cold vs warm, their ratio
    (``work_ratio`` — scripts/bench_gate.py holds this under 0.5: a warm
    re-solve must cost at most half a cold one), the cold-vs-warm status
    agreement, and the objective error on commonly-OPTIMAL LPs.  Step 0 is
    excluded from the means (both paths solve it cold — it only seeds the
    chain)."""
    from repro.core import solve_batched
    from repro.io.mps import fixture_path, perturbed_sequence, read_mps

    g = read_mps(fixture_path(fixture))
    seq = perturbed_sequence(g, B, K, np.random.default_rng(seed))
    engines = (("tableau", "revised", "pdhg") if backends == "all"
               else (backends,))
    row = {"fixture": fixture, "B": B, "K": K, "backends": {}}
    for backend in engines:
        cold_iters, warm_iters, match, errs = [], [], [], []
        ws = None
        for k, gb in enumerate(seq):
            cold = solve_batched(gb, backend=backend)
            if k > 0:
                warm = solve_batched(gb, backend=backend, warm=ws)
                cold_iters.append(np.asarray(cold.iterations, np.int64))
                warm_iters.append(np.asarray(warm.iterations, np.int64))
                match.append(np.asarray(warm.status)
                             == np.asarray(cold.status))
                ok = (np.asarray(cold.status) == OPTIMAL) \
                    & (np.asarray(warm.status) == OPTIMAL)
                if ok.any():
                    errs.append(float(
                        (np.abs(warm.objective[ok] - cold.objective[ok])
                         / np.maximum(np.abs(cold.objective[ok]),
                                      1e-12)).max()))
                ws = warm.warm_start()  # chain from the warm trajectory
            else:
                ws = cold.warm_start()
        cold_mean = float(np.concatenate(cold_iters).mean())
        warm_mean = float(np.concatenate(warm_iters).mean())
        row["backends"][backend] = {
            "cold_iters_mean": cold_mean,
            "warm_iters_mean": warm_mean,
            "work_ratio": warm_mean / max(cold_mean, 1e-12),
            "status_match_frac": float(np.concatenate(match).mean()),
            "rel_obj_err": float(max(errs)) if errs else 0.0,
        }
    return row


def measure_bnb(fixture: str, *, frontier: int = BNB_FRONTIER,
                backends: str = "all") -> dict:
    """Branch-and-bound row: the same MIP tree driven with warm-started
    frontiers and cold ones, per exact simplex engine.  Warm and cold runs
    fathom identically (same relaxation optima), so nodes match and the
    total-LP-iteration ``work_ratio`` isolates what parent-basis reuse
    saves across the tree.  PDHG is skipped — its iteration counts are not
    pivot work and its tree can differ (weaker safe bounds)."""
    from repro.core import branch_and_bound
    from repro.io.mps import fixture_path, read_mps

    g = read_mps(fixture_path(fixture))
    engines = [b for b in ("tableau", "revised")
               if backends in ("all", b)]
    row = {"fixture": fixture, "frontier": frontier, "backends": {}}
    for backend in engines:
        warm = branch_and_bound(g, backend=backend, frontier=frontier)
        wall = timeit(lambda: branch_and_bound(g, backend=backend,
                                               frontier=frontier),
                      warmup=0, iters=1)
        cold = branch_and_bound(g, backend=backend, frontier=frontier,
                                warm_start=False)
        row["backends"][backend] = {
            "objective": float(warm.objective),
            "proven": bool(warm.proven and cold.proven),
            "objective_match": bool(
                abs(warm.objective - cold.objective)
                <= 1e-6 * max(1.0, abs(cold.objective))),
            "nodes": int(warm.nodes),
            "nodes_cold": int(cold.nodes),
            "dispatches": int(warm.dispatches),
            "warm_lp_iters": int(warm.lp_iterations),
            "cold_lp_iters": int(cold.lp_iterations),
            "work_ratio": warm.lp_iterations / max(cold.lp_iterations, 1),
            "wall_s": wall,
        }
    return row


def measure_pallas(m: int, n: int, B: int = PALLAS_B, *,
                   tile_b: int = PALLAS_TILE_B, seed: int = 0,
                   backends: str = "all") -> dict:
    """One Pallas-kernel workload row: every selected tile kernel vs its
    JAX engine on the same mixed batch, monolithic and through the
    compaction scheduler (segment kernels with bucket gathers between
    launches).  The simplex kernels are pivot-exact — statuses and
    iteration counts must equal the engine's; PDHG agrees to ~tol (a
    different XLA compilation of the same rounds).  ``elements_scheduled``
    is the executed element traffic of the scheduled kernel run (the
    bench_gate ceiling); ``wall_s`` is an interpreter time, recorded for
    trend only."""
    from repro.kernels import solve_batched_pallas

    batch = mixed_batch(m, n, B, seed)
    engines = {
        "tableau": solve_batched_jax,
        "revised": solve_batched_revised,
        "pdhg": solve_batched_pdhg,
    }
    names = tuple(engines) if backends == "all" else (backends,)
    row = {"m": m, "n": n, "B": B, "tile_b": tile_b, "kernels": {}}
    for name in names:
        ref = engines[name](batch)
        t0 = time.time()
        pal = solve_batched_pallas(batch, backend=name, tile_b=tile_b)
        wall = time.time() - t0
        stats = []
        pal_sched = solve_batched_pallas(batch, backend=name, tile_b=tile_b,
                                         compaction=True, segment_k=6,
                                         stats_out=stats)
        ok = (np.asarray(ref.status) == OPTIMAL) \
            & (np.asarray(pal.status) == OPTIMAL)
        rel = (np.abs(pal.objective[ok] - ref.objective[ok])
               / np.maximum(np.abs(ref.objective[ok]), 1e-12)).max() \
            if ok.any() else 0.0
        buckets = [s.bucket for s in stats]
        row["kernels"][name] = {
            "status_match_engine_frac": float(
                (np.asarray(pal.status) == np.asarray(ref.status)).mean()),
            "iters_match_engine": bool(np.array_equal(
                np.asarray(pal.iterations), np.asarray(ref.iterations))),
            "rel_obj_err_vs_engine": float(rel),
            "segments": len(stats),
            "elements_scheduled": int(total_elements(stats)),
            "bucket_shrunk": bool(buckets and min(buckets) < max(buckets)),
            "scheduled_status_match_frac": float(
                (np.asarray(pal_sched.status)
                 == np.asarray(ref.status)).mean()),
            "wall_s_interpret": wall,
        }
    return row


def measure_pdhg(batch: LPBatch, sched, iters: int) -> dict:
    """The first-order engine's workload row: tolerance-based agreement
    with the (exact) tableau engine on statuses and objectives, iteration
    counts, honest flops per iteration, and the compaction round-trip
    (scheduled pdhg must agree with monolithic pdhg — gathers never touch
    an LP's own iterates).  Measured on a leading slice like the revised
    rows (PDHG runs thousands of per-LP iterations; the slice keeps the
    bench minutes bounded while the metrics stay per-LP)."""
    m, n = batch.m, batch.n
    B = batch.batch
    B_pdhg = min(B, 128 if m < 50 else 64)
    sub = LPBatch(A=np.asarray(batch.A)[:B_pdhg],
                  b=np.asarray(batch.b)[:B_pdhg],
                  c=np.asarray(batch.c)[:B_pdhg])
    tab_status = np.asarray(sched.status)[:B_pdhg]
    tab_obj = np.asarray(sched.objective)[:B_pdhg]
    res = solve_batched_pdhg(sub)
    wall = timeit(lambda: solve_batched_pdhg(sub), warmup=0, iters=iters)
    stats = []
    res_sched = solve_batched_pdhg_compacted(sub, stats_out=stats)
    it = res.iterations.astype(np.int64)
    ok = (res.status == OPTIMAL) & (tab_status == OPTIMAL)
    rel = (np.abs(res.objective[ok] - tab_obj[ok])
           / np.maximum(np.abs(tab_obj[ok]), 1e-12)).max() if ok.any() else 0.0
    # adaptive-step-size A/B on the same adversarial dense workload: the
    # Malitsky-Pock linesearch must cut iterations without moving statuses
    mp = solve_batched_pdhg(sub, step_rule="malitsky_pock")
    mp_it = mp.iterations.astype(np.int64)
    mp_ok = (res.status == OPTIMAL) & (mp.status == OPTIMAL)
    mp_rel = (np.abs(mp.objective[mp_ok] - res.objective[mp_ok])
              / np.maximum(np.abs(res.objective[mp_ok]), 1e-12)).max() \
        if mp_ok.any() else 0.0
    mp_row = {
        "iters_mean": float(mp_it.mean()),
        "iters_cut_vs_fixed": 1.0 - float(mp_it.mean()) / max(
            float(it.mean()), 1e-12),
        "status_match_fixed_frac": float((mp.status == res.status).mean()),
        "rel_obj_err_vs_fixed": float(mp_rel),
    }
    return {
        "B": B_pdhg,
        "iters_mean": float(it.mean()),
        "iters_max": int(it.max()),
        "flops_per_iter": pdhg_iteration_flops(m, n),
        "elements_per_iter": pdhg_elements(m, n),
        "elements_scheduled": int(total_elements(stats)),
        "wall_s": wall,
        "status_match_tableau_frac": float(
            (res.status == tab_status).mean()),
        "rel_obj_err_vs_tableau": float(rel),
        "scheduled_status_match_frac": float(
            (res_sched.status == res.status).mean()),
        "malitsky_pock": mp_row,
    }


def measure(m: int, n: int, B: int, *, segment_k: int | None = None,
            compact_threshold: float = 0.5, iters: int = 2,
            seed: int = 0, backends: str = "all") -> dict:
    batch = mixed_batch(m, n, B, seed)
    max_iters = default_max_iters(m, n)
    if segment_k is None:
        segment_k = auto_segment_k(m, n)  # the compaction auto-tune heuristic

    # --- seed lockstep (single combined loop, full tableau throughout) ------
    lock = solve_batched_jax(batch, phase_compaction=False)
    t_lock = timeit(lambda: solve_batched_jax(batch, phase_compaction=False),
                    warmup=0, iters=iters)  # first call above was the warmup
    piv = lock.iterations.astype(np.int64)
    steps_lock = lockstep_steps(piv)
    elems_lock = element_updates_lockstep(piv, m, n)

    # --- Level 1: phase-compacted two-loop solve ----------------------------
    pc = solve_batched_jax(batch)
    t_pc = timeit(lambda: solve_batched_jax(batch), warmup=0, iters=iters)
    # executed-step accounting via the scheduler with compaction disabled
    # (threshold=0, one segment per stage == the monolithic loop split)
    stats_pc = []
    pc2 = solve_batched_compacted(batch, segment_k=max_iters,
                                  compact_threshold=0.0, stats_out=stats_pc)
    elems_pc = total_elements(stats_pc)

    # --- Level 1+2: compaction-scheduled ------------------------------------
    # telemetry=True: the counter plane sources the pivot accounting below,
    # so BENCH rows and user-facing telemetry can never drift apart
    stats_sched = []
    sched = solve_batched_compacted(batch, segment_k=segment_k,
                                    compact_threshold=compact_threshold,
                                    stats_out=stats_sched, telemetry=True)
    t_sched = timeit(lambda: solve_batched_compacted(
        batch, segment_k=segment_k, compact_threshold=compact_threshold),
        warmup=0, iters=iters)
    elems_sched = total_elements(stats_sched)

    statuses_identical = bool(
        np.array_equal(lock.status, pc.status)
        and np.array_equal(lock.status, pc2.status)
        and np.array_equal(lock.status, sched.status))
    buckets = sorted({s.bucket for s in stats_sched}, reverse=True)

    # --- pricing rules x two-level engine ------------------------------------
    # (dantzig reuses the scheduled run above: same solver, same rule)
    rules = {}
    for rule in PRICING_RULES:
        if rule == "dantzig":
            r_res, r_stats, r_wall = sched, stats_sched, t_sched
        else:
            r_stats = []
            r_res = solve_batched_compacted(
                batch, segment_k=segment_k, compact_threshold=compact_threshold,
                pricing=rule, stats_out=r_stats)
            r_wall = timeit(lambda: solve_batched_compacted(
                batch, segment_k=segment_k,
                compact_threshold=compact_threshold, pricing=rule),
                warmup=0, iters=iters)
        r_piv = r_res.iterations.astype(np.int64)
        rules[rule] = {
            "pivots_mean": float(r_piv.mean()),
            "pivots_max": int(r_piv.max()),
            "pivots_total": int(r_piv.sum()),
            "steps": total_steps(r_stats),
            "elements": int(total_elements(r_stats)),
            "wall_s": r_wall,
            "statuses_match_dantzig": bool(
                np.array_equal(r_res.status, sched.status)),
        }
    dz_mean = rules["dantzig"]["pivots_mean"]
    for rule in PRICING_RULES:
        rules[rule]["pivot_cut_vs_dantzig"] = (
            1.0 - rules[rule]["pivots_mean"] / max(dz_mean, 1e-12))

    backend_rows = (measure_backends(batch, sched, segment_k, iters)
                    if backends in ("all", "revised") else {})
    pdhg_row = (measure_pdhg(batch, sched, iters)
                if backends in ("all", "pdhg") else {})

    # telemetry-sourced counters from the scheduled run's SolveReport; the
    # match flags assert they equal the bespoke LPResult-derived counts
    rep = sched.stats
    tel_piv = rep.iterations.astype(np.int64)
    telemetry_row = {
        "iterations_match_result": bool(
            np.array_equal(rep.iterations,
                           np.asarray(sched.iterations))),
        "iterations_match_lockstep": bool(np.array_equal(tel_piv, piv)),
        "useful_pivots": int(tel_piv.sum()),
        "phase1_pivots_total": int(rep.total("phase1_pivots")),
        "phase2_pivots_total": int(rep.total("phase2_pivots")),
        "bound_flips_total": int(rep.total("bound_flips")),
        "degenerate_pivots_total": int(rep.total("degenerate_pivots")),
        "elements_lockstep_from_telemetry": element_updates_lockstep(
            tel_piv, m, n),
    }

    return {
        "m": m, "n": n, "B": B, "mixed": True,
        "segment_k": segment_k, "compact_threshold": compact_threshold,
        "useful_pivots": int(tel_piv.sum()),
        "pivots_mean": float(tel_piv.mean()),
        "pivots_max": int(tel_piv.max()),
        "statuses_identical": statuses_identical,
        "telemetry": telemetry_row,
        "lockstep": {
            "steps": steps_lock,
            "elements": int(elems_lock),
            "wall_s": t_lock,
        },
        "phase_compacted": {
            "steps": total_steps(stats_pc),
            "elements": int(elems_pc),
            "wall_s": t_pc,
        },
        "scheduled": {
            "steps": total_steps(stats_sched),
            "elements": int(elems_sched),
            "wall_s": t_sched,
            "bucket_ladder": buckets,
            "segments": len(stats_sched),
            "survivor_curve": [s.survivors for s in stats_sched],
        },
        "rules": rules,
        "backends": backend_rows,
        "pdhg": pdhg_row,
        "reduction_phase_compacted": elems_lock / max(1, elems_pc),
        "reduction_scheduled": elems_lock / max(1, elems_sched),
        "reduction_steepest_edge": elems_lock / max(
            1, rules["steepest_edge"]["elements"]),
    }


def _measure_rows(sizes, B: int, quick: bool, backends: str) -> list:
    rows = []
    for (m, n) in sizes:
        iters = 1 if (quick or m >= 50) else 2
        r = measure(m, n, B, iters=iters, backends=backends)
        rows.append(r)
        print(f"pivot_work m={m} n={n} B={B}: "
              f"elems lockstep={r['lockstep']['elements']:.3e} "
              f"compacted={r['phase_compacted']['elements']:.3e} "
              f"scheduled={r['scheduled']['elements']:.3e} "
              f"(x{r['reduction_scheduled']:.2f}) "
              f"statuses_identical={r['statuses_identical']}")
        for rule, rr in r["rules"].items():
            print(f"  pricing={rule:<14} pivots_mean={rr['pivots_mean']:8.2f} "
                  f"(cut {rr['pivot_cut_vs_dantzig']:+.1%}) "
                  f"elems={rr['elements']:.3e} wall={rr['wall_s']:.3f}s "
                  f"statuses_match={rr['statuses_match_dantzig']}")
        for name, bb in r["backends"].items():
            if name == "tableau":
                continue
            print(f"  backend={name:<15} pivots_mean={bb['pivots_mean']:8.2f} "
                  f"elems={bb['elements_lockstep']:.3e} "
                  f"(x{bb['element_reduction_vs_tableau']:.1f} fewer element "
                  f"updates) wall={bb['wall_s']:.3f}s "
                  f"statuses_match={bb['statuses_match_tableau']}")
        if r["pdhg"]:
            pp = r["pdhg"]
            print(f"  backend=pdhg            iters_mean={pp['iters_mean']:8.0f} "
                  f"status_match={pp['status_match_tableau_frac']:.3f} "
                  f"rel_obj={pp['rel_obj_err_vs_tableau']:.1e} "
                  f"wall={pp['wall_s']:.3f}s "
                  f"sched_match={pp['scheduled_status_match_frac']:.3f}")
            mp = pp["malitsky_pock"]
            print(f"  step_rule=malitsky_pock iters_mean={mp['iters_mean']:8.0f} "
                  f"(cut {mp['iters_cut_vs_fixed']:+.1%} vs fixed) "
                  f"status_match={mp['status_match_fixed_frac']:.3f} "
                  f"rel_obj={mp['rel_obj_err_vs_fixed']:.1e}")
    return rows


def run(quick: bool = False, B: int = 4096, out: str | None = None,
        backends: str = "all") -> dict:
    sizes = QUICK_SIZES if quick else SIZES
    if quick:
        B = min(B, 128)
    if out is None:
        out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                           "BENCH_pivot_work.json")
    out = os.path.abspath(out)
    # fail on an unwritable destination *before* burning benchmark minutes
    os.makedirs(os.path.dirname(out), exist_ok=True)
    t0 = time.time()
    rows = _measure_rows(sizes, B, quick, backends)
    if quick:
        quick_rows = rows
    else:
        # the --quick configuration again, so scripts/bench_gate.py can diff
        # a CI smoke run against this file on exactly matching workloads
        print("-- quick_workloads (bench_gate baseline) --")
        quick_rows = _measure_rows(QUICK_SIZES, 128, True, backends)
    print("-- general_workloads (fixture-backed, bench_gate baseline) --")
    general_rows = []
    for fixture in GENERAL_FIXTURES:
        r = measure_general(fixture, backends=backends)
        general_rows.append(r)
        print(f"general {r['fixture']} B={r['B']}: "
              f"{r['m']}x{r['n']} -> canonical "
              f"{r['m_canonical']}x{r['n_canonical']}  "
              + "  ".join(
                  f"{k}: match={v['status_match_oracle_frac']:.2f} "
                  f"err={v['rel_obj_err']:.1e}"
                  for k, v in r["backends"].items())
              + f"  scaling_changes_f32={r['scaling']['changes_f32']}")
    sparse_rows = []
    if backends in ("all", "pdhg"):
        print("-- sparse_workloads (shared-pattern PDHG, bench_gate "
              "baseline) --")
        for fixture in SPARSE_FIXTURES:
            r = measure_sparse(fixture)
            sparse_rows.append(r)
            print(f"sparse {r['fixture']} B={r['B']}: canonical "
                  f"{r['m']}x{r['n']} nnz={r['nnz']} "
                  f"(density {r['density']:.3f}) "
                  f"traffic x{r['element_traffic_ratio']:.1f} "
                  f"status_match={r['status_match_dense_frac']:.3f} "
                  f"rel_obj={r['rel_obj_err_vs_dense']:.1e} "
                  f"wall dense={r['wall_s_dense']:.3f}s "
                  f"sparse={r['wall_s_sparse']:.3f}s")
    print("-- warm_workloads (warm-start engine, bench_gate baseline) --")
    warm_rows = []
    for fixture in WARM_FIXTURES:
        r = measure_warm(fixture, backends=backends)
        warm_rows.append(r)
        for name, wb in r["backends"].items():
            ratio = wb["work_ratio"]
            cut = "all" if ratio == 0.0 else f"x{1.0 / ratio:.1f}"
            print(f"warm {r['fixture']} B={r['B']} K={r['K']} "
                  f"{name:<8} cold_iters={wb['cold_iters_mean']:8.1f} "
                  f"warm_iters={wb['warm_iters_mean']:8.1f} "
                  f"({cut} re-solve work eliminated) "
                  f"status_match={wb['status_match_frac']:.3f} "
                  f"rel_obj={wb['rel_obj_err']:.1e}")
    print("-- pallas_workloads (tile kernels vs engines, bench_gate "
          "baseline) --")
    pallas_rows = []
    for (pm, pn) in PALLAS_SIZES:
        r = measure_pallas(pm, pn, backends=backends)
        pallas_rows.append(r)
        for name, kk in r["kernels"].items():
            print(f"pallas {r['m']}x{r['n']} B={r['B']} "
                  f"{name:<8} status_match={kk['status_match_engine_frac']:.3f} "
                  f"iters_match={kk['iters_match_engine']} "
                  f"rel_obj={kk['rel_obj_err_vs_engine']:.1e} "
                  f"segments={kk['segments']} "
                  f"elems={kk['elements_scheduled']:.3e} "
                  f"shrunk={kk['bucket_shrunk']} "
                  f"wall={kk['wall_s_interpret']:.1f}s (interpret)")
    bnb_rows = []
    if backends in ("all", "tableau", "revised"):
        print("-- bnb_workloads (branch-and-bound driver, bench_gate "
              "baseline) --")
        for fixture in BNB_FIXTURES:
            r = measure_bnb(fixture, backends=backends)
            bnb_rows.append(r)
            for name, nb in r["backends"].items():
                print(f"bnb {r['fixture']} frontier={r['frontier']} "
                      f"{name:<8} obj={nb['objective']:10.4f} "
                      f"proven={nb['proven']} nodes={nb['nodes']} "
                      f"warm_iters={nb['warm_lp_iters']} "
                      f"cold_iters={nb['cold_lp_iters']} "
                      f"(x{nb['work_ratio']:.2f} of cold) "
                      f"wall={nb['wall_s']:.3f}s")
    result = {
        "benchmark": "pivot_work",
        "quick": quick,
        "backends": backends,
        "elapsed_s": time.time() - t0,
        "workloads": rows,
        "quick_workloads": quick_rows,
        "general_workloads": general_rows,
        "sparse_workloads": sparse_rows,
        "warm_workloads": warm_rows,
        "bnb_workloads": bnb_rows,
        "pallas_workloads": pallas_rows,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {out}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short smoke: small sizes, B=128, 1 timing iter")
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--backend",
                    choices=("tableau", "revised", "pdhg", "all"),
                    default="all",
                    help="which solver engines get per-backend rows "
                         "(tableau base metrics are always measured; "
                         "'tableau' skips the revised and pdhg rows)")
    args = ap.parse_args()
    run(quick=args.quick, B=args.batch, out=args.out, backends=args.backend)


if __name__ == "__main__":
    main()
