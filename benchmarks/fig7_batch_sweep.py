"""Paper Fig. 7: batched device solve vs sequential CPU solve, sweeping
batch size x LP dimension, feasible-start LPs. GLPK/CPLEX are not available
offline; the float64 NumPy simplex (core/reference.py) is the sequential
baseline (same pivot rule — so the comparison isolates batching, exactly the
paper's variable)."""
import numpy as np

from repro.core import random_lp_batch, solve_batched_jax, solve_batched_reference
from repro.kernels import solve_batched_pallas

from .common import RNG, emit, timeit


def run(dims=(5, 28, 50), batches=(1, 50, 100, 500, 1000, 2000),
        seq_cap: int = 200, pallas: bool = False):
    rows = []
    for n in dims:
        m = n
        for B in batches:
            batch = random_lp_batch(RNG, B=B, m=m, n=n)
            t_jax = timeit(lambda: solve_batched_jax(batch), iters=3)
            # sequential baseline cost extrapolated above seq_cap LPs
            Bs = min(B, seq_cap)
            sub = random_lp_batch(RNG, B=Bs, m=m, n=n)
            t_seq_sub = timeit(lambda: solve_batched_reference(sub),
                               warmup=0, iters=1)
            t_seq = t_seq_sub * (B / Bs)
            row = {"dim": n, "batch": B, "t_seq": t_seq, "t_jax": t_jax,
                   "speedup": t_seq / t_jax}
            if pallas:
                t_pal = timeit(lambda: solve_batched_pallas(sub if B > Bs
                                                            else batch),
                               iters=1)
                row["t_pallas_interp"] = t_pal
            emit(f"fig7/dim{n}_batch{B}", t_jax,
                 f"seq={t_seq:.4f}s;speedup={row['speedup']:.2f}x")
            rows.append(row)
    return rows
