"""Shared benchmark utilities: timing, CSV emission, problem generators,
and the general-form oracle-agreement check."""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

RNG = np.random.default_rng(2018)  # paper year


def oracle_checks(general, res, ref) -> dict:
    """Agreement of a recovered f32 result with the float64 oracle on the
    same general-form batch: status-match fraction, relative objective
    error over jointly-OPTIMAL members, and the original-space feasibility
    certificate (max `general_violation`).  Shared by table6_netlib and
    pivot_work so the metric definitions cannot drift apart."""
    from repro.core import OPTIMAL, general_violation

    status = np.asarray(res.status)
    ok = (status == OPTIMAL) & (np.asarray(ref.status) == OPTIMAL)
    rel = (np.abs(res.objective[ok] - ref.objective[ok])
           / np.abs(ref.objective[ok])).max() if ok.any() else 0.0
    viol = general_violation(general, np.asarray(res.x))
    return {
        "status_match_oracle_frac": float((status == ref.status).mean()),
        "rel_obj_err": float(rel),
        "max_violation": float(viol[ok].max() if ok.any() else 0.0),
    }


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over `iters` runs (after warmup/compile)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


# The paper's 8 Netlib problems at their converted (standard-form) sizes
# (Table 5). MPS sources aren't redistributable offline, so we generate
# sparse LPs at identical dimensions ('-like' suffix everywhere).
NETLIB_LIKE = (
    ("ADLITTLE-like", 71, 97),
    ("AFIRO-like", 35, 32),
    ("BLEND-like", 117, 83),
    ("ISRAEL-like", 174, 142),
    ("SC105-like", 150, 103),
    ("SC205-like", 296, 203),
    ("SC50A-like", 70, 48),
    ("SC50B-like", 70, 48),
)
