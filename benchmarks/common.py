"""Shared benchmark utilities: timing, CSV emission, problem generators."""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

RNG = np.random.default_rng(2018)  # paper year


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over `iters` runs (after warmup/compile)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


# The paper's 8 Netlib problems at their converted (standard-form) sizes
# (Table 5). MPS sources aren't redistributable offline, so we generate
# sparse LPs at identical dimensions ('-like' suffix everywhere).
NETLIB_LIKE = (
    ("ADLITTLE-like", 71, 97),
    ("AFIRO-like", 35, 32),
    ("BLEND-like", 117, 83),
    ("ISRAEL-like", 174, 142),
    ("SC105-like", 150, 103),
    ("SC205-like", 296, 203),
    ("SC50A-like", 70, 48),
    ("SC50B-like", 70, 48),
)
