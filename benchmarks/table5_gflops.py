"""Paper Table 5: solver throughput in Gflop/s on the Netlib-like set.

FLOPs are counted analytically per pivot (core.simplex.flops_per_pivot) x
measured per-LP pivot counts — the same accounting the paper's nvvp numbers
approximate. Reported against this host CPU; the roofline table in
EXPERIMENTS.md §Roofline carries the TPU projection."""
import numpy as np

from repro.core import (flops_per_pivot, random_sparse_lp_batch,
                        solve_batched_jax)

from .common import NETLIB_LIKE, RNG, emit, timeit


def run(batch: int = 512, problems=NETLIB_LIKE[:6]):
    rows = []
    for name, m, n in problems:
        lps = random_sparse_lp_batch(RNG, B=batch, m=m, n=n, density=0.1)
        res = solve_batched_jax(lps)
        t = timeit(lambda: solve_batched_jax(lps), iters=3)
        flops = float(flops_per_pivot(m, n)) * float(np.sum(res.iterations))
        gflops = flops / t / 1e9
        emit(f"table5/{name}", t, f"batch={batch};gflops={gflops:.2f}")
        rows.append((name, batch, gflops))
    return rows
