"""Paper Table 2 analogue: memory-layout ablation of Step 3 (pivot update).

The paper's experiment: column-major (coalesced) vs row-major tableau and
the loop-interchange non-coalesced variant — 8.7-15.7x on a K40c. The TPU
question is *which axis rides the vector lanes*; on this CPU host the same
contiguity argument applies to SIMD. We time the full pivot step (reduction
+ rank-1 update) under two layouts:

  batch-major (B, R, C): tableau columns contiguous (our production layout —
      C on the 128-lane axis of the Pallas kernel)
  batch-minor (R, C, B): LPs contiguous (one-thread-per-LP layout the paper
      argues AGAINST for tableau manipulation)
"""
import jax
import jax.numpy as jnp
import numpy as np

from .common import RNG, emit, timeit


def _pivot_step_batch_major(T, e_onehot, l_onehot):
    col = jnp.einsum("brc,bc->br", T, e_onehot)
    pe = jnp.einsum("br,br->b", col, l_onehot)
    pivrow = jnp.einsum("br,brc->bc", l_onehot, T) / pe[:, None]
    return T - col[:, :, None] * pivrow[:, None, :] \
        + l_onehot[:, :, None] * pivrow[:, None, :]


def _pivot_step_batch_minor(T, e_onehot, l_onehot):
    # T: (R, C, B)
    col = jnp.einsum("rcb,cb->rb", T, e_onehot)
    pe = jnp.einsum("rb,rb->b", col, l_onehot)
    pivrow = jnp.einsum("rb,rcb->cb", l_onehot, T) / pe[None, :]
    return T - col[:, None, :] * pivrow[None, :, :] \
        + l_onehot[:, None, :] * pivrow[None, :, :]


def run(dims=(10, 50, 100, 200), batch: int = 1000, iters: int = 20):
    rows = []
    for n in dims:
        m = n
        R, C = m + 2, n + 2 * m + 1
        T = jnp.asarray(RNG.normal(size=(batch, R, C)), jnp.float32)
        e = jax.nn.one_hot(RNG.integers(0, C, batch), C, dtype=jnp.float32)
        l = jax.nn.one_hot(RNG.integers(0, R, batch), R, dtype=jnp.float32)

        f_maj = jax.jit(lambda T, e, l: _pivot_step_batch_major(T, e, l))
        f_min = jax.jit(lambda T, e, l: _pivot_step_batch_minor(T, e, l))
        Tt = jnp.transpose(T, (1, 2, 0))
        et = e.T
        lt = l.T

        t_maj = timeit(lambda: jax.block_until_ready(f_maj(T, e, l)),
                       iters=iters) 
        t_min = timeit(lambda: jax.block_until_ready(f_min(Tt, et, lt)),
                       iters=iters)
        emit(f"table2/layout_batch_major_dim{n}", t_maj,
             f"batch={batch}")
        emit(f"table2/layout_batch_minor_dim{n}", t_min,
             f"batch={batch};ratio={t_min / t_maj:.2f}x")
        rows.append((n, t_maj, t_min))
    return rows
