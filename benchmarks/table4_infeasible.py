"""Paper Table 4: LPs whose initial basic solution is infeasible (two-phase
simplex, kernel effectively runs twice)."""
from repro.core import random_lp_batch, solve_batched_jax, solve_batched_reference

from .common import RNG, emit, timeit


def run(dims=(5, 28, 50), batches=(50, 500, 2000), seq_cap: int = 100):
    rows = []
    for n in dims:
        for B in batches:
            batch = random_lp_batch(RNG, B=B, m=n, n=n, feasible_start=False)
            t_jax = timeit(lambda: solve_batched_jax(batch), iters=3)
            Bs = min(B, seq_cap)
            sub = random_lp_batch(RNG, B=Bs, m=n, n=n, feasible_start=False)
            t_seq = timeit(lambda: solve_batched_reference(sub), warmup=0,
                           iters=1) * (B / Bs)
            emit(f"table4/dim{n}_batch{B}", t_jax,
                 f"seq={t_seq:.4f}s;speedup={t_seq / t_jax:.2f}x")
            rows.append((n, B, t_seq, t_jax))
    return rows
