"""Paper Table 7: state-space exploration (XSpeed workload) — support
functions over hyper-rectangles. Compares (a) the closed-form hyperbox
solver (paper Sec. 5.6) against (b) the same LPs pushed through the general
batched simplex, and (c) a sequential CPU loop — reproducing the paper's
observation that the special case is the dominant win for this application.

Also measures warm-start chaining on the simplex leg: the second half of
the flow-pipe re-solved from the first half's terminal bases (the
repeated-solve pattern a reachability loop actually executes), reporting
cold-vs-warm pivot counts."""
import numpy as np

from repro.core import (hyperbox_as_general_lp, solve_batched_jax,
                        solve_hyperbox, solve_hyperbox_ref)
import jax.numpy as jnp

from .common import RNG, emit, timeit


def _flowpipe(n, T):
    A = np.eye(n) + 0.01 * RNG.normal(size=(n, n))
    lo, hi = [-0.1 * np.ones(n)], [0.1 * np.ones(n)]
    for _ in range(T - 1):
        c = (lo[-1] + hi[-1]) / 2
        r = (hi[-1] - lo[-1]) / 2
        c = A @ c
        r = np.abs(A) @ r + 1e-3
        lo.append(c - r)
        hi.append(c + r)
    return np.stack(lo), np.stack(hi)


def run(n: int = 5, T: int = 500, K: int = 40):
    lo, hi = _flowpipe(n, T)
    dirs = RNG.normal(size=(K, n))
    # expand to (T*K) box LPs like XSpeed's per-direction sampling
    lo_e = np.repeat(lo, K, axis=0)
    hi_e = np.repeat(hi, K, axis=0)
    d_e = np.tile(dirs, (T, 1))

    jl, jh, jd = map(jnp.asarray, (lo_e, hi_e, d_e))
    t_box = timeit(lambda: np.asarray(solve_hyperbox(jl, jh, jd)), iters=5)
    lp, off = hyperbox_as_general_lp(lo_e, hi_e, d_e)
    t_simplex = timeit(lambda: solve_batched_jax(lp), iters=2)
    t_seq = timeit(lambda: solve_hyperbox_ref(lo_e, hi_e, d_e), iters=3)

    # warm-start chaining: the back half of the pipe re-solved from the
    # front half's terminal bases (same K directions, drifted boxes)
    half = (T // 2) * K
    lp_a, _ = hyperbox_as_general_lp(lo_e[:half], hi_e[:half], d_e[:half])
    lp_b, _ = hyperbox_as_general_lp(lo_e[half:2 * half], hi_e[half:2 * half],
                                     d_e[half:2 * half])
    parent = solve_batched_jax(lp_a)
    cold = solve_batched_jax(lp_b)
    warm = solve_batched_jax(lp_b, warm=parent.warm_start())
    t_warm = timeit(lambda: solve_batched_jax(lp_b, warm=parent.warm_start()),
                    iters=2)
    cold_piv = float(cold.iterations.astype(np.int64).mean())
    warm_piv = float(warm.iterations.astype(np.int64).mean())

    n_lps = T * K
    emit("table7/hyperbox_batched", t_box,
         f"lps={n_lps};vs_simplex={t_simplex / t_box:.1f}x;"
         f"vs_seq_numpy={t_seq / t_box:.1f}x")
    emit("table7/general_simplex_same_lps", t_simplex, f"lps={n_lps}")
    emit("table7/general_simplex_warm_resolve", t_warm,
         f"lps={half};cold_pivots={cold_piv:.1f};warm_pivots={warm_piv:.1f};"
         f"statuses_agree={bool(np.array_equal(cold.status, warm.status))}")
    return {"t_box": t_box, "t_simplex": t_simplex, "t_seq": t_seq,
            "t_warm": t_warm, "cold_pivots": cold_piv,
            "warm_pivots": warm_piv}
