"""Paper Table 7: state-space exploration (XSpeed workload) — support
functions over hyper-rectangles. Compares (a) the closed-form hyperbox
solver (paper Sec. 5.6) against (b) the same LPs pushed through the general
batched simplex, and (c) a sequential CPU loop — reproducing the paper's
observation that the special case is the dominant win for this application."""
import numpy as np

from repro.core import (hyperbox_as_general_lp, solve_batched_jax,
                        solve_hyperbox, solve_hyperbox_ref)
import jax.numpy as jnp

from .common import RNG, emit, timeit


def _flowpipe(n, T):
    A = np.eye(n) + 0.01 * RNG.normal(size=(n, n))
    lo, hi = [-0.1 * np.ones(n)], [0.1 * np.ones(n)]
    for _ in range(T - 1):
        c = (lo[-1] + hi[-1]) / 2
        r = (hi[-1] - lo[-1]) / 2
        c = A @ c
        r = np.abs(A) @ r + 1e-3
        lo.append(c - r)
        hi.append(c + r)
    return np.stack(lo), np.stack(hi)


def run(n: int = 5, T: int = 500, K: int = 40):
    lo, hi = _flowpipe(n, T)
    dirs = RNG.normal(size=(K, n))
    # expand to (T*K) box LPs like XSpeed's per-direction sampling
    lo_e = np.repeat(lo, K, axis=0)
    hi_e = np.repeat(hi, K, axis=0)
    d_e = np.tile(dirs, (T, 1))

    jl, jh, jd = map(jnp.asarray, (lo_e, hi_e, d_e))
    t_box = timeit(lambda: np.asarray(solve_hyperbox(jl, jh, jd)), iters=5)
    lp, off = hyperbox_as_general_lp(lo_e, hi_e, d_e)
    t_simplex = timeit(lambda: solve_batched_jax(lp), iters=2)
    t_seq = timeit(lambda: solve_hyperbox_ref(lo_e, hi_e, d_e), iters=3)

    n_lps = T * K
    emit("table7/hyperbox_batched", t_box,
         f"lps={n_lps};vs_simplex={t_simplex / t_box:.1f}x;"
         f"vs_seq_numpy={t_seq / t_box:.1f}x")
    emit("table7/general_simplex_same_lps", t_simplex, f"lps={n_lps}")
    return {"t_box": t_box, "t_simplex": t_simplex, "t_seq": t_seq}
