"""Benchmark harness: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV. `--full` widens sweeps toward the paper's
original sizes (1e5-size batches; slow on 1 CPU core)."""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="comma list: table2,fig7,table4,table5,table6,"
                         "table7,pivot_work")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (fig7_batch_sweep, pivot_work, table2_layout,
                   table4_infeasible, table5_gflops, table6_netlib,
                   table7_reachability)

    print("name,us_per_call,derived")
    if only is None or "table2" in only:
        table2_layout.run(dims=(10, 50, 100, 200) if not args.full
                          else (10, 50, 100, 200, 300, 500))
    if only is None or "fig7" in only:
        fig7_batch_sweep.run(batches=(1, 50, 100, 500, 1000, 2000) if not
                             args.full else (1, 50, 100, 500, 1000, 2000,
                                             5000, 20000, 50000))
    if only is None or "table4" in only:
        table4_infeasible.run()
    if only is None or "table5" in only:
        table5_gflops.run(batch=512 if not args.full else 4096)
    if only is None or "table6" in only:
        table6_netlib.run(batches=(1, 10, 100, 1000) if not args.full
                          else (1, 10, 100, 1000, 10000, 100000))
    if only is None or "table7" in only:
        table7_reachability.run(T=500 if not args.full else 2000)
    if "pivot_work" in (only or ()):  # JSON artifact, opt-in from here
        # only a --full run may refresh the committed B=4096 baseline;
        # quick smokes write to /tmp so they can't corrupt the trajectory
        pivot_work.run(quick=not args.full,
                       out=None if args.full else "/tmp/pivot_work_quick.json")


if __name__ == "__main__":
    main()
