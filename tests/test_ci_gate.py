"""scripts/bench_gate.py must demonstrably fail on a regressed bench file
(and pass on a faithful one) — the CI bench-regression gate's own test."""
import copy
import importlib.util
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_GATE = os.path.join(_REPO, "scripts", "bench_gate.py")

spec = importlib.util.spec_from_file_location("bench_gate", _GATE)
bench_gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_gate)


def _workload(m=5, n=5, B=128):
    return {
        "m": m, "n": n, "B": B,
        "statuses_identical": True,
        "reduction_scheduled": 2.0,
        "rules": {
            "dantzig": {"pivot_cut_vs_dantzig": 0.0,
                        "statuses_match_dantzig": True},
            "steepest_edge": {"pivot_cut_vs_dantzig": 0.40,
                              "statuses_match_dantzig": True},
            "devex": {"pivot_cut_vs_dantzig": 0.15,
                      "statuses_match_dantzig": True},
        },
        "backends": {
            "tableau": {"statuses_match_tableau": True},
            "revised_dantzig": {"statuses_match_tableau": True,
                                "element_reduction_vs_tableau": 10.0},
        },
    }


def _general_row(fixture="afiro", B=32):
    return {
        "fixture": fixture, "B": B, "m": 27, "n": 32,
        "m_canonical": 35, "n_canonical": 32,
        "backends": {
            "tableau": {"status_match_oracle_frac": 1.0,
                        "rel_obj_err": 3e-7},
            "revised": {"status_match_oracle_frac": 1.0,
                        "rel_obj_err": 4e-7},
        },
        "scaling": {"scaled_status": 0, "scaled_iters": 17,
                    "unscaled_status": 0, "unscaled_iters": 17,
                    "changes_f32": fixture != "afiro"},
    }


def _warm_row(fixture="afiro", B=16, K=4):
    return {
        "fixture": fixture, "B": B, "K": K,
        "backends": {
            "tableau": {"cold_iters_mean": 17.0, "warm_iters_mean": 0.0,
                        "work_ratio": 0.0, "status_match_frac": 1.0,
                        "rel_obj_err": 1e-7},
            "revised": {"cold_iters_mean": 17.0, "warm_iters_mean": 0.0,
                        "work_ratio": 0.0, "status_match_frac": 1.0,
                        "rel_obj_err": 1e-7},
            "pdhg": {"cold_iters_mean": 700.0, "warm_iters_mean": 250.0,
                     "work_ratio": 0.36, "status_match_frac": 1.0,
                     "rel_obj_err": 6e-5},
        },
    }


@pytest.fixture
def baseline():
    return {"benchmark": "pivot_work", "quick": False, "backends": "all",
            "quick_workloads": [_workload()],
            "general_workloads": [_general_row(),
                                  _general_row("sc50b_like")],
            "warm_workloads": [_warm_row()]}


@pytest.fixture
def current():
    return {"benchmark": "pivot_work", "quick": True, "backends": "all",
            "workloads": [_workload()],
            "general_workloads": [_general_row(),
                                  _general_row("sc50b_like")],
            "warm_workloads": [_warm_row()]}


def test_gate_passes_on_matching_run(baseline, current):
    assert bench_gate.gate(current, baseline) == []


def test_gate_passes_within_tolerance(baseline, current):
    # 10% relative drop is inside the 20% budget
    current["workloads"][0]["reduction_scheduled"] = 1.8
    current["workloads"][0]["rules"]["steepest_edge"][
        "pivot_cut_vs_dantzig"] = 0.36
    assert bench_gate.gate(current, baseline) == []


def test_gate_fails_on_scheduled_regression(baseline, current):
    current["workloads"][0]["reduction_scheduled"] = 1.5  # -25%
    failures = bench_gate.gate(current, baseline)
    assert any("reduction_scheduled" in f for f in failures)


def test_gate_fails_on_pricing_cut_regression(baseline, current):
    current["workloads"][0]["rules"]["steepest_edge"][
        "pivot_cut_vs_dantzig"] = 0.25  # -37.5% relative
    failures = bench_gate.gate(current, baseline)
    assert any("steepest_edge" in f for f in failures)


def test_gate_ignores_noise_on_near_zero_cuts(baseline, current):
    # devex baseline 0.15 -> floor 0.15*0.8 - 0.02 = 0.10
    current["workloads"][0]["rules"]["devex"]["pivot_cut_vs_dantzig"] = 0.11
    assert bench_gate.gate(current, baseline) == []
    current["workloads"][0]["rules"]["devex"]["pivot_cut_vs_dantzig"] = 0.05
    assert bench_gate.gate(current, baseline) != []


def test_gate_fails_on_status_divergence(baseline, current):
    current["workloads"][0]["statuses_identical"] = False
    assert any("diverged" in f for f in bench_gate.gate(current, baseline))
    current["workloads"][0]["statuses_identical"] = True
    current["workloads"][0]["backends"]["revised_dantzig"][
        "statuses_match_tableau"] = False
    assert any("revised_dantzig" in f
               for f in bench_gate.gate(current, baseline))


def test_gate_fails_on_backend_element_regression(baseline, current):
    current["workloads"][0]["backends"]["revised_dantzig"][
        "element_reduction_vs_tableau"] = 6.0  # -40%
    assert any("element_reduction_vs_tableau" in f
               for f in bench_gate.gate(current, baseline))


def test_gate_skips_backend_rows_for_tableau_only_smoke(baseline, current):
    current["backends"] = "tableau"
    del current["workloads"][0]["backends"]
    assert bench_gate.gate(current, baseline) == []


def test_gate_fails_when_nothing_matches(baseline, current):
    current["workloads"][0]["B"] = 4096  # different workload entirely
    assert any("no workload" in f for f in bench_gate.gate(current, baseline))


def test_gate_general_status_regression(baseline, current):
    """Status regressions on real (fixture-backed) instances fail CI."""
    current["general_workloads"][0]["backends"]["revised"][
        "status_match_oracle_frac"] = 0.9
    failures = bench_gate.gate(current, baseline)
    assert any("status agreement" in f and "afiro" in f for f in failures)


def test_gate_general_objective_regression(baseline, current):
    current["general_workloads"][1]["backends"]["tableau"][
        "rel_obj_err"] = 5e-3
    failures = bench_gate.gate(current, baseline)
    assert any("rel_obj_err" in f for f in failures)


def test_gate_general_missing_row(baseline, current):
    current["general_workloads"] = current["general_workloads"][:1]
    failures = bench_gate.gate(current, baseline)
    assert any("row missing" in f for f in failures)


def test_gate_general_scaling_effect_must_persist(baseline, current):
    # the sc50b_like baseline records a real f32 scaling effect; a smoke run
    # where it vanishes means equilibration stopped running
    current["general_workloads"][1]["scaling"]["changes_f32"] = False
    failures = bench_gate.gate(current, baseline)
    assert any("scaling" in f for f in failures)
    # afiro's baseline has no effect, so False there is fine
    current["general_workloads"][1]["scaling"]["changes_f32"] = True
    current["general_workloads"][0]["scaling"]["changes_f32"] = False
    assert bench_gate.gate(current, baseline) == []


def test_gate_general_small_drift_tolerated(baseline, current):
    current["general_workloads"][0]["backends"]["tableau"][
        "status_match_oracle_frac"] = 0.99
    assert bench_gate.gate(current, baseline) == []


def test_gate_warm_hard_bound(baseline, current):
    """work_ratio > 0.5 fails even if the baseline itself was that bad —
    a warm re-solve must cost at most half a cold one, absolutely."""
    for d in (baseline, current):
        d["warm_workloads"][0]["backends"]["pdhg"]["work_ratio"] = 0.6
    fails = bench_gate.gate(current, baseline)
    assert any("hard bound" in f for f in fails)


def test_gate_warm_relative_regression(baseline, current):
    current["warm_workloads"][0]["backends"]["pdhg"]["work_ratio"] = 0.49
    fails = bench_gate.gate(current, baseline)  # baseline 0.36 + 20% < 0.49
    assert any("stopped eliminating" in f for f in fails)


def test_gate_warm_status_and_objective(baseline, current):
    current["warm_workloads"][0]["backends"]["tableau"][
        "status_match_frac"] = 0.9
    current["warm_workloads"][0]["backends"]["revised"][
        "rel_obj_err"] = 5e-3
    fails = bench_gate.gate(current, baseline)
    assert any("status agreement" in f and "warm" in f for f in fails)
    assert any("changed the answer" in f for f in fails)


def test_gate_warm_missing_row_and_old_baseline(baseline, current):
    current["warm_workloads"] = []
    fails = bench_gate.gate(current, baseline)
    assert any("warm" in f and "missing" in f for f in fails)
    # a baseline predating the warm engine has no rows to hold floors on
    del baseline["warm_workloads"]
    assert not any("warm" in f for f in bench_gate.gate(current, baseline))


def test_gate_warm_skips_unmeasured_engines(baseline, current):
    """A per-engine smoke leg (--backend tableau) measures only its own
    warm rows; the gate must not demand the others."""
    current["backends"] = "tableau"
    for name in ("revised", "pdhg"):
        del current["warm_workloads"][0]["backends"][name]
    assert not any("warm" in f
                   for f in bench_gate.gate(current, baseline))


def test_gate_cli_exit_codes(tmp_path, baseline, current):
    """End-to-end: the CLI exits 0 on a clean run and 1 on a synthetic
    regression — what scripts/check.sh and the CI `full` job rely on."""
    base_p = tmp_path / "baseline.json"
    base_p.write_text(json.dumps(baseline))
    good_p = tmp_path / "good.json"
    good_p.write_text(json.dumps(current))
    bad = copy.deepcopy(current)
    bad["workloads"][0]["reduction_scheduled"] = 0.9
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(bad))

    def run(cur):
        return subprocess.run(
            [sys.executable, _GATE, str(cur), "--baseline", str(base_p)],
            capture_output=True, text=True)

    ok = run(good_p)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    fail = run(bad_p)
    assert fail.returncode == 1
    assert "reduction_scheduled" in fail.stdout
