"""Data pipeline: determinism, resume, prefetch, LP mixture."""
import numpy as np

from repro.data import DataPipeline, optimal_mixture


def test_deterministic_and_resumable():
    p1 = DataPipeline(vocab=128, batch=4, seq=16, seed=5)
    p2 = DataPipeline(vocab=128, batch=4, seq=16, seed=5)
    b0 = p1.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"], p2.batch_at(0)["tokens"])
    # resume: batch_at(k) is independent of history
    b7a = p1.batch_at(7)
    for _ in range(3):
        p2.batch_at(np.random.randint(100))
    np.testing.assert_array_equal(b7a["tokens"], p2.batch_at(7)["tokens"])


def test_labels_shifted():
    p = DataPipeline(vocab=64, batch=2, seq=8, seed=1)
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint():
    a = DataPipeline(vocab=64, batch=4, seq=8, seed=2, host_id=0, num_hosts=2)
    b = DataPipeline(vocab=64, batch=4, seq=8, seed=2, host_id=1, num_hosts=2)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])
    assert a.local_batch == 2


def test_prefetch_thread():
    p = DataPipeline(vocab=64, batch=2, seq=8, seed=3).start(step=0)
    try:
        b0 = next(p)
        b1 = next(p)
        np.testing.assert_array_equal(b0["tokens"], p.batch_at(0)["tokens"])
        np.testing.assert_array_equal(b1["tokens"], p.batch_at(1)["tokens"])
    finally:
        p.stop()


def test_lp_mixture_respects_constraints():
    u = np.array([[3.0, 1.0, 2.0], [1.0, 5.0, 1.0]])
    caps = np.array([0.5, 0.6, 0.9])
    floors = np.array([0.05, 0.05, 0.05])
    w = optimal_mixture(u, caps, floors)
    assert w.shape == (2, 3)
    assert (w <= caps + 1e-4).all() and (w >= floors - 1e-4).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    # higher-utility source gets its cap
    assert w[0, 0] >= 0.45 and w[1, 1] >= 0.55
