"""Pricing-engine invariance (core/pricing.py).

A pricing rule changes *which* improving column enters — the path through
the basis graph — but never the optimality/infeasibility/unboundedness
certificate.  So for every rule: statuses must match Dantzig and the float64
oracle, objectives must agree to tolerance, and each rule must be
*self-consistent* across every solve path (pure JAX, compaction scheduler,
Pallas interpret, shard_map): same rule => same pivot sequence => bitwise
identical iterations/status regardless of which engine executes it.
"""
import numpy as np
import pytest

from repro.core import (
    OPTIMAL,
    PRICING_RULES,
    LPBatch,
    random_lp_batch,
    random_sparse_lp_batch,
    solve_batched,
    solve_batched_compacted,
    solve_batched_jax,
    solve_batched_reference,
    solve_shard_map,
)
from repro.core.compaction import auto_segment_k
from repro.core.lp import default_max_iters
from repro.distributed.sharding import make_mesh
from repro.kernels import solve_batched_pallas


def _mixed_batch(rng, B_each=8, m=10, n=8):
    f = random_lp_batch(rng, B_each, m, n, feasible_start=True)
    p1 = random_lp_batch(rng, B_each, m, n, feasible_start=False)
    return LPBatch(A=np.concatenate([f.A, p1.A]),
                   b=np.concatenate([f.b, p1.b]),
                   c=np.concatenate([f.c, p1.c]))


def _assert_same_solution(a, b, rtol=1e-4):
    np.testing.assert_array_equal(a.status, b.status)
    ok = a.status == OPTIMAL
    np.testing.assert_allclose(a.objective[ok], b.objective[ok], rtol=rtol)


@pytest.mark.parametrize("rule", PRICING_RULES)
def test_rule_matches_reference_and_dantzig_dense(rule):
    """Dense mixed batch: each rule agrees with its own float64 oracle on
    status/objective, and with Dantzig on the certificate."""
    batch = _mixed_batch(np.random.default_rng(11))
    ref = solve_batched_reference(batch, pricing=rule)
    jx = solve_batched_jax(batch, pricing=rule)
    _assert_same_solution(ref, jx)
    dz = solve_batched_jax(batch)
    _assert_same_solution(dz, jx)


@pytest.mark.parametrize("rule", ["steepest_edge", "devex"])
def test_rule_matches_reference_sparse(rule):
    batch = random_sparse_lp_batch(np.random.default_rng(7), B=12, m=14, n=10)
    ref = solve_batched_reference(batch, pricing=rule)
    jx = solve_batched_jax(batch, pricing=rule)
    _assert_same_solution(ref, jx)
    dz = solve_batched_jax(batch)
    _assert_same_solution(dz, jx)


@pytest.mark.parametrize("rule", PRICING_RULES)
def test_rule_survives_compaction_bitwise(rule):
    """Active-set compaction gathers must preserve the pricing-weight state:
    the scheduled solve is bitwise the monolithic solve under every rule."""
    batch = _mixed_batch(np.random.default_rng(23))
    mono = solve_batched_jax(batch, pricing=rule)
    sched = solve_batched_compacted(batch, pricing=rule, segment_k=3,
                                    compact_threshold=0.9)
    np.testing.assert_array_equal(mono.status, sched.status)
    np.testing.assert_array_equal(mono.iterations, sched.iterations)
    np.testing.assert_array_equal(mono.x, sched.x)
    np.testing.assert_array_equal(np.nan_to_num(mono.objective),
                                  np.nan_to_num(sched.objective))


@pytest.mark.parametrize("rule", PRICING_RULES)
@pytest.mark.parametrize("m,n", [(10, 8), (7, 9)])
def test_rule_pallas_interpret_matches_jax(rule, m, n):
    """Pallas tile kernels (interpret) execute the same per-rule pivot
    sequence as the pure-JAX solver, whole-solve and segmented alike.
    m=7 covers the tile geometry where the compacted row pad (8) differs
    from the full-stage pad (16)."""
    batch = _mixed_batch(np.random.default_rng(31), B_each=9, m=m, n=n)
    jx = solve_batched_jax(batch, pricing=rule)
    pal = solve_batched_pallas(batch, tile_b=8, pricing=rule)
    np.testing.assert_array_equal(jx.status, pal.status)
    np.testing.assert_array_equal(jx.iterations, pal.iterations)
    ok = jx.status == OPTIMAL
    np.testing.assert_allclose(jx.objective[ok], pal.objective[ok], rtol=1e-5)
    palc = solve_batched_pallas(batch, tile_b=8, pricing=rule,
                                compaction=True, segment_k=4)
    np.testing.assert_array_equal(pal.status, palc.status)
    np.testing.assert_array_equal(pal.iterations, palc.iterations)


@pytest.mark.parametrize("rule", ["steepest_edge", "devex"])
def test_rule_shard_map_single_device(rule):
    """pricing= plumbs through solve_shard_map (1-device mesh here; the
    multi-device path is covered by tests/test_distributed.py)."""
    mesh = make_mesh((1,), ("data",))
    batch = _mixed_batch(np.random.default_rng(41), B_each=6)
    jx = solve_batched_jax(batch, pricing=rule)
    sm = solve_shard_map(batch, mesh, pricing=rule)
    sms = solve_shard_map(batch, mesh, pricing=rule, segment_k=4)
    for res in (sm, sms):
        np.testing.assert_array_equal(jx.status, res.status)
        np.testing.assert_array_equal(jx.iterations, res.iterations)


@pytest.mark.parametrize("rule", ["steepest_edge", "devex"])
def test_rule_phase_compaction_invariant(rule):
    """Dropping artificial columns must not change the rule's pivot path:
    weight state for priceable columns is layout-independent (devex pins
    non-priceable slots to 1, steepest-edge recomputes from live columns),
    so the single-loop and two-loop solves are bitwise identical."""
    batch = _mixed_batch(np.random.default_rng(59), B_each=12)
    two_loop = solve_batched_jax(batch, pricing=rule)
    single = solve_batched_jax(batch, pricing=rule, phase_compaction=False)
    np.testing.assert_array_equal(two_loop.status, single.status)
    np.testing.assert_array_equal(two_loop.iterations, single.iterations)
    np.testing.assert_array_equal(two_loop.x, single.x)


def test_steepest_edge_cuts_pivots():
    """The reason this engine exists: steepest-edge needs meaningfully fewer
    pivots than Dantzig on the paper's mixed workload."""
    batch = _mixed_batch(np.random.default_rng(5), B_each=32, m=14, n=14)
    dz = solve_batched_jax(batch)
    se = solve_batched_jax(batch, pricing="steepest_edge")
    assert se.iterations.mean() < 0.9 * dz.iterations.mean()


def test_sorted_compacted_unpermutes_correctly():
    """sort_by_difficulty + compaction + non-default pricing: the difficulty
    pre-pass reorders LPs into waves and results must come back unpermuted —
    bitwise equal to the unsorted solve of the same rule."""
    batch = _mixed_batch(np.random.default_rng(19), B_each=16)
    plain = solve_batched(batch, chunk_size=8, compaction=True,
                          pricing="steepest_edge", segment_k=4)
    srt = solve_batched(batch, chunk_size=8, compaction=True,
                        pricing="steepest_edge", segment_k=4,
                        sort_by_difficulty=True)
    np.testing.assert_array_equal(plain.status, srt.status)
    np.testing.assert_array_equal(plain.iterations, srt.iterations)
    np.testing.assert_array_equal(plain.x, srt.x)
    np.testing.assert_array_equal(np.nan_to_num(plain.objective),
                                  np.nan_to_num(srt.objective))


def test_auto_segment_k_and_survivor_curve():
    """segment_k=None derives the segment length from the iteration cap, and
    SegmentStat records a non-increasing survivor curve ending at zero."""
    m = n = 10
    assert auto_segment_k(m, n) == max(4, default_max_iters(m, n) // 64)
    batch = _mixed_batch(np.random.default_rng(3), B_each=16, m=m, n=n)
    stats = []
    auto = solve_batched_compacted(batch, segment_k=None, stats_out=stats)
    explicit = solve_batched_compacted(batch, segment_k=auto_segment_k(m, n))
    np.testing.assert_array_equal(auto.status, explicit.status)
    np.testing.assert_array_equal(auto.iterations, explicit.iterations)
    curve = [s.survivors for s in stats]
    assert curve, "expected at least one segment"
    assert all(a >= b for a, b in zip(curve, curve[1:])), curve
    assert curve[-1] == 0


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="unknown pricing rule"):
        solve_batched_jax(_mixed_batch(np.random.default_rng(0), B_each=1),
                          pricing="bland")
    with pytest.raises(ValueError, match="pricing"):
        solve_batched(_mixed_batch(np.random.default_rng(0), B_each=1),
                      solver=lambda b: None, pricing="steepest_edge")
