"""Native variable upper bounds: degenerate flip cases and consistency of
the bounded ratio test against the explicit bound-row encoding.

The bounded simplex never materializes ``x_j <= u_j`` as rows: the ratio
test lets the entering variable hit its own bound (a "flip": the column is
complemented in place, no pivot), and a basic variable leaving at its upper
bound complements the leaving row.  These tests pin down the degenerate
corners of that bookkeeping and the invariant that the compact encoding
solves the *same* LP as the row encoding on every engine and pricing rule.
"""
import numpy as np
import pytest

from repro.core import (INFEASIBLE, LPBatch, OPTIMAL,
                        canonical_shape, solve_batched_jax,
                        solve_batched_reference, solve_batched_revised)
from repro.core.forms import GeneralLPBatch

RNG = np.random.default_rng(11)
PRICING = ("dantzig", "steepest_edge", "devex")


def _engines(pricing):
    yield "tableau", lambda b: solve_batched_jax(b, pricing=pricing)
    # the revised engine prices without the dense tableau: dantzig/partial
    rule = pricing if pricing in ("dantzig", "partial") else "partial"
    yield "revised", lambda b: solve_batched_revised(b, pricing=rule)


def _with_bound_rows(batch: LPBatch) -> LPBatch:
    """Re-encode finite upper bounds as explicit ``x_j <= u_j`` rows."""
    A, b, c = batch.A, batch.b, batch.c
    ub = batch.upper_bounds()
    B, m, n = A.shape
    fin = np.isfinite(ub).any(axis=0)
    eye = np.eye(n)[fin]
    rows = np.broadcast_to(eye, (B,) + eye.shape)
    return LPBatch.from_arrays(
        np.concatenate([A, rows], axis=1),
        np.concatenate([b, np.where(np.isfinite(ub[:, fin]),
                                    ub[:, fin], 1e30)], axis=1), c)


# ---------------------------------------------------------------------------
# degenerate flips
# ---------------------------------------------------------------------------

def test_all_at_upper_optimum():
    """Slack rows only: the optimum puts *every* variable at its upper
    bound, so the whole solve is flips (no pivots ever become binding)."""
    B, m, n = 4, 3, 5
    A = np.abs(RNG.uniform(0.1, 1.0, size=(B, m, n)))
    ub = RNG.uniform(0.5, 2.0, size=(B, n))
    b = np.einsum("bmn,bn->bm", A, ub) + 1.0       # rows never bind
    c = RNG.uniform(0.5, 2.0, size=(B, n))          # all costs improve
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    want = np.einsum("bn,bn->b", c, ub)
    ref = solve_batched_reference(batch)
    assert (ref.status == OPTIMAL).all()
    np.testing.assert_allclose(ref.objective, want, rtol=1e-12)
    np.testing.assert_allclose(ref.x, ub, rtol=1e-12)
    for pricing in PRICING:
        for name, solve in _engines(pricing):
            res = solve(batch)
            assert (res.status == OPTIMAL).all(), (name, pricing)
            np.testing.assert_allclose(res.objective, want, rtol=1e-4,
                                       err_msg=f"{name}/{pricing}")


def test_zero_upper_bound_degenerate_flip():
    """A zero upper bound on an attractive column: the flip happens at
    ratio t_e = 0 (pure bookkeeping, zero objective progress).  The solver
    must take it without cycling and optimize over the remaining column."""
    A = np.array([[[1.0, 1.0]]])
    b = np.array([[1.0]])
    c = np.array([[2.0, 1.0]])                      # x1 looks best but ub=0
    ub = np.array([[0.0, np.inf]])
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    ref = solve_batched_reference(batch)
    assert ref.status[0] == OPTIMAL
    np.testing.assert_allclose(ref.objective[0], 1.0, rtol=1e-12)
    np.testing.assert_allclose(ref.x[0], [0.0, 1.0], atol=1e-12)
    for pricing in PRICING:
        for name, solve in _engines(pricing):
            res = solve(batch)
            assert res.status[0] == OPTIMAL, (name, pricing)
            np.testing.assert_allclose(res.objective[0], 1.0, rtol=1e-5)


def test_degenerate_row_beats_flip():
    """A zero-rhs binding row makes min_ratio = 0 < t_e: the pivot (not the
    flip) must win — the strict ``t_e < min_ratio`` rule breaks the tie
    toward the row, matching the row-encoded pivot order."""
    A = np.array([[[1.0, -1.0], [1.0, 1.0]]])
    b = np.array([[0.0, 4.0]])
    c = np.array([[1.0, 0.0]])
    ub = np.array([[3.0, np.inf]])
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    ref = solve_batched_reference(batch)
    assert ref.status[0] == OPTIMAL
    np.testing.assert_allclose(ref.objective[0], 2.0, rtol=1e-12)
    for name, solve in _engines("dantzig"):
        res = solve(batch)
        assert res.status[0] == OPTIMAL, name
        np.testing.assert_allclose(res.objective[0], 2.0, rtol=1e-5)


def test_bounded_never_unbounded():
    """Finite bounds on every variable rule out UNBOUNDED even when no row
    restrains the objective direction."""
    A = np.array([[[0.0, 1.0]]])
    b = np.array([[1.0]])
    c = np.array([[1.0, 0.0]])                      # unbounded without ub
    ub = np.array([[5.0, np.inf]])
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    for solver in (solve_batched_reference,
                   solve_batched_jax, solve_batched_revised):
        res = solver(batch)
        assert res.status[0] == OPTIMAL
        np.testing.assert_allclose(res.objective[0], 5.0, rtol=1e-5)


def test_infeasible_with_bounds_stays_infeasible():
    """Bounds must not mask genuine row infeasibility (phase 1 still runs
    with the bounded ratio test)."""
    A = np.array([[[1.0, 1.0]]])
    b = np.array([[-1.0]])                          # x1 + x2 <= -1, x >= 0
    c = np.array([[1.0, 1.0]])
    ub = np.array([[2.0, 2.0]])
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    assert solve_batched_reference(batch).status[0] == INFEASIBLE
    assert solve_batched_jax(batch).status[0] == INFEASIBLE
    assert solve_batched_revised(batch).status[0] == INFEASIBLE


# ---------------------------------------------------------------------------
# compact encoding == row encoding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pricing", PRICING)
def test_bound_flip_matches_row_encoding(pricing):
    """The native-ub solve and the explicit bound-row solve are the same LP:
    statuses and objectives must agree across tableau and revised engines,
    while the native form carries fewer rows."""
    B, m, n = 12, 6, 5
    A = RNG.uniform(1.0, 100.0, size=(B, m, n))
    b = RNG.uniform(50.0, 500.0, size=(B, m))
    c = RNG.uniform(1.0, 50.0, size=(B, n))
    ub = np.where(RNG.random((B, n)) < 0.7,
                  RNG.uniform(0.5, 10.0, size=(B, n)), np.inf)
    native = LPBatch.from_arrays(A, b, c, ub=ub)
    rows = _with_bound_rows(native)
    assert rows.A.shape[1] > native.A.shape[1]

    ref_n = solve_batched_reference(native)
    ref_r = solve_batched_reference(rows)
    assert (ref_n.status == ref_r.status).all()
    ok = ref_n.status == OPTIMAL
    assert ok.sum() > 0
    np.testing.assert_allclose(ref_n.objective[ok], ref_r.objective[ok],
                               rtol=1e-9)

    for name, solve in _engines(pricing):
        res_n = solve(native)
        res_r = solve(rows)
        agree = (res_n.status == ref_n.status).mean()
        assert agree >= 0.9, (name, pricing, agree)
        both = (res_n.status == OPTIMAL) & (res_r.status == OPTIMAL)
        rel = np.abs(res_n.objective[both] - res_r.objective[both]) \
            / np.maximum(1.0, np.abs(res_r.objective[both]))
        assert rel.max() < 2e-3, (name, pricing)


def test_chunked_solve_keeps_bounds():
    """The chunked driver must thread ub into every chunk (and through the
    difficulty sort): a dropped bound turns bounded-only LPs UNBOUNDED."""
    from repro.core import solve_batched
    B, m, n = 9, 3, 4
    A = RNG.uniform(-0.5, 1.0, size=(B, m, n))
    b = RNG.uniform(1.0, 5.0, size=(B, m))
    c = RNG.uniform(0.5, 2.0, size=(B, n))
    ub = RNG.uniform(0.5, 3.0, size=(B, n))         # every column bounded
    batch = LPBatch.from_arrays(A, b, c, ub=ub)
    whole = solve_batched(batch)
    chunked = solve_batched(batch, chunk_size=4)
    sorted_ = solve_batched(batch, chunk_size=4, sort_by_difficulty=True)
    assert not (whole.status == 1).any()            # bounded: never UNBOUNDED
    np.testing.assert_array_equal(whole.status, chunked.status)
    np.testing.assert_array_equal(whole.status, sorted_.status)
    np.testing.assert_allclose(chunked.objective, whole.objective, rtol=1e-6)
    np.testing.assert_allclose(sorted_.objective, whole.objective, rtol=1e-6)


def test_canonical_shape_drops_bound_rows():
    """General-form canonicalization routes finite ubs into the bound
    vector: canonical m must not grow with the number of bounded columns."""
    n = 8
    g = GeneralLPBatch.from_arrays(
        A=RNG.uniform(0.1, 1.0, size=(1, 3, n)), sense=["L"] * 3,
        rhs=RNG.uniform(5.0, 9.0, size=(1, 3)),
        ub=np.full((1, n), 2.0), c=np.ones((1, n)))
    m_native, n_native = canonical_shape(g)
    m_rows, n_rows = canonical_shape(g, bound_rows=True)
    assert n_native == n_rows
    assert m_rows == m_native + n           # one row per finite ub
