"""Checkpoint manager: atomicity, retention, async, restore."""
import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t, extra={"data_step": 3})
    assert mgr.latest_step() == 3
    out = mgr.restore(3, t)
    np.testing.assert_allclose(out["a"], t["a"])
    np.testing.assert_array_equal(out["b"]["c"], t["b"]["c"])
    assert mgr.extra(3)["data_step"] == 3


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(s), blocking=False)
    mgr.wait()
    mgr._gc()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    out = mgr.restore(4, _tree())
    np.testing.assert_allclose(out["a"], _tree(4)["a"])


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(0, t)
    like = {"a": jnp.zeros((8, 4), jnp.bfloat16),
            "b": {"c": jnp.zeros((3,), jnp.int32)}}
    out = mgr.restore(0, like)
    assert out["a"].dtype == jnp.bfloat16
