"""End-to-end behaviour of the paper's system: batch -> chunked device solve
-> results, plus the motivating reachability application (paper Sec. 7)."""
import numpy as np

from repro.core import (OPTIMAL, random_lp_batch, solve_batched,
                        solve_batched_reference, solve_hyperbox)
from repro.kernels import solve_batched_pallas
import jax.numpy as jnp


def test_pipeline_jax_and_pallas_agree_with_oracle():
    rng = np.random.default_rng(42)
    batch = random_lp_batch(rng, B=120, m=10, n=6, feasible_start=False)
    ref = solve_batched_reference(batch)
    for solver in (None, solve_batched_pallas):
        res = solve_batched(batch, solver=solver, chunk_size=50)
        ok = (ref.status == OPTIMAL) & (res.status == OPTIMAL)
        assert ok.mean() > 0.9
        rel = np.abs(ref.objective[ok] - res.objective[ok]) \
            / np.abs(ref.objective[ok])
        assert rel.max() < 5e-4


def test_reachability_support_functions():
    """Support-function sampling of a reachable-set flow-pipe over boxes —
    the XSpeed workload shape (many directions x many boxes)."""
    rng = np.random.default_rng(0)
    n, K, T = 5, 32, 50
    # simple linear system x' = Ax discretized; box bloating per step
    A = np.eye(n) + 0.01 * rng.normal(size=(n, n))
    lo = -np.ones((1, n)) * 0.1
    hi = np.ones((1, n)) * 0.1
    dirs = rng.normal(size=(K, n))
    los, his = [lo[0]], [hi[0]]
    for t in range(T - 1):
        c = (los[-1] + his[-1]) / 2
        r = (his[-1] - los[-1]) / 2
        c = A @ c
        r = np.abs(A) @ r + 1e-3
        los.append(c - r)
        his.append(c + r)
    los = np.stack(los)
    his = np.stack(his)
    sup = np.asarray(solve_hyperbox(jnp.asarray(los), jnp.asarray(his),
                                    jnp.asarray(dirs)))
    assert sup.shape == (T, K)
    # support values bound every box vertex sample along each direction
    for t in (0, T // 2, T - 1):
        pts = rng.uniform(los[t], his[t], size=(64, n))
        proj = pts @ dirs.T
        assert (proj <= sup[t] + 1e-6).all()
