* Deterministic SC50B-class staircase (50 rows x 48 cols): 8-stage
* production/inventory model with deliberately mixed units
* (coefficients span ~1e-3..1e3) so presolve equilibration matters
* in float32.  Not Netlib data -- see README.md in this directory.
NAME          SC50BLIKE
ROWS
 E  BAL0
 E  EMS0
 L  CAP0
 G  DEM0
 L  ENV0
 E  BAL1
 E  EMS1
 L  CAP1
 G  DEM1
 L  ENV1
 E  BAL2
 E  EMS2
 L  CAP2
 G  DEM2
 L  ENV2
 E  BAL3
 E  EMS3
 L  CAP3
 G  DEM3
 L  ENV3
 E  BAL4
 E  EMS4
 L  CAP4
 G  DEM4
 L  ENV4
 E  BAL5
 E  EMS5
 L  CAP5
 G  DEM5
 L  ENV5
 E  BAL6
 E  EMS6
 L  CAP6
 G  DEM6
 L  ENV6
 E  BAL7
 E  EMS7
 L  CAP7
 G  DEM7
 L  ENV7
 G  TOTSL
 L  TOTPR
 L  MW0
 L  MW1
 L  MW2
 L  MW3
 L  MW4
 L  MW5
 L  MW6
 L  MW7
 N  COST
COLUMNS
    P10       BAL0              0.01   EMS0              -300
    P10       CAP0                 1   TOTPR                1
    P10       MW0                  1   MW7                  1
    P10       COST               1.1
    P20       BAL0                 1   EMS0             -2000
    P20       CAP0               120   TOTPR              100
    P20       COST                95
    INV0      BAL0             -0.01   BAL1      0.0316227766
    INV0      COST              0.02
    SL0       BAL0             -0.01   DEM0                 1
    SL0       TOTSL                1   COST                -3
    EM0       EMS0                 1   ENV0                 1
    OF0       ENV0             -1000   COST                 4
    P11       MW0                  1   BAL1      0.0316227766
    P11       EMS1              -300   CAP1                 1
    P11       TOTPR                1   MW1                  1
    P11       COST              1.15
    P21       BAL1        3.16227766   EMS1             -2000
    P21       CAP1               120   TOTPR              100
    P21       COST              93.5
    INV1      BAL1      -0.0316227766   BAL2               0.1
    INV1      COST              0.02
    SL1       BAL1      -0.0316227766   DEM1                 1
    SL1       TOTSL                1   COST              -2.9
    EM1       EMS1                 1   ENV1                 1
    OF1       ENV1             -1000   COST                 4
    P12       MW1                  1   BAL2               0.1
    P12       EMS2              -300   CAP2                 1
    P12       TOTPR                1   MW2                  1
    P12       COST               1.2
    P22       BAL2                10   EMS2             -2000
    P22       CAP2               120   TOTPR              100
    P22       COST                92
    INV2      BAL2              -0.1   BAL3       0.316227766
    INV2      COST              0.02
    SL2       BAL2              -0.1   DEM2                 1
    SL2       TOTSL                1   COST              -2.8
    EM2       EMS2                 1   ENV2                 1
    OF2       ENV2             -1000   COST                 4
    P13       MW2                  1   BAL3       0.316227766
    P13       EMS3              -300   CAP3                 1
    P13       TOTPR                1   MW3                  1
    P13       COST              1.25
    P23       BAL3        31.6227766   EMS3             -2000
    P23       CAP3               120   TOTPR              100
    P23       COST              90.5
    INV3      BAL3      -0.316227766   BAL4                 1
    INV3      COST              0.02
    SL3       BAL3      -0.316227766   DEM3                 1
    SL3       TOTSL                1   COST              -2.7
    EM3       EMS3                 1   ENV3                 1
    OF3       ENV3             -1000   COST                 4
    P14       MW3                  1   BAL4                 1
    P14       EMS4              -300   CAP4                 1
    P14       TOTPR                1   MW4                  1
    P14       COST               1.3
    P24       BAL4               100   EMS4             -2000
    P24       CAP4               120   TOTPR              100
    P24       COST                89
    INV4      BAL4                -1   BAL5        3.16227766
    INV4      COST              0.02
    SL4       BAL4                -1   DEM4                 1
    SL4       TOTSL                1   COST              -2.6
    EM4       EMS4                 1   ENV4                 1
    OF4       ENV4             -1000   COST                 4
    P15       MW4                  1   BAL5        3.16227766
    P15       EMS5              -300   CAP5                 1
    P15       TOTPR                1   MW5                  1
    P15       COST              1.35
    P25       BAL5        316.227766   EMS5             -2000
    P25       CAP5               120   TOTPR              100
    P25       COST              87.5
    INV5      BAL5       -3.16227766   BAL6                10
    INV5      COST              0.02
    SL5       BAL5       -3.16227766   DEM5                 1
    SL5       TOTSL                1   COST              -2.5
    EM5       EMS5                 1   ENV5                 1
    OF5       ENV5             -1000   COST                 4
    P16       MW5                  1   BAL6                10
    P16       EMS6              -300   CAP6                 1
    P16       TOTPR                1   MW6                  1
    P16       COST               1.4
    P26       BAL6              1000   EMS6             -2000
    P26       CAP6               120   TOTPR              100
    P26       COST                86
    INV6      BAL6               -10   BAL7        31.6227766
    INV6      COST              0.02
    SL6       BAL6               -10   DEM6                 1
    SL6       TOTSL                1   COST              -2.4
    EM6       EMS6                 1   ENV6                 1
    OF6       ENV6             -1000   COST                 4
    P17       MW6                  1   BAL7        31.6227766
    P17       EMS7              -300   CAP7                 1
    P17       TOTPR                1   MW7                  1
    P17       COST              1.45
    P27       BAL7        3162.27766   EMS7             -2000
    P27       CAP7               120   TOTPR              100
    P27       COST              84.5
    INV7      BAL7       -31.6227766   COST              0.02
    SL7       BAL7       -31.6227766   DEM7                 1
    SL7       TOTSL                1   COST              -2.3
    EM7       EMS7                 1   ENV7                 1
    OF7       ENV7             -1000   COST                 4
RHS
    RHS       CAP0               260   DEM0                40
    RHS       ENV0             25000   CAP1               270
    RHS       DEM1                46   ENV1             25000
    RHS       CAP2               280   DEM2                52
    RHS       ENV2             25000   CAP3               290
    RHS       DEM3                58   ENV3             25000
    RHS       CAP4               300   DEM4                64
    RHS       ENV4             25000   CAP5               310
    RHS       DEM5                70   ENV5             25000
    RHS       CAP6               320   DEM6                76
    RHS       ENV6             25000   CAP7               330
    RHS       DEM7                82   ENV7             25000
    RHS       TOTSL              520   TOTPR             1900
    RHS       MW0                300   MW1                300
    RHS       MW2                300   MW3                300
    RHS       MW4                300   MW5                300
    RHS       MW6                300   MW7                300
RANGES
    RNG       DEM0                60   DEM2                68
    RNG       DEM4                76   DEM6                84
    RNG       TOTPR              600
BOUNDS
 FX BND       INV0                10
 UP BND       INV1                40
 UP BND       INV2                40
 UP BND       INV3                40
 UP BND       INV4                40
 UP BND       INV5                40
 UP BND       INV6                40
 UP BND       INV7                40
 LO BND       SL0                  2
 MI BND       OF7       
 UP BND       OF7                 30
 FR BND       EM0       
ENDATA
