* Deterministic SC205-class staircase (204 rows x 160 cols, 758 nnz,
* ~2.3% density): 40-stage production/inventory model, every coefficient
* a closed-form function of the stage index.  All 160 columns carry
* finite UP bounds so the native-bound canonical form (246 x 159) stays
* ~40% smaller than the bound-row encoding (405 x 159).  Mixed row units
* (1e-2..1e2) keep float32 equilibration relevant.  Not Netlib data --
* see README.md in this directory.
NAME          SC205LIKE
ROWS
 E  BAL0
 L  CAP0
 G  DEM0
 L  EMS0
 L  RMP0
 E  BAL1
 L  CAP1
 G  DEM1
 L  EMS1
 L  RMP1
 E  BAL2
 L  CAP2
 G  DEM2
 L  EMS2
 L  RMP2
 E  BAL3
 L  CAP3
 G  DEM3
 L  EMS3
 L  RMP3
 E  BAL4
 L  CAP4
 G  DEM4
 L  EMS4
 L  RMP4
 E  BAL5
 L  CAP5
 G  DEM5
 L  EMS5
 L  RMP5
 E  BAL6
 L  CAP6
 G  DEM6
 L  EMS6
 L  RMP6
 E  BAL7
 L  CAP7
 G  DEM7
 L  EMS7
 L  RMP7
 E  BAL8
 L  CAP8
 G  DEM8
 L  EMS8
 L  RMP8
 E  BAL9
 L  CAP9
 G  DEM9
 L  EMS9
 L  RMP9
 E  BAL10
 L  CAP10
 G  DEM10
 L  EMS10
 L  RMP10
 E  BAL11
 L  CAP11
 G  DEM11
 L  EMS11
 L  RMP11
 E  BAL12
 L  CAP12
 G  DEM12
 L  EMS12
 L  RMP12
 E  BAL13
 L  CAP13
 G  DEM13
 L  EMS13
 L  RMP13
 E  BAL14
 L  CAP14
 G  DEM14
 L  EMS14
 L  RMP14
 E  BAL15
 L  CAP15
 G  DEM15
 L  EMS15
 L  RMP15
 E  BAL16
 L  CAP16
 G  DEM16
 L  EMS16
 L  RMP16
 E  BAL17
 L  CAP17
 G  DEM17
 L  EMS17
 L  RMP17
 E  BAL18
 L  CAP18
 G  DEM18
 L  EMS18
 L  RMP18
 E  BAL19
 L  CAP19
 G  DEM19
 L  EMS19
 L  RMP19
 E  BAL20
 L  CAP20
 G  DEM20
 L  EMS20
 L  RMP20
 E  BAL21
 L  CAP21
 G  DEM21
 L  EMS21
 L  RMP21
 E  BAL22
 L  CAP22
 G  DEM22
 L  EMS22
 L  RMP22
 E  BAL23
 L  CAP23
 G  DEM23
 L  EMS23
 L  RMP23
 E  BAL24
 L  CAP24
 G  DEM24
 L  EMS24
 L  RMP24
 E  BAL25
 L  CAP25
 G  DEM25
 L  EMS25
 L  RMP25
 E  BAL26
 L  CAP26
 G  DEM26
 L  EMS26
 L  RMP26
 E  BAL27
 L  CAP27
 G  DEM27
 L  EMS27
 L  RMP27
 E  BAL28
 L  CAP28
 G  DEM28
 L  EMS28
 L  RMP28
 E  BAL29
 L  CAP29
 G  DEM29
 L  EMS29
 L  RMP29
 E  BAL30
 L  CAP30
 G  DEM30
 L  EMS30
 L  RMP30
 E  BAL31
 L  CAP31
 G  DEM31
 L  EMS31
 L  RMP31
 E  BAL32
 L  CAP32
 G  DEM32
 L  EMS32
 L  RMP32
 E  BAL33
 L  CAP33
 G  DEM33
 L  EMS33
 L  RMP33
 E  BAL34
 L  CAP34
 G  DEM34
 L  EMS34
 L  RMP34
 E  BAL35
 L  CAP35
 G  DEM35
 L  EMS35
 L  RMP35
 E  BAL36
 L  CAP36
 G  DEM36
 L  EMS36
 L  RMP36
 E  BAL37
 L  CAP37
 G  DEM37
 L  EMS37
 L  RMP37
 E  BAL38
 L  CAP38
 G  DEM38
 L  EMS38
 L  RMP38
 E  BAL39
 L  CAP39
 G  DEM39
 L  EMS39
 L  RMP39
 L  TOTPR
 L  TOTSL
 L  TOTEM
 G  TOTIN
 N  COST
COLUMNS
    P1S0      BAL0                0.01   CAP0                   1
    P1S0      DEM0                   1   EMS0                  30
    P1S0      RMP0                   1   RMP1                  -1
    P1S0      TOTPR                  1   COST                   2
    P2S0      BAL0               0.009   CAP0                 1.2
    P2S0      DEM0                   1   EMS0                  10
    P2S0      TOTEM                0.1   COST                 2.5
    IVS0      BAL0               -0.01   EMS0                   1
    IVS0      BAL1               0.095   TOTIN                  1
    IVS0      COST                 0.3
    UNS0      BAL0                0.01   DEM0                   1
    UNS0      TOTSL                  1   COST                  50
    P1S1      BAL1                 0.1   CAP1                   1
    P1S1      DEM1                   1   EMS1                3.05
    P1S1      RMP1                   1   RMP2                  -1
    P1S1      TOTPR                  1   COST                2.01
    P2S1      BAL1              0.0902   CAP1                 1.2
    P2S1      DEM1                   1   EMS1                1.03
    P2S1      TOTEM                0.1   COST                2.49
    IVS1      BAL1                -0.1   EMS1                 0.1
    IVS1      BAL2                0.95   TOTIN                  1
    IVS1      COST                 0.3
    UNS1      BAL1                 0.1   DEM1                   1
    UNS1      TOTSL                  1   COST                  50
    P1S2      BAL2                   1   CAP2                   1
    P1S2      DEM2                   1   EMS2                0.31
    P1S2      RMP2                   1   RMP3                  -1
    P1S2      TOTPR                  1   COST                2.02
    P2S2      BAL2               0.904   CAP2                 1.2
    P2S2      DEM2                   1   EMS2               0.106
    P2S2      TOTEM                0.1   COST                2.48
    IVS2      BAL2                  -1   EMS2                0.01
    IVS2      BAL3                 9.5   TOTIN                  1
    IVS2      COST                 0.3
    UNS2      BAL2                   1   DEM2                   1
    UNS2      TOTSL                  1   COST                  50
    P1S3      BAL3                  10   CAP3                   1
    P1S3      DEM3                   1   EMS3                31.5
    P1S3      RMP3                   1   RMP4                  -1
    P1S3      TOTPR                  1   COST                2.03
    P2S3      BAL3                9.06   CAP3                 1.2
    P2S3      DEM3                   1   EMS3                10.9
    P2S3      TOTEM                0.1   COST                2.47
    IVS3      BAL3                 -10   EMS3                   1
    IVS3      BAL4                  95   TOTIN                  1
    IVS3      COST                 0.3
    UNS3      BAL3                  10   DEM3                   1
    UNS3      TOTSL                  1   COST                  50
    P1S4      BAL4                 100   CAP4                   1
    P1S4      DEM4                   1   EMS4                 3.2
    P1S4      RMP4                   1   RMP5                  -1
    P1S4      TOTPR                  1   COST                2.04
    P2S4      BAL4                90.8   CAP4                 1.2
    P2S4      DEM4                   1   EMS4                1.12
    P2S4      TOTEM                0.1   COST                2.46
    IVS4      BAL4                -100   EMS4                 0.1
    IVS4      BAL5              0.0095   TOTIN                  1
    IVS4      COST                 0.3
    UNS4      BAL4                 100   DEM4                   1
    UNS4      TOTSL                  1   COST                  50
    P1S5      BAL5                0.01   CAP5                   1
    P1S5      DEM5                   1   EMS5               0.325
    P1S5      RMP5                   1   RMP6                  -1
    P1S5      TOTPR                  1   COST                2.05
    P2S5      BAL5              0.0091   CAP5                 1.2
    P2S5      DEM5                   1   EMS5               0.115
    P2S5      TOTEM                0.1   COST                2.45
    IVS5      BAL5               -0.01   EMS5                0.01
    IVS5      BAL6               0.095   TOTIN                  1
    IVS5      COST                 0.3
    UNS5      BAL5                0.01   DEM5                   1
    UNS5      TOTSL                  1   COST                  50
    P1S6      BAL6                 0.1   CAP6                   1
    P1S6      DEM6                   1   EMS6                  33
    P1S6      RMP6                   1   RMP7                  -1
    P1S6      TOTPR                  1   COST                2.06
    P2S6      BAL6              0.0912   CAP6                 1.2
    P2S6      DEM6                   1   EMS6                11.8
    P2S6      TOTEM                0.1   COST                2.44
    IVS6      BAL6                -0.1   EMS6                   1
    IVS6      BAL7                0.95   TOTIN                  1
    IVS6      COST                 0.3
    UNS6      BAL6                 0.1   DEM6                   1
    UNS6      TOTSL                  1   COST                  50
    P1S7      BAL7                   1   CAP7                   1
    P1S7      DEM7                   1   EMS7                3.35
    P1S7      RMP7                   1   RMP8                  -1
    P1S7      TOTPR                  1   COST                2.07
    P2S7      BAL7               0.914   CAP7                 1.2
    P2S7      DEM7                   1   EMS7                1.21
    P2S7      TOTEM                0.1   COST                2.43
    IVS7      BAL7                  -1   EMS7                 0.1
    IVS7      BAL8                 9.5   TOTIN                  1
    IVS7      COST                 0.3
    UNS7      BAL7                   1   DEM7                   1
    UNS7      TOTSL                  1   COST                  50
    P1S8      BAL8                  10   CAP8                   1
    P1S8      DEM8                   1   EMS8                0.34
    P1S8      RMP8                   1   RMP9                  -1
    P1S8      TOTPR                  1   COST                2.08
    P2S8      BAL8                9.16   CAP8                 1.2
    P2S8      DEM8                   1   EMS8               0.124
    P2S8      TOTEM                0.1   COST                2.42
    IVS8      BAL8                 -10   EMS8                0.01
    IVS8      BAL9                  95   TOTIN                  1
    IVS8      COST                 0.3
    UNS8      BAL8                  10   DEM8                   1
    UNS8      TOTSL                  1   COST                  50
    P1S9      BAL9                 100   CAP9                   1
    P1S9      DEM9                   1   EMS9                34.5
    P1S9      RMP9                   1   RMP10                 -1
    P1S9      TOTPR                  1   COST                2.09
    P2S9      BAL9                91.8   CAP9                 1.2
    P2S9      DEM9                   1   EMS9                12.7
    P2S9      TOTEM                0.1   COST                2.41
    IVS9      BAL9                -100   EMS9                   1
    IVS9      BAL10             0.0095   TOTIN                  1
    IVS9      COST                 0.3
    UNS9      BAL9                 100   DEM9                   1
    UNS9      TOTSL                  1   COST                  50
    P1S10     BAL10               0.01   CAP10                  1
    P1S10     DEM10                  1   EMS10                3.5
    P1S10     RMP10                  1   RMP11                 -1
    P1S10     TOTPR                  1   COST                 2.1
    P2S10     BAL10             0.0092   CAP10                1.2
    P2S10     DEM10                  1   EMS10                1.3
    P2S10     TOTEM                0.1   COST                 2.4
    IVS10     BAL10              -0.01   EMS10                0.1
    IVS10     BAL11              0.095   TOTIN                  1
    IVS10     COST                 0.3
    UNS10     BAL10               0.01   DEM10                  1
    UNS10     TOTSL                  1   COST                  50
    P1S11     BAL11                0.1   CAP11                  1
    P1S11     DEM11                  1   EMS11              0.355
    P1S11     RMP11                  1   RMP12                 -1
    P1S11     TOTPR                  1   COST                2.11
    P2S11     BAL11             0.0922   CAP11                1.2
    P2S11     DEM11                  1   EMS11              0.133
    P2S11     TOTEM                0.1   COST                2.39
    IVS11     BAL11               -0.1   EMS11               0.01
    IVS11     BAL12               0.95   TOTIN                  1
    IVS11     COST                 0.3
    UNS11     BAL11                0.1   DEM11                  1
    UNS11     TOTSL                  1   COST                  50
    P1S12     BAL12                  1   CAP12                  1
    P1S12     DEM12                  1   EMS12                 36
    P1S12     RMP12                  1   RMP13                 -1
    P1S12     TOTPR                  1   COST                2.12
    P2S12     BAL12              0.924   CAP12                1.2
    P2S12     DEM12                  1   EMS12               13.6
    P2S12     TOTEM                0.1   COST                2.38
    IVS12     BAL12                 -1   EMS12                  1
    IVS12     BAL13                9.5   TOTIN                  1
    IVS12     COST                 0.3
    UNS12     BAL12                  1   DEM12                  1
    UNS12     TOTSL                  1   COST                  50
    P1S13     BAL13                 10   CAP13                  1
    P1S13     DEM13                  1   EMS13               3.65
    P1S13     RMP13                  1   RMP14                 -1
    P1S13     TOTPR                  1   COST                2.13
    P2S13     BAL13               9.26   CAP13                1.2
    P2S13     DEM13                  1   EMS13               1.39
    P2S13     TOTEM                0.1   COST                2.37
    IVS13     BAL13                -10   EMS13                0.1
    IVS13     BAL14                 95   TOTIN                  1
    IVS13     COST                 0.3
    UNS13     BAL13                 10   DEM13                  1
    UNS13     TOTSL                  1   COST                  50
    P1S14     BAL14                100   CAP14                  1
    P1S14     DEM14                  1   EMS14               0.37
    P1S14     RMP14                  1   RMP15                 -1
    P1S14     TOTPR                  1   COST                2.14
    P2S14     BAL14               92.8   CAP14                1.2
    P2S14     DEM14                  1   EMS14              0.142
    P2S14     TOTEM                0.1   COST                2.36
    IVS14     BAL14               -100   EMS14               0.01
    IVS14     BAL15             0.0095   TOTIN                  1
    IVS14     COST                 0.3
    UNS14     BAL14                100   DEM14                  1
    UNS14     TOTSL                  1   COST                  50
    P1S15     BAL15               0.01   CAP15                  1
    P1S15     DEM15                  1   EMS15               37.5
    P1S15     RMP15                  1   RMP16                 -1
    P1S15     TOTPR                  1   COST                2.15
    P2S15     BAL15             0.0093   CAP15                1.2
    P2S15     DEM15                  1   EMS15               14.5
    P2S15     TOTEM                0.1   COST                2.35
    IVS15     BAL15              -0.01   EMS15                  1
    IVS15     BAL16              0.095   TOTIN                  1
    IVS15     COST                 0.3
    UNS15     BAL15               0.01   DEM15                  1
    UNS15     TOTSL                  1   COST                  50
    P1S16     BAL16                0.1   CAP16                  1
    P1S16     DEM16                  1   EMS16                3.8
    P1S16     RMP16                  1   RMP17                 -1
    P1S16     TOTPR                  1   COST                2.16
    P2S16     BAL16             0.0932   CAP16                1.2
    P2S16     DEM16                  1   EMS16               1.48
    P2S16     TOTEM                0.1   COST                2.34
    IVS16     BAL16               -0.1   EMS16                0.1
    IVS16     BAL17               0.95   TOTIN                  1
    IVS16     COST                 0.3
    UNS16     BAL16                0.1   DEM16                  1
    UNS16     TOTSL                  1   COST                  50
    P1S17     BAL17                  1   CAP17                  1
    P1S17     DEM17                  1   EMS17              0.385
    P1S17     RMP17                  1   RMP18                 -1
    P1S17     TOTPR                  1   COST                2.17
    P2S17     BAL17              0.934   CAP17                1.2
    P2S17     DEM17                  1   EMS17              0.151
    P2S17     TOTEM                0.1   COST                2.33
    IVS17     BAL17                 -1   EMS17               0.01
    IVS17     BAL18                9.5   TOTIN                  1
    IVS17     COST                 0.3
    UNS17     BAL17                  1   DEM17                  1
    UNS17     TOTSL                  1   COST                  50
    P1S18     BAL18                 10   CAP18                  1
    P1S18     DEM18                  1   EMS18                 39
    P1S18     RMP18                  1   RMP19                 -1
    P1S18     TOTPR                  1   COST                2.18
    P2S18     BAL18               9.36   CAP18                1.2
    P2S18     DEM18                  1   EMS18               15.4
    P2S18     TOTEM                0.1   COST                2.32
    IVS18     BAL18                -10   EMS18                  1
    IVS18     BAL19                 95   TOTIN                  1
    IVS18     COST                 0.3
    UNS18     BAL18                 10   DEM18                  1
    UNS18     TOTSL                  1   COST                  50
    P1S19     BAL19                100   CAP19                  1
    P1S19     DEM19                  1   EMS19               3.95
    P1S19     RMP19                  1   RMP20                 -1
    P1S19     TOTPR                  1   COST                2.19
    P2S19     BAL19               93.8   CAP19                1.2
    P2S19     DEM19                  1   EMS19               1.57
    P2S19     TOTEM                0.1   COST                2.31
    IVS19     BAL19               -100   EMS19                0.1
    IVS19     BAL20             0.0095   TOTIN                  1
    IVS19     COST                 0.3
    UNS19     BAL19                100   DEM19                  1
    UNS19     TOTSL                  1   COST                  50
    P1S20     BAL20               0.01   CAP20                  1
    P1S20     DEM20                  1   EMS20                0.4
    P1S20     RMP20                  1   RMP21                 -1
    P1S20     TOTPR                  1   COST                 2.2
    P2S20     BAL20             0.0094   CAP20                1.2
    P2S20     DEM20                  1   EMS20               0.16
    P2S20     TOTEM                0.1   COST                 2.3
    IVS20     BAL20              -0.01   EMS20               0.01
    IVS20     BAL21              0.095   TOTIN                  1
    IVS20     COST                 0.3
    UNS20     BAL20               0.01   DEM20                  1
    UNS20     TOTSL                  1   COST                  50
    P1S21     BAL21                0.1   CAP21                  1
    P1S21     DEM21                  1   EMS21               40.5
    P1S21     RMP21                  1   RMP22                 -1
    P1S21     TOTPR                  1   COST                2.21
    P2S21     BAL21             0.0942   CAP21                1.2
    P2S21     DEM21                  1   EMS21               16.3
    P2S21     TOTEM                0.1   COST                2.29
    IVS21     BAL21               -0.1   EMS21                  1
    IVS21     BAL22               0.95   TOTIN                  1
    IVS21     COST                 0.3
    UNS21     BAL21                0.1   DEM21                  1
    UNS21     TOTSL                  1   COST                  50
    P1S22     BAL22                  1   CAP22                  1
    P1S22     DEM22                  1   EMS22                4.1
    P1S22     RMP22                  1   RMP23                 -1
    P1S22     TOTPR                  1   COST                2.22
    P2S22     BAL22              0.944   CAP22                1.2
    P2S22     DEM22                  1   EMS22               1.66
    P2S22     TOTEM                0.1   COST                2.28
    IVS22     BAL22                 -1   EMS22                0.1
    IVS22     BAL23                9.5   TOTIN                  1
    IVS22     COST                 0.3
    UNS22     BAL22                  1   DEM22                  1
    UNS22     TOTSL                  1   COST                  50
    P1S23     BAL23                 10   CAP23                  1
    P1S23     DEM23                  1   EMS23              0.415
    P1S23     RMP23                  1   RMP24                 -1
    P1S23     TOTPR                  1   COST                2.23
    P2S23     BAL23               9.46   CAP23                1.2
    P2S23     DEM23                  1   EMS23              0.169
    P2S23     TOTEM                0.1   COST                2.27
    IVS23     BAL23                -10   EMS23               0.01
    IVS23     BAL24                 95   TOTIN                  1
    IVS23     COST                 0.3
    UNS23     BAL23                 10   DEM23                  1
    UNS23     TOTSL                  1   COST                  50
    P1S24     BAL24                100   CAP24                  1
    P1S24     DEM24                  1   EMS24                 42
    P1S24     RMP24                  1   RMP25                 -1
    P1S24     TOTPR                  1   COST                2.24
    P2S24     BAL24               94.8   CAP24                1.2
    P2S24     DEM24                  1   EMS24               17.2
    P2S24     TOTEM                0.1   COST                2.26
    IVS24     BAL24               -100   EMS24                  1
    IVS24     BAL25             0.0095   TOTIN                  1
    IVS24     COST                 0.3
    UNS24     BAL24                100   DEM24                  1
    UNS24     TOTSL                  1   COST                  50
    P1S25     BAL25               0.01   CAP25                  1
    P1S25     DEM25                  1   EMS25               4.25
    P1S25     RMP25                  1   RMP26                 -1
    P1S25     TOTPR                  1   COST                2.25
    P2S25     BAL25             0.0095   CAP25                1.2
    P2S25     DEM25                  1   EMS25               1.75
    P2S25     TOTEM                0.1   COST                2.25
    IVS25     BAL25              -0.01   EMS25                0.1
    IVS25     BAL26              0.095   TOTIN                  1
    IVS25     COST                 0.3
    UNS25     BAL25               0.01   DEM25                  1
    UNS25     TOTSL                  1   COST                  50
    P1S26     BAL26                0.1   CAP26                  1
    P1S26     DEM26                  1   EMS26               0.43
    P1S26     RMP26                  1   RMP27                 -1
    P1S26     TOTPR                  1   COST                2.26
    P2S26     BAL26             0.0952   CAP26                1.2
    P2S26     DEM26                  1   EMS26              0.178
    P2S26     TOTEM                0.1   COST                2.24
    IVS26     BAL26               -0.1   EMS26               0.01
    IVS26     BAL27               0.95   TOTIN                  1
    IVS26     COST                 0.3
    UNS26     BAL26                0.1   DEM26                  1
    UNS26     TOTSL                  1   COST                  50
    P1S27     BAL27                  1   CAP27                  1
    P1S27     DEM27                  1   EMS27               43.5
    P1S27     RMP27                  1   RMP28                 -1
    P1S27     TOTPR                  1   COST                2.27
    P2S27     BAL27              0.954   CAP27                1.2
    P2S27     DEM27                  1   EMS27               18.1
    P2S27     TOTEM                0.1   COST                2.23
    IVS27     BAL27                 -1   EMS27                  1
    IVS27     BAL28                9.5   TOTIN                  1
    IVS27     COST                 0.3
    UNS27     BAL27                  1   DEM27                  1
    UNS27     TOTSL                  1   COST                  50
    P1S28     BAL28                 10   CAP28                  1
    P1S28     DEM28                  1   EMS28                4.4
    P1S28     RMP28                  1   RMP29                 -1
    P1S28     TOTPR                  1   COST                2.28
    P2S28     BAL28               9.56   CAP28                1.2
    P2S28     DEM28                  1   EMS28               1.84
    P2S28     TOTEM                0.1   COST                2.22
    IVS28     BAL28                -10   EMS28                0.1
    IVS28     BAL29                 95   TOTIN                  1
    IVS28     COST                 0.3
    UNS28     BAL28                 10   DEM28                  1
    UNS28     TOTSL                  1   COST                  50
    P1S29     BAL29                100   CAP29                  1
    P1S29     DEM29                  1   EMS29              0.445
    P1S29     RMP29                  1   RMP30                 -1
    P1S29     TOTPR                  1   COST                2.29
    P2S29     BAL29               95.8   CAP29                1.2
    P2S29     DEM29                  1   EMS29              0.187
    P2S29     TOTEM                0.1   COST                2.21
    IVS29     BAL29               -100   EMS29               0.01
    IVS29     BAL30             0.0095   TOTIN                  1
    IVS29     COST                 0.3
    UNS29     BAL29                100   DEM29                  1
    UNS29     TOTSL                  1   COST                  50
    P1S30     BAL30               0.01   CAP30                  1
    P1S30     DEM30                  1   EMS30                 45
    P1S30     RMP30                  1   RMP31                 -1
    P1S30     TOTPR                  1   COST                 2.3
    P2S30     BAL30             0.0096   CAP30                1.2
    P2S30     DEM30                  1   EMS30                 19
    P2S30     TOTEM                0.1   COST                 2.2
    IVS30     BAL30              -0.01   EMS30                  1
    IVS30     BAL31              0.095   TOTIN                  1
    IVS30     COST                 0.3
    UNS30     BAL30               0.01   DEM30                  1
    UNS30     TOTSL                  1   COST                  50
    P1S31     BAL31                0.1   CAP31                  1
    P1S31     DEM31                  1   EMS31               4.55
    P1S31     RMP31                  1   RMP32                 -1
    P1S31     TOTPR                  1   COST                2.31
    P2S31     BAL31             0.0962   CAP31                1.2
    P2S31     DEM31                  1   EMS31               1.93
    P2S31     TOTEM                0.1   COST                2.19
    IVS31     BAL31               -0.1   EMS31                0.1
    IVS31     BAL32               0.95   TOTIN                  1
    IVS31     COST                 0.3
    UNS31     BAL31                0.1   DEM31                  1
    UNS31     TOTSL                  1   COST                  50
    P1S32     BAL32                  1   CAP32                  1
    P1S32     DEM32                  1   EMS32               0.46
    P1S32     RMP32                  1   RMP33                 -1
    P1S32     TOTPR                  1   COST                2.32
    P2S32     BAL32              0.964   CAP32                1.2
    P2S32     DEM32                  1   EMS32              0.196
    P2S32     TOTEM                0.1   COST                2.18
    IVS32     BAL32                 -1   EMS32               0.01
    IVS32     BAL33                9.5   TOTIN                  1
    IVS32     COST                 0.3
    UNS32     BAL32                  1   DEM32                  1
    UNS32     TOTSL                  1   COST                  50
    P1S33     BAL33                 10   CAP33                  1
    P1S33     DEM33                  1   EMS33               46.5
    P1S33     RMP33                  1   RMP34                 -1
    P1S33     TOTPR                  1   COST                2.33
    P2S33     BAL33               9.66   CAP33                1.2
    P2S33     DEM33                  1   EMS33               19.9
    P2S33     TOTEM                0.1   COST                2.17
    IVS33     BAL33                -10   EMS33                  1
    IVS33     BAL34                 95   TOTIN                  1
    IVS33     COST                 0.3
    UNS33     BAL33                 10   DEM33                  1
    UNS33     TOTSL                  1   COST                  50
    P1S34     BAL34                100   CAP34                  1
    P1S34     DEM34                  1   EMS34                4.7
    P1S34     RMP34                  1   RMP35                 -1
    P1S34     TOTPR                  1   COST                2.34
    P2S34     BAL34               96.8   CAP34                1.2
    P2S34     DEM34                  1   EMS34               2.02
    P2S34     TOTEM                0.1   COST                2.16
    IVS34     BAL34               -100   EMS34                0.1
    IVS34     BAL35             0.0095   TOTIN                  1
    IVS34     COST                 0.3
    UNS34     BAL34                100   DEM34                  1
    UNS34     TOTSL                  1   COST                  50
    P1S35     BAL35               0.01   CAP35                  1
    P1S35     DEM35                  1   EMS35              0.475
    P1S35     RMP35                  1   RMP36                 -1
    P1S35     TOTPR                  1   COST                2.35
    P2S35     BAL35             0.0097   CAP35                1.2
    P2S35     DEM35                  1   EMS35              0.205
    P2S35     TOTEM                0.1   COST                2.15
    IVS35     BAL35              -0.01   EMS35               0.01
    IVS35     BAL36              0.095   TOTIN                  1
    IVS35     COST                 0.3
    UNS35     BAL35               0.01   DEM35                  1
    UNS35     TOTSL                  1   COST                  50
    P1S36     BAL36                0.1   CAP36                  1
    P1S36     DEM36                  1   EMS36                 48
    P1S36     RMP36                  1   RMP37                 -1
    P1S36     TOTPR                  1   COST                2.36
    P2S36     BAL36             0.0972   CAP36                1.2
    P2S36     DEM36                  1   EMS36               20.8
    P2S36     TOTEM                0.1   COST                2.14
    IVS36     BAL36               -0.1   EMS36                  1
    IVS36     BAL37               0.95   TOTIN                  1
    IVS36     COST                 0.3
    UNS36     BAL36                0.1   DEM36                  1
    UNS36     TOTSL                  1   COST                  50
    P1S37     BAL37                  1   CAP37                  1
    P1S37     DEM37                  1   EMS37               4.85
    P1S37     RMP37                  1   RMP38                 -1
    P1S37     TOTPR                  1   COST                2.37
    P2S37     BAL37              0.974   CAP37                1.2
    P2S37     DEM37                  1   EMS37               2.11
    P2S37     TOTEM                0.1   COST                2.13
    IVS37     BAL37                 -1   EMS37                0.1
    IVS37     BAL38                9.5   TOTIN                  1
    IVS37     COST                 0.3
    UNS37     BAL37                  1   DEM37                  1
    UNS37     TOTSL                  1   COST                  50
    P1S38     BAL38                 10   CAP38                  1
    P1S38     DEM38                  1   EMS38               0.49
    P1S38     RMP38                  1   RMP39                 -1
    P1S38     TOTPR                  1   COST                2.38
    P2S38     BAL38               9.76   CAP38                1.2
    P2S38     DEM38                  1   EMS38              0.214
    P2S38     TOTEM                0.1   COST                2.12
    IVS38     BAL38                -10   EMS38               0.01
    IVS38     BAL39                 95   TOTIN                  1
    IVS38     COST                 0.3
    UNS38     BAL38                 10   DEM38                  1
    UNS38     TOTSL                  1   COST                  50
    P1S39     BAL39                100   CAP39                  1
    P1S39     DEM39                  1   EMS39               49.5
    P1S39     RMP39                  1   TOTPR                  1
    P1S39     COST                2.39
    P2S39     BAL39               97.8   CAP39                1.2
    P2S39     DEM39                  1   EMS39               21.7
    P2S39     TOTEM                0.1   COST                2.11
    IVS39     BAL39               -100   EMS39                  1
    IVS39     TOTIN                  1   COST                 0.3
    UNS39     BAL39                100   DEM39                  1
    UNS39     TOTSL                  1   COST                  50
RHS
    RHS       BAL0                 0.1   CAP0                  18
    RHS       DEM0                   6   EMS0                 600
    RHS       RMP0                   6   BAL1               1.125
    RHS       CAP1                  19   DEM1                6.75
    RHS       EMS1                  61   RMP1                   6
    RHS       BAL2                12.5   CAP2                  20
    RHS       DEM2                 7.5   EMS2                 6.2
    RHS       RMP2                   6   BAL3               137.5
    RHS       CAP3                  21   DEM3                8.25
    RHS       EMS3                 630   RMP3                   6
    RHS       BAL4                1500   CAP4                  22
    RHS       DEM4                   9   EMS4                  64
    RHS       RMP4                   6   BAL5              0.1625
    RHS       CAP5                  18   DEM5                9.75
    RHS       EMS5                 6.5   RMP5                   6
    RHS       BAL6                1.75   CAP6                  19
    RHS       DEM6                10.5   EMS6                 660
    RHS       RMP6                   6   BAL7               11.75
    RHS       CAP7                  20   DEM7                7.05
    RHS       EMS7                  67   RMP7                   6
    RHS       BAL8                 130   CAP8                  21
    RHS       DEM8                 7.8   EMS8                 6.8
    RHS       RMP8                   6   BAL9                1425
    RHS       CAP9                  22   DEM9                8.55
    RHS       EMS9                 690   RMP9                   6
    RHS       BAL10              0.155   CAP10                 18
    RHS       DEM10                9.3   EMS10                 70
    RHS       RMP10                  6   BAL11              1.675
    RHS       CAP11                 19   DEM11              10.05
    RHS       EMS11                7.1   RMP11                  6
    RHS       BAL12                 18   CAP12                 20
    RHS       DEM12               10.8   EMS12                720
    RHS       RMP12                  6   BAL13              192.5
    RHS       CAP13                 21   DEM13              11.55
    RHS       EMS13                 73   RMP13                  6
    RHS       BAL14               1350   CAP14                 22
    RHS       DEM14                8.1   EMS14                7.4
    RHS       RMP14                  6   BAL15             0.1475
    RHS       CAP15                 18   DEM15               8.85
    RHS       EMS15                750   RMP15                  6
    RHS       BAL16                1.6   CAP16                 19
    RHS       DEM16                9.6   EMS16                 76
    RHS       RMP16                  6   BAL17              17.25
    RHS       CAP17                 20   DEM17              10.35
    RHS       EMS17                7.7   RMP17                  6
    RHS       BAL18                185   CAP18                 21
    RHS       DEM18               11.1   EMS18                780
    RHS       RMP18                  6   BAL19               1975
    RHS       CAP19                 22   DEM19              11.85
    RHS       EMS19                 79   RMP19                  6
    RHS       BAL20               0.21   CAP20                 18
    RHS       DEM20               12.6   EMS20                  8
    RHS       RMP20                  6   BAL21              1.525
    RHS       CAP21                 19   DEM21               9.15
    RHS       EMS21                810   RMP21                  6
    RHS       BAL22               16.5   CAP22                 20
    RHS       DEM22                9.9   EMS22                 82
    RHS       RMP22                  6   BAL23              177.5
    RHS       CAP23                 21   DEM23              10.65
    RHS       EMS23                8.3   RMP23                  6
    RHS       BAL24               1900   CAP24                 22
    RHS       DEM24               11.4   EMS24                840
    RHS       RMP24                  6   BAL25             0.2025
    RHS       CAP25                 18   DEM25              12.15
    RHS       EMS25                 85   RMP25                  6
    RHS       BAL26               2.15   CAP26                 19
    RHS       DEM26               12.9   EMS26                8.6
    RHS       RMP26                  6   BAL27              22.75
    RHS       CAP27                 20   DEM27              13.65
    RHS       EMS27                870   RMP27                  6
    RHS       BAL28                170   CAP28                 21
    RHS       DEM28               10.2   EMS28                 88
    RHS       RMP28                  6   BAL29               1825
    RHS       CAP29                 22   DEM29              10.95
    RHS       EMS29                8.9   RMP29                  6
    RHS       BAL30              0.195   CAP30                 18
    RHS       DEM30               11.7   EMS30                900
    RHS       RMP30                  6   BAL31              2.075
    RHS       CAP31                 19   DEM31              12.45
    RHS       EMS31                 91   RMP31                  6
    RHS       BAL32                 22   CAP32                 20
    RHS       DEM32               13.2   EMS32                9.2
    RHS       RMP32                  6   BAL33              232.5
    RHS       CAP33                 21   DEM33              13.95
    RHS       EMS33                930   RMP33                  6
    RHS       BAL34               2450   CAP34                 22
    RHS       DEM34               14.7   EMS34                 94
    RHS       RMP34                  6   BAL35             0.1875
    RHS       CAP35                 18   DEM35              11.25
    RHS       EMS35                9.5   RMP35                  6
    RHS       BAL36                  2   CAP36                 19
    RHS       DEM36                 12   EMS36                960
    RHS       RMP36                  6   BAL37              21.25
    RHS       CAP37                 20   DEM37              12.75
    RHS       EMS37                 97   RMP37                  6
    RHS       BAL38                225   CAP38                 21
    RHS       DEM38               13.5   EMS38                9.8
    RHS       RMP38                  6   BAL39               2375
    RHS       CAP39                 22   DEM39              14.25
    RHS       EMS39                990   RMP39                  6
    RHS       TOTPR                300   TOTSL                426
    RHS       TOTEM                100   TOTIN                  5
RANGES
    RNG       DEM3                   5   TOTIN                 40
BOUNDS
 UP BND       P1S0                  15
 UP BND       P2S0                  12
 UP BND       IVS0                   8
 UP BND       UNS0                  10
 UP BND       P1S1                  15
 UP BND       P2S1                  12
 UP BND       IVS1                   8
 UP BND       UNS1               11.25
 UP BND       P1S2                  15
 UP BND       P2S2                  12
 UP BND       IVS2                   8
 UP BND       UNS2                12.5
 UP BND       P1S3                  15
 UP BND       P2S3                  12
 UP BND       IVS3                   8
 UP BND       UNS3               13.75
 UP BND       P1S4                  15
 UP BND       P2S4                  12
 UP BND       IVS4                   8
 UP BND       UNS4                  15
 UP BND       P1S5                  15
 UP BND       P2S5                  12
 LO BND       IVS5                   1
 UP BND       IVS5                   8
 UP BND       UNS5               16.25
 UP BND       P1S6                  15
 UP BND       P2S6                  12
 UP BND       IVS6                   8
 UP BND       UNS6                17.5
 UP BND       P1S7                  15
 UP BND       P2S7                  12
 UP BND       IVS7                   8
 UP BND       UNS7               11.75
 UP BND       P1S8                  15
 UP BND       P2S8                  12
 UP BND       IVS8                   8
 UP BND       UNS8                  13
 UP BND       P1S9                  15
 UP BND       P2S9                  12
 UP BND       IVS9                   8
 UP BND       UNS9               14.25
 UP BND       P1S10                 15
 UP BND       P2S10                 12
 UP BND       IVS10                  8
 UP BND       UNS10               15.5
 UP BND       P1S11                 15
 UP BND       P2S11                 12
 UP BND       IVS11                  8
 UP BND       UNS11              16.75
 UP BND       P1S12                 15
 UP BND       P2S12                 12
 UP BND       IVS12                  8
 UP BND       UNS12                 18
 UP BND       P1S13                 15
 UP BND       P2S13                 12
 UP BND       IVS13                  8
 UP BND       UNS13              19.25
 UP BND       P1S14                 15
 UP BND       P2S14                 12
 UP BND       IVS14                  8
 UP BND       UNS14               13.5
 UP BND       P1S15                 15
 UP BND       P2S15                 12
 UP BND       IVS15                  8
 UP BND       UNS15              14.75
 UP BND       P1S16                 15
 UP BND       P2S16                 12
 UP BND       IVS16                  8
 UP BND       UNS16                 16
 UP BND       P1S17                 15
 UP BND       P2S17                 12
 UP BND       IVS17                  8
 UP BND       UNS17              17.25
 UP BND       P1S18                 15
 UP BND       P2S18                 12
 UP BND       IVS18                  8
 UP BND       UNS18               18.5
 UP BND       P1S19                 15
 UP BND       P2S19                 12
 UP BND       IVS19                  8
 UP BND       UNS19              19.75
 UP BND       P1S20                 15
 UP BND       P2S20                 12
 UP BND       IVS20                  8
 UP BND       UNS20                 21
 UP BND       P1S21                 15
 UP BND       P2S21                 12
 UP BND       IVS21                  8
 UP BND       UNS21              15.25
 UP BND       P1S22                 15
 UP BND       P2S22                 12
 UP BND       IVS22                  8
 UP BND       UNS22               16.5
 UP BND       P1S23                 15
 UP BND       P2S23                 12
 UP BND       IVS23                  8
 UP BND       UNS23              17.75
 UP BND       P1S24                 15
 UP BND       P2S24                 12
 UP BND       IVS24                  8
 UP BND       UNS24                 19
 UP BND       P1S25                 15
 UP BND       P2S25                 12
 UP BND       IVS25                  8
 UP BND       UNS25              20.25
 UP BND       P1S26                 15
 UP BND       P2S26                 12
 UP BND       IVS26                  8
 UP BND       UNS26               21.5
 UP BND       P1S27                 15
 UP BND       P2S27                 12
 UP BND       IVS27                  8
 UP BND       UNS27              22.75
 UP BND       P1S28                 15
 UP BND       P2S28                 12
 UP BND       IVS28                  8
 UP BND       UNS28                 17
 UP BND       P1S29                 15
 UP BND       P2S29                 12
 UP BND       IVS29                  8
 UP BND       UNS29              18.25
 UP BND       P1S30                 15
 UP BND       P2S30                 12
 UP BND       IVS30                  8
 UP BND       UNS30               19.5
 UP BND       P1S31                 15
 UP BND       P2S31                 12
 UP BND       IVS31                  8
 UP BND       UNS31              20.75
 UP BND       P1S32                 15
 UP BND       P2S32                 12
 UP BND       IVS32                  8
 UP BND       UNS32                 22
 UP BND       P1S33                 15
 UP BND       P2S33                 12
 UP BND       IVS33                  8
 UP BND       UNS33              23.25
 UP BND       P1S34                 15
 UP BND       P2S34                 12
 UP BND       IVS34                  8
 UP BND       UNS34               24.5
 UP BND       P1S35                 15
 UP BND       P2S35                 12
 UP BND       IVS35                  8
 UP BND       UNS35              18.75
 UP BND       P1S36                 15
 UP BND       P2S36                 12
 UP BND       IVS36                  8
 UP BND       UNS36                 20
 UP BND       P1S37                 15
 UP BND       P2S37                 12
 UP BND       IVS37                  8
 UP BND       UNS37              21.25
 UP BND       P1S38                 15
 UP BND       P2S38                 12
 UP BND       IVS38                  8
 UP BND       UNS38               22.5
 UP BND       P1S39                 15
 UP BND       P2S39                 12
 FX BND       IVS39                  2
 UP BND       UNS39              23.75
ENDATA
