* Netlib AFIRO (reconstruction -- see README.md in this directory).
* 27 constraint rows (8 E + 19 L) + COST, 32 columns, 88 nonzeros
* including the objective row.  Minimization; published optimum
* -4.6475314286E+02 is reproduced exactly by this file.
NAME          AFIRO
ROWS
 E  R09
 E  R10
 L  X05
 L  X21
 E  R12
 E  R13
 L  X17
 L  X18
 L  X19
 L  X20
 E  R19
 E  R20
 L  X27
 L  X44
 E  R22
 E  R23
 L  X40
 L  X41
 L  X42
 L  X43
 L  X45
 L  X46
 L  X47
 L  X48
 L  X49
 L  X50
 L  X51
 N  COST
COLUMNS
    X01       X48             .301   R09            -1.
    X01       R10            -1.06   X05             1.
    X02       X21             -1.   R09              1.
    X02       COST            -.4
    X03       X46             -1.   R09              1.
    X04       X50              1.   R10              1.
    X06       X49             .301   R12            -1.
    X06       R13            -1.06   X17             1.
    X07       X49             .313   R12            -1.
    X07       R13            -1.06   X18             1.
    X08       X49             .313   R12            -1.
    X08       R13             -.96   X19             1.
    X09       X49             .326   R12            -1.
    X09       R13             -.86   X20             1.
    X10       X45             2.364  X17            -1.
    X11       X45             2.386  X18            -1.
    X12       X45             2.408  X19            -1.
    X13       X45             2.429  X20            -1.
    X14       X21             1.4    R12             1.
    X14       COST            -.32
    X15       X47             -1.   R12              1.
    X16       X51              1.   R13              1.
    X22       X46             .109   R19            -1.
    X22       R20            -1.06   X27             1.
    X23       X44             -1.   R19              1.
    X23       COST            -.6
    X24       X48             -1.   R19              1.
    X25       X45             -1.   R20              1.
    X26       X50              1.   R20              1.
    X28       X47             .109   R22            -1.
    X28       R23             -.43   X40             1.
    X29       X47             .109   R22            -1.
    X29       R23             -.43   X41             1.
    X30       X47             .108   R22            -1.
    X30       R23             -.39   X42             1.
    X31       X47             .107   R22            -1.
    X31       R23             -.37   X43             1.
    X32       X45             2.364  X40            -1.
    X33       X45             2.386  X41            -1.
    X34       X45             2.408  X42            -1.
    X35       X45             2.429  X43            -1.
    X36       X44             1.4    R22             1.
    X36       COST            -.48
    X37       X49             -1.   R22              1.
    X38       X50              1.   R23              1.
    X39       X51              1.   R23              1.
RHS
    B         X50           310.   X51             300.
    B         X05            80.   X17              80.
    B         X27           500.   R23              44.
    B         X40           500.
ENDATA
