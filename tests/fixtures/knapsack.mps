NAME          knapsack
OBJSENSE
    MAX
ROWS
 L  CAP
 N  COST
COLUMNS
    MARKER                 'MARKER'                 'INTORG'
    X0        CAP                    2   COST                  15
    X1        CAP                   20   COST                 100
    X2        CAP                   20   COST                  90
    X3        CAP                   30   COST                  60
    X4        CAP                   40   COST                  40
    X5        CAP                   30   COST                  15
    X6        CAP                   60   COST                  10
    X7        CAP                   10   COST                   1
    MARKER                 'MARKER'                 'INTEND'
RHS
    RHS       CAP                  102
BOUNDS
 UP BND       X0                     1
 UP BND       X1                     1
 UP BND       X2                     1
 UP BND       X3                     1
 UP BND       X4                     1
 UP BND       X5                     1
 UP BND       X6                     1
 UP BND       X7                     1
ENDATA
