* The canonical fixed-format MPS reference example (IBM MPSX manual;
* reproduced in the format's standard documentation).  Exercises N/L/G/E
* rows and UP/MI bounds.  Optimum: -13 at (x1, x2, x3) = (1, -7, 0).
NAME          TESTPROB
ROWS
 N  COST
 L  LIM1
 G  LIM2
 E  MYEQN
COLUMNS
    X1        COST            1.0   LIM1            1.0
    X1        LIM2            1.0
    X2        COST            2.0   LIM1            1.0
    X2        MYEQN          -1.0
    X3        COST           -1.0   MYEQN           1.0
RHS
    RHS1      LIM1            4.0   LIM2            1.0
    RHS1      MYEQN           7.0
BOUNDS
 UP BND1      X1              4.0
 MI BND1      X2
ENDATA
