NAME          scheduling
ROWS
 L  HOURS0
 L  HOURS1
 G  DEM0
 G  DEM1
 G  DEM2
 N  COST
COLUMNS
    MARKER                 'MARKER'                 'INTORG'
    X00       HOURS0                 3   DEM0                   1
    X00       COST                   4
    X01       HOURS0                 5   DEM1                   1
    X01       COST                   6
    X02       HOURS0                 7   DEM2                   1
    X02       COST                   9
    X10       HOURS1                 3   DEM0                   1
    X10       COST                   5
    X11       HOURS1                 5   DEM1                   1
    X11       COST                   8
    X12       HOURS1                 7   DEM2                   1
    X12       COST                  11
    MARKER                 'MARKER'                 'INTEND'
RHS
    RHS       HOURS0                19   HOURS1                17
    RHS       DEM0                   2   DEM1                   2
    RHS       DEM2                   2
BOUNDS
 UP BND       X00                    3
 UP BND       X01                    3
 UP BND       X02                    3
 UP BND       X10                    3
 UP BND       X11                    3
 UP BND       X12                    3
ENDATA
