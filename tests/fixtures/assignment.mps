NAME          assignment
ROWS
 E  AGENT0
 E  AGENT1
 E  AGENT2
 E  TASK0
 E  TASK1
 E  TASK2
 N  COST
COLUMNS
    MARKER                 'MARKER'                 'INTORG'
    X00       AGENT0                 1   TASK0                  1
    X00       COST                   4
    X01       AGENT0                 1   TASK1                  1
    X01       COST                   1
    X02       AGENT0                 1   TASK2                  1
    X02       COST                   3
    X10       AGENT1                 1   TASK0                  1
    X10       COST                   2
    X11       AGENT1                 1   TASK1                  1
    X12       AGENT1                 1   TASK2                  1
    X12       COST                   5
    X20       AGENT2                 1   TASK0                  1
    X20       COST                   3
    X21       AGENT2                 1   TASK1                  1
    X21       COST                   2
    X22       AGENT2                 1   TASK2                  1
    X22       COST                   2
    MARKER                 'MARKER'                 'INTEND'
RHS
    RHS       AGENT0                 1   AGENT1                 1
    RHS       AGENT2                 1   TASK0                  1
    RHS       TASK1                  1   TASK2                  1
BOUNDS
 UP BND       X00                    1
 UP BND       X01                    1
 UP BND       X02                    1
 UP BND       X10                    1
 UP BND       X11                    1
 UP BND       X12                    1
 UP BND       X20                    1
 UP BND       X21                    1
 UP BND       X22                    1
ENDATA
