"""LP-capacity MoE router: allocation properties."""
import jax.numpy as jnp
import numpy as np

from repro.core import expert_capacity_lp


def test_budget_and_ceiling():
    rng = np.random.default_rng(0)
    demand = jnp.asarray(rng.uniform(0, 50, (3, 8)), jnp.float32)
    caps = np.asarray(expert_capacity_lp(demand, total_slots=128.0, c_max=32.0))
    assert caps.shape == (3, 8)
    assert (caps <= 32.0 + 1e-3).all()
    assert (caps.sum(-1) <= 128.0 + 1e-2).all()
    assert (caps <= np.asarray(demand) + 1e-3).all()


def test_hot_expert_gets_more():
    demand = jnp.asarray([[100.0, 1.0, 1.0, 1.0]], jnp.float32)
    caps = np.asarray(expert_capacity_lp(demand, total_slots=16.0, c_max=12.0))
    assert caps[0, 0] >= 11.9  # hot expert saturates its ceiling
    assert caps[0, 0] > caps[0, 1]
