"""End-to-end: tiny LM trains (loss decreases) and resumes deterministically."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataPipeline
from repro.distributed.steps import make_train_step
from repro.models import build_model
from repro.optim import get_optimizer


def _setup(seed=0):
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    opt = get_optimizer("adamw", lr=3e-3, warmup=10)
    step_fn = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    data = DataPipeline(vocab=cfg.vocab, batch=8, seq=32, seed=seed)
    return cfg, model, params, opt_state, step_fn, data


def test_loss_decreases():
    cfg, model, params, opt_state, step_fn, data = _setup()
    losses = []
    for s in range(30):
        b = data.batch_at(s)
        params, opt_state, m = step_fn(params, opt_state,
                                       jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatched_grads_match_full():
    from repro.distributed.steps import make_train_step
    cfg, model, params, opt_state, _, data = _setup()
    opt = get_optimizer("adamw", lr=3e-3)
    full = make_train_step(model, opt, microbatches=1)
    micro = make_train_step(model, opt, microbatches=4)
    b = jax.tree.map(jnp.asarray, data.batch_at(0))
    p1, _, m1 = jax.jit(full)(params, opt_state, b)
    p2, _, m2 = jax.jit(micro)(params, opt_state, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    d = max(float(jnp.max(jnp.abs(a - b2)))
            for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3


def test_checkpoint_resume_is_deterministic(tmp_path):
    cfg, model, params, opt_state, step_fn, data = _setup()
    mgr = CheckpointManager(str(tmp_path))
    for s in range(5):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt_state, m = step_fn(params, opt_state, b)
    mgr.save(5, {"params": params, "opt": opt_state}, extra={"data_step": 5})
    # continue 3 more
    ref, opt_ref = params, opt_state
    for s in range(5, 8):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        ref, opt_ref, _ = step_fn(ref, opt_ref, b)
    # simulated restart: restore & replay from the recorded data step
    state = mgr.restore(5, {"params": params, "opt": opt_state})
    p2, o2 = state["params"], state["opt"]
    assert mgr.extra(5)["data_step"] == 5
    for s in range(5, 8):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        p2, o2, _ = step_fn(p2, o2, b)
    for a, b2 in zip(jax.tree.leaves(ref), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=1e-6)
