"""Pallas kernel sweeps (interpret=True) vs the pure-jnp oracle."""
import numpy as np
import pytest

from repro.core import OPTIMAL, random_lp_batch, solve_batched_reference
from repro.core.hyperbox import solve_hyperbox_ref
from repro.kernels import (pick_tile_b, solve_batched_pallas,
                           solve_hyperbox_pallas)

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("m,n", [(5, 5), (10, 6), (28, 28), (50, 40)])
@pytest.mark.parametrize("feas", [True, False])
@pytest.mark.parametrize("tile_b", [1, 8, 32])
def test_simplex_kernel_sweep(m, n, feas, tile_b):
    batch = random_lp_batch(RNG, B=19, m=m, n=n, feasible_start=feas)
    ref = solve_batched_reference(batch)
    pal = solve_batched_pallas(batch, tile_b=tile_b)
    assert (ref.status == pal.status).mean() >= 0.95
    ok = (ref.status == OPTIMAL) & (pal.status == OPTIMAL)
    rel = np.abs(ref.objective[ok] - pal.objective[ok]) / np.abs(ref.objective[ok])
    assert rel.max() < 2e-3


def test_kernel_matches_jax_backend_bitwise_statuses():
    from repro.core import solve_batched_jax
    batch = random_lp_batch(RNG, B=33, m=12, n=8)
    jx = solve_batched_jax(batch)
    pal = solve_batched_pallas(batch, tile_b=8)
    np.testing.assert_array_equal(jx.status, pal.status)
    np.testing.assert_array_equal(jx.iterations, pal.iterations)


def test_tile_policy_respects_vmem():
    tb_small = pick_tile_b(300, 300, vmem_budget=2 << 20)
    tb_big = pick_tile_b(300, 300, vmem_budget=16 << 20)
    assert tb_small >= 1 and tb_big >= tb_small
    rows = 302
    cols = ((300 + 600 + 1) + 127) // 128 * 128
    assert tb_big * rows * cols * 4 <= (16 << 20) * 1.1


@pytest.mark.parametrize("n", [3, 7, 64, 130])
@pytest.mark.parametrize("dtype", ["float32"])
def test_hyperbox_kernel_sweep(n, dtype):
    lo = RNG.uniform(-4, 0, (57, n)).astype(dtype)
    hi = (lo + RNG.uniform(0.1, 3, (57, n))).astype(dtype)
    d = RNG.normal(size=(57, n)).astype(dtype)
    out = solve_hyperbox_pallas(lo, hi, d, tile_b=16)
    np.testing.assert_allclose(out, solve_hyperbox_ref(lo, hi, d),
                               rtol=2e-5, atol=1e-5)
