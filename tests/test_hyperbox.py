"""Hyperbox special case (paper Sec. 5.6) vs general simplex."""
import numpy as np

from repro.core import (OPTIMAL, hyperbox_as_general_lp, solve_batched_jax,
                        solve_hyperbox, solve_hyperbox_ref)
import jax.numpy as jnp

RNG = np.random.default_rng(13)


def test_matches_simplex_on_box_lps():
    lo = RNG.uniform(-5, 0, (40, 6))
    hi = lo + RNG.uniform(0.5, 4, (40, 6))
    d = RNG.normal(size=(40, 6))
    fast = np.asarray(solve_hyperbox(jnp.asarray(lo), jnp.asarray(hi),
                                     jnp.asarray(d)))
    lp, off = hyperbox_as_general_lp(lo, hi, d)
    res = solve_batched_jax(lp)
    assert (res.status == OPTIMAL).all()
    np.testing.assert_allclose(fast, res.objective + off, rtol=1e-4)


def test_direction_broadcast():
    lo = RNG.uniform(-1, 0, (9, 4))
    hi = lo + 1.0
    dirs = RNG.normal(size=(5, 4))
    out = np.asarray(solve_hyperbox(jnp.asarray(lo), jnp.asarray(hi),
                                    jnp.asarray(dirs)))
    assert out.shape == (9, 5)
    np.testing.assert_allclose(out, solve_hyperbox_ref(lo, hi, dirs), rtol=1e-5)
